//! Property-based testing substrate (proptest is unavailable offline):
//! seeded random-case generation with failing-seed reporting and a
//! simple shrink-by-replay knob (re-run a specific case via env var).
//!
//! Usage:
//! ```ignore
//! property("ordering invariant", 500, |rng| {
//!     let xs = gen_vec(rng, 0..=32, |r| r.uniform(0.0, 1.0));
//!     check(is_sorted(&sorted(xs)), "sorted output")
//! });
//! ```
//! On failure the macro panics with the case seed; re-run only that case
//! with `STANNIC_PROP_SEED=<seed> cargo test <name>`.

use crate::workload::Rng;

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Convenience assertion for property bodies.
pub fn check(cond: bool, what: &str) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(what.to_string())
    }
}

/// Run `cases` random cases of `body`, each with a deterministic
/// per-case RNG. Panics with the failing case seed on first failure.
pub fn property<F: FnMut(&mut Rng) -> CaseResult>(name: &str, cases: u64, mut body: F) {
    // Replay mode: run exactly one pinned case.
    if let Ok(seed) = std::env::var("STANNIC_PROP_SEED") {
        let seed: u64 = seed.parse().expect("STANNIC_PROP_SEED must be a u64");
        let mut rng = Rng::new(seed);
        if let Err(e) = body(&mut rng) {
            panic!("property '{name}' failed on replayed seed {seed}: {e}");
        }
        return;
    }
    let base = 0x57a2_21c5_0c0f_fee0u64;
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let mut rng = Rng::new(seed);
        if let Err(e) = body(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed}): {e}\n\
                 replay with: STANNIC_PROP_SEED={seed}"
            );
        }
    }
}

/// Generate a vector whose length is drawn from `len_range`.
pub fn gen_vec<T, F: FnMut(&mut Rng) -> T>(
    rng: &mut Rng,
    min_len: usize,
    max_len: usize,
    mut item: F,
) -> Vec<T> {
    let n = rng.range(min_len, max_len);
    (0..n).map(|_| item(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_passes_on_tautology() {
        property("tautology", 50, |rng| {
            let x = rng.uniform(0.0, 1.0);
            check((0.0..1.0).contains(&x), "uniform in range")
        });
    }

    #[test]
    #[should_panic(expected = "property 'falsum'")]
    fn property_reports_failing_seed() {
        property("falsum", 10, |rng| {
            let x = rng.uniform(0.0, 1.0);
            check(x < 0.0, "impossible")
        });
    }

    #[test]
    fn gen_vec_respects_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let v = gen_vec(&mut rng, 2, 5, |r| r.next_u64());
            assert!((2..=5).contains(&v.len()));
        }
    }
}
