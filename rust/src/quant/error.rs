//! Quantization error metrics for Fig. 7b/c/d: per-attribute percentage
//! error vs the FP32 baseline and schedule-distribution divergence.

use super::Precision;

/// Mean absolute percentage error of the WSPT ratio across a population
/// of (weight, ept) samples (Fig. 7d).
pub fn wspt_error_pct(p: Precision, samples: &[(f32, f32)]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0f64;
    for &(w, e) in samples {
        let exact = (w / e) as f64;
        let (_, _, tq) = p.q_job(w, e);
        acc += ((tq as f64 - exact) / exact).abs();
    }
    100.0 * acc / samples.len() as f64
}

/// Mean absolute percentage error of the alpha release point (Fig. 7c).
pub fn alpha_error_pct(p: Precision, alpha: f32, samples: &[(f32, f32)]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0f64;
    for &(_, e) in samples {
        let exact = (alpha * e).ceil() as f64;
        let q = p.alpha_point(alpha, e) as f64;
        acc += ((q - exact) / exact).abs();
    }
    100.0 * acc / samples.len() as f64
}

/// L1 divergence between two per-machine job-count distributions,
/// normalized to [0, 1] (0 = identical schedules; Fig. 7b's comparison of
/// each scheme's distribution against FP32).
pub fn distribution_divergence(a: &[usize], b: &[usize]) -> f64 {
    assert_eq!(a.len(), b.len());
    let ta: usize = a.iter().sum();
    let tb: usize = b.iter().sum();
    if ta == 0 || tb == 0 {
        return if ta == tb { 0.0 } else { 1.0 };
    }
    let mut l1 = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        l1 += (x as f64 / ta as f64 - y as f64 / tb as f64).abs();
    }
    l1 / 2.0
}

/// One row of the Fig. 7 study for a given precision scheme.
#[derive(Debug, Clone)]
pub struct QuantErrorReport {
    pub precision: Precision,
    pub wspt_err_pct: f64,
    pub alpha_err_pct: f64,
    pub distribution_div: f64,
    pub jobs_per_machine: Vec<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn samples() -> Vec<(f32, f32)> {
        let mut v = Vec::new();
        let mut w = 1.0f32;
        let mut e = 10.0f32;
        for _ in 0..200 {
            v.push((w, e));
            w = 1.0 + (w * 7.3) % 254.0;
            e = 10.0 + (e * 3.1) % 245.0;
        }
        v
    }

    #[test]
    fn fp32_has_zero_error() {
        let s = samples();
        assert_eq!(wspt_error_pct(Precision::Fp32, &s), 0.0);
        assert_eq!(alpha_error_pct(Precision::Fp32, 0.5, &s), 0.0);
    }

    #[test]
    fn error_ordering_matches_paper_narrative() {
        // Section 4.2: INT8 has the second-highest WSPT error (INT4's
        // coarse EPT scale actually *helps* its WSPT ratio there), but
        // INT8's alpha error is lower than INT4's and Mixed's.
        let s = samples();
        let a_int8 = alpha_error_pct(Precision::Int8, 0.5, &s);
        let a_int4 = alpha_error_pct(Precision::Int4, 0.5, &s);
        assert!(
            a_int8 < a_int4,
            "INT8 alpha err {a_int8} should be < INT4 {a_int4}"
        );
        let w_fp16 = wspt_error_pct(Precision::Fp16, &s);
        let w_int8 = wspt_error_pct(Precision::Int8, &s);
        assert!(w_fp16 < w_int8, "FP16 WSPT err should be < INT8");
    }

    #[test]
    fn divergence_bounds() {
        assert_eq!(distribution_divergence(&[10, 0], &[10, 0]), 0.0);
        assert_eq!(distribution_divergence(&[10, 0], &[0, 10]), 1.0);
        let half = distribution_divergence(&[5, 5], &[10, 0]);
        assert!((half - 0.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn divergence_requires_same_len() {
        distribution_divergence(&[1], &[1, 2]);
    }
}
