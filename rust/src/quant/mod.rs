//! Numerical-precision schemes for the quantization study (Section 4.2,
//! Fig. 7). The scheduler datapath stores three derived quantities per
//! job — weight `W`, per-machine EPT `eps`, and the WSPT ratio `T = W/eps`
//! — plus the alpha release point `ceil(alpha*eps)`. Each scheme fixes a
//! representation for every attribute; `INT8` is the paper's choice.

mod error;

pub use error::{alpha_error_pct, wspt_error_pct, distribution_divergence, QuantErrorReport};

use crate::core::{f16_round, fixed_round};

/// A numerical precision scheme for the scheduler datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Full FP32 — the accuracy baseline of Fig. 7.
    Fp32,
    /// IEEE binary16 for every attribute.
    Fp16,
    /// The paper's selected scheme: 8-bit integer weight & EPT, WSPT in
    /// UQ4.4 fixed point (max 255/10 = 25.5 needs saturation; UQ4.4 tops
    /// at 15.94 — saturation is part of the modeled behaviour).
    Int8,
    /// 4-bit integers: weight & EPT stored in 4 bits (EPT pre-scaled by
    /// 1/16), WSPT in UQ2.2.
    Int4,
    /// Mixed: INT8 weight, INT4 EPT (x16 scale), WSPT in UQ4.4 — the
    /// "Mixed" row of Fig. 7a. EPT coarseness gives it INT4-like alpha
    /// error while the 8-bit weight keeps cost magnitudes accurate,
    /// matching the paper's observation that Mixed (like INT4) releases
    /// jobs earlier than intended.
    Mixed,
}

impl Precision {
    pub const ALL: [Precision; 5] = [
        Precision::Fp32,
        Precision::Fp16,
        Precision::Int8,
        Precision::Int4,
        Precision::Mixed,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Precision::Fp32 => "FP32",
            Precision::Fp16 => "FP16",
            Precision::Int8 => "INT8",
            Precision::Int4 => "INT4",
            Precision::Mixed => "Mixed",
        }
    }

    /// Storage bits per job attribute (W, eps, T) — Fig. 7a's scheme table.
    pub fn attribute_bits(&self) -> (u32, u32, u32) {
        match self {
            Precision::Fp32 => (32, 32, 32),
            Precision::Fp16 => (16, 16, 16),
            Precision::Int8 => (8, 8, 8),
            Precision::Int4 => (4, 4, 4),
            Precision::Mixed => (8, 4, 8),
        }
    }

    /// Quantize a job weight. Minimum weight is 1 (Section 4.2).
    pub fn q_weight(&self, w: f32) -> f32 {
        match self {
            Precision::Fp32 => w,
            Precision::Fp16 => f16_round(w),
            Precision::Int8 => fixed_round(w, 8, 0).max(1.0),
            Precision::Int4 => fixed_round(w, 4, 0).max(1.0),
            Precision::Mixed => fixed_round(w, 8, 0).max(1.0),
        }
    }

    /// Quantize an expected processing time. Minimum EPT is 10
    /// (Section 4.2), except in sub-8-bit schemes where the scale factor
    /// absorbs it.
    pub fn q_ept(&self, e: f32) -> f32 {
        match self {
            Precision::Fp32 => e,
            Precision::Fp16 => f16_round(e),
            Precision::Int8 => fixed_round(e, 8, 0).max(1.0),
            // INT4 EPT is stored as a 4-bit mantissa at x16 scale:
            // representable values are {16, 32, ..., 240}.
            Precision::Int4 => (fixed_round(e / 16.0, 4, 0) * 16.0).max(16.0),
            Precision::Mixed => (fixed_round(e / 16.0, 4, 0) * 16.0).max(16.0),
        }
    }

    /// Quantize a WSPT ratio (computed from already-quantized W and eps —
    /// the scheduler stores T to avoid repeated division, Section 3.3).
    pub fn q_wspt(&self, t: f32) -> f32 {
        match self {
            Precision::Fp32 => t,
            Precision::Fp16 => f16_round(t),
            Precision::Int8 => fixed_round(t, 4, 4),
            Precision::Int4 => fixed_round(t, 2, 2),
            Precision::Mixed => fixed_round(t, 4, 4),
        }
    }

    /// Full attribute pipeline: quantize (W, eps) then derive and
    /// quantize T = W/eps. Returns (w_q, eps_q, t_q).
    pub fn q_job(&self, w: f32, eps: f32) -> (f32, f32, f32) {
        let wq = self.q_weight(w);
        let eq = self.q_ept(eps);
        let tq = self.q_wspt(wq / eq);
        (wq, eq, tq)
    }

    /// Alpha release point under this precision: `ceil(alpha * eps_q)`.
    pub fn alpha_point(&self, alpha: f32, eps: f32) -> u32 {
        (alpha * self.q_ept(eps)).ceil() as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp32_is_identity() {
        let p = Precision::Fp32;
        assert_eq!(p.q_job(3.7, 42.3), (3.7, 42.3, 3.7 / 42.3));
    }

    #[test]
    fn int8_rounds_to_integers() {
        let p = Precision::Int8;
        let (w, e, t) = p.q_job(3.7, 42.3);
        assert_eq!(w, 4.0);
        assert_eq!(e, 42.0);
        // T = 4/42 = 0.0952 -> UQ4.4 nearest = 0.0625 or 0.125
        assert!((t - 0.0625).abs() < 1e-6 || (t - 0.125).abs() < 1e-6);
    }

    #[test]
    fn int8_saturates_at_255() {
        let p = Precision::Int8;
        assert_eq!(p.q_weight(300.0), 255.0);
        assert_eq!(p.q_ept(300.0), 255.0);
    }

    #[test]
    fn int4_ept_scale() {
        let p = Precision::Int4;
        assert_eq!(p.q_ept(100.0), 96.0); // 100/16=6.25 -> 6 -> 96
        assert_eq!(p.q_ept(250.0), 240.0); // saturate at 15*16
        assert_eq!(p.q_ept(5.0), 16.0); // floor of the scheme
    }

    #[test]
    fn mixed_is_int8_weight_int4_ept() {
        let p = Precision::Mixed;
        assert_eq!(p.q_weight(200.0), 200.0);
        assert_eq!(p.q_ept(200.0), 208.0); // 200/16=12.5 -> rounds to 13 -> 208
        assert_eq!(p.attribute_bits(), (8, 4, 8));
    }

    #[test]
    fn weight_floor_is_one() {
        for p in Precision::ALL {
            assert!(p.q_weight(1.0) >= 1.0, "{}", p.name());
        }
    }

    #[test]
    fn alpha_point_matches_ceil() {
        let p = Precision::Fp32;
        assert_eq!(p.alpha_point(0.5, 21.0), 11);
        assert_eq!(p.alpha_point(1.0, 21.0), 21);
        assert_eq!(p.alpha_point(0.1, 21.0), 3);
    }

    #[test]
    fn attribute_bits_table() {
        assert_eq!(Precision::Int8.attribute_bits(), (8, 8, 8));
        assert_eq!(Precision::Mixed.attribute_bits(), (8, 4, 8));
    }
}
