//! Command-line argument substrate (clap is unavailable offline):
//! subcommand + `--flag value` / `--flag` parsing with typed accessors
//! and generated usage text. Accessors return
//! [`crate::error::Result`], so command handlers propagate flag errors
//! with bare `?` instead of string-shimming.

use std::collections::BTreeMap;

use crate::err;
use crate::error::{Ctx, Result};

/// Parsed arguments: a subcommand, positionals, and `--key value` flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positionals: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
}

/// A flag specification for usage text + validation. `help` is owned so
/// callers can interpolate single-source-of-truth strings (e.g. the
/// engine registry's accepted-names list) instead of hand-copying them.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: String,
    pub takes_value: bool,
}

impl FlagSpec {
    pub fn new(name: &'static str, help: impl Into<String>, takes_value: bool) -> FlagSpec {
        FlagSpec {
            name,
            help: help.into(),
            takes_value,
        }
    }
}

impl Args {
    /// Parse raw arguments (without argv[0]). Flags may appear anywhere;
    /// the first non-flag token is the subcommand, the rest positionals.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, specs: &[FlagSpec]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == name)
                    .with_ctx(|| format!("unknown flag --{name}"))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .with_ctx(|| format!("--{name} requires a value"))?,
                    };
                    out.flags.insert(name.to_string(), v);
                } else {
                    if inline.is_some() {
                        return Err(err!("--{name} takes no value"));
                    }
                    out.bools.push(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| err!("--{name}: expected integer ({e})")),
        }
    }

    pub fn u64_flag(&self, name: &str, default: u64) -> Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| err!("--{name}: expected integer ({e})")),
        }
    }

    pub fn f32_flag(&self, name: &str, default: f32) -> Result<f32> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| err!("--{name}: expected number ({e})")),
        }
    }

    pub fn str_flag<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }
}

/// Render usage text from specs.
pub fn usage(program: &str, commands: &[(&str, &str)], specs: &[FlagSpec]) -> String {
    let mut s = format!("usage: {program} <command> [flags]\n\ncommands:\n");
    for (name, help) in commands {
        s.push_str(&format!("  {name:<14} {help}\n"));
    }
    s.push_str("\nflags:\n");
    for f in specs {
        let v = if f.takes_value { " <value>" } else { "" };
        s.push_str(&format!("  --{}{v:<10} {}\n", f.name, f.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<FlagSpec> {
        vec![
            FlagSpec::new("machines", "machine count", true),
            FlagSpec::new("quick", "fast mode", false),
        ]
    }

    fn parse(args: &[&str]) -> Result<Args> {
        Args::parse(args.iter().map(|s| s.to_string()), &specs())
    }

    #[test]
    fn parses_subcommand_flags_positionals() {
        let a = parse(&["run", "--machines", "10", "trace.txt", "--quick"]).unwrap();
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.flag("machines"), Some("10"));
        assert!(a.has("quick"));
        assert_eq!(a.positionals, vec!["trace.txt"]);
        assert_eq!(a.usize_flag("machines", 5).unwrap(), 10);
        assert_eq!(a.usize_flag("depth", 7).unwrap(), 7);
    }

    #[test]
    fn inline_equals_form() {
        let a = parse(&["run", "--machines=42"]).unwrap();
        assert_eq!(a.usize_flag("machines", 0).unwrap(), 42);
    }

    #[test]
    fn rejects_unknown_and_malformed() {
        assert!(parse(&["run", "--nope"]).is_err());
        assert!(parse(&["run", "--machines"]).is_err());
        assert!(parse(&["run", "--quick=1"]).is_err());
        let a = parse(&["run", "--machines", "abc"]).unwrap();
        assert!(a.usize_flag("machines", 0).is_err());
    }

    #[test]
    fn usage_mentions_everything() {
        let u = usage("stannic", &[("report", "render a figure")], &specs());
        assert!(u.contains("report"));
        assert!(u.contains("--machines"));
        assert!(u.contains("--quick"));
    }
}
