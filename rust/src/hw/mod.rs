//! FPGA substrate models: the Alveo U55C envelope, analytical LUT/FF
//! resource estimation, routing-congestion feasibility, and power —
//! the pieces of the paper's evaluation we must simulate in lieu of
//! Vivado synthesis + xbtop on real hardware (see DESIGN.md §1).

pub mod fpga;
pub mod power;
pub mod resources;
pub mod routing;

pub use fpga::{Fabric, CLOCK_HZ, IDLE_WATTS, U55C};
pub use resources::Resources;
pub use routing::Routability;
