//! Target-device envelope: the AMD Alveo U55C card used by the paper
//! (Section 7.1) and the synthesized design's operating point.

/// Alveo U55C fabric resources (XCU55C, from the product brief).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fabric {
    pub luts: u64,
    pub ffs: u64,
    /// Abstract routing capacity in congestion units (see
    /// [`super::routing`]); calibrated so the paper's max-routable
    /// boundaries (10 machines Hercules / 140 Stannic) are reproduced.
    pub routing_capacity: f64,
}

/// The U55C as modeled here.
pub const U55C: Fabric = Fabric {
    luts: 1_303_680,
    ffs: 2_607_360,
    routing_capacity: 100_000.0,
};

/// Synthesized clock of both designs (Section 7.1): 371.47 MHz.
pub const CLOCK_HZ: f64 = 371_470_000.0;

/// Idle power draw of the card with a bitstream loaded (Section 8.3.3:
/// the scheduler "barely brings the Alveo U55C above its idle power").
pub const IDLE_WATTS: f64 = 20.4;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_period_ns() {
        let period_ns = 1e9 / CLOCK_HZ;
        assert!((period_ns - 2.692).abs() < 0.01);
    }

    #[test]
    fn fabric_sizes_sane() {
        assert!(U55C.luts > 1_000_000);
        assert_eq!(U55C.ffs, 2 * U55C.luts);
    }
}
