//! Analytical LUT/FF resource model (Fig. 18b/18c).
//!
//! Per-component cost functions of (M machines, d depth) derived from
//! the datapath widths of Sections 4/6 (8-bit attributes, 24+x-bit JMM
//! registers, N-1 adders per tree, CAM of size N, one PE per V_i slot)
//! with per-component unit costs calibrated so the C1–C4 averages land
//! on the paper's synthesis results:
//!
//! * Hercules: 218,762 LUTs / 118,086 FFs (avg over C1–C4)
//! * Stannic:   97,607 LUTs /  56,284 FFs
//!
//! The model preserves the *scaling shape*: both designs grow with M·d
//! (per-job tracking hardware), Hercules with a much larger coefficient
//! (IJCC duplication + tree adders + three-way coherency logic) and a
//! heavier per-machine fixed block (MMU + CAM + batch-interface port).

/// Resource estimate for one design point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Resources {
    pub luts: u64,
    pub ffs: u64,
}

/// HERCULES per-unit costs (LUTs, FFs).
mod hercules_costs {
    /// IJCC: two 8-bit mul-ish datapaths + comparator + masks (Fig. 6b).
    pub const IJCC: (u64, u64) = (640, 260);
    /// Per tree-adder node (two per CC, N-1 nodes each).
    pub const TREE_NODE: (u64, u64) = (90, 40);
    /// JMM register + write-port decode per slot (24+x bits, Fig. 5).
    pub const JMM_SLOT: (u64, u64) = (120, 230);
    /// VSM register + 4-way data selector per slot (Fig. 6d).
    pub const VSM_SLOT: (u64, u64) = (110, 60);
    /// AC CAM way (tag compare + countdown) per slot.
    pub const CAM_WAY: (u64, u64) = (150, 70);
    /// Per-machine fixed: MMU (LUT table + FIFO), batch-interface port,
    /// cost-comparator stage, control FSMs.
    pub const MACHINE_FIXED: (u64, u64) = (8000, 4000);
    /// Global fixed: host interface, batch table control, CR core.
    pub const GLOBAL: (u64, u64) = (24000, 9300);
}

/// STANNIC per-unit costs (LUTs, FFs).
mod stannic_costs {
    /// One PE: MEM (id, T, n, alpha, two memoized sums) + local ALU + CU.
    pub const PE: (u64, u64) = (440, 260);
    /// Per-machine fixed: SMMU cost calculator, broadcast/cost bus
    /// drivers, head-PE alpha check.
    pub const MACHINE_FIXED: (u64, u64) = (2600, 1200);
    /// Global fixed: host interface + shared cost comparator.
    pub const GLOBAL: (u64, u64) = (28600, 18000);
}

/// HERCULES resource estimate.
pub fn hercules(machines: usize, depth: usize) -> Resources {
    use hercules_costs::*;
    let m = machines as u64;
    let d = depth as u64;
    let per_slot =
        IJCC.0 + TREE_NODE.0 * 2 + JMM_SLOT.0 + VSM_SLOT.0 + CAM_WAY.0;
    let per_slot_ff =
        IJCC.1 + TREE_NODE.1 * 2 + JMM_SLOT.1 + VSM_SLOT.1 + CAM_WAY.1;
    Resources {
        luts: GLOBAL.0 + m * MACHINE_FIXED.0 + m * d * per_slot,
        ffs: GLOBAL.1 + m * MACHINE_FIXED.1 + m * d * per_slot_ff,
    }
}

/// STANNIC resource estimate.
pub fn stannic(machines: usize, depth: usize) -> Resources {
    use stannic_costs::*;
    let m = machines as u64;
    let d = depth as u64;
    Resources {
        luts: GLOBAL.0 + m * MACHINE_FIXED.0 + m * d * PE.0,
        ffs: GLOBAL.1 + m * MACHINE_FIXED.1 + m * d * PE.1,
    }
}

/// The paper's four comparison configurations (Section 7.2.1).
pub const PAPER_CONFIGS: [(usize, usize); 4] = [(5, 10), (5, 20), (10, 10), (10, 20)];

/// Average resources over the paper configs.
pub fn average<F: Fn(usize, usize) -> Resources>(f: F) -> Resources {
    let mut luts = 0;
    let mut ffs = 0;
    for &(m, d) in &PAPER_CONFIGS {
        let r = f(m, d);
        luts += r.luts;
        ffs += r.ffs;
    }
    Resources {
        luts: luts / 4,
        ffs: ffs / 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hercules_average_calibrated() {
        let avg = average(hercules);
        let lut_err = (avg.luts as f64 - 218_762.0).abs() / 218_762.0;
        let ff_err = (avg.ffs as f64 - 118_086.0).abs() / 118_086.0;
        assert!(lut_err < 0.03, "LUT avg {} err {lut_err}", avg.luts);
        assert!(ff_err < 0.03, "FF avg {} err {ff_err}", avg.ffs);
    }

    #[test]
    fn stannic_average_calibrated() {
        let avg = average(stannic);
        let lut_err = (avg.luts as f64 - 97_607.0).abs() / 97_607.0;
        let ff_err = (avg.ffs as f64 - 56_284.0).abs() / 56_284.0;
        assert!(lut_err < 0.03, "LUT avg {} err {lut_err}", avg.luts);
        assert!(ff_err < 0.03, "FF avg {} err {ff_err}", avg.ffs);
    }

    #[test]
    fn stannic_uses_less_than_half_of_hercules() {
        // Section 8.3.2: 2.24x fewer LUTs, 2.1x fewer FFs.
        let h = average(hercules);
        let s = average(stannic);
        let lut_ratio = h.luts as f64 / s.luts as f64;
        let ff_ratio = h.ffs as f64 / s.ffs as f64;
        assert!((2.0..2.5).contains(&lut_ratio), "LUT ratio {lut_ratio}");
        assert!((1.9..2.3).contains(&ff_ratio), "FF ratio {ff_ratio}");
    }

    #[test]
    fn luts_exceed_ffs_everywhere() {
        // Section 8.3.2: "Across all configurations in both designs, the
        // LUT usage was higher than the FF usage".
        for &(m, d) in &PAPER_CONFIGS {
            assert!(hercules(m, d).luts > hercules(m, d).ffs);
            assert!(stannic(m, d).luts > stannic(m, d).ffs);
        }
    }

    #[test]
    fn monotone_in_configuration() {
        assert!(hercules(10, 20).luts > hercules(5, 10).luts);
        assert!(stannic(10, 20).luts > stannic(5, 10).luts);
    }
}
