//! Power model (Fig. 16b's FPC column and Section 8.3.3).
//!
//! The paper's xbtop measurements show *flat* draw: every configuration
//! of both designs lands at ~21 W, "negligibly" above the card's idle.
//! We model: card idle + small dynamic term proportional to toggled
//! flip-flops (activity-scaled) + a deterministic measurement jitter
//! standing in for xbtop's sampling noise.

use super::fpga::IDLE_WATTS;
use super::resources::Resources;

/// Dynamic watts per toggling FF at 371 MHz with the observed activity
/// factor (calibrated so the fleet of paper configs spans ~20.7–21.4 W).
const WATTS_PER_FF: f64 = 4.0e-6;

/// Deterministic stand-in for measurement jitter: hash the config to
/// +-0.25 W. Same config -> same "measurement", like re-running xbtop on
/// the same bitstream.
fn jitter(machines: usize, depth: usize, salt: u64) -> f64 {
    let mut h = (machines as u64)
        .wrapping_mul(0x9e37_79b9)
        .wrapping_add((depth as u64).wrapping_mul(0x85eb_ca6b))
        .wrapping_add(salt);
    h ^= h >> 13;
    h = h.wrapping_mul(0xc2b2_ae35);
    h ^= h >> 16;
    ((h % 500) as f64 / 1000.0) - 0.25
}

/// Estimated average draw of a design under load.
pub fn watts(resources: Resources, machines: usize, depth: usize, salt: u64) -> f64 {
    IDLE_WATTS + WATTS_PER_FF * resources.ffs as f64 + jitter(machines, depth, salt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::resources::{hercules, stannic, PAPER_CONFIGS};

    #[test]
    fn all_paper_configs_near_21_watts() {
        for &(m, d) in &PAPER_CONFIGS {
            for (r, salt) in [(hercules(m, d), 1), (stannic(m, d), 2)] {
                let w = watts(r, m, d, salt);
                assert!(
                    (20.4..21.6).contains(&w),
                    "{m}x{d}: {w} W outside the paper's envelope"
                );
            }
        }
    }

    #[test]
    fn stannic_140_machines_still_cool() {
        // Section 8.3.3: the 140-machine Stannic config holds ~the same
        // power draw.
        let w = watts(stannic(140, 10), 140, 10, 2);
        assert!(w < 22.5, "140-machine draw {w} W");
    }

    #[test]
    fn deterministic_measurements() {
        let a = watts(hercules(5, 10), 5, 10, 1);
        let b = watts(hercules(5, 10), 5, 10, 1);
        assert_eq!(a, b);
    }
}
