//! Routing-congestion feasibility model (Fig. 18d).
//!
//! The paper's scalability boundary is a *routing* failure, not a LUT
//! shortage: Hercules's decentralized JMM/VSM/MMU triplet requires every
//! component to communicate with every other about arbitrarily ordered
//! data, plus an any-machine-to-any-entry batch interface table — wiring
//! demand that grows ~quadratically with machine count. Stannic's PEs
//! talk only to their immediate neighbours and two shared buses, so its
//! demand grows linearly and the boundary moves out 14x.
//!
//! The model scores interconnect demand in abstract congestion units and
//! declares a design routable while demand <= the fabric's capacity
//! (and its LUTs fit). Coefficients are calibrated to the paper's
//! boundaries: Hercules routes at 10 machines and fails at 20 (the
//! paper's 10-machine step resolution), Stannic routes at 140 and fails
//! at 150.

use super::fpga::Fabric;
#[cfg(test)]
use super::fpga::U55C;
use super::resources;

/// Interconnect demand of a HERCULES instance.
///
/// * `M^2` term: the iterative cost comparator and batch-interface table
///   give every machine a path to every other machine's result lanes,
///   and the MMU/VSM/JMM coherency web multiplies per-machine wiring.
/// * `M·d` term: each tracked job's metadata fans out from JMM to CC to
///   VSM across component boundaries.
pub fn hercules_congestion(machines: usize, depth: usize) -> f64 {
    let m = machines as f64;
    let d = depth as f64;
    760.0 * m * m + 18.0 * m * d
}

/// Interconnect demand of a STANNIC instance: per-machine bus drops plus
/// per-PE neighbour links (local, cheap) and the shared comparator fan-in.
pub fn stannic_congestion(machines: usize, depth: usize) -> f64 {
    let m = machines as f64;
    let d = depth as f64;
    680.0 * m + 6.0 * m * d / 10.0
}

/// Routability verdict for a design point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Routability {
    Routable,
    /// Interconnect demand exceeds fabric routing capacity.
    CongestionFailure,
    /// Insufficient LUTs/FFs.
    ResourceFailure,
}

pub fn route_hercules(machines: usize, depth: usize, fabric: &Fabric) -> Routability {
    let r = resources::hercules(machines, depth);
    if r.luts > fabric.luts || r.ffs > fabric.ffs {
        return Routability::ResourceFailure;
    }
    if hercules_congestion(machines, depth) > fabric.routing_capacity {
        return Routability::CongestionFailure;
    }
    Routability::Routable
}

pub fn route_stannic(machines: usize, depth: usize, fabric: &Fabric) -> Routability {
    let r = resources::stannic(machines, depth);
    if r.luts > fabric.luts || r.ffs > fabric.ffs {
        return Routability::ResourceFailure;
    }
    if stannic_congestion(machines, depth) > fabric.routing_capacity {
        return Routability::CongestionFailure;
    }
    Routability::Routable
}

/// The paper's measurement protocol (Section 7.2.1): grow the machine
/// count in steps of 10 until synthesis fails; report the last success.
pub fn max_routable<F: Fn(usize, usize, &Fabric) -> Routability>(
    route: F,
    depth: usize,
    fabric: &Fabric,
) -> usize {
    let mut best = 0;
    let mut m = 10;
    while m <= 1000 {
        if route(m, depth, fabric) == Routability::Routable {
            best = m;
            m += 10;
        } else {
            break;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_boundaries_reproduced() {
        // Fig. 18d: Hercules max 10, Stannic max 140 (10-step protocol).
        assert_eq!(max_routable(route_hercules, 10, &U55C), 10);
        assert_eq!(max_routable(route_stannic, 10, &U55C), 140);
    }

    #[test]
    fn paper_comparison_configs_all_route() {
        for &(m, d) in &resources::PAPER_CONFIGS {
            assert_eq!(route_hercules(m, d, &U55C), Routability::Routable);
            assert_eq!(route_stannic(m, d, &U55C), Routability::Routable);
        }
    }

    #[test]
    fn hercules_fails_by_congestion_not_luts() {
        // Section 5: the decentralized memory management is "the crucial
        // bottleneck on system scalability", i.e. wiring, not area.
        assert_eq!(
            route_hercules(20, 10, &U55C),
            Routability::CongestionFailure
        );
        let r = resources::hercules(20, 10);
        assert!(r.luts < U55C.luts, "LUTs would still fit");
    }

    #[test]
    fn congestion_shapes() {
        // Hercules quadratic vs Stannic linear in machine count.
        let h_ratio = hercules_congestion(20, 10) / hercules_congestion(10, 10);
        let s_ratio = stannic_congestion(20, 10) / stannic_congestion(10, 10);
        assert!(h_ratio > 3.5, "hercules ~quadratic, got {h_ratio}");
        assert!((1.9..2.1).contains(&s_ratio), "stannic ~linear, got {s_ratio}");
    }
}
