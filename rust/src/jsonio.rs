//! Minimal JSON substrate (serde_json is unavailable offline): a value
//! tree, a writer, and a recursive-descent parser sufficient for the
//! artifact manifest and report output. Parse errors are
//! [`crate::error::Error`]s, so artifact loaders chain path/field
//! context with `.ctx()` instead of re-wrapping strings.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::err;
use crate::error::Result;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn items(&self) -> &[Json] {
        match self {
            Json::Arr(v) => v,
            _ => &[],
        }
    }

    /// Serialize (compact). Named `render` so the inherent method no
    /// longer shadows `std::string::ToString::to_string` (which now
    /// routes through the [`std::fmt::Display`] impl and produces the
    /// same text).
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(err!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.render())
    }
}

/// Helpers for building objects tersely.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: impl Into<String>) -> Json {
    Json::Str(v.into())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(err!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(err!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(err!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || b".eE+-".contains(&c))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| err!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).ok_or("bad codepoint")?);
                            self.i += 4;
                        }
                        other => return Err(err!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let c = rest.chars().next().expect("non-empty");
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(err!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(err!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let v = obj(vec![
            ("name", s("stannic")),
            ("machines", num(140.0)),
            ("ratio", num(7.5)),
            ("flags", arr(vec![Json::Bool(true), Json::Null])),
            ("nested", obj(vec![("k", s("v\"esc\\aped\n"))])),
        ]);
        let text = v.render();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{ "configs": [ {"machines": 5, "depth": 10} ], "batch": 16 }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("batch").and_then(Json::as_usize), Some(16));
        let c = &v.get("configs").unwrap().items()[0];
        assert_eq!(c.get("machines").and_then(Json::as_usize), Some(5));
        assert_eq!(c.get("depth").and_then(Json::as_usize), Some(10));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn display_matches_render() {
        let v = obj(vec![
            ("k", num(1.5)),
            ("s", s("x")),
            ("a", arr(vec![Json::Null])),
        ]);
        assert_eq!(v.render(), format!("{v}"));
        // ToString now resolves to the Display impl (no inherent shadow)
        assert_eq!(v.render(), v.to_string());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""a\u0041b""#).unwrap();
        assert_eq!(v.as_str(), Some("aAb"));
    }
}
