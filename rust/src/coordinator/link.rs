//! Timed host↔accelerator interconnect with backpressure tickets — the
//! active counterpart to the passive [`super::pcie`] cost ledger.
//!
//! The paper's deployment argument lives on the PCIe link (Section 8.2:
//! ~479 ns per scheduled job), but a cost ledger alone never pushes
//! back: the serve loop would happily admit batches as if dispatch were
//! free and only bill the time after the fact. [`TimedLink`] closes the
//! loop with a deterministic virtual-time service law:
//!
//! * **Service law**: a round trip of `B` bytes that starts at tick `S`
//!   occupies the wire for `ceil(B / width)` ticks and completes at
//!   `S + ceil(B / width) + latency`. The wire is serial — a transfer
//!   starts at `max(now, free_at)` where `free_at` is when the previous
//!   transfer leaves the wire — so link state is a pure function of the
//!   virtual-time issue sequence, never of host thread interleaving.
//! * **Tickets**: every admission round trip acquires a [`Ticket`]
//!   carrying its explicit start and completion tick. Tickets retire in
//!   FIFO order when virtual time reaches their completion tick, so
//!   `issued == completed` holds whenever the link is drained — the
//!   conservation invariant the tests pin.
//! * **Backpressure**: when capacity is exhausted the link refuses
//!   admission with a typed [`Backpressure`] reason instead of a bare
//!   bool — [`Backpressure::LinkBusy`] (wire still transmitting),
//!   [`Backpressure::WindowFull`] (in-flight window exhausted), or
//!   [`Backpressure::ResponseStalled`] (a response had to queue behind
//!   the backlog; responses are never refused outright, because dropped
//!   completions would lose jobs). Stalled work waits in the caller's
//!   merge queue — never dropped, never reordered.
//! * **Horizon**: [`TimedLink::next_completion`] feeds the pending
//!   completion tick into [`crate::scheduler::Horizon::merge`], so
//!   tickless drive loops jump over idle gaps without skipping a ticket
//!   retirement — link completions are release-class events exactly
//!   like machine-up faults.
//!
//! The unconstrained coordinator (`--link-width 0`, the default) does
//! not construct a `TimedLink` at all, which keeps every historical
//! surface byte-identical; the [`super::pcie`] ledger keeps billing in
//! both regimes (now in exact integer units — see
//! [`super::pcie::PcieStats`]).

use std::collections::VecDeque;

use crate::metrics::Histogram;

/// Default round-trip setup latency (ticks added after the wire frees).
pub const LINK_LATENCY: u64 = 2;
/// Default bound on in-flight (issued, not yet completed) tickets.
pub const LINK_WINDOW: usize = 8;

/// The interconnect service law: `width` bytes leave the wire per
/// virtual tick, every round trip pays `latency` setup ticks, and at
/// most `window` tickets may be in flight at once.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkModel {
    /// Wire service rate in bytes per virtual tick (must be >= 1; the
    /// unconstrained regime is modeled by not constructing a link).
    pub width: u64,
    /// Fixed setup ticks added to every round trip after wire service.
    pub latency: u64,
    /// Maximum in-flight tickets before admission sees `WindowFull`.
    pub window: usize,
}

impl LinkModel {
    /// The standard constrained model at a given wire width, with the
    /// default latency and window — what `serve --link-width W` arms.
    pub fn with_width(width: u64) -> LinkModel {
        LinkModel {
            width,
            latency: LINK_LATENCY,
            window: LINK_WINDOW,
        }
    }
}

/// Why the link refused (or delayed) a transfer at a given tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backpressure {
    /// The wire is still transmitting an earlier transfer.
    LinkBusy,
    /// The in-flight ticket window is exhausted.
    WindowFull,
    /// A response could not start immediately and queued behind the
    /// backlog (responses are delayed, never refused).
    ResponseStalled,
}

impl Backpressure {
    pub fn label(&self) -> &'static str {
        match self {
            Backpressure::LinkBusy => "link-busy",
            Backpressure::WindowFull => "window-full",
            Backpressure::ResponseStalled => "response-stalled",
        }
    }
}

/// One admitted round trip: issued at a tick, wire service from
/// `start`, retired when virtual time reaches `complete`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Ticket {
    /// Tick the ticket was acquired.
    pub issued: u64,
    /// Tick wire service began (`>= issued`; later when queued).
    pub start: u64,
    /// Tick the round trip completes — an event-horizon event.
    pub complete: u64,
    /// Round-trip payload in bytes (request + response).
    pub bytes: u64,
}

/// Aggregated link telemetry for [`super::ServeReport`] — present only
/// on constrained runs.
#[derive(Debug, Clone)]
pub struct LinkTelemetry {
    /// Wire width in bytes per tick (always >= 1 when present).
    pub width: u64,
    /// Setup latency in ticks.
    pub latency: u64,
    /// In-flight window bound.
    pub window: u64,
    /// Tickets issued over the run.
    pub issued: u64,
    /// Tickets retired over the run (== issued once drained).
    pub completed: u64,
    /// Admission stalls refused because the wire was busy.
    pub stall_busy: u64,
    /// Admission stalls refused because the window was full.
    pub stall_window: u64,
    /// Responses that had to queue behind the backlog.
    pub stall_response: u64,
    /// In-flight ticket count, sampled once per executed tick.
    pub occupancy: Histogram,
    /// Per-ticket wait (`complete - issued`) in ticks.
    pub wait: Histogram,
}

impl LinkTelemetry {
    /// Total typed stalls across all three reasons.
    pub fn total_stalls(&self) -> u64 {
        self.stall_busy + self.stall_window + self.stall_response
    }
}

/// Deterministic virtual-time link state. All mutation is keyed by the
/// caller's virtual tick, so two runs that issue the same byte sequence
/// at the same ticks hold bit-identical link state regardless of host
/// thread count or queue depth.
#[derive(Debug, Clone)]
pub struct TimedLink {
    model: LinkModel,
    /// First tick the wire is free for a new transfer.
    free_at: u64,
    /// FIFO in-flight tickets; completion ticks are non-decreasing
    /// because the wire is serial.
    in_flight: VecDeque<Ticket>,
    issued: u64,
    completed: u64,
    stall_busy: u64,
    stall_window: u64,
    stall_response: u64,
    occupancy: Histogram,
    wait: Histogram,
}

impl TimedLink {
    /// `model.width` and `model.window` must be >= 1 (callers validate
    /// at the CLI/opts boundary; 0 widths mean "no link at all").
    pub fn new(model: LinkModel) -> TimedLink {
        debug_assert!(model.width >= 1 && model.window >= 1);
        TimedLink {
            model,
            free_at: 0,
            in_flight: VecDeque::new(),
            issued: 0,
            completed: 0,
            stall_busy: 0,
            stall_window: 0,
            stall_response: 0,
            occupancy: Histogram::new(),
            wait: Histogram::new(),
        }
    }

    pub fn model(&self) -> &LinkModel {
        &self.model
    }

    /// Retire every ticket whose completion tick has been reached.
    /// Call once at the top of each executed tick (and after a jump —
    /// retirement depends only on `now`, so bulk retirement after a
    /// jump is bit-identical to per-tick retirement).
    pub fn begin_tick(&mut self, now: u64) {
        while self.in_flight.front().is_some_and(|t| t.complete <= now) {
            let t = self.in_flight.pop_front().expect("checked front");
            self.completed += 1;
            self.wait.record(t.complete - t.issued);
        }
    }

    /// Sample end-of-tick occupancy. Call once per executed tick, after
    /// any issue.
    pub fn end_tick(&mut self) {
        self.occupancy.record(self.in_flight.len() as u64);
    }

    /// Account `skipped` jumped ticks in the occupancy histogram. A
    /// jump never crosses a ticket completion (pending completions are
    /// merged into the event horizon) and never issues, so every
    /// skipped tick would have sampled exactly the current in-flight
    /// count — bulk recording keeps the histogram bit-identical to
    /// per-tick driving.
    pub fn bulk_occupancy(&mut self, skipped: u64) {
        self.occupancy.record_n(self.in_flight.len() as u64, skipped);
    }

    /// May a new request round trip start at `now`? Pure query — the
    /// caller records the refusal via [`Self::note_admission_stall`]
    /// only when work was actually waiting, so stall counts measure
    /// real backpressure rather than idle polling.
    pub fn try_acquire(&self, now: u64) -> Result<(), Backpressure> {
        if self.in_flight.len() >= self.model.window {
            return Err(Backpressure::WindowFull);
        }
        if self.free_at > now {
            return Err(Backpressure::LinkBusy);
        }
        Ok(())
    }

    /// Count one admission stall with its typed reason.
    pub fn note_admission_stall(&mut self, why: Backpressure) {
        match why {
            Backpressure::LinkBusy => self.stall_busy += 1,
            Backpressure::WindowFull => self.stall_window += 1,
            Backpressure::ResponseStalled => self.stall_response += 1,
        }
    }

    /// Issue a round trip of `bytes` at tick `now` and return its
    /// ticket. Never refuses: a transfer that cannot start immediately
    /// (response-only ticks racing a busy wire) queues behind the
    /// backlog and is counted as [`Backpressure::ResponseStalled`].
    /// Admission paths call [`Self::try_acquire`] first, in which case
    /// the issue is immediate and stall-free.
    pub fn issue(&mut self, now: u64, bytes: u64) -> Ticket {
        if self.free_at > now || self.in_flight.len() >= self.model.window {
            self.stall_response += 1;
        }
        let start = self.free_at.max(now);
        let busy = bytes.div_ceil(self.model.width).max(1);
        let complete = start + busy + self.model.latency;
        self.free_at = start + busy;
        let ticket = Ticket {
            issued: now,
            start,
            complete,
            bytes,
        };
        self.issued += 1;
        self.in_flight.push_back(ticket);
        ticket
    }

    /// The earliest pending completion tick — release-class on the
    /// event horizon, so drive loops merge it before jumping.
    pub fn next_completion(&self) -> Option<u64> {
        self.in_flight.front().map(|t| t.complete)
    }

    /// True when no tickets are in flight (`issued == completed`).
    pub fn is_drained(&self) -> bool {
        self.in_flight.is_empty()
    }

    pub fn issued(&self) -> u64 {
        self.issued
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    /// Fold the run's link state into report telemetry.
    pub fn into_telemetry(self) -> LinkTelemetry {
        LinkTelemetry {
            width: self.model.width,
            latency: self.model.latency,
            window: self.model.window as u64,
            issued: self.issued,
            completed: self.completed,
            stall_busy: self.stall_busy,
            stall_window: self.stall_window,
            stall_response: self.stall_response,
            occupancy: self.occupancy,
            wait: self.wait,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn narrow() -> TimedLink {
        TimedLink::new(LinkModel {
            width: 4,
            latency: 2,
            window: 2,
        })
    }

    #[test]
    fn service_law_is_latency_plus_bytes_per_tick() {
        let mut link = narrow();
        // 10 bytes over a 4 B/tick wire: ceil(10/4) = 3 busy ticks,
        // + 2 latency => completes at 1 + 3 + 2 = 6.
        let t = link.issue(1, 10);
        assert_eq!(t, Ticket { issued: 1, start: 1, complete: 6, bytes: 10 });
        assert_eq!(link.next_completion(), Some(6));
        // zero-byte round trips still occupy the wire for one tick
        let mut idle = narrow();
        let z = idle.issue(5, 0);
        assert_eq!((z.start, z.complete), (5, 5 + 1 + 2));
    }

    #[test]
    fn wire_is_serial_and_queued_transfers_count_as_response_stalls() {
        let mut link = narrow();
        link.issue(1, 8); // busy ticks 1..=2, wire frees at 3
        assert_eq!(link.try_acquire(2), Err(Backpressure::LinkBusy));
        // a response forced onto the busy wire queues behind it
        let t = link.issue(2, 4);
        assert_eq!((t.issued, t.start), (2, 3));
        assert_eq!(t.complete, 3 + 1 + 2);
        assert_eq!(link.into_telemetry().stall_response, 1);
    }

    #[test]
    fn window_bounds_in_flight_tickets() {
        let mut link = TimedLink::new(LinkModel {
            width: 100,
            latency: 10,
            window: 2,
        });
        link.issue(1, 4);
        assert_eq!(link.try_acquire(2), Ok(()));
        link.issue(2, 4);
        assert_eq!(link.try_acquire(3), Err(Backpressure::WindowFull));
        link.note_admission_stall(Backpressure::WindowFull);
        // retiring the first ticket reopens the window
        link.begin_tick(12); // first completes at 1 + 1 + 10 = 12
        assert_eq!(link.completed(), 1);
        assert_eq!(link.try_acquire(12), Ok(()));
        assert_eq!(link.into_telemetry().stall_window, 1);
    }

    #[test]
    fn tickets_retire_in_fifo_order_and_conserve_counts() {
        let mut link = narrow();
        let mut completes = Vec::new();
        for (tick, bytes) in [(1u64, 4u64), (3, 12), (9, 1)] {
            link.begin_tick(tick);
            completes.push(link.issue(tick, bytes).complete);
        }
        assert!(completes.windows(2).all(|w| w[0] <= w[1]), "FIFO wire");
        link.begin_tick(*completes.last().unwrap());
        assert!(link.is_drained());
        assert_eq!(link.issued(), link.completed());
        let t = link.into_telemetry();
        assert_eq!(t.wait.count(), 3);
        assert_eq!(t.total_stalls(), 0);
    }

    #[test]
    fn bulk_retirement_after_a_jump_matches_per_tick_retirement() {
        let mut jumped = narrow();
        let mut stepped = narrow();
        for l in [&mut jumped, &mut stepped] {
            l.issue(1, 16);
            l.issue(1, 16);
        }
        for t in 2..=20 {
            stepped.begin_tick(t);
        }
        jumped.begin_tick(20);
        assert_eq!(jumped.completed(), stepped.completed());
        assert_eq!(jumped.is_drained(), stepped.is_drained());
        let (a, b) = (jumped.into_telemetry(), stepped.into_telemetry());
        assert_eq!(a.wait.p50(), b.wait.p50());
        assert_eq!(a.wait.p95(), b.wait.p95());
    }

    #[test]
    fn backpressure_reasons_carry_stable_labels() {
        assert_eq!(Backpressure::LinkBusy.label(), "link-busy");
        assert_eq!(Backpressure::WindowFull.label(), "window-full");
        assert_eq!(Backpressure::ResponseStalled.label(), "response-stalled");
    }

    #[test]
    fn link_state_is_a_pure_function_of_the_issue_sequence() {
        // Same (tick, bytes) sequence => bit-identical telemetry, no
        // matter how many times begin_tick is polled in between (the
        // thread-interleaving invariance the serve loop relies on).
        let seq = [(1u64, 7u64), (2, 30), (6, 3), (6, 3), (40, 1)];
        let run = |poll_every_tick: bool| {
            let mut link = narrow();
            let mut now = 0;
            for &(tick, bytes) in &seq {
                if poll_every_tick {
                    while now < tick {
                        now += 1;
                        link.begin_tick(now);
                    }
                } else {
                    link.begin_tick(tick);
                }
                link.issue(tick, bytes);
            }
            link.begin_tick(1000);
            link.into_telemetry()
        };
        let (a, b) = (run(false), run(true));
        assert_eq!(a.issued, b.issued);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.stall_response, b.stall_response);
        assert_eq!(a.wait.p50(), b.wait.p50());
        assert_eq!(a.wait.max(), b.wait.max());
    }
}
