//! The L3 online coordinator: pluggable scheduling engines behind a
//! common adapter, a threaded serving loop with per-machine workers,
//! and the PCIe transport model for accelerator round-trips.

mod adapter;
pub mod pcie;
mod server;

pub use adapter::{build_engine, EngineAdapter};
pub use pcie::{PcieModel, PcieStats};
pub use server::{serve, CompletionRecord, ServeOpts, ServeReport};
