//! The L3 online coordinator — the serving spine between workload
//! generation and the scheduling engines.
//!
//! The paper's coordinator exists to keep a hardware-speed scheduler fed
//! under *stochastic online* arrivals, so this layer is built as a
//! batched multi-source arrival pipeline rather than a trace drainer:
//!
//! * **Arrival sources** ([`ArrivalSource`]): N concurrent streams, each
//!   an independent `WorkloadSpec` + RNG stream (or a replayed trace),
//!   generated on their own threads and fed through bounded queues —
//!   backpressure on a slow scheduler shows up as per-source enqueue
//!   stalls, not lost jobs.
//! * **Deterministic merge**: the scheduler thread merges queue heads in
//!   virtual-time order (ties broken by source id) into a bounded merge
//!   queue, so the merged arrival order — and therefore the schedule —
//!   is identical for any thread interleaving and any queue depth
//!   (property-tested).
//! * **Batched admission**: up to [`ServeOpts::batch`] merged arrivals
//!   enter the engine per tick; the merge-queue depth and batch-size
//!   distributions are first-class telemetry on [`ServeReport`].
//! * **Engine adapters** ([`EngineAdapter`]): one object-safe interface
//!   over every backend; construction/naming lives in the
//!   [`crate::engine::EngineId`] registry.
//! * **Transport + workers**: the PCIe round-trip model ([`pcie`]) and
//!   one virtual-time worker thread per machine, reporting
//!   [`CompletionRecord`]s.
//! * **Timed interconnect** ([`link`]): `serve --link-width W` wraps
//!   the dispatch path in a deterministic virtual-time service law —
//!   every admission round trip acquires a [`Ticket`] with an explicit
//!   completion tick, a bounded in-flight window, and a typed
//!   [`Backpressure`] reason when capacity is exhausted. Stalled jobs
//!   wait in the merge queue (never dropped or reordered), stall
//!   reasons ride [`ServeReport`] and a compat-gated artifact block,
//!   and pending completion ticks merge into the event horizon so
//!   tickless jumps stay bit-exact. The default (width 0) constructs
//!   no link and is byte-identical to the historical pipeline.
//! * **Persistence + diffing** ([`ServeRecord`]): `serve --record`
//!   archives a run through the shared [`crate::artifact`] layer
//!   (schema-checked, parse-back-verified, schedule-identity digest),
//!   and `serve diff` gates two archived runs through the same generic
//!   diff core as `sweep diff`.
//! * **Fault injection** ([`crate::faults`]): `serve --faults SPEC` arms
//!   a seeded, deterministic fault plan on the golden engine (machine
//!   down/up, stragglers, storms) and applies source-dropout cut-offs at
//!   the merge; recovery metrics ride on [`ServeReport`] and the
//!   artifact, keyed by the canonical fault string.
//! * **Sharding** ([`shard`]): `serve --shards K` routes merged arrivals
//!   across K independent tickless parks behind one adapter. The
//!   invariant that keeps sharding deterministic and diffable: *routing
//!   is a pure function of the merged virtual-time order* (least-loaded
//!   shard, ties to the lowest index — decided post-merge, where the
//!   order is already interleaving-invariant), and *jobs change shards
//!   only at global virtual-time barriers*, which drain and re-route
//!   queued-but-unstarted work in canonical shard order. `--shards 1`
//!   is bit-identical to the unsharded pipeline; per-shard telemetry
//!   (completions, schedule digests, rebalance counts, imbalance CV)
//!   rides on [`ServeReport`] and, as parity cells, on the artifact.
//! * **Policy racing** ([`crate::engine::portfolio`]): `serve --engine
//!   portfolio` serves through the competitive meta-engine, which
//!   shadow-replays each 64-tick window's merged arrivals through the
//!   golden engine and the baseline schedulers and switches the live
//!   policy to the window winner at boundaries only. Its telemetry
//!   (windows, wins, switch log, shadow-replay work) rides on
//!   [`ServeReport`] and, compat-gated, on [`ServeRecord`] — the
//!   switch-log digest is a parity cell, so two portfolio runs diff
//!   down to the exact switch sequence.

mod adapter;
pub mod link;
pub mod pcie;
mod record;
mod server;
pub mod shard;

pub use adapter::EngineAdapter;
pub use link::{Backpressure, LinkModel, LinkTelemetry, Ticket, TimedLink};
// Horizon lives in the scheduler (it describes the golden engine's
// event horizon); re-exported here because EngineAdapter::horizon is
// the coordinator-facing way to read it.
pub use crate::scheduler::Horizon;
pub use pcie::{PcieModel, PcieStats};
pub use record::{ServeRecord, ShardRecord, SourceRecord, SERVE_RECORD_SCHEMA};
pub use server::{
    serve, serve_sources, ArrivalSource, CompletionRecord, IdHasher, ServeOpts, ServeReport,
    SourceStats,
};
pub use shard::{ShardSlice, ShardTelemetry, ShardedEngine, REBALANCE_INTERVAL};
