//! Sharded multi-park coordinator — the Agon-scale routing front-end.
//!
//! One golden `SosEngine` park is a single scheduling domain; serving
//! millions of users needs many parks behind one front door. A
//! [`ShardedEngine`] splits a park of `M` machines into `K` contiguous
//! shards, each an independent tickless [`SosEngine`] with its own event
//! horizon, and routes every merged arrival to exactly one shard. Agon
//! (arXiv:2109.00665) is the blueprint: give each sub-scheduler a park
//! it can be near-optimal over, and keep the top level cheap.
//!
//! # The routing + rebalance-barrier invariant
//!
//! Determinism survives sharding because of two rules:
//!
//! * **Routing is a pure function of the merged virtual-time order.**
//!   The serve pipeline's merge already makes the arrival sequence
//!   identical for any thread interleaving and any queue depth; the
//!   router adds no new nondeterminism on top — each arrival goes to
//!   the least-loaded shard (backlog + in-flight, ties to the lowest
//!   shard index), a decision that depends only on the arrivals routed
//!   before it. Storm jobs route exactly like real arrivals.
//! * **Jobs move between shards only at global virtual-time barriers —
//!   and only queued-but-unstarted jobs move.** Every
//!   [`REBALANCE_INTERVAL`] ticks, the router drains each shard's
//!   arrival FIFO (never its virtual schedules), and re-routes the
//!   drained jobs in canonical order (shard 0's FIFO first, then shard
//!   1's, …) through the same least-loaded rule. Between barriers the
//!   shards are fully independent, so each shard's schedule — and its
//!   per-shard FNV digest — is deterministic and diffable.
//!
//! The barriers cannot be jumped over: whenever any shard has a
//! non-empty backlog its horizon is the very next tick (the golden
//! engine reports `Some(tick + 1)` while its FIFO holds work), so the
//! merged [`Horizon`] forces per-tick driving exactly while there is
//! anything to rebalance. A barrier inside a provably-empty window is a
//! no-op by construction.
//!
//! With `K = 1` the router degenerates to the identity — one shard
//! owning the whole park, no rebalancing, full-width EPT slices — so
//! `serve --shards 1` is bit-identical to the unsharded pipeline
//! (digest, tick count, completions; pinned by `tests/sharding.rs`).
//!
//! Under `serve --link-width W` the sharded router consumes
//! backpressure tickets through the same admission gate as a single
//! park: one [`super::link::TimedLink`] fronts the whole router, the
//! serve loop parks merged arrivals until the wire grants a ticket,
//! and the routed sequence the shards see is the admitted sequence —
//! so per-shard digests stay deterministic with or without the link.
//!
//! # Faults
//!
//! Machine-scoped fault clauses (`down=`/`slow=`) address machines
//! through the shard map: [`crate::faults::FaultPlan::split_shards`]
//! remaps each event onto the owning shard's local machine index. Storm
//! events stay at the routing layer and their jobs are routed like real
//! arrivals. A known, documented consequence of barrier rebalancing: an
//! evicted job that changes shards before reassignment leaves its
//! re-queue latency sample unclosed (the destination shard never saw
//! the eviction) — deterministic, and only the per-shard histograms are
//! affected.

use std::collections::{HashMap, VecDeque};

use crate::artifact::fnv1a64_hex;
use crate::core::{Job, JobId};
use crate::error::Result;
use crate::faults::{FaultEvent, FaultKind, FaultPlan, FaultStats};
use crate::metrics::coefficient_of_variation;
use crate::quant::Precision;
use crate::scheduler::{Horizon, SosEngine, TickOutcome};

use super::adapter::EngineAdapter;

/// Global virtual-time barrier period: every this-many executed ticks
/// the router may move queued-but-unstarted jobs between shards (and
/// only then — see the module docs for why jumps cannot skip a barrier
/// that has work to move).
pub const REBALANCE_INTERVAL: u64 = 64;

/// One shard's slice of the telemetry: its machine range, how much work
/// the router sent it, what it completed, its schedule-identity digest,
/// and how much rebalancing touched it.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardSlice {
    /// First global machine index this shard owns.
    pub first_machine: usize,
    /// Number of machines in the shard.
    pub machines: usize,
    /// Arrivals (incl. storm jobs) the router sent here first.
    pub routed: u64,
    /// Jobs this shard released to its machines.
    pub completed: u64,
    /// FNV-1a digest over this shard's `(tick, job, global machine)`
    /// release stream — the per-shard schedule identity.
    pub digest: String,
    /// Jobs moved into this shard by rebalance barriers.
    pub moved_in: u64,
    /// Jobs moved out of this shard by rebalance barriers.
    pub moved_out: u64,
}

/// Aggregated sharding telemetry, surfaced on `ServeReport` and (as
/// parity cells) on the `stannic.serve.record.v1` artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardTelemetry {
    pub per_shard: Vec<ShardSlice>,
    /// Jobs that changed shard at a rebalance barrier.
    pub rebalance_moves: u64,
    /// Barriers at which at least one job was drained for re-routing.
    pub rebalance_events: u64,
    /// Coefficient of variation of per-shard completion counts — the
    /// load-imbalance figure of merit (0 = perfectly balanced).
    pub imbalance_cv: f64,
}

impl ShardTelemetry {
    pub fn shards(&self) -> usize {
        self.per_shard.len()
    }
}

/// K independent tickless parks behind one [`EngineAdapter`] front end.
#[derive(Debug)]
pub struct ShardedEngine {
    shards: Vec<SosEngine>,
    /// `(first_machine, machines)` per shard — contiguous, covering the
    /// park, remainder machines on the earlier shards.
    ranges: Vec<(usize, usize)>,
    tick: u64,
    /// Full-park payload per in-flight job id: rebalancing re-slices a
    /// drained job's EPT for its new shard, which needs the original
    /// full-width vector. Entries drop on release.
    full: HashMap<JobId, Job>,
    /// Per-shard release log, digested lazily into [`ShardSlice::digest`].
    release_log: Vec<String>,
    routed: Vec<u64>,
    completed: Vec<u64>,
    moved_in: Vec<u64>,
    moved_out: Vec<u64>,
    rebalance_moves: u64,
    rebalance_events: u64,
    /// Shard-layer storm events (K > 1 only): storms route like real
    /// arrivals instead of being pinned to one shard's plan.
    storms: VecDeque<FaultEvent>,
    storms_fired: u64,
    storm_jobs_injected: u64,
    faulted: bool,
}

impl ShardedEngine {
    /// Split a park of `machines` into `shards` contiguous slices (the
    /// remainder machines go to the earlier shards) and build one
    /// tickless golden engine per slice.
    pub fn new(
        shards: usize,
        machines: usize,
        depth: usize,
        alpha: f32,
        precision: Precision,
    ) -> Self {
        assert!(shards >= 1, "at least one shard");
        assert!(
            shards <= machines,
            "cannot split {machines} machines into {shards} shards"
        );
        let mut ranges = Vec::with_capacity(shards);
        let (per, extra) = (machines / shards, machines % shards);
        let mut base = 0;
        for s in 0..shards {
            let len = per + usize::from(s < extra);
            ranges.push((base, len));
            base += len;
        }
        let engines = ranges
            .iter()
            .map(|&(_, len)| SosEngine::new(len, depth, alpha, precision))
            .collect();
        ShardedEngine {
            shards: engines,
            ranges,
            tick: 0,
            full: HashMap::new(),
            release_log: vec![String::new(); shards],
            routed: vec![0; shards],
            completed: vec![0; shards],
            moved_in: vec![0; shards],
            moved_out: vec![0; shards],
            rebalance_moves: 0,
            rebalance_events: 0,
            storms: VecDeque::new(),
            storms_fired: 0,
            storm_jobs_injected: 0,
            faulted: false,
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard map: `(first_machine, machines)` per shard.
    pub fn ranges(&self) -> &[(usize, usize)] {
        &self.ranges
    }

    /// Clone of `job` with its EPT vector cut down to shard `s`'s
    /// machine range (identity slice when the shard owns the whole park).
    fn slice_for(&self, job: &Job, s: usize) -> Job {
        let (base, len) = self.ranges[s];
        let mut local = job.clone();
        local.ept = job.ept[base..base + len].to_vec();
        local
    }

    /// Least-loaded shard (backlog + in-flight), ties to the lowest
    /// index — the pure routing function of the merged arrival order.
    fn pick_shard(&self) -> usize {
        let mut best = 0;
        let mut best_load = usize::MAX;
        for (s, shard) in self.shards.iter().enumerate() {
            let load = shard.backlog() + shard.in_flight();
            if load < best_load {
                best = s;
                best_load = load;
            }
        }
        best
    }

    /// Route a full-park job to a shard. `fresh` marks first-time
    /// arrivals (counted in [`ShardSlice::routed`]); rebalanced jobs
    /// re-route with `fresh = false`.
    fn route(&mut self, job: Job, fresh: bool) -> usize {
        let s = self.pick_shard();
        if fresh {
            self.routed[s] += 1;
        }
        let local = self.slice_for(&job, s);
        self.full.insert(job.id, job);
        self.shards[s].submit(local);
        s
    }

    /// Drain every shard's arrival FIFO (queued-but-unstarted jobs
    /// only) and re-route the drained jobs in canonical order. Runs
    /// only at global barriers, so between barriers the shards stay
    /// independent.
    fn rebalance(&mut self) {
        let mut drained: Vec<(usize, Job)> = Vec::new();
        for s in 0..self.shards.len() {
            for local in self.shards[s].drain_backlog() {
                let job = self
                    .full
                    .get(&local.id)
                    .expect("every queued job has a retained full payload")
                    .clone();
                drained.push((s, job));
            }
        }
        if drained.is_empty() {
            return;
        }
        self.rebalance_events += 1;
        for (old, job) in drained {
            let new = self.route(job, false);
            if new != old {
                self.rebalance_moves += 1;
                self.moved_out[old] += 1;
                self.moved_in[new] += 1;
            }
        }
    }

    /// One global tick: barrier rebalance (if due), shard-layer storm
    /// routing, then one tick of every shard in index order, with the
    /// per-shard outcomes merged into one machine-remapped
    /// [`TickOutcome`].
    pub fn tick(&mut self) -> TickOutcome {
        self.tick += 1;
        let now = self.tick;
        if self.shards.len() > 1 && now % REBALANCE_INTERVAL == 0 {
            self.rebalance();
        }

        let mut out = TickOutcome::default();

        // Storm events route like real arrivals, before the shard ticks
        // — the same point in the tick where the unsharded engine's
        // fault layer appends storm jobs to its FIFO.
        while self.storms.front().is_some_and(|e| e.tick <= now) {
            let ev = self.storms.pop_front().expect("front checked");
            let FaultKind::Storm(jobs) = ev.kind else {
                unreachable!("only storm events are retained at the shard layer");
            };
            self.storms_fired += 1;
            for job in jobs {
                self.storm_jobs_injected += 1;
                out.injected.push(job.clone());
                self.route(job, true);
            }
        }

        for s in 0..self.shards.len() {
            let (base, _) = self.ranges[s];
            let shard_out = self.shards[s].tick(None);
            for (id, m) in shard_out.released {
                let gm = base + m;
                self.completed[s] += 1;
                // `(tick:job:machine);` — the shard's schedule identity
                use std::fmt::Write as _;
                let _ = write!(self.release_log[s], "{now}:{id}:{gm};");
                self.full.remove(&id);
                out.released.push((id, gm));
            }
            for (id, m) in shard_out.evicted {
                out.evicted.push((id, base + m));
            }
            for job in shard_out.injected {
                // K = 1 keeps storms inside the shard's own plan; track
                // the payload so the bookkeeping matches the routed path
                self.full.entry(job.id).or_insert_with(|| job.clone());
                out.injected.push(job);
            }
            for a in shard_out
                .assigned
                .into_iter()
                .chain(shard_out.co_assigned)
            {
                let mut a = a;
                a.machine += base;
                if out.assigned.is_none() {
                    out.assigned = Some(a);
                } else {
                    out.co_assigned.push(a);
                }
            }
            out.stalled |= shard_out.stalled;
        }
        out
    }

    pub fn tick_no(&self) -> u64 {
        self.tick
    }

    /// Merged horizon: the earliest event across every shard and the
    /// shard-layer storm queue ([`Horizon::merge`] fold). Safe to jump
    /// on exactly when every member's horizon is.
    pub fn horizon(&mut self) -> Horizon {
        let mut h = match self.storms.front() {
            Some(ev) => Horizon::At(ev.tick.max(self.tick + 1)),
            None => Horizon::Idle,
        };
        for shard in &mut self.shards {
            h = h.merge(Horizon::of(shard.next_event_tick()));
        }
        h
    }

    /// Fast-forward every shard (and the global clock) over a window
    /// the merged horizon proved event-free.
    pub fn advance_to(&mut self, tick: u64) {
        for shard in &mut self.shards {
            shard.advance_to(tick);
        }
        self.tick = tick;
    }

    /// True when no work remains in any shard and no storm is pending.
    pub fn is_idle(&self) -> bool {
        self.storms.is_empty() && self.shards.iter().all(|s| s.is_idle())
    }

    /// Arm a park-wide fault plan. With one shard the plan installs
    /// unchanged (bit-identical to the unsharded engine); with K > 1 it
    /// splits through the shard map and storms stay here for routing.
    pub fn install_faults(&mut self, plan: FaultPlan) {
        assert_eq!(self.tick, 0, "install faults before driving the engine");
        assert_eq!(
            plan.machines(),
            self.ranges.last().map_or(0, |&(b, l)| b + l),
            "fault plan built for a different park size"
        );
        self.faulted = true;
        if self.shards.len() == 1 {
            self.shards[0].install_faults(plan);
            return;
        }
        let (plans, storms) = plan.split_shards(&self.ranges);
        for (shard, p) in self.shards.iter_mut().zip(plans) {
            shard.install_faults(p);
        }
        self.storms = storms.into();
    }

    /// Aggregated recovery metrics: scalar sums plus merged re-queue
    /// latency histograms across shards, with the shard-layer storm
    /// counts added. `degraded_ticks` and `max_concurrent_down` are
    /// per-shard sums, i.e. upper bounds on the global figures when
    /// K > 1 (two shards degraded in the same tick count twice).
    pub fn fault_stats(&self) -> Option<FaultStats> {
        if !self.faulted {
            return None;
        }
        let mut agg = FaultStats::default();
        for shard in &self.shards {
            if let Some(fs) = shard.fault_stats() {
                agg.downs += fs.downs;
                agg.ups += fs.ups;
                agg.slow_events += fs.slow_events;
                agg.storms += fs.storms;
                agg.injected_jobs += fs.injected_jobs;
                agg.evicted_jobs += fs.evicted_jobs;
                agg.work_lost_cycles += fs.work_lost_cycles;
                agg.requeue_latency.merge(&fs.requeue_latency);
                agg.degraded_ticks += fs.degraded_ticks;
                agg.down_machine_ticks += fs.down_machine_ticks;
                agg.max_concurrent_down += fs.max_concurrent_down;
                agg.dropped_arrivals += fs.dropped_arrivals;
            }
        }
        agg.storms += self.storms_fired;
        agg.injected_jobs += self.storm_jobs_injected;
        Some(agg)
    }

    /// Snapshot the sharding telemetry (digests finalized here).
    pub fn telemetry(&self) -> ShardTelemetry {
        let per_shard: Vec<ShardSlice> = (0..self.shards.len())
            .map(|s| ShardSlice {
                first_machine: self.ranges[s].0,
                machines: self.ranges[s].1,
                routed: self.routed[s],
                completed: self.completed[s],
                digest: fnv1a64_hex(self.release_log[s].as_bytes()),
                moved_in: self.moved_in[s],
                moved_out: self.moved_out[s],
            })
            .collect();
        let completions: Vec<f64> = self.completed.iter().map(|&c| c as f64).collect();
        ShardTelemetry {
            per_shard,
            rebalance_moves: self.rebalance_moves,
            rebalance_events: self.rebalance_events,
            imbalance_cv: coefficient_of_variation(&completions),
        }
    }
}

impl EngineAdapter for ShardedEngine {
    /// The sharded front end schedules with golden-engine semantics per
    /// shard, and with `K = 1` it *is* the golden engine bit-for-bit —
    /// so it shares the registry label. Sharded (K > 1) runs are kept
    /// from pairing with unsharded baselines by the record's per-shard
    /// parity cells and digest shard block, not by the label.
    fn label(&self) -> &'static str {
        "sos"
    }
    fn submit(&mut self, job: Job) {
        self.route(job, true);
    }
    /// Routing decisions depend on the arrivals routed before them (the
    /// least-loaded rule reads each shard's backlog), so a batch must be
    /// routed job by job in arrival order — this override exists to pin
    /// that, not to shortcut it. The batching win is unaffected: each
    /// shard's Phase II runs the wavefront kernel over its own mirror
    /// regardless of how its FIFO was fed.
    fn submit_batch(&mut self, jobs: Vec<Job>) {
        for job in jobs {
            self.route(job, true);
        }
    }
    fn tick(&mut self) -> Result<TickOutcome> {
        Ok(ShardedEngine::tick(self))
    }
    fn is_idle(&self) -> bool {
        ShardedEngine::is_idle(self)
    }
    fn horizon(&mut self) -> Horizon {
        ShardedEngine::horizon(self)
    }
    fn advance_to(&mut self, tick: u64) {
        ShardedEngine::advance_to(self, tick);
    }
    fn install_faults(&mut self, plan: FaultPlan) -> Result<()> {
        ShardedEngine::install_faults(self, plan);
        Ok(())
    }
    fn fault_stats(&self) -> Option<FaultStats> {
        ShardedEngine::fault_stats(self)
    }
    fn shard_stats(&self) -> Option<ShardTelemetry> {
        Some(self.telemetry())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::JobNature;
    use crate::faults::FaultSpec;

    fn job(id: u64, w: f32, ept: Vec<f32>) -> Job {
        Job::new(id, w, ept, JobNature::Mixed)
    }

    fn even_job(id: u64, machines: usize) -> Job {
        job(id, 2.0, vec![20.0; machines])
    }

    #[test]
    fn ranges_cover_the_park_with_remainder_up_front() {
        let e = ShardedEngine::new(3, 10, 4, 0.5, Precision::Int8);
        assert_eq!(e.ranges(), &[(0, 4), (4, 3), (7, 3)]);
        let e = ShardedEngine::new(2, 8, 4, 0.5, Precision::Int8);
        assert_eq!(e.ranges(), &[(0, 4), (4, 4)]);
    }

    #[test]
    fn single_shard_matches_the_golden_engine_exactly() {
        let mut golden = SosEngine::new(5, 4, 0.5, Precision::Int8);
        let mut sharded = ShardedEngine::new(1, 5, 4, 0.5, Precision::Int8);
        for i in 0..20u64 {
            let j = job(i, 1.0 + (i % 5) as f32, (0..5).map(|m| 10.0 + ((i + m) % 7) as f32 * 9.0).collect());
            golden.submit(j.clone());
            sharded.route(j, true);
            let a = golden.tick(None);
            let b = sharded.tick();
            assert_eq!(a, b, "tick {}", i + 1);
        }
        // drain both to idle, comparing every executed tick
        while !golden.is_idle() || !sharded.is_idle() {
            assert_eq!(golden.tick(None), sharded.tick());
            assert!(golden.tick_no() < 10_000);
        }
        assert_eq!(golden.tick_no(), sharded.tick_no());
    }

    #[test]
    fn routing_is_least_loaded_with_ties_to_the_lowest_shard() {
        let mut e = ShardedEngine::new(2, 4, 4, 0.5, Precision::Int8);
        assert_eq!(e.route(even_job(0, 4), true), 0, "empty park: tie -> shard 0");
        assert_eq!(e.route(even_job(1, 4), true), 1, "shard 0 now loaded");
        assert_eq!(e.route(even_job(2, 4), true), 0);
        let t = e.telemetry();
        assert_eq!(t.per_shard[0].routed, 2);
        assert_eq!(t.per_shard[1].routed, 1);
    }

    #[test]
    fn released_machines_are_remapped_to_global_indices() {
        // 2 shards x 1 machine; make shard 1 the cheap one.
        let mut e = ShardedEngine::new(2, 2, 4, 1.0, Precision::Fp32);
        e.route(even_job(7, 2), true); // shard 0 (tie)
        e.route(even_job(8, 2), true); // shard 1
        let out = e.tick();
        let mut machines: Vec<usize> = std::iter::once(out.assigned.unwrap().machine)
            .chain(out.co_assigned.iter().map(|a| a.machine))
            .collect();
        machines.sort_unstable();
        assert_eq!(machines, vec![0, 1], "one assignment per shard, remapped");
        // drive to release: alpha_pt = 20 -> pops at tick 21
        e.advance_to(20);
        let out = e.tick();
        let mut rel: Vec<usize> = out.released.iter().map(|&(_, m)| m).collect();
        rel.sort_unstable();
        assert_eq!(rel, vec![0, 1]);
        assert!(e.is_idle());
        let t = e.telemetry();
        assert_eq!(t.per_shard[0].completed, 1);
        assert_eq!(t.per_shard[1].completed, 1);
        assert_eq!(t.imbalance_cv, 0.0, "perfectly balanced");
        assert_ne!(t.per_shard[0].digest, t.per_shard[1].digest, "different release streams");
    }

    #[test]
    fn horizon_folds_shards_and_storm_queue() {
        let mut e = ShardedEngine::new(2, 4, 4, 0.5, Precision::Int8);
        assert_eq!(e.horizon(), Horizon::Idle);
        e.install_faults(FaultSpec::parse("storm=2@50,seed=3").unwrap().plan(4).unwrap());
        assert!(!e.is_idle(), "pending storm keeps the engine live");
        assert_eq!(e.horizon(), Horizon::At(50), "storm bounds the jump");
        e.advance_to(49);
        let out = e.tick();
        assert_eq!(out.injected.len(), 2);
        assert!(out.assigned.is_some(), "storm jobs route and assign same tick");
        let fs = e.fault_stats().unwrap();
        assert_eq!(fs.storms, 1);
        assert_eq!(fs.injected_jobs, 2);
        // both storm jobs routed like arrivals: one per shard (least loaded)
        let t = e.telemetry();
        assert_eq!(t.per_shard[0].routed + t.per_shard[1].routed, 2);
    }

    #[test]
    fn machine_faults_address_shards_through_the_map() {
        // Park of 4 split 2+2: global machine 3 is shard 1's local 1.
        let mut e = ShardedEngine::new(2, 4, 4, 1.0, Precision::Fp32);
        e.install_faults(FaultSpec::parse("down=3@2+5").unwrap().plan(4).unwrap());
        // load shard 1 with a job queued behind a head so the down evicts it
        e.route(job(1, 2.0, vec![90.0, 90.0, 10.0, 10.0]), true); // shard 0 (tie)
        e.route(job(2, 4.0, vec![90.0, 90.0, 10.0, 10.0]), true); // shard 1, head on local 0 (tie)
        e.tick(); // tick 1: both assigned
        e.route(job(3, 8.0, vec![95.0, 95.0, 80.0, 12.0]), true); // shard 1 tie-break? loads equal -> shard 0
        let out = e.tick(); // tick 2: down fires on global 3 (shard 1 local 1)
        // nothing was queued on machine 3, so no evictions — but the
        // dip accounting must land on shard 1's stats
        assert!(out.evicted.is_empty());
        let fs = e.fault_stats().unwrap();
        assert_eq!(fs.downs, 1);
        assert!(fs.degraded_ticks >= 1);
        // drain; the up event must fire before idle
        while !e.is_idle() {
            e.tick();
            assert!(e.tick_no() < 10_000);
        }
        assert_eq!(e.fault_stats().unwrap().ups, 1);
    }

    #[test]
    fn rebalance_moves_queued_jobs_at_barriers_only() {
        // 2 shards x 1 machine, depth 1: pile a deep backlog onto the
        // park, then watch a barrier re-route the queued tail.
        let mut e = ShardedEngine::new(2, 2, 1, 1.0, Precision::Fp32);
        for i in 0..6u64 {
            e.route(job(i, 2.0, vec![300.0, 300.0]), true);
        }
        let mut moves_before_barrier = 0;
        for t in 1..REBALANCE_INTERVAL {
            e.tick();
            moves_before_barrier = e.telemetry().rebalance_moves;
            assert_eq!(moves_before_barrier, 0, "no moves before the barrier (tick {t})");
        }
        e.tick(); // the barrier tick
        let t = e.telemetry();
        assert!(t.rebalance_events <= 1);
        // moves only happen when the drain found queued work; with
        // depth-1 schedules and 300-tick jobs the backlog is non-empty
        assert_eq!(t.rebalance_events, 1, "barrier drained the queued tail");
        assert_eq!(
            t.per_shard.iter().map(|s| s.moved_in).sum::<u64>(),
            t.rebalance_moves
        );
        assert_eq!(
            t.per_shard.iter().map(|s| s.moved_out).sum::<u64>(),
            t.rebalance_moves
        );
    }

    #[test]
    fn sharded_run_is_deterministic_across_reruns() {
        let run = || {
            let mut e = ShardedEngine::new(3, 9, 4, 0.5, Precision::Int8);
            for i in 0..40u64 {
                let j = job(
                    i,
                    1.0 + (i % 7) as f32,
                    (0..9).map(|m| 10.0 + ((i * 3 + m) % 11) as f32 * 8.0).collect(),
                );
                e.route(j, true);
                e.tick();
            }
            while !e.is_idle() {
                e.tick();
                assert!(e.tick_no() < 100_000);
            }
            (e.tick_no(), e.telemetry())
        };
        let (ticks_a, tel_a) = run();
        let (ticks_b, tel_b) = run();
        assert_eq!(ticks_a, ticks_b);
        assert_eq!(tel_a, tel_b, "telemetry incl. digests is bit-stable");
        assert_eq!(tel_a.per_shard.iter().map(|s| s.completed).sum::<u64>(), 40);
    }
}
