//! The online serving loop: a scheduler thread drives the engine over
//! the arrival trace, charging PCIe transport per accelerator
//! round-trip; released jobs stream over bounded channels to one worker
//! thread per machine, which simulates execution in virtual time and
//! reports completion records back. (tokio is unavailable offline; this
//! is the std::thread + mpsc equivalent of the async runtime.)

use std::hash::{BuildHasherDefault, Hasher};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread;
use std::time::Instant;

/// Pass-through hasher for JobId keys (perf: job ids are already
/// well-distributed u64s; SipHash costs ~40 ns per op on the hot path —
/// see EXPERIMENTS.md §Perf).
#[derive(Default)]
pub struct IdHasher(u64);

impl Hasher for IdHasher {
    fn finish(&self) -> u64 {
        self.0
    }
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = self.0.rotate_left(8) ^ b as u64;
        }
    }
    fn write_u64(&mut self, v: u64) {
        // multiplicative mix: sequential ids stay collision-free while
        // spreading across buckets
        self.0 = v.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    }
}

type JobMap = std::collections::HashMap<u64, Job, BuildHasherDefault<IdHasher>>;

use crate::core::{Job, MachineId};
use crate::error::Result;
use crate::metrics::{Histogram, MetricSet, ScheduleMetrics};
use crate::workload::Trace;

use super::adapter::EngineAdapter;
use super::pcie::{PcieModel, PcieStats};

/// One completed job as reported by a machine worker.
#[derive(Debug, Clone, PartialEq)]
pub struct CompletionRecord {
    pub job: Job,
    pub machine: MachineId,
    /// Tick at which the job was released to the machine queue.
    pub released: u64,
    /// Tick at which execution started (>= released).
    pub started: u64,
    /// Tick at which execution finished.
    pub finished: u64,
}

/// A released job message to a worker.
struct WorkItem {
    job: Job,
    released: u64,
}

/// Serving-run report.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub engine: &'static str,
    pub metrics: ScheduleMetrics,
    /// Queue-latency distribution (creation -> execution start).
    pub latency_hist: Histogram,
    pub completions: Vec<CompletionRecord>,
    pub pcie: PcieStats,
    /// Scheduler ticks consumed.
    pub ticks: u64,
    /// Simulated accelerator cycles (0 for pure-software engines).
    pub accel_cycles: u64,
    /// Host wall-clock for the scheduling loop.
    pub wall: std::time::Duration,
    /// Stalled iterations (arrival waited, every V_i full).
    pub stalls: u64,
}

/// Coordinator options.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    pub pcie: PcieModel,
    /// Bounded channel depth per machine worker (backpressure).
    pub queue_depth: usize,
    pub max_ticks: u64,
    /// Metric interval for load-balance CV.
    pub metric_interval: u64,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            pcie: PcieModel::default(),
            queue_depth: 256,
            max_ticks: 5_000_000,
            metric_interval: 64,
        }
    }
}

/// Machine worker: virtual-time FIFO executor. Receives released jobs,
/// executes each for its actual (stochastic) runtime, reports
/// completions.
fn worker(
    machine: MachineId,
    rx: Receiver<WorkItem>,
    tx: SyncSender<CompletionRecord>,
) {
    let mut busy_until: u64 = 0;
    while let Ok(item) = rx.recv() {
        let started = busy_until.max(item.released);
        let finished = started + item.job.actual_time(machine);
        busy_until = finished;
        let rec = CompletionRecord {
            machine,
            released: item.released,
            started,
            finished,
            job: item.job,
        };
        if tx.send(rec).is_err() {
            return; // coordinator gone
        }
    }
}

/// Drive `engine` over `trace` with machine workers on threads.
pub fn serve(
    mut engine: Box<dyn EngineAdapter>,
    trace: &Trace,
    opts: &ServeOpts,
) -> Result<ServeReport> {
    let machines = trace.machines();
    let total_jobs = trace.n_jobs();
    let started = Instant::now();

    // spawn workers
    let mut work_txs: Vec<SyncSender<WorkItem>> = Vec::with_capacity(machines);
    let (done_tx, done_rx) = sync_channel::<CompletionRecord>(total_jobs.max(16));
    let mut handles = Vec::with_capacity(machines);
    for m in 0..machines {
        let (tx, rx) = sync_channel::<WorkItem>(opts.queue_depth);
        let done = done_tx.clone();
        handles.push(
            thread::Builder::new()
                .name(format!("machine-{m}"))
                .spawn(move || worker(m, rx, done))
                .expect("spawn worker"),
        );
        work_txs.push(tx);
    }
    drop(done_tx);

    // job registry: released ids -> Job payloads (the engine tracks only
    // metadata, like the FPGA; the host keeps the payloads)
    let mut payloads: JobMap =
        JobMap::with_capacity_and_hasher(total_jobs, Default::default());

    let mut pcie = PcieStats::default();
    let mut metrics = MetricSet::new(machines, opts.metric_interval);
    let mut stalls = 0u64;
    let mut released_count = 0usize;
    let mut events = trace.events().iter().peekable();
    let mut tick = 0u64;

    while tick < opts.max_ticks {
        tick += 1;
        // arrivals for this tick (burst serialization happens inside the
        // engine's FIFO, matching the hardware's host interface)
        while events.peek().is_some_and(|e| e.tick <= tick) {
            let e = events.next().expect("peeked");
            if let Some(job) = &e.job {
                payloads.insert(job.id, job.clone());
                engine.submit(job.clone());
            }
        }

        let out = engine.tick()?;
        if out.stalled {
            stalls += 1;
        }
        // transport accounting: one round-trip per scheduling iteration
        // that talks to the accelerator (assignment and/or releases)
        if out.assigned.is_some() || !out.released.is_empty() {
            opts.pcie
                .charge(&mut pcie, machines, out.released.len());
        }
        if let Some(a) = &out.assigned {
            metrics.record_assignment(a.machine, tick);
        }
        for (id, m) in &out.released {
            let job = payloads
                .remove(id)
                .expect("released job must have a payload");
            released_count += 1;
            work_txs[*m]
                .send(WorkItem {
                    job,
                    released: tick,
                })
                .expect("worker alive");
        }

        if released_count == total_jobs && engine.is_idle() && events.peek().is_none() {
            break;
        }
    }

    // close work channels; collect completions
    drop(work_txs);
    let mut completions: Vec<CompletionRecord> = done_rx.iter().collect();
    for h in handles {
        let _ = h.join();
    }
    completions.sort_by_key(|c| (c.finished, c.job.id));
    let mut latency_hist = Histogram::new();
    for c in &completions {
        metrics.record_latency(c.machine, c.job.arrival, c.started);
        latency_hist.record(c.started - c.job.arrival);
    }

    Ok(ServeReport {
        engine: engine.label(),
        metrics: metrics.finish(),
        latency_hist,
        completions,
        pcie,
        ticks: tick,
        accel_cycles: engine.cycles(),
        wall: started.elapsed(),
        stalls,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;
    use crate::coordinator::adapter::build_engine;
    use crate::core::MachinePark;
    use crate::quant::Precision;
    use crate::workload::{generate_trace, WorkloadSpec};

    fn run(kind: EngineKind, jobs: usize, seed: u64) -> ServeReport {
        let park = MachinePark::paper_m1_m5();
        let trace = generate_trace(&WorkloadSpec::default(), &park, jobs, seed);
        let engine = build_engine(kind, 5, 10, 0.5, Precision::Int8).unwrap();
        serve(engine, &trace, &ServeOpts::default()).unwrap()
    }

    #[test]
    fn serves_full_trace_with_native_engine() {
        let r = run(EngineKind::Native, 200, 9);
        assert_eq!(r.completions.len(), 200);
        assert_eq!(r.metrics.total_scheduled, 200);
        assert!(r.pcie.transactions > 0);
        assert!(r.metrics.avg_latency >= 0.0);
        // every machine got work under the even workload
        assert!(!r.metrics.starvation);
    }

    #[test]
    fn sim_engine_reports_cycles() {
        let r = run(EngineKind::StannicSim, 100, 3);
        assert_eq!(r.completions.len(), 100);
        assert!(r.accel_cycles > 0);
        let h = run(EngineKind::HerculesSim, 100, 3);
        assert!(
            h.accel_cycles > r.accel_cycles,
            "hercules {} vs stannic {}",
            h.accel_cycles,
            r.accel_cycles
        );
    }

    #[test]
    fn identical_schedules_across_engines() {
        let a = run(EngineKind::Native, 150, 21);
        let b = run(EngineKind::StannicSim, 150, 21);
        let c = run(EngineKind::HerculesSim, 150, 21);
        assert_eq!(a.metrics.jobs_per_machine, b.metrics.jobs_per_machine);
        assert_eq!(a.metrics.jobs_per_machine, c.metrics.jobs_per_machine);
        assert_eq!(a.metrics.avg_latency, b.metrics.avg_latency);
    }

    #[test]
    fn worker_virtual_time_is_fifo() {
        // one machine, two jobs released same tick: second starts when
        // the first finishes
        use crate::core::JobNature;
        let park = MachinePark::homogeneous_cpu(1);
        let mut events = Vec::new();
        for id in 1..=2u64 {
            events.push(crate::workload::TraceEvent {
                tick: 1,
                job: Some(Job::new(id, 200.0, vec![10.0], JobNature::Mixed).with_arrival(1)),
            });
        }
        let trace = Trace::new(events, 1);
        let engine = build_engine(EngineKind::Native, 1, 10, 0.5, Precision::Int8).unwrap();
        let r = serve(engine, &trace, &ServeOpts::default()).unwrap();
        assert_eq!(r.completions.len(), 2);
        let c0 = &r.completions[0];
        let c1 = &r.completions[1];
        assert!(c1.started >= c0.finished);
        let _ = park;
    }
}
