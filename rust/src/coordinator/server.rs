//! The online serving pipeline: N concurrent arrival-source threads
//! (each an independent workload stream with its own RNG) feed bounded
//! queues into a deterministic virtual-time merge; the scheduler thread
//! admits merged arrivals to the engine in configurable batches per
//! tick, charging PCIe transport per accelerator round-trip; released
//! jobs stream over bounded channels to one worker thread per machine,
//! which simulates execution in virtual time and reports completion
//! records back. (tokio is unavailable offline; this is the std::thread
//! + mpsc equivalent of the async runtime.)
//!
//! **Determinism is load-bearing**: the merged arrival order depends
//! only on `(virtual tick, source id, per-source FIFO order)` — never on
//! thread interleaving — so the schedule produced for a given source
//! set, batch size and engine is byte-identical across runs and across
//! `queue_depth` settings (property-tested in `tests/properties.rs`).
//! Backpressure shows up in *telemetry*, not in the schedule: per-source
//! enqueue stalls, the merge-queue depth histogram, and the batch-size
//! distribution on [`ServeReport`].
//!
//! **The scheduler loop is tickless**: when the merge queue is empty,
//! virtual time jumps to `min(engine event horizon, earliest source
//! head)` instead of idle-spinning toward `max_ticks` one tick at a
//! time (engines without a horizon — [`crate::scheduler::Horizon::Unknown`] — keep the
//! per-tick loop). Jumps are semantically invisible: tick counts,
//! schedules, digests and the per-tick merge-depth histogram (bulk
//! zero samples) are bit-identical to per-tick driving.

use std::hash::{BuildHasherDefault, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::thread;
use std::time::Instant;

/// Pass-through hasher for JobId keys (perf: job ids are already
/// well-distributed u64s; SipHash costs ~40 ns per op on the hot path —
/// see EXPERIMENTS.md §Perf).
#[derive(Default)]
pub struct IdHasher(u64);

impl Hasher for IdHasher {
    fn finish(&self) -> u64 {
        self.0
    }
    fn write(&mut self, bytes: &[u8]) {
        // Same multiplicative finisher as `write_u64`: rotate-xor alone
        // leaves short byte keys clustered in the low bits, which would
        // silently degrade `JobMap` if a non-u64 key type ever landed.
        for &b in bytes {
            self.0 = (self.0.rotate_left(8) ^ u64::from(b))
                .wrapping_mul(0x9e37_79b9_7f4a_7c15);
        }
    }
    fn write_u64(&mut self, v: u64) {
        // multiplicative mix: sequential ids stay collision-free while
        // spreading across buckets
        self.0 = v.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    }
}

type JobMap = std::collections::HashMap<u64, Job, BuildHasherDefault<IdHasher>>;

use crate::core::{Job, MachineId, MachinePark};
use crate::engine::portfolio::PortfolioTelemetry;
use crate::error::Result;
use crate::faults::{FaultSpec, FaultStats};
use crate::metrics::{Histogram, MetricSet, ScheduleMetrics};
use crate::workload::{generate_trace, Trace, WorkloadSpec};

use super::adapter::EngineAdapter;
use super::link::{LinkModel, LinkTelemetry, TimedLink};
use super::pcie::{PcieModel, PcieStats};
use super::shard::ShardTelemetry;

/// One completed job as reported by a machine worker.
#[derive(Debug, Clone, PartialEq)]
pub struct CompletionRecord {
    pub job: Job,
    pub machine: MachineId,
    /// Tick at which the job was released to the machine queue.
    pub released: u64,
    /// Tick at which execution started (>= released).
    pub started: u64,
    /// Tick at which execution finished.
    pub finished: u64,
}

/// A released job message to a worker.
struct WorkItem {
    job: Job,
    released: u64,
}

/// One arrival event in flight from a source thread to the merge stage.
struct SourceEvent {
    tick: u64,
    job: Job,
}

/// What an [`ArrivalSource`] feeds through its bounded queue. The
/// machine/job counts live once, on the [`ArrivalSource`] itself.
enum SourcePayload {
    /// Pre-built events (trace replay), tick-ordered.
    Events(Vec<(u64, Job)>),
    /// A workload synthesized *inside the source thread* — generation
    /// overlaps with scheduling, which is the point of the pipeline.
    Synth { spec: WorkloadSpec, seed: u64 },
}

/// One independent arrival stream feeding the coordinator's merge stage.
pub struct ArrivalSource {
    pub name: String,
    machines: usize,
    jobs: usize,
    payload: SourcePayload,
}

impl ArrivalSource {
    /// Replay an existing trace as a single stream. Explicit idle events
    /// (`job: None`) are dropped: a job-less tick never reaches the
    /// engine, and the pipeline's clock free-runs past the last arrival
    /// until the park drains (so a trailing idle marker no longer pads
    /// `ServeReport::ticks` the way the pre-pipeline loop did).
    pub fn from_trace(name: &str, trace: &Trace) -> ArrivalSource {
        let events: Vec<(u64, Job)> = trace
            .events()
            .iter()
            .filter_map(|e| e.job.clone().map(|j| (e.tick, j)))
            .collect();
        ArrivalSource {
            name: name.to_string(),
            machines: trace.machines(),
            jobs: events.len(),
            payload: SourcePayload::Events(events),
        }
    }

    /// A synthetic stream: `jobs` arrivals drawn from `spec` with an
    /// independent RNG stream seeded by `seed`, generated lazily on the
    /// source thread.
    pub fn synthetic(
        name: &str,
        spec: WorkloadSpec,
        machines: usize,
        jobs: usize,
        seed: u64,
    ) -> ArrivalSource {
        ArrivalSource {
            name: name.to_string(),
            machines,
            jobs,
            payload: SourcePayload::Synth { spec, seed },
        }
    }

    /// The CLI's default multi-source mix: stream 0 carries the caller's
    /// base spec ("steady"), further streams rotate through the bursty
    /// and heavy-tailed stress mixes (the Agon regimes where concurrent
    /// arrival streams separate schedulers — arXiv:2109.00665). Jobs are
    /// split evenly (remainder to the earlier sources); each source gets
    /// a distinct seed so the RNG streams are independent.
    pub fn standard_mix(
        base: &WorkloadSpec,
        machines: usize,
        total_jobs: usize,
        seed: u64,
        n_sources: usize,
    ) -> Vec<ArrivalSource> {
        let mixes: [(&str, WorkloadSpec); 3] = [
            ("steady", base.clone()),
            ("bursty", WorkloadSpec::bursty()),
            ("heavy", WorkloadSpec::heavy_tailed()),
        ];
        (0..n_sources)
            .map(|i| {
                let (mix_name, spec) = &mixes[i % mixes.len()];
                let jobs =
                    total_jobs / n_sources + usize::from(i < total_jobs % n_sources);
                ArrivalSource::synthetic(
                    &format!("{i}:{mix_name}"),
                    spec.clone(),
                    machines,
                    jobs,
                    seed.wrapping_add(i as u64),
                )
            })
            .collect()
    }

    /// Machine count this stream's jobs carry EPTs for.
    pub fn machines(&self) -> usize {
        self.machines
    }

    /// Number of jobs this stream will emit.
    pub fn jobs(&self) -> usize {
        self.jobs
    }
}

/// Per-source backpressure telemetry.
#[derive(Debug, Clone, PartialEq)]
pub struct SourceStats {
    pub name: String,
    /// Jobs this source contributed to the merged stream.
    pub jobs: usize,
    /// Times the source blocked on a full arrival queue (timing-
    /// dependent, like wall time — never part of determinism checks).
    pub enqueue_stalls: u64,
}

/// Serving-run report.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub engine: &'static str,
    pub metrics: ScheduleMetrics,
    /// Queue-latency distribution (creation -> execution start).
    pub latency_hist: Histogram,
    pub completions: Vec<CompletionRecord>,
    pub pcie: PcieStats,
    /// Scheduler ticks consumed.
    pub ticks: u64,
    /// Simulated accelerator cycles (0 for pure-software engines).
    pub accel_cycles: u64,
    /// Host wall-clock for the scheduling loop.
    pub wall: std::time::Duration,
    /// Stalled iterations (arrival waited, every V_i full).
    pub stalls: u64,
    /// Per-source arrival/backpressure stats, in source-id order.
    pub sources: Vec<SourceStats>,
    /// Merge-queue depth after admission, sampled every scheduler tick
    /// (deterministic).
    pub merge_depth: Histogram,
    /// Arrivals admitted per tick, over ticks admitting >= 1 job
    /// (deterministic).
    pub batch_sizes: Histogram,
    /// Canonical fault key ([`FaultSpec::render`]) when the run was
    /// faulted; empty for clean runs (keeps clean artifacts byte-stable).
    pub fault_key: String,
    /// Recovery metrics for a faulted run (`None` when clean), with
    /// [`FaultStats::dropped_arrivals`] filled in by the pipeline.
    pub faults: Option<FaultStats>,
    /// Per-shard telemetry when the run drove the sharded coordinator
    /// with more than one shard (`None` for single-domain runs — keeps
    /// unsharded reports and artifacts byte-stable).
    pub shards: Option<ShardTelemetry>,
    /// Portfolio meta-engine telemetry (window wins, switch log,
    /// shadow-replay work counters). `None` for plain engines — keeps
    /// non-portfolio reports and artifacts byte-stable.
    pub portfolio: Option<PortfolioTelemetry>,
    /// Timed-interconnect telemetry (ticket counts, typed stall
    /// reasons, occupancy and wait histograms) when the run was
    /// link-constrained (`serve --link-width W`). `None` for unbounded
    /// runs — keeps historical reports and artifacts byte-stable.
    pub link: Option<LinkTelemetry>,
}

impl ServeReport {
    /// The `serve --json` payload. The gated blocks follow the record's
    /// compat discipline: fault, shard, and portfolio keys appear only
    /// when the run carried them, so a clean plain-engine summary is
    /// byte-identical to pre-feature builds (pinned by tests here).
    pub fn json_summary(&self) -> crate::jsonio::Json {
        use crate::jsonio::{arr, num, obj, s};
        let m = &self.metrics;
        let mut fields = vec![
            ("engine", s(self.engine)),
            ("completed", num(self.completions.len() as f64)),
            ("ticks", num(self.ticks as f64)),
            ("avg_latency", num(m.avg_latency)),
            ("fairness", num(m.fairness)),
            ("load_cv", num(m.load_balance_cv)),
            ("throughput", num(m.throughput)),
            (
                "jobs_per_machine",
                arr(m.jobs_per_machine.iter().map(|&c| num(c as f64)).collect()),
            ),
            ("pcie_us", num(self.pcie.total_ns() / 1000.0)),
            ("accel_cycles", num(self.accel_cycles as f64)),
            ("sources", num(self.sources.len() as f64)),
        ];
        if let Some(f) = self.faults.as_ref() {
            fields.push(("fault", s(self.fault_key.clone())));
            fields.push(("fault_injected", num(f.injected_jobs as f64)));
            fields.push(("fault_evicted", num(f.evicted_jobs as f64)));
            fields.push(("fault_dropped", num(f.dropped_arrivals as f64)));
        }
        if let Some(t) = self.shards.as_ref() {
            fields.push(("shards", num(t.shards() as f64)));
            fields.push(("rebalance_moves", num(t.rebalance_moves as f64)));
            fields.push(("shard_imbalance_cv", num(t.imbalance_cv)));
        }
        if let Some(p) = self.portfolio.as_ref() {
            fields.push(("portfolio_windows", num(p.windows as f64)));
            fields.push(("portfolio_switches", num(p.switches as f64)));
            fields.push(("portfolio_live", s(p.live)));
            fields.push(("portfolio_switch_digest", s(p.switch_digest())));
            fields.push(("portfolio_replay_ticks", num(p.replay_ticks as f64)));
        }
        if let Some(l) = self.link.as_ref() {
            fields.push(("link_width", num(l.width as f64)));
            fields.push(("link_issued", num(l.issued as f64)));
            fields.push(("link_completed", num(l.completed as f64)));
            fields.push(("link_stall_busy", num(l.stall_busy as f64)));
            fields.push(("link_stall_window", num(l.stall_window as f64)));
            fields.push(("link_stall_response", num(l.stall_response as f64)));
            fields.push(("link_wait_p95", num(l.wait.p95() as f64)));
        }
        obj(fields)
    }
}

/// Coordinator options.
///
/// Construct with the builder chain — `ServeOpts::new().with_batch(4)`
/// — rather than struct literals: every field addition (the `faults`
/// field, then `shards`) otherwise ripples through all construction
/// sites. The fields stay `pub` for read access.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    pub pcie: PcieModel,
    /// Bounded queue depth: per-source arrival channels, the merge
    /// queue, and per-machine worker channels (backpressure).
    pub queue_depth: usize,
    pub max_ticks: u64,
    /// Metric interval for load-balance CV.
    pub metric_interval: u64,
    /// Max arrivals admitted to the engine per scheduler tick.
    /// `usize::MAX` (or 0, the CLI's spelling) = unbatched: admit
    /// everything due this tick, which reproduces the single-trace
    /// serve loop exactly.
    pub batch: usize,
    /// Deterministic fault scenario ([`crate::faults`]). `None` (or an
    /// empty spec) runs clean — bit-identical to a build without the
    /// fault layer. Requires the golden engine; others reject the plan.
    pub faults: Option<FaultSpec>,
    /// Scheduling domains the engine is expected to expose. `1` (the
    /// default) accepts any engine; `> 1` requires an engine built via
    /// [`crate::engine::EngineId::build_sharded`] with exactly this
    /// shard count — the pipeline refuses a mismatch up front, so a
    /// shard request can never silently run single-domain.
    pub shards: usize,
    /// Timed-interconnect service law ([`super::link`]). `None` (the
    /// default, the CLI's `--link-width 0`) runs unbounded and is
    /// byte-identical to a build without the link layer; `Some(model)`
    /// gates admission through backpressure tickets.
    pub link: Option<LinkModel>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            pcie: PcieModel::default(),
            queue_depth: 256,
            max_ticks: 5_000_000,
            metric_interval: 64,
            batch: usize::MAX,
            faults: None,
            shards: 1,
            link: None,
        }
    }
}

impl ServeOpts {
    /// Builder entry point (alias of [`ServeOpts::default`]).
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_pcie(mut self, pcie: PcieModel) -> Self {
        self.pcie = pcie;
        self
    }

    pub fn with_queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth;
        self
    }

    pub fn with_max_ticks(mut self, max_ticks: u64) -> Self {
        self.max_ticks = max_ticks;
        self
    }

    pub fn with_metric_interval(mut self, interval: u64) -> Self {
        self.metric_interval = interval;
        self
    }

    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// `None` clears a previously set spec; `Some`/bare `FaultSpec`
    /// both work via `Into`.
    pub fn with_faults(mut self, faults: impl Into<Option<FaultSpec>>) -> Self {
        self.faults = faults.into();
        self
    }

    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    /// `None` clears a previously set model; `Some`/bare `LinkModel`
    /// both work via `Into`.
    pub fn with_link(mut self, link: impl Into<Option<LinkModel>>) -> Self {
        self.link = link.into();
        self
    }
}

/// Machine worker: virtual-time FIFO executor. Receives released jobs,
/// executes each for its actual (stochastic) runtime, reports
/// completions.
fn worker(
    machine: MachineId,
    rx: Receiver<WorkItem>,
    tx: SyncSender<CompletionRecord>,
) {
    let mut busy_until: u64 = 0;
    while let Ok(item) = rx.recv() {
        let started = busy_until.max(item.released);
        let finished = started + item.job.actual_time(machine);
        busy_until = finished;
        let rec = CompletionRecord {
            machine,
            released: item.released,
            started,
            finished,
            job: item.job,
        };
        if tx.send(rec).is_err() {
            return; // coordinator gone
        }
    }
}

/// Source thread body: push tick-ordered events through the bounded
/// queue, counting enqueue stalls (a stall = the queue was full when the
/// event became ready).
fn feed_source(events: Vec<(u64, Job)>, tx: SyncSender<SourceEvent>, stalls: &AtomicU64) {
    for (tick, job) in events {
        match tx.try_send(SourceEvent { tick, job }) {
            Ok(()) => {}
            Err(TrySendError::Full(ev)) => {
                stalls.fetch_add(1, Ordering::Relaxed);
                if tx.send(ev).is_err() {
                    return; // scheduler bailed (max_ticks)
                }
            }
            Err(TrySendError::Disconnected(_)) => return,
        }
    }
}

/// Receive a source's next *surviving* event, discarding (and counting)
/// everything at or past the source's dropout cut-off. Dropout is a
/// stream fault: the source thread still feeds its whole trace, the
/// merge just never sees the tail, so the engine-side schedule is a pure
/// function of the surviving arrivals.
fn next_live(
    rx: &Receiver<SourceEvent>,
    drop_at: Option<u64>,
    dropped: &mut u64,
) -> Option<SourceEvent> {
    loop {
        let ev = rx.recv().ok()?;
        if drop_at.is_some_and(|t| ev.tick >= t) {
            *dropped += 1;
            continue;
        }
        return Some(ev);
    }
}

/// Drive `engine` over a single pre-built trace (the classic replay
/// path; a one-source pipeline with the default unbatched admission is
/// exactly the historical serve loop).
pub fn serve(
    engine: Box<dyn EngineAdapter>,
    trace: &Trace,
    opts: &ServeOpts,
) -> Result<ServeReport> {
    serve_sources(engine, vec![ArrivalSource::from_trace("trace", trace)], opts)
}

/// Drive `engine` over N concurrent arrival sources.
///
/// Pipeline: each source runs on its own thread and feeds a bounded
/// queue; the scheduler thread merges queue heads in virtual-time order
/// (ties broken by source id) into a bounded merge queue, admits up to
/// [`ServeOpts::batch`] merged arrivals per tick, and drives the engine;
/// released jobs go to per-machine worker threads as before. Job ids
/// are namespaced per source (`id + source_index << 32`) so concurrent
/// streams can reuse local ids.
pub fn serve_sources(
    mut engine: Box<dyn EngineAdapter>,
    sources: Vec<ArrivalSource>,
    opts: &ServeOpts,
) -> Result<ServeReport> {
    if sources.is_empty() {
        crate::bail!("serve_sources needs at least one arrival source");
    }
    let machines = sources[0].machines();
    if sources.iter().any(|s| s.machines() != machines) {
        crate::bail!("all arrival sources must target the same machine park");
    }
    let total_jobs: usize = sources.iter().map(ArrivalSource::jobs).sum();
    let n_sources = sources.len();
    // A shard request must match the engine's actual domain layout —
    // refusing up front is what keeps `--shards K` from silently
    // degrading to a single-domain run on the wrong engine.
    if opts.shards > 1 {
        match engine.shard_stats() {
            Some(t) if t.shards() == opts.shards => {}
            Some(t) => crate::bail!(
                "opts.shards = {} but engine `{}` was built with {} shard(s)",
                opts.shards,
                engine.label(),
                t.shards()
            ),
            None => crate::bail!(
                "opts.shards = {} but engine `{}` is single-domain \
                 (build it with EngineId::build_sharded / serve --shards)",
                opts.shards,
                engine.label()
            ),
        }
    }
    // A constrained link must describe a servable wire: zero-width or
    // zero-window models would deadlock admission forever, so they are
    // refused up front (the unbounded regime is spelled `link: None`).
    if let Some(l) = opts.link.as_ref() {
        if l.width == 0 || l.window == 0 {
            crate::bail!(
                "link model needs width >= 1 byte/tick and window >= 1 \
                 (got width {}, window {})",
                l.width,
                l.window
            );
        }
    }
    // Arm the fault layer up front: plan validation (machine bounds,
    // storm synthesis) and engine support both fail before any thread
    // spawns. Drop clauses never reach the engine — they become
    // per-source cut-offs applied where arrivals are still attributed
    // to sources.
    let mut drop_after: Vec<Option<u64>> = vec![None; n_sources];
    let mut injected_total = 0usize;
    let mut fault_key = String::new();
    if let Some(spec) = opts.faults.as_ref().filter(|s| !s.is_empty()) {
        for (src, at) in spec.drops() {
            if src >= n_sources {
                crate::bail!(
                    "fault spec drops source {src}, but only {n_sources} source(s) exist"
                );
            }
            let cut = drop_after[src].get_or_insert(at);
            *cut = (*cut).min(at);
        }
        injected_total = spec.injected_total();
        let plan = spec.plan(machines)?;
        fault_key = plan.key().to_string();
        engine.install_faults(plan)?;
    }
    let source_meta: Vec<(String, usize)> = sources
        .iter()
        .map(|s| (s.name.clone(), s.jobs()))
        .collect();
    let depth = opts.queue_depth.max(1);
    // 0 means unbatched (the CLI convention); a literal 0 budget would
    // otherwise admit nothing and idle-spin to max_ticks
    let batch = if opts.batch == 0 { usize::MAX } else { opts.batch };
    let started = Instant::now();
    let stall_counts: Vec<AtomicU64> = (0..n_sources).map(|_| AtomicU64::new(0)).collect();

    thread::scope(|scope| -> Result<ServeReport> {
        // spawn arrival sources
        let mut source_rxs: Vec<Receiver<SourceEvent>> = Vec::with_capacity(n_sources);
        let mut source_handles = Vec::with_capacity(n_sources);
        for (i, src) in sources.into_iter().enumerate() {
            let (tx, rx) = sync_channel::<SourceEvent>(depth);
            let stalls = &stall_counts[i];
            source_handles.push(scope.spawn(move || {
                let (machines, jobs) = (src.machines, src.jobs);
                match src.payload {
                    SourcePayload::Events(events) => feed_source(events, tx, stalls),
                    SourcePayload::Synth { spec, seed } => {
                        // cycled(5) is exactly the paper M1-M5 park, so
                        // one constructor covers every size.
                        let park = MachinePark::cycled(machines);
                        let trace = generate_trace(&spec, &park, jobs, seed);
                        let events: Vec<(u64, Job)> = trace
                            .events()
                            .iter()
                            .filter_map(|e| e.job.clone().map(|j| (e.tick, j)))
                            .collect();
                        feed_source(events, tx, stalls);
                    }
                }
            }));
            source_rxs.push(rx);
        }

        // spawn machine workers
        let mut work_txs: Vec<SyncSender<WorkItem>> = Vec::with_capacity(machines);
        let (done_tx, done_rx) =
            sync_channel::<CompletionRecord>((total_jobs + injected_total).max(16));
        for m in 0..machines {
            let (tx, rx) = sync_channel::<WorkItem>(depth);
            let done = done_tx.clone();
            scope.spawn(move || worker(m, rx, done));
            work_txs.push(tx);
        }
        drop(done_tx);

        // job registry: released ids -> Job payloads (the engine tracks
        // only metadata, like the FPGA; the host keeps the payloads)
        let mut payloads: JobMap = JobMap::with_capacity_and_hasher(
            total_jobs + injected_total,
            Default::default(),
        );

        // merge state: one head per source (None = exhausted). Blocking
        // recv is what makes the merge independent of interleaving — a
        // source is either drained or must reveal its next event before
        // the merge proceeds past its virtual time. Dropout cut-offs
        // filter here, so a dropped tail never influences the merge.
        let mut dropped = 0u64;
        let mut heads: Vec<Option<SourceEvent>> = Vec::with_capacity(n_sources);
        for src in 0..n_sources {
            heads.push(next_live(&source_rxs[src], drop_after[src], &mut dropped));
        }
        let mut staged: std::collections::VecDeque<Job> =
            std::collections::VecDeque::with_capacity(depth);

        let mut pcie = PcieStats::default();
        // The timed interconnect, when constrained. Link state depends
        // only on (virtual tick, issued byte sequence) — both pure
        // functions of the merged arrival order — so everything it
        // feeds back (admission gating, stall counts, completion ticks)
        // is interleaving- and queue-depth-invariant by construction.
        let mut link: Option<TimedLink> = opts.link.map(TimedLink::new);
        let mut metrics = MetricSet::new(machines, opts.metric_interval);
        let mut merge_depth = Histogram::new();
        let mut batch_sizes = Histogram::new();
        let mut stalls = 0u64;
        let mut released_count = 0usize;
        let mut tick = 0u64;

        while tick < opts.max_ticks {
            // Tickless jump: when the merge queue is drained, the next
            // tick that can matter is the earlier of the engine's event
            // horizon and the earliest source head. Skipped ticks are
            // provably empty (no admission, empty outcome, no exit-
            // condition change), so only telemetry needs accounting:
            // each skipped tick sampled an empty merge queue. Engines
            // without a horizon (Horizon::Unknown) run per-tick — the
            // historical loop. The jump target is deterministic (heads
            // are a pure function of the merged streams), so the
            // schedule and tick count stay interleaving-independent.
            if staged.is_empty() {
                let next_arrival = heads.iter().flatten().map(|e| e.tick).min();
                // Pending link completions are release-class events:
                // merging them into the horizon means a jump can never
                // skip a ticket retirement, so bulk accounting below
                // stays bit-identical to per-tick driving.
                let mut horizon = engine.horizon();
                if let Some(l) = link.as_ref() {
                    horizon = horizon.merge(super::Horizon::of(l.next_completion()));
                }
                let target = horizon.jump_target(next_arrival, tick).min(opts.max_ticks);
                if target > tick + 1 {
                    merge_depth.record_n(0, target - 1 - tick);
                    if let Some(l) = link.as_mut() {
                        l.bulk_occupancy(target - 1 - tick);
                    }
                    engine.advance_to(target - 1);
                    tick = target - 1;
                }
            }
            tick += 1;
            if let Some(l) = link.as_mut() {
                l.begin_tick(tick);
            }
            // arrivals for this tick: deterministic ordered merge into
            // the bounded merge queue, then batched admission (burst
            // serialization continues inside the engine's FIFO,
            // matching the hardware's host interface)
            let mut admitted = 0usize;
            // Consume an admission ticket before any job may enter the
            // engine this tick: a refused acquire throttles the whole
            // tick's admission with its typed reason, and the refused
            // jobs simply stay in the merge queue — never dropped,
            // never reordered (the merge itself keeps running below).
            let admission = match link.as_ref() {
                Some(l) => l.try_acquire(tick),
                None => Ok(()),
            };
            loop {
                while staged.len() < depth {
                    let next = heads
                        .iter()
                        .enumerate()
                        .filter_map(|(i, h)| h.as_ref().map(|e| (e.tick, i)))
                        .filter(|&(t, _)| t <= tick)
                        .min();
                    let Some((_, src)) = next else { break };
                    let ev = heads[src].take().expect("selected head exists");
                    heads[src] = next_live(&source_rxs[src], drop_after[src], &mut dropped);
                    let mut job = ev.job;
                    if n_sources > 1 && job.id >= (1 << 32) {
                        crate::bail!(
                            "source {src} produced job id {} — ids must fit in 32 bits \
                             so sources can be namespaced for the merge",
                            job.id
                        );
                    }
                    job.id += (src as u64) << 32;
                    staged.push_back(job);
                }
                if let Err(why) = admission {
                    if !staged.is_empty() {
                        link.as_mut()
                            .expect("gate refusals only come from a link")
                            .note_admission_stall(why);
                    }
                    break;
                }
                let budget = batch.saturating_sub(admitted);
                if budget == 0 || staged.is_empty() {
                    break;
                }
                // hand the whole round over as one merged batch: same
                // jobs, same FIFO order as per-job submits, but batched
                // engines cost the burst through their wavefront kernel
                let take = budget.min(staged.len());
                let mut burst = Vec::with_capacity(take);
                for _ in 0..take {
                    let job = staged.pop_front().expect("staged non-empty");
                    payloads.insert(job.id, job.clone());
                    burst.push(job);
                }
                admitted += burst.len();
                engine.submit_batch(burst);
            }
            merge_depth.record(staged.len() as u64);
            if admitted > 0 {
                batch_sizes.record(admitted as u64);
            }

            let out = engine.tick()?;
            if out.stalled {
                stalls += 1;
            }
            // storm-injected jobs materialize inside the engine and
            // bypass the merge, but the host still owns their payloads
            // (evicted jobs need nothing: their payloads stay registered
            // until the re-queued job is eventually released)
            for job in &out.injected {
                payloads.insert(job.id, job.clone());
            }
            // transport accounting: one round-trip per scheduling
            // iteration that talks to the accelerator (assignment and/or
            // releases). Under a constrained link the same round trip
            // additionally acquires a ticket: admission ticks start it
            // on a wire try_acquire just proved free, while
            // response-only ticks may queue behind the backlog (counted
            // as ResponseStalled — responses are delayed, never lost).
            if out.assigned.is_some() || !out.co_assigned.is_empty() || !out.released.is_empty()
            {
                opts.pcie.charge(&mut pcie, machines, out.released.len());
                if let Some(l) = link.as_mut() {
                    let bytes = opts.pcie.request_bytes(machines)
                        + opts.pcie.response_bytes(out.released.len());
                    l.issue(tick, bytes);
                }
            }
            if let Some(l) = link.as_mut() {
                l.end_tick();
            }
            // multi-domain engines (the sharded coordinator) assign up
            // to one job per shard per tick; co_assigned carries the
            // extras beyond the historical single slot
            for a in out.assigned.iter().chain(&out.co_assigned) {
                metrics.record_assignment(a.machine, tick);
            }
            for (id, m) in &out.released {
                let job = payloads
                    .remove(id)
                    .expect("released job must have a payload");
                released_count += 1;
                work_txs[*m]
                    .send(WorkItem {
                        job,
                        released: tick,
                    })
                    .expect("worker alive");
            }

            // A constrained run also waits for the wire to drain, so
            // `issued == completed` holds on every finished report (the
            // ticket-conservation invariant) and `ticks` covers the
            // final response's flight time.
            let link_drained = match link.as_ref() {
                Some(l) => l.is_drained(),
                None => true,
            };
            if released_count + dropped as usize == total_jobs + injected_total
                && engine.is_idle()
                && staged.is_empty()
                && heads.iter().all(Option::is_none)
                && link_drained
            {
                break;
            }
        }

        // unblock any still-feeding sources (max_ticks bailout), then
        // wait for them so the stall counters are final
        drop(heads);
        drop(source_rxs);
        for h in source_handles {
            let _ = h.join();
        }
        let source_stats: Vec<SourceStats> = source_meta
            .iter()
            .zip(&stall_counts)
            .map(|((name, jobs), stalls)| SourceStats {
                name: name.clone(),
                jobs: *jobs,
                enqueue_stalls: stalls.load(Ordering::Relaxed),
            })
            .collect();

        // close work channels; collect completions
        drop(work_txs);
        let mut completions: Vec<CompletionRecord> = done_rx.iter().collect();
        completions.sort_by_key(|c| (c.finished, c.job.id));
        let mut latency_hist = Histogram::new();
        for c in &completions {
            metrics.record_latency(c.machine, c.job.arrival, c.started);
            latency_hist.record(c.started - c.job.arrival);
        }

        let faults = engine.fault_stats().map(|mut s| {
            s.dropped_arrivals = dropped;
            s
        });
        // K = 1 sharded runs are bit-identical to unsharded runs, so
        // they report (and record) as unsharded — telemetry surfaces
        // only when there is more than one domain to tell apart.
        let shards = engine.shard_stats().filter(|t| t.shards() > 1);
        let portfolio = engine.portfolio_stats();
        let link = link.map(TimedLink::into_telemetry);
        Ok(ServeReport {
            engine: engine.label(),
            metrics: metrics.finish(),
            latency_hist,
            completions,
            pcie,
            ticks: tick,
            accel_cycles: engine.cycles(),
            wall: started.elapsed(),
            stalls,
            sources: source_stats,
            merge_depth,
            batch_sizes,
            fault_key,
            faults,
            shards,
            portfolio,
            link,
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::MachinePark;
    use crate::engine::EngineId;
    use crate::quant::Precision;
    use crate::workload::{generate_trace, WorkloadSpec};

    fn run(id: EngineId, jobs: usize, seed: u64) -> ServeReport {
        let park = MachinePark::paper_m1_m5();
        let trace = generate_trace(&WorkloadSpec::default(), &park, jobs, seed);
        let engine = id.build(5, 10, 0.5, Precision::Int8).unwrap();
        serve(engine, &trace, &ServeOpts::default()).unwrap()
    }

    #[test]
    fn serves_full_trace_with_sos_engine() {
        let r = run(EngineId::Sos, 200, 9);
        assert_eq!(r.completions.len(), 200);
        assert_eq!(r.metrics.total_scheduled, 200);
        assert!(r.pcie.transactions > 0);
        assert!(r.metrics.avg_latency >= 0.0);
        // every machine got work under the even workload
        assert!(!r.metrics.starvation);
        // single-source replay: one stream, all jobs, no id remapping
        assert_eq!(r.sources.len(), 1);
        assert_eq!(r.sources[0].jobs, 200);
        assert!(r.completions.iter().all(|c| c.job.id < (1 << 32)));
    }

    #[test]
    fn sim_engine_reports_cycles() {
        let r = run(EngineId::StannicSim, 100, 3);
        assert_eq!(r.completions.len(), 100);
        assert!(r.accel_cycles > 0);
        let h = run(EngineId::HerculesSim, 100, 3);
        assert!(
            h.accel_cycles > r.accel_cycles,
            "hercules {} vs stannic {}",
            h.accel_cycles,
            r.accel_cycles
        );
    }

    #[test]
    fn identical_schedules_across_engines() {
        let a = run(EngineId::Sos, 150, 21);
        let b = run(EngineId::StannicSim, 150, 21);
        let c = run(EngineId::HerculesSim, 150, 21);
        assert_eq!(a.metrics.jobs_per_machine, b.metrics.jobs_per_machine);
        assert_eq!(a.metrics.jobs_per_machine, c.metrics.jobs_per_machine);
        assert_eq!(a.metrics.avg_latency, b.metrics.avg_latency);
    }

    #[test]
    fn worker_virtual_time_is_fifo() {
        // one machine, two jobs released same tick: second starts when
        // the first finishes
        use crate::core::JobNature;
        let mut events = Vec::new();
        for id in 1..=2u64 {
            events.push(crate::workload::TraceEvent {
                tick: 1,
                job: Some(Job::new(id, 200.0, vec![10.0], JobNature::Mixed).with_arrival(1)),
            });
        }
        let trace = Trace::new(events, 1);
        let engine = EngineId::Sos.build(1, 10, 0.5, Precision::Int8).unwrap();
        let r = serve(engine, &trace, &ServeOpts::default()).unwrap();
        assert_eq!(r.completions.len(), 2);
        let c0 = &r.completions[0];
        let c1 = &r.completions[1];
        assert!(c1.started >= c0.finished);
    }

    #[test]
    fn synthetic_source_matches_trace_replay() {
        // A one-source synthetic pipeline must produce the identical
        // schedule to replaying the same generated trace.
        let park = MachinePark::cycled(5);
        let spec = WorkloadSpec::default();
        let trace = generate_trace(&spec, &park, 120, 77);
        let opts = ServeOpts::default();
        let a = serve(
            EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap(),
            &trace,
            &opts,
        )
        .unwrap();
        let b = serve_sources(
            EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap(),
            vec![ArrivalSource::synthetic("synth", spec, 5, 120, 77)],
            &opts,
        )
        .unwrap();
        assert_eq!(a.metrics.jobs_per_machine, b.metrics.jobs_per_machine);
        assert_eq!(a.metrics.avg_latency, b.metrics.avg_latency);
        assert_eq!(a.ticks, b.ticks);
        assert_eq!(a.stalls, b.stalls);
    }

    #[test]
    fn multi_source_merges_all_streams() {
        let sources =
            ArrivalSource::standard_mix(&WorkloadSpec::default(), 5, 100, 42, 3);
        assert_eq!(sources.iter().map(ArrivalSource::jobs).sum::<usize>(), 100);
        let r = serve_sources(
            EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap(),
            sources,
            &ServeOpts::default(),
        )
        .unwrap();
        assert_eq!(r.completions.len(), 100);
        assert_eq!(r.sources.len(), 3);
        assert_eq!(r.sources.iter().map(|s| s.jobs).sum::<usize>(), 100);
        // jobs from all three namespaces completed
        for src in 0..3u64 {
            assert!(
                r.completions.iter().any(|c| c.job.id >> 32 == src),
                "no completions from source {src}"
            );
        }
    }

    #[test]
    fn batched_admission_caps_per_tick_submissions() {
        let spec = WorkloadSpec::default();
        let opts = ServeOpts::new().with_batch(2);
        let r = serve_sources(
            EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap(),
            vec![ArrivalSource::synthetic("s", spec, 5, 150, 5)],
            &opts,
        )
        .unwrap();
        assert_eq!(r.completions.len(), 150);
        assert!(r.batch_sizes.count() > 0);
        assert!(
            r.batch_sizes.max() <= 2,
            "admission must respect the batch cap, saw {}",
            r.batch_sizes.max()
        );
    }

    #[test]
    fn empty_source_set_is_an_error() {
        assert!(serve_sources(
            EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap(),
            Vec::new(),
            &ServeOpts::default(),
        )
        .is_err());
    }

    #[test]
    fn faulted_serve_completes_and_reports_recovery() {
        use crate::faults::FaultSpec;
        let spec = WorkloadSpec::default();
        let opts = ServeOpts::new()
            .with_faults(FaultSpec::parse("down=1@20+30,storm=4@25,seed=3").unwrap());
        let r = serve_sources(
            EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap(),
            vec![ArrivalSource::synthetic("s", spec, 5, 80, 11)],
            &opts,
        )
        .unwrap();
        // every trace job plus every storm job completes
        assert_eq!(r.completions.len(), 84);
        assert_eq!(r.fault_key, "down=1@20+30,storm=4@25,seed=3");
        let stats = r.faults.expect("faulted run reports recovery metrics");
        assert_eq!(stats.downs, 1);
        assert_eq!(stats.ups, 1);
        assert_eq!(stats.injected_jobs, 4);
        assert_eq!(
            stats.degraded_ticks, 30,
            "the down window is ticks 20..50 whether executed or jumped"
        );
        assert_eq!(stats.down_machine_ticks, 30);
    }

    #[test]
    fn faulted_serve_is_queue_depth_invariant() {
        use crate::faults::FaultSpec;
        let run = |depth: usize| {
            let opts = ServeOpts::new().with_queue_depth(depth).with_faults(
                FaultSpec::parse("down=0@15+20,slow=2@10+40x4,policy=lose").unwrap(),
            );
            serve_sources(
                EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap(),
                ArrivalSource::standard_mix(&WorkloadSpec::default(), 5, 90, 13, 2),
                &opts,
            )
            .unwrap()
        };
        let a = run(4);
        let b = run(256);
        assert_eq!(a.completions, b.completions);
        assert_eq!(a.ticks, b.ticks);
        assert_eq!(a.metrics.jobs_per_machine, b.metrics.jobs_per_machine);
    }

    #[test]
    fn source_dropout_discards_the_tail() {
        use crate::faults::FaultSpec;
        // drop=0@1 silences the only source entirely: nothing completes,
        // and the pipeline still terminates with full accounting
        let opts = ServeOpts::new().with_faults(FaultSpec::parse("drop=0@1").unwrap());
        let r = serve_sources(
            EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap(),
            vec![ArrivalSource::synthetic("s", WorkloadSpec::default(), 5, 40, 9)],
            &opts,
        )
        .unwrap();
        assert_eq!(r.completions.len(), 0);
        assert_eq!(r.faults.expect("faulted run").dropped_arrivals, 40);

        // a drop clause naming a source that does not exist fails loudly
        let opts = ServeOpts::new().with_faults(FaultSpec::parse("drop=7@5").unwrap());
        assert!(serve_sources(
            EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap(),
            vec![ArrivalSource::synthetic("s", WorkloadSpec::default(), 5, 10, 9)],
            &opts,
        )
        .is_err());
    }

    #[test]
    fn non_golden_engine_rejects_fault_specs() {
        use crate::faults::FaultSpec;
        let opts = ServeOpts::new().with_faults(FaultSpec::parse("down=0@5+5").unwrap());
        let err = serve_sources(
            EngineId::Sosc.build(5, 10, 0.5, Precision::Int8).unwrap(),
            vec![ArrivalSource::synthetic("s", WorkloadSpec::default(), 5, 10, 1)],
            &opts,
        )
        .unwrap_err();
        assert!(err.to_string().contains("does not support fault injection"));
    }

    #[test]
    fn sharded_pipeline_serves_and_reports_telemetry() {
        let sources =
            ArrivalSource::standard_mix(&WorkloadSpec::default(), 10, 120, 17, 2);
        let engine = EngineId::Sos.build_sharded(2, 10, 10, 0.5, Precision::Int8).unwrap();
        let r = serve_sources(engine, sources, &ServeOpts::new().with_shards(2)).unwrap();
        assert_eq!(r.completions.len(), 120);
        let t = r.shards.expect("sharded run reports shard telemetry");
        assert_eq!(t.shards(), 2);
        assert_eq!(t.per_shard.iter().map(|s| s.completed).sum::<u64>(), 120);
        assert_eq!(t.per_shard.iter().map(|s| s.routed).sum::<u64>(), 120);
        assert_eq!(t.per_shard[0].first_machine, 0);
        assert_eq!(t.per_shard[1].first_machine, 5);
        assert!(t.imbalance_cv >= 0.0);
    }

    #[test]
    fn shard_request_refuses_single_domain_and_mismatched_engines() {
        let opts = ServeOpts::new().with_shards(2);
        let err = serve_sources(
            EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap(),
            vec![ArrivalSource::synthetic("s", WorkloadSpec::default(), 5, 10, 1)],
            &opts,
        )
        .unwrap_err();
        assert!(err.to_string().contains("single-domain"), "{err}");
        let err = serve_sources(
            EngineId::Sos.build_sharded(3, 6, 10, 0.5, Precision::Int8).unwrap(),
            vec![ArrivalSource::synthetic("s", WorkloadSpec::default(), 6, 10, 1)],
            &opts,
        )
        .unwrap_err();
        assert!(err.to_string().contains("built with 3 shard(s)"), "{err}");
    }

    #[test]
    fn unsharded_and_single_shard_reports_carry_no_shard_telemetry() {
        let run = |sharded: bool| {
            let engine = if sharded {
                EngineId::Sos.build_sharded(1, 5, 10, 0.5, Precision::Int8).unwrap()
            } else {
                EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap()
            };
            serve_sources(
                engine,
                vec![ArrivalSource::synthetic("s", WorkloadSpec::default(), 5, 60, 4)],
                &ServeOpts::default(),
            )
            .unwrap()
        };
        assert!(run(false).shards.is_none());
        assert!(run(true).shards.is_none(), "K = 1 reports as unsharded");
    }

    #[test]
    fn portfolio_serve_drains_reports_telemetry_and_switches() {
        // The rotating standard mix (steady + bursty + heavy-tailed)
        // is exactly the drifting arrival regime the portfolio exists
        // for: at least one loaded window must hand the win to a
        // non-SOS candidate.
        let r = serve_sources(
            EngineId::Portfolio.build(5, 10, 0.5, Precision::Int8).unwrap(),
            ArrivalSource::standard_mix(&WorkloadSpec::default(), 5, 150, 42, 3),
            &ServeOpts::default(),
        )
        .unwrap();
        assert_eq!(r.engine, "portfolio");
        assert_eq!(r.completions.len(), 150);
        let t = r.portfolio.expect("portfolio run reports telemetry");
        assert!(t.windows >= 1, "loaded run must evaluate windows");
        assert!(t.switches >= 1, "rotating mix must trigger a policy switch");
        assert_eq!(t.wins.iter().map(|(_, w)| *w).sum::<u64>(), t.windows);
        assert_eq!(t.switch_log.len() as u64, t.switches);
        assert!(t.replay_ticks > 0);
    }

    #[test]
    fn portfolio_serve_is_queue_depth_invariant() {
        let run = |depth: usize| {
            serve_sources(
                EngineId::Portfolio.build(5, 10, 0.5, Precision::Int8).unwrap(),
                ArrivalSource::standard_mix(&WorkloadSpec::default(), 5, 120, 7, 2),
                &ServeOpts::new().with_queue_depth(depth),
            )
            .unwrap()
        };
        let a = run(2);
        let b = run(256);
        assert_eq!(a.completions, b.completions);
        assert_eq!(a.ticks, b.ticks);
        let (ta, tb) = (a.portfolio.unwrap(), b.portfolio.unwrap());
        assert_eq!(ta, tb, "switch sequence is interleaving-independent");
        assert_eq!(ta.switch_digest(), tb.switch_digest());
    }

    #[test]
    fn plain_engine_reports_carry_no_portfolio_telemetry() {
        let r = run(EngineId::Sos, 60, 4);
        assert!(r.portfolio.is_none());
    }

    #[test]
    fn json_summary_of_a_clean_run_carries_no_gated_blocks() {
        let r = run(EngineId::Sos, 60, 4);
        let text = r.json_summary().to_string();
        let j = crate::jsonio::Json::parse(&text).expect("summary parses");
        assert!(j.get("engine").is_some());
        assert!(j.get("completed").is_some());
        for gated in [
            "fault",
            "fault_injected",
            "shards",
            "rebalance_moves",
            "portfolio_windows",
            "portfolio_switch_digest",
            "link_width",
            "link_issued",
            "link_stall_busy",
            "link_wait_p95",
        ] {
            assert!(
                j.get(gated).is_none(),
                "clean summary must not carry gated key {gated}: {text}"
            );
        }
        // the clean payload is byte-stable: re-running the same scenario
        // renders the identical string (no timing field leaks in)
        assert_eq!(text, run(EngineId::Sos, 60, 4).json_summary().to_string());
    }

    #[test]
    fn json_summary_carries_fault_and_portfolio_blocks_when_present() {
        let faulted = {
            let park = MachinePark::paper_m1_m5();
            let trace = generate_trace(&WorkloadSpec::default(), &park, 120, 11);
            let engine = EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap();
            let opts =
                ServeOpts::new().with_faults(FaultSpec::parse("down=1@30+20,seed=3").unwrap());
            serve(engine, &trace, &opts).unwrap()
        };
        let j = crate::jsonio::Json::parse(&faulted.json_summary().to_string()).unwrap();
        assert!(j.get("fault").is_some(), "faulted summary names the spec");
        assert!(j.get("fault_evicted").is_some());
        assert!(j.get("portfolio_windows").is_none());

        let portfolio = serve_sources(
            EngineId::Portfolio.build(5, 10, 0.5, Precision::Int8).unwrap(),
            ArrivalSource::standard_mix(&WorkloadSpec::default(), 5, 120, 7, 2),
            &ServeOpts::default(),
        )
        .unwrap();
        let j = crate::jsonio::Json::parse(&portfolio.json_summary().to_string()).unwrap();
        for key in [
            "portfolio_windows",
            "portfolio_switches",
            "portfolio_live",
            "portfolio_switch_digest",
            "portfolio_replay_ticks",
        ] {
            assert!(j.get(key).is_some(), "portfolio summary must carry {key}");
        }
        assert!(j.get("fault").is_none());
        assert!(j.get("shards").is_none());
    }

    #[test]
    fn narrow_link_throttles_but_never_drops_jobs() {
        use super::super::link::LinkModel;
        let spec = WorkloadSpec::bursty();
        let r = serve_sources(
            EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap(),
            vec![ArrivalSource::synthetic("s", spec.clone(), 5, 120, 5)],
            &ServeOpts::new().with_link(LinkModel::with_width(4)),
        )
        .unwrap();
        // graceful degradation: every job still completes, exactly once
        assert_eq!(r.completions.len(), 120);
        let mut ids: Vec<u64> = r.completions.iter().map(|c| c.job.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 120, "no job completed twice");
        let l = r.link.expect("constrained run reports link telemetry");
        assert_eq!(l.width, 4);
        // ticket conservation: the loop drains the wire before exiting
        assert_eq!(l.issued, l.completed);
        assert!(l.issued > 0);
        // a 4 B/tick wire under the bursty mix must actually push back
        assert!(l.total_stalls() > 0, "narrow link must report stalls");
        assert!(l.wait.count() == l.completed);
        // and the same scenario unbounded carries no link block at all
        let clean = serve_sources(
            EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap(),
            vec![ArrivalSource::synthetic("s", spec, 5, 120, 5)],
            &ServeOpts::default(),
        )
        .unwrap();
        assert!(clean.link.is_none());
        assert!(
            r.ticks > clean.ticks,
            "a saturated wire must stretch virtual drain time ({} vs {})",
            r.ticks,
            clean.ticks
        );
    }

    #[test]
    fn constrained_serve_is_queue_depth_invariant() {
        use super::super::link::LinkModel;
        let run = |depth: usize| {
            serve_sources(
                EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap(),
                ArrivalSource::standard_mix(&WorkloadSpec::default(), 5, 100, 23, 2),
                &ServeOpts::new()
                    .with_queue_depth(depth)
                    .with_link(LinkModel::with_width(6)),
            )
            .unwrap()
        };
        let a = run(2);
        let b = run(256);
        assert_eq!(a.completions, b.completions);
        assert_eq!(a.ticks, b.ticks);
        let (la, lb) = (a.link.unwrap(), b.link.unwrap());
        assert_eq!(la.issued, lb.issued);
        assert_eq!(
            (la.stall_busy, la.stall_window, la.stall_response),
            (lb.stall_busy, lb.stall_window, lb.stall_response),
            "typed stall counts are interleaving-invariant"
        );
        assert_eq!(la.occupancy.p50(), lb.occupancy.p50());
        assert_eq!(la.wait.p95(), lb.wait.p95());
    }

    #[test]
    fn degenerate_link_models_are_refused() {
        use super::super::link::LinkModel;
        for model in [
            LinkModel { width: 0, latency: 1, window: 8 },
            LinkModel { width: 8, latency: 1, window: 0 },
        ] {
            let err = serve_sources(
                EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap(),
                vec![ArrivalSource::synthetic("s", WorkloadSpec::default(), 5, 10, 1)],
                &ServeOpts::new().with_link(model),
            )
            .unwrap_err();
            assert!(err.to_string().contains("link model"), "{err}");
        }
    }

    #[test]
    fn id_hasher_byte_path_mixes_like_u64_path() {
        use std::hash::Hasher as _;
        // the byte path must spread short keys across the full word, not
        // cluster them in the low bits
        let mut lows = std::collections::HashSet::new();
        for k in 0u32..64 {
            let mut h = IdHasher::default();
            h.write(&k.to_le_bytes());
            lows.insert(h.finish() >> 48);
        }
        assert!(
            lows.len() > 32,
            "high bits of byte-hashed keys barely vary: {} distinct",
            lows.len()
        );
        // and the u64 fast path stays what the hot path relies on
        let mut h = IdHasher::default();
        h.write_u64(7);
        assert_eq!(h.finish(), 7u64.wrapping_mul(0x9e37_79b9_7f4a_7c15));
    }
}
