//! PCIe transport model — the host<->accelerator link of the paper's
//! deployment (Xilinx XRT / AXI4 Memory-Map over PCIe, Section 7.1).
//!
//! Section 8.2 reports "PCIe communication overhead is on average 4789
//! microseconds per 10,000 jobs across all tested configuration sizes",
//! i.e. ~479 ns per scheduled job, dominated by per-transaction latency
//! rather than payload size. The model charges a fixed per-transaction
//! cost plus a small per-byte cost, which reproduces both the magnitude
//! and the (near-)configuration-independence the paper observed.

/// Transport model parameters.
#[derive(Debug, Clone, Copy)]
pub struct PcieModel {
    /// Per-transaction round-trip latency (ns) — doorbell + DMA setup.
    pub per_txn_ns: f64,
    /// Per-byte streaming cost (ns) — ~16 GB/s effective gen3 x16.
    pub per_byte_ns: f64,
}

impl Default for PcieModel {
    fn default() -> Self {
        PcieModel {
            per_txn_ns: 470.0,
            per_byte_ns: 0.0625,
        }
    }
}

/// Accumulated transport accounting for one run.
#[derive(Debug, Clone, Default)]
pub struct PcieStats {
    pub transactions: u64,
    pub bytes: u64,
    pub total_ns: f64,
}

impl PcieModel {
    /// Bytes to ship one job's scheduling request: id (8) + weight (1,
    /// INT8) + EPT vector (1 byte per machine) + flags.
    pub fn request_bytes(&self, machines: usize) -> u64 {
        8 + 1 + machines as u64 + 3
    }

    /// Bytes for the accelerator's response: assigned machine + released
    /// job ids this iteration (paper: scheduling decisions stream back).
    pub fn response_bytes(&self, released: usize) -> u64 {
        4 + 8 * released as u64
    }

    /// Charge one scheduling round-trip.
    pub fn charge(&self, stats: &mut PcieStats, machines: usize, released: usize) {
        let bytes = self.request_bytes(machines) + self.response_bytes(released);
        stats.transactions += 1;
        stats.bytes += bytes;
        stats.total_ns += self.per_txn_ns + self.per_byte_ns * bytes as f64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrated_to_paper_overhead() {
        // 10,000 jobs across config sizes 5..=140 should land near the
        // paper's 4789 us average.
        let model = PcieModel::default();
        let mut totals = Vec::new();
        for m in [5usize, 10, 20, 40, 80, 140] {
            let mut s = PcieStats::default();
            for _ in 0..10_000 {
                model.charge(&mut s, m, 1);
            }
            totals.push(s.total_ns / 1000.0); // us
        }
        let avg = totals.iter().sum::<f64>() / totals.len() as f64;
        assert!(
            (avg - 4789.0).abs() / 4789.0 < 0.05,
            "avg {avg} us vs paper 4789 us"
        );
        // and near configuration-independent (latency-dominated)
        let spread = totals.iter().cloned().fold(f64::MIN, f64::max)
            - totals.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread / avg < 0.25, "spread {spread} vs avg {avg}");
    }

    #[test]
    fn accounting_accumulates() {
        let model = PcieModel::default();
        let mut s = PcieStats::default();
        model.charge(&mut s, 10, 2);
        assert_eq!(s.transactions, 1);
        assert_eq!(s.bytes, model.request_bytes(10) + model.response_bytes(2));
        assert!(s.total_ns > model.per_txn_ns);
    }
}
