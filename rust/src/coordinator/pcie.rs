//! PCIe transport model — the host<->accelerator link of the paper's
//! deployment (Xilinx XRT / AXI4 Memory-Map over PCIe, Section 7.1).
//!
//! Section 8.2 reports "PCIe communication overhead is on average 4789
//! microseconds per 10,000 jobs across all tested configuration sizes",
//! i.e. ~479 ns per scheduled job, dominated by per-transaction latency
//! rather than payload size. The model charges a fixed per-transaction
//! cost plus a small per-byte cost, which reproduces both the magnitude
//! and the (near-)configuration-independence the paper observed.
//!
//! This module is the *passive* cost ledger: it bills round trips after
//! the fact and never pushes back. The *active* counterpart is
//! [`super::link`] — a timed virtual-time service law that throttles
//! admission with backpressure tickets when the wire saturates
//! (`serve --link-width W`). Both regimes bill through [`PcieStats`];
//! the ledger accumulates in exact integer femtoseconds, so the total
//! is independent of charge order and can gate as a deterministic perf
//! cell in `serve diff`.

/// Femtoseconds per nanosecond — the ledger's fixed-point scale. The
/// default model's costs are whole femtosecond counts (470 ns and
/// 1/16 ns both are), so accumulation is exact and order-independent.
const FS_PER_NS: f64 = 1e6;

/// Transport model parameters.
#[derive(Debug, Clone, Copy)]
pub struct PcieModel {
    /// Per-transaction round-trip latency (ns) — doorbell + DMA setup.
    pub per_txn_ns: f64,
    /// Per-byte streaming cost (ns) — ~16 GB/s effective gen3 x16.
    pub per_byte_ns: f64,
}

impl Default for PcieModel {
    fn default() -> Self {
        PcieModel {
            per_txn_ns: 470.0,
            per_byte_ns: 0.0625,
        }
    }
}

/// Accumulated transport accounting for one run. Time accrues in
/// integer femtoseconds ([`PcieStats::total_fs`]); the rendered
/// [`PcieStats::total_ns`] is derived on read and is numerically
/// identical to the historical f64 accumulator for the default model
/// (every charge is an exact multiple of 1/16 ns).
#[derive(Debug, Clone, Default)]
pub struct PcieStats {
    pub transactions: u64,
    pub bytes: u64,
    /// Total transport time in integer femtoseconds — exact, so the sum
    /// is the same for any charge order (the property the f64
    /// accumulator it replaced could not guarantee).
    pub total_fs: u64,
}

impl PcieStats {
    /// Total transport time in nanoseconds, for rendering.
    pub fn total_ns(&self) -> f64 {
        self.total_fs as f64 / FS_PER_NS
    }
}

impl PcieModel {
    /// Bytes to ship one job's scheduling request: id (8) + weight (1,
    /// INT8) + EPT vector (1 byte per machine) + flags.
    pub fn request_bytes(&self, machines: usize) -> u64 {
        8 + 1 + machines as u64 + 3
    }

    /// Bytes for the accelerator's response: assigned machine + released
    /// job ids this iteration (paper: scheduling decisions stream back).
    pub fn response_bytes(&self, released: usize) -> u64 {
        4 + 8 * released as u64
    }

    /// One round trip's cost in integer femtoseconds.
    pub fn round_trip_fs(&self, bytes: u64) -> u64 {
        let per_txn_fs = (self.per_txn_ns * FS_PER_NS).round() as u64;
        let per_byte_fs = (self.per_byte_ns * FS_PER_NS).round() as u64;
        per_txn_fs + per_byte_fs * bytes
    }

    /// Charge one scheduling round-trip.
    pub fn charge(&self, stats: &mut PcieStats, machines: usize, released: usize) {
        let bytes = self.request_bytes(machines) + self.response_bytes(released);
        stats.transactions += 1;
        stats.bytes += bytes;
        stats.total_fs += self.round_trip_fs(bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrated_to_paper_overhead() {
        // 10,000 jobs across config sizes 5..=140 should land near the
        // paper's 4789 us average.
        let model = PcieModel::default();
        let mut totals = Vec::new();
        for m in [5usize, 10, 20, 40, 80, 140] {
            let mut s = PcieStats::default();
            for _ in 0..10_000 {
                model.charge(&mut s, m, 1);
            }
            totals.push(s.total_ns() / 1000.0); // us
        }
        let avg = totals.iter().sum::<f64>() / totals.len() as f64;
        assert!(
            (avg - 4789.0).abs() / 4789.0 < 0.05,
            "avg {avg} us vs paper 4789 us"
        );
        // and near configuration-independent (latency-dominated)
        let spread = totals.iter().cloned().fold(f64::MIN, f64::max)
            - totals.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread / avg < 0.25, "spread {spread} vs avg {avg}");
    }

    #[test]
    fn accounting_accumulates() {
        let model = PcieModel::default();
        let mut s = PcieStats::default();
        model.charge(&mut s, 10, 2);
        assert_eq!(s.transactions, 1);
        assert_eq!(s.bytes, model.request_bytes(10) + model.response_bytes(2));
        assert!(s.total_ns() > model.per_txn_ns);
    }

    #[test]
    fn integer_accumulation_is_order_independent_and_ns_exact() {
        let model = PcieModel::default();
        // forward and reverse charge orders land on the same integer
        let mut fwd = PcieStats::default();
        let mut rev = PcieStats::default();
        let loads: Vec<(usize, usize)> = (0..200).map(|i| (5 + i % 17, i % 5)).collect();
        for &(m, r) in &loads {
            model.charge(&mut fwd, m, r);
        }
        for &(m, r) in loads.iter().rev() {
            model.charge(&mut rev, m, r);
        }
        assert_eq!(fwd.total_fs, rev.total_fs);
        // the rendered value matches the historical f64 accumulator
        let mut f64_total = 0.0;
        for &(m, r) in &loads {
            let bytes = model.request_bytes(m) + model.response_bytes(r);
            f64_total += model.per_txn_ns + model.per_byte_ns * bytes as f64;
        }
        assert_eq!(fwd.total_ns(), f64_total);
        // default-model costs are exact femtosecond counts
        assert_eq!(model.round_trip_fs(0), 470_000_000);
        assert_eq!(model.round_trip_fs(16), 470_000_000 + 16 * 62_500);
    }
}
