//! Engine adapters: a single object-safe interface over every SOS
//! implementation in the repo. Construction and naming live in the
//! [`crate::engine::EngineId`] registry; each adapter's `label()` is the
//! registry's canonical name for that backend.
//!
//! Per-tick engines (no event horizon, no fault layer, infallible tick)
//! all adapt identically except for the label and the method-dispatch
//! path; [`per_tick_adapter!`] stamps those impls out, so adding a new
//! per-tick backend is one line here, not a forty-line copy-paste.
//! Engines with real capabilities (the golden tickless [`SosEngine`],
//! the sharded [`super::shard::ShardedEngine`], the fallible
//! [`XlaSosEngine`]) keep hand-written impls.
//!
//! The timed interconnect ([`super::link::TimedLink`]) sits *above*
//! this interface: the serve loop acquires a backpressure ticket
//! before `submit_batch`/`submit` is ever called, so adapters stay
//! wire-oblivious — an engine sees exactly the admission sequence the
//! link let through, and an unconstrained run's call stream is
//! untouched.

use crate::baselines::{SimdSos, SoscEngine};
use crate::bail;
use crate::core::Job;
use crate::engine::portfolio::PortfolioTelemetry;
use crate::error::Result;
use crate::faults::{FaultPlan, FaultStats};
use crate::runtime::XlaSosEngine;
use crate::scheduler::{Horizon, SosEngine, TickOutcome};
use crate::sim::{hercules::HerculesSim, stannic::StannicSim, ArchSim};

use super::shard::ShardTelemetry;

/// Object-safe engine interface used by the coordinator. (Not `Send`:
/// the PJRT client is single-threaded by design; the coordinator keeps
/// the engine on the scheduler thread and ships only work items across
/// channels.)
pub trait EngineAdapter {
    fn label(&self) -> &'static str;
    fn submit(&mut self, job: Job);
    /// Enqueue one merged admission batch. Semantically identical to
    /// submitting each job in order (the default does exactly that) —
    /// engines with a batched Phase-II entry override this to hand the
    /// whole burst over in one call (the golden engine routes it to
    /// [`SosEngine::assign_batch`], whose wavefront kernel costs the
    /// burst against resident SoA columns).
    fn submit_batch(&mut self, jobs: Vec<Job>) {
        for job in jobs {
            self.submit(job);
        }
    }
    fn tick(&mut self) -> Result<TickOutcome>;
    fn is_idle(&self) -> bool;
    /// Simulated accelerator cycles consumed so far (0 for software
    /// engines that have no cycle model).
    fn cycles(&self) -> u64 {
        0
    }
    /// The engine's event horizon. Engines that cannot fast-forward
    /// report [`Horizon::Unknown`] and are driven per-tick, which is
    /// exactly the historical behaviour.
    fn horizon(&mut self) -> Horizon {
        Horizon::Unknown
    }
    /// Fast-forward virtual time to `tick`. Drive loops only call this
    /// for a window their own `horizon()` call proved event-free, and
    /// never on [`Horizon::Unknown`] engines.
    fn advance_to(&mut self, tick: u64) {
        let _ = tick;
        unreachable!(
            "advance_to on engine `{}`, which reported Horizon::Unknown",
            self.label()
        );
    }
    /// Arm a deterministic fault plan ([`crate::faults`]). Only the
    /// golden engine carries the fault layer; every other backend
    /// rejects the request up front so `serve --faults` fails loudly
    /// instead of silently running clean.
    fn install_faults(&mut self, plan: FaultPlan) -> Result<()> {
        let _ = plan;
        bail!(
            "engine `{}` does not support fault injection (use --engine sos)",
            self.label()
        );
    }
    /// Recovery metrics of the installed fault plan, if any.
    fn fault_stats(&self) -> Option<FaultStats> {
        None
    }
    /// Per-shard telemetry (routing counts, schedule digests, rebalance
    /// activity). `Some` only for the sharded coordinator engine —
    /// `serve --shards K>1` refuses any engine that returns `None`, so
    /// a shard request can never silently run single-domain.
    fn shard_stats(&self) -> Option<ShardTelemetry> {
        None
    }
    /// Portfolio meta-engine telemetry (window wins, switch log,
    /// shadow-replay work counters). `Some` only for
    /// [`crate::engine::portfolio::PortfolioEngine`]; plain engines
    /// return `None` so their serve reports and records stay
    /// byte-identical.
    fn portfolio_stats(&self) -> Option<PortfolioTelemetry> {
        None
    }
}

/// Stamp out an [`EngineAdapter`] impl for a per-tick engine: label,
/// submit/tick/is_idle forwarded through `$via` (the inherent or trait
/// path the engine's methods live on), horizon left at the
/// [`Horizon::Unknown`] default. Append `, cycles` for simulators whose
/// `stats().total_cycles()` models accelerator time.
macro_rules! per_tick_adapter {
    ($engine:ty, $label:expr, via $via:ident) => {
        per_tick_adapter!(@impl $engine, $label, $via;);
    };
    ($engine:ty, $label:expr, via $via:ident, cycles) => {
        per_tick_adapter!(@impl $engine, $label, $via;
            fn cycles(&self) -> u64 {
                self.stats().total_cycles()
            });
    };
    (@impl $engine:ty, $label:expr, $via:ident; $($extra:item)*) => {
        impl EngineAdapter for $engine {
            fn label(&self) -> &'static str {
                $label
            }
            fn submit(&mut self, job: Job) {
                $via::submit(self, job);
            }
            fn tick(&mut self) -> Result<TickOutcome> {
                Ok($via::tick(self, None))
            }
            fn is_idle(&self) -> bool {
                $via::is_idle(self)
            }
            $($extra)*
        }
    };
}

per_tick_adapter!(SoscEngine, "sosc", via SoscEngine);
per_tick_adapter!(SimdSos, "simd", via SimdSos);
per_tick_adapter!(StannicSim, "stannic-sim", via ArchSim, cycles);
per_tick_adapter!(HerculesSim, "hercules-sim", via ArchSim, cycles);

impl EngineAdapter for SosEngine {
    fn label(&self) -> &'static str {
        "sos"
    }
    fn submit(&mut self, job: Job) {
        SosEngine::submit(self, job);
    }
    fn submit_batch(&mut self, jobs: Vec<Job>) {
        SosEngine::assign_batch(self, jobs);
    }
    fn tick(&mut self) -> Result<TickOutcome> {
        Ok(SosEngine::tick(self, None))
    }
    fn is_idle(&self) -> bool {
        SosEngine::is_idle(self)
    }
    fn horizon(&mut self) -> Horizon {
        Horizon::of(self.next_event_tick())
    }
    fn advance_to(&mut self, tick: u64) {
        SosEngine::advance_to(self, tick);
    }
    fn install_faults(&mut self, plan: FaultPlan) -> Result<()> {
        SosEngine::install_faults(self, plan);
        Ok(())
    }
    fn fault_stats(&self) -> Option<FaultStats> {
        SosEngine::fault_stats(self).cloned()
    }
}

impl EngineAdapter for XlaSosEngine {
    fn label(&self) -> &'static str {
        "xla"
    }
    fn submit(&mut self, job: Job) {
        XlaSosEngine::submit(self, job);
    }
    fn tick(&mut self) -> Result<TickOutcome> {
        XlaSosEngine::tick(self, None)
    }
    fn is_idle(&self) -> bool {
        XlaSosEngine::is_idle(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::JobNature;
    use crate::quant::Precision;

    #[test]
    fn golden_adapter_exposes_the_event_horizon() {
        let mut e: Box<dyn EngineAdapter> =
            Box::new(SosEngine::new(2, 4, 0.5, Precision::Int8));
        assert_eq!(e.horizon(), Horizon::Idle, "fresh engine: nothing scheduled");
        e.submit(Job::new(1, 8.0, vec![40.0, 90.0], JobNature::Mixed));
        assert_eq!(e.horizon(), Horizon::At(1), "pending arrival: next tick");
        e.tick().unwrap(); // assign; alpha_pt = 20 -> pops at tick 21
        assert_eq!(e.horizon(), Horizon::At(21));
        e.advance_to(20);
        let out = e.tick().unwrap();
        assert_eq!(out.released, vec![(1, 0)]);
        assert_eq!(e.horizon(), Horizon::Idle);
    }

    #[test]
    fn per_tick_adapters_report_unknown_horizon() {
        let mut engines: Vec<Box<dyn EngineAdapter>> = vec![
            Box::new(SoscEngine::new(2, 4, 0.5, Precision::Int8)),
            Box::new(SimdSos::new(2, 4, 0.5, Precision::Int8)),
            Box::new(StannicSim::new(2, 4, 0.5, Precision::Int8)),
            Box::new(HerculesSim::new(2, 4, 0.5, Precision::Int8)),
        ];
        for e in engines.iter_mut() {
            assert_eq!(e.horizon(), Horizon::Unknown, "{}", e.label());
        }
    }

    #[test]
    #[should_panic(expected = "engine `sosc`")]
    fn advance_to_default_names_the_misbehaving_engine() {
        let mut e: Box<dyn EngineAdapter> =
            Box::new(SoscEngine::new(2, 4, 0.5, Precision::Int8));
        e.advance_to(10);
    }

    #[test]
    fn only_the_golden_adapter_accepts_faults() {
        let plan = crate::faults::FaultSpec::parse("down=0@5+2")
            .unwrap()
            .plan(2)
            .unwrap();
        let mut sos: Box<dyn EngineAdapter> =
            Box::new(SosEngine::new(2, 4, 0.5, Precision::Int8));
        assert!(sos.install_faults(plan.clone()).is_ok());
        assert!(sos.fault_stats().is_some());
        let mut sosc: Box<dyn EngineAdapter> =
            Box::new(SoscEngine::new(2, 4, 0.5, Precision::Int8));
        assert!(sosc.install_faults(plan).is_err());
        assert!(sosc.fault_stats().is_none());
    }

    #[test]
    fn adapters_share_semantics() {
        let mut engines: Vec<Box<dyn EngineAdapter>> = vec![
            Box::new(SosEngine::new(2, 4, 0.5, Precision::Int8)),
            Box::new(SoscEngine::new(2, 4, 0.5, Precision::Int8)),
            Box::new(SimdSos::new(2, 4, 0.5, Precision::Int8)),
            Box::new(StannicSim::new(2, 4, 0.5, Precision::Int8)),
            Box::new(HerculesSim::new(2, 4, 0.5, Precision::Int8)),
        ];
        let job = Job::new(1, 4.0, vec![20.0, 40.0], JobNature::Mixed);
        let mut outcomes = Vec::new();
        for e in engines.iter_mut() {
            e.submit(job.clone());
            let out = e.tick().unwrap();
            outcomes.push(out.assigned.map(|a| (a.job, a.machine, a.position)));
        }
        for o in &outcomes[1..] {
            assert_eq!(o, &outcomes[0]);
        }
    }
}
