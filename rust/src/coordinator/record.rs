//! Persisted serve reports — the coordinator's arm of the repo's
//! benchmarking backbone.
//!
//! `serve --record out.json` turns one serving run into a durable,
//! machine-readable artifact the same way `sweep --record` does for the
//! grid: a [`ServeRecord`] serializes the run key (engine, batch,
//! sources), the deterministic outcome (schedule metrics, tick count,
//! merge/batch telemetry percentiles), and the timing-dependent
//! backpressure observations (per-source enqueue stalls, wall time)
//! through [`crate::jsonio`]. Parsing reuses the strict field accessors
//! of [`crate::sweep::record`] (u64-exact fields travel as strings;
//! hand-edited artifacts fail at parse time with the field name).

use std::time::{SystemTime, UNIX_EPOCH};

use crate::jsonio::{arr, num, obj, s, Json};
use crate::sweep::record::{get_arr, get_str, get_u64_str, get_uint};

use super::server::ServeReport;

/// Schema tag embedded in every serve artifact.
pub const SERVE_RECORD_SCHEMA: &str = "stannic.serve.record.v1";

/// Per-source slice of a persisted serve run.
#[derive(Debug, Clone, PartialEq)]
pub struct SourceRecord {
    pub name: String,
    pub jobs: usize,
    /// Enqueue stalls observed on this source's bounded arrival queue
    /// (timing-dependent, like wall time).
    pub enqueue_stalls: u64,
}

/// One persisted serving run.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeRecord {
    pub label: String,
    pub engine: String,
    /// Unix seconds at record time (0 when the clock is unavailable).
    pub created_unix: u64,
    pub completed: usize,
    pub ticks: u64,
    /// Engine-side stalled iterations (every V_i full).
    pub stalls: u64,
    pub accel_cycles: u64,
    pub wall_ns: u64,
    pub avg_latency: f64,
    pub fairness: f64,
    pub load_cv: f64,
    pub throughput: f64,
    pub jobs_per_machine: Vec<usize>,
    pub latency_p50: u64,
    pub latency_p95: u64,
    pub latency_p99: u64,
    /// Merge-queue depth percentiles (per-tick samples).
    pub merge_depth_p50: u64,
    pub merge_depth_p99: u64,
    pub merge_depth_max: u64,
    /// Admission batch-size percentiles (ticks admitting >= 1 job).
    pub batch_p50: u64,
    pub batch_p99: u64,
    pub batch_max: u64,
    pub sources: Vec<SourceRecord>,
}

impl ServeRecord {
    pub fn from_report(label: &str, r: &ServeReport) -> ServeRecord {
        ServeRecord {
            label: label.to_string(),
            engine: r.engine.to_string(),
            created_unix: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
            completed: r.completions.len(),
            ticks: r.ticks,
            stalls: r.stalls,
            accel_cycles: r.accel_cycles,
            wall_ns: r.wall.as_nanos().max(1) as u64,
            avg_latency: r.metrics.avg_latency,
            fairness: r.metrics.fairness,
            load_cv: r.metrics.load_balance_cv,
            throughput: r.metrics.throughput,
            jobs_per_machine: r.metrics.jobs_per_machine.clone(),
            latency_p50: r.latency_hist.p50(),
            latency_p95: r.latency_hist.p95(),
            latency_p99: r.latency_hist.p99(),
            merge_depth_p50: r.merge_depth.p50(),
            merge_depth_p99: r.merge_depth.p99(),
            merge_depth_max: r.merge_depth.max(),
            batch_p50: r.batch_sizes.p50(),
            batch_p99: r.batch_sizes.p99(),
            batch_max: r.batch_sizes.max(),
            sources: r
                .sources
                .iter()
                .map(|src| SourceRecord {
                    name: src.name.clone(),
                    jobs: src.jobs,
                    enqueue_stalls: src.enqueue_stalls,
                })
                .collect(),
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("schema", s(SERVE_RECORD_SCHEMA)),
            ("label", s(self.label.clone())),
            ("engine", s(self.engine.clone())),
            ("created_unix", s(self.created_unix.to_string())),
            ("completed", num(self.completed as f64)),
            ("ticks", num(self.ticks as f64)),
            ("stalls", num(self.stalls as f64)),
            ("accel_cycles", num(self.accel_cycles as f64)),
            // u64-exact fields go through strings: jsonio numbers are f64
            ("wall_ns", s(self.wall_ns.to_string())),
            ("avg_latency", num(self.avg_latency)),
            ("fairness", num(self.fairness)),
            ("load_cv", num(self.load_cv)),
            ("throughput", num(self.throughput)),
            (
                "jobs_per_machine",
                arr(self
                    .jobs_per_machine
                    .iter()
                    .map(|&c| num(c as f64))
                    .collect()),
            ),
            ("latency_p50", num(self.latency_p50 as f64)),
            ("latency_p95", num(self.latency_p95 as f64)),
            ("latency_p99", num(self.latency_p99 as f64)),
            ("merge_depth_p50", num(self.merge_depth_p50 as f64)),
            ("merge_depth_p99", num(self.merge_depth_p99 as f64)),
            ("merge_depth_max", num(self.merge_depth_max as f64)),
            ("batch_p50", num(self.batch_p50 as f64)),
            ("batch_p99", num(self.batch_p99 as f64)),
            ("batch_max", num(self.batch_max as f64)),
            (
                "sources",
                arr(self
                    .sources
                    .iter()
                    .map(|src| {
                        obj(vec![
                            ("name", s(src.name.clone())),
                            ("jobs", num(src.jobs as f64)),
                            ("enqueue_stalls", s(src.enqueue_stalls.to_string())),
                        ])
                    })
                    .collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ServeRecord, String> {
        let schema = get_str(j, "schema")?;
        if schema != SERVE_RECORD_SCHEMA {
            return Err(format!(
                "unsupported serve record schema '{schema}' (expected {SERVE_RECORD_SCHEMA})"
            ));
        }
        let sources = get_arr(j, "sources")?
            .iter()
            .map(|src| {
                Ok(SourceRecord {
                    name: get_str(src, "name")?,
                    jobs: get_uint(src, "jobs")? as usize,
                    enqueue_stalls: get_u64_str(src, "enqueue_stalls")?,
                })
            })
            .collect::<Result<Vec<SourceRecord>, String>>()?;
        Ok(ServeRecord {
            label: get_str(j, "label")?,
            engine: get_str(j, "engine")?,
            created_unix: get_u64_str(j, "created_unix")?,
            completed: get_uint(j, "completed")? as usize,
            ticks: get_uint(j, "ticks")?,
            stalls: get_uint(j, "stalls")?,
            accel_cycles: get_uint(j, "accel_cycles")?,
            wall_ns: get_u64_str(j, "wall_ns")?,
            avg_latency: crate::sweep::record::get_f64(j, "avg_latency")?,
            fairness: crate::sweep::record::get_f64(j, "fairness")?,
            load_cv: crate::sweep::record::get_f64(j, "load_cv")?,
            throughput: crate::sweep::record::get_f64(j, "throughput")?,
            jobs_per_machine: get_arr(j, "jobs_per_machine")?
                .iter()
                .map(|v| {
                    v.as_f64()
                        .ok_or_else(|| "non-numeric jobs_per_machine entry".to_string())
                        .and_then(|n| {
                            crate::sweep::record::uint_value(n, "jobs_per_machine entry")
                        })
                        .map(|n| n as usize)
                })
                .collect::<Result<Vec<usize>, String>>()?,
            latency_p50: get_uint(j, "latency_p50")?,
            latency_p95: get_uint(j, "latency_p95")?,
            latency_p99: get_uint(j, "latency_p99")?,
            merge_depth_p50: get_uint(j, "merge_depth_p50")?,
            merge_depth_p99: get_uint(j, "merge_depth_p99")?,
            merge_depth_max: get_uint(j, "merge_depth_max")?,
            batch_p50: get_uint(j, "batch_p50")?,
            batch_p99: get_uint(j, "batch_p99")?,
            batch_max: get_uint(j, "batch_max")?,
            sources,
        })
    }

    /// Parse an artifact from its serialized text.
    pub fn parse(text: &str) -> Result<ServeRecord, String> {
        ServeRecord::from_json(&Json::parse(text)?)
    }

    /// Serialize to the artifact text (compact JSON + trailing newline).
    pub fn render(&self) -> String {
        let mut text = self.to_json().render();
        text.push('\n');
        text
    }
}

#[cfg(test)]
mod tests {
    use super::super::server::{serve_sources, ArrivalSource, ServeOpts};
    use super::*;
    use crate::engine::EngineId;
    use crate::quant::Precision;
    use crate::workload::WorkloadSpec;

    fn small_record() -> ServeRecord {
        let sources =
            ArrivalSource::standard_mix(&WorkloadSpec::default(), 5, 90, 7, 2);
        let opts = ServeOpts {
            batch: 3,
            ..ServeOpts::default()
        };
        let report = serve_sources(
            EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap(),
            sources,
            &opts,
        )
        .unwrap();
        ServeRecord::from_report("test", &report)
    }

    #[test]
    fn record_round_trips_through_jsonio() {
        let rec = small_record();
        assert_eq!(rec.completed, 90);
        assert_eq!(rec.sources.len(), 2);
        let text = rec.render();
        let back = ServeRecord::parse(&text).expect("parse own artifact");
        assert_eq!(rec, back, "parse(render(r)) == r");
        assert_eq!(text, back.render(), "serialize -> parse -> serialize fixed point");
    }

    #[test]
    fn rejects_wrong_schema_and_garbage() {
        assert!(ServeRecord::parse("{}").is_err());
        assert!(ServeRecord::parse("not json").is_err());
        let rec = small_record();
        let text = rec
            .render()
            .replace(SERVE_RECORD_SCHEMA, "stannic.serve.record.v0");
        assert!(ServeRecord::parse(&text).is_err());
    }

    #[test]
    fn rejects_corrupt_integer_fields() {
        let rec = small_record();
        let ticks = format!("\"ticks\":{}", rec.ticks);
        let text = rec.render().replacen(&ticks, "\"ticks\":-4", 1);
        assert!(ServeRecord::parse(&text).is_err());
    }
}
