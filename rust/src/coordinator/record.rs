//! Persisted serve reports — the coordinator's arm of the repo's
//! benchmarking backbone, built on the [`crate::artifact`] layer.
//!
//! `serve --record out.json` turns one serving run into a durable,
//! machine-readable artifact the same way `sweep --record` does for the
//! grid: a [`ServeRecord`] serializes the run key (engine, batch,
//! sources), the deterministic outcome (schedule metrics, tick count,
//! merge/batch telemetry percentiles, and a FNV-1a **schedule-identity
//! digest**), and the timing-dependent backpressure observations
//! (per-source enqueue stalls, wall time) through [`crate::jsonio`]
//! under the [`crate::artifact::SERVE_RECORD`] schema.
//!
//! `serve diff old.json new.json` runs the same generic
//! [`crate::artifact::diff`] core as `sweep diff`: ticks, completions
//! and the schedule digest are parity-gated (any mismatch means the
//! deterministic serving semantics changed — never a perf delta), while
//! the latency percentiles and jobs-level throughput are perf-gated
//! with identical median-shift normalization and threshold handling.
//!
//! Sharded runs (`serve --shards K`, K > 1) add a shard block: one
//! [`ShardRecord`] per shard (machine range, routing/completion counts,
//! per-shard schedule digest, rebalance traffic) plus the global
//! rebalance counters and the load-imbalance CV. Like the fault block,
//! it is rendered, digested and diffed *only when present* — clean
//! unsharded (and `--shards 1`) artifacts stay byte-identical to
//! pre-shard recordings, and the extra parity cells guarantee a sharded
//! recording can never silently gate-pass against an unsharded
//! baseline.
//!
//! Portfolio runs (`serve --engine portfolio`) follow the same compat
//! discipline with a third gated block: decision-window count, switch
//! count, per-candidate win table, and the FNV-1a switch-log digest are
//! identity (parity-gated down to the exact switch *sequence*), while
//! the shadow-replay tick counter is a deterministic perf cell. Plain
//! engine recordings carry none of it and stay byte-identical.
//!
//! Link-constrained runs (`serve --link-width W`) add a fourth gated
//! block: the service-law parameters (width, latency, window), the
//! ticket conservation counters (issued always equals completed on a
//! finished run), the per-reason backpressure stall counts, the
//! occupancy/ticket-wait percentiles, and the exact integer transport
//! time ([`crate::coordinator::PcieStats::total_fs`]) as a
//! deterministic perf cell. Unconstrained recordings carry none of it
//! and stay byte-identical to pre-link artifacts.

use std::fmt::Write as _;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::artifact::{
    self, fnv1a64_hex, get_arr, get_f64, get_str, get_u64_str, get_uint, get_usize_arr, Artifact,
    Diffable, PerfCell, Schema,
};
use crate::err;
use crate::error::Result;
use crate::jsonio::{arr, num, obj, s, Json};

use super::server::ServeReport;

/// Schema tag embedded in every serve artifact (the rendered form of
/// [`artifact::SERVE_RECORD`]).
pub const SERVE_RECORD_SCHEMA: &str = "stannic.serve.record.v1";

/// Per-source slice of a persisted serve run.
#[derive(Debug, Clone, PartialEq)]
pub struct SourceRecord {
    pub name: String,
    pub jobs: usize,
    /// Enqueue stalls observed on this source's bounded arrival queue
    /// (timing-dependent, like wall time).
    pub enqueue_stalls: u64,
}

/// Per-shard slice of a persisted sharded serve run — the artifact form
/// of [`crate::coordinator::ShardSlice`]. Everything here is virtual
/// time, hence deterministic and parity-gated.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardRecord {
    /// First global machine index the shard owns.
    pub first_machine: usize,
    /// Machines in the shard.
    pub machines: usize,
    /// Arrivals (incl. storm jobs) the router sent here first.
    pub routed: u64,
    /// Jobs the shard released.
    pub completed: u64,
    /// FNV-1a digest of the shard's `(tick, job, global machine)`
    /// release stream.
    pub digest: String,
    /// Jobs rebalance barriers moved into / out of the shard.
    pub moved_in: u64,
    pub moved_out: u64,
}

/// One persisted serving run.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeRecord {
    pub label: String,
    pub engine: String,
    /// Unix seconds at record time (0 when the clock is unavailable).
    pub created_unix: u64,
    pub completed: usize,
    pub ticks: u64,
    /// Engine-side stalled iterations (every V_i full).
    pub stalls: u64,
    pub accel_cycles: u64,
    pub wall_ns: u64,
    /// FNV-1a digest of the schedule identity (engine, completions,
    /// stalls, per-machine assignment counts, per-source job counts);
    /// equal runs with different digests mean serving semantics changed.
    pub digest: String,
    pub avg_latency: f64,
    pub fairness: f64,
    pub load_cv: f64,
    pub throughput: f64,
    pub jobs_per_machine: Vec<usize>,
    pub latency_p50: u64,
    pub latency_p95: u64,
    pub latency_p99: u64,
    /// Merge-queue depth percentiles (per-tick samples).
    pub merge_depth_p50: u64,
    pub merge_depth_p99: u64,
    pub merge_depth_max: u64,
    /// Admission batch-size percentiles (ticks admitting >= 1 job).
    pub batch_p50: u64,
    pub batch_p99: u64,
    pub batch_max: u64,
    pub sources: Vec<SourceRecord>,
    /// Canonical fault key ([`crate::faults::FaultSpec::render`]); empty
    /// for clean runs. Folded into the digest only when non-empty, so a
    /// faulted recording can never parity-pair with a clean one while
    /// clean artifacts stay byte-identical to pre-fault recordings.
    pub fault: String,
    /// Recovery metrics of the faulted run (all zero, and unrendered,
    /// when clean): evictions, storm injections, dropped arrivals, work
    /// lost, utilization-dip duration/area/depth, re-queue percentiles.
    pub fault_evicted: u64,
    pub fault_injected: u64,
    pub fault_dropped: u64,
    pub fault_work_lost: u64,
    pub fault_degraded_ticks: u64,
    pub fault_down_machine_ticks: u64,
    pub fault_max_down: u64,
    pub fault_requeue_p50: u64,
    pub fault_requeue_p99: u64,
    /// Shard block ([`crate::coordinator::ShardTelemetry`]); empty for
    /// unsharded and `--shards 1` runs, which keeps their artifacts
    /// byte-identical to pre-shard recordings. Folded into the digest
    /// and the parity cells only when non-empty, so sharded and
    /// unsharded recordings can never silently pair.
    pub shards: Vec<ShardRecord>,
    /// Jobs that changed shard at a rebalance barrier.
    pub rebalance_moves: u64,
    /// Barriers that drained at least one queued job for re-routing.
    pub rebalance_events: u64,
    /// Coefficient of variation of per-shard completion counts
    /// (0 = perfectly balanced). Deterministic, parity-gated with fixed
    /// 4-decimal rendering.
    pub shard_imbalance_cv: f64,
    /// Portfolio block ([`crate::engine::portfolio::PortfolioTelemetry`]);
    /// `window_ticks` doubles as the presence marker — 0 for every plain
    /// engine run, which keeps plain artifacts byte-identical to
    /// pre-portfolio recordings. Folded into the digest and the parity
    /// cells only when present, so a portfolio recording can never
    /// silently pair with a plain one.
    pub portfolio_window_ticks: u64,
    /// Decision windows evaluated (windows with at least one arrival).
    pub portfolio_windows: u64,
    /// Live-policy switches performed.
    pub portfolio_switches: u64,
    /// Policy live at the end of the run.
    pub portfolio_live: String,
    /// Per-candidate window wins, in registry order.
    pub portfolio_wins: Vec<(String, u64)>,
    /// FNV-1a digest of the canonical switch log
    /// ([`crate::engine::portfolio::PortfolioTelemetry::switch_digest`]).
    pub portfolio_switch_digest: String,
    /// Virtual ticks simulated across all shadow replays — deterministic
    /// engine work, perf-gated (never wall clock).
    pub portfolio_replay_ticks: u64,
    /// Jobs fed to shadow candidates across all replays.
    pub portfolio_replay_submissions: u64,
    /// Link block ([`crate::coordinator::LinkTelemetry`]); the width
    /// doubles as the presence marker — 0 for every unconstrained run,
    /// which keeps default artifacts byte-identical to pre-link
    /// recordings. Folded into the digest and the parity cells only
    /// when present, so a narrow-link recording can never silently
    /// pair with an unconstrained baseline.
    pub link_width: u64,
    /// Fixed per-transfer latency of the service law (ticks).
    pub link_latency: u64,
    /// Bounded in-flight ticket window.
    pub link_window: u64,
    /// Tickets issued; equals `link_completed` on a finished run.
    pub link_issued: u64,
    pub link_completed: u64,
    /// Admission ticks refused because the wire was busy.
    pub link_stall_busy: u64,
    /// Admission ticks refused because the ticket window was full.
    pub link_stall_window: u64,
    /// Issued transfers that had to queue behind the serial wire.
    pub link_stall_response: u64,
    /// In-flight ticket occupancy percentiles (per-tick samples).
    pub link_occupancy_p50: u64,
    pub link_occupancy_max: u64,
    /// Ticket wait (issue -> completion tick) percentiles.
    pub link_wait_p50: u64,
    pub link_wait_p95: u64,
    /// Exact integer transport time (femtoseconds) — deterministic, so
    /// it gates as a perf cell; rendered only on constrained records
    /// to keep unconstrained artifacts byte-stable.
    pub pcie_fs: u64,
}

impl ServeRecord {
    pub fn from_report(label: &str, r: &ServeReport) -> ServeRecord {
        let mut rec = ServeRecord {
            label: label.to_string(),
            engine: r.engine.to_string(),
            created_unix: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
            completed: r.completions.len(),
            ticks: r.ticks,
            stalls: r.stalls,
            accel_cycles: r.accel_cycles,
            wall_ns: r.wall.as_nanos().max(1) as u64,
            digest: String::new(),
            avg_latency: r.metrics.avg_latency,
            fairness: r.metrics.fairness,
            load_cv: r.metrics.load_balance_cv,
            throughput: r.metrics.throughput,
            jobs_per_machine: r.metrics.jobs_per_machine.clone(),
            latency_p50: r.latency_hist.p50(),
            latency_p95: r.latency_hist.p95(),
            latency_p99: r.latency_hist.p99(),
            merge_depth_p50: r.merge_depth.p50(),
            merge_depth_p99: r.merge_depth.p99(),
            merge_depth_max: r.merge_depth.max(),
            batch_p50: r.batch_sizes.p50(),
            batch_p99: r.batch_sizes.p99(),
            batch_max: r.batch_sizes.max(),
            sources: r
                .sources
                .iter()
                .map(|src| SourceRecord {
                    name: src.name.clone(),
                    jobs: src.jobs,
                    enqueue_stalls: src.enqueue_stalls,
                })
                .collect(),
            fault: r.fault_key.clone(),
            fault_evicted: r.faults.as_ref().map_or(0, |f| f.evicted_jobs),
            fault_injected: r.faults.as_ref().map_or(0, |f| f.injected_jobs),
            fault_dropped: r.faults.as_ref().map_or(0, |f| f.dropped_arrivals),
            fault_work_lost: r.faults.as_ref().map_or(0, |f| f.work_lost_cycles),
            fault_degraded_ticks: r.faults.as_ref().map_or(0, |f| f.degraded_ticks),
            fault_down_machine_ticks: r
                .faults
                .as_ref()
                .map_or(0, |f| f.down_machine_ticks),
            fault_max_down: r.faults.as_ref().map_or(0, |f| f.max_concurrent_down as u64),
            fault_requeue_p50: r.faults.as_ref().map_or(0, |f| f.requeue_latency.p50()),
            fault_requeue_p99: r.faults.as_ref().map_or(0, |f| f.requeue_latency.p99()),
            // the report carries telemetry only for K > 1 (the server
            // filters K = 1 down to None, preserving bit-identity)
            shards: r.shards.as_ref().map_or_else(Vec::new, |t| {
                t.per_shard
                    .iter()
                    .map(|sh| ShardRecord {
                        first_machine: sh.first_machine,
                        machines: sh.machines,
                        routed: sh.routed,
                        completed: sh.completed,
                        digest: sh.digest.clone(),
                        moved_in: sh.moved_in,
                        moved_out: sh.moved_out,
                    })
                    .collect()
            }),
            rebalance_moves: r.shards.as_ref().map_or(0, |t| t.rebalance_moves),
            rebalance_events: r.shards.as_ref().map_or(0, |t| t.rebalance_events),
            shard_imbalance_cv: r.shards.as_ref().map_or(0.0, |t| t.imbalance_cv),
            // only the portfolio meta-engine reports telemetry; plain
            // engines leave the whole block zero/empty (unrendered)
            portfolio_window_ticks: r.portfolio.as_ref().map_or(0, |p| p.window_ticks),
            portfolio_windows: r.portfolio.as_ref().map_or(0, |p| p.windows),
            portfolio_switches: r.portfolio.as_ref().map_or(0, |p| p.switches),
            portfolio_live: r.portfolio.as_ref().map_or_else(String::new, |p| p.live.to_string()),
            portfolio_wins: r.portfolio.as_ref().map_or_else(Vec::new, |p| {
                p.wins.iter().map(|&(name, w)| (name.to_string(), w)).collect()
            }),
            portfolio_switch_digest: r
                .portfolio
                .as_ref()
                .map_or_else(String::new, |p| p.switch_digest()),
            portfolio_replay_ticks: r.portfolio.as_ref().map_or(0, |p| p.replay_ticks),
            portfolio_replay_submissions: r.portfolio.as_ref().map_or(0, |p| p.replay_submissions),
            // only link-constrained runs report telemetry; unbounded
            // runs leave the whole block zero (unrendered)
            link_width: r.link.as_ref().map_or(0, |l| l.width),
            link_latency: r.link.as_ref().map_or(0, |l| l.latency),
            link_window: r.link.as_ref().map_or(0, |l| l.window),
            link_issued: r.link.as_ref().map_or(0, |l| l.issued),
            link_completed: r.link.as_ref().map_or(0, |l| l.completed),
            link_stall_busy: r.link.as_ref().map_or(0, |l| l.stall_busy),
            link_stall_window: r.link.as_ref().map_or(0, |l| l.stall_window),
            link_stall_response: r.link.as_ref().map_or(0, |l| l.stall_response),
            link_occupancy_p50: r.link.as_ref().map_or(0, |l| l.occupancy.p50()),
            link_occupancy_max: r.link.as_ref().map_or(0, |l| l.occupancy.max()),
            link_wait_p50: r.link.as_ref().map_or(0, |l| l.wait.p50()),
            link_wait_p95: r.link.as_ref().map_or(0, |l| l.wait.p95()),
            pcie_fs: if r.link.is_some() { r.pcie.total_fs } else { 0 },
        };
        rec.digest = rec.compute_digest();
        rec
    }

    /// Digest of the schedule identity: who scheduled what, where. The
    /// latency trajectory is deliberately excluded — percentiles are
    /// perf-gated by `serve diff`, and folding them into the identity
    /// would turn every latency shift into a parity break.
    pub fn compute_digest(&self) -> String {
        let mut canon = String::new();
        let _ = write!(
            canon,
            "{}|{}|{}|{:?}",
            self.engine, self.completed, self.stalls, self.jobs_per_machine
        );
        for src in &self.sources {
            let _ = write!(canon, "|{}={}", src.name, src.jobs);
        }
        // the fault scenario and its deterministic recovery outcome are
        // identity — only when faulted, so clean digests are unchanged
        if !self.fault.is_empty() {
            let _ = write!(
                canon,
                "|f:{}|{}|{}|{}|{}|{}|{}|{}",
                self.fault,
                self.fault_evicted,
                self.fault_injected,
                self.fault_dropped,
                self.fault_work_lost,
                self.fault_degraded_ticks,
                self.fault_down_machine_ticks,
                self.fault_max_down
            );
        }
        // the shard map and every shard's deterministic outcome are
        // identity — only when sharded, so unsharded digests are
        // unchanged (and sharded can never collide with unsharded)
        for sh in &self.shards {
            let _ = write!(
                canon,
                "|s:{}+{}:{}:{}/{}:{}/{}",
                sh.first_machine,
                sh.machines,
                sh.digest,
                sh.completed,
                sh.routed,
                sh.moved_in,
                sh.moved_out
            );
        }
        if !self.shards.is_empty() {
            let _ = write!(
                canon,
                "|rb:{}/{}",
                self.rebalance_moves, self.rebalance_events
            );
        }
        // the portfolio decision trail (window/switch counts, final live
        // policy, switch-sequence digest, win table) is identity — only
        // for portfolio runs, so plain-engine digests are unchanged (and
        // a portfolio record can never collide with a plain one)
        if self.portfolio_window_ticks > 0 {
            let _ = write!(
                canon,
                "|p:{}:{}:{}:{}:{}",
                self.portfolio_window_ticks,
                self.portfolio_windows,
                self.portfolio_switches,
                self.portfolio_live,
                self.portfolio_switch_digest
            );
            for (name, wins) in &self.portfolio_wins {
                let _ = write!(canon, "|pw:{name}={wins}");
            }
        }
        // the link service law and its deterministic ticket/stall
        // outcome are identity — only when constrained, so unbounded
        // digests are unchanged (and a narrow-link record can never
        // collide with an unconstrained one). The exact transport time
        // is deliberately excluded: `pcie_fs` is perf-gated.
        if self.link_width > 0 {
            let _ = write!(
                canon,
                "|l:{}/{}/{}:{}/{}:{}/{}/{}",
                self.link_width,
                self.link_latency,
                self.link_window,
                self.link_issued,
                self.link_completed,
                self.link_stall_busy,
                self.link_stall_window,
                self.link_stall_response
            );
        }
        fnv1a64_hex(canon.as_bytes())
    }

    /// Serving throughput: completed jobs per wall-clock second.
    pub fn jobs_per_sec(&self) -> f64 {
        artifact::jobs_per_sec(self.completed, self.wall_ns)
    }
}

/// [`get_uint`] for a field that may be absent (defaults to 0): the
/// fault and shard blocks only exist on faulted/sharded recordings.
fn opt_uint(j: &Json, key: &str) -> Result<u64> {
    if j.get(key).is_some() {
        get_uint(j, key)
    } else {
        Ok(0)
    }
}

/// [`get_f64`] for a field that may be absent (defaults to 0.0).
fn opt_f64(j: &Json, key: &str) -> Result<f64> {
    if j.get(key).is_some() {
        get_f64(j, key)
    } else {
        Ok(0.0)
    }
}

impl Artifact for ServeRecord {
    const SCHEMA: Schema = artifact::SERVE_RECORD;

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("schema", s(Self::SCHEMA.tag())),
            ("label", s(self.label.clone())),
            ("engine", s(self.engine.clone())),
            ("created_unix", s(self.created_unix.to_string())),
            ("completed", num(self.completed as f64)),
            ("ticks", num(self.ticks as f64)),
            ("stalls", num(self.stalls as f64)),
            ("accel_cycles", num(self.accel_cycles as f64)),
            // u64-exact fields go through strings: jsonio numbers are f64
            ("wall_ns", s(self.wall_ns.to_string())),
            ("digest", s(self.digest.clone())),
            ("avg_latency", num(self.avg_latency)),
            ("fairness", num(self.fairness)),
            ("load_cv", num(self.load_cv)),
            ("throughput", num(self.throughput)),
            (
                "jobs_per_machine",
                arr(self
                    .jobs_per_machine
                    .iter()
                    .map(|&c| num(c as f64))
                    .collect()),
            ),
            ("latency_p50", num(self.latency_p50 as f64)),
            ("latency_p95", num(self.latency_p95 as f64)),
            ("latency_p99", num(self.latency_p99 as f64)),
            ("merge_depth_p50", num(self.merge_depth_p50 as f64)),
            ("merge_depth_p99", num(self.merge_depth_p99 as f64)),
            ("merge_depth_max", num(self.merge_depth_max as f64)),
            ("batch_p50", num(self.batch_p50 as f64)),
            ("batch_p99", num(self.batch_p99 as f64)),
            ("batch_max", num(self.batch_max as f64)),
            (
                "sources",
                arr(self
                    .sources
                    .iter()
                    .map(|src| {
                        obj(vec![
                            ("name", s(src.name.clone())),
                            ("jobs", num(src.jobs as f64)),
                            ("enqueue_stalls", s(src.enqueue_stalls.to_string())),
                        ])
                    })
                    .collect()),
            ),
        ];
        // only faulted runs carry the fault block: clean artifacts render
        // byte-identically to pre-fault versions of this schema
        if !self.fault.is_empty() {
            fields.push(("fault", s(self.fault.clone())));
            fields.push(("fault_evicted", num(self.fault_evicted as f64)));
            fields.push(("fault_injected", num(self.fault_injected as f64)));
            fields.push(("fault_dropped", num(self.fault_dropped as f64)));
            fields.push(("fault_work_lost", num(self.fault_work_lost as f64)));
            fields.push(("fault_degraded_ticks", num(self.fault_degraded_ticks as f64)));
            fields.push((
                "fault_down_machine_ticks",
                num(self.fault_down_machine_ticks as f64),
            ));
            fields.push(("fault_max_down", num(self.fault_max_down as f64)));
            fields.push(("fault_requeue_p50", num(self.fault_requeue_p50 as f64)));
            fields.push(("fault_requeue_p99", num(self.fault_requeue_p99 as f64)));
        }
        // only sharded runs carry the shard block (same compat pattern
        // as the fault block above)
        if !self.shards.is_empty() {
            fields.push((
                "shards",
                arr(self
                    .shards
                    .iter()
                    .map(|sh| {
                        obj(vec![
                            ("first_machine", num(sh.first_machine as f64)),
                            ("machines", num(sh.machines as f64)),
                            ("routed", num(sh.routed as f64)),
                            ("completed", num(sh.completed as f64)),
                            ("digest", s(sh.digest.clone())),
                            ("moved_in", num(sh.moved_in as f64)),
                            ("moved_out", num(sh.moved_out as f64)),
                        ])
                    })
                    .collect()),
            ));
            fields.push(("rebalance_moves", num(self.rebalance_moves as f64)));
            fields.push(("rebalance_events", num(self.rebalance_events as f64)));
            fields.push(("shard_imbalance_cv", num(self.shard_imbalance_cv)));
        }
        // only portfolio runs carry the portfolio block (same compat
        // pattern as the fault and shard blocks above)
        if self.portfolio_window_ticks > 0 {
            fields.push(("portfolio_window_ticks", num(self.portfolio_window_ticks as f64)));
            fields.push(("portfolio_windows", num(self.portfolio_windows as f64)));
            fields.push(("portfolio_switches", num(self.portfolio_switches as f64)));
            fields.push(("portfolio_live", s(self.portfolio_live.clone())));
            fields.push((
                "portfolio_wins",
                arr(self
                    .portfolio_wins
                    .iter()
                    .map(|(name, wins)| {
                        obj(vec![("name", s(name.clone())), ("wins", num(*wins as f64))])
                    })
                    .collect()),
            ));
            fields.push(("portfolio_switch_digest", s(self.portfolio_switch_digest.clone())));
            fields.push(("portfolio_replay_ticks", num(self.portfolio_replay_ticks as f64)));
            fields.push((
                "portfolio_replay_submissions",
                num(self.portfolio_replay_submissions as f64),
            ));
        }
        // only link-constrained runs carry the link block (same compat
        // pattern as the fault, shard and portfolio blocks above)
        if self.link_width > 0 {
            fields.push(("link_width", num(self.link_width as f64)));
            fields.push(("link_latency", num(self.link_latency as f64)));
            fields.push(("link_window", num(self.link_window as f64)));
            fields.push(("link_issued", num(self.link_issued as f64)));
            fields.push(("link_completed", num(self.link_completed as f64)));
            fields.push(("link_stall_busy", num(self.link_stall_busy as f64)));
            fields.push(("link_stall_window", num(self.link_stall_window as f64)));
            fields.push(("link_stall_response", num(self.link_stall_response as f64)));
            fields.push(("link_occupancy_p50", num(self.link_occupancy_p50 as f64)));
            fields.push(("link_occupancy_max", num(self.link_occupancy_max as f64)));
            fields.push(("link_wait_p50", num(self.link_wait_p50 as f64)));
            fields.push(("link_wait_p95", num(self.link_wait_p95 as f64)));
            // femtosecond counts overflow f64 exactness; go via string
            fields.push(("pcie_fs", s(self.pcie_fs.to_string())));
        }
        obj(fields)
    }

    fn from_json(j: &Json) -> Result<ServeRecord> {
        Self::SCHEMA.check(j)?;
        let sources = get_arr(j, "sources")?
            .iter()
            .map(|src| {
                Ok(SourceRecord {
                    name: get_str(src, "name")?,
                    jobs: get_uint(src, "jobs")? as usize,
                    enqueue_stalls: get_u64_str(src, "enqueue_stalls")?,
                })
            })
            .collect::<Result<Vec<SourceRecord>>>()?;
        let mut rec = ServeRecord {
            label: get_str(j, "label")?,
            engine: get_str(j, "engine")?,
            created_unix: get_u64_str(j, "created_unix")?,
            completed: get_uint(j, "completed")? as usize,
            ticks: get_uint(j, "ticks")?,
            stalls: get_uint(j, "stalls")?,
            accel_cycles: get_uint(j, "accel_cycles")?,
            wall_ns: get_u64_str(j, "wall_ns")?,
            digest: String::new(),
            avg_latency: get_f64(j, "avg_latency")?,
            fairness: get_f64(j, "fairness")?,
            load_cv: get_f64(j, "load_cv")?,
            throughput: get_f64(j, "throughput")?,
            jobs_per_machine: get_usize_arr(j, "jobs_per_machine")?,
            latency_p50: get_uint(j, "latency_p50")?,
            latency_p95: get_uint(j, "latency_p95")?,
            latency_p99: get_uint(j, "latency_p99")?,
            merge_depth_p50: get_uint(j, "merge_depth_p50")?,
            merge_depth_p99: get_uint(j, "merge_depth_p99")?,
            merge_depth_max: get_uint(j, "merge_depth_max")?,
            batch_p50: get_uint(j, "batch_p50")?,
            batch_p99: get_uint(j, "batch_p99")?,
            batch_max: get_uint(j, "batch_max")?,
            sources,
            // absent on clean (and pre-fault) artifacts; a present field
            // is still strictly validated
            fault: if j.get("fault").is_some() {
                get_str(j, "fault")?
            } else {
                String::new()
            },
            fault_evicted: opt_uint(j, "fault_evicted")?,
            fault_injected: opt_uint(j, "fault_injected")?,
            fault_dropped: opt_uint(j, "fault_dropped")?,
            fault_work_lost: opt_uint(j, "fault_work_lost")?,
            fault_degraded_ticks: opt_uint(j, "fault_degraded_ticks")?,
            fault_down_machine_ticks: opt_uint(j, "fault_down_machine_ticks")?,
            fault_max_down: opt_uint(j, "fault_max_down")?,
            fault_requeue_p50: opt_uint(j, "fault_requeue_p50")?,
            fault_requeue_p99: opt_uint(j, "fault_requeue_p99")?,
            // absent on unsharded artifacts; present fields are still
            // strictly validated
            shards: if j.get("shards").is_some() {
                get_arr(j, "shards")?
                    .iter()
                    .map(|sh| {
                        Ok(ShardRecord {
                            first_machine: get_uint(sh, "first_machine")? as usize,
                            machines: get_uint(sh, "machines")? as usize,
                            routed: get_uint(sh, "routed")?,
                            completed: get_uint(sh, "completed")?,
                            digest: get_str(sh, "digest")?,
                            moved_in: get_uint(sh, "moved_in")?,
                            moved_out: get_uint(sh, "moved_out")?,
                        })
                    })
                    .collect::<Result<Vec<ShardRecord>>>()?
            } else {
                Vec::new()
            },
            rebalance_moves: opt_uint(j, "rebalance_moves")?,
            rebalance_events: opt_uint(j, "rebalance_events")?,
            shard_imbalance_cv: opt_f64(j, "shard_imbalance_cv")?,
            // absent on plain-engine artifacts; present fields are still
            // strictly validated
            portfolio_window_ticks: opt_uint(j, "portfolio_window_ticks")?,
            portfolio_windows: opt_uint(j, "portfolio_windows")?,
            portfolio_switches: opt_uint(j, "portfolio_switches")?,
            portfolio_live: if j.get("portfolio_live").is_some() {
                get_str(j, "portfolio_live")?
            } else {
                String::new()
            },
            portfolio_wins: if j.get("portfolio_wins").is_some() {
                get_arr(j, "portfolio_wins")?
                    .iter()
                    .map(|w| Ok((get_str(w, "name")?, get_uint(w, "wins")?)))
                    .collect::<Result<Vec<(String, u64)>>>()?
            } else {
                Vec::new()
            },
            portfolio_switch_digest: if j.get("portfolio_switch_digest").is_some() {
                get_str(j, "portfolio_switch_digest")?
            } else {
                String::new()
            },
            portfolio_replay_ticks: opt_uint(j, "portfolio_replay_ticks")?,
            portfolio_replay_submissions: opt_uint(j, "portfolio_replay_submissions")?,
            // absent on unconstrained artifacts; present fields are
            // still strictly validated
            link_width: opt_uint(j, "link_width")?,
            link_latency: opt_uint(j, "link_latency")?,
            link_window: opt_uint(j, "link_window")?,
            link_issued: opt_uint(j, "link_issued")?,
            link_completed: opt_uint(j, "link_completed")?,
            link_stall_busy: opt_uint(j, "link_stall_busy")?,
            link_stall_window: opt_uint(j, "link_stall_window")?,
            link_stall_response: opt_uint(j, "link_stall_response")?,
            link_occupancy_p50: opt_uint(j, "link_occupancy_p50")?,
            link_occupancy_max: opt_uint(j, "link_occupancy_max")?,
            link_wait_p50: opt_uint(j, "link_wait_p50")?,
            link_wait_p95: opt_uint(j, "link_wait_p95")?,
            pcie_fs: if j.get("pcie_fs").is_some() {
                get_u64_str(j, "pcie_fs")?
            } else {
                0
            },
        };
        // Pre-digest v1 artifacts (recorded before the artifact-layer
        // redesign) lack the field; recompute so they stay loadable and
        // diffable against fresh recordings. A *present* digest must
        // match the recomputation (every identity input is an integer
        // or string, so the recompute is exact): a stale digest on a
        // hand-edited artifact would otherwise silently defeat the
        // parity gate that trusts it.
        rec.digest = rec.compute_digest();
        if j.get("digest").is_some() {
            let stored = get_str(j, "digest")?;
            if stored != rec.digest {
                return Err(err!(
                    "digest '{stored}' does not match the artifact's identity \
                     fields (expected '{}') — artifact was hand-edited",
                    rec.digest
                ));
            }
        }
        Ok(rec)
    }
}

impl Diffable for ServeRecord {
    const KIND: &'static str = "serve";
    const UNIT: &'static str = "value";

    fn label(&self) -> &str {
        &self.label
    }

    /// Parity cells (schedule digest, tick count, completions) plus perf
    /// cells. The latency percentiles (lower is better; floored at one
    /// tick so an instant-completion run stays measurable) and jobs/tick
    /// are virtual-time measurements — host-independent, so they gate
    /// *raw* at the full threshold. Wall-clock jobs/sec is the record's
    /// single noisy cell: with nothing to take a median against it
    /// cannot distinguish host speed from regression, so it is advisory
    /// (it feeds the reported shift, which `--fail-on-shift` gates for
    /// same-host A/B runs).
    fn cells(&self) -> Vec<PerfCell> {
        let mut cells = vec![
            PerfCell::parity("schedule-digest", self.digest.clone()),
            PerfCell::parity("ticks", self.ticks.to_string()),
            PerfCell::parity("completions", self.completed.to_string()),
            PerfCell::lower("latency_p50", self.latency_p50.max(1) as f64),
            PerfCell::lower("latency_p95", self.latency_p95.max(1) as f64),
            PerfCell::lower("latency_p99", self.latency_p99.max(1) as f64),
            PerfCell::higher("jobs_per_tick", self.throughput),
            PerfCell::higher("jobs_per_sec", self.jobs_per_sec())
                .noisy()
                .advisory(),
        ];
        // faulted runs add a parity cell keyed by the fault scenario:
        // its recovery outcome is deterministic, and the key itself
        // guarantees a faulted record never cleanly pairs with a clean
        // one (the unmatched cell fails the gate even before the digest
        // parity break does)
        if !self.fault.is_empty() {
            cells.push(PerfCell::parity(
                format!("fault[{}]", self.fault),
                format!(
                    "{}|{}|{}|{}|{}|{}|{}",
                    self.fault_evicted,
                    self.fault_injected,
                    self.fault_dropped,
                    self.fault_work_lost,
                    self.fault_degraded_ticks,
                    self.fault_down_machine_ticks,
                    self.fault_max_down
                ),
            ));
        }
        // sharded runs add one parity cell per shard plus the global
        // rebalance and imbalance cells — all deterministic virtual-time
        // facts, and unmatched against any unsharded (or differently
        // sharded) baseline, so the gate fails before a human has to
        // notice the shard counts differ
        for (i, sh) in self.shards.iter().enumerate() {
            cells.push(PerfCell::parity(
                format!("shard{i}[{}+{}]", sh.first_machine, sh.machines),
                format!(
                    "{}|{}|{}|{}|{}",
                    sh.digest, sh.completed, sh.routed, sh.moved_in, sh.moved_out
                ),
            ));
        }
        if !self.shards.is_empty() {
            cells.push(PerfCell::parity(
                "rebalance",
                format!("{}|{}", self.rebalance_moves, self.rebalance_events),
            ));
            cells.push(PerfCell::parity(
                "shard_imbalance_cv",
                format!("{:.4}", self.shard_imbalance_cv),
            ));
        }
        // portfolio runs add a parity cell pinning the decision trail —
        // window/switch counts, final live policy, switch-sequence
        // digest, per-candidate win table — plus a deterministic
        // replay-overhead perf cell. Both are unmatched against any
        // plain-engine baseline, so a portfolio record never silently
        // gate-passes against one
        if self.portfolio_window_ticks > 0 {
            let wins = self
                .portfolio_wins
                .iter()
                .map(|(name, w)| format!("{name}={w}"))
                .collect::<Vec<String>>()
                .join(",");
            cells.push(PerfCell::parity(
                format!("portfolio[w{}]", self.portfolio_window_ticks),
                format!(
                    "{}|{}|{}|{}|{}",
                    self.portfolio_windows,
                    self.portfolio_switches,
                    self.portfolio_live,
                    self.portfolio_switch_digest,
                    wins
                ),
            ));
            cells.push(PerfCell::lower(
                "portfolio_replay_ticks",
                self.portfolio_replay_ticks.max(1) as f64,
            ));
        }
        // link-constrained runs add a parity cell keyed by the service
        // law, pinning the deterministic ticket and per-reason stall
        // outcome, plus the exact integer transport-time perf cell
        // (order-independent, hence deterministic — unlike wall time).
        // Both are unmatched against any unconstrained baseline, so a
        // narrow-link record never silently gate-passes against one
        if self.link_width > 0 {
            cells.push(PerfCell::parity(
                format!(
                    "link[w{}l{}q{}]",
                    self.link_width, self.link_latency, self.link_window
                ),
                format!(
                    "{}|{}|{}|{}|{}",
                    self.link_issued,
                    self.link_completed,
                    self.link_stall_busy,
                    self.link_stall_window,
                    self.link_stall_response
                ),
            ));
            cells.push(PerfCell::lower("pcie_fs", self.pcie_fs.max(1) as f64));
        }
        cells
    }
}

#[cfg(test)]
mod tests {
    use super::super::server::{serve_sources, ArrivalSource, ServeOpts};
    use super::*;
    use crate::artifact::{diff_records, CellVerdict, DiffOpts};
    use crate::engine::EngineId;
    use crate::quant::Precision;
    use crate::workload::WorkloadSpec;

    fn small_record() -> ServeRecord {
        let sources =
            ArrivalSource::standard_mix(&WorkloadSpec::default(), 5, 90, 7, 2);
        let opts = ServeOpts::new().with_batch(3);
        let report = serve_sources(
            EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap(),
            sources,
            &opts,
        )
        .unwrap();
        ServeRecord::from_report("test", &report)
    }

    fn faulted_record() -> ServeRecord {
        let opts = ServeOpts::new().with_batch(3).with_faults(
            crate::faults::FaultSpec::parse("down=0@15+10,storm=3@20,seed=2").unwrap(),
        );
        let report = serve_sources(
            EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap(),
            ArrivalSource::standard_mix(&WorkloadSpec::default(), 5, 90, 7, 2),
            &opts,
        )
        .unwrap();
        ServeRecord::from_report("test", &report)
    }

    fn sharded_record(shards: usize) -> ServeRecord {
        let opts = ServeOpts::new().with_batch(3).with_shards(shards);
        let report = serve_sources(
            EngineId::Sos
                .build_sharded(shards, 6, 10, 0.5, Precision::Int8)
                .unwrap(),
            ArrivalSource::standard_mix(&WorkloadSpec::default(), 6, 90, 7, 2),
            &opts,
        )
        .unwrap();
        ServeRecord::from_report("test", &report)
    }

    fn portfolio_record() -> ServeRecord {
        let report = serve_sources(
            EngineId::Portfolio.build(5, 10, 0.5, Precision::Int8).unwrap(),
            ArrivalSource::standard_mix(&WorkloadSpec::default(), 5, 150, 42, 3),
            &ServeOpts::new().with_batch(3),
        )
        .unwrap();
        ServeRecord::from_report("test", &report)
    }

    fn link_record() -> ServeRecord {
        let opts = ServeOpts::new()
            .with_batch(3)
            .with_link(super::super::link::LinkModel::with_width(4));
        let report = serve_sources(
            EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap(),
            ArrivalSource::standard_mix(&WorkloadSpec::default(), 5, 90, 7, 2),
            &opts,
        )
        .unwrap();
        ServeRecord::from_report("test", &report)
    }

    #[test]
    fn link_record_round_trips_and_self_diffs_clean() {
        let rec = link_record();
        assert_eq!(rec.link_width, 4, "the width doubles as the presence marker");
        assert!(rec.link_issued > 0, "a served run issued tickets");
        assert_eq!(
            rec.link_issued, rec.link_completed,
            "ticket conservation: every issued ticket retired"
        );
        assert!(rec.pcie_fs > 0, "transport time is billed on the link path");
        let back = ServeRecord::parse(&rec.render()).expect("link artifact parses");
        assert_eq!(rec, back);
        let report = diff_records(&rec, &rec, &DiffOpts::default());
        assert!(report.ok(), "{}", report.render());
        assert_eq!(report.parity_breaks(), 0);
        assert_eq!(
            report.cells.len(),
            10,
            "8 standard + link parity + pcie_fs perf cells"
        );
    }

    #[test]
    fn link_and_unconstrained_records_never_pair_silently() {
        let clean = small_record();
        assert!(
            !clean.render().contains("link"),
            "unconstrained artifact carries no link block"
        );
        assert!(
            !clean.render().contains("pcie_fs"),
            "unconstrained artifact carries no transport-time cell"
        );
        let link = link_record();
        assert_ne!(clean.digest, link.digest, "the service law is identity");
        let report = diff_records(&clean, &link, &DiffOpts::default());
        assert!(
            !report.ok(),
            "a narrow-link run must never gate-pass against an unconstrained baseline"
        );
        let reverse = diff_records(&link, &clean, &DiffOpts::default());
        assert!(!reverse.ok(), "nor the other way around");
    }

    #[test]
    fn portfolio_record_round_trips_and_self_diffs_clean() {
        let rec = portfolio_record();
        assert_eq!(
            rec.portfolio_window_ticks,
            crate::engine::portfolio::WINDOW_TICKS,
            "window length doubles as the presence marker"
        );
        assert!(rec.portfolio_windows >= 1, "rotating mix evaluates windows");
        assert_eq!(rec.portfolio_wins.len(), 5, "one win row per candidate");
        assert_eq!(
            rec.portfolio_wins.iter().map(|&(_, w)| w).sum::<u64>(),
            rec.portfolio_windows,
            "every evaluated window has exactly one winner"
        );
        assert!(!rec.portfolio_switch_digest.is_empty());
        assert!(rec.portfolio_replay_ticks > 0, "replay work is measured");
        let back = ServeRecord::parse(&rec.render()).expect("portfolio artifact parses");
        assert_eq!(rec, back);
        let report = diff_records(&rec, &rec, &DiffOpts::default());
        assert!(report.ok(), "{}", report.render());
        assert_eq!(report.parity_breaks(), 0);
        assert_eq!(
            report.cells.len(),
            10,
            "8 standard + portfolio parity + replay perf cells"
        );
    }

    #[test]
    fn portfolio_and_plain_records_never_pair_silently() {
        let clean = small_record();
        assert!(
            !clean.render().contains("portfolio"),
            "plain artifact carries no portfolio block"
        );
        let portfolio = portfolio_record();
        assert_ne!(clean.digest, portfolio.digest, "the decision trail is identity");
        let report = diff_records(&clean, &portfolio, &DiffOpts::default());
        assert!(
            !report.ok(),
            "a portfolio run must never gate-pass against a plain baseline"
        );
    }

    #[test]
    fn record_schema_is_the_registry_instance() {
        assert_eq!(SERVE_RECORD_SCHEMA, artifact::SERVE_RECORD.tag());
        assert_eq!(SERVE_RECORD_SCHEMA, ServeRecord::SCHEMA.tag());
    }

    #[test]
    fn faulted_record_round_trips_and_self_diffs_clean() {
        let rec = faulted_record();
        assert_eq!(rec.fault, "down=0@15+10,storm=3@20,seed=2");
        assert_eq!(rec.completed, 93, "90 trace jobs + 3 storm jobs");
        assert_eq!(rec.fault_injected, 3);
        assert_eq!(rec.fault_degraded_ticks, 10, "down window is ticks 15..25");
        let back = ServeRecord::parse(&rec.render()).expect("faulted artifact parses");
        assert_eq!(rec, back);
        // faulted A/B self-diff: parity-clean, with the extra fault cell
        let report = diff_records(&rec, &rec, &DiffOpts::default());
        assert!(report.ok(), "{}", report.render());
        assert_eq!(report.parity_breaks(), 0);
        assert_eq!(report.cells.len(), 9, "8 standard + 1 fault parity cell");
    }

    #[test]
    fn faulted_and_clean_records_never_pair_silently() {
        let clean = small_record();
        assert!(!clean.render().contains("\"fault\""), "clean artifact unchanged");
        let faulted = faulted_record();
        assert_ne!(clean.digest, faulted.digest, "the fault key is identity");
        let report = diff_records(&clean, &faulted, &DiffOpts::default());
        assert!(!report.ok(), "a faulted run must never gate-pass against clean");
    }

    #[test]
    fn sharded_record_round_trips_and_self_diffs_clean() {
        let rec = sharded_record(2);
        assert_eq!(rec.shards.len(), 2, "one ShardRecord per shard");
        assert_eq!(rec.shards[0].first_machine, 0);
        assert_eq!(rec.shards[0].machines, 3);
        assert_eq!(rec.shards[1].first_machine, 3);
        assert_eq!(rec.shards[1].machines, 3);
        assert_eq!(
            rec.shards.iter().map(|sh| sh.completed).sum::<u64>(),
            rec.completed as u64,
            "every completion belongs to exactly one shard"
        );
        let back = ServeRecord::parse(&rec.render()).expect("sharded artifact parses");
        assert_eq!(rec, back);
        let report = diff_records(&rec, &rec, &DiffOpts::default());
        assert!(report.ok(), "{}", report.render());
        assert_eq!(report.parity_breaks(), 0);
        assert_eq!(
            report.cells.len(),
            12,
            "8 standard + 2 shard + rebalance + imbalance cells"
        );
    }

    #[test]
    fn sharded_and_unsharded_records_never_pair_silently() {
        let rec = sharded_record(2);
        // unsharded baseline over the same park and workload
        let base = {
            let report = serve_sources(
                EngineId::Sos.build(6, 10, 0.5, Precision::Int8).unwrap(),
                ArrivalSource::standard_mix(&WorkloadSpec::default(), 6, 90, 7, 2),
                &ServeOpts::new().with_batch(3),
            )
            .unwrap();
            ServeRecord::from_report("test", &report)
        };
        assert!(
            !base.render().contains("shard"),
            "clean artifact carries no shard block: {}",
            base.render()
        );
        assert_ne!(base.digest, rec.digest, "the shard map is identity");
        let report = diff_records(&base, &rec, &DiffOpts::default());
        assert!(
            !report.ok(),
            "a sharded run must never gate-pass against an unsharded baseline"
        );
    }

    #[test]
    fn shard_one_records_byte_identically_to_unsharded() {
        // --shards 1 is the degenerate identity: the record must not
        // merely be equivalent, it must render the very same bytes
        // (modulo the wall-clock fields excluded from identity).
        let sharded = sharded_record(1);
        let base = {
            let report = serve_sources(
                EngineId::Sos.build(6, 10, 0.5, Precision::Int8).unwrap(),
                ArrivalSource::standard_mix(&WorkloadSpec::default(), 6, 90, 7, 2),
                &ServeOpts::new().with_batch(3),
            )
            .unwrap();
            ServeRecord::from_report("test", &report)
        };
        assert!(sharded.shards.is_empty(), "K = 1 records as unsharded");
        assert_eq!(sharded.digest, base.digest);
        assert_eq!(sharded.ticks, base.ticks);
        assert_eq!(sharded.completed, base.completed);
        assert_eq!(sharded.jobs_per_machine, base.jobs_per_machine);
        let report = diff_records(&base, &sharded, &DiffOpts::default());
        assert!(report.ok(), "{}", report.render());
        assert_eq!(report.parity_breaks(), 0);
    }

    #[test]
    fn record_round_trips_through_jsonio() {
        let rec = small_record();
        assert_eq!(rec.completed, 90);
        assert_eq!(rec.sources.len(), 2);
        let text = rec.render();
        let back = ServeRecord::parse(&text).expect("parse own artifact");
        assert_eq!(rec, back, "parse(render(r)) == r");
        assert_eq!(text, back.render(), "serialize -> parse -> serialize fixed point");
    }

    #[test]
    fn digest_is_wall_time_independent_and_recomputable() {
        let mut rec = small_record();
        assert_eq!(rec.digest, rec.compute_digest());
        let digest = rec.digest.clone();
        rec.wall_ns *= 17;
        rec.sources[0].enqueue_stalls += 5;
        assert_eq!(rec.compute_digest(), digest, "timing fields are not identity");
        rec.jobs_per_machine[0] += 1;
        assert_ne!(rec.compute_digest(), digest, "assignment counts are identity");
    }

    #[test]
    fn pre_digest_artifacts_still_parse() {
        // Artifacts recorded before the artifact-layer redesign carry no
        // digest field; the loader recomputes it from the identity
        // fields so old and new recordings stay diffable.
        let rec = small_record();
        let legacy = rec.render().replacen(
            &format!("\"digest\":\"{}\",", rec.digest),
            "",
            1,
        );
        assert!(!legacy.contains("\"digest\""), "field removal failed:\n{legacy}");
        let back = ServeRecord::parse(&legacy).expect("legacy artifact parses");
        assert_eq!(back.digest, rec.digest, "digest recomputed from identity fields");
    }

    #[test]
    fn stale_digest_is_rejected_at_parse_time() {
        // A hand-edited artifact whose identity fields changed but whose
        // digest was left stale must fail to parse — otherwise the
        // parity gate would trust the lie.
        let rec = small_record();
        let jpm = format!("\"jobs_per_machine\":[{}", rec.jobs_per_machine[0]);
        let tampered = rec.render().replacen(
            &jpm,
            &format!("\"jobs_per_machine\":[{}", rec.jobs_per_machine[0] + 1),
            1,
        );
        let err = ServeRecord::parse(&tampered).unwrap_err();
        assert!(
            format!("{err:#}").contains("does not match"),
            "stale digest must be named: {err:#}"
        );
    }

    #[test]
    fn rejects_wrong_schema_and_garbage() {
        assert!(ServeRecord::parse("{}").is_err());
        assert!(ServeRecord::parse("not json").is_err());
        let rec = small_record();
        let text = rec
            .render()
            .replace(SERVE_RECORD_SCHEMA, "stannic.serve.record.v0");
        assert!(ServeRecord::parse(&text).is_err());
    }

    #[test]
    fn rejects_corrupt_integer_fields() {
        let rec = small_record();
        let ticks = format!("\"ticks\":{}", rec.ticks);
        let text = rec.render().replacen(&ticks, "\"ticks\":-4", 1);
        assert!(ServeRecord::parse(&text).is_err());
    }

    #[test]
    fn self_diff_is_clean() {
        let rec = small_record();
        let report = diff_records(&rec, &rec, &DiffOpts::default());
        assert!(report.ok(), "{}", report.render());
        assert_eq!(report.parity_breaks(), 0);
        assert_eq!(report.cells.len(), 8, "3 parity + 5 perf cells");
        assert!(report.render().starts_with("serve diff: test -> test"));
    }

    #[test]
    fn latency_regression_is_perf_not_parity() {
        let old = small_record();
        let mut new = old.clone();
        new.latency_p99 = new.latency_p99 * 10 + 100;
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert_eq!(report.parity_breaks(), 0, "{}", report.render());
        assert_eq!(report.regressions(), 1, "{}", report.render());
        let bad = report
            .cells
            .iter()
            .find(|c| c.verdict == CellVerdict::Regression)
            .unwrap();
        assert_eq!(bad.key, "latency_p99");
        assert!(bad.ratio < 0.2, "goodness ratio: {}", bad.ratio);
    }

    #[test]
    fn uniform_latency_regression_fails_despite_being_uniform() {
        // The latency cells are virtual-time (host-independent), so they
        // gate raw: a change that makes EVERY percentile 4x worse must
        // not cancel itself through median normalization.
        let old = small_record();
        let mut new = old.clone();
        new.latency_p50 = new.latency_p50 * 4 + 4;
        new.latency_p95 = new.latency_p95 * 4 + 4;
        new.latency_p99 = new.latency_p99 * 4 + 4;
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert_eq!(report.regressions(), 3, "{}", report.render());
        assert!(!report.ok());
    }

    #[test]
    fn wall_clock_throughput_is_advisory_shift_not_a_gate() {
        // A slower host (10x wall time, identical schedule) must not
        // fail the gate — but it surfaces as the reported shift, which
        // --fail-on-shift gates for same-host A/B runs.
        let old = small_record();
        let mut new = old.clone();
        new.wall_ns *= 10;
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert_eq!(report.regressions(), 0, "{}", report.render());
        assert!(report.ok(), "{}", report.render());
        assert!(report.render().contains("(advisory)"), "{}", report.render());
        assert!(report.global_regression, "shift {}", report.shift);
        let strict = DiffOpts {
            fail_on_shift: true,
            ..DiffOpts::default()
        };
        assert!(!diff_records(&old, &new, &strict).ok());
    }

    #[test]
    fn tick_and_schedule_changes_are_parity_breaks() {
        let old = small_record();
        let mut new = old.clone();
        new.ticks += 1;
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert_eq!(report.parity_breaks(), 1, "{}", report.render());
        assert!(!report.ok());

        let mut new = old.clone();
        new.jobs_per_machine[0] += 1;
        new.digest = new.compute_digest();
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert_eq!(report.parity_breaks(), 1, "{}", report.render());
        assert!(report.gate().is_err());
    }
}
