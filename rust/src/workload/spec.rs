//! Workload generator parameters — Section 7.1's WG knobs: Job
//! Composition (JC), Machine Composition (MC, carried by `MachinePark`),
//! Burst Factor (BF), Burst Type (BT), Idle Time (IT), Idle Interval (II).

use crate::bail;
use crate::error::Result;

/// Job arrival pattern (BT).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BurstType {
    /// Jobs are released at randomly selected ticks (0..=BF per tick).
    Random,
    /// Exactly BF jobs are released every tick.
    Uniform,
}

/// Service-time (base EPT) distribution shape. The seed repo drew base
/// EPTs uniformly; Agon-scale evaluation (arXiv:2109.00665) also needs
/// heavy-tailed service times, where a few elephant jobs dominate the
/// work mass and queue-aware cost functions earn their keep.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EptDist {
    /// Uniform over `ept_range` (the original behaviour).
    Uniform,
    /// Bounded Pareto on `ept_range` with the given tail exponent
    /// (smaller `shape` = heavier tail; 1.2 is the classic web/HPC
    /// service-time regime).
    Pareto { shape: f32 },
}

/// Full workload specification.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    /// JC: fraction of compute-intensive jobs (must sum to 1 with the
    /// other two).
    pub frac_compute: f64,
    /// JC: fraction of memory-intensive jobs.
    pub frac_memory: f64,
    /// JC: fraction of mixed jobs.
    pub frac_mixed: f64,
    /// BF: maximum number of jobs released in a single clock tick.
    pub burst_factor: usize,
    /// BT: arrival pattern.
    pub burst_type: BurstType,
    /// IT: number of idle ticks inserted after `idle_interval` jobs.
    pub idle_time: u64,
    /// II: maximum number of jobs released before an idle period (0
    /// disables idling).
    pub idle_interval: usize,
    /// Job weight range [w_min, w_max] (paper: minimum weight 1).
    pub weight_range: (f32, f32),
    /// Base EPT range [e_min, e_max] before affinity/quality scaling
    /// (paper: minimum EPT 10).
    pub ept_range: (f32, f32),
    /// Relative spread of actual runtime around the EPT estimate.
    pub runtime_noise: f32,
    /// Distribution of the base EPT draw within `ept_range`.
    pub ept_dist: EptDist,
}

impl Default for WorkloadSpec {
    /// The "evenly distributed" workload of Section 8.4 experiment (1):
    /// 35% memory, 35% compute, 30% mixed.
    fn default() -> Self {
        WorkloadSpec {
            frac_compute: 0.35,
            frac_memory: 0.35,
            frac_mixed: 0.30,
            burst_factor: 3,
            burst_type: BurstType::Random,
            idle_time: 8,
            idle_interval: 40,
            weight_range: (1.0, 255.0),
            ept_range: (10.0, 200.0),
            runtime_noise: 0.15,
            ept_dist: EptDist::Uniform,
        }
    }
}

impl WorkloadSpec {
    /// Experiment (1): evenly distributed workload.
    pub fn even() -> Self {
        Self::default()
    }

    /// Experiment (2): memory-skewed — 70% memory, 10% compute, 20% mixed.
    pub fn memory_skewed() -> Self {
        WorkloadSpec {
            frac_compute: 0.10,
            frac_memory: 0.70,
            frac_mixed: 0.20,
            ..Self::default()
        }
    }

    /// Experiment (3): compute-skewed — 70% compute, 10% memory, 20%
    /// mixed. (The paper's text says "30% mixed", which does not sum to
    /// 1 with 70+10; we normalize to 20% and note the discrepancy in
    /// EXPERIMENTS.md.)
    pub fn compute_skewed() -> Self {
        WorkloadSpec {
            frac_compute: 0.70,
            frac_memory: 0.10,
            frac_mixed: 0.20,
            ..Self::default()
        }
    }

    /// Experiment (4): fully homogeneous memory-intensive workload.
    pub fn homogeneous_memory() -> Self {
        WorkloadSpec {
            frac_compute: 0.0,
            frac_memory: 1.0,
            frac_mixed: 0.0,
            ..Self::default()
        }
    }

    /// Experiment (5): compute-intensive workload (paired with a
    /// homogeneous CPU machine park).
    pub fn homogeneous_compute() -> Self {
        WorkloadSpec {
            frac_compute: 1.0,
            frac_memory: 0.0,
            frac_mixed: 0.0,
            ..Self::default()
        }
    }

    /// Agon-scale mix (1): bursty arrivals — large random bursts chased
    /// by idle troughs, the arrival pattern where queue-depth-aware cost
    /// separates competitive schedulers from greedy ones at scale.
    pub fn bursty() -> Self {
        WorkloadSpec {
            burst_factor: 8,
            burst_type: BurstType::Random,
            idle_time: 25,
            idle_interval: 24,
            ..Self::default()
        }
    }

    /// Agon-scale mix (2): heavy-tailed service times (bounded Pareto,
    /// shape 1.2) over the even job composition — elephant jobs make
    /// head-of-line blocking visible in the latency percentiles.
    pub fn heavy_tailed() -> Self {
        WorkloadSpec {
            ept_dist: EptDist::Pareto { shape: 1.2 },
            ..Self::default()
        }
    }

    pub fn with_burst(mut self, bf: usize, bt: BurstType) -> Self {
        self.burst_factor = bf;
        self.burst_type = bt;
        self
    }

    pub fn with_idle(mut self, it: u64, ii: usize) -> Self {
        self.idle_time = it;
        self.idle_interval = ii;
        self
    }

    /// Validate that JC sums to 1 (within rounding).
    pub fn validate(&self) -> Result<()> {
        let s = self.frac_compute + self.frac_memory + self.frac_mixed;
        if (s - 1.0).abs() > 1e-6 {
            bail!("job composition sums to {s}, expected 1.0");
        }
        if self.burst_factor == 0 {
            bail!("burst_factor must be >= 1");
        }
        if self.weight_range.0 < 1.0 {
            bail!("minimum job weight is 1 (Section 4.2)");
        }
        if self.ept_range.0 < 10.0 {
            bail!("minimum EPT is 10 (Section 4.2)");
        }
        if let EptDist::Pareto { shape } = self.ept_dist {
            if !shape.is_finite() || shape <= 0.0 {
                bail!("Pareto shape must be positive and finite");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_valid() {
        for s in [
            WorkloadSpec::even(),
            WorkloadSpec::memory_skewed(),
            WorkloadSpec::compute_skewed(),
            WorkloadSpec::homogeneous_memory(),
            WorkloadSpec::homogeneous_compute(),
            WorkloadSpec::bursty(),
            WorkloadSpec::heavy_tailed(),
        ] {
            s.validate().unwrap();
        }
    }

    #[test]
    fn pareto_shape_validated() {
        let mut s = WorkloadSpec::heavy_tailed();
        assert!(s.validate().is_ok());
        s.ept_dist = EptDist::Pareto { shape: 0.0 };
        assert!(s.validate().is_err());
        s.ept_dist = EptDist::Pareto { shape: f32::NAN };
        assert!(s.validate().is_err());
    }

    #[test]
    fn invalid_composition_rejected() {
        let mut s = WorkloadSpec::default();
        s.frac_compute = 0.9;
        assert!(s.validate().is_err());
    }

    #[test]
    fn invalid_floors_rejected() {
        let mut s = WorkloadSpec::default();
        s.weight_range = (0.5, 10.0);
        assert!(s.validate().is_err());
        let mut s = WorkloadSpec::default();
        s.ept_range = (1.0, 10.0);
        assert!(s.validate().is_err());
    }
}
