//! The in-house workload generator of Section 7.1: emulates job dispatch
//! in heterogeneous systems with configurable Job Composition, Burst
//! Factor/Type and Idle Time/Interval, plus Monte-Carlo sampling over the
//! parameter space (Section 8.1's 50-workload sweeps).

pub mod dag;
mod generator;
mod montecarlo;
pub mod rng;
mod spec;
mod trace;

pub use dag::{generate_dag, DagSpec, TaskGraph};
pub use generator::{affinity, generate_trace, synth_job};
pub use montecarlo::sample_specs;
pub use rng::Rng;
pub use spec::{BurstType, EptDist, WorkloadSpec};
pub use trace::{Trace, TraceEvent};
