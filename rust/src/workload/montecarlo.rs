//! Monte-Carlo workload sampling — Section 8.1 generates "50 different
//! workloads by varying the workload parameters"; this module draws
//! random-but-reproducible [`WorkloadSpec`]s from the generator's
//! parameter space.

use super::rng::Rng;
use super::spec::{BurstType, EptDist, WorkloadSpec};

/// Sample `count` workload specifications from the WG parameter space.
pub fn sample_specs(count: usize, seed: u64) -> Vec<WorkloadSpec> {
    let mut rng = Rng::new(seed ^ 0x5eed_5eed_5eed_5eed);
    (0..count).map(|_| sample_one(&mut rng)).collect()
}

fn sample_one(rng: &mut Rng) -> WorkloadSpec {
    // Random job composition on the simplex (rounded to 2 decimals, then
    // renormalized onto frac_mixed so validate() passes exactly).
    let a = rng.next_f64();
    let b = rng.next_f64();
    let (lo, hi) = if a < b { (a, b) } else { (b, a) };
    let mut fc = (lo * 100.0).round() / 100.0;
    let mut fm = (((hi - lo) * 100.0).round()) / 100.0;
    fc = fc.clamp(0.0, 1.0);
    fm = fm.clamp(0.0, 1.0 - fc);
    let fx = 1.0 - fc - fm;

    WorkloadSpec {
        frac_compute: fc,
        frac_memory: fm,
        frac_mixed: fx,
        burst_factor: rng.range(1, 6),
        burst_type: if rng.chance(0.5) {
            BurstType::Random
        } else {
            BurstType::Uniform
        },
        idle_time: rng.range(0, 20) as u64,
        idle_interval: rng.range(10, 80),
        weight_range: (1.0, rng.uniform(32.0, 255.0).round()),
        ept_range: (10.0, rng.uniform(64.0, 200.0).round()),
        runtime_noise: rng.uniform(0.05, 0.3),
        ept_dist: EptDist::Uniform,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_are_valid_specs() {
        for (i, s) in sample_specs(50, 42).iter().enumerate() {
            s.validate().unwrap_or_else(|e| panic!("spec {i}: {e}"));
        }
    }

    #[test]
    fn sampling_is_deterministic() {
        assert_eq!(sample_specs(10, 7), sample_specs(10, 7));
        assert_ne!(sample_specs(10, 7), sample_specs(10, 8));
    }

    #[test]
    fn parameter_diversity() {
        let specs = sample_specs(50, 3);
        let bursts: std::collections::HashSet<_> =
            specs.iter().map(|s| s.burst_factor).collect();
        assert!(bursts.len() >= 3, "burst factors should vary: {bursts:?}");
        let uniform = specs
            .iter()
            .filter(|s| s.burst_type == BurstType::Uniform)
            .count();
        assert!((10..=40).contains(&uniform));
    }
}
