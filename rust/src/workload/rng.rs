//! Deterministic PRNG substrate (no `rand` crate in this environment).
//!
//! xorshift64* — fast, well-distributed enough for workload synthesis and
//! Monte-Carlo sampling, and fully reproducible across runs/platforms.

/// A seeded xorshift64* generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point; mix the seed with splitmix64
        // so small consecutive seeds give unrelated streams.
        let mut z = seed.wrapping_add(0x9e37_79b9_7f4a_7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^= z >> 31;
        Rng {
            state: if z == 0 { 0xdead_beef_cafe_f00d } else { z },
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_f491_4f6c_dd1d)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // multiply-shift bounded sampling (Lemire); bias negligible for
        // workload purposes but we reject the tail anyway for exactness.
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform usize in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Sample an index from a discrete distribution (weights need not be
    /// normalized).
    pub fn pick_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            if x < w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Truncated-normal-ish noise factor around 1.0 with relative spread
    /// `sigma` (sum of three uniforms — Irwin–Hall approximation), used
    /// for the stochastic deviation of actual runtimes from EPTs.
    pub fn noise_factor(&mut self, sigma: f32) -> f32 {
        let s = (self.next_f64() + self.next_f64() + self.next_f64()) as f32 / 1.5 - 1.0;
        (1.0 + sigma * s).max(0.1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_respects_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.uniform(10.0, 255.0);
            assert!((10.0..255.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(9);
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[r.range(2, 5) - 2] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn weighted_pick_respects_weights() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.pick_weighted(&[0.7, 0.2, 0.1])] += 1;
        }
        assert!(counts[0] > counts[1] && counts[1] > counts[2]);
        assert!((19_000..23_000).contains(&counts[0]), "{counts:?}");
    }

    #[test]
    fn noise_factor_centered_on_one() {
        let mut r = Rng::new(11);
        let mean: f32 =
            (0..10_000).map(|_| r.noise_factor(0.2)).sum::<f32>() / 10_000.0;
        assert!((mean - 1.0).abs() < 0.02, "mean {mean}");
    }
}
