//! Workload synthesis: turns a [`WorkloadSpec`] + [`MachinePark`] into a
//! deterministic arrival trace (Phase I of the algorithm — EPT estimates
//! are attached per machine from the job's nature and the machine's
//! type/quality affinity).

use crate::core::{Job, JobNature, MachineKind, MachinePark};

use super::rng::Rng;
use super::spec::{BurstType, EptDist, WorkloadSpec};
use super::trace::{Trace, TraceEvent};

/// Affinity multiplier: how well a machine type runs a job nature.
/// Lower = faster. The matrix encodes the paper's intuition (Section 2's
/// CNN-layer example: a convolution runs on either, but the GPU is
/// expected to finish quicker) plus the Mixed machine's jack-of-all-
/// trades profile.
pub fn affinity(nature: JobNature, kind: MachineKind) -> f32 {
    match (nature, kind) {
        (JobNature::Compute, MachineKind::Gpu) => 0.5,
        (JobNature::Compute, MachineKind::Cpu) => 1.5,
        (JobNature::Compute, MachineKind::Mixed) => 1.0,
        (JobNature::Memory, MachineKind::Gpu) => 1.6,
        (JobNature::Memory, MachineKind::Cpu) => 0.7,
        (JobNature::Memory, MachineKind::Mixed) => 1.0,
        (JobNature::Mixed, MachineKind::Gpu) => 1.1,
        (JobNature::Mixed, MachineKind::Cpu) => 1.1,
        (JobNature::Mixed, MachineKind::Mixed) => 0.8,
    }
}

/// Draw a base EPT from the spec's service-time distribution. Exactly
/// one RNG draw per job in every branch, and the `Uniform` branch is the
/// seed repo's original call — so traces for `Uniform` specs (including
/// the pinned golden scenario) are unchanged byte-for-byte.
fn sample_base_ept(spec: &WorkloadSpec, rng: &mut Rng) -> f32 {
    let (lo, hi) = spec.ept_range;
    match spec.ept_dist {
        EptDist::Uniform => rng.uniform(lo, hi),
        EptDist::Pareto { shape } => {
            // Bounded-Pareto inverse CDF on [lo, hi]:
            //   x = lo / (1 - u * (1 - (lo/hi)^a))^(1/a)
            // u=0 -> lo, u->1 -> hi; mass concentrates near lo with a
            // heavy upper tail.
            let u = rng.next_f64();
            let a = shape as f64;
            let ratio = (lo as f64 / hi as f64).powf(a);
            let x = lo as f64 / (1.0 - u * (1.0 - ratio)).powf(1.0 / a);
            (x as f32).clamp(lo, hi)
        }
    }
}

/// Synthesize one job: nature from JC, weight uniform, per-machine EPT =
/// base * affinity * quality (clamped to the spec's representable range).
pub fn synth_job(
    id: u64,
    spec: &WorkloadSpec,
    park: &MachinePark,
    rng: &mut Rng,
) -> Job {
    let nature = match rng.pick_weighted(&[
        spec.frac_compute,
        spec.frac_memory,
        spec.frac_mixed,
    ]) {
        0 => JobNature::Compute,
        1 => JobNature::Memory,
        _ => JobNature::Mixed,
    };
    let weight = rng.uniform(spec.weight_range.0, spec.weight_range.1).round().max(1.0);
    let base = sample_base_ept(spec, rng);
    let ept = park
        .iter()
        .map(|m| {
            (base * affinity(nature, m.kind) * m.quality_factor())
                .clamp(spec.ept_range.0, 255.0)
                .round()
        })
        .collect();
    Job::new(id, weight, ept, nature).with_actual_factor(rng.noise_factor(spec.runtime_noise))
}

/// Generate a deterministic arrival trace of `n_jobs` jobs.
///
/// Arrival pattern per tick follows BT/BF; IT idle ticks are inserted
/// after every II released jobs (II = 0 disables idling). The trace's
/// tick axis is the *scheduler clock* — the SOS engines serialize
/// same-tick bursts internally.
pub fn generate_trace(
    spec: &WorkloadSpec,
    park: &MachinePark,
    n_jobs: usize,
    seed: u64,
) -> Trace {
    spec.validate().expect("invalid workload spec");
    let mut rng = Rng::new(seed);
    let mut events: Vec<TraceEvent> = Vec::with_capacity(n_jobs);
    let mut tick: u64 = 0;
    let mut emitted = 0usize;
    let mut since_idle = 0usize;

    while emitted < n_jobs {
        tick += 1;
        // idle-period insertion (IT after II jobs)
        if spec.idle_interval > 0 && since_idle >= spec.idle_interval {
            tick += spec.idle_time;
            since_idle = 0;
        }
        let burst = match spec.burst_type {
            BurstType::Uniform => spec.burst_factor,
            BurstType::Random => {
                // random ticks release 0..=BF jobs; bias toward small
                // bursts so arrivals stay stochastic rather than dense
                if rng.chance(0.45) {
                    rng.range(1, spec.burst_factor)
                } else {
                    0
                }
            }
        };
        for _ in 0..burst.min(n_jobs - emitted) {
            let id = (emitted + 1) as u64;
            let job = synth_job(id, spec, park, &mut rng).with_arrival(tick);
            events.push(TraceEvent {
                tick,
                job: Some(job),
            });
            emitted += 1;
            since_idle += 1;
        }
    }
    Trace::new(events, park.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Quality;

    #[test]
    fn trace_is_deterministic() {
        let park = MachinePark::paper_m1_m5();
        let spec = WorkloadSpec::default();
        let a = generate_trace(&spec, &park, 100, 42);
        let b = generate_trace(&spec, &park, 100, 42);
        assert_eq!(a.n_jobs(), 100);
        assert_eq!(a, b);
    }

    #[test]
    fn seeds_change_traces() {
        let park = MachinePark::paper_m1_m5();
        let spec = WorkloadSpec::default();
        assert_ne!(
            generate_trace(&spec, &park, 50, 1),
            generate_trace(&spec, &park, 50, 2)
        );
    }

    #[test]
    fn job_composition_respected() {
        let park = MachinePark::paper_m1_m5();
        let spec = WorkloadSpec::memory_skewed();
        let t = generate_trace(&spec, &park, 2000, 7);
        let mem = t
            .jobs()
            .filter(|j| j.nature == JobNature::Memory)
            .count() as f64
            / 2000.0;
        assert!((mem - 0.70).abs() < 0.05, "memory fraction {mem}");
    }

    #[test]
    fn gpu_best_is_fastest_for_compute() {
        let park = MachinePark::paper_m1_m5();
        let spec = WorkloadSpec::homogeneous_compute();
        let t = generate_trace(&spec, &park, 200, 3);
        for j in t.jobs() {
            // M4 = <GPU,Best> (index 3) must beat M1 = <CPU,Best> (0)
            assert!(j.ept[3] <= j.ept[0], "GPU best {} CPU {}", j.ept[3], j.ept[0]);
        }
    }

    #[test]
    fn quality_slows_machines() {
        let park = MachinePark::paper_m1_m5();
        assert_eq!(park[3].quality, Quality::Best);
        assert_eq!(park[4].quality, Quality::Worst);
        let spec = WorkloadSpec::default();
        let t = generate_trace(&spec, &park, 300, 11);
        let mut faster = 0;
        for j in t.jobs() {
            if j.ept[3] <= j.ept[4] {
                faster += 1;
            }
        }
        assert!(faster >= 290, "best GPU should rarely lose to worst GPU");
    }

    #[test]
    fn uniform_burst_releases_bf_per_tick() {
        let park = MachinePark::paper_m1_m5();
        let spec = WorkloadSpec::default()
            .with_burst(4, BurstType::Uniform)
            .with_idle(0, 0);
        let t = generate_trace(&spec, &park, 40, 5);
        // 40 jobs / 4 per tick = ticks 1..=10, 4 each
        let mut per_tick = std::collections::HashMap::new();
        for e in t.events() {
            *per_tick.entry(e.tick).or_insert(0usize) += 1;
        }
        assert!(per_tick.values().all(|&c| c == 4));
        assert_eq!(per_tick.len(), 10);
    }

    #[test]
    fn idle_periods_create_gaps() {
        let park = MachinePark::paper_m1_m5();
        let spec = WorkloadSpec::default()
            .with_burst(1, BurstType::Uniform)
            .with_idle(10, 5);
        let t = generate_trace(&spec, &park, 20, 5);
        let ticks: Vec<u64> = t.events().iter().map(|e| e.tick).collect();
        let max_gap = ticks.windows(2).map(|w| w[1] - w[0]).max().unwrap();
        assert!(max_gap >= 10, "idle gap missing: {ticks:?}");
    }

    #[test]
    fn heavy_tail_skews_low_with_elephants() {
        let park = MachinePark::paper_m1_m5();
        let uni = generate_trace(&WorkloadSpec::even(), &park, 2000, 21);
        let hvy = generate_trace(&WorkloadSpec::heavy_tailed(), &park, 2000, 21);
        let median = |t: &Trace| {
            let mut v: Vec<f32> = t.jobs().map(|j| j.ept[0]).collect();
            v.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
            v[v.len() / 2]
        };
        // Pareto mass concentrates near the floor...
        assert!(
            median(&hvy) < median(&uni),
            "heavy-tailed median {} !< uniform median {}",
            median(&hvy),
            median(&uni)
        );
        // ...while the tail still reaches the elephants.
        let max_hvy = hvy.jobs().map(|j| j.ept[0]).fold(0.0f32, f32::max);
        assert!(max_hvy > 150.0, "tail too short: max EPT {max_hvy}");
        // Bounds still respected.
        for j in hvy.jobs() {
            for &e in &j.ept {
                assert!((10.0..=255.0).contains(&e));
            }
        }
    }

    #[test]
    fn bursty_spec_produces_bigger_bursts() {
        let park = MachinePark::paper_m1_m5();
        let t = generate_trace(&WorkloadSpec::bursty(), &park, 500, 8);
        let mut per_tick = std::collections::HashMap::new();
        for e in t.events() {
            *per_tick.entry(e.tick).or_insert(0usize) += 1;
        }
        let max_burst = per_tick.values().copied().max().unwrap();
        assert!(max_burst >= 5, "bursty max burst only {max_burst}");
    }

    #[test]
    fn ept_within_representable_range() {
        let park = MachinePark::paper_m1_m5();
        let t = generate_trace(&WorkloadSpec::default(), &park, 500, 13);
        for j in t.jobs() {
            for &e in &j.ept {
                assert!((10.0..=255.0).contains(&e));
            }
            assert!(j.weight >= 1.0 && j.weight <= 255.0);
        }
    }
}
