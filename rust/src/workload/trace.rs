//! Arrival traces: the serialized form of a generated workload, plus a
//! text round-trip format so experiments can be archived and replayed.

use crate::bail;
use crate::core::{Job, JobNature};
use crate::error::Result;

/// One arrival event on the scheduler clock.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub tick: u64,
    /// `None` events are idle ticks explicitly recorded (normally elided:
    /// consumers iterate the clock themselves).
    pub job: Option<Job>,
}

/// A complete arrival trace for a fixed machine count.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    events: Vec<TraceEvent>,
    machines: usize,
}

impl Trace {
    pub fn new(events: Vec<TraceEvent>, machines: usize) -> Self {
        debug_assert!(events.windows(2).all(|w| w[0].tick <= w[1].tick));
        Trace { events, machines }
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn machines(&self) -> usize {
        self.machines
    }

    pub fn n_jobs(&self) -> usize {
        self.events.iter().filter(|e| e.job.is_some()).count()
    }

    pub fn jobs(&self) -> impl Iterator<Item = &Job> {
        self.events.iter().filter_map(|e| e.job.as_ref())
    }

    /// Last arrival tick (0 for an empty trace).
    pub fn horizon(&self) -> u64 {
        self.events.last().map_or(0, |e| e.tick)
    }

    /// Serialize to a line-oriented text format:
    /// `tick id weight nature actual_factor ept0 ept1 ...`
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("# stannic-trace v1 machines={}\n", self.machines));
        for e in &self.events {
            if let Some(j) = &e.job {
                s.push_str(&format!(
                    "{} {} {} {} {}",
                    e.tick,
                    j.id,
                    j.weight,
                    match j.nature {
                        JobNature::Compute => "C",
                        JobNature::Memory => "M",
                        JobNature::Mixed => "X",
                    },
                    j.actual_factor,
                ));
                for v in &j.ept {
                    s.push_str(&format!(" {v}"));
                }
                s.push('\n');
            }
        }
        s
    }

    /// Parse the text format produced by [`Trace::to_text`].
    ///
    /// Every line [`Trace::to_text`] emits is newline-terminated, so
    /// text that does not end in `'\n'` was truncated mid-record and is
    /// rejected outright. Field-level checks alone cannot catch this: a
    /// float cut to `"25."` still parses, and a record cut between EPTs
    /// can leave a prefix that passes every per-token check.
    pub fn from_text(text: &str) -> Result<Trace> {
        if !text.is_empty() && !text.ends_with('\n') {
            bail!(
                "line {}: trace truncated mid-record (no trailing newline)",
                text.lines().count()
            );
        }
        let mut lines = text.lines();
        let header = lines.next().ok_or("empty trace")?;
        let machines: usize = header
            .split("machines=")
            .nth(1)
            .ok_or("missing machines= in header")?
            .trim()
            .parse()
            .map_err(|e| format!("bad machine count: {e}"))?;
        let mut events = Vec::new();
        for (ln, line) in lines.enumerate() {
            if line.trim().is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let mut next = |what: &str| {
                it.next()
                    .ok_or_else(|| format!("line {}: missing {what}", ln + 2))
            };
            let tick: u64 = next("tick")?.parse().map_err(|e| format!("tick: {e}"))?;
            let id: u64 = next("id")?.parse().map_err(|e| format!("id: {e}"))?;
            let weight: f32 = next("weight")?.parse().map_err(|e| format!("weight: {e}"))?;
            let nature = match next("nature")? {
                "C" => JobNature::Compute,
                "M" => JobNature::Memory,
                "X" => JobNature::Mixed,
                other => bail!("line {}: bad nature {other}", ln + 2),
            };
            let af: f32 = next("factor")?.parse().map_err(|e| format!("factor: {e}"))?;
            let ept: Vec<f32> = it
                .map(|v| v.parse().map_err(|e| format!("ept: {e}")))
                .collect::<Result<_, _>>()?;
            if ept.len() != machines {
                bail!("line {}: {} EPTs for {} machines", ln + 2, ept.len(), machines);
            }
            events.push(TraceEvent {
                tick,
                job: Some(
                    Job::new(id, weight, ept, nature)
                        .with_arrival(tick)
                        .with_actual_factor(af),
                ),
            });
        }
        Ok(Trace::new(events, machines))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::MachinePark;
    use crate::workload::{generate_trace, WorkloadSpec};

    #[test]
    fn text_round_trip() {
        let park = MachinePark::paper_m1_m5();
        let t = generate_trace(&WorkloadSpec::default(), &park, 50, 99);
        let text = t.to_text();
        let back = Trace::from_text(&text).unwrap();
        assert_eq!(back.n_jobs(), 50);
        assert_eq!(back.machines(), 5);
        for (a, b) in t.jobs().zip(back.jobs()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.weight, b.weight);
            assert_eq!(a.nature, b.nature);
            assert_eq!(a.ept, b.ept);
        }
    }

    #[test]
    fn from_text_rejects_malformed() {
        assert!(Trace::from_text("").is_err());
        assert!(Trace::from_text("# stannic-trace v1 machines=2\n1 1 5 C 1.0 10\n").is_err());
        assert!(Trace::from_text("# stannic-trace v1 machines=1\n1 1 5 Q 1.0 10\n").is_err());
    }

    #[test]
    fn from_text_rejects_truncation_anywhere_in_the_tail() {
        let park = MachinePark::paper_m1_m5();
        let good = generate_trace(&WorkloadSpec::default(), &park, 10, 1).to_text();
        assert!(good.ends_with('\n'), "to_text must newline-terminate");
        // cutting anywhere inside the final record must be a hard error,
        // even where the surviving prefix still parses token-by-token
        for cut in 1..=6 {
            let bad = &good[..good.len() - cut];
            let err = Trace::from_text(bad).unwrap_err().to_string();
            assert!(err.contains("truncated"), "cut {cut}: {err}");
            assert!(
                err.contains(&format!("line {}", good.lines().count())),
                "cut {cut} not line-numbered: {err}"
            );
        }
    }

    #[test]
    fn horizon_is_last_tick() {
        let park = MachinePark::paper_m1_m5();
        let t = generate_trace(&WorkloadSpec::default(), &park, 20, 4);
        assert_eq!(t.horizon(), t.events().last().unwrap().tick);
    }
}
