//! DAG task-graph workloads — the paper's Definition 2 intuition made
//! concrete: "Weight could correlate with the number of jobs that depend
//! on the completion of this job (i.e., how many downstream task nodes
//! this job has in a DAG Task Graph), prioritizing the minimization of
//! start delays."
//!
//! Generates a layered random DAG, assigns each node a weight of
//! `1 + |descendants|` (saturated to the representable range), and emits
//! an arrival trace in topological order with edge-respecting arrival
//! times (a child arrives a few ticks after its last parent).

use crate::core::{Job, MachinePark};

use super::generator::synth_job;
use super::rng::Rng;
use super::spec::WorkloadSpec;
use super::trace::{Trace, TraceEvent};

/// A generated task graph: adjacency (parents -> children) plus the
/// derived schedule trace.
#[derive(Debug, Clone)]
pub struct TaskGraph {
    /// children[i] = indices of jobs depending on job i (0-based).
    pub children: Vec<Vec<usize>>,
    /// Per-node descendant counts (the weight source).
    pub descendants: Vec<usize>,
    pub trace: Trace,
}

/// DAG-shape knobs.
#[derive(Debug, Clone)]
pub struct DagSpec {
    /// Base workload parameters (nature mix, EPT ranges, noise).
    pub base: WorkloadSpec,
    /// Average nodes per layer.
    pub layer_width: usize,
    /// Probability of an edge between consecutive-layer node pairs.
    pub edge_prob: f64,
    /// Ticks between a parent's arrival and its child's earliest arrival.
    pub edge_delay: u64,
}

impl Default for DagSpec {
    fn default() -> Self {
        DagSpec {
            base: WorkloadSpec::default(),
            layer_width: 6,
            edge_prob: 0.35,
            edge_delay: 4,
        }
    }
}

/// Count descendants per node by reverse-topological accumulation of
/// reachable sets (bitset per node; fine for the <=10k-node workloads
/// used here).
fn descendant_counts(children: &[Vec<usize>]) -> Vec<usize> {
    let n = children.len();
    let words = n.div_ceil(64);
    let mut reach: Vec<Vec<u64>> = vec![vec![0u64; words]; n];
    for i in (0..n).rev() {
        // children have larger indices (layered construction)
        let mut acc = vec![0u64; words];
        for &c in &children[i] {
            acc[c / 64] |= 1 << (c % 64);
            for w in 0..words {
                acc[w] |= reach[c][w];
            }
        }
        reach[i] = acc;
    }
    reach
        .iter()
        .map(|bits| bits.iter().map(|w| w.count_ones() as usize).sum())
        .collect()
}

/// Generate a layered DAG workload of `n_jobs` nodes.
pub fn generate_dag(spec: &DagSpec, park: &MachinePark, n_jobs: usize, seed: u64) -> TaskGraph {
    spec.base.validate().expect("invalid base workload spec");
    assert!(spec.layer_width >= 1);
    let mut rng = Rng::new(seed ^ 0xda6_0da6_0da6_0da6);

    // 1. layered topology: node i lives in layer i / layer_width
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); n_jobs];
    let layer_of = |i: usize| i / spec.layer_width;
    for i in 0..n_jobs {
        for j in (i + 1)..n_jobs {
            if layer_of(j) == layer_of(i) + 1 && rng.chance(spec.edge_prob) {
                children[i].push(j);
            } else if layer_of(j) > layer_of(i) + 1 {
                break;
            }
        }
    }

    // 2. weights from descendant counts
    let descendants = descendant_counts(&children);

    // 3. arrival times: roots arrive on a base cadence; children arrive
    // edge_delay after their latest parent
    let mut arrival = vec![0u64; n_jobs];
    let mut next_root_tick = 1u64;
    for i in 0..n_jobs {
        let mut earliest = 0u64;
        for p in 0..i {
            if children[p].contains(&i) {
                earliest = earliest.max(arrival[p] + spec.edge_delay);
            }
        }
        if earliest == 0 {
            arrival[i] = next_root_tick;
            next_root_tick += rng.range(1, 3) as u64;
        } else {
            arrival[i] = earliest;
        }
    }

    // 4. synthesize jobs; override weight with the dependency count
    let mut events: Vec<TraceEvent> = Vec::with_capacity(n_jobs);
    for i in 0..n_jobs {
        let mut job: Job = synth_job((i + 1) as u64, &spec.base, park, &mut rng);
        job.weight = (1.0 + descendants[i] as f32).min(spec.base.weight_range.1);
        job = job.with_arrival(arrival[i]);
        events.push(TraceEvent {
            tick: arrival[i],
            job: Some(job),
        });
    }
    events.sort_by_key(|e| (e.tick, e.job.as_ref().map(|j| j.id)));
    TaskGraph {
        children,
        descendants,
        trace: Trace::new(events, park.len()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Precision;
    use crate::scheduler::SosEngine;

    fn graph(n: usize, seed: u64) -> TaskGraph {
        generate_dag(&DagSpec::default(), &MachinePark::paper_m1_m5(), n, seed)
    }

    #[test]
    fn dag_is_acyclic_and_layered() {
        let g = graph(120, 5);
        for (i, kids) in g.children.iter().enumerate() {
            for &c in kids {
                assert!(c > i, "edges point forward");
            }
        }
    }

    #[test]
    fn weights_track_descendant_counts() {
        let g = graph(120, 5);
        for (i, e) in g.trace.events().iter().enumerate() {
            let j = e.job.as_ref().unwrap();
            // events are sorted by tick; match by id
            let node = (j.id - 1) as usize;
            assert_eq!(j.weight, 1.0 + g.descendants[node] as f32, "node {i}");
        }
        // at least one node has descendants in a 120-node layered DAG
        assert!(g.descendants.iter().any(|&d| d > 0));
    }

    #[test]
    fn descendant_counts_transitive() {
        // chain 0 -> 1 -> 2: node 0 has TWO descendants (1 and 2)
        let children = vec![vec![1], vec![2], vec![]];
        assert_eq!(descendant_counts(&children), vec![2, 1, 0]);
        // diamond 0 -> {1,2} -> 3
        let children = vec![vec![1, 2], vec![3], vec![3], vec![]];
        assert_eq!(descendant_counts(&children), vec![3, 1, 1, 0]);
    }

    #[test]
    fn children_arrive_after_parents() {
        let g = graph(150, 9);
        let spec = DagSpec::default();
        let arrival: std::collections::HashMap<u64, u64> = g
            .trace
            .jobs()
            .map(|j| (j.id, j.arrival))
            .collect();
        for (p, kids) in g.children.iter().enumerate() {
            for &c in kids {
                let pa = arrival[&((p + 1) as u64)];
                let ca = arrival[&((c + 1) as u64)];
                assert!(ca >= pa + spec.edge_delay, "edge {p}->{c}: {pa} {ca}");
            }
        }
    }

    #[test]
    fn sos_prioritizes_high_fanout_roots() {
        // A bottleneck root with many descendants gets a high weight and
        // thus high WSPT priority -> it should be assigned immediately
        // and hold schedule heads ahead of low-fanout peers.
        let g = graph(200, 11);
        let mut engine = SosEngine::new(5, 10, 0.5, Precision::Int8);
        let mut events = g.trace.events().iter().peekable();
        let mut t = 0u64;
        loop {
            t += 1;
            while events.peek().is_some_and(|e| e.tick <= t) {
                engine.submit(events.next().unwrap().job.clone().unwrap());
            }
            engine.tick(None);
            if engine.is_idle() && events.peek().is_none() {
                break;
            }
        }
        assert!(engine.is_idle());
    }
}
