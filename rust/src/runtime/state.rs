//! Host-side mirror of the schedule state fed to the XLA cost
//! executable: the padded [M, D] arrays (t, rem_hi, rem_lo, valid) in
//! row-major layout matching `python/compile/model.py`, plus the
//! metadata (ids, alpha points, VW counters) the host needs for pops and
//! inserts. Rows maintain Definition 4 proper ordering.

use crate::core::JobId;

#[derive(Debug, Clone)]
pub struct XlaScheduleState {
    machines: usize,
    depth: usize,
    t: Vec<f32>,
    rem_hi: Vec<f32>,
    rem_lo: Vec<f32>,
    valid: Vec<f32>,
    // host-side metadata (not shipped to the accelerator)
    ids: Vec<JobId>,
    eps: Vec<f32>,
    w: Vec<f32>,
    n: Vec<u32>,
    alpha_pt: Vec<u32>,
    lens: Vec<usize>,
}

impl XlaScheduleState {
    pub fn new(machines: usize, depth: usize) -> Self {
        let md = machines * depth;
        XlaScheduleState {
            machines,
            depth,
            t: vec![0.0; md],
            rem_hi: vec![0.0; md],
            rem_lo: vec![0.0; md],
            valid: vec![0.0; md],
            ids: vec![0; md],
            eps: vec![0.0; md],
            w: vec![0.0; md],
            n: vec![0; md],
            alpha_pt: vec![0; md],
            lens: vec![0; machines],
        }
    }

    #[inline]
    fn at(&self, m: usize, k: usize) -> usize {
        m * self.depth + k
    }

    pub fn t(&self) -> &[f32] {
        &self.t
    }

    pub fn rem_hi(&self) -> &[f32] {
        &self.rem_hi
    }

    pub fn rem_lo(&self) -> &[f32] {
        &self.rem_lo
    }

    pub fn valid(&self) -> &[f32] {
        &self.valid
    }

    pub fn len(&self, m: usize) -> usize {
        self.lens[m]
    }

    pub fn total_jobs(&self) -> usize {
        self.lens.iter().sum()
    }

    pub fn any_free(&self) -> bool {
        self.lens.iter().any(|&l| l < self.depth)
    }

    /// Refresh the accelerator-visible rem arrays for slot (m, k) from
    /// the metadata.
    fn sync_rem(&mut self, m: usize, k: usize) {
        let i = self.at(m, k);
        let nf = self.n[i] as f32;
        self.rem_hi[i] = self.eps[i] - nf;
        self.rem_lo[i] = self.w[i] - nf * self.t[i];
    }

    /// Insert a job at row-`m`, position `pos` (shifting the tail right).
    pub fn insert(
        &mut self,
        m: usize,
        pos: usize,
        id: JobId,
        w: f32,
        eps: f32,
        t: f32,
        alpha_pt: u32,
    ) {
        assert!(self.lens[m] < self.depth, "insert into full row");
        assert!(pos <= self.lens[m]);
        // shift right
        for k in (pos..self.lens[m]).rev() {
            let src = self.at(m, k);
            let dst = self.at(m, k + 1);
            self.t[dst] = self.t[src];
            self.rem_hi[dst] = self.rem_hi[src];
            self.rem_lo[dst] = self.rem_lo[src];
            self.valid[dst] = self.valid[src];
            self.ids[dst] = self.ids[src];
            self.eps[dst] = self.eps[src];
            self.w[dst] = self.w[src];
            self.n[dst] = self.n[src];
            self.alpha_pt[dst] = self.alpha_pt[src];
        }
        let i = self.at(m, pos);
        self.t[i] = t;
        self.valid[i] = 1.0;
        self.ids[i] = id;
        self.eps[i] = eps;
        self.w[i] = w;
        self.n[i] = 0;
        self.alpha_pt[i] = alpha_pt;
        self.lens[m] += 1;
        self.sync_rem(m, pos);
        debug_assert!(self.row_ordered(m));
    }

    /// Pop the head of row `m` if it has reached its alpha point.
    pub fn pop_if_ready(&mut self, m: usize) -> Option<JobId> {
        if self.lens[m] == 0 {
            return None;
        }
        let h = self.at(m, 0);
        if self.n[h] < self.alpha_pt[h] {
            return None;
        }
        let id = self.ids[h];
        // shift left
        for k in 1..self.lens[m] {
            let src = self.at(m, k);
            let dst = self.at(m, k - 1);
            self.t[dst] = self.t[src];
            self.rem_hi[dst] = self.rem_hi[src];
            self.rem_lo[dst] = self.rem_lo[src];
            self.valid[dst] = self.valid[src];
            self.ids[dst] = self.ids[src];
            self.eps[dst] = self.eps[src];
            self.w[dst] = self.w[src];
            self.n[dst] = self.n[src];
            self.alpha_pt[dst] = self.alpha_pt[src];
        }
        let last = self.at(m, self.lens[m] - 1);
        self.t[last] = 0.0;
        self.rem_hi[last] = 0.0;
        self.rem_lo[last] = 0.0;
        self.valid[last] = 0.0;
        self.ids[last] = 0;
        self.lens[m] -= 1;
        Some(id)
    }

    /// Virtual-work accrual: the head of every non-empty row gains one
    /// cycle; the accelerator-visible rem arrays are refreshed.
    pub fn accrue_heads(&mut self) {
        for m in 0..self.machines {
            if self.lens[m] > 0 {
                let h = self.at(m, 0);
                self.n[h] += 1;
                self.sync_rem(m, 0);
            }
        }
    }

    fn row_ordered(&self, m: usize) -> bool {
        (1..self.lens[m]).all(|k| self.t[self.at(m, k - 1)] >= self.t[self.at(m, k)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_pop_accrue_cycle() {
        let mut s = XlaScheduleState::new(2, 4);
        s.insert(0, 0, 1, 20.0, 10.0, 2.0, 5);
        s.insert(0, 1, 2, 5.0, 10.0, 0.5, 5);
        assert_eq!(s.len(0), 2);
        assert_eq!(s.total_jobs(), 2);
        // accrue 5 cycles -> head ready
        for _ in 0..5 {
            assert!(s.pop_if_ready(0).is_none());
            s.accrue_heads();
        }
        assert_eq!(s.rem_hi()[0], 5.0); // eps 10 - n 5
        assert_eq!(s.rem_lo()[0], 10.0); // w 20 - 5*2
        assert_eq!(s.pop_if_ready(0), Some(1));
        assert_eq!(s.len(0), 1);
        assert_eq!(s.t()[0], 0.5, "tail shifted to head");
        assert_eq!(s.valid()[1], 0.0, "freed slot invalid");
    }

    #[test]
    fn rows_are_independent() {
        let mut s = XlaScheduleState::new(3, 2);
        s.insert(1, 0, 9, 10.0, 10.0, 1.0, 1);
        assert_eq!(s.len(0), 0);
        assert_eq!(s.len(1), 1);
        assert_eq!(s.valid()[2], 1.0); // row 1 starts at flat index 2
        s.accrue_heads();
        assert_eq!(s.pop_if_ready(1), Some(9));
    }

    #[test]
    #[should_panic]
    fn full_row_rejects_insert() {
        let mut s = XlaScheduleState::new(1, 1);
        s.insert(0, 0, 1, 1.0, 10.0, 0.1, 1);
        s.insert(0, 0, 2, 1.0, 10.0, 0.1, 1);
    }
}
