//! Batched what-if cost analysis — amortizes PJRT dispatch over B
//! candidate jobs evaluated against a *fixed* schedule state. Used by
//! burst triage ("which of these 16 queued arrivals is cheapest to place
//! right now?") and capacity planning; the single-job engine remains the
//! decision path because the SOS algorithm assigns sequentially.
//!
//! The artifact (`batched_cost_{M}x{D}x{B}.hlo.txt`) evaluates the exact
//! ratio `T_j = W/eps` per probe (what-if analyses probe unquantized
//! candidates); for datapath-exact costs use [`super::XlaCostEngine`].

use crate::error::{Ctx, Result};
use crate::{bail, err};

use super::artifacts::ArtifactRegistry;
use super::state::XlaScheduleState;
use super::xla;

/// Compiled batched cost evaluator for one (M, D, B) configuration.
pub struct BatchedCostEngine {
    #[allow(dead_code)] // owns the PJRT runtime backing `exe`
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    machines: usize,
    depth: usize,
    batch: usize,
}

impl BatchedCostEngine {
    pub fn compile(registry: &ArtifactRegistry, m: usize, d: usize, b: usize) -> Result<Self> {
        if !registry.has_config(m, d) {
            bail!("no artifacts for {m}x{d}");
        }
        let path = registry
            .path(super::artifacts::ArtifactKind::StannicCost, m, d)
            .with_file_name(format!("batched_cost_{m}x{d}x{b}.hlo.txt"));
        let client = xla::PjRtClient::cpu().ctx("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| err!("non-utf8 path"))?,
        )
        .with_ctx(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).ctx("compiling batched module")?;
        Ok(BatchedCostEngine {
            client,
            exe,
            machines: m,
            depth: d,
            batch: b,
        })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Evaluate `batch` probes: weights [B], EPT matrix [B, M] (row
    /// major). Returns (cost [B][M], pos [B][M]).
    pub fn what_if(
        &self,
        state: &XlaScheduleState,
        weights: &[f32],
        epts: &[f32],
    ) -> Result<(Vec<Vec<f32>>, Vec<Vec<i32>>)> {
        let (b, m, d) = (self.batch, self.machines, self.depth);
        if weights.len() != b || epts.len() != b * m {
            bail!(
                "expected {b} weights and {}x{m} EPTs, got {} / {}",
                b,
                weights.len(),
                epts.len()
            );
        }
        let mk = |v: &[f32]| -> Result<xla::Literal> {
            Ok(xla::Literal::vec1(v).reshape(&[m as i64, d as i64])?)
        };
        let t = mk(state.t())?;
        let rem_hi = mk(state.rem_hi())?;
        let rem_lo = mk(state.rem_lo())?;
        let valid = mk(state.valid())?;
        let w = xla::Literal::vec1(weights);
        let e = xla::Literal::vec1(epts).reshape(&[b as i64, m as i64])?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[t, rem_hi, rem_lo, valid, w, e])?[0][0]
            .to_literal_sync()?;
        let (cost_l, pos_l) = result.to_tuple2()?;
        let flat_c = cost_l.to_vec::<f32>()?;
        let flat_p = pos_l.to_vec::<i32>()?;
        let cost = flat_c.chunks(m).map(|c| c.to_vec()).collect();
        let pos = flat_p.chunks(m).map(|c| c.to_vec()).collect();
        Ok((cost, pos))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{cost_of, Slot, VirtualSchedule};

    #[test]
    fn batched_what_if_matches_scalar_reference() {
        let Ok(reg) = ArtifactRegistry::open_default() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (m, d, b) = (5usize, 10usize, 16usize);
        let eng = BatchedCostEngine::compile(&reg, m, d, b).unwrap();

        // build matching states: XLA arrays + native schedules
        let mut state = XlaScheduleState::new(m, d);
        let mut native: Vec<VirtualSchedule> =
            (0..m).map(|_| VirtualSchedule::new(d)).collect();
        let jobs = [
            (0usize, 40.0f32, 20.0f32),
            (0, 10.0, 20.0),
            (2, 12.0, 30.0),
            (4, 99.0, 11.0),
        ];
        for (i, &(mach, w, eps)) in jobs.iter().enumerate() {
            let t = w / eps;
            let pos = native[mach].position_for(t);
            native[mach].insert(Slot {
                id: (i + 1) as u64,
                weight: w,
                ept: eps,
                wspt: t,
                alpha_pt: 5,
                n: 0,
            });
            state.insert(mach, pos, (i + 1) as u64, w, eps, t, 5);
        }

        let weights: Vec<f32> = (0..b).map(|i| 1.0 + 3.0 * i as f32).collect();
        let epts: Vec<f32> = (0..b * m).map(|i| 10.0 + (i % 37) as f32).collect();
        let (cost, pos) = eng.what_if(&state, &weights, &epts).unwrap();
        assert_eq!(cost.len(), b);

        for k in 0..b {
            for mach in 0..m {
                let w = weights[k];
                let e = epts[k * m + mach];
                let c = cost_of(&native[mach], w, e, w / e).expect("not full");
                assert!(
                    (cost[k][mach] - c.total()).abs() <= 1e-2 * c.total().max(1.0),
                    "probe {k} machine {mach}: {} vs {}",
                    cost[k][mach],
                    c.total()
                );
                assert_eq!(pos[k][mach] as usize, c.position, "probe {k} m {mach}");
            }
        }
    }

    #[test]
    fn shape_validation() {
        let Ok(reg) = ArtifactRegistry::open_default() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let eng = BatchedCostEngine::compile(&reg, 5, 10, 16).unwrap();
        let state = XlaScheduleState::new(5, 10);
        assert!(eng.what_if(&state, &[1.0; 3], &[10.0; 15]).is_err());
        assert!(BatchedCostEngine::compile(&reg, 5, 10, 99).is_err());
    }
}
