//! Tick-update engine: executes the `tick_{M}x{D}.hlo.txt` artifact —
//! the Phase III virtual-work accrual + alpha-release check, vectorized
//! over machines. The single-job [`super::XlaSosEngine`] performs these
//! transformations host-side (they are O(M) scalar updates); this engine
//! exists to validate the artifact end-to-end and to serve deployments
//! that keep the entire schedule state accelerator-resident.

use crate::error::{Ctx, Result};
use crate::{bail, err};

use super::artifacts::{ArtifactKind, ArtifactRegistry};
use super::xla;

/// Compiled Phase III step for one (M, D) configuration.
pub struct TickEngine {
    #[allow(dead_code)] // owns the PJRT runtime backing `exe`
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    machines: usize,
}

impl TickEngine {
    pub fn compile(registry: &ArtifactRegistry, m: usize, d: usize) -> Result<Self> {
        if !registry.has_config(m, d) {
            bail!("no artifacts for {m}x{d}");
        }
        let path = registry.path(ArtifactKind::Tick, m, d);
        let client = xla::PjRtClient::cpu().ctx("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| err!("non-utf8 path"))?,
        )
        .with_ctx(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).ctx("compiling tick module")?;
        Ok(TickEngine {
            client,
            exe,
            machines: m,
        })
    }

    /// One Phase III step over the head slots of every machine:
    /// `eps0`/`n0`/`valid0` are the heads' EPTs, virtual-work counts and
    /// occupancy; returns (n_next, pop flags), where pop means the head
    /// reaches `ceil(alpha * eps)` after this tick's accrual.
    pub fn step(
        &self,
        eps0: &[f32],
        n0: &[f32],
        valid0: &[f32],
        alpha: f32,
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        if eps0.len() != self.machines || n0.len() != self.machines || valid0.len() != self.machines
        {
            bail!("expected {} machines", self.machines);
        }
        let result = self.exe.execute::<xla::Literal>(&[
            xla::Literal::vec1(eps0),
            xla::Literal::vec1(n0),
            xla::Literal::vec1(valid0),
            xla::Literal::scalar(alpha),
        ])?[0][0]
            .to_literal_sync()?;
        let (n_next, pop) = result.to_tuple2()?;
        Ok((n_next.to_vec::<f32>()?, pop.to_vec::<i32>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tick_artifact_matches_host_semantics() {
        let Ok(reg) = ArtifactRegistry::open_default() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let eng = TickEngine::compile(&reg, 5, 10).unwrap();
        let eps0 = [20.0f32, 21.0, 10.0, 255.0, 40.0];
        let valid0 = [1.0f32, 1.0, 1.0, 0.0, 1.0];
        let alpha = 0.5f32;
        // host-side golden rule: n+valid; pop iff n_next >= ceil(alpha*eps)
        let mut n = [9.0f32, 9.0, 4.0, 0.0, 3.0];
        for _ in 0..4 {
            let (n_next, pop) = eng.step(&eps0, &n, &valid0, alpha).unwrap();
            for m in 0..5 {
                let want_n = n[m] + valid0[m];
                assert_eq!(n_next[m], want_n, "machine {m}");
                let want_pop = valid0[m] > 0.0
                    && want_n >= (alpha * eps0[m]).ceil();
                assert_eq!(pop[m] == 1, want_pop, "machine {m} n={want_n}");
            }
            n = [n_next[0], n_next[1], n_next[2], n_next[3], n_next[4]];
        }
    }

    #[test]
    fn tick_engine_validates_shapes() {
        let Ok(reg) = ArtifactRegistry::open_default() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let eng = TickEngine::compile(&reg, 5, 10).unwrap();
        assert!(eng.step(&[1.0; 3], &[0.0; 5], &[1.0; 5], 0.5).is_err());
    }
}
