//! Artifact registry: locates and loads the AOT-compiled HLO text
//! modules emitted by `python/compile/aot.py` (see `artifacts/
//! manifest.json`). HLO *text* is the interchange format — the crate's
//! XLA (xla_extension 0.5.1) rejects jax>=0.5 serialized protos with
//! 64-bit instruction ids; the text parser reassigns ids.

use std::path::{Path, PathBuf};

use crate::bail;
use crate::error::{Ctx, Result};
use crate::jsonio::Json;

/// Which compiled datapath an artifact implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// Systolic cost+argmin+pos (the Pallas stannic kernel, row-per-step).
    StannicCost,
    /// Fused all-rows systolic variant (single VMEM block).
    StannicFusedCost,
    /// Dense cost+argmin+pos (the Pallas hercules kernel).
    HerculesCost,
    /// Virtual-work update + pop flags.
    Tick,
}

impl ArtifactKind {
    fn file_name(&self, m: usize, d: usize) -> String {
        match self {
            ArtifactKind::StannicCost => format!("stannic_cost_{m}x{d}.hlo.txt"),
            ArtifactKind::StannicFusedCost => {
                format!("stannic_fused_cost_{m}x{d}.hlo.txt")
            }
            ArtifactKind::HerculesCost => format!("hercules_cost_{m}x{d}.hlo.txt"),
            ArtifactKind::Tick => format!("tick_{m}x{d}.hlo.txt"),
        }
    }
}

/// The artifact directory + its manifest.
#[derive(Debug, Clone)]
pub struct ArtifactRegistry {
    dir: PathBuf,
    configs: Vec<(usize, usize)>,
}

impl ArtifactRegistry {
    /// Open a registry; reads `manifest.json`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_ctx(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let json = Json::parse(&text).ctx("parsing manifest")?;
        let configs = json
            .get("configs")
            .map(|c| {
                c.items()
                    .iter()
                    .filter_map(|e| {
                        Some((
                            e.get("machines")?.as_usize()?,
                            e.get("depth")?.as_usize()?,
                        ))
                    })
                    .collect::<Vec<_>>()
            })
            .unwrap_or_default();
        if configs.is_empty() {
            bail!("manifest at {} lists no configs", manifest_path.display());
        }
        Ok(ArtifactRegistry { dir, configs })
    }

    /// Default location relative to the repo root / current directory.
    pub fn open_default() -> Result<Self> {
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            if Path::new(cand).join("manifest.json").exists() {
                return Self::open(cand);
            }
        }
        Self::open("artifacts")
    }

    pub fn configs(&self) -> &[(usize, usize)] {
        &self.configs
    }

    pub fn has_config(&self, m: usize, d: usize) -> bool {
        self.configs.contains(&(m, d))
    }

    /// Path of a specific artifact.
    pub fn path(&self, kind: ArtifactKind, m: usize, d: usize) -> PathBuf {
        self.dir.join(kind.file_name(m, d))
    }

    /// Load the HLO text of an artifact (existence-checked).
    pub fn load_text(&self, kind: ArtifactKind, m: usize, d: usize) -> Result<String> {
        let p = self.path(kind, m, d);
        std::fs::read_to_string(&p)
            .with_ctx(|| format!("artifact {} missing — run `make artifacts`", p.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_file_names() {
        assert_eq!(
            ArtifactKind::StannicCost.file_name(5, 10),
            "stannic_cost_5x10.hlo.txt"
        );
        assert_eq!(ArtifactKind::Tick.file_name(20, 10), "tick_20x10.hlo.txt");
    }

    #[test]
    fn open_reads_manifest_when_present() {
        // Only run the content checks when artifacts exist (CI may build
        // rust before python).
        if let Ok(reg) = ArtifactRegistry::open_default() {
            assert!(reg.has_config(5, 10));
            let text = reg
                .load_text(ArtifactKind::StannicCost, 5, 10)
                .expect("artifact listed in manifest");
            assert!(text.starts_with("HloModule"));
        }
    }

    #[test]
    fn open_missing_dir_errors() {
        assert!(ArtifactRegistry::open("/nonexistent/dir").is_err());
    }
}
