//! The XLA/PJRT-offloaded scheduling engine — this repo's stand-in for
//! the FPGA accelerator: the Phase II cost datapath (lowered from the
//! Pallas systolic kernel) runs inside a compiled XLA executable; the
//! Rust host holds the schedule state and performs the state
//! transformations the hardware would do in its PE writeback stage.
//! Python is never on this path — the executables were AOT-compiled by
//! `make artifacts`.

use crate::core::Job;
use crate::error::{Ctx, Result};
use crate::quant::Precision;
use crate::scheduler::{Assignment, TickOutcome, FULL_COST};
use crate::{bail, err};

use super::artifacts::{ArtifactKind, ArtifactRegistry};
use super::state::XlaScheduleState;
use super::xla;

/// Which compiled cost datapath to dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CostImpl {
    /// Per-row systolic kernel (one SMMU per grid step).
    Stannic,
    /// Fused all-rows systolic kernel (whole state in one VMEM block).
    StannicFused,
    /// Dense tree-adder analog (Hercules datapath).
    Hercules,
}

/// A compiled cost executable for one (M, D) configuration.
pub struct XlaCostEngine {
    client: xla::PjRtClient,
    cost_exe: xla::PjRtLoadedExecutable,
    machines: usize,
    depth: usize,
    /// Dispatch counter (for PCIe/dispatch overhead accounting).
    pub dispatches: u64,
    /// Preallocated input literals, refreshed in place per query
    /// (perf: avoids 7 allocations + an extra copy per dispatch — see
    /// EXPERIMENTS.md §Perf).
    inputs: Vec<xla::Literal>,
}

impl XlaCostEngine {
    /// Compile the cost artifact for (m, d) on the local CPU PJRT client.
    pub fn compile(
        registry: &ArtifactRegistry,
        imp: CostImpl,
        m: usize,
        d: usize,
    ) -> Result<Self> {
        if !registry.has_config(m, d) {
            bail!(
                "no artifact for {m}x{d}; available: {:?}",
                registry.configs()
            );
        }
        let kind = match imp {
            CostImpl::Stannic => ArtifactKind::StannicCost,
            CostImpl::StannicFused => ArtifactKind::StannicFusedCost,
            CostImpl::Hercules => ArtifactKind::HerculesCost,
        };
        let client = xla::PjRtClient::cpu().ctx("creating PJRT CPU client")?;
        let path = registry.path(kind, m, d);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| err!("non-utf8 path"))?,
        )
        .with_ctx(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let cost_exe = client.compile(&comp).ctx("compiling cost module")?;
        let f32t = xla::PrimitiveType::F32;
        let mat = || xla::Literal::create_from_shape(f32t, &[m, d]);
        let inputs = vec![
            mat(),                                           // t
            mat(),                                           // rem_hi
            mat(),                                           // rem_lo
            mat(),                                           // valid
            xla::Literal::create_from_shape(f32t, &[]),      // j_w
            xla::Literal::create_from_shape(f32t, &[m]),     // j_eps
            xla::Literal::create_from_shape(f32t, &[m]),     // j_t
        ];
        Ok(XlaCostEngine {
            client,
            cost_exe,
            machines: m,
            depth: d,
            dispatches: 0,
            inputs,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn config(&self) -> (usize, usize) {
        (self.machines, self.depth)
    }

    /// Dispatch one cost query: returns (cost [M], best machine, pos [M]).
    /// `j_t` is the per-machine stored (quantized) WSPT of the probe job —
    /// the hardware computes it once at job creation (Section 3.3 opt. 1).
    pub fn cost_select(
        &mut self,
        state: &XlaScheduleState,
        j_w: f32,
        j_eps: &[f32],
        j_t: &[f32],
    ) -> Result<(Vec<f32>, usize, Vec<i32>)> {
        debug_assert_eq!(j_eps.len(), self.machines);
        debug_assert_eq!(j_t.len(), self.machines);
        self.dispatches += 1;
        // refresh the preallocated input literals in place
        self.inputs[0].copy_raw_from(state.t())?;
        self.inputs[1].copy_raw_from(state.rem_hi())?;
        self.inputs[2].copy_raw_from(state.rem_lo())?;
        self.inputs[3].copy_raw_from(state.valid())?;
        self.inputs[4].copy_raw_from(&[j_w])?;
        self.inputs[5].copy_raw_from(j_eps)?;
        self.inputs[6].copy_raw_from(j_t)?;

        let result = self
            .cost_exe
            .execute::<xla::Literal>(&self.inputs)?[0][0]
            .to_literal_sync()?;
        let (cost_l, best_l, pos_l) = result.to_tuple3()?;
        let cost = cost_l.to_vec::<f32>()?;
        let best = best_l.get_first_element::<i32>()? as usize;
        let pos = pos_l.to_vec::<i32>()?;
        Ok((cost, best, pos))
    }
}

/// A full SOS engine whose Phase II cost query is offloaded to the XLA
/// executable. Produces schedules identical to the golden engine
/// (integration-tested) — the host-side state transformations implement
/// the same pop/insert/accrue semantics.
pub struct XlaSosEngine {
    cost: XlaCostEngine,
    state: XlaScheduleState,
    alpha: f32,
    precision: Precision,
    pending: std::collections::VecDeque<Job>,
    tick_no: u64,
}

impl XlaSosEngine {
    pub fn new(
        registry: &ArtifactRegistry,
        imp: CostImpl,
        machines: usize,
        depth: usize,
        alpha: f32,
        precision: Precision,
    ) -> Result<Self> {
        Ok(XlaSosEngine {
            cost: XlaCostEngine::compile(registry, imp, machines, depth)?,
            state: XlaScheduleState::new(machines, depth),
            alpha,
            precision,
            pending: Default::default(),
            tick_no: 0,
        })
    }

    pub fn dispatches(&self) -> u64 {
        self.cost.dispatches
    }

    pub fn machines(&self) -> usize {
        self.cost.machines
    }

    pub fn submit(&mut self, job: Job) {
        self.pending.push_back(job);
    }

    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.state.total_jobs() == 0
    }

    /// One scheduler tick with golden semantics: pop, cost+insert
    /// (offloaded), accrue.
    pub fn tick(&mut self, arrival: Option<&Job>) -> Result<TickOutcome> {
        self.tick_no += 1;
        if let Some(j) = arrival {
            self.pending.push_back(j.clone());
        }
        let mut out = TickOutcome::default();

        // pop alpha-ready heads (host-side state transformation)
        for m in 0..self.cost.machines {
            if let Some(id) = self.state.pop_if_ready(m) {
                out.released.push((id, m));
            }
        }

        // offloaded Phase II
        if !self.pending.is_empty() {
            if self.state.any_free() {
                let job = self.pending.pop_front().expect("non-empty");
                // quantize per machine: probe EPT and stored-WSPT vectors
                let mut j_eps = vec![0.0f32; self.cost.machines];
                let mut j_t = vec![0.0f32; self.cost.machines];
                for m in 0..self.cost.machines {
                    let (_, eq, tq) = self.precision.q_job(job.weight, job.ept[m]);
                    j_eps[m] = eq;
                    j_t[m] = tq;
                }
                let j_w = self.precision.q_weight(job.weight);
                let (cost_vec, best, pos) =
                    self.cost.cost_select(&self.state, j_w, &j_eps, &j_t)?;
                if cost_vec[best] >= FULL_COST {
                    bail!("accelerator selected a full machine");
                }
                let (wq, eq, tq) = self.precision.q_job(job.weight, job.ept[best]);
                self.state.insert(
                    best,
                    pos[best] as usize,
                    job.id,
                    wq,
                    eq,
                    tq,
                    (self.alpha * eq).ceil() as u32,
                );
                out.assigned = Some(Assignment {
                    job: job.id,
                    machine: best,
                    position: pos[best] as usize,
                    cost: cost_vec[best],
                });
            } else {
                out.stalled = true;
            }
        }

        // accrue virtual work on heads
        self.state.accrue_heads();
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::MachinePark;
    use crate::scheduler::SosEngine;
    use crate::workload::{generate_trace, WorkloadSpec};

    fn registry() -> Option<ArtifactRegistry> {
        ArtifactRegistry::open_default().ok()
    }

    /// Full schedule parity golden vs XLA-offloaded engine. Skipped when
    /// artifacts have not been built (e.g. pure-rust CI stage).
    #[test]
    fn xla_engine_schedule_parity() {
        let Some(reg) = registry() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let park = MachinePark::paper_m1_m5();
        let trace = generate_trace(&WorkloadSpec::default(), &park, 60, 5);
        let mut golden = SosEngine::new(5, 10, 0.5, Precision::Int8);
        let mut xla_eng =
            XlaSosEngine::new(&reg, CostImpl::Stannic, 5, 10, 0.5, Precision::Int8).unwrap();

        let mut events = trace.events().iter().peekable();
        for t in 1..=100_000u64 {
            while events.peek().is_some_and(|e| e.tick <= t) {
                let j = events.next().unwrap().job.clone().unwrap();
                golden.submit(j.clone());
                xla_eng.submit(j);
            }
            let g = golden.tick(None);
            let x = xla_eng.tick(None).unwrap();
            assert_eq!(g.released, x.released, "tick {t}");
            assert_eq!(
                g.assigned.as_ref().map(|a| (a.job, a.machine, a.position)),
                x.assigned.as_ref().map(|a| (a.job, a.machine, a.position)),
                "tick {t}"
            );
            if golden.is_idle() && xla_eng.is_idle() && events.peek().is_none() {
                break;
            }
        }
        assert!(golden.is_idle() && xla_eng.is_idle());
        assert!(xla_eng.dispatches() >= 60);
    }

    #[test]
    fn hercules_artifact_matches_stannic_artifact() {
        let Some(reg) = registry() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut a = XlaCostEngine::compile(&reg, CostImpl::Stannic, 5, 10).unwrap();
        let mut b = XlaCostEngine::compile(&reg, CostImpl::Hercules, 5, 10).unwrap();
        let mut state = XlaScheduleState::new(5, 10);
        // seed some jobs
        state.insert(0, 0, 1, 40.0, 20.0, 2.0, 10);
        state.insert(0, 1, 2, 10.0, 20.0, 0.5, 10);
        state.insert(3, 0, 3, 9.0, 30.0, 0.3, 15);
        let j_eps = [15.0f32, 20.0, 25.0, 30.0, 35.0];
        let j_t: Vec<f32> = j_eps.iter().map(|e| 12.0 / e).collect();
        let (ca, ba, pa) = a.cost_select(&state, 12.0, &j_eps, &j_t).unwrap();
        let (cb, bb, pb) = b.cost_select(&state, 12.0, &j_eps, &j_t).unwrap();
        assert_eq!(ba, bb);
        assert_eq!(pa, pb);
        for (x, y) in ca.iter().zip(&cb) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }
}
