//! Offline stub of the `xla` PJRT bindings the runtime layer was written
//! against. The real crate (xla_extension 0.5.1) is unavailable in this
//! environment, so every entry point that would reach a PJRT runtime
//! returns a descriptive error instead; state-free constructors (literal
//! shapes) succeed so the call sites type-check and unit-test. The
//! artifact registry fails before any of this is reached in practice
//! (no `make artifacts` output exists offline), and the integration
//! tests skip the XLA paths when artifacts are absent.

use crate::error::{Error, Result};

fn unavailable(what: &str) -> Error {
    Error::msg(format!(
        "XLA/PJRT backend unavailable in this offline build ({what}); \
         use a software engine instead (native|stannic|hercules)"
    ))
}

/// Element type selector (only F32 is used by the cost datapath).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
}

/// Host-side literal: shape bookkeeping only in the stub.
#[derive(Debug, Clone)]
pub struct Literal {
    elems: usize,
}

impl Literal {
    pub fn create_from_shape(_ty: PrimitiveType, dims: &[usize]) -> Literal {
        Literal {
            elems: dims.iter().product(),
        }
    }

    pub fn vec1(v: &[f32]) -> Literal {
        Literal { elems: v.len() }
    }

    pub fn scalar(_v: f32) -> Literal {
        Literal { elems: 1 }
    }

    pub fn copy_raw_from(&mut self, src: &[f32]) -> Result<()> {
        if src.len() == self.elems {
            Ok(())
        } else {
            Err(Error::msg(format!(
                "literal shape mismatch: {} elements copied into {}",
                src.len(),
                self.elems
            )))
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let elems: usize = dims.iter().map(|&d| d as usize).product();
        if elems == self.elems {
            Ok(Literal { elems })
        } else {
            Err(Error::msg(format!(
                "reshape {:?} does not match {} elements",
                dims, self.elems
            )))
        }
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(unavailable("Literal::get_first_element"))
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(unavailable("Literal::to_tuple2"))
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        Err(unavailable("Literal::to_tuple3"))
    }
}

/// Parsed HLO module handle.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation wrapper around a parsed module.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_accounting() {
        let mut l = Literal::create_from_shape(PrimitiveType::F32, &[2, 3]);
        assert!(l.copy_raw_from(&[0.0; 6]).is_ok());
        assert!(l.copy_raw_from(&[0.0; 5]).is_err());
        let s = Literal::create_from_shape(PrimitiveType::F32, &[]);
        assert_eq!(s.elems, 1, "scalar shape");
        assert!(Literal::vec1(&[1.0; 6]).reshape(&[2, 3]).is_ok());
        assert!(Literal::vec1(&[1.0; 6]).reshape(&[4, 2]).is_err());
    }

    #[test]
    fn runtime_entry_points_error_gracefully() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("offline"), "{e}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::vec1(&[1.0]).to_vec::<f32>().is_err());
    }
}
