//! PJRT/XLA runtime — the accelerator-offload path.
//!
//! Loads the HLO-text artifacts AOT-compiled by `python/compile/aot.py`
//! (`make artifacts`), compiles them on the PJRT CPU client via the
//! `xla` crate, and executes the Phase II cost datapath from the Rust
//! host. This is the reproduction's analog of the paper's host->FPGA
//! offload: Python never runs at request time.

mod artifacts;
mod batched;
mod engine;
mod state;
mod tick;
mod xla;

pub use artifacts::{ArtifactKind, ArtifactRegistry};
pub use batched::BatchedCostEngine;
pub use engine::{CostImpl, XlaCostEngine, XlaSosEngine};
pub use state::XlaScheduleState;
pub use tick::TickEngine;
