//! Measurement harness (criterion is unavailable offline): warmup +
//! timed iterations with mean / median / p95 / min reporting, plus a
//! tiny table printer shared by the figure-regeneration benches.

use std::time::{Duration, Instant};

/// One measured statistic set, in nanoseconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Measurement {
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub total: Duration,
}

impl Measurement {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns / 1e9
    }

    /// Throughput in ops/sec given `ops` units of work per iteration.
    pub fn ops_per_sec(&self, ops: f64) -> f64 {
        ops / self.mean_secs()
    }
}

/// Benchmark options.
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    pub warmup_iters: u64,
    pub sample_iters: u64,
    /// Hard wall-clock budget; sampling stops early once exceeded.
    pub max_time: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup_iters: 3,
            sample_iters: 30,
            max_time: Duration::from_secs(10),
        }
    }
}

impl BenchOpts {
    pub fn quick() -> Self {
        BenchOpts {
            warmup_iters: 1,
            sample_iters: 5,
            max_time: Duration::from_secs(3),
        }
    }

    /// Resolve options from the bench driver's argv: quick in smoke mode
    /// ([`smoke_mode`]), full-effort otherwise.
    pub fn from_args() -> Self {
        if smoke_mode() {
            Self::quick()
        } else {
            Self::default()
        }
    }
}

/// True when the bench driver was invoked with `--bench-smoke` (the CI
/// smoke flag shared by all 8 harness-less benches) or the legacy
/// `--quick`. CI runs one bench this way so the drivers cannot rot
/// unnoticed without paying full paper-effort wall time.
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--bench-smoke" || a == "--quick")
}

/// Run `f` repeatedly and collect timing statistics. `f` should perform
/// one logical unit of work; use `std::hint::black_box` inside to keep
/// the optimizer honest.
pub fn bench<F: FnMut()>(opts: BenchOpts, mut f: F) -> Measurement {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let started = Instant::now();
    let mut samples: Vec<f64> = Vec::with_capacity(opts.sample_iters as usize);
    for _ in 0..opts.sample_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
        if started.elapsed() > opts.max_time && samples.len() >= 3 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    Measurement {
        iters: n as u64,
        mean_ns: mean,
        median_ns: samples[n / 2],
        p95_ns: samples[(((n - 1) as f64) * 0.95) as usize],
        min_ns: samples[0],
        total: started.elapsed(),
    }
}

/// Fixed-width table printer for bench/report output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String], widths: &[usize]| {
            for (c, cell) in cells.iter().enumerate() {
                out.push_str(&format!("{:<w$}  ", cell, w = widths[c]));
            }
            out.push('\n');
        };
        line(&mut out, &self.headers, &widths);
        out.push_str(&format!(
            "{}\n",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  ")
        ));
        for row in &self.rows {
            line(&mut out, row, &widths);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Humanized duration for report output.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut count = 0u64;
        let m = bench(BenchOpts::quick(), || {
            count += 1;
            std::hint::black_box(count);
        });
        assert_eq!(count, m.iters + BenchOpts::quick().warmup_iters);
        assert!(m.mean_ns >= 0.0);
        assert!(m.min_ns <= m.median_ns);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["config", "cycles"]);
        t.row(vec!["5x10".into(), "50".into()]);
        t.row(vec!["10x20".into(), "75".into()]);
        let r = t.render();
        assert!(r.contains("config"));
        assert!(r.lines().count() == 4);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 us");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }
}
