//! `stannic` — the launcher: schedule workloads with any engine,
//! regenerate every figure of the paper, verify cross-implementation
//! parity, and inspect hardware-model estimates.

use stannic::artifact::{self, diff_records, resolve_threshold, Artifact, Diffable, DiffOpts};
use stannic::cli::{usage, Args, FlagSpec};
use stannic::config::RunConfig;
use stannic::coordinator::{
    serve, serve_sources, ArrivalSource, LinkModel, ServeOpts, ServeRecord, ServeReport,
};
use stannic::core::MachinePark;
use stannic::engine::EngineId;
use stannic::error::{Ctx, Result};
use stannic::faults::FaultSpec;
use stannic::quant::Precision;
use stannic::report::{self, Effort};
use stannic::scheduler::SosEngine;
use stannic::sim::{hercules::HerculesSim, stannic::StannicSim, lockstep_verify};
use stannic::sweep::{run_sweep, SweepConfig, SweepRecord};
use stannic::workload::{generate_trace, Trace, WorkloadSpec};
use stannic::{bail, err};

fn flag_specs() -> Vec<FlagSpec> {
    vec![
        FlagSpec::new("machines", "machine count (default 5 = paper M1-M5)", true),
        FlagSpec::new("depth", "virtual-schedule depth (default 10)", true),
        FlagSpec::new("alpha", "alpha release factor in (0,1] (default 0.5)", true),
        FlagSpec::new("jobs", "number of jobs (default 1000)", true),
        FlagSpec::new("seed", "workload seed (default 42)", true),
        // the accepted-name lists come straight from the engine registry
        // so the help can never drift from the parser
        FlagSpec::new("engine", format!("scheduling engine: {} (default sos)", EngineId::USAGE), true),
        FlagSpec::new("precision", "FP32|FP16|INT8|INT4|Mixed (default INT8)", true),
        FlagSpec::new("workload", "even|memory|compute|homogeneous|bursty|heavy (default even)", true),
        FlagSpec::new("trace", "replay a trace file instead of generating", true),
        FlagSpec::new("save-trace", "write the generated trace to a file", true),
        FlagSpec::new("threads", "sweep worker threads (default: one per core)", true),
        FlagSpec::new("engines", format!("sweep engine list, comma-separated from: {}, or 'all' for every artifact-free engine", EngineId::USAGE), true),
        FlagSpec::new("sources", "serve: concurrent arrival-source threads (default 1; >1 rotates steady/bursty/heavy mixes)", true),
        FlagSpec::new("batch", "serve: max arrivals admitted per scheduler tick (default 0 = unbatched)", true),
        FlagSpec::new("queue-depth", "serve: bounded depth of arrival/merge/worker queues (default 256)", true),
        FlagSpec::new("shards", "serve: split the park across K independent scheduling shards (default 1 = unsharded; sos engine only)", true),
        FlagSpec::new("faults", "serve/sweep: seeded fault spec, e.g. 'down=1@40+30,slow=0@20+40x4,storm=6@60,seed=7'", true),
        FlagSpec::new("link-width", "serve/sweep: interconnect width in bytes/tick (default 0 = unbounded; admission throttles on backpressure tickets)", true),
        FlagSpec::new("quick", "reduced-effort runs for smoke testing", false),
        FlagSpec::new("scale", "sweep the Agon-scale grid (parks up to 140 machines)", false),
        FlagSpec::new("record", "persist results (sweep: BENCH_<label>.json, serve: serve record) at this path", true),
        FlagSpec::new("label", "label stored in the record artifact (default 'sweep'/'serve')", true),
        FlagSpec::new("threshold", "sweep/serve diff: relative perf drop that fails (default 0.25 or $STANNIC_PERF_THRESHOLD)", true),
        FlagSpec::new("raw-ratios", "sweep/serve diff: disable median-shift normalization", false),
        FlagSpec::new("fail-on-shift", "sweep/serve diff: also fail on a whole-grid median slowdown (same-host A/B runs)", false),
        FlagSpec::new("json", "emit machine-readable JSON where supported", false),
    ]
}

fn commands() -> Vec<(&'static str, &'static str)> {
    vec![
        ("serve", "run the online coordinator pipeline (or `serve diff <old.json> <new.json>`)"),
        ("report", "regenerate a paper figure: fig7|fig15|fig16a|fig16b|fig17|fig18|fig19|all"),
        ("verify", "lockstep-verify both microarchitecture sims against the golden engine"),
        ("hw", "print resource/routing/power estimates for a configuration"),
        ("gen", "generate and print (or save) a workload trace"),
        ("stats", "summarize a workload trace (composition, bursts, EPT spread)"),
        ("sweep", "run the parallel multi-engine scenario sweep (or `sweep diff <old.json> <new.json>`)"),
    ]
}

fn parse_precision(name: &str) -> Result<Precision> {
    Ok(match name.to_ascii_uppercase().as_str() {
        "FP32" => Precision::Fp32,
        "FP16" => Precision::Fp16,
        "INT8" => Precision::Int8,
        "INT4" => Precision::Int4,
        "MIXED" => Precision::Mixed,
        other => bail!("unknown precision {other}"),
    })
}

fn parse_workload(name: &str) -> Result<WorkloadSpec> {
    Ok(match name {
        "even" => WorkloadSpec::even(),
        "memory" => WorkloadSpec::memory_skewed(),
        "compute" => WorkloadSpec::compute_skewed(),
        "homogeneous" => WorkloadSpec::homogeneous_memory(),
        "bursty" => WorkloadSpec::bursty(),
        "heavy" | "heavy-tailed" => WorkloadSpec::heavy_tailed(),
        other => bail!("unknown workload {other}"),
    })
}

fn config_from(args: &Args) -> Result<RunConfig> {
    let mut cfg = RunConfig::default();
    cfg.machines = args.usize_flag("machines", cfg.machines)?;
    cfg.depth = args.usize_flag("depth", cfg.depth)?;
    cfg.alpha = args.f32_flag("alpha", cfg.alpha)?;
    cfg.jobs = args.usize_flag("jobs", cfg.jobs)?;
    cfg.seed = args.u64_flag("seed", cfg.seed)?;
    cfg.engine = EngineId::parse(args.str_flag("engine", "sos"))?;
    cfg.precision = parse_precision(args.str_flag("precision", "INT8"))?;
    cfg.workload = parse_workload(args.str_flag("workload", "even"))?;
    Ok(cfg)
}

fn load_or_generate(args: &Args, cfg: &RunConfig) -> Result<Trace> {
    if let Some(path) = args.flag("trace") {
        let text = std::fs::read_to_string(path)?;
        return Trace::from_text(&text).with_ctx(|| format!("parsing {path}"));
    }
    let trace = generate_trace(&cfg.workload, &cfg.park(), cfg.jobs, cfg.seed);
    if let Some(path) = args.flag("save-trace") {
        std::fs::write(path, trace.to_text())?;
        eprintln!("trace written to {path}");
    }
    Ok(trace)
}

fn serve_opts_from(args: &Args) -> Result<ServeOpts> {
    let defaults = ServeOpts::default();
    let queue_depth = args
        .usize_flag("queue-depth", defaults.queue_depth)?
        .max(1);
    let batch = args.usize_flag("batch", 0)?;
    let shards = args.usize_flag("shards", defaults.shards)?;
    if shards == 0 {
        bail!("--shards must be >= 1");
    }
    let mut opts = ServeOpts::new()
        .with_queue_depth(queue_depth)
        .with_batch(if batch == 0 { usize::MAX } else { batch })
        .with_shards(shards);
    if let Some(spec) = args.flag("faults") {
        opts =
            opts.with_faults(FaultSpec::parse(spec).with_ctx(|| "parsing --faults".to_string())?);
    }
    // width 0 is the unbounded default: no link is constructed and the
    // pipeline stays byte-identical to the pre-link coordinator
    let link_width = args.u64_flag("link-width", 0)?;
    if link_width > 0 {
        opts = opts.with_link(LinkModel::with_width(link_width));
    }
    Ok(opts)
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.positionals.first().is_some_and(|p| p == "diff") {
        return cmd_artifact_diff::<ServeRecord>(args);
    }
    let cfg = config_from(args)?;
    let opts = serve_opts_from(args)?;
    let n_sources = args.usize_flag("sources", 1)?;
    if n_sources == 0 {
        bail!("--sources must be >= 1");
    }
    // --shards 1 stays on the plain engine (the sharded front end's
    // K = 1 form is bit-identical anyway — pinned by tests/sharding.rs)
    let engine = if opts.shards > 1 {
        cfg.engine.build_sharded(
            opts.shards,
            cfg.machines,
            cfg.depth,
            cfg.alpha,
            cfg.precision,
        )?
    } else {
        cfg.engine
            .build(cfg.machines, cfg.depth, cfg.alpha, cfg.precision)?
    };
    let report: ServeReport = if n_sources == 1 {
        let trace = load_or_generate(args, &cfg)?;
        serve(engine, &trace, &opts)?
    } else {
        if args.flag("trace").is_some() {
            bail!("--trace replays a single recorded stream; drop --sources to use it");
        }
        if args.flag("save-trace").is_some() {
            bail!(
                "--save-trace archives the single generated stream; with --sources > 1 \
                 the workload is synthesized per source (re-create it from the same \
                 --seed/--jobs instead)"
            );
        }
        let sources = ArrivalSource::standard_mix(
            &cfg.workload,
            cfg.machines,
            cfg.jobs,
            cfg.seed,
            n_sources,
        );
        serve_sources(engine, sources, &opts)?
    };
    let m = &report.metrics;
    println!("engine            : {}", report.engine);
    println!("jobs completed    : {}", report.completions.len());
    println!("scheduler ticks   : {}", report.ticks);
    println!("stalled iterations: {}", report.stalls);
    println!("arrival sources   : {}", report.sources.len());
    for src in &report.sources {
        println!(
            "  source {:<12}: {} jobs, {} enqueue stalls",
            src.name, src.jobs, src.enqueue_stalls
        );
    }
    println!(
        "merge queue depth : p50 {} / p99 {} / max {}",
        report.merge_depth.p50(),
        report.merge_depth.p99(),
        report.merge_depth.max()
    );
    if report.batch_sizes.count() > 0 {
        println!(
            "admission batches : p50 {} / p99 {} / max {} jobs/tick",
            report.batch_sizes.p50(),
            report.batch_sizes.p99(),
            report.batch_sizes.max()
        );
    }
    println!("jobs per machine  : {:?}", m.jobs_per_machine);
    println!("avg latency       : {:.2} ticks", m.avg_latency);
    println!(
        "latency p50/95/99 : {} / {} / {} ticks (max {})",
        report.latency_hist.p50(),
        report.latency_hist.p95(),
        report.latency_hist.p99(),
        report.latency_hist.max()
    );
    println!("fairness (Jain)   : {:.3}", m.fairness);
    println!("load balance CV   : {:.3}", m.load_balance_cv);
    println!("throughput        : {:.3} jobs/tick", m.throughput);
    println!(
        "PCIe              : {} txns, {} bytes, {:.1} us",
        report.pcie.transactions,
        report.pcie.bytes,
        report.pcie.total_ns() / 1000.0
    );
    if let Some(l) = report.link.as_ref() {
        println!(
            "link              : {} B/tick, latency {} ticks, window {} ({} issued / {} completed)",
            l.width, l.latency, l.window, l.issued, l.completed
        );
        println!(
            "link stalls       : {} total ({} link-busy, {} window-full, {} response-stalled)",
            l.total_stalls(),
            l.stall_busy,
            l.stall_window,
            l.stall_response
        );
        println!(
            "link occupancy    : p50 {} / max {} in flight; ticket wait p50 {} / p95 {} ticks",
            l.occupancy.p50(),
            l.occupancy.max(),
            l.wait.p50(),
            l.wait.p95()
        );
    }
    if report.accel_cycles > 0 {
        println!(
            "accelerator       : {} cycles = {:.3} ms at 371.47 MHz",
            report.accel_cycles,
            report.accel_cycles as f64 / stannic::hw::CLOCK_HZ * 1e3
        );
    }
    if let Some(f) = report.faults.as_ref() {
        println!("fault spec        : {}", report.fault_key);
        println!(
            "fault events      : {} down / {} up / {} slow / {} storm ({} jobs injected)",
            f.downs, f.ups, f.slow_events, f.storms, f.injected_jobs
        );
        println!(
            "fault evictions   : {} jobs re-queued, {} cycles of work lost, {} arrivals dropped",
            f.evicted_jobs, f.work_lost_cycles, f.dropped_arrivals
        );
        if f.requeue_latency.count() > 0 {
            println!(
                "re-queue latency  : p50 {} / p99 {} / max {} ticks",
                f.requeue_latency.p50(),
                f.requeue_latency.p99(),
                f.requeue_latency.max()
            );
        }
        println!(
            "utilization dip   : {} degraded ticks, {} machine-ticks down (max {} down at once)",
            f.degraded_ticks, f.down_machine_ticks, f.max_concurrent_down
        );
    }
    if let Some(t) = report.shards.as_ref() {
        println!(
            "shards            : {} parks, {} rebalance moves at {} barriers, imbalance CV {:.3}",
            t.shards(),
            t.rebalance_moves,
            t.rebalance_events,
            t.imbalance_cv
        );
        for (i, sh) in t.per_shard.iter().enumerate() {
            println!(
                "  shard {i:<11}: machines {}..{}, {} routed, {} completed, +{}/-{} rebalanced, digest {}",
                sh.first_machine,
                sh.first_machine + sh.machines - 1,
                sh.routed,
                sh.completed,
                sh.moved_in,
                sh.moved_out,
                sh.digest
            );
        }
    }
    if let Some(p) = report.portfolio.as_ref() {
        println!(
            "portfolio         : {} windows of {} ticks, {} policy switches, live policy {}",
            p.windows, p.window_ticks, p.switches, p.live
        );
        let wins = p
            .wins
            .iter()
            .map(|&(name, w)| format!("{name}={w}"))
            .collect::<Vec<String>>()
            .join(" ");
        println!("  window wins     : {wins}");
        for e in &p.switch_log {
            println!(
                "  switch          : window {} @ tick {}: {} -> {}",
                e.window, e.tick, e.from, e.to
            );
        }
        println!(
            "  shadow replay   : {} ticks, {} submissions, max score spread {:.2}, switch digest {}",
            p.replay_ticks,
            p.replay_submissions,
            p.max_score_spread,
            p.switch_digest()
        );
    }
    println!("host wall         : {:.2?}", report.wall);
    if args.has("json") {
        println!("{}", report.json_summary());
    }
    if let Some(path) = args.flag("record") {
        let label = args.str_flag("label", "serve");
        let record = ServeRecord::from_report(label, &report);
        // artifact::store parse-back-verifies, keeping CI's artifact
        // check honest: a record that does not round-trip is a hard error
        artifact::store(path, &record)?;
        eprintln!(
            "recorded serve run (label '{label}', {} sources) to {path}",
            record.sources.len()
        );
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let effort = if args.has("quick") { Effort::Quick } else { Effort::Paper };
    let seed = args.u64_flag("seed", 42)?;
    let which = args
        .positionals
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let run_one = |name: &str| -> Result<()> {
        match name {
            "fig7" => print!("{}", report::fig7::render(&report::fig7::run(effort, seed))),
            "fig15" => print!("{}", report::fig15::render(&report::fig15::run(effort, seed))),
            "fig16a" => print!("{}", report::fig16::render_16a(&report::fig16::run_16a(effort, seed))),
            "fig16b" => print!("{}", report::fig16::render_16b(&report::fig16::run_16b(effort, seed))),
            "fig17" => print!("{}", report::fig17::render(&report::fig17::run(effort, seed))),
            "fig18" => print!("{}", report::fig18::render(&report::fig18::run())),
            "fig19" => print!("{}", report::fig19::render(&report::fig19::run(effort, seed))),
            "ablations" => print!(
                "{}",
                report::ablations::render(
                    &report::ablations::alpha_sweep(effort, seed),
                    &report::ablations::depth_sweep(effort, seed),
                    &report::ablations::adder_ablation(),
                    &report::ablations::batch_interface_sweep(effort, seed),
                )
            ),
            other => bail!("unknown figure {other}"),
        }
        Ok(())
    };
    if which == "all" {
        for name in [
            "fig7", "fig15", "fig16a", "fig16b", "fig17", "fig18", "fig19", "ablations",
        ] {
            println!("==================== {name} ====================");
            run_one(name)?;
            println!();
        }
    } else {
        run_one(which)?;
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let trace = load_or_generate(args, &cfg)?;
    let max_ticks = 50_000_000;

    let mut golden = SosEngine::new(cfg.machines, cfg.depth, cfg.alpha, cfg.precision);
    let mut sim = StannicSim::new(cfg.machines, cfg.depth, cfg.alpha, cfg.precision);
    let ticks = lockstep_verify(&mut sim, &mut golden, &trace, max_ticks)
        .map_err(|e| err!("STANNIC sim diverged: {e}"))?;
    println!(
        "STANNIC sim : identical schedule over {} jobs ({} ticks, {} cycles, decision latency {} cyc)",
        trace.n_jobs(),
        ticks,
        stannic::sim::ArchSim::stats(&sim).total_cycles(),
        stannic::sim::ArchSim::stats(&sim).decision_latency,
    );

    let mut golden = SosEngine::new(cfg.machines, cfg.depth, cfg.alpha, cfg.precision);
    let mut sim = HerculesSim::new(cfg.machines, cfg.depth, cfg.alpha, cfg.precision);
    let ticks = lockstep_verify(&mut sim, &mut golden, &trace, max_ticks)
        .map_err(|e| err!("HERCULES sim diverged: {e}"))?;
    println!(
        "HERCULES sim: identical schedule over {} jobs ({} ticks, {} cycles, decision latency {} cyc)",
        trace.n_jobs(),
        ticks,
        stannic::sim::ArchSim::stats(&sim).total_cycles(),
        stannic::sim::ArchSim::stats(&sim).decision_latency,
    );
    println!("parity OK");
    Ok(())
}

fn cmd_hw(args: &Args) -> Result<()> {
    use stannic::hw::{power, resources, routing, U55C};
    let m = args.usize_flag("machines", 10)?;
    let d = args.usize_flag("depth", 10)?;
    let h = resources::hercules(m, d);
    let s = resources::stannic(m, d);
    println!("configuration {m}x{d} on Alveo U55C @ 371.47 MHz");
    println!(
        "HERCULES: {} LUT / {} FF, routing: {:?}, est {:.2} W, decision latency {} cyc",
        h.luts,
        h.ffs,
        routing::route_hercules(m, d, &U55C),
        power::watts(h, m, d, 1),
        stannic::sim::hercules::timing::decision_latency(m, d),
    );
    println!(
        "STANNIC : {} LUT / {} FF, routing: {:?}, est {:.2} W, decision latency {} cyc",
        s.luts,
        s.ffs,
        routing::route_stannic(m, d, &U55C),
        power::watts(s, m, d, 2),
        stannic::sim::stannic::timing::decision_latency(m, d),
    );
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let trace = generate_trace(&cfg.workload, &cfg.park(), cfg.jobs, cfg.seed);
    match args.flag("save-trace") {
        Some(path) => {
            std::fs::write(path, trace.to_text())?;
            println!(
                "wrote {} jobs over {} ticks to {path}",
                trace.n_jobs(),
                trace.horizon()
            );
        }
        None => print!("{}", trace.to_text()),
    }
    Ok(())
}

fn cmd_stats(args: &Args) -> Result<()> {
    use stannic::core::JobNature;
    let cfg = config_from(args)?;
    let trace = load_or_generate(args, &cfg)?;
    let n = trace.n_jobs();
    let horizon = trace.horizon();
    let mut by_nature = [0usize; 3];
    let mut w_min = f32::MAX;
    let mut w_max = f32::MIN;
    let mut e_min = f32::MAX;
    let mut e_max = f32::MIN;
    let mut per_tick = std::collections::BTreeMap::<u64, usize>::new();
    for j in trace.jobs() {
        by_nature[match j.nature {
            JobNature::Compute => 0,
            JobNature::Memory => 1,
            JobNature::Mixed => 2,
        }] += 1;
        w_min = w_min.min(j.weight);
        w_max = w_max.max(j.weight);
        for &e in &j.ept {
            e_min = e_min.min(e);
            e_max = e_max.max(e);
        }
        *per_tick.entry(j.arrival).or_default() += 1;
    }
    let max_burst = per_tick.values().copied().max().unwrap_or(0);
    let active_ticks = per_tick.len();
    println!("jobs            : {n}");
    println!("horizon         : {horizon} ticks ({active_ticks} arrival ticks)");
    println!(
        "composition     : {:.1}% compute / {:.1}% memory / {:.1}% mixed",
        100.0 * by_nature[0] as f64 / n as f64,
        100.0 * by_nature[1] as f64 / n as f64,
        100.0 * by_nature[2] as f64 / n as f64
    );
    println!("max burst       : {max_burst} jobs/tick");
    println!("weight range    : [{w_min}, {w_max}]");
    println!("EPT range       : [{e_min}, {e_max}]");
    let gaps: Vec<u64> = per_tick
        .keys()
        .copied()
        .collect::<Vec<_>>()
        .windows(2)
        .map(|w| w[1] - w[0])
        .collect();
    if let Some(max_gap) = gaps.iter().max() {
        println!("max idle gap    : {max_gap} ticks");
    }
    Ok(())
}

/// `sweep diff` / `serve diff <old.json> <new.json>`: compare two
/// persisted artifacts through the shared [`stannic::artifact::diff`]
/// core and fail (non-zero exit) on per-cell regressions beyond the
/// threshold, parity breaks, unmeasured cells, or missing baseline
/// coverage; `--fail-on-shift` additionally gates on a whole-grid
/// median slowdown (meaningful for same-host A/B runs).
fn cmd_artifact_diff<R: Artifact + Diffable>(args: &Args) -> Result<()> {
    let (old_path, new_path) = match (args.positionals.get(1), args.positionals.get(2)) {
        (Some(a), Some(b)) => (a.as_str(), b.as_str()),
        _ => bail!(
            "usage: {} diff <old.json> <new.json> [--threshold F] [--raw-ratios] [--fail-on-shift]",
            R::KIND
        ),
    };
    let old: R = artifact::load(old_path)?;
    let new: R = artifact::load(new_path)?;
    let opts = DiffOpts {
        threshold: resolve_threshold(args.flag("threshold"))?,
        normalize: !args.has("raw-ratios"),
        fail_on_shift: args.has("fail-on-shift"),
    };
    let report = diff_records(&old, &new, &opts);
    print!("{}", report.render());
    report.gate()
}

fn cmd_sweep(args: &Args) -> Result<()> {
    if args.positionals.first().is_some_and(|p| p == "diff") {
        return cmd_artifact_diff::<SweepRecord>(args);
    }
    let mut cfg = if args.has("scale") {
        SweepConfig::at_scale()
    } else if args.has("quick") {
        SweepConfig::quick()
    } else {
        SweepConfig::default()
    };
    cfg.jobs = args.usize_flag("jobs", cfg.jobs)?;
    cfg.seed = args.u64_flag("seed", cfg.seed)?;
    cfg.depth = args.usize_flag("depth", cfg.depth)?;
    cfg.threads = args.usize_flag("threads", cfg.threads)?;
    // The shared single-value flags narrow the corresponding grid axis.
    if args.flag("machines").is_some() {
        cfg.machine_counts = vec![args.usize_flag("machines", 5)?];
    }
    if args.flag("alpha").is_some() {
        cfg.alphas = vec![args.f32_flag("alpha", 0.5)?];
    }
    if let Some(name) = args.flag("precision") {
        cfg.precisions = vec![parse_precision(name)?];
    }
    if let Some(name) = args.flag("workload") {
        cfg.workloads = vec![(name.to_string(), parse_workload(name)?)];
    }
    if args.flag("link-width").is_some() {
        // 0 clears the axis (no link cells); any other value pins it
        let w = args.u64_flag("link-width", 0)?;
        cfg.link_widths = if w == 0 { Vec::new() } else { vec![w] };
    }
    if let Some(list) = args.flag("engines").or_else(|| args.flag("engine")) {
        cfg.engines = EngineId::parse_list(list)?;
    }
    if let Some(spec) = args.flag("faults") {
        let parsed = FaultSpec::parse(spec).with_ctx(|| "parsing --faults".to_string())?;
        if parsed.has_drops() {
            bail!(
                "drop= clauses cut live arrival sources; the sweep replays fixed \
                 traces (use `serve --faults` for source dropout)"
            );
        }
        // store the canonical rendering so cell keys and artifact fault
        // keys are identical no matter how the user spelled the spec
        cfg.faults = if parsed.is_empty() { Vec::new() } else { vec![parsed.render()] };
    }
    if cfg.engines.iter().any(|e| !e.is_software()) {
        bail!(
            "the sweep fans across artifact-free engines only; 'xla' needs a PJRT \
             runtime (drive it via `serve --engine xla` instead)"
        );
    }
    let started = std::time::Instant::now();
    let results = run_sweep(&cfg);
    // The rendered report is deterministic (identical for any worker
    // count); wall-clock and pool info go to stderr only.
    print!("{}", results.render());
    match results.check_parity() {
        Ok(groups) => println!("\ncross-engine schedule parity OK ({groups} comparisons)"),
        Err(e) => bail!("cross-engine parity violated: {e}"),
    }
    if let Some(path) = args.flag("record") {
        let label = args.str_flag("label", "sweep");
        let record = SweepRecord::from_results(label, &results);
        artifact::store(path, &record)?;
        eprintln!(
            "recorded {} cells (label '{label}') to {path}",
            record.cells.len()
        );
    }
    eprintln!(
        "sweep wall time: {:.2?} on {} worker thread(s)",
        started.elapsed(),
        results.threads
    );
    Ok(())
}

fn main() {
    let specs = flag_specs();
    let args = match Args::parse(std::env::args().skip(1), &specs) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprint!("{}", usage("stannic", &commands(), &specs));
            std::process::exit(2);
        }
    };
    let result = match args.command.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("report") => cmd_report(&args),
        Some("verify") => cmd_verify(&args),
        Some("hw") => cmd_hw(&args),
        Some("gen") => cmd_gen(&args),
        Some("stats") => cmd_stats(&args),
        Some("sweep") => cmd_sweep(&args),
        Some(other) => {
            eprintln!("unknown command: {other}\n");
            eprint!("{}", usage("stannic", &commands(), &specs));
            std::process::exit(2);
        }
        None => {
            eprint!("{}", usage("stannic", &commands(), &specs));
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
    let _ = MachinePark::paper_m1_m5(); // keep prelude types exercised in docs builds
}
