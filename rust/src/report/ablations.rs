//! Ablation studies for the design choices DESIGN.md calls out — not
//! paper figures, but quantitative backing for decisions the paper makes
//! in prose:
//!
//! * **alpha sweep** — the alpha_J release policy (Phase III): early
//!   release (small alpha) cuts queue latency but surrenders reordering
//!   opportunity; late release maximizes the virtual schedule's value.
//! * **depth sweep** — V_i capacity: shallow schedules stall under
//!   bursts, deep schedules cost Hercules latency (and area in both).
//! * **tree adder vs accumulator** — Section 4.1.2: "an accumulator-based
//!   design would reduce area, but would require multiple cycles per
//!   computation"; we quantify both sides of that trade.
//! * **batched host interface** — Section 5's memory-interface critique:
//!   Hercules's X-entry batching delays arrivals; we sweep the batch
//!   size X and measure the induced queue-latency penalty.

use crate::bench::Table;
use crate::cluster::{Cluster, ClusterConfig, SosCluster};
use crate::core::MachinePark;
use crate::hw::resources::PAPER_CONFIGS;
use crate::quant::Precision;
use crate::sim::hercules::cost_calc::tree_stages;
use crate::workload::{generate_trace, WorkloadSpec};

use super::Effort;

/// One row of the alpha sweep.
#[derive(Debug, Clone)]
pub struct AlphaRow {
    pub alpha: f32,
    pub avg_latency: f64,
    pub fairness: f64,
    pub load_cv: f64,
    pub makespan: u64,
}

/// Sweep the alpha_J release point.
pub fn alpha_sweep(effort: Effort, seed: u64) -> Vec<AlphaRow> {
    let n_jobs = effort.scale(300, 1500);
    let park = MachinePark::paper_m1_m5();
    let trace = generate_trace(&WorkloadSpec::default(), &park, n_jobs, seed);
    [0.1f32, 0.25, 0.5, 0.75, 1.0]
        .iter()
        .map(|&alpha| {
            let mut s = SosCluster::new(5, 10, alpha, Precision::Int8);
            let sum = Cluster::new(park.clone(), ClusterConfig::default()).run(&mut s, &trace);
            AlphaRow {
                alpha,
                avg_latency: sum.metrics.avg_latency,
                fairness: sum.metrics.fairness,
                load_cv: sum.metrics.load_balance_cv,
                makespan: sum.makespan,
            }
        })
        .collect()
}

/// One row of the depth sweep.
#[derive(Debug, Clone)]
pub struct DepthRow {
    pub depth: usize,
    pub stalled_ticks: u64,
    pub avg_latency: f64,
    pub hercules_latency_cycles: u64,
    pub stannic_latency_cycles: u64,
    pub hercules_luts: u64,
    pub stannic_luts: u64,
}

/// Sweep the virtual-schedule depth under bursty traffic.
pub fn depth_sweep(effort: Effort, seed: u64) -> Vec<DepthRow> {
    use crate::scheduler::SosEngine;
    let n_jobs = effort.scale(300, 1500);
    let park = MachinePark::paper_m1_m5();
    let spec = WorkloadSpec::default().with_burst(5, crate::workload::BurstType::Uniform);
    let trace = generate_trace(&spec, &park, n_jobs, seed);
    [2usize, 5, 10, 20, 40]
        .iter()
        .map(|&depth| {
            // stall measurement on the raw engine (event-jumping drive:
            // stalls only happen on backlogged ticks, which are never
            // skipped, so the count is identical to per-tick driving)
            let mut engine = SosEngine::new(5, depth, 0.5, Precision::Int8);
            let mut stalled = 0u64;
            crate::scheduler::drive_trace(&mut engine, &trace, u64::MAX, |_, out| {
                stalled += out.stalled as u64;
            })
            .expect("depth-sweep run did not drain");
            // schedule quality through the executor
            let mut s = SosCluster::new(5, depth, 0.5, Precision::Int8);
            let sum = Cluster::new(park.clone(), ClusterConfig::default()).run(&mut s, &trace);
            DepthRow {
                depth,
                stalled_ticks: stalled,
                avg_latency: sum.metrics.avg_latency,
                hercules_latency_cycles: crate::sim::hercules::timing::decision_latency(5, depth),
                stannic_latency_cycles: crate::sim::stannic::timing::decision_latency(5, depth),
                hercules_luts: crate::hw::resources::hercules(5, depth).luts,
                stannic_luts: crate::hw::resources::stannic(5, depth).luts,
            }
        })
        .collect()
}

/// Tree-adder vs accumulator Cost Calculator (Section 4.1.2's trade).
#[derive(Debug, Clone)]
pub struct AdderRow {
    pub config: (usize, usize),
    /// Tree adder: stages * stage-cost, single issue per query.
    pub tree_cycles: u64,
    /// Accumulator: one add per schedule slot, sequential.
    pub accumulator_cycles: u64,
    /// LUT cost of the N-1 adder tree vs a single accumulator.
    pub tree_luts: u64,
    pub accumulator_luts: u64,
}

/// Quantify the paper's tree-adder decision across the comparison
/// configurations. The accumulator saves (N-2) adders per tree but
/// serializes the reduction to N cycles.
pub fn adder_ablation() -> Vec<AdderRow> {
    const LUT_PER_ADDER: u64 = 90; // matches hw::resources tree node cost
    const CYCLES_PER_STAGE: u64 = 8; // matches sim::hercules::timing
    PAPER_CONFIGS
        .iter()
        .map(|&(m, d)| AdderRow {
            config: (m, d),
            tree_cycles: CYCLES_PER_STAGE * tree_stages(d) as u64,
            accumulator_cycles: CYCLES_PER_STAGE * d as u64,
            tree_luts: (d as u64 - 1) * LUT_PER_ADDER * 2 * m as u64, // TAH+TAL per machine
            accumulator_luts: LUT_PER_ADDER * 2 * m as u64,
        })
        .collect()
}

/// Batched host interface (Section 5): arrivals are staged in an X-entry
/// table and released to the scheduler only when the batch fills,
/// delaying early jobs in each batch.
#[derive(Debug, Clone)]
pub struct BatchRow {
    pub batch: usize,
    pub avg_latency: f64,
    pub makespan: u64,
}

pub fn batch_interface_sweep(effort: Effort, seed: u64) -> Vec<BatchRow> {
    use crate::workload::TraceEvent;
    let n_jobs = effort.scale(300, 1500);
    let park = MachinePark::paper_m1_m5();
    let trace = generate_trace(&WorkloadSpec::default(), &park, n_jobs, seed);
    [1usize, 4, 16, 64]
        .iter()
        .map(|&batch| {
            // re-time arrivals through the X-entry staging table: a job
            // becomes visible only when its batch is complete
            let mut events: Vec<TraceEvent> = Vec::with_capacity(n_jobs);
            let mut staged: Vec<TraceEvent> = Vec::with_capacity(batch);
            for e in trace.events() {
                staged.push(e.clone());
                if staged.len() == batch {
                    let release_tick = staged.last().expect("non-empty").tick;
                    for mut s in staged.drain(..) {
                        s.tick = release_tick;
                        if let Some(j) = &mut s.job {
                            // arrival timestamp stays at creation time, so
                            // the staging delay shows up as queue latency
                            let _ = j;
                        }
                        events.push(s);
                    }
                }
            }
            for s in staged.drain(..) {
                events.push(s);
            }
            let batched = crate::workload::Trace::new(events, park.len());
            let mut s = SosCluster::new(5, 10, 0.5, Precision::Int8);
            let sum = Cluster::new(park.clone(), ClusterConfig::default()).run(&mut s, &batched);
            BatchRow {
                batch,
                avg_latency: sum.metrics.avg_latency,
                makespan: sum.makespan,
            }
        })
        .collect()
}

pub fn render(
    alphas: &[AlphaRow],
    depths: &[DepthRow],
    adders: &[AdderRow],
    batches: &[BatchRow],
) -> String {
    let mut out = String::new();

    out.push_str("Ablation A — alpha_J release policy\n");
    let mut t = Table::new(&["alpha", "avg latency", "fairness", "load CV", "makespan"]);
    for r in alphas {
        t.row(vec![
            format!("{:.2}", r.alpha),
            format!("{:.1}", r.avg_latency),
            format!("{:.3}", r.fairness),
            format!("{:.3}", r.load_cv),
            r.makespan.to_string(),
        ]);
    }
    out.push_str(&t.render());

    out.push_str("\nAblation B — virtual-schedule depth under uniform bursts\n");
    let mut t = Table::new(&[
        "depth",
        "stalled ticks",
        "avg latency",
        "H cycles",
        "S cycles",
        "H LUTs",
        "S LUTs",
    ]);
    for r in depths {
        t.row(vec![
            r.depth.to_string(),
            r.stalled_ticks.to_string(),
            format!("{:.1}", r.avg_latency),
            r.hercules_latency_cycles.to_string(),
            r.stannic_latency_cycles.to_string(),
            r.hercules_luts.to_string(),
            r.stannic_luts.to_string(),
        ]);
    }
    out.push_str(&t.render());

    out.push_str("\nAblation C — tree adder vs accumulator Cost Calculator (Sec 4.1.2)\n");
    let mut t = Table::new(&[
        "config",
        "tree cycles",
        "accum cycles",
        "tree LUTs",
        "accum LUTs",
    ]);
    for r in adders {
        t.row(vec![
            format!("{}x{}", r.config.0, r.config.1),
            r.tree_cycles.to_string(),
            r.accumulator_cycles.to_string(),
            r.tree_luts.to_string(),
            r.accumulator_luts.to_string(),
        ]);
    }
    out.push_str(&t.render());

    out.push_str("\nAblation D — batched host interface (Sec 5 critique)\n");
    let mut t = Table::new(&["batch X", "avg latency", "makespan"]);
    for r in batches {
        t.row(vec![
            r.batch.to_string(),
            format!("{:.1}", r.avg_latency),
            r.makespan.to_string(),
        ]);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_trades_latency_for_schedule_quality() {
        let rows = alpha_sweep(Effort::Quick, 7);
        assert_eq!(rows.len(), 5);
        // smaller alpha releases earlier -> lower queue latency
        assert!(
            rows[0].avg_latency <= rows[4].avg_latency,
            "alpha 0.1 {} vs 1.0 {}",
            rows[0].avg_latency,
            rows[4].avg_latency
        );
    }

    #[test]
    fn shallow_schedules_stall_more() {
        let rows = depth_sweep(Effort::Quick, 7);
        let d2 = rows.iter().find(|r| r.depth == 2).unwrap();
        let d40 = rows.iter().find(|r| r.depth == 40).unwrap();
        assert!(d2.stalled_ticks >= d40.stalled_ticks);
        // Hercules pays for depth in cycles; Stannic does not
        assert!(d40.hercules_latency_cycles > d2.hercules_latency_cycles);
        assert_eq!(d40.stannic_latency_cycles, d2.stannic_latency_cycles);
    }

    #[test]
    fn tree_adder_wins_cycles_accumulator_wins_area() {
        for r in adder_ablation() {
            assert!(r.tree_cycles < r.accumulator_cycles);
            assert!(r.tree_luts > r.accumulator_luts);
        }
    }

    #[test]
    fn larger_batches_inflate_latency() {
        let rows = batch_interface_sweep(Effort::Quick, 7);
        let b1 = rows.iter().find(|r| r.batch == 1).unwrap();
        let b64 = rows.iter().find(|r| r.batch == 64).unwrap();
        assert!(
            b64.avg_latency > b1.avg_latency,
            "batch 64 {} vs unbatched {}",
            b64.avg_latency,
            b1.avg_latency
        );
    }

    #[test]
    fn render_contains_all_sections() {
        let text = render(
            &alpha_sweep(Effort::Quick, 3),
            &depth_sweep(Effort::Quick, 3),
            &adder_ablation(),
            &batch_interface_sweep(Effort::Quick, 3),
        );
        for s in ["Ablation A", "Ablation B", "Ablation C", "Ablation D"] {
            assert!(text.contains(s));
        }
    }
}
