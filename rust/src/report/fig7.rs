//! Fig. 7 — the quantization study (Section 4.2): per-scheme bit table,
//! schedule-distribution divergence vs FP32, % error in alpha release
//! points, % error in WSPT ratios.

use crate::bench::Table;
use crate::core::MachinePark;
use crate::quant::{
    alpha_error_pct, distribution_divergence, wspt_error_pct, Precision, QuantErrorReport,
};
use crate::scheduler::{drive_trace, SosEngine};
use crate::workload::{generate_trace, Trace, WorkloadSpec};

use super::Effort;

/// Run the SOS engine at `precision` over a trace; return jobs/machine.
/// Tickless: the event-jumping driver executes only the ticks that can
/// assign or release, which is what makes regenerating this figure at
/// paper effort cheap.
fn schedule_distribution(trace: &Trace, precision: Precision, depth: usize) -> Vec<usize> {
    let m = trace.machines();
    let mut engine = SosEngine::new(m, depth, 0.5, precision);
    let mut counts = vec![0usize; m];
    drive_trace(&mut engine, trace, 50_000_000, |_, out| {
        if let Some(a) = &out.assigned {
            counts[a.machine] += 1;
        }
    })
    .expect("fig7 run did not drain");
    counts
}

/// The full Fig. 7 study.
pub fn run(effort: Effort, seed: u64) -> Vec<QuantErrorReport> {
    let park = MachinePark::paper_m1_m5();
    let n_jobs = effort.scale(400, 4000);
    let trace = generate_trace(&WorkloadSpec::default(), &park, n_jobs, seed);

    // (weight, ept) sample population for the attribute-error metrics
    let samples: Vec<(f32, f32)> = trace
        .jobs()
        .flat_map(|j| j.ept.iter().map(|&e| (j.weight, e)))
        .collect();

    let fp32_dist = schedule_distribution(&trace, Precision::Fp32, 10);
    Precision::ALL
        .iter()
        .map(|&p| {
            let dist = if p == Precision::Fp32 {
                fp32_dist.clone()
            } else {
                schedule_distribution(&trace, p, 10)
            };
            QuantErrorReport {
                precision: p,
                wspt_err_pct: wspt_error_pct(p, &samples),
                alpha_err_pct: alpha_error_pct(p, 0.5, &samples),
                distribution_div: distribution_divergence(&dist, &fp32_dist),
                jobs_per_machine: dist,
            }
        })
        .collect()
}

/// Render the paper's Fig. 7 panels as tables.
pub fn render(reports: &[QuantErrorReport]) -> String {
    let mut out = String::new();
    out.push_str("Fig 7a — quantization schemes (bits per attribute W/eps/T)\n");
    let mut t = Table::new(&["scheme", "W", "eps", "T", "note"]);
    for r in reports {
        let (w, e, tt) = r.precision.attribute_bits();
        let note = match r.precision {
            Precision::Int8 => "selected (green in paper)",
            Precision::Fp32 => "accuracy baseline",
            _ => "",
        };
        t.row(vec![
            r.precision.name().into(),
            w.to_string(),
            e.to_string(),
            tt.to_string(),
            note.into(),
        ]);
    }
    out.push_str(&t.render());

    out.push_str("\nFig 7b — scheduled job distribution per machine (vs FP32)\n");
    let mut t = Table::new(&["scheme", "M1", "M2", "M3", "M4", "M5", "L1 divergence"]);
    for r in reports {
        let mut row: Vec<String> = vec![r.precision.name().into()];
        row.extend(r.jobs_per_machine.iter().map(|c| c.to_string()));
        row.push(format!("{:.4}", r.distribution_div));
        t.row(row);
    }
    out.push_str(&t.render());

    out.push_str("\nFig 7c — % error in alpha_J release point\n");
    let mut t = Table::new(&["scheme", "% err"]);
    for r in reports {
        t.row(vec![r.precision.name().into(), format!("{:.3}", r.alpha_err_pct)]);
    }
    out.push_str(&t.render());

    out.push_str("\nFig 7d — % error in WSPT ratio\n");
    let mut t = Table::new(&["scheme", "% err"]);
    for r in reports {
        t.row(vec![r.precision.name().into(), format!("{:.3}", r.wspt_err_pct)]);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp32_reference_has_zero_divergence() {
        let reports = run(Effort::Quick, 7);
        assert_eq!(reports.len(), 5);
        let fp32 = &reports[0];
        assert_eq!(fp32.precision, Precision::Fp32);
        assert_eq!(fp32.distribution_div, 0.0);
        assert_eq!(fp32.wspt_err_pct, 0.0);
    }

    #[test]
    fn int8_tracks_fp32_distribution_closely() {
        // Section 4.2: "INT8 quantization closely replicates the FP32
        // job distribution" and has lower alpha error than INT4/Mixed.
        let reports = run(Effort::Quick, 7);
        let by = |p: Precision| reports.iter().find(|r| r.precision == p).unwrap();
        let int8 = by(Precision::Int8);
        let int4 = by(Precision::Int4);
        let mixed = by(Precision::Mixed);
        assert!(int8.distribution_div < 0.15, "{}", int8.distribution_div);
        // Section 4.2: "INT8 demonstrates lower alpha_J error than INT4
        // and Mixed quantization. Consequently, the latter schemes
        // release jobs for execution earlier than intended."
        assert!(int8.alpha_err_pct < int4.alpha_err_pct);
        assert!(int8.alpha_err_pct < mixed.alpha_err_pct);
        assert!(int8.distribution_div <= int4.distribution_div);
    }

    #[test]
    fn render_contains_all_schemes() {
        let reports = run(Effort::Quick, 3);
        let text = render(&reports);
        for p in Precision::ALL {
            assert!(text.contains(p.name()));
        }
        assert!(text.contains("Fig 7d"));
    }
}
