//! Fig. 15 — SOSA effectiveness over 50 Monte-Carlo workloads
//! (Section 8.1): (a) average jobs per machine at run-fraction
//! checkpoints, (b) scheduler throughput per workload.

use crate::bench::Table;
use crate::core::MachinePark;
use crate::quant::Precision;
use crate::scheduler::{drive_trace, SosEngine};
use crate::workload::{generate_trace, sample_specs};

use super::Effort;

/// Fractions of the run at which machine utilization is sampled.
pub const FRACTIONS: [f64; 4] = [0.25, 0.5, 0.75, 1.0];

#[derive(Debug, Clone)]
pub struct Fig15 {
    /// `[fraction][machine]` — average cumulative jobs assigned.
    pub avg_jobs_at_fraction: Vec<Vec<f64>>,
    /// Per-workload throughput (jobs scheduled per tick).
    pub throughput: Vec<f64>,
    pub workloads: usize,
    pub machines: usize,
}

/// One workload's trajectory: cumulative jobs/machine at each fraction +
/// throughput.
fn run_one(spec_seed: (usize, u64), n_jobs: usize) -> (Vec<Vec<usize>>, f64) {
    let (idx, seed) = spec_seed;
    let park = MachinePark::paper_m1_m5();
    let spec = &sample_specs(50, seed)[idx];
    let trace = generate_trace(spec, &park, n_jobs, seed ^ (idx as u64) << 8);
    let mut engine = SosEngine::new(5, 10, 0.5, Precision::Int8);
    let mut counts = vec![0usize; 5];
    let mut checkpoints: Vec<Vec<usize>> = Vec::with_capacity(FRACTIONS.len());
    let mut assigned = 0usize;
    let mut next_frac = 0usize;
    // Scheduler throughput (Fig. 15b) = assignments per *active* tick —
    // ticks where the scheduler had work pending. This measures the
    // scheduler's own decision rate (the paper's near-constant jobs per
    // clock tick), independent of workload sparsity (idle periods). A
    // tick had backlog exactly when it assigned or stalled, so the
    // event-jumping driver counts the same active ticks the per-tick
    // loop did (skipped ticks never have backlog).
    let mut active_ticks = 0u64;
    drive_trace(&mut engine, &trace, 50_000_000, |_, out| {
        if out.assigned.is_some() || out.stalled {
            active_ticks += 1;
        }
        if let Some(a) = &out.assigned {
            counts[a.machine] += 1;
            assigned += 1;
            while next_frac < FRACTIONS.len()
                && assigned as f64 >= FRACTIONS[next_frac] * n_jobs as f64
            {
                checkpoints.push(counts.clone());
                next_frac += 1;
            }
        }
    })
    .expect("fig15 run did not drain");
    while checkpoints.len() < FRACTIONS.len() {
        checkpoints.push(counts.clone());
    }
    (checkpoints, assigned as f64 / active_ticks.max(1) as f64)
}

pub fn run(effort: Effort, seed: u64) -> Fig15 {
    let workloads = effort.scale(8, 50);
    let n_jobs = effort.scale(200, 1000);
    let mut avg = vec![vec![0.0f64; 5]; FRACTIONS.len()];
    let mut throughput = Vec::with_capacity(workloads);
    for w in 0..workloads {
        let (checkpoints, tput) = run_one((w, seed), n_jobs);
        for (f, counts) in checkpoints.iter().enumerate() {
            for (m, &c) in counts.iter().enumerate() {
                avg[f][m] += c as f64 / workloads as f64;
            }
        }
        throughput.push(tput);
    }
    Fig15 {
        avg_jobs_at_fraction: avg,
        throughput,
        workloads,
        machines: 5,
    }
}

pub fn render(f: &Fig15) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Fig 15a — avg jobs per machine at run fractions ({} workloads)\n",
        f.workloads
    ));
    let mut t = Table::new(&["fraction", "M1", "M2", "M3", "M4", "M5"]);
    for (i, frac) in FRACTIONS.iter().enumerate() {
        let mut row = vec![format!("{frac:.2}")];
        row.extend(f.avg_jobs_at_fraction[i].iter().map(|v| format!("{v:.1}")));
        t.row(row);
    }
    out.push_str(&t.render());

    out.push_str("\nFig 15b — scheduler throughput per workload (jobs/tick)\n");
    let mean = f.throughput.iter().sum::<f64>() / f.throughput.len() as f64;
    let min = f.throughput.iter().cloned().fold(f64::MAX, f64::min);
    let max = f.throughput.iter().cloned().fold(f64::MIN, f64::max);
    out.push_str(&format!(
        "workloads={} mean={mean:.4} min={min:.4} max={max:.4} (near-constant across workloads)\n",
        f.throughput.len()
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_are_cumulative() {
        let f = run(Effort::Quick, 11);
        for m in 0..5 {
            for i in 1..FRACTIONS.len() {
                assert!(
                    f.avg_jobs_at_fraction[i][m] >= f.avg_jobs_at_fraction[i - 1][m],
                    "machine {m} fraction {i}"
                );
            }
        }
        // all jobs assigned by fraction 1.0
        let total: f64 = f.avg_jobs_at_fraction[3].iter().sum();
        assert!((total - 200.0).abs() < 1e-6);
    }

    #[test]
    fn throughput_is_stable_across_workloads() {
        // Section 8.1: "throughput ... almost remains constant across all
        // the 50 workloads". Our Monte-Carlo sampler spans a wider
        // burst/idle envelope than the paper's appears to (saturating
        // workloads throttle the decision rate to the alpha-release drain
        // rate), so we assert same-order stability rather than
        // near-equality; see EXPERIMENTS.md §Fig15.
        let f = run(Effort::Quick, 11);
        let mean = f.throughput.iter().sum::<f64>() / f.throughput.len() as f64;
        for tp in &f.throughput {
            assert!(*tp > mean / 3.0 && *tp < mean * 3.0, "tp {tp} vs mean {mean}");
        }
    }

    #[test]
    fn best_machines_highly_utilized() {
        // Section 8.1: M1, M3, M4 (the Best machines) consistently carry
        // the most load.
        let f = run(Effort::Quick, 11);
        let final_ = &f.avg_jobs_at_fraction[3];
        let best = final_[0] + final_[2] + final_[3];
        let worst = final_[1] + final_[4];
        assert!(best > worst, "best {best} vs worst {worst}");
        // but no starvation
        for (m, &v) in final_.iter().enumerate() {
            assert!(v > 0.0, "machine {m} starved");
        }
    }
}
