//! Fig. 19 — SOSA vs the four baseline schedulers under five workload
//! scenarios (Section 8.4): job distribution and average latency per
//! machine for SOS, RR, Greedy, WSRR, WSG.

use crate::baselines::{GreedyScheduler, RoundRobin, WsGreedy, WsRoundRobin};
use crate::bench::Table;
use crate::cluster::{Cluster, ClusterConfig, OnlineScheduler, SosCluster};
use crate::core::MachinePark;
use crate::metrics::ScheduleMetrics;
use crate::quant::Precision;
use crate::workload::{generate_trace, WorkloadSpec};

use super::Effort;

/// The five experiment scenarios of Section 8.4.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// (1) evenly distributed workload (35/35/30).
    Even,
    /// (2) memory-skewed workload (70/10/20).
    MemorySkewed,
    /// (3) compute-skewed workload (70/10/20 normalized; see
    /// EXPERIMENTS.md note on the paper's 70+10+30).
    ComputeSkewed,
    /// (4) fully homogeneous memory-intensive workload.
    HomogeneousWorkload,
    /// (5) compute workload on homogeneous (CPU-only) machines.
    HomogeneousMachines,
}

impl Scenario {
    pub const ALL: [Scenario; 5] = [
        Scenario::Even,
        Scenario::MemorySkewed,
        Scenario::ComputeSkewed,
        Scenario::HomogeneousWorkload,
        Scenario::HomogeneousMachines,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Even => "even (35/35/30)",
            Scenario::MemorySkewed => "memory-skewed (70% mem)",
            Scenario::ComputeSkewed => "compute-skewed (70% compute)",
            Scenario::HomogeneousWorkload => "homogeneous workload (all mem)",
            Scenario::HomogeneousMachines => "homogeneous machines (CPU-only)",
        }
    }

    pub fn spec(&self) -> WorkloadSpec {
        match self {
            Scenario::Even => WorkloadSpec::even(),
            Scenario::MemorySkewed => WorkloadSpec::memory_skewed(),
            Scenario::ComputeSkewed => WorkloadSpec::compute_skewed(),
            Scenario::HomogeneousWorkload => WorkloadSpec::homogeneous_memory(),
            Scenario::HomogeneousMachines => WorkloadSpec::homogeneous_compute(),
        }
    }

    pub fn park(&self) -> MachinePark {
        match self {
            Scenario::HomogeneousMachines => MachinePark::homogeneous_cpu(5),
            _ => MachinePark::paper_m1_m5(),
        }
    }
}

/// Result for one (scenario, scheduler) cell.
#[derive(Debug, Clone)]
pub struct Cell {
    pub scheduler: &'static str,
    pub metrics: ScheduleMetrics,
}

#[derive(Debug, Clone)]
pub struct ScenarioResult {
    pub scenario: Scenario,
    pub cells: Vec<Cell>,
}

fn run_sched<S: OnlineScheduler>(
    mut s: S,
    scenario: Scenario,
    n_jobs: usize,
    seed: u64,
) -> Cell {
    let park = scenario.park();
    let trace = generate_trace(&scenario.spec(), &park, n_jobs, seed);
    let sum = Cluster::new(park, ClusterConfig::default()).run(&mut s, &trace);
    debug_assert_eq!(sum.completed, n_jobs, "{} did not drain", sum.scheduler);
    Cell {
        scheduler: sum.scheduler,
        metrics: sum.metrics,
    }
}

pub fn run_scenario(scenario: Scenario, effort: Effort, seed: u64) -> ScenarioResult {
    let n_jobs = effort.scale(250, 2000);
    let m = scenario.park().len();
    let cells = vec![
        run_sched(
            SosCluster::new(m, 10, 0.5, Precision::Int8),
            scenario,
            n_jobs,
            seed,
        ),
        run_sched(RoundRobin::new(), scenario, n_jobs, seed),
        run_sched(GreedyScheduler::new(), scenario, n_jobs, seed),
        run_sched(WsRoundRobin::new(), scenario, n_jobs, seed),
        run_sched(WsGreedy::new(), scenario, n_jobs, seed),
    ];
    ScenarioResult { scenario, cells }
}

pub fn run(effort: Effort, seed: u64) -> Vec<ScenarioResult> {
    Scenario::ALL
        .iter()
        .map(|&s| run_scenario(s, effort, seed))
        .collect()
}

pub fn render(results: &[ScenarioResult]) -> String {
    let mut out = String::new();
    for r in results {
        out.push_str(&format!("\nFig 19 — scenario: {}\n", r.scenario.name()));
        let mut t = Table::new(&[
            "scheduler",
            "jobs/machine",
            "avg latency",
            "fairness (Jain)",
            "load CV",
        ]);
        for c in &r.cells {
            t.row(vec![
                c.scheduler.into(),
                format!("{:?}", c.metrics.jobs_per_machine),
                format!("{:.1}", c.metrics.avg_latency),
                format!("{:.3}", c.metrics.fairness),
                format!("{:.3}", c.metrics.load_balance_cv),
            ]);
        }
        out.push_str(&t.render());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell<'a>(r: &'a ScenarioResult, name: &str) -> &'a Cell {
        r.cells.iter().find(|c| c.scheduler == name).unwrap()
    }

    #[test]
    fn even_workload_sos_wins_fairness_and_balance() {
        // Section 8.4 (1): "SOSA demonstrates superior performance in
        // terms of fairness and load balancing", at slightly higher
        // latency than the FIFO baselines.
        let r = run_scenario(Scenario::Even, Effort::Quick, 17);
        let sos = cell(&r, "SOS");
        let rr = cell(&r, "RR");
        assert!(sos.metrics.fairness >= rr.metrics.fairness * 0.9);
        assert!(!sos.metrics.starvation);
    }

    #[test]
    fn skewed_workloads_do_not_break_sos() {
        // Sections 8.4 (2)/(3): SOSA keeps its fairness/balance under
        // heavy skew without explicit workload profiling.
        for scenario in [Scenario::MemorySkewed, Scenario::ComputeSkewed] {
            let r = run_scenario(scenario, Effort::Quick, 23);
            let sos = cell(&r, "SOS");
            assert!(!sos.metrics.starvation, "{scenario:?}");
            assert!(sos.metrics.fairness > 0.5, "{scenario:?}");
        }
    }

    #[test]
    fn homogeneous_machines_distributions_converge() {
        // Section 8.4 (5): "job distribution across machines is nearly
        // identical for all schedulers" on the CPU-only park.
        let r = run_scenario(Scenario::HomogeneousMachines, Effort::Quick, 29);
        let sos = cell(&r, "SOS");
        let wsg = cell(&r, "WSG");
        let div = crate::quant::distribution_divergence(
            &sos.metrics.jobs_per_machine,
            &wsg.metrics.jobs_per_machine,
        );
        assert!(div < 0.35, "divergence {div}");
    }

    #[test]
    fn sos_latency_penalty_is_by_design() {
        // Section 8.4 (4): WSRR/WSG beat SOSA on raw latency (SOSA
        // buffers jobs in virtual schedules deliberately).
        let r = run_scenario(Scenario::HomogeneousWorkload, Effort::Quick, 31);
        let sos = cell(&r, "SOS");
        let wsg = cell(&r, "WSG");
        assert!(
            sos.metrics.avg_latency >= wsg.metrics.avg_latency * 0.8,
            "sos {} wsg {}",
            sos.metrics.avg_latency,
            wsg.metrics.avg_latency
        );
    }

    #[test]
    fn all_scenarios_produce_five_schedulers() {
        let results = run(Effort::Quick, 41);
        assert_eq!(results.len(), 5);
        for r in &results {
            assert_eq!(r.cells.len(), 5);
            let names: Vec<_> = r.cells.iter().map(|c| c.scheduler).collect();
            assert_eq!(names, vec!["SOS", "RR", "Greedy", "WSRR", "WSG"]);
        }
    }
}
