//! Fig. 16 — (a) jobs and average latency per machine; (b) the headline
//! speedup table: software execution time (ST) vs hardware execution
//! time (HT), speedup (SU), and power (FPC) for the four comparison
//! configurations, for both architectures (Section 8.2).

use std::time::Instant;

use crate::bench::Table;
use crate::cluster::{Cluster, ClusterConfig, SosCluster};
use crate::core::MachinePark;
use crate::hw::{self, CLOCK_HZ};
use crate::quant::Precision;
use crate::sim::{hercules::HerculesSim, stannic::StannicSim, ArchSim};
use crate::workload::{generate_trace, WorkloadSpec};

use super::Effort;

/// Fig. 16a data: per-machine jobs + average latency from a cluster run.
#[derive(Debug, Clone)]
pub struct Fig16a {
    pub jobs_per_machine: Vec<usize>,
    pub avg_latency_per_machine: Vec<f64>,
}

pub fn run_16a(effort: Effort, seed: u64) -> Fig16a {
    let park = MachinePark::paper_m1_m5();
    let n_jobs = effort.scale(300, 2500);
    let trace = generate_trace(&WorkloadSpec::default(), &park, n_jobs, seed);
    let mut sched = SosCluster::new(5, 10, 0.5, Precision::Int8);
    let sum = Cluster::new(park, ClusterConfig::default()).run(&mut sched, &trace);
    Fig16a {
        jobs_per_machine: sum.metrics.jobs_per_machine,
        avg_latency_per_machine: sum.metrics.avg_latency_per_machine,
    }
}

/// One row of Fig. 16b.
#[derive(Debug, Clone)]
pub struct SpeedupRow {
    pub config: (usize, usize),
    /// Software (naive SOSC) wall-clock seconds for the job batch.
    pub st_secs: f64,
    /// Hercules hardware seconds (cycles / 371.47 MHz) + power.
    pub hercules_ht: f64,
    pub hercules_su: f64,
    pub hercules_w: f64,
    /// Stannic hardware seconds + power.
    pub stannic_ht: f64,
    pub stannic_su: f64,
    pub stannic_w: f64,
    pub jobs: usize,
}

/// Drive an ArchSim over a trace; return simulated seconds at the FPGA
/// clock.
fn hw_seconds<S: ArchSim>(mut sim: S, trace: &crate::workload::Trace) -> f64 {
    let mut events = trace.events().iter().peekable();
    let mut t = 0u64;
    loop {
        t += 1;
        while events.peek().is_some_and(|e| e.tick <= t) {
            sim.submit(events.next().expect("peeked").job.clone().expect("job"));
        }
        sim.tick(None);
        if sim.is_idle() && events.peek().is_none() {
            break;
        }
        if t > 100_000_000 {
            panic!("sim did not drain");
        }
    }
    sim.stats().seconds_at(CLOCK_HZ)
}

/// Software baseline: the naive SOSC engine, measured wall-clock.
fn sw_seconds(machines: usize, depth: usize, trace: &crate::workload::Trace) -> f64 {
    let mut engine =
        crate::baselines::SoscEngine::new(machines, depth, 0.5, Precision::Int8);
    let mut events = trace.events().iter().peekable();
    let started = Instant::now();
    let mut t = 0u64;
    loop {
        t += 1;
        while events.peek().is_some_and(|e| e.tick <= t) {
            engine.submit(events.next().expect("peeked").job.clone().expect("job"));
        }
        engine.tick(None);
        if engine.is_idle() && events.peek().is_none() {
            break;
        }
        if t > 100_000_000 {
            panic!("sosc did not drain");
        }
    }
    started.elapsed().as_secs_f64()
}

pub fn run_16b(effort: Effort, seed: u64) -> Vec<SpeedupRow> {
    let n_jobs = effort.scale(500, 10_000);
    hw::resources::PAPER_CONFIGS
        .iter()
        .map(|&(m, d)| {
            let park = MachinePark::cycled(m);
            let trace = generate_trace(&WorkloadSpec::default(), &park, n_jobs, seed);
            let st = sw_seconds(m, d, &trace);
            let h_ht = hw_seconds(HerculesSim::new(m, d, 0.5, Precision::Int8), &trace);
            let s_ht = hw_seconds(StannicSim::new(m, d, 0.5, Precision::Int8), &trace);
            SpeedupRow {
                config: (m, d),
                st_secs: st,
                hercules_ht: h_ht,
                hercules_su: st / h_ht,
                hercules_w: hw::power::watts(hw::resources::hercules(m, d), m, d, 1),
                stannic_ht: s_ht,
                stannic_su: st / s_ht,
                stannic_w: hw::power::watts(hw::resources::stannic(m, d), m, d, 2),
                jobs: n_jobs,
            }
        })
        .collect()
}

pub fn render_16a(f: &Fig16a) -> String {
    let mut out = String::new();
    out.push_str("Fig 16a — jobs and average latency per machine (SOS)\n");
    let mut t = Table::new(&["machine", "jobs", "avg latency (ticks)"]);
    for m in 0..f.jobs_per_machine.len() {
        t.row(vec![
            format!("M{}", m + 1),
            f.jobs_per_machine[m].to_string(),
            format!("{:.1}", f.avg_latency_per_machine[m]),
        ]);
    }
    out.push_str(&t.render());
    out
}

pub fn render_16b(rows: &[SpeedupRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Fig 16b — SOSA vs software implementation ({} jobs; HT = sim cycles / 371.47 MHz)\n",
        rows.first().map_or(0, |r| r.jobs)
    ));
    let mut t = Table::new(&[
        "C", "cfg", "ST(s)", "H-HT(s)", "H-SU", "H-W", "S-HT(s)", "S-SU", "S-W",
    ]);
    for (i, r) in rows.iter().enumerate() {
        t.row(vec![
            format!("C{}", i + 1),
            format!("{}x{}", r.config.0, r.config.1),
            format!("{:.3}", r.st_secs),
            format!("{:.4}", r.hercules_ht),
            format!("{:.0}x", r.hercules_su),
            format!("{:.2}", r.hercules_w),
            format!("{:.4}", r.stannic_ht),
            format!("{:.0}x", r.stannic_su),
            format!("{:.2}", r.stannic_w),
        ]);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig16a_latency_favors_best_machines() {
        let f = run_16a(Effort::Quick, 5);
        assert_eq!(f.jobs_per_machine.iter().sum::<usize>(), 300);
        // Best machines (M1/M3/M4 = idx 0/2/3) should see low latency
        // relative to the Worst ones on average.
        let best = (f.avg_latency_per_machine[0]
            + f.avg_latency_per_machine[2]
            + f.avg_latency_per_machine[3])
            / 3.0;
        let worst = (f.avg_latency_per_machine[1] + f.avg_latency_per_machine[4]) / 2.0;
        assert!(best <= worst * 1.5, "best {best} vs worst {worst}");
    }

    #[test]
    fn fig16b_shape_holds() {
        let rows = run_16b(Effort::Quick, 5);
        assert_eq!(rows.len(), 4);
        for r in &rows {
            // the paper's core claims, shape-wise: hardware decisively
            // beats software (absolute magnitude depends on the software
            // baseline's host/CPU — see EXPERIMENTS.md), Stannic's
            // speedup clearly exceeds Hercules's, both within ~21 W.
            // Quick-effort debug builds still clear 2x comfortably.
            assert!(r.hercules_su > 2.0, "H speedup {}", r.hercules_su);
            assert!(r.stannic_su > r.hercules_su * 1.5);
            assert!((20.0..22.0).contains(&r.hercules_w));
            assert!((20.0..22.0).contains(&r.stannic_w));
        }
    }
}
