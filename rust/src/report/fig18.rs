//! Fig. 18 — the quantitative architecture comparison (Section 8.3):
//! (a) iteration latency, (b) FF utilization, (c) LUT utilization for
//! C1–C4 plus averages, and (d) the averages table with maximum routable
//! configuration size.

use crate::bench::Table;
use crate::hw::resources::{hercules, stannic, Resources, PAPER_CONFIGS};
use crate::hw::routing::{max_routable, route_hercules, route_stannic};
use crate::hw::U55C;
use crate::sim::{hercules::timing as h_timing, stannic::timing as s_timing};

#[derive(Debug, Clone)]
pub struct Fig18Row {
    pub config: (usize, usize),
    pub hercules_latency: u64,
    pub stannic_latency: u64,
    pub hercules_res: Resources,
    pub stannic_res: Resources,
}

#[derive(Debug, Clone)]
pub struct Fig18 {
    pub rows: Vec<Fig18Row>,
    pub avg_hercules_latency: f64,
    pub avg_stannic_latency: f64,
    pub avg_hercules_res: Resources,
    pub avg_stannic_res: Resources,
    pub max_routable_hercules: usize,
    pub max_routable_stannic: usize,
}

pub fn run() -> Fig18 {
    let rows: Vec<Fig18Row> = PAPER_CONFIGS
        .iter()
        .map(|&(m, d)| Fig18Row {
            config: (m, d),
            hercules_latency: h_timing::decision_latency(m, d),
            stannic_latency: s_timing::decision_latency(m, d),
            hercules_res: hercules(m, d),
            stannic_res: stannic(m, d),
        })
        .collect();
    let n = rows.len() as f64;
    let avg = |f: &dyn Fn(&Fig18Row) -> f64| rows.iter().map(|r| f(r)).sum::<f64>() / n;
    Fig18 {
        avg_hercules_latency: avg(&|r| r.hercules_latency as f64),
        avg_stannic_latency: avg(&|r| r.stannic_latency as f64),
        avg_hercules_res: Resources {
            luts: avg(&|r| r.hercules_res.luts as f64) as u64,
            ffs: avg(&|r| r.hercules_res.ffs as f64) as u64,
        },
        avg_stannic_res: Resources {
            luts: avg(&|r| r.stannic_res.luts as f64) as u64,
            ffs: avg(&|r| r.stannic_res.ffs as f64) as u64,
        },
        max_routable_hercules: max_routable(route_hercules, 10, &U55C),
        max_routable_stannic: max_routable(route_stannic, 10, &U55C),
        rows,
    }
}

pub fn render(f: &Fig18) -> String {
    let mut out = String::new();
    out.push_str("Fig 18a — iteration latency (cycles)\n");
    let mut t = Table::new(&["config", "HERCULES", "STANNIC", "ratio"]);
    for (i, r) in f.rows.iter().enumerate() {
        t.row(vec![
            format!("C{} ({}x{})", i + 1, r.config.0, r.config.1),
            r.hercules_latency.to_string(),
            r.stannic_latency.to_string(),
            format!("{:.1}x", r.hercules_latency as f64 / r.stannic_latency as f64),
        ]);
    }
    t.row(vec![
        "average".into(),
        format!("{:.0}", f.avg_hercules_latency),
        format!("{:.1}", f.avg_stannic_latency),
        format!("{:.1}x", f.avg_hercules_latency / f.avg_stannic_latency),
    ]);
    out.push_str(&t.render());

    out.push_str("\nFig 18b — flip-flop utilization\n");
    let mut t = Table::new(&["config", "HERCULES FF", "STANNIC FF"]);
    for (i, r) in f.rows.iter().enumerate() {
        t.row(vec![
            format!("C{}", i + 1),
            r.hercules_res.ffs.to_string(),
            r.stannic_res.ffs.to_string(),
        ]);
    }
    t.row(vec![
        "average".into(),
        f.avg_hercules_res.ffs.to_string(),
        f.avg_stannic_res.ffs.to_string(),
    ]);
    out.push_str(&t.render());

    out.push_str("\nFig 18c — LUT utilization\n");
    let mut t = Table::new(&["config", "HERCULES LUT", "STANNIC LUT"]);
    for (i, r) in f.rows.iter().enumerate() {
        t.row(vec![
            format!("C{}", i + 1),
            r.hercules_res.luts.to_string(),
            r.stannic_res.luts.to_string(),
        ]);
    }
    t.row(vec![
        "average".into(),
        f.avg_hercules_res.luts.to_string(),
        f.avg_stannic_res.luts.to_string(),
    ]);
    out.push_str(&t.render());

    out.push_str("\nFig 18d — averages & maximum routable configuration\n");
    let mut t = Table::new(&["metric", "HERCULES", "STANNIC", "improvement"]);
    t.row(vec![
        "avg iteration latency".into(),
        format!("{:.0}", f.avg_hercules_latency),
        format!("{:.1}", f.avg_stannic_latency),
        format!("{:.1}x", f.avg_hercules_latency / f.avg_stannic_latency),
    ]);
    t.row(vec![
        "avg LUTs".into(),
        f.avg_hercules_res.luts.to_string(),
        f.avg_stannic_res.luts.to_string(),
        format!(
            "{:.2}x",
            f.avg_hercules_res.luts as f64 / f.avg_stannic_res.luts as f64
        ),
    ]);
    t.row(vec![
        "avg FFs".into(),
        f.avg_hercules_res.ffs.to_string(),
        f.avg_stannic_res.ffs.to_string(),
        format!(
            "{:.2}x",
            f.avg_hercules_res.ffs as f64 / f.avg_stannic_res.ffs as f64
        ),
    ]);
    t.row(vec![
        "max routable machines".into(),
        f.max_routable_hercules.to_string(),
        f.max_routable_stannic.to_string(),
        format!(
            "{:.0}x",
            f.max_routable_stannic as f64 / f.max_routable_hercules as f64
        ),
    ]);
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_numbers_match_paper() {
        let f = run();
        // 466 vs 62 cycles (7.5x), 2.24x LUT, 2.1x FF, 10 vs 140 machines
        assert!((f.avg_hercules_latency - 466.0).abs() / 466.0 < 0.02);
        assert!((f.avg_stannic_latency - 62.0).abs() / 62.0 < 0.02);
        let ratio = f.avg_hercules_latency / f.avg_stannic_latency;
        assert!((7.0..8.0).contains(&ratio), "latency ratio {ratio}");
        assert_eq!(f.max_routable_hercules, 10);
        assert_eq!(f.max_routable_stannic, 140);
        let lut_ratio = f.avg_hercules_res.luts as f64 / f.avg_stannic_res.luts as f64;
        assert!((2.0..2.5).contains(&lut_ratio));
    }

    #[test]
    fn render_contains_all_panels() {
        let text = render(&run());
        for panel in ["Fig 18a", "Fig 18b", "Fig 18c", "Fig 18d"] {
            assert!(text.contains(panel));
        }
        assert!(text.contains("140"));
    }
}
