//! Fig. 17 — AVX SIMD software vs STANNIC latency across system sizes
//! (Section 8.2): total scheduling latency for a 10k-job workload at
//! machine counts 5..=140 (V_i depth 10), with Stannic's PCIe component
//! reported separately.

use std::time::Instant;

use crate::bench::Table;
use crate::baselines::SimdSos;
use crate::coordinator::{PcieModel, PcieStats};
use crate::core::MachinePark;
use crate::hw::CLOCK_HZ;
use crate::quant::Precision;
use crate::sim::{stannic::StannicSim, ArchSim};
use crate::workload::{generate_trace, Trace, WorkloadSpec};

use super::Effort;

/// Default machine-count sweep (the paper sweeps to its 140 max).
pub const SWEEP: [usize; 6] = [5, 10, 20, 40, 80, 140];

#[derive(Debug, Clone)]
pub struct Fig17Row {
    pub machines: usize,
    /// AVX-style software wall-clock (seconds).
    pub avx_secs: f64,
    /// Stannic compute time (cycles / clock).
    pub stannic_secs: f64,
    /// Stannic PCIe overhead (seconds).
    pub pcie_secs: f64,
    pub jobs: usize,
}

fn run_simd(machines: usize, depth: usize, trace: &Trace) -> f64 {
    let mut engine = SimdSos::new(machines, depth, 0.5, Precision::Int8);
    let mut events = trace.events().iter().peekable();
    let started = Instant::now();
    let mut t = 0u64;
    loop {
        t += 1;
        while events.peek().is_some_and(|e| e.tick <= t) {
            engine.submit(events.next().expect("peeked").job.clone().expect("job"));
        }
        engine.tick(None);
        if engine.is_idle() && events.peek().is_none() {
            break;
        }
        if t > 100_000_000 {
            panic!("simd did not drain");
        }
    }
    started.elapsed().as_secs_f64()
}

fn run_stannic(machines: usize, depth: usize, trace: &Trace) -> (f64, f64) {
    let mut sim = StannicSim::new(machines, depth, 0.5, Precision::Int8);
    let pcie = PcieModel::default();
    let mut pcie_stats = PcieStats::default();
    let mut events = trace.events().iter().peekable();
    let mut t = 0u64;
    loop {
        t += 1;
        while events.peek().is_some_and(|e| e.tick <= t) {
            sim.submit(events.next().expect("peeked").job.clone().expect("job"));
        }
        let out = sim.tick(None);
        if out.assigned.is_some() || !out.released.is_empty() {
            pcie.charge(&mut pcie_stats, machines, out.released.len());
        }
        if sim.is_idle() && events.peek().is_none() {
            break;
        }
        if t > 100_000_000 {
            panic!("stannic sim did not drain");
        }
    }
    (
        sim.stats().seconds_at(CLOCK_HZ),
        pcie_stats.total_ns() / 1e9,
    )
}

pub fn run(effort: Effort, seed: u64) -> Vec<Fig17Row> {
    let n_jobs = effort.scale(500, 10_000);
    let depth = 10;
    SWEEP
        .iter()
        .map(|&m| {
            let park = MachinePark::cycled(m);
            let trace = generate_trace(&WorkloadSpec::default(), &park, n_jobs, seed);
            let avx = run_simd(m, depth, &trace);
            let (st, pcie) = run_stannic(m, depth, &trace);
            Fig17Row {
                machines: m,
                avx_secs: avx,
                stannic_secs: st,
                pcie_secs: pcie,
                jobs: n_jobs,
            }
        })
        .collect()
}

pub fn render(rows: &[Fig17Row]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Fig 17 — AVX SIMD vs STANNIC scheduling latency ({} jobs, depth 10)\n",
        rows.first().map_or(0, |r| r.jobs)
    ));
    let mut t = Table::new(&[
        "machines",
        "AVX (s)",
        "Stannic compute (s)",
        "Stannic PCIe (s)",
        "Stannic total (s)",
        "winner",
    ]);
    for r in rows {
        let total = r.stannic_secs + r.pcie_secs;
        t.row(vec![
            r.machines.to_string(),
            format!("{:.4}", r.avx_secs),
            format!("{:.4}", r.stannic_secs),
            format!("{:.4}", r.pcie_secs),
            format!("{:.4}", total),
            if r.avx_secs < total { "AVX" } else { "STANNIC" }.into(),
        ]);
    }
    out.push_str(&t.render());
    out.push_str(
        "(paper: AVX wins marginally at small configs; Stannic scales linearly and \
         dominates at large configs; PCIe overhead is negligible)\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stannic_scales_better_than_avx() {
        // The paper's claim is the *crossover*: AVX degrades with machine
        // count faster than Stannic. Compare growth ratios on a reduced
        // sweep so the test stays fast.
        let n_jobs = 400;
        let depth = 10;
        let mut ratios = Vec::new();
        for &m in &[5usize, 80] {
            let park = MachinePark::cycled(m);
            let trace = generate_trace(&WorkloadSpec::default(), &park, n_jobs, 5);
            // median of 3 to damp wall-clock noise (debug builds, 1 core)
            let mut avx: Vec<f64> = (0..3).map(|_| run_simd(m, depth, &trace)).collect();
            avx.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let (st, pcie) = run_stannic(m, depth, &trace);
            ratios.push((avx[1], st + pcie));
        }
        let avx_growth = ratios[1].0 / ratios[0].0;
        let stannic_growth = ratios[1].1 / ratios[0].1;
        assert!(
            avx_growth > stannic_growth * 0.9,
            "avx grew {avx_growth}x vs stannic {stannic_growth}x"
        );
    }

    #[test]
    fn pcie_per_job_overhead_matches_paper() {
        // Section 8.2: "on average 4789 microseconds per 10,000 jobs
        // across all tested configuration sizes" => ~479 ns/job, roughly
        // configuration-independent.
        let n_jobs = 200;
        let mut per_job = Vec::new();
        for &m in &[5usize, 40] {
            let park = MachinePark::cycled(m);
            let trace = generate_trace(&WorkloadSpec::default(), &park, n_jobs, 9);
            let (_, pcie) = run_stannic(m, 10, &trace);
            per_job.push(pcie * 1e9 / n_jobs as f64);
        }
        for p in &per_job {
            assert!((300.0..900.0).contains(p), "per-job PCIe {p} ns");
        }
    }

    #[test]
    fn pcie_fraction_shrinks_with_scale() {
        // The dark-blue PCIe band of Fig. 17 becomes a smaller share of
        // Stannic's total as the configuration grows (compute scales
        // with M, the latency-dominated link does not).
        let n_jobs = 200;
        let frac = |m: usize| {
            let park = MachinePark::cycled(m);
            let trace = generate_trace(&WorkloadSpec::default(), &park, n_jobs, 9);
            let (st, pcie) = run_stannic(m, 10, &trace);
            pcie / (st + pcie)
        };
        assert!(frac(80) < frac(5));
    }
}
