//! Figure/table regeneration — one module per paper artifact (see
//! DESIGN.md §5 for the experiment index). Each module exposes a
//! `run(...) -> <data struct>` used by both the CLI (`stannic report
//! figN`) and the benches, plus a `render` that prints the same rows or
//! series the paper reports.

pub mod ablations;
pub mod fig15;
pub mod fig16;
pub mod fig17;
pub mod fig18;
pub mod fig19;
pub mod fig7;

/// Effort knob shared by the report runners: paper-scale runs are the
/// default; `quick` keeps CI and smoke runs fast.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Effort {
    Quick,
    Paper,
}

impl Effort {
    pub fn scale(&self, quick: usize, paper: usize) -> usize {
        match self {
            Effort::Quick => quick,
            Effort::Paper => paper,
        }
    }
}
