//! Seeded, deterministic fault injection — the chaos layer.
//!
//! Production scale means machines die mid-job, stragglers run 10× slow,
//! and storms arrive correlated. This module turns those into
//! *first-class virtual-time events* on the same event horizon the
//! tickless core jumps on: a [`FaultPlan`] is a sorted queue of
//! [`FaultEvent`]s that [`crate::scheduler::SosEngine`] consumes at the
//! start of every tick, and whose next pending tick is folded into
//! `SosEngine::next_event_tick` as a release-class event. That is the
//! load-bearing invariant — any fault that is *not* on the horizon would
//! be silently jumped over by `advance_to`, so faulted runs stay
//! bit-reproducible and every jump-invariance gate (golden test,
//! `tests/tickless.rs`, the sweep/serve A/B self-diffs) keeps holding
//! with faults enabled.
//!
//! # Spec grammar
//!
//! A fault scenario is a comma-separated list of clauses
//! ([`FaultSpec::parse`] / [`FaultSpec::render`] round-trip):
//!
//! | clause          | meaning                                                        |
//! |-----------------|----------------------------------------------------------------|
//! | `down=M@T+D`    | machine `M` goes down at tick `T`, back up at `T+D`            |
//! | `down=M..N@T+D` | rack-scale correlated failure: machines `M..=N` down together  |
//! | `downs=K@T+D`   | correlated random failure: `K` distinct seed-sampled machines  |
//! | `slow=M@T+DxF`  | machine `M` straggles ×`F` for arrivals assigned in `[T, T+D)` |
//! | `storm=K@T`     | `K` correlated synthetic jobs injected at tick `T`             |
//! | `drop=S@T`      | arrival source `S` drops every event with tick ≥ `T` (serve)   |
//! | `policy=lose\|resume` | fate of a down machine's running head (default `resume`) |
//! | `seed=N`        | RNG seed for storm-job synthesis (default 0)                   |
//!
//! Determinism: the spec is the only input — storm jobs are synthesized
//! from `seed` via the same [`crate::workload::Rng`] substrate as the
//! workload generators (and `downs=` samples its machine set from the
//! same per-clause streams), events fire in (tick, clause-order) order, and a
//! down machine's evicted slots re-enter the arrival FIFO in schedule
//! order. Two runs with the same spec produce identical schedules for
//! any thread count or queue depth; the canonical [`FaultSpec::render`]
//! string doubles as the artifact fault key, so `diff` never pairs a
//! faulted recording with a clean one.
//!
//! # Recovery metrics
//!
//! [`FaultStats`] records re-queue latency (eviction → reassignment),
//! work lost (discarded virtual-work cycles), and the utilization dip
//! (degraded-tick duration, down-machine-tick area, max concurrent
//! downs), surfaced per run through `ServeReport` and the artifact
//! records.

use std::collections::VecDeque;

use crate::core::{Job, JobId, JobNature, MachineId};
use crate::error::Result;
use crate::metrics::Histogram;
use crate::workload::Rng;
use crate::{bail, err};

/// Storm-injected job ids live in their own namespace, far above both
/// trace ids and the serve pipeline's per-source (src << 32) namespaces.
pub const STORM_ID_BASE: JobId = 1 << 48;

/// Fate of a down machine's *running head* (queued-but-unstarted slots
/// are always evicted back to the arrival FIFO).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DownPolicy {
    /// The head's accrued virtual work is discarded and the job re-queues
    /// from scratch (the work-lost cycles are recorded).
    Lose,
    /// The head stays in place and resumes exactly where it stopped when
    /// the machine comes back up (no virtual work accrues while down).
    ResumeOnUp,
}

/// One parsed fault clause, in spec order.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultClause {
    Down { machine: MachineId, at: u64, dur: u64 },
    /// Rack-scale correlated failure: the contiguous machines
    /// `first..=last` all go down at `at`, back up at `at + dur`.
    /// [`FaultSpec::plan`] expands the range to per-machine down/up
    /// events (ascending machine order within the tick), so the engine's
    /// fault loop — and [`FaultPlan::split_shards`], which remaps
    /// per-machine events — need no range awareness. A degenerate
    /// `M..M` range is canonicalized to a plain [`FaultClause::Down`]
    /// at parse time.
    DownRange { first: MachineId, last: MachineId, at: u64, dur: u64 },
    /// Correlated *random* failure: `count` distinct machines — sampled
    /// at plan time from the spec seed via the clause's own RNG stream,
    /// then sorted ascending — all go down at `at`, back up at
    /// `at + dur`. Like [`FaultClause::DownRange`], the plan expands it
    /// to per-machine down/up events, so the engine fault loop and
    /// [`FaultPlan::split_shards`] stay sampling-oblivious and a
    /// sharded run sees exactly the per-machine events a single park
    /// would.
    Downs { count: usize, at: u64, dur: u64 },
    Slow { machine: MachineId, at: u64, dur: u64, factor: u32 },
    Storm { jobs: usize, at: u64 },
    Drop { source: usize, at: u64 },
}

/// A parsed fault scenario: the seed, the head policy, and the clauses.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    pub seed: u64,
    pub policy: DownPolicy,
    clauses: Vec<FaultClause>,
}

/// Accepted clause vocabulary, interpolated into every parse error.
pub const USAGE: &str = "down=M@T+D, down=M..N@T+D, downs=K@T+D, slow=M@T+DxF, storm=K@T, \
                         drop=S@T, policy=lose|resume, seed=N";

fn parse_u64(what: &str, s: &str) -> Result<u64> {
    s.trim()
        .parse()
        .map_err(|e| err!("fault spec: bad {what} `{s}`: {e}"))
}

impl FaultSpec {
    /// Parse the comma-separated clause grammar (see module docs).
    pub fn parse(s: &str) -> Result<FaultSpec> {
        let mut spec = FaultSpec {
            seed: 0,
            policy: DownPolicy::ResumeOnUp,
            clauses: Vec::new(),
        };
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((key, val)) = part.split_once('=') else {
                bail!("fault spec: clause `{part}` is not key=value (expected: {USAGE})");
            };
            match key {
                "seed" => spec.seed = parse_u64("seed", val)?,
                "policy" => {
                    spec.policy = match val {
                        "lose" => DownPolicy::Lose,
                        "resume" => DownPolicy::ResumeOnUp,
                        other => bail!("fault spec: unknown policy `{other}` (lose|resume)"),
                    }
                }
                "down" => {
                    let (m, rest) = val
                        .split_once('@')
                        .ok_or_else(|| err!("fault spec: down=`{val}` wants M@T+D or M..N@T+D"))?;
                    let (at, dur) = rest
                        .split_once('+')
                        .ok_or_else(|| err!("fault spec: down=`{val}` wants M@T+D or M..N@T+D"))?;
                    let at = parse_u64("tick", at)?;
                    let dur = parse_u64("duration", dur)?;
                    if let Some((first, last)) = m.split_once("..") {
                        let first = parse_u64("machine", first)? as usize;
                        let last = parse_u64("machine", last)? as usize;
                        if first == last {
                            // canonicalize the degenerate range so render()
                            // emits the minimal spelling
                            spec.clauses.push(FaultClause::Down { machine: first, at, dur });
                        } else {
                            spec.clauses.push(FaultClause::DownRange { first, last, at, dur });
                        }
                    } else {
                        spec.clauses.push(FaultClause::Down {
                            machine: parse_u64("machine", m)? as usize,
                            at,
                            dur,
                        });
                    }
                }
                "downs" => {
                    let (k, rest) = val
                        .split_once('@')
                        .ok_or_else(|| err!("fault spec: downs=`{val}` wants K@T+D"))?;
                    let (at, dur) = rest
                        .split_once('+')
                        .ok_or_else(|| err!("fault spec: downs=`{val}` wants K@T+D"))?;
                    spec.clauses.push(FaultClause::Downs {
                        count: parse_u64("machine count", k)? as usize,
                        at: parse_u64("tick", at)?,
                        dur: parse_u64("duration", dur)?,
                    });
                }
                "slow" => {
                    let (m, rest) = val
                        .split_once('@')
                        .ok_or_else(|| err!("fault spec: slow=`{val}` wants M@T+DxF"))?;
                    let (at, rest) = rest
                        .split_once('+')
                        .ok_or_else(|| err!("fault spec: slow=`{val}` wants M@T+DxF"))?;
                    let (dur, factor) = rest
                        .split_once('x')
                        .ok_or_else(|| err!("fault spec: slow=`{val}` wants M@T+DxF"))?;
                    spec.clauses.push(FaultClause::Slow {
                        machine: parse_u64("machine", m)? as usize,
                        at: parse_u64("tick", at)?,
                        dur: parse_u64("duration", dur)?,
                        factor: parse_u64("factor", factor)? as u32,
                    });
                }
                "storm" => {
                    let (k, at) = val
                        .split_once('@')
                        .ok_or_else(|| err!("fault spec: storm=`{val}` wants K@T"))?;
                    spec.clauses.push(FaultClause::Storm {
                        jobs: parse_u64("job count", k)? as usize,
                        at: parse_u64("tick", at)?,
                    });
                }
                "drop" => {
                    let (s, at) = val
                        .split_once('@')
                        .ok_or_else(|| err!("fault spec: drop=`{val}` wants S@T"))?;
                    spec.clauses.push(FaultClause::Drop {
                        source: parse_u64("source", s)? as usize,
                        at: parse_u64("tick", at)?,
                    });
                }
                other => bail!("fault spec: unknown clause `{other}` (expected: {USAGE})"),
            }
        }
        spec.validate()?;
        Ok(spec)
    }

    fn validate(&self) -> Result<()> {
        for c in &self.clauses {
            match *c {
                FaultClause::Down { at, dur, .. } => {
                    if at == 0 {
                        bail!("fault spec: down at tick 0 (scheduler ticks start at 1)");
                    }
                    if dur == 0 {
                        bail!("fault spec: down duration must be >= 1");
                    }
                }
                FaultClause::DownRange { first, last, at, dur } => {
                    if first > last {
                        bail!("fault spec: down range {first}..{last} is reversed (want M <= N)");
                    }
                    if at == 0 {
                        bail!("fault spec: down at tick 0 (scheduler ticks start at 1)");
                    }
                    if dur == 0 {
                        bail!("fault spec: down duration must be >= 1");
                    }
                }
                FaultClause::Downs { count, at, dur } => {
                    if count == 0 {
                        bail!("fault spec: downs count must be >= 1");
                    }
                    if at == 0 {
                        bail!("fault spec: downs at tick 0 (scheduler ticks start at 1)");
                    }
                    if dur == 0 {
                        bail!("fault spec: downs duration must be >= 1");
                    }
                }
                FaultClause::Slow { at, dur, factor, .. } => {
                    if at == 0 {
                        bail!("fault spec: slow at tick 0 (scheduler ticks start at 1)");
                    }
                    if dur == 0 {
                        bail!("fault spec: slow duration must be >= 1");
                    }
                    if factor < 2 {
                        bail!("fault spec: slow factor must be >= 2 (1 is a no-op)");
                    }
                }
                FaultClause::Storm { jobs, at } => {
                    if at == 0 {
                        bail!("fault spec: storm at tick 0 (scheduler ticks start at 1)");
                    }
                    if jobs == 0 || jobs > 100_000 {
                        bail!("fault spec: storm size must be in 1..=100000");
                    }
                }
                FaultClause::Drop { at, .. } => {
                    if at == 0 {
                        bail!("fault spec: drop at tick 0 (scheduler ticks start at 1)");
                    }
                }
            }
        }
        Ok(())
    }

    /// No clauses at all — scheduling is bit-identical to a clean run.
    pub fn is_empty(&self) -> bool {
        self.clauses.is_empty()
    }

    pub fn clauses(&self) -> &[FaultClause] {
        &self.clauses
    }

    /// Canonical spec string: clauses in spec order, then non-default
    /// `policy`/`seed`. Re-parses to an equal spec, and doubles as the
    /// artifact fault key (so a faulted cell can never pair with a clean
    /// one in `diff`).
    pub fn render(&self) -> String {
        let mut parts: Vec<String> = self
            .clauses
            .iter()
            .map(|c| match *c {
                FaultClause::Down { machine, at, dur } => format!("down={machine}@{at}+{dur}"),
                FaultClause::DownRange { first, last, at, dur } => {
                    format!("down={first}..{last}@{at}+{dur}")
                }
                FaultClause::Downs { count, at, dur } => format!("downs={count}@{at}+{dur}"),
                FaultClause::Slow { machine, at, dur, factor } => {
                    format!("slow={machine}@{at}+{dur}x{factor}")
                }
                FaultClause::Storm { jobs, at } => format!("storm={jobs}@{at}"),
                FaultClause::Drop { source, at } => format!("drop={source}@{at}"),
            })
            .collect();
        if self.policy == DownPolicy::Lose {
            parts.push("policy=lose".into());
        }
        if self.seed != 0 {
            parts.push(format!("seed={}", self.seed));
        }
        parts.join(",")
    }

    /// Per-source dropout cut-offs: `(source, first dropped tick)`.
    /// Dropout is a *source-stream* fault, applied by the serve pipeline
    /// where arrivals are still attributed to sources — the engine never
    /// sees the dropped events.
    pub fn drops(&self) -> impl Iterator<Item = (usize, u64)> + '_ {
        self.clauses.iter().filter_map(|c| match *c {
            FaultClause::Drop { source, at } => Some((source, at)),
            _ => None,
        })
    }

    pub fn has_drops(&self) -> bool {
        self.drops().next().is_some()
    }

    /// Total jobs the storm clauses will inject.
    pub fn injected_total(&self) -> usize {
        self.clauses
            .iter()
            .map(|c| match *c {
                FaultClause::Storm { jobs, .. } => jobs,
                _ => 0,
            })
            .sum()
    }

    /// Materialize the engine-side event queue for a park of `machines`.
    /// Validates machine indices and synthesizes storm jobs
    /// deterministically from the seed (one independent RNG stream per
    /// storm clause, so reordering unrelated clauses cannot change a
    /// storm's jobs).
    pub fn plan(&self, machines: usize) -> Result<FaultPlan> {
        let mut events: Vec<FaultEvent> = Vec::new();
        for (ci, c) in self.clauses.iter().enumerate() {
            match *c {
                FaultClause::Down { machine, at, dur } => {
                    if machine >= machines {
                        bail!("fault spec: down machine {machine} out of range (park has {machines})");
                    }
                    events.push(FaultEvent { tick: at, kind: FaultKind::Down(machine) });
                    events.push(FaultEvent { tick: at + dur, kind: FaultKind::Up(machine) });
                }
                FaultClause::DownRange { first, last, at, dur } => {
                    if last >= machines {
                        bail!(
                            "fault spec: down range {first}..{last} out of range (park has {machines})"
                        );
                    }
                    // expand to per-machine events (ascending machine
                    // order within the tick): the engine's fault loop and
                    // split_shards stay range-oblivious
                    for machine in first..=last {
                        events.push(FaultEvent { tick: at, kind: FaultKind::Down(machine) });
                        events.push(FaultEvent { tick: at + dur, kind: FaultKind::Up(machine) });
                    }
                }
                FaultClause::Downs { count, at, dur } => {
                    if count > machines {
                        bail!("fault spec: downs={count} exceeds the park ({machines} machines)");
                    }
                    // Sample `count` distinct machines with a partial
                    // Fisher-Yates over 0..machines, driven by the same
                    // per-clause RNG stream scheme as storms — then sort
                    // ascending so the per-machine expansion (and hence
                    // split_shards) is canonical regardless of draw order.
                    let mut rng = Rng::new(self.seed.wrapping_add((ci as u64 + 1) << 32));
                    let mut pool: Vec<MachineId> = (0..machines).collect();
                    for i in 0..count {
                        let j = i + rng.below((machines - i) as u64) as usize;
                        pool.swap(i, j);
                    }
                    let mut victims = pool[..count].to_vec();
                    victims.sort_unstable();
                    for machine in victims {
                        events.push(FaultEvent { tick: at, kind: FaultKind::Down(machine) });
                        events.push(FaultEvent { tick: at + dur, kind: FaultKind::Up(machine) });
                    }
                }
                FaultClause::Slow { machine, at, dur, factor } => {
                    if machine >= machines {
                        bail!("fault spec: slow machine {machine} out of range (park has {machines})");
                    }
                    events.push(FaultEvent {
                        tick: at,
                        kind: FaultKind::SlowStart(machine, factor),
                    });
                    events.push(FaultEvent { tick: at + dur, kind: FaultKind::SlowEnd(machine) });
                }
                FaultClause::Storm { jobs, at } => {
                    let mut rng = Rng::new(self.seed.wrapping_add((ci as u64 + 1) << 32));
                    let batch: Vec<Job> = (0..jobs)
                        .map(|k| {
                            let id = STORM_ID_BASE + ((ci as u64) << 24) + k as u64;
                            let weight = rng.uniform(1.0, 64.0).round().max(1.0);
                            let ept: Vec<f32> = (0..machines)
                                .map(|_| rng.uniform(10.0, 255.0).round())
                                .collect();
                            Job::new(id, weight, ept, JobNature::Mixed).with_arrival(at)
                        })
                        .collect();
                    events.push(FaultEvent { tick: at, kind: FaultKind::Storm(batch) });
                }
                FaultClause::Drop { .. } => {} // serve-side, not an engine event
            }
        }
        // Stable by tick: same-tick events keep clause order, so the
        // plan is a pure function of the spec string.
        events.sort_by_key(|e| e.tick);
        Ok(FaultPlan {
            events: events.into(),
            policy: self.policy,
            key: self.render(),
            machines,
        })
    }
}

/// What happens at a fault event's tick.
#[derive(Debug, Clone)]
pub enum FaultKind {
    /// Machine goes down: tail slots evicted to the FIFO, head per policy.
    Down(MachineId),
    /// Machine comes back up; a resumed head re-arms the event horizon.
    Up(MachineId),
    /// Machine starts straggling: EPTs of *newly assigned* jobs inflate
    /// by the factor (in-flight heads keep their contracted rate).
    SlowStart(MachineId, u32),
    SlowEnd(MachineId),
    /// A correlated burst of synthetic jobs enters the arrival FIFO.
    Storm(Vec<Job>),
}

/// One scheduled perturbation on the virtual clock.
#[derive(Debug, Clone)]
pub struct FaultEvent {
    pub tick: u64,
    pub kind: FaultKind,
}

/// The materialized, engine-consumable event queue (sorted by tick,
/// clause order within a tick).
#[derive(Debug, Clone)]
pub struct FaultPlan {
    events: VecDeque<FaultEvent>,
    pub policy: DownPolicy,
    key: String,
    machines: usize,
}

impl FaultPlan {
    /// The canonical spec string this plan was built from (artifact key).
    pub fn key(&self) -> &str {
        &self.key
    }

    pub fn machines(&self) -> usize {
        self.machines
    }

    /// Tick of the next pending fault event — a release-class event for
    /// `SosEngine::next_event_tick`, which is what keeps a fault inside
    /// an otherwise-empty window from being jumped over.
    pub fn next_tick(&self) -> Option<u64> {
        self.events.front().map(|e| e.tick)
    }

    /// Pop the next event if it is due at or before `now`.
    pub fn pop_due(&mut self, now: u64) -> Option<FaultEvent> {
        if self.events.front().is_some_and(|e| e.tick <= now) {
            self.events.pop_front()
        } else {
            None
        }
    }

    /// All events consumed — the run may drain (an idle engine must keep
    /// running while ups/storms are still scheduled).
    pub fn is_done(&self) -> bool {
        self.events.is_empty()
    }

    /// Split a park-wide plan across contiguous machine ranges
    /// (`(first_machine, machines)` per shard, covering the park):
    /// machine-scoped events (down/up/slow) land on the shard that owns
    /// the machine with the index remapped to shard-local, keeping their
    /// relative order; storm events are returned separately for the
    /// routing layer — a storm is a burst of *arrivals*, so the sharded
    /// coordinator routes its jobs exactly like real merged arrivals
    /// instead of pinning them to one shard. Each returned plan carries
    /// the same canonical key, so artifact fault-scoping is unchanged.
    pub fn split_shards(&self, ranges: &[(usize, usize)]) -> (Vec<FaultPlan>, Vec<FaultEvent>) {
        let shard_of = |m: usize| {
            ranges
                .iter()
                .position(|&(base, len)| m >= base && m < base + len)
                .expect("fault plan machine outside the shard map")
        };
        let mut shards: Vec<VecDeque<FaultEvent>> =
            ranges.iter().map(|_| VecDeque::new()).collect();
        let mut storms: Vec<FaultEvent> = Vec::new();
        for ev in &self.events {
            let (s, kind) = match ev.kind {
                FaultKind::Down(m) => (shard_of(m), FaultKind::Down(m - ranges[shard_of(m)].0)),
                FaultKind::Up(m) => (shard_of(m), FaultKind::Up(m - ranges[shard_of(m)].0)),
                FaultKind::SlowStart(m, f) => {
                    (shard_of(m), FaultKind::SlowStart(m - ranges[shard_of(m)].0, f))
                }
                FaultKind::SlowEnd(m) => (shard_of(m), FaultKind::SlowEnd(m - ranges[shard_of(m)].0)),
                FaultKind::Storm(_) => {
                    storms.push(ev.clone());
                    continue;
                }
            };
            shards[s].push_back(FaultEvent { tick: ev.tick, kind });
        }
        let plans = shards
            .into_iter()
            .zip(ranges)
            .map(|(events, &(_, len))| FaultPlan {
                events,
                policy: self.policy,
                key: self.key.clone(),
                machines: len,
            })
            .collect();
        (plans, storms)
    }
}

/// Recovery metrics for one faulted run.
#[derive(Debug, Clone, Default)]
pub struct FaultStats {
    /// Fault events applied, by kind.
    pub downs: u64,
    pub ups: u64,
    pub slow_events: u64,
    pub storms: u64,
    /// Jobs injected by storm events.
    pub injected_jobs: u64,
    /// Slots evicted from down machines back into the arrival FIFO.
    pub evicted_jobs: u64,
    /// Virtual-work cycles discarded by evictions (`policy=lose` heads
    /// plus any accrued work on displaced tail slots).
    pub work_lost_cycles: u64,
    /// Eviction → reassignment latency per evicted job.
    pub requeue_latency: Histogram,
    /// Ticks with at least one machine down (utilization dip duration).
    pub degraded_ticks: u64,
    /// Σ over ticks of the number of down machines (dip area).
    pub down_machine_ticks: u64,
    /// Dip depth: most machines simultaneously down.
    pub max_concurrent_down: usize,
    /// Arrivals lost to source dropout (filled in by the serve pipeline;
    /// the engine never sees them).
    pub dropped_arrivals: u64,
}

/// Live fault state carried by a faulted [`crate::scheduler::SosEngine`]:
/// the remaining plan, per-machine down/straggle flags, the retained
/// payloads needed to re-queue evicted slots, and the recovery metrics.
#[derive(Debug, Clone)]
pub struct FaultState {
    pub plan: FaultPlan,
    pub down: Vec<bool>,
    pub n_down: usize,
    /// Service-time inflation factor per machine (1 = nominal).
    pub slow: Vec<u32>,
    /// Original `Job` per in-flight slot id. The engine stores quantized
    /// `Slot`s, so re-queuing an evicted slot needs the job it came from;
    /// entries are dropped on release.
    pub retained: std::collections::HashMap<JobId, Job>,
    /// Eviction tick per job currently awaiting reassignment.
    pub evicted_at: std::collections::HashMap<JobId, u64>,
    pub stats: FaultStats,
}

/// Straggler EPT inflation (Phase II): a slow machine inflates the EPT
/// of *newly assigned* jobs only — in-flight slots keep their contracted
/// rate. This is the single definition both cost kernels share: the
/// scalar loop applies it via `SosEngine::effective_ept` and the
/// wavefront sweep via its mirrored slow column, so the two paths cannot
/// drift. The `factor > 1` guard keeps the nominal path multiplication-
/// free (though `* 1.0` would be bit-exact anyway).
#[inline]
pub fn inflate_ept(ept: f32, factor: u32) -> f32 {
    if factor > 1 {
        ept * factor as f32
    } else {
        ept
    }
}

impl FaultState {
    pub fn new(plan: FaultPlan, machines: usize) -> Self {
        debug_assert_eq!(plan.machines(), machines, "plan built for a different park");
        FaultState {
            plan,
            down: vec![false; machines],
            n_down: 0,
            slow: vec![1; machines],
            retained: std::collections::HashMap::new(),
            evicted_at: std::collections::HashMap::new(),
            stats: FaultStats::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_render_round_trip() {
        let s = "down=1@40+20,slow=0@10+5x4,storm=8@30,drop=1@25,policy=lose,seed=7";
        let spec = FaultSpec::parse(s).unwrap();
        assert_eq!(spec.render(), s);
        assert_eq!(FaultSpec::parse(&spec.render()).unwrap(), spec);
        assert_eq!(spec.policy, DownPolicy::Lose);
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.clauses().len(), 4);
        assert!(spec.has_drops());
        assert_eq!(spec.injected_total(), 8);
    }

    #[test]
    fn defaults_are_elided_from_the_canonical_form() {
        let spec = FaultSpec::parse("down=0@5+3,policy=resume,seed=0").unwrap();
        assert_eq!(spec.render(), "down=0@5+3");
        assert!(FaultSpec::parse("").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_malformed_clauses() {
        for bad in [
            "nonsense",
            "boom=1@2+3",
            "down=1@2",          // missing +D
            "down=1@0+5",        // tick 0
            "down=1@5+0",        // zero duration
            "downs=0@5+5",       // empty sample
            "downs=2@0+5",       // tick 0
            "downs=2@5+0",       // zero duration
            "downs=2@5",         // missing +D
            "slow=1@5+5x1",      // factor 1 is a no-op
            "slow=1@5+5",        // missing xF
            "storm=0@5",         // empty storm
            "storm=5",           // missing @T
            "policy=explode",
            "seed=abc",
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "accepted `{bad}`");
        }
    }

    #[test]
    fn plan_orders_events_and_validates_machines() {
        let spec = FaultSpec::parse("storm=3@50,down=1@10+15").unwrap();
        let mut plan = spec.plan(2).unwrap();
        assert_eq!(plan.next_tick(), Some(10));
        assert!(matches!(plan.pop_due(10).unwrap().kind, FaultKind::Down(1)));
        assert_eq!(plan.next_tick(), Some(25)); // the paired Up
        assert!(plan.pop_due(20).is_none(), "not due yet");
        assert!(matches!(plan.pop_due(25).unwrap().kind, FaultKind::Up(1)));
        assert!(matches!(plan.pop_due(50).unwrap().kind, FaultKind::Storm(_)));
        assert!(plan.is_done());
        // machine 1 does not exist in a 1-machine park
        assert!(spec.plan(1).is_err());
    }

    #[test]
    fn storm_jobs_are_deterministic_and_namespaced() {
        let spec = FaultSpec::parse("storm=4@30,seed=9").unwrap();
        let jobs = |p: &mut FaultPlan| -> Vec<Job> {
            match p.pop_due(30).unwrap().kind {
                FaultKind::Storm(js) => js,
                other => panic!("expected storm, got {other:?}"),
            }
        };
        let a = jobs(&mut spec.plan(3).unwrap());
        let b = jobs(&mut spec.plan(3).unwrap());
        assert_eq!(a, b, "same spec, same jobs");
        for j in &a {
            assert!(j.id >= STORM_ID_BASE);
            assert_eq!(j.arrival, 30);
            assert_eq!(j.fanout(), 3);
            assert!(j.weight >= 1.0 && j.ept.iter().all(|&e| e >= 1.0));
        }
        // a different seed gives a different storm
        let c = jobs(&mut FaultSpec::parse("storm=4@30,seed=10").unwrap().plan(3).unwrap());
        assert_ne!(a, c);
    }

    #[test]
    fn split_shards_remaps_machine_events_and_retains_storms() {
        // Park of 5 split 3 + 2: machine 4 is shard 1's local machine 1.
        let spec =
            FaultSpec::parse("down=4@10+5,slow=1@20+10x3,storm=2@30,policy=lose,seed=2").unwrap();
        let plan = spec.plan(5).unwrap();
        let (plans, storms) = plan.split_shards(&[(0, 3), (3, 2)]);
        assert_eq!(plans.len(), 2);
        assert_eq!(plans[0].machines(), 3);
        assert_eq!(plans[1].machines(), 2);
        assert_eq!(plans[0].key(), plan.key(), "fault key survives the split");
        assert_eq!(plans[0].policy, DownPolicy::Lose);
        // shard 0 owns machine 1's slow window
        let mut p0 = plans.into_iter().next().unwrap();
        assert!(matches!(p0.pop_due(20).unwrap().kind, FaultKind::SlowStart(1, 3)));
        assert!(matches!(p0.pop_due(30).unwrap().kind, FaultKind::SlowEnd(1)));
        assert!(p0.is_done());
        // shard 1 gets down/up for local machine 1 — checked via a
        // fresh split (p1 was consumed by the into_iter above)
        let (plans, _) = plan.split_shards(&[(0, 3), (3, 2)]);
        let mut p1 = plans.into_iter().nth(1).unwrap();
        assert!(matches!(p1.pop_due(10).unwrap().kind, FaultKind::Down(1)));
        assert!(matches!(p1.pop_due(15).unwrap().kind, FaultKind::Up(1)));
        assert!(p1.is_done());
        // the storm is the routing layer's, jobs untouched (full-park EPT)
        assert_eq!(storms.len(), 1);
        assert_eq!(storms[0].tick, 30);
        match &storms[0].kind {
            FaultKind::Storm(jobs) => {
                assert_eq!(jobs.len(), 2);
                assert!(jobs.iter().all(|j| j.fanout() == 5));
            }
            other => panic!("expected storm, got {other:?}"),
        }
    }

    #[test]
    fn down_range_parses_canonically_and_expands_per_machine() {
        let spec = FaultSpec::parse("down=2..4@10+5,seed=3").unwrap();
        assert_eq!(spec.render(), "down=2..4@10+5,seed=3");
        assert_eq!(FaultSpec::parse(&spec.render()).unwrap(), spec);
        // the plan expands the rack to per-machine down/up pairs, in
        // ascending machine order within each tick
        let mut plan = spec.plan(5).unwrap();
        for m in 2..=4usize {
            let ev = plan.pop_due(10).unwrap();
            assert!(matches!(ev.kind, FaultKind::Down(got) if got == m), "machine {m}");
        }
        for m in 2..=4usize {
            let ev = plan.pop_due(15).unwrap();
            assert!(matches!(ev.kind, FaultKind::Up(got) if got == m), "machine {m}");
        }
        assert!(plan.is_done());
        // the whole range must fit the park
        assert!(spec.plan(4).is_err(), "machine 4 does not exist in a 4-park");
        // degenerate and malformed ranges
        assert_eq!(
            FaultSpec::parse("down=3..3@5+5").unwrap().render(),
            "down=3@5+5",
            "M..M canonicalizes to the plain clause"
        );
        assert!(FaultSpec::parse("down=3..2@5+5").is_err(), "reversed range");
        assert!(FaultSpec::parse("down=1..4@0+5").is_err(), "tick 0");
        assert!(FaultSpec::parse("down=1..4@5+0").is_err(), "zero duration");
        assert!(FaultSpec::parse("down=a..4@5+5").is_err(), "non-numeric bound");
    }

    #[test]
    fn downs_samples_distinct_machines_deterministically() {
        let spec = FaultSpec::parse("downs=3@10+5,seed=11").unwrap();
        assert_eq!(spec.render(), "downs=3@10+5,seed=11");
        assert_eq!(FaultSpec::parse(&spec.render()).unwrap(), spec);
        let victims = |p: &mut FaultPlan| -> Vec<MachineId> {
            let mut out = Vec::new();
            while let Some(ev) = p.pop_due(10) {
                match ev.kind {
                    FaultKind::Down(m) => out.push(m),
                    other => panic!("expected down, got {other:?}"),
                }
            }
            out
        };
        let a = victims(&mut spec.plan(8).unwrap());
        let b = victims(&mut spec.plan(8).unwrap());
        assert_eq!(a, b, "same spec, same sampled machine set");
        assert_eq!(a.len(), 3);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "distinct + ascending: {a:?}");
        assert!(a.iter().all(|&m| m < 8), "in range: {a:?}");
        // the paired ups retire the same set, in the same order
        let mut plan = spec.plan(8).unwrap();
        let _ = victims(&mut plan);
        for &m in &a {
            assert!(matches!(plan.pop_due(15).unwrap().kind, FaultKind::Up(got) if got == m));
        }
        assert!(plan.is_done());
        // K == park size downs every machine, whatever the seed
        let all = victims(&mut FaultSpec::parse("downs=4@10+5,seed=99").unwrap().plan(4).unwrap());
        assert_eq!(all, vec![0, 1, 2, 3]);
        // the sample must fit the park — caught at plan time, like down=M
        assert!(spec.plan(2).is_err(), "3 machines from a 2-park");
    }

    #[test]
    fn down_range_splits_across_shards_like_per_machine_downs() {
        // Park of 5 split 3 + 2: the rack 1..3 straddles the boundary —
        // machines 1, 2 stay shard-0-local, machine 3 becomes shard 1's
        // local machine 0.
        let spec = FaultSpec::parse("down=1..3@10+5").unwrap();
        let plan = spec.plan(5).unwrap();
        let (plans, storms) = plan.split_shards(&[(0, 3), (3, 2)]);
        assert!(storms.is_empty());
        let mut p0 = plans[0].clone();
        assert!(matches!(p0.pop_due(10).unwrap().kind, FaultKind::Down(1)));
        assert!(matches!(p0.pop_due(10).unwrap().kind, FaultKind::Down(2)));
        assert!(matches!(p0.pop_due(15).unwrap().kind, FaultKind::Up(1)));
        assert!(matches!(p0.pop_due(15).unwrap().kind, FaultKind::Up(2)));
        assert!(p0.is_done());
        let mut p1 = plans[1].clone();
        assert!(matches!(p1.pop_due(10).unwrap().kind, FaultKind::Down(0)));
        assert!(matches!(p1.pop_due(15).unwrap().kind, FaultKind::Up(0)));
        assert!(p1.is_done());
    }

    #[test]
    fn drop_clauses_never_reach_the_engine_plan() {
        let spec = FaultSpec::parse("drop=0@100").unwrap();
        let plan = spec.plan(2).unwrap();
        assert!(plan.is_done(), "drop is serve-side only");
        assert_eq!(spec.drops().collect::<Vec<_>>(), vec![(0, 100)]);
    }
}
