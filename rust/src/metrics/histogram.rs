//! Log-bucketed latency histogram — percentile reporting for the
//! serving-style metrics (p50/p95/p99 queue latency), cheap enough for
//! the coordinator hot path (one increment per completion).

/// Histogram over u64 tick values with power-of-two-ish buckets:
/// sub-bucket resolution of 1/8 within each octave (HdrHistogram-lite).
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: u128,
    max: u64,
}

const SUB: usize = 8;

fn bucket_of(v: u64) -> usize {
    if v < SUB as u64 {
        return v as usize;
    }
    let octave = 63 - v.leading_zeros() as usize; // >= 3
    let sub = ((v >> (octave - 3)) & 7) as usize; // top 3 bits below msb
    SUB + (octave - 3) * SUB + sub
}

fn bucket_lower_bound(i: usize) -> u64 {
    if i < SUB {
        return i as u64;
    }
    let octave = (i - SUB) / SUB + 3;
    let sub = (i - SUB) % SUB;
    (1u64 << octave) + ((sub as u64) << (octave - 3))
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: vec![0; SUB + 61 * SUB],
            count: 0,
            sum: 0,
            max: 0,
        }
    }

    pub fn record(&mut self, v: u64) {
        self.record_n(v, 1);
    }

    /// Record `n` identical samples in O(1) — equivalent to calling
    /// [`Self::record`] `n` times. Lets tickless drive loops keep
    /// per-tick telemetry histograms bit-identical while skipping the
    /// ticks themselves (the skipped ticks all sample the same value).
    pub fn record_n(&mut self, v: u64, n: u64) {
        if n == 0 {
            return;
        }
        self.buckets[bucket_of(v)] += n;
        self.count += n;
        self.sum += (v as u128) * (n as u128);
        self.max = self.max.max(v);
    }

    /// Fold another histogram into this one, element-wise: afterwards
    /// this histogram reports exactly what it would had every sample of
    /// `other` been recorded here directly (the bucket layout is fixed
    /// at construction, so merging is pure addition). This is how the
    /// sharded coordinator aggregates per-shard latency distributions
    /// without losing percentile fidelity to pre-summarized scalars.
    pub fn merge(&mut self, other: &Histogram) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate quantile (bucket lower bound; <= 12.5% relative
    /// error by construction).
    ///
    /// `q = 0` (or below) returns the minimum recorded value, up to
    /// bucket resolution: the target rank is clamped to at least 1, so
    /// the scan stops at the first non-empty bucket instead of
    /// degenerating to "0 samples seen satisfies rank 0". This is also
    /// why `quantile(0.125)` over the eight samples `0..=7` is 0 — rank
    /// 1 lands in the minimum's bucket.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * self.count as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return bucket_lower_bound(i).min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_for_small_values() {
        let mut h = Histogram::new();
        for v in [0u64, 1, 2, 3, 4, 5, 6, 7] {
            h.record(v);
        }
        assert_eq!(h.count(), 8);
        assert_eq!(h.quantile(0.125), 0);
        assert_eq!(h.p50(), 3);
        assert_eq!(h.max(), 7);
        assert!((h.mean() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_zero_is_the_minimum_recorded_value() {
        // exact buckets below SUB: q=0 is the true minimum
        let mut h = Histogram::new();
        for v in [3u64, 5, 7] {
            h.record(v);
        }
        assert_eq!(h.quantile(0.0), 3);
        assert_eq!(h.quantile(-1.0), 3, "q clamps into [0, 1]");
        // log buckets: q=0 is the minimum's bucket lower bound
        let mut big = Histogram::new();
        for v in [42u64, 100, 7000] {
            big.record(v);
        }
        assert_eq!(big.quantile(0.0), bucket_lower_bound(bucket_of(42)));
        // a lone zero sample stays zero
        let mut z = Histogram::new();
        z.record(0);
        assert_eq!(z.quantile(0.0), 0);
    }

    #[test]
    fn bucket_bounds_monotone_and_consistent() {
        let mut last = 0;
        for i in 0..200 {
            let lb = bucket_lower_bound(i);
            assert!(lb >= last, "bucket {i}");
            last = lb;
            // the lower bound maps back into its own bucket
            assert_eq!(bucket_of(lb), i, "bucket {i} lb {lb}");
        }
    }

    #[test]
    fn quantile_error_bounded() {
        let mut h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        for (q, want) in [(0.5, 5000.0), (0.95, 9500.0), (0.99, 9900.0)] {
            let got = h.quantile(q) as f64;
            assert!(
                (got - want).abs() / want < 0.13,
                "q{q}: got {got} want {want}"
            );
        }
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.p99(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn merge_equals_recording_everything_in_one_histogram() {
        let mut merged = Histogram::new();
        let mut oracle = Histogram::new();
        let mut parts = [Histogram::new(), Histogram::new(), Histogram::new()];
        for (i, v) in [0u64, 3, 42, 977, 7000, 12, 12, 1].iter().enumerate() {
            parts[i % 3].record(*v);
            oracle.record(*v);
        }
        for p in &parts {
            merged.merge(p);
        }
        assert_eq!(merged.count(), oracle.count());
        assert_eq!(merged.mean(), oracle.mean());
        assert_eq!(merged.max(), oracle.max());
        for q in [0.0, 0.5, 0.95, 0.99] {
            assert_eq!(merged.quantile(q), oracle.quantile(q), "q{q}");
        }
        // merging an empty histogram is a no-op
        let before = merged.count();
        merged.merge(&Histogram::new());
        assert_eq!(merged.count(), before);
    }

    #[test]
    fn merge_is_associative_with_the_empty_histogram_as_identity() {
        let fill = |vals: &[u64]| {
            let mut h = Histogram::new();
            for &v in vals {
                h.record(v);
            }
            h
        };
        let a = fill(&[1, 9, 64, 64]);
        let b = fill(&[0, 0, 4000]);
        let c = fill(&[77]);
        // (a + b) + c == a + (b + c), through every report surface
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left.count(), right.count());
        assert_eq!(left.mean(), right.mean());
        assert_eq!(left.max(), right.max());
        for q in [0.0, 0.25, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(left.quantile(q), right.quantile(q), "q{q}");
        }
        // the empty histogram is a left identity too (merging *into* a
        // fresh one reports exactly the source)
        let mut id = Histogram::new();
        id.merge(&a);
        assert_eq!(id.count(), a.count());
        assert_eq!(id.mean(), a.mean());
        assert_eq!(id.max(), a.max());
        for q in [0.0, 0.5, 0.99] {
            assert_eq!(id.quantile(q), a.quantile(q), "q{q}");
        }
    }

    #[test]
    fn merged_quantiles_reflect_combined_mass_not_averaged_summaries() {
        // Two shards with disjoint latency regimes: the merged median
        // must land in the low regime (half the combined mass) and the
        // merged p95 in the high one — what pre-summarized per-shard
        // scalars cannot reconstruct.
        let mut low = Histogram::new();
        for v in 1..=1000u64 {
            low.record(v);
        }
        let mut high = Histogram::new();
        for v in 9001..=10_000u64 {
            high.record(v);
        }
        low.merge(&high);
        assert_eq!(low.count(), 2000);
        let p50 = low.p50() as f64;
        assert!(
            (p50 - 1000.0).abs() / 1000.0 < 0.13,
            "merged p50 {p50} should sit at the low regime's edge"
        );
        let p95 = low.quantile(0.95) as f64;
        assert!(
            (9001.0..=10_000.0).contains(&p95),
            "merged p95 {p95} should come from the high regime"
        );
    }

    #[test]
    fn record_n_equals_repeated_record() {
        let mut bulk = Histogram::new();
        let mut looped = Histogram::new();
        for (v, n) in [(0u64, 500), (3, 2), (977, 41), (12, 0)] {
            bulk.record_n(v, n);
            for _ in 0..n {
                looped.record(v);
            }
        }
        assert_eq!(bulk.count(), looped.count());
        assert_eq!(bulk.mean(), looped.mean());
        assert_eq!(bulk.max(), looped.max());
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(bulk.quantile(q), looped.quantile(q), "q{q}");
        }
    }
}
