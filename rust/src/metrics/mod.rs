//! Schedule-quality metrics (Section 7.1): fairness, load balancing
//! (coefficient of variation), latency, and throughput — plus serving-
//! style latency percentiles via [`Histogram`].

mod histogram;

pub use histogram::Histogram;

/// Per-run metric accumulator: feed it assignment/latency observations,
/// read the paper's four comparison metrics at the end.
#[derive(Debug, Clone)]
pub struct MetricSet {
    machines: usize,
    /// Jobs assigned per machine.
    pub jobs_per_machine: Vec<usize>,
    /// Sum of per-job queue latency (creation -> execution start), per machine.
    latency_sum: Vec<f64>,
    latency_count: Vec<usize>,
    /// Jobs-assigned counts per observation interval (for CV load balance).
    interval_counts: Vec<Vec<usize>>,
    current_interval: Vec<usize>,
    interval_len: u64,
    last_interval_start: u64,
    /// Total jobs scheduled and the tick span, for throughput.
    scheduled: usize,
    first_tick: Option<u64>,
    last_tick: u64,
}

impl MetricSet {
    pub fn new(machines: usize, interval_len: u64) -> Self {
        MetricSet {
            machines,
            jobs_per_machine: vec![0; machines],
            latency_sum: vec![0.0; machines],
            latency_count: vec![0; machines],
            interval_counts: Vec::new(),
            current_interval: vec![0; machines],
            interval_len: interval_len.max(1),
            last_interval_start: 0,
            scheduled: 0,
            first_tick: None,
            last_tick: 0,
        }
    }

    /// Record a job assignment to `machine` at `tick`.
    pub fn record_assignment(&mut self, machine: usize, tick: u64) {
        self.roll_intervals(tick);
        self.jobs_per_machine[machine] += 1;
        self.current_interval[machine] += 1;
        self.scheduled += 1;
        self.first_tick.get_or_insert(tick);
        self.last_tick = self.last_tick.max(tick);
    }

    /// Record a job's queue latency: creation tick -> execution start tick.
    pub fn record_latency(&mut self, machine: usize, created: u64, started: u64) {
        debug_assert!(started >= created);
        self.latency_sum[machine] += (started - created) as f64;
        self.latency_count[machine] += 1;
    }

    fn roll_intervals(&mut self, tick: u64) {
        while tick >= self.last_interval_start + self.interval_len {
            self.interval_counts
                .push(std::mem::replace(&mut self.current_interval, vec![0; self.machines]));
            self.last_interval_start += self.interval_len;
        }
    }

    /// Finalize and compute the summary metrics.
    pub fn finish(mut self) -> ScheduleMetrics {
        if self.current_interval.iter().any(|&c| c > 0) {
            self.interval_counts.push(self.current_interval.clone());
        }
        let avg_latency: Vec<f64> = (0..self.machines)
            .map(|m| {
                if self.latency_count[m] == 0 {
                    0.0
                } else {
                    self.latency_sum[m] / self.latency_count[m] as f64
                }
            })
            .collect();
        let overall_latency = {
            let n: usize = self.latency_count.iter().sum();
            if n == 0 {
                0.0
            } else {
                self.latency_sum.iter().sum::<f64>() / n as f64
            }
        };
        let span = self
            .first_tick
            .map_or(1, |f| (self.last_tick - f + 1).max(1));
        ScheduleMetrics {
            jobs_per_machine: self.jobs_per_machine.clone(),
            avg_latency_per_machine: avg_latency,
            avg_latency: overall_latency,
            load_balance_cv: load_balance_cv(&self.interval_counts),
            fairness: jains_index(&self.jobs_per_machine),
            starvation: self.jobs_per_machine.iter().any(|&c| c == 0)
                && self.scheduled >= self.machines,
            throughput: self.scheduled as f64 / span as f64,
            total_scheduled: self.scheduled,
        }
    }
}

/// Load balancing as the paper defines it: the Coefficient of Variation
/// of per-machine job counts across scheduling intervals (lower = better).
pub fn load_balance_cv(interval_counts: &[Vec<usize>]) -> f64 {
    // Pool all (interval, machine) observations.
    let obs: Vec<f64> = interval_counts
        .iter()
        .flat_map(|v| v.iter().map(|&c| c as f64))
        .collect();
    coefficient_of_variation(&obs)
}

/// CV = sigma / mu (0 when mean is 0).
pub fn coefficient_of_variation(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mu = xs.iter().sum::<f64>() / xs.len() as f64;
    if mu == 0.0 {
        return 0.0;
    }
    let var = xs.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / xs.len() as f64;
    var.sqrt() / mu
}

/// Jain's fairness index over per-machine job counts: 1 = perfectly
/// fair, 1/n = one machine hogs everything. Used as the quantitative
/// form of the paper's "low-performing machines are not starved".
pub fn jains_index(counts: &[usize]) -> f64 {
    if counts.is_empty() {
        return 1.0;
    }
    let s: f64 = counts.iter().map(|&c| c as f64).sum();
    if s == 0.0 {
        return 1.0;
    }
    let sq: f64 = counts.iter().map(|&c| (c as f64) * (c as f64)).sum();
    s * s / (counts.len() as f64 * sq)
}

/// Final metric bundle for one scheduler run.
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduleMetrics {
    pub jobs_per_machine: Vec<usize>,
    pub avg_latency_per_machine: Vec<f64>,
    /// Mean queue latency across all jobs (ticks).
    pub avg_latency: f64,
    /// Coefficient of variation of per-interval machine loads.
    pub load_balance_cv: f64,
    /// Jain's index of the final job distribution.
    pub fairness: f64,
    /// True if some machine received zero jobs despite enough work.
    pub starvation: bool,
    /// Jobs scheduled per tick over the active span.
    pub throughput: f64,
    pub total_scheduled: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jains_index_extremes() {
        assert!((jains_index(&[10, 10, 10]) - 1.0).abs() < 1e-12);
        let skew = jains_index(&[30, 0, 0]);
        assert!((skew - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn cv_zero_for_constant() {
        assert_eq!(coefficient_of_variation(&[5.0, 5.0, 5.0]), 0.0);
        assert!(coefficient_of_variation(&[1.0, 9.0]) > 0.5);
    }

    #[test]
    fn metricset_counts_and_latency() {
        let mut m = MetricSet::new(2, 10);
        m.record_assignment(0, 1);
        m.record_assignment(0, 2);
        m.record_assignment(1, 3);
        m.record_latency(0, 1, 5);
        m.record_latency(0, 2, 4);
        m.record_latency(1, 3, 13);
        let s = m.finish();
        assert_eq!(s.jobs_per_machine, vec![2, 1]);
        assert_eq!(s.avg_latency_per_machine, vec![3.0, 10.0]);
        assert!((s.avg_latency - 16.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.total_scheduled, 3);
        assert!(!s.starvation);
    }

    #[test]
    fn starvation_detected() {
        let mut m = MetricSet::new(3, 10);
        for t in 0..9 {
            m.record_assignment(t % 2, t as u64);
        }
        assert!(m.finish().starvation);
    }

    #[test]
    fn intervals_roll_over() {
        let mut m = MetricSet::new(1, 5);
        m.record_assignment(0, 1);
        m.record_assignment(0, 7); // second interval
        m.record_assignment(0, 12); // third interval
        let s = m.finish();
        assert_eq!(s.total_scheduled, 3);
        // three intervals of one job each -> CV 0
        assert_eq!(s.load_balance_cv, 0.0);
    }

    #[test]
    fn throughput_span() {
        let mut m = MetricSet::new(1, 100);
        m.record_assignment(0, 10);
        m.record_assignment(0, 19);
        let s = m.finish();
        assert!((s.throughput - 2.0 / 10.0).abs() < 1e-12);
    }
}
