//! The one versioned-artifact layer: every durable measurement file the
//! repo writes (`BENCH_*.json` sweep records, `SERVE_*.json` serve
//! records) goes through this module.
//!
//! Before it existed the repo carried two drifting artifact
//! vocabularies — `sweep::record` (schema string, jsonio glue, FNV-1a
//! digest, diff classification) and `coordinator::record` (a second
//! schema check + parse-back with no digest and no diff) — and every new
//! record type would have forced a third copy. Exactly like the engine
//! registry consolidation, this module is the single API:
//!
//! * **Schema registry** — [`Schema`] is one family+version type;
//!   [`SWEEP_RECORD`] and [`SERVE_RECORD`] are its instances, and
//!   [`Schema::check`] is the single unsupported-schema error path
//!   (wrong version, wrong family, and unknown tags each get a precise
//!   message instead of a field error).
//! * **Codec plumbing** — the [`Artifact`] trait owns
//!   `to_json`/`from_json`/`parse`/`render`, and [`load`]/[`store`]
//!   add path context and parse-back verification (a written artifact
//!   that does not round-trip to an equal record is a hard error, for
//!   every record type, before the caller reports success).
//! * **Digest** — [`fnv1a64`]/[`fnv1a64_hex`], the deterministic
//!   schedule-identity hash both record types embed (unit-tested
//!   against the published FNV-1a vectors).
//! * **Diff core** — [`diff`] classifies any two artifacts made of
//!   keyed [`diff::PerfCell`]s; `sweep diff` and `serve diff` are thin
//!   instantiations of [`diff::diff_records`].
//!
//! Everything here returns [`crate::error::Result`]; the strict field
//! accessors ([`get_str`], [`get_uint`], ...) reject corrupt or
//! hand-edited artifacts at parse time with the field name.

pub mod diff;

pub use diff::{
    diff_records, resolve_threshold, CellDiff, CellVerdict, Diffable, DiffOpts, DiffReport,
    PerfCell, THRESHOLD_ENV,
};

use crate::error::{Ctx, Result};
use crate::jsonio::Json;
use crate::{bail, err};

/// One versioned artifact schema: a dotted family name plus an integer
/// version, rendered as the `schema` field tag `<family>.v<version>`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Schema {
    pub family: &'static str,
    pub version: u32,
}

/// The sweep-record schema (`stannic.sweep.record.v1`).
pub const SWEEP_RECORD: Schema = Schema {
    family: "stannic.sweep.record",
    version: 1,
};

/// The serve-record schema (`stannic.serve.record.v1`).
pub const SERVE_RECORD: Schema = Schema {
    family: "stannic.serve.record",
    version: 1,
};

/// Every schema this build knows about — lets cross-family mistakes
/// ("fed a serve artifact to `sweep diff`") produce a precise message.
pub const REGISTRY: [Schema; 2] = [SWEEP_RECORD, SERVE_RECORD];

impl Schema {
    /// The tag embedded in the artifact's `schema` field.
    pub fn tag(&self) -> String {
        format!("{}.v{}", self.family, self.version)
    }

    /// Split a tag into (family, version); `None` when the tag does not
    /// end in `.v<digits>`.
    pub fn split_tag(tag: &str) -> Option<(&str, u32)> {
        let (family, version) = tag.rsplit_once(".v")?;
        version.parse::<u32>().ok().map(|v| (family, v))
    }

    /// The single unsupported-schema error path: verify the document's
    /// `schema` field names exactly this schema, distinguishing a
    /// version mismatch from a different artifact family from an
    /// unrecognized tag.
    pub fn check(&self, j: &Json) -> Result<()> {
        let tag = j
            .get("schema")
            .and_then(Json::as_str)
            .ctx("missing string field 'schema'")?;
        if tag == self.tag() {
            return Ok(());
        }
        match Schema::split_tag(tag) {
            // version != self.version: a same-version tag that failed the
            // exact-tag equality is non-canonical (e.g. `...v01`) and
            // falls through to "unrecognized" instead of the absurd
            // "v1 unsupported (this build reads v1)".
            Some((family, version)) if family == self.family && version != self.version => bail!(
                "unsupported {} schema version v{version} (this build reads v{})",
                self.family,
                self.version
            ),
            Some((family, _))
                if family != self.family && REGISTRY.iter().any(|s| s.family == family) =>
            {
                bail!(
                    "artifact is a {family} record, not {} (schema '{tag}')",
                    self.family
                )
            }
            _ => bail!(
                "unrecognized artifact schema '{tag}' (expected {})",
                self.tag()
            ),
        }
    }
}

/// A persisted, versioned measurement record. Implementors provide the
/// JSON layout; the trait provides the text codec, and [`load`]/
/// [`store`] the verified file I/O.
pub trait Artifact: Sized + PartialEq {
    /// The registry entry this record type serializes as.
    const SCHEMA: Schema;

    /// Serialize to the JSON tree (must embed `Self::SCHEMA.tag()` under
    /// the `schema` key).
    fn to_json(&self) -> Json;

    /// Deserialize from a JSON tree; implementations call
    /// `Self::SCHEMA.check(j)?` first so every record type shares the
    /// one schema error path.
    fn from_json(j: &Json) -> Result<Self>;

    /// Parse an artifact from its serialized text.
    fn parse(text: &str) -> Result<Self> {
        Self::from_json(&Json::parse(text)?)
    }

    /// Serialize to the artifact text (compact JSON + trailing newline).
    fn render(&self) -> String {
        let mut text = self.to_json().render();
        text.push('\n');
        text
    }
}

/// Read and parse an artifact file, with the path in the error chain.
pub fn load<A: Artifact>(path: &str) -> Result<A> {
    let text = std::fs::read_to_string(path).with_ctx(|| format!("reading {path}"))?;
    A::parse(&text).with_ctx(|| format!("parsing {path}"))
}

/// Write an artifact and parse-back-verify it: the written file must
/// round-trip to an equal record before the caller may report success
/// (keeps CI's artifact checks honest for every record type).
pub fn store<A: Artifact>(path: &str, a: &A) -> Result<()> {
    std::fs::write(path, a.render()).with_ctx(|| format!("writing {path}"))?;
    let back: A = load(path).ctx("recorded artifact failed to parse back")?;
    if back != *a {
        bail!("recorded artifact round-trip mismatch at {path}");
    }
    Ok(())
}

/// FNV-1a 64-bit — deterministic, dependency-free digest for schedule
/// outcomes (not cryptographic; collisions only hide a parity break that
/// the golden test would catch anyway).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The 16-hex-char form both record types embed as their digest field.
pub fn fnv1a64_hex(bytes: &[u8]) -> String {
    format!("{:016x}", fnv1a64(bytes))
}

/// Wall-clock throughput shared by both record types: jobs per second,
/// 0.0 when the wall time is absent (recorders floor `wall_ns` at 1, so
/// a zero only appears in hand-edited artifacts, where the diff flags
/// the cell as unmeasured).
pub fn jobs_per_sec(jobs: usize, wall_ns: u64) -> f64 {
    if wall_ns == 0 {
        0.0
    } else {
        jobs as f64 / (wall_ns as f64 / 1e9)
    }
}

pub fn get_str(j: &Json, k: &str) -> Result<String> {
    j.get(k)
        .and_then(Json::as_str)
        .map(str::to_string)
        .with_ctx(|| format!("missing string field '{k}'"))
}

pub fn get_f64(j: &Json, k: &str) -> Result<f64> {
    j.get(k)
        .and_then(Json::as_f64)
        .with_ctx(|| format!("missing numeric field '{k}'"))
}

/// Reject negative/fractional/huge values for integer-typed fields
/// instead of silently saturating through `as` casts — a hand-edited
/// artifact should fail at parse time with the field name, not surface
/// later as a confusing digest mismatch.
pub fn uint_value(v: f64, what: &str) -> Result<u64> {
    if v.is_nan() || v < 0.0 || v.fract() != 0.0 || v > 9_007_199_254_740_992.0 {
        return Err(err!("{what}: expected a non-negative integer, got {v}"));
    }
    Ok(v as u64)
}

pub fn get_uint(j: &Json, k: &str) -> Result<u64> {
    uint_value(get_f64(j, k)?, k)
}

/// Require an actual JSON array (`Json::items` silently yields an empty
/// slice for non-arrays, which would let a corrupt artifact parse).
pub fn get_arr<'a>(j: &'a Json, k: &str) -> Result<&'a [Json]> {
    match j.get(k) {
        Some(Json::Arr(v)) => Ok(v),
        Some(_) => Err(err!("field '{k}': expected an array")),
        None => Err(err!("missing array field '{k}'")),
    }
}

/// An array of non-negative integers (e.g. per-machine job counts),
/// with the same strictness as [`get_uint`] per element.
pub fn get_usize_arr(j: &Json, k: &str) -> Result<Vec<usize>> {
    get_arr(j, k)?
        .iter()
        .map(|v| {
            v.as_f64()
                .with_ctx(|| format!("non-numeric '{k}' entry"))
                .and_then(|n| uint_value(n, &format!("'{k}' entry")))
                .map(|n| n as usize)
        })
        .collect()
}

pub fn get_u64_str(j: &Json, k: &str) -> Result<u64> {
    get_str(j, k)?
        .parse::<u64>()
        .map_err(|e| err!("field '{k}': {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonio::{num, obj, s};

    #[test]
    fn fnv1a64_matches_published_vectors() {
        // Reference vectors from the FNV test suite
        // (draft-eastlake-fnv, fnv64a).
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
        assert_eq!(fnv1a64_hex(b"foobar"), "85944171f73967e8");
    }

    #[test]
    fn schema_tags_round_trip() {
        assert_eq!(SWEEP_RECORD.tag(), "stannic.sweep.record.v1");
        assert_eq!(SERVE_RECORD.tag(), "stannic.serve.record.v1");
        assert_eq!(
            Schema::split_tag("stannic.sweep.record.v1"),
            Some(("stannic.sweep.record", 1))
        );
        assert_eq!(
            Schema::split_tag("stannic.serve.record.v12"),
            Some(("stannic.serve.record", 12))
        );
        assert_eq!(Schema::split_tag("no-version-suffix"), None);
        assert_eq!(Schema::split_tag("family.vNaN"), None);
    }

    #[test]
    fn check_distinguishes_version_family_and_garbage() {
        let ok = obj(vec![("schema", s(SWEEP_RECORD.tag()))]);
        assert!(SWEEP_RECORD.check(&ok).is_ok());

        let missing = obj(vec![("other", num(1.0))]);
        let e = SWEEP_RECORD.check(&missing).unwrap_err();
        assert!(format!("{e:#}").contains("schema"), "{e:#}");

        let newer = obj(vec![("schema", s("stannic.sweep.record.v9"))]);
        let e = SWEEP_RECORD.check(&newer).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("unsupported"), "{msg}");
        assert!(msg.contains("v9"), "{msg}");
        assert!(msg.contains("reads v1"), "{msg}");

        let cross = obj(vec![("schema", s(SERVE_RECORD.tag()))]);
        let e = SWEEP_RECORD.check(&cross).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("stannic.serve.record"), "{msg}");
        assert!(msg.contains("not stannic.sweep.record"), "{msg}");

        let garbage = obj(vec![("schema", s("who.knows"))]);
        let e = SWEEP_RECORD.check(&garbage).unwrap_err();
        assert!(format!("{e:#}").contains("unrecognized"), "{e:#}");

        // a non-canonical spelling of the supported version must not
        // claim "v1 unsupported" from a build that reads v1
        let noncanon = obj(vec![("schema", s("stannic.sweep.record.v01"))]);
        let e = SWEEP_RECORD.check(&noncanon).unwrap_err();
        assert!(format!("{e:#}").contains("unrecognized"), "{e:#}");
    }

    #[test]
    fn strict_accessors_name_the_field() {
        let j = obj(vec![
            ("n", num(3.5)),
            ("u", num(-1.0)),
            ("s", s("text")),
            ("big", s("18446744073709551615")),
        ]);
        assert_eq!(get_f64(&j, "n").unwrap(), 3.5);
        assert_eq!(get_str(&j, "s").unwrap(), "text");
        assert_eq!(get_u64_str(&j, "big").unwrap(), u64::MAX);
        for (k, what) in [("n", "fractional"), ("u", "negative")] {
            let e = get_uint(&j, k).unwrap_err();
            assert!(format!("{e:#}").contains(k), "{what}: {e:#}");
        }
        assert!(get_str(&j, "absent").is_err());
        assert!(get_arr(&j, "s").is_err(), "non-array must be rejected");
        assert!(get_arr(&j, "absent").is_err());
    }

    #[derive(Debug, PartialEq)]
    struct Mini {
        v: u64,
    }

    impl Artifact for Mini {
        const SCHEMA: Schema = Schema {
            family: "stannic.sweep.record",
            version: 1,
        };

        fn to_json(&self) -> Json {
            obj(vec![
                ("schema", s(Self::SCHEMA.tag())),
                ("v", num(self.v as f64)),
            ])
        }

        fn from_json(j: &Json) -> Result<Mini> {
            Self::SCHEMA.check(j)?;
            Ok(Mini {
                v: get_uint(j, "v")?,
            })
        }
    }

    #[test]
    fn store_parse_back_verifies_and_load_adds_path_context() {
        let path = std::env::temp_dir().join(format!("stannic_artifact_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let m = Mini { v: 7 };
        store(&path, &m).unwrap();
        let back: Mini = load(&path).unwrap();
        assert_eq!(back, m);
        let e = load::<Mini>("/nonexistent/artifact.json").unwrap_err();
        assert!(
            format!("{e:#}").contains("/nonexistent/artifact.json"),
            "{e:#}"
        );
        let _ = std::fs::remove_file(&path);
    }
}
