//! Generic artifact diffing: the one classification/threshold/rendering
//! core behind `sweep diff` and `serve diff`.
//!
//! Any record type that can present itself as keyed [`PerfCell`]s — a
//! stable matching key, a deterministic identity string, and optionally
//! a perf scalar — gets the full pipeline: keyed-cell matching,
//! median-shift normalization (so a uniformly slower host doesn't flag
//! every cell), `Regression`/`Improvement`/`ParityBreak`/`Unmeasured`
//! classification, threshold + `--fail-on-shift` +
//! `STANNIC_PERF_THRESHOLD` handling, and [`DiffReport`] rendering.
//!
//! Identity mismatches are *parity breaks* (the deterministic outcome
//! changed — scheduling semantics, never a perf delta) and fail at any
//! threshold. Perf ratios are "goodness" ratios (>1 = better), so cells
//! whose scalar improves downward (latency percentiles) classify with
//! the same code as cells that improve upward (jobs/sec).
//!
//! Cells declare how their scalar was measured:
//!
//! * **noisy** cells (wall-clock derived) are the host-speed signal:
//!   the median shift is computed over them, and they are normalized by
//!   it — a uniformly slower host must not flag every sweep cell.
//! * deterministic cells (virtual-time derived) are host-independent,
//!   so they always compare raw: normalizing them would let a uniform
//!   real regression cancel itself through the median.
//! * **advisory** cells' perf verdicts never fail the gate — a record
//!   with a *single* noisy cell (serve's wall-clock jobs/sec) cannot
//!   distinguish host speed from regression, exactly like the
//!   whole-grid shift, so its regressions gate only via
//!   [`DiffOpts::fail_on_shift`]. Integrity verdicts (parity break,
//!   unmeasured) still gate on advisory cells: advisory waives perf
//!   judgement, not artifact integrity.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::bench::Table;
use crate::error::Result;
use crate::{bail, err, ensure};

/// Environment override for the diff gate threshold, read by every
/// artifact diff surface (`sweep diff`, `serve diff`, ci.sh).
pub const THRESHOLD_ENV: &str = "STANNIC_PERF_THRESHOLD";

/// One comparable observation extracted from a record: cells from two
/// artifacts are matched on `key`, parity-gated on `ident`, and
/// perf-gated on `perf`.
#[derive(Debug, Clone)]
pub struct PerfCell {
    /// Stable matching key (everything that must be equal for two cells
    /// to be the same measurement).
    pub key: String,
    /// Deterministic identity; matched cells with different identities
    /// are a parity break. Empty = no parity component.
    pub ident: String,
    /// Perf scalar; `None` = parity-only cell, `<= 0` = unmeasured.
    pub perf: Option<f64>,
    /// Direction of the scalar (latency percentiles improve downward,
    /// throughput improves upward).
    pub lower_is_better: bool,
    /// Wall-clock-derived (host-dependent) measurement: contributes to
    /// the median shift and is normalized by it. Deterministic
    /// (virtual-time) cells compare raw.
    pub noisy: bool,
    /// Perf verdicts (regression/improvement) never fail the gate;
    /// integrity verdicts (parity break, unmeasured) still do.
    pub advisory: bool,
}

impl PerfCell {
    /// A parity-only cell: gated purely on identity equality.
    pub fn parity(key: impl Into<String>, ident: impl Into<String>) -> PerfCell {
        PerfCell {
            key: key.into(),
            ident: ident.into(),
            perf: None,
            lower_is_better: false,
            noisy: false,
            advisory: false,
        }
    }

    /// A perf cell whose scalar improves upward (e.g. jobs/sec).
    pub fn higher(key: impl Into<String>, value: f64) -> PerfCell {
        PerfCell {
            key: key.into(),
            ident: String::new(),
            perf: Some(value),
            lower_is_better: false,
            noisy: false,
            advisory: false,
        }
    }

    /// A perf cell whose scalar improves downward (e.g. latency).
    pub fn lower(key: impl Into<String>, value: f64) -> PerfCell {
        PerfCell {
            key: key.into(),
            ident: String::new(),
            perf: Some(value),
            lower_is_better: true,
            noisy: false,
            advisory: false,
        }
    }

    /// Attach a deterministic identity to a perf cell (sweep cells carry
    /// both a digest and a throughput scalar).
    pub fn with_ident(mut self, ident: impl Into<String>) -> PerfCell {
        self.ident = ident.into();
        self
    }

    /// Mark the scalar as wall-clock derived (host-dependent).
    pub fn noisy(mut self) -> PerfCell {
        self.noisy = true;
        self
    }

    /// Mark the cell as advisory: its perf verdicts are shown but never
    /// gate (integrity verdicts still do).
    pub fn advisory(mut self) -> PerfCell {
        self.advisory = true;
        self
    }
}

/// A record type the generic differ understands.
pub trait Diffable {
    /// Kind tag for the report header and CLI usage ("sweep", "serve").
    const KIND: &'static str;
    /// Unit label for the perf value columns ("jobs/s", "value").
    const UNIT: &'static str;
    /// Human label for the report header.
    fn label(&self) -> &str;
    /// The record flattened into comparable cells.
    fn cells(&self) -> Vec<PerfCell>;
}

/// Diff configuration.
#[derive(Debug, Clone, Copy)]
pub struct DiffOpts {
    /// Relative per-cell goodness drop that counts as a regression
    /// (0.25 = fail on >25% worse).
    pub threshold: f64,
    /// Normalize each cell's ratio by the grid's median ratio, so a
    /// uniformly slower/faster host doesn't flag every cell.
    pub normalize: bool,
    /// Also *fail* the gate when the median shift itself regressed past
    /// the threshold. Off by default: the shift conflates real uniform
    /// slowdowns with baseline-host-vs-CI-host speed differences, so it
    /// is reported prominently but only gates when the caller knows
    /// both records come from comparable hosts (same-machine A/B runs).
    pub fail_on_shift: bool,
}

impl Default for DiffOpts {
    fn default() -> Self {
        DiffOpts {
            threshold: 0.25,
            normalize: true,
            fail_on_shift: false,
        }
    }
}

/// Resolve the gate threshold: explicit flag value beats the
/// [`THRESHOLD_ENV`] environment override beats the default; validated
/// to `[0, 1)` on every path.
pub fn resolve_threshold(flag: Option<&str>) -> Result<f64> {
    let threshold = match flag {
        Some(v) => v
            .parse::<f64>()
            .map_err(|e| err!("--threshold: expected number ({e})"))?,
        None => match std::env::var(THRESHOLD_ENV) {
            Ok(v) => v
                .parse::<f64>()
                .map_err(|e| err!("{THRESHOLD_ENV}: expected number ({e})"))?,
            Err(_) => DiffOpts::default().threshold,
        },
    };
    ensure!(
        (0.0..1.0).contains(&threshold),
        "threshold must be in [0, 1), got {threshold}"
    );
    Ok(threshold)
}

/// Per-cell diff verdict.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellVerdict {
    Unchanged,
    Regression,
    Improvement,
    /// The deterministic identity changed: scheduling semantics differ
    /// between the two records. Never a perf delta; requires an
    /// intentional re-bless of the baseline.
    ParityBreak,
    /// One side has no usable perf measurement (zero wall time in a
    /// hand-edited or corrupt artifact — recorders floor wall_ns at 1).
    /// Fails the gate: an unmeasured cell must not pass as "ok".
    Unmeasured,
}

impl CellVerdict {
    pub fn name(&self) -> &'static str {
        match self {
            CellVerdict::Unchanged => "ok",
            CellVerdict::Regression => "REGRESSION",
            CellVerdict::Improvement => "improvement",
            CellVerdict::ParityBreak => "PARITY-BREAK",
            CellVerdict::Unmeasured => "UNMEASURED",
        }
    }
}

/// One matched cell in a diff.
#[derive(Debug, Clone)]
pub struct CellDiff {
    pub key: String,
    /// Raw perf scalars (`None` for parity-only cells).
    pub old_value: Option<f64>,
    pub new_value: Option<f64>,
    /// Raw goodness ratio (>1 = better; 1.0 for parity-only or
    /// unmeasured cells).
    pub ratio: f64,
    /// Ratio divided by the grid's median shift for noisy cells
    /// (== `ratio` for deterministic cells or when normalization is
    /// off).
    pub norm_ratio: f64,
    pub verdict: CellVerdict,
    /// Advisory cells' perf verdicts are rendered but never fail the
    /// gate (integrity verdicts still do).
    pub advisory: bool,
}

/// Result of diffing two artifacts.
#[derive(Debug, Clone)]
pub struct DiffReport {
    /// Kind tag of the diffed record type ("sweep", "serve").
    pub kind: &'static str,
    /// Unit label for the value columns.
    pub unit: &'static str,
    pub old_label: String,
    pub new_label: String,
    pub cells: Vec<CellDiff>,
    pub only_in_old: Vec<String>,
    pub only_in_new: Vec<String>,
    /// Median goodness ratio across measured cells — the whole-grid
    /// (host) speed shift.
    pub shift: f64,
    pub threshold: f64,
    /// True when the median shift itself regressed past the threshold —
    /// a uniform slowdown *or* a slower host. Only fails the gate under
    /// [`DiffOpts::fail_on_shift`].
    pub global_regression: bool,
    /// Whether `global_regression` participates in [`Self::ok`].
    pub fail_on_shift: bool,
}

impl DiffReport {
    pub fn regressions(&self) -> usize {
        self.count(CellVerdict::Regression)
    }

    pub fn improvements(&self) -> usize {
        self.count(CellVerdict::Improvement)
    }

    pub fn parity_breaks(&self) -> usize {
        self.count(CellVerdict::ParityBreak)
    }

    pub fn unmeasured(&self) -> usize {
        self.count(CellVerdict::Unmeasured)
    }

    /// Gate counts exclude advisory cells' *perf* verdicts (regression/
    /// improvement carry no exit-code weight there), but integrity
    /// verdicts — parity breaks and unmeasured cells — always count:
    /// advisory waives perf judgement, not artifact integrity.
    fn count(&self, v: CellVerdict) -> usize {
        let integrity = matches!(v, CellVerdict::ParityBreak | CellVerdict::Unmeasured);
        self.cells
            .iter()
            .filter(|c| (integrity || !c.advisory) && c.verdict == v)
            .count()
    }

    /// Gate verdict: no per-cell regressions, no parity breaks, no
    /// unmeasured cells, full coverage of the baseline grid, and (only
    /// when `fail_on_shift` is set) no global slowdown.
    pub fn ok(&self) -> bool {
        self.regressions() == 0
            && self.parity_breaks() == 0
            && self.unmeasured() == 0
            && !(self.fail_on_shift && self.global_regression)
            && self.only_in_old.is_empty()
    }

    /// The CLI exit gate: `Err` with the failure summary when the diff
    /// must fail the build.
    pub fn gate(&self) -> Result<()> {
        if self.ok() {
            return Ok(());
        }
        bail!(
            "perf gate failed: {} regressions, {} parity breaks, {} unmeasured, \
             {} missing{} — re-bless the baseline if the change is intentional",
            self.regressions(),
            self.parity_breaks(),
            self.unmeasured(),
            self.only_in_old.len(),
            if self.fail_on_shift && self.global_regression {
                ", global slowdown"
            } else {
                ""
            }
        );
    }

    fn fmt_value(v: Option<f64>) -> String {
        match v {
            None => "-".to_string(),
            Some(v) if v >= 100.0 => format!("{v:.0}"),
            Some(v) => format!("{v:.2}"),
        }
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "{} diff: {} -> {} ({} matched cells, threshold {:.0}%)\n",
            self.kind,
            self.old_label,
            self.new_label,
            self.cells.len(),
            self.threshold * 100.0
        );
        let old_col = format!("old {}", self.unit);
        let new_col = format!("new {}", self.unit);
        let mut t = Table::new(&[
            "cell",
            old_col.as_str(),
            new_col.as_str(),
            "ratio",
            "norm",
            "verdict",
        ]);
        for c in &self.cells {
            // only the non-gating (perf) verdicts get the advisory tag;
            // parity breaks and unmeasured cells gate regardless
            let advisory_perf = c.advisory
                && matches!(
                    c.verdict,
                    CellVerdict::Regression | CellVerdict::Improvement
                );
            let verdict = if advisory_perf {
                format!("{} (advisory)", c.verdict.name())
            } else {
                c.verdict.name().to_string()
            };
            t.row(vec![
                c.key.clone(),
                Self::fmt_value(c.old_value),
                Self::fmt_value(c.new_value),
                format!("{:.3}", c.ratio),
                format!("{:.3}", c.norm_ratio),
                verdict,
            ]);
        }
        out.push_str(&t.render());
        let _ = writeln!(
            out,
            "\ngrid shift (median ratio): {:.3}x{}",
            self.shift,
            if self.global_regression && self.fail_on_shift {
                "  <- GLOBAL REGRESSION (gating: --fail-on-shift)"
            } else if self.global_regression {
                "  <- whole-grid slowdown (uniform regression OR slower \
                 host; advisory — gate with --fail-on-shift)"
            } else {
                ""
            }
        );
        for k in &self.only_in_old {
            let _ = writeln!(out, "MISSING in new record: {k}");
        }
        for k in &self.only_in_new {
            let _ = writeln!(out, "new cell (not in baseline): {k}");
        }
        let _ = writeln!(
            out,
            "{} regressions, {} improvements, {} parity breaks, {} unmeasured, {} missing => {}",
            self.regressions(),
            self.improvements(),
            self.parity_breaks(),
            self.unmeasured(),
            self.only_in_old.len(),
            if self.ok() { "OK" } else { "FAIL" }
        );
        out
    }
}

/// Diff two artifacts cell-by-cell (matched on the cell key).
pub fn diff_records<R: Diffable>(old: &R, new: &R, opts: &DiffOpts) -> DiffReport {
    let old_cells = old.cells();
    let new_cells = new.cells();
    let old_by_key: BTreeMap<&str, &PerfCell> =
        old_cells.iter().map(|c| (c.key.as_str(), c)).collect();
    let new_by_key: BTreeMap<&str, &PerfCell> =
        new_cells.iter().map(|c| (c.key.as_str(), c)).collect();

    let mut matched: Vec<(&PerfCell, &PerfCell)> = Vec::new();
    let mut only_in_old = Vec::new();
    for (key, o) in old_by_key.iter() {
        match new_by_key.get(*key) {
            Some(n) => matched.push((*o, *n)),
            None => only_in_old.push((*key).to_string()),
        }
    }
    let only_in_new: Vec<String> = new_by_key
        .keys()
        .filter(|k| !old_by_key.contains_key(*k))
        .map(|k| k.to_string())
        .collect();

    // Goodness ratio (>1 = better) for a matched pair with sane
    // measurements on both sides.
    let goodness = |o: &PerfCell, n: &PerfCell| -> Option<f64> {
        match (o.perf, n.perf) {
            (Some(a), Some(b)) if a > 0.0 && b > 0.0 => {
                Some(if o.lower_is_better { a / b } else { b / a })
            }
            _ => None,
        }
    };

    // Median goodness ratio over the *noisy* (host-dependent) measured
    // cells — the host-speed signal. Deterministic cells are excluded:
    // folding them in would let a uniform real regression cancel itself
    // through the median.
    let mut ratios: Vec<f64> = matched
        .iter()
        .filter(|(o, _)| o.noisy)
        .filter_map(|(o, n)| goodness(o, n))
        .collect();
    ratios.sort_by(|a, b| a.partial_cmp(b).expect("finite ratios"));
    let shift = match ratios.len() {
        0 => 1.0,
        n if n % 2 == 1 => ratios[n / 2],
        n => (ratios[n / 2 - 1] * ratios[n / 2]).sqrt(),
    };
    // On tiny grids the median IS the (possibly regressed) cell, so
    // normalizing by it would cancel the very signal we gate on — a
    // 10x-slower single-cell grid must not read as "unchanged". Below
    // this many noisy cells, their ratios are compared raw.
    const MIN_CELLS_TO_NORMALIZE: usize = 4;
    let denom = if opts.normalize && shift > 0.0 && ratios.len() >= MIN_CELLS_TO_NORMALIZE {
        shift
    } else {
        1.0
    };

    let cells: Vec<CellDiff> = matched
        .into_iter()
        .map(|(o, n)| {
            let measured = goodness(o, n);
            let ratio = measured.unwrap_or(1.0);
            // deterministic (virtual-time) cells always compare raw
            let norm_ratio = if o.noisy { ratio / denom } else { ratio };
            let verdict = if o.ident != n.ident {
                CellVerdict::ParityBreak
            } else if o.perf.is_none() && n.perf.is_none() {
                // parity-only cell: identity matched, nothing to measure
                CellVerdict::Unchanged
            } else if measured.is_none() {
                CellVerdict::Unmeasured
            } else if norm_ratio < 1.0 - opts.threshold {
                CellVerdict::Regression
            } else if norm_ratio > 1.0 + opts.threshold {
                CellVerdict::Improvement
            } else {
                CellVerdict::Unchanged
            };
            CellDiff {
                key: o.key.clone(),
                old_value: o.perf,
                new_value: n.perf,
                ratio,
                norm_ratio,
                verdict,
                advisory: o.advisory,
            }
        })
        .collect();

    DiffReport {
        kind: R::KIND,
        unit: R::UNIT,
        old_label: old.label().to_string(),
        new_label: new.label().to_string(),
        cells,
        only_in_old,
        only_in_new,
        shift,
        threshold: opts.threshold,
        global_regression: shift < 1.0 - opts.threshold,
        fail_on_shift: opts.fail_on_shift,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal Diffable: parity cells + mixed-direction perf cells,
    /// exercising exactly the surface the real records build on.
    struct Fake {
        label: String,
        cells: Vec<PerfCell>,
    }

    impl Diffable for Fake {
        const KIND: &'static str = "fake";
        const UNIT: &'static str = "value";

        fn label(&self) -> &str {
            &self.label
        }

        fn cells(&self) -> Vec<PerfCell> {
            self.cells.clone()
        }
    }

    fn fake(cells: Vec<PerfCell>) -> Fake {
        Fake {
            label: "fake".to_string(),
            cells,
        }
    }

    fn base_cells() -> Vec<PerfCell> {
        vec![
            PerfCell::parity("ident", "abc"),
            PerfCell::lower("lat_p50", 40.0),
            PerfCell::lower("lat_p99", 90.0),
            PerfCell::higher("jps", 1000.0),
            PerfCell::higher("thru", 2.5),
        ]
    }

    #[test]
    fn identical_records_pass_and_parity_cells_render_dashes() {
        let a = fake(base_cells());
        let b = fake(base_cells());
        let report = diff_records(&a, &b, &DiffOpts::default());
        assert!(report.ok(), "{}", report.render());
        assert!(report.gate().is_ok());
        assert_eq!(report.cells.len(), 5);
        assert!((report.shift - 1.0).abs() < 1e-12);
        let rendered = report.render();
        assert!(rendered.starts_with("fake diff: fake -> fake"), "{rendered}");
        assert!(rendered.contains("old value"), "{rendered}");
    }

    #[test]
    fn identity_mismatch_is_a_parity_break_not_a_perf_delta() {
        let a = fake(base_cells());
        let mut cells = base_cells();
        cells[0] = PerfCell::parity("ident", "different");
        let b = fake(cells);
        let report = diff_records(&a, &b, &DiffOpts::default());
        assert_eq!(report.parity_breaks(), 1, "{}", report.render());
        assert!(!report.ok());
        assert!(report.gate().is_err());
    }

    #[test]
    fn lower_is_better_cells_classify_by_goodness_ratio() {
        let a = fake(base_cells());
        let mut cells = base_cells();
        cells[2] = PerfCell::lower("lat_p99", 900.0); // 10x worse latency
        let b = fake(cells);
        let report = diff_records(&a, &b, &DiffOpts::default());
        assert_eq!(report.regressions(), 1, "{}", report.render());
        let bad = report
            .cells
            .iter()
            .find(|c| c.verdict == CellVerdict::Regression)
            .unwrap();
        assert_eq!(bad.key, "lat_p99");
        assert!(bad.ratio < 0.2, "goodness ratio: {}", bad.ratio);

        // the same-size move downward is an improvement
        let mut cells = base_cells();
        cells[2] = PerfCell::lower("lat_p99", 9.0);
        let b = fake(cells);
        let report = diff_records(&a, &b, &DiffOpts::default());
        assert_eq!(report.improvements(), 1, "{}", report.render());
        assert!(report.ok(), "improvement must not fail the gate");
    }

    #[test]
    fn uniform_deterministic_regressions_do_not_cancel() {
        // Deterministic (virtual-time) cells must compare raw: if they
        // were folded into the median, a change that makes EVERY cell
        // 2x worse would normalize to "unchanged" and pass the gate.
        let a = fake(base_cells());
        let b = fake(vec![
            PerfCell::parity("ident", "abc"),
            PerfCell::lower("lat_p50", 80.0),
            PerfCell::lower("lat_p99", 180.0),
            PerfCell::higher("jps", 500.0),
            PerfCell::higher("thru", 1.25),
        ]);
        let report = diff_records(&a, &b, &DiffOpts::default());
        assert_eq!(report.regressions(), 4, "{}", report.render());
        assert!(!report.ok());
    }

    #[test]
    fn noisy_cells_normalize_by_their_own_median() {
        let noisy_cells = |scale: f64, odd_one: f64| -> Vec<PerfCell> {
            vec![
                PerfCell::higher("c0", 1000.0 * scale).noisy(),
                PerfCell::higher("c1", 2000.0 * scale).noisy(),
                PerfCell::higher("c2", 3000.0 * scale).noisy(),
                PerfCell::higher("c3", 4000.0 * scale).noisy(),
                PerfCell::higher("c4", 5000.0 * odd_one).noisy(),
            ]
        };
        // whole grid uniformly 3x slower: a host effect, not a per-cell
        // regression — advisory shift only (the sweep semantics)
        let a = fake(noisy_cells(1.0, 1.0));
        let b = fake(noisy_cells(1.0 / 3.0, 1.0 / 3.0));
        let report = diff_records(&a, &b, &DiffOpts::default());
        assert_eq!(report.regressions(), 0, "{}", report.render());
        assert!(report.global_regression);
        assert!(report.ok(), "uniform noisy shift must not gate by default");
        let strict = DiffOpts {
            fail_on_shift: true,
            ..DiffOpts::default()
        };
        assert!(!diff_records(&a, &b, &strict).ok());

        // one noisy cell 10x slower while the rest hold: a real per-cell
        // regression, surfaced through the normalized ratio
        let b = fake(noisy_cells(1.0, 0.1));
        let report = diff_records(&a, &b, &DiffOpts::default());
        assert_eq!(report.regressions(), 1, "{}", report.render());
        assert!(!report.ok());
    }

    #[test]
    fn advisory_cells_report_but_never_gate() {
        let cells = |jps: f64| -> Vec<PerfCell> {
            vec![
                PerfCell::lower("lat_p50", 40.0),
                PerfCell::lower("lat_p99", 90.0),
                PerfCell::higher("jobs_per_sec", jps).noisy().advisory(),
            ]
        };
        let a = fake(cells(1000.0));
        let b = fake(cells(100.0)); // 10x slower wall clock
        let report = diff_records(&a, &b, &DiffOpts::default());
        assert_eq!(report.regressions(), 0, "{}", report.render());
        assert!(report.ok(), "advisory cell must not gate:\n{}", report.render());
        assert!(report.render().contains("(advisory)"), "{}", report.render());
        // ...but it IS the host-shift signal, so --fail-on-shift gates it
        assert!((report.shift - 0.1).abs() < 1e-9, "shift {}", report.shift);
        assert!(report.global_regression);
        let strict = DiffOpts {
            fail_on_shift: true,
            ..DiffOpts::default()
        };
        assert!(!diff_records(&a, &b, &strict).ok());

        // advisory waives perf judgement, not integrity: an unmeasured
        // advisory cell (corrupt artifact) still fails the gate
        let b = fake(cells(0.0));
        let report = diff_records(&a, &b, &DiffOpts::default());
        assert_eq!(report.unmeasured(), 1, "{}", report.render());
        assert!(!report.ok(), "{}", report.render());
    }

    #[test]
    fn unmeasured_and_missing_cells_fail() {
        let a = fake(base_cells());
        let mut cells = base_cells();
        cells[3] = PerfCell::higher("jps", 0.0);
        let b = fake(cells);
        let report = diff_records(&a, &b, &DiffOpts::default());
        assert_eq!(report.unmeasured(), 1, "{}", report.render());
        assert!(!report.ok());

        let mut cells = base_cells();
        cells.pop();
        let b = fake(cells);
        let report = diff_records(&a, &b, &DiffOpts::default());
        assert_eq!(report.only_in_old.len(), 1);
        assert!(!report.ok());
        // the reverse direction (grid grew) is fine
        let report = diff_records(&b, &a, &DiffOpts::default());
        assert_eq!(report.only_in_new.len(), 1);
        assert!(report.ok(), "{}", report.render());
    }

    #[test]
    fn threshold_resolution_precedence_and_validation() {
        assert_eq!(resolve_threshold(Some("0.4")).unwrap(), 0.4);
        assert!(resolve_threshold(Some("1.5")).is_err());
        assert!(resolve_threshold(Some("abc")).is_err());
        // No flag and no env (the harness does not set it for unit
        // tests) falls back to the default.
        if std::env::var(THRESHOLD_ENV).is_err() {
            assert_eq!(
                resolve_threshold(None).unwrap(),
                DiffOpts::default().threshold
            );
        }
    }
}
