//! The single engine registry: every scheduling backend in the repo,
//! one vocabulary, one constructor.
//!
//! Before this module existed the crate carried three parallel
//! engine-selection surfaces (`config::EngineKind`, `sweep::SweepEngine`
//! and `coordinator::build_engine`) with drifting name sets — exactly
//! the registry sprawl STOMP's pluggable-policy harness
//! (arXiv:2007.14371) warns against. [`EngineId`] is now the sole
//! source of truth for:
//!
//! * **names** — [`EngineId::name`] is the canonical spelling used in
//!   CLI output, sweep record keys and config JSON; [`EngineId::parse`]
//!   additionally accepts the historical aliases (`native`, `stannic`,
//!   `hercules`) so archived `RunConfig` files keep parsing;
//! * **lists** — [`EngineId::parse_list`] for `--engines`, where `all`
//!   selects [`EngineId::SOFTWARE`] (every artifact-free backend; the
//!   XLA engine needs compiled PJRT artifacts and must be named
//!   explicitly);
//! * **construction** — [`EngineId::build`] yields the boxed
//!   [`EngineAdapter`] the coordinator and sweep drive;
//! * **help/error text** — [`EngineId::USAGE`] is interpolated into
//!   every parse error and the CLI flag help, so the accepted-name list
//!   can never drift from the parser again.

pub mod portfolio;

use crate::baselines::{SimdSos, SoscEngine};
use crate::coordinator::{EngineAdapter, ShardedEngine};
use crate::err;
use crate::error::Result;
use crate::bail;
use crate::quant::Precision;
use crate::runtime::{ArtifactRegistry, CostImpl, XlaSosEngine};
use crate::scheduler::SosEngine;
use crate::sim::{hercules::HerculesSim, stannic::StannicSim};

use portfolio::PortfolioEngine;

/// Identifier of one scheduling backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineId {
    /// Golden software SOS engine (canonical name `sos`, alias `native`).
    Sos,
    /// Naive single-threaded software baseline.
    Sosc,
    /// Lane-vectorised software SOS.
    Simd,
    /// Cycle-accurate Stannic simulator (alias `stannic`).
    StannicSim,
    /// Cycle-accurate Hercules simulator (alias `hercules`).
    HerculesSim,
    /// Competitive portfolio meta-engine: races the golden SOS engine
    /// against the baseline schedulers in shadow replays and switches
    /// the live policy at window boundaries ([`portfolio`]).
    Portfolio,
    /// XLA/PJRT-offloaded cost engine (requires compiled artifacts).
    Xla,
}

impl EngineId {
    /// Every backend, including the artifact-gated XLA engine.
    pub const ALL: [EngineId; 7] = [
        EngineId::Sos,
        EngineId::Sosc,
        EngineId::Simd,
        EngineId::StannicSim,
        EngineId::HerculesSim,
        EngineId::Portfolio,
        EngineId::Xla,
    ];

    /// The artifact-free backends — what `--engines all` selects and
    /// what the sweep grid fans across (XLA needs a PJRT runtime that
    /// does not exist offline). The portfolio meta-engine is also
    /// excluded on purpose: it *wraps* these candidates rather than
    /// reimplementing SOS, its schedules intentionally diverge from
    /// the cross-engine parity group, and keeping it out of `all`
    /// keeps historical sweep/serve artifacts byte-identical — name it
    /// explicitly (`--engine portfolio`) to race the policies.
    pub const SOFTWARE: [EngineId; 5] = [
        EngineId::Sos,
        EngineId::Sosc,
        EngineId::Simd,
        EngineId::StannicSim,
        EngineId::HerculesSim,
    ];

    /// Every documented alias and the canonical name it maps to —
    /// [`EngineId::parse`] accepts these; the round-trip test pins the
    /// table against the parser so neither can drift.
    pub const ALIASES: [(&str, EngineId); 3] = [
        ("native", EngineId::Sos),
        ("stannic", EngineId::StannicSim),
        ("hercules", EngineId::HerculesSim),
    ];

    /// The one accepted-names string: interpolated into every parse
    /// error, the `--engine`/`--engines` CLI help, and the docs, so the
    /// vocabulary cannot drift between surfaces. List contexts
    /// ([`EngineId::parse_list`]) additionally accept `all` — say so at
    /// the call site (see the `--engines` help) rather than here, so
    /// single-engine errors never advertise a spelling they reject.
    pub const USAGE: &'static str =
        "sos(=native)|sosc|simd|stannic-sim(=stannic)|hercules-sim(=hercules)|portfolio|xla";

    /// Canonical name — the spelling used in CLI output, sweep record
    /// keys, and `RunConfig` JSON.
    pub fn name(self) -> &'static str {
        match self {
            EngineId::Sos => "sos",
            EngineId::Sosc => "sosc",
            EngineId::Simd => "simd",
            EngineId::StannicSim => "stannic-sim",
            EngineId::HerculesSim => "hercules-sim",
            EngineId::Portfolio => "portfolio",
            EngineId::Xla => "xla",
        }
    }

    /// Parse one engine name (canonical or alias).
    pub fn parse(name: &str) -> Result<EngineId> {
        match name.trim() {
            "sos" | "native" => Ok(EngineId::Sos),
            "sosc" => Ok(EngineId::Sosc),
            "simd" => Ok(EngineId::Simd),
            "stannic" | "stannic-sim" => Ok(EngineId::StannicSim),
            "hercules" | "hercules-sim" => Ok(EngineId::HerculesSim),
            "portfolio" => Ok(EngineId::Portfolio),
            "xla" => Ok(EngineId::Xla),
            other => Err(err!(
                "unknown engine '{other}' (expected {})",
                EngineId::USAGE
            )),
        }
    }

    /// Parse a comma-separated engine list; `"all"` selects
    /// [`EngineId::SOFTWARE`].
    pub fn parse_list(text: &str) -> Result<Vec<EngineId>> {
        if text.trim() == "all" {
            return Ok(EngineId::SOFTWARE.to_vec());
        }
        text.split(',')
            .map(EngineId::parse)
            .collect::<Result<Vec<EngineId>>>()
            .map_err(|e| err!("{e}; 'all' selects every artifact-free engine"))
    }

    /// True for backends that construct without compiled artifacts.
    pub fn is_software(self) -> bool {
        !matches!(self, EngineId::Xla)
    }

    /// Construct the backend. Software engines cannot fail; the XLA
    /// engine errors when the artifact registry is absent.
    pub fn build(
        self,
        machines: usize,
        depth: usize,
        alpha: f32,
        precision: Precision,
    ) -> Result<Box<dyn EngineAdapter>> {
        Ok(match self {
            EngineId::Sos => Box::new(SosEngine::new(machines, depth, alpha, precision)),
            EngineId::Sosc => Box::new(SoscEngine::new(machines, depth, alpha, precision)),
            EngineId::Simd => Box::new(SimdSos::new(machines, depth, alpha, precision)),
            EngineId::StannicSim => Box::new(StannicSim::new(machines, depth, alpha, precision)),
            EngineId::HerculesSim => Box::new(HerculesSim::new(machines, depth, alpha, precision)),
            EngineId::Portfolio => {
                Box::new(PortfolioEngine::new(machines, depth, alpha, precision))
            }
            EngineId::Xla => {
                let reg = ArtifactRegistry::open_default()?;
                Box::new(XlaSosEngine::new(
                    &reg,
                    CostImpl::Stannic,
                    machines,
                    depth,
                    alpha,
                    precision,
                )?)
            }
        })
    }

    /// Construct the backend split across `shards` independent parks
    /// behind the [`crate::coordinator::shard`] routing front end.
    /// Sharding composes shard-local scheduling with top-level routing,
    /// which only the golden tickless engine supports (each shard needs
    /// its own event horizon and fault layer); every other backend is
    /// refused up front so `serve --shards K` can never silently run
    /// single-domain. `shards = 1` yields the front end in its
    /// bit-identical-to-unsharded degenerate form.
    pub fn build_sharded(
        self,
        shards: usize,
        machines: usize,
        depth: usize,
        alpha: f32,
        precision: Precision,
    ) -> Result<Box<dyn EngineAdapter>> {
        if self != EngineId::Sos {
            bail!(
                "engine `{}` does not support sharding (use --engine sos)",
                self.name()
            );
        }
        if shards == 0 {
            bail!("--shards must be >= 1");
        }
        if shards > machines {
            bail!("cannot split {machines} machines into {shards} shards");
        }
        Ok(Box::new(ShardedEngine::new(
            shards, machines, depth, alpha, precision,
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_names_round_trip_through_parse() {
        for id in EngineId::ALL {
            assert_eq!(EngineId::parse(id.name()).unwrap(), id, "{}", id.name());
        }
    }

    #[test]
    fn historical_aliases_accepted() {
        assert_eq!(EngineId::parse("native").unwrap(), EngineId::Sos);
        assert_eq!(EngineId::parse("stannic").unwrap(), EngineId::StannicSim);
        assert_eq!(EngineId::parse("hercules").unwrap(), EngineId::HerculesSim);
    }

    /// The anti-drift gate: every canonical name and every documented
    /// alias must parse back to its variant, and every canonical name
    /// must appear verbatim in [`EngineId::USAGE`] — so registering a
    /// new engine (like `portfolio`) can never leave the help text or
    /// the parser stale. Whitespace robustness is exercised alongside,
    /// since `parse` trims and `parse_list` splits on commas.
    #[test]
    fn registry_names_round_trip_and_usage_stays_complete() {
        crate::testing::property("engine-name-round-trip", 64, |rng| {
            for id in EngineId::ALL {
                crate::testing::check(
                    EngineId::parse(id.name()) == Ok(id),
                    "canonical name parses back to its variant",
                )?;
                crate::testing::check(
                    EngineId::USAGE.contains(id.name()),
                    "canonical name appears verbatim in USAGE",
                )?;
                let padded = format!("  {}\t", id.name());
                crate::testing::check(
                    EngineId::parse(&padded) == Ok(id),
                    "parse trims surrounding whitespace",
                )?;
            }
            for (alias, id) in EngineId::ALIASES {
                crate::testing::check(
                    EngineId::parse(alias) == Ok(id),
                    "documented alias parses to its variant",
                )?;
                crate::testing::check(
                    EngineId::USAGE.contains(alias),
                    "documented alias appears verbatim in USAGE",
                )?;
            }
            // A random 2-engine list drawn from ALL round-trips too.
            let a = EngineId::ALL[rng.range(0, EngineId::ALL.len() - 1)];
            let b = EngineId::ALL[rng.range(0, EngineId::ALL.len() - 1)];
            let list = format!("{}, {}", a.name(), b.name());
            crate::testing::check(
                EngineId::parse_list(&list) == Ok(vec![a, b]),
                "comma-separated canonical names parse as a list",
            )?;
            Ok(())
        });
    }

    #[test]
    fn portfolio_is_registered_software_and_builds() {
        assert_eq!(EngineId::Portfolio.name(), "portfolio");
        assert_eq!(EngineId::parse("portfolio").unwrap(), EngineId::Portfolio);
        assert!(EngineId::Portfolio.is_software());
        assert!(
            !EngineId::SOFTWARE.contains(&EngineId::Portfolio),
            "portfolio must stay out of `all` so historical grids/artifacts are unchanged"
        );
        let mut e = EngineId::Portfolio.build(3, 4, 0.5, Precision::Int8).unwrap();
        assert!(e.is_idle());
        assert_eq!(e.label(), "portfolio");
        assert!(e.portfolio_stats().is_some(), "portfolio telemetry surfaced");
        assert!(
            e.install_faults(
                crate::faults::FaultSpec::parse("down=0@5+2").unwrap().plan(3).unwrap()
            )
            .is_err(),
            "portfolio refuses fault plans like every non-golden engine"
        );
    }

    #[test]
    fn parse_error_carries_the_usage_string() {
        let err = EngineId::parse("warp-drive").unwrap_err().to_string();
        assert!(err.contains("warp-drive"));
        assert!(
            err.contains(EngineId::USAGE),
            "error message must quote the registry's USAGE string: {err}"
        );
    }

    #[test]
    fn list_parsing_and_all() {
        assert_eq!(
            EngineId::parse_list("all").unwrap(),
            EngineId::SOFTWARE.to_vec()
        );
        assert_eq!(
            EngineId::parse_list("sos, simd").unwrap(),
            vec![EngineId::Sos, EngineId::Simd]
        );
        assert_eq!(
            EngineId::parse_list("native,stannic,xla").unwrap(),
            vec![EngineId::Sos, EngineId::StannicSim, EngineId::Xla]
        );
        assert!(EngineId::parse_list("sos,gpu").is_err());
    }

    #[test]
    fn software_engines_build_and_start_idle() {
        for id in EngineId::SOFTWARE {
            assert!(id.is_software());
            let e = id.build(3, 4, 0.5, Precision::Int8).unwrap();
            assert!(e.is_idle(), "{}", id.name());
            assert_eq!(e.label(), id.name(), "adapter label matches registry");
        }
    }

    #[test]
    fn sharded_construction_is_golden_engine_only() {
        let e = EngineId::Sos.build_sharded(4, 10, 4, 0.5, Precision::Int8).unwrap();
        assert!(e.is_idle());
        assert_eq!(e.label(), "sos");
        assert_eq!(e.shard_stats().unwrap().shards(), 4);
        for id in [
            EngineId::Sosc,
            EngineId::Simd,
            EngineId::StannicSim,
            EngineId::HerculesSim,
            EngineId::Portfolio,
        ] {
            let err = id.build_sharded(2, 10, 4, 0.5, Precision::Int8).unwrap_err();
            assert!(err.to_string().contains("does not support sharding"), "{}", id.name());
        }
        assert!(EngineId::Sos.build_sharded(0, 10, 4, 0.5, Precision::Int8).is_err());
        assert!(EngineId::Sos.build_sharded(11, 10, 4, 0.5, Precision::Int8).is_err());
    }

    #[test]
    fn xla_is_artifact_gated() {
        assert!(!EngineId::Xla.is_software());
        // Offline (no artifacts) this must be a clean error, not a panic.
        if ArtifactRegistry::open_default().is_err() {
            assert!(EngineId::Xla.build(5, 10, 0.5, Precision::Int8).is_err());
        }
    }
}
