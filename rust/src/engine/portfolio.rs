//! Competitive portfolio meta-engine: deterministic policy racing.
//!
//! No single fixed policy stays optimal as the arrival mix drifts — the
//! argument Agon (arXiv:2109.00665) makes *competitive*: run several
//! policies, keep the winner. [`PortfolioEngine`] wraps the repo's five
//! cluster-level schedulers (the golden SOS engine behind its
//! [`SosCluster`] adapter plus the greedy / round-robin / work-stealing
//! baselines) behind one [`EngineAdapter`], and at fixed virtual-time
//! decision windows replays the window's arrivals through each *shadow*
//! candidate on a cloned park — exactly the policy-evaluation loop STOMP
//! (arXiv:2007.14371) frames — scoring them on a deterministic
//! objective and switching the live policy at the window boundary only.
//!
//! ## Scoring objective
//!
//! Each shadow replay starts from the park state snapshotted at the
//! window start (pending queues, running jobs with their finish ticks,
//! jobs submitted but not yet dispatched) and feeds the window's
//! arrivals at their recorded ticks, then drains up to
//! [`REPLAY_DRAIN_WINDOWS`] extra windows. Candidates are ranked by
//! **completed count descending, then completed-weighted latency**
//! (Σ weight × (finish − arrival)) **ascending**, ties broken by
//! registry order ([`CANDIDATE_NAMES`]). The winner takes the window;
//! if it is not the live policy, the live policy is replaced at the
//! boundary — its undispatched jobs are resubmitted to the fresh winner
//! in their original submission order, queued jobs stay where they are.
//!
//! ## Determinism invariant
//!
//! For a fixed seed the window boundaries (every [`WINDOW_TICKS`]
//! virtual ticks), shadow scores, and switch sequence are a pure
//! function of the merged arrival order: no wall clock, no ambient
//! randomness, and hash containers are used for membership only (never
//! iterated). Two runs of the same scenario — at any `--threads`,
//! `--queue-depth`, or channel interleaving — produce bit-identical
//! switch logs, schedule digests, and tick counts (property-pinned in
//! `tests/portfolio.rs`).
//!
//! ## Execution model
//!
//! The engine carries its own machine-occupancy model (mirroring
//! [`crate::cluster::Cluster`]'s finish-then-start step) and reports a
//! job *released* at the tick its machine starts it, so the serve
//! workers' `busy_until.max(released)` serialization reproduces the
//! same timeline. Shadow-replay effort is surfaced as deterministic
//! engine-work counters ([`PortfolioTelemetry::replay_ticks`] /
//! [`PortfolioTelemetry::replay_submissions`]) — never wall clock.

use std::collections::HashSet;
use std::fmt::Write as _;

use crate::artifact::fnv1a64_hex;
use crate::baselines::{GreedyScheduler, RoundRobin, WsGreedy, WsRoundRobin};
use crate::cluster::{OnlineScheduler, SosCluster, WorkQueue};
use crate::coordinator::EngineAdapter;
use crate::core::{Job, JobId, MachineId};
use crate::error::Result;
use crate::quant::Precision;
use crate::scheduler::{Assignment, TickOutcome};

/// Virtual-time decision window length. Window boundaries fall on every
/// multiple of this tick count; the live policy can change only there.
pub const WINDOW_TICKS: u64 = 64;

/// How many extra windows a shadow replay may run past the boundary to
/// drain its in-flight work before scoring (bounds replay cost; jobs
/// still unfinished at the cap simply don't count as completed).
pub const REPLAY_DRAIN_WINDOWS: u64 = 4;

/// Candidate registry, in tie-break priority order. Index 0 is the
/// initial live policy. Names are the schedulers' own
/// [`OnlineScheduler::name`] spellings.
pub const CANDIDATE_NAMES: [&str; 5] = ["SOS", "Greedy", "RR", "WSG", "WSRR"];

/// Construction parameters shared by every candidate (only the SOS
/// candidate consumes depth/alpha/precision).
#[derive(Debug, Clone, Copy)]
struct CandidateParams {
    machines: usize,
    depth: usize,
    alpha: f32,
    precision: Precision,
}

fn make_candidate(idx: usize, p: CandidateParams) -> Box<dyn OnlineScheduler> {
    match idx {
        0 => Box::new(SosCluster::new(p.machines, p.depth, p.alpha, p.precision)),
        1 => Box::new(GreedyScheduler::new()),
        2 => Box::new(RoundRobin::new()),
        3 => Box::new(WsGreedy::new()),
        4 => Box::new(WsRoundRobin::new()),
        _ => unreachable!("candidate index {idx} out of registry range"),
    }
}

/// One live-policy switch, recorded at the window boundary it took
/// effect on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SwitchEvent {
    /// 1-based index among *evaluated* (non-empty) windows.
    pub window: u64,
    /// Boundary tick the switch took effect at.
    pub tick: u64,
    pub from: &'static str,
    pub to: &'static str,
}

/// Portfolio telemetry riding [`crate::coordinator::ServeReport`]. All
/// fields are pure functions of the merged arrival order; the work
/// counters measure shadow-replay effort in engine ticks/submissions,
/// never wall clock.
#[derive(Debug, Clone, PartialEq)]
pub struct PortfolioTelemetry {
    /// Decision-window length ([`WINDOW_TICKS`]).
    pub window_ticks: u64,
    /// Windows evaluated (windows with at least one arrival).
    pub windows: u64,
    /// Live-policy switches performed.
    pub switches: u64,
    /// Policy live when the telemetry was read.
    pub live: &'static str,
    /// Per-candidate window wins, in registry order.
    pub wins: Vec<(&'static str, u64)>,
    /// Every switch, in order.
    pub switch_log: Vec<SwitchEvent>,
    /// Virtual ticks simulated across all shadow replays.
    pub replay_ticks: u64,
    /// Jobs fed to shadow candidates across all replays.
    pub replay_submissions: u64,
    /// Largest per-window spread between the best and worst candidate's
    /// weighted-latency score (diagnostic only; not schedule identity).
    pub max_score_spread: f64,
}

impl PortfolioTelemetry {
    /// FNV-1a digest of the canonical switch log — the parity cell that
    /// pins the switch *sequence*, not just its count.
    pub fn switch_digest(&self) -> String {
        let mut canon = String::new();
        for e in &self.switch_log {
            let _ = write!(canon, "w{}@t{}:{}>{};", e.window, e.tick, e.from, e.to);
        }
        fnv1a64_hex(canon.as_bytes())
    }
}

/// The engine's internal park model: pending queues plus per-machine
/// running jobs with their finish ticks, stepped finish-then-start
/// exactly like [`crate::cluster::Cluster::run`].
#[derive(Debug)]
struct ParkSim {
    queues: Vec<WorkQueue>,
    running: Vec<Option<(Job, u64)>>,
}

impl ParkSim {
    fn new(machines: usize) -> Self {
        ParkSim {
            queues: (0..machines).map(|_| WorkQueue::default()).collect(),
            running: vec![None; machines],
        }
    }

    /// Expose machine occupancy to the policy (Cluster step 2).
    fn sync(&mut self) {
        for (q, r) in self.queues.iter_mut().zip(&self.running) {
            match r {
                Some((_, finish)) => {
                    q.busy = true;
                    q.busy_until = *finish;
                }
                None => {
                    q.busy = false;
                    q.busy_until = 0;
                }
            }
        }
    }

    /// Finish-then-start machine pass (Cluster step 3). Returns the
    /// jobs started this tick (release point) and the jobs finished,
    /// with their exact finish ticks.
    fn step(&mut self, now: u64) -> (Vec<(JobId, MachineId)>, Vec<(Job, u64)>) {
        let mut started = Vec::new();
        let mut finished = Vec::new();
        for m in 0..self.queues.len() {
            if self.running[m].as_ref().is_some_and(|(_, f)| *f <= now) {
                let done = self.running[m].take().expect("just checked");
                finished.push(done);
            }
            if self.running[m].is_none() {
                if let Some(job) = self.queues[m].pending.pop_front() {
                    let dur = job.actual_time(m);
                    started.push((job.id, m));
                    self.running[m] = Some((job, now + dur));
                }
            }
        }
        (started, finished)
    }

    fn pending_empty(&self) -> bool {
        self.queues.iter().all(|q| q.pending.is_empty())
    }

    fn pending_jobs(&self) -> usize {
        self.queues.iter().map(|q| q.pending.len()).sum()
    }

    fn running_jobs(&self) -> usize {
        self.running.iter().filter(|r| r.is_some()).count()
    }
}

impl Clone for ParkSim {
    fn clone(&self) -> Self {
        ParkSim {
            queues: self
                .queues
                .iter()
                .map(|q| WorkQueue {
                    pending: q.pending.clone(),
                    busy: q.busy,
                    busy_until: q.busy_until,
                })
                .collect(),
            running: self.running.clone(),
        }
    }
}

/// Park + policy state frozen at a window start; shadow replays branch
/// from here.
#[derive(Debug, Clone)]
struct WindowSnapshot {
    start: u64,
    park: ParkSim,
    undispatched: Vec<Job>,
}

#[derive(Debug, Clone, Copy)]
struct ReplayScore {
    completed: u64,
    weighted_latency: f64,
}

/// Replay one candidate from `snapshot` through the window's arrivals.
/// Returns the score plus the (ticks, submissions) work it cost.
fn shadow_replay(
    idx: usize,
    p: CandidateParams,
    snapshot: &WindowSnapshot,
    window_arrivals: &[(u64, Job)],
    boundary: u64,
) -> (ReplayScore, u64, u64) {
    let mut policy = make_candidate(idx, p);
    let mut park = snapshot.park.clone();
    let total = park.pending_jobs()
        + park.running_jobs()
        + snapshot.undispatched.len()
        + window_arrivals.len();
    let mut replay_ticks = 0u64;
    let mut replay_submissions = 0u64;
    for job in &snapshot.undispatched {
        policy.submit(job.clone());
        replay_submissions += 1;
    }
    let cap = boundary + REPLAY_DRAIN_WINDOWS * WINDOW_TICKS;
    let mut arrivals = window_arrivals.iter().peekable();
    let mut completed = 0u64;
    let mut weighted_latency = 0.0f64;
    let mut t = snapshot.start;
    while (completed as usize) < total && t < cap {
        t += 1;
        replay_ticks += 1;
        while arrivals.peek().is_some_and(|(at, _)| *at <= t) {
            let (_, job) = arrivals.next().expect("peeked");
            policy.submit(job.clone());
            replay_submissions += 1;
        }
        park.sync();
        policy.tick(t, &mut park.queues);
        let (_, finished) = park.step(t);
        for (job, finish) in finished {
            completed += 1;
            weighted_latency += job.weight as f64 * finish.saturating_sub(job.arrival) as f64;
        }
    }
    (
        ReplayScore {
            completed,
            weighted_latency,
        },
        replay_ticks,
        replay_submissions,
    )
}

/// The portfolio meta-engine (registry name `portfolio`). See the
/// module docs for the window/switch protocol and the determinism
/// invariant.
pub struct PortfolioEngine {
    params: CandidateParams,
    live: usize,
    policy: Box<dyn OnlineScheduler>,
    park: ParkSim,
    /// Jobs accepted since the last tick, in submission order.
    inbox: Vec<Job>,
    /// Jobs handed to the live policy but not yet on a machine queue —
    /// resubmitted verbatim to the winner on a switch.
    undispatched: Vec<Job>,
    /// Every job id ever seen on a machine queue (membership only —
    /// never iterated — so determinism survives the hash order).
    dispatched: HashSet<JobId>,
    /// (arrival tick, job) log of the current window.
    window_arrivals: Vec<(u64, Job)>,
    snapshot: WindowSnapshot,
    now: u64,
    windows: u64,
    switches: u64,
    wins: Vec<u64>,
    switch_log: Vec<SwitchEvent>,
    replay_ticks: u64,
    replay_submissions: u64,
    max_score_spread: f64,
}

impl PortfolioEngine {
    pub fn new(machines: usize, depth: usize, alpha: f32, precision: Precision) -> Self {
        assert!(machines > 0, "portfolio needs at least one machine");
        let params = CandidateParams {
            machines,
            depth,
            alpha,
            precision,
        };
        PortfolioEngine {
            params,
            live: 0,
            policy: make_candidate(0, params),
            park: ParkSim::new(machines),
            inbox: Vec::new(),
            undispatched: Vec::new(),
            dispatched: HashSet::new(),
            window_arrivals: Vec::new(),
            snapshot: WindowSnapshot {
                start: 0,
                park: ParkSim::new(machines),
                undispatched: Vec::new(),
            },
            now: 0,
            windows: 0,
            switches: 0,
            wins: vec![0; CANDIDATE_NAMES.len()],
            switch_log: Vec::new(),
            replay_ticks: 0,
            replay_submissions: 0,
            max_score_spread: 0.0,
        }
    }

    /// Current telemetry snapshot.
    pub fn telemetry(&self) -> PortfolioTelemetry {
        PortfolioTelemetry {
            window_ticks: WINDOW_TICKS,
            windows: self.windows,
            switches: self.switches,
            live: CANDIDATE_NAMES[self.live],
            wins: CANDIDATE_NAMES
                .iter()
                .copied()
                .zip(self.wins.iter().copied())
                .collect(),
            switch_log: self.switch_log.clone(),
            replay_ticks: self.replay_ticks,
            replay_submissions: self.replay_submissions,
            max_score_spread: self.max_score_spread,
        }
    }

    fn step(&mut self) -> TickOutcome {
        self.now += 1;
        let now = self.now;
        let mut out = TickOutcome::default();

        // 1. Admissions buffered since the last tick enter the live
        //    policy and the window's arrival log, in submission order.
        for job in std::mem::take(&mut self.inbox) {
            self.window_arrivals.push((now, job.clone()));
            self.undispatched.push(job.clone());
            self.policy.submit(job);
        }

        // 2+3. Expose occupancy, let the live policy dispatch.
        self.park.sync();
        self.policy.tick(now, &mut self.park.queues);

        // 4. Detect fresh dispatches (machine order, then queue
        //    position — deterministic). The first keeps the historical
        //    `assigned` slot; the rest ride `co_assigned` like the
        //    sharded coordinator's extra domains. Work-stealing moves
        //    of already-dispatched jobs are not re-reported.
        for (m, q) in self.park.queues.iter().enumerate() {
            for (pos, job) in q.pending.iter().enumerate() {
                if self.dispatched.insert(job.id) {
                    let a = Assignment {
                        job: job.id,
                        machine: m,
                        position: pos,
                        cost: 0.0,
                    };
                    if out.assigned.is_none() {
                        out.assigned = Some(a);
                    } else {
                        out.co_assigned.push(a);
                    }
                }
            }
        }
        if out.assigned.is_some() {
            let dispatched = &self.dispatched;
            self.undispatched.retain(|j| !dispatched.contains(&j.id));
        }

        // 5. Machine pass: a job is *released* at the tick its machine
        //    starts it, so the serve workers reproduce this timeline.
        let (started, _) = self.park.step(now);
        out.released = started;

        // 6. Window boundary: score the shadows, switch at most once.
        if now % WINDOW_TICKS == 0 {
            self.window_boundary(now);
        }
        out
    }

    fn window_boundary(&mut self, now: u64) {
        if !self.window_arrivals.is_empty() {
            self.windows += 1;
            let mut best = 0usize;
            let mut best_score: Option<ReplayScore> = None;
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for idx in 0..CANDIDATE_NAMES.len() {
                let (score, ticks, subs) =
                    shadow_replay(idx, self.params, &self.snapshot, &self.window_arrivals, now);
                self.replay_ticks += ticks;
                self.replay_submissions += subs;
                lo = lo.min(score.weighted_latency);
                hi = hi.max(score.weighted_latency);
                let better = match &best_score {
                    None => true,
                    Some(b) => {
                        score.completed > b.completed
                            || (score.completed == b.completed
                                && score.weighted_latency < b.weighted_latency)
                    }
                };
                if better {
                    best = idx;
                    best_score = Some(score);
                }
            }
            let spread = (hi - lo).max(0.0);
            if spread > self.max_score_spread {
                self.max_score_spread = spread;
            }
            self.wins[best] += 1;
            if best != self.live {
                self.switches += 1;
                self.switch_log.push(SwitchEvent {
                    window: self.windows,
                    tick: now,
                    from: CANDIDATE_NAMES[self.live],
                    to: CANDIDATE_NAMES[best],
                });
                self.live = best;
                self.policy = make_candidate(best, self.params);
                for job in &self.undispatched {
                    self.policy.submit(job.clone());
                }
            }
            self.window_arrivals.clear();
        }
        // Re-anchor the snapshot for the next window (evaluated or
        // idle: shadow replays always branch from the latest boundary).
        self.snapshot = WindowSnapshot {
            start: now,
            park: self.park.clone(),
            undispatched: self.undispatched.clone(),
        };
    }
}

impl EngineAdapter for PortfolioEngine {
    fn label(&self) -> &'static str {
        "portfolio"
    }

    fn submit(&mut self, job: Job) {
        self.inbox.push(job);
    }

    fn tick(&mut self) -> Result<TickOutcome> {
        Ok(self.step())
    }

    fn is_idle(&self) -> bool {
        // Running jobs are excluded on purpose: once every accepted job
        // has been released to its machine, the serve pipeline owns the
        // remaining execution.
        self.inbox.is_empty() && self.policy.idle() && self.park.pending_empty()
    }

    fn portfolio_stats(&self) -> Option<PortfolioTelemetry> {
        Some(self.telemetry())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::JobNature;

    fn engine(machines: usize) -> PortfolioEngine {
        PortfolioEngine::new(machines, 8, 0.5, Precision::Int8)
    }

    fn job(id: u64, ept: f32, machines: usize, arrival: u64) -> Job {
        Job::new(id, 1.0 + (id % 3) as f32, vec![ept; machines], JobNature::Mixed)
            .with_arrival(arrival)
    }

    /// Drive until idle with no further submissions; returns the
    /// released log and the tick count.
    fn drain(e: &mut PortfolioEngine, cap: u64) -> (Vec<(JobId, MachineId)>, u64) {
        let mut released = Vec::new();
        let mut ticks = 0;
        while (!e.is_idle() || !e.inbox.is_empty()) && ticks < cap {
            let out = e.step();
            released.extend(out.released);
            ticks += 1;
        }
        (released, e.now)
    }

    #[test]
    fn starts_on_sos_with_empty_telemetry() {
        let e = engine(3);
        assert!(e.is_idle());
        let t = e.telemetry();
        assert_eq!(t.live, "SOS");
        assert_eq!(t.windows, 0);
        assert_eq!(t.switches, 0);
        assert_eq!(t.window_ticks, WINDOW_TICKS);
        assert_eq!(t.wins.len(), CANDIDATE_NAMES.len());
        assert!(t.wins.iter().all(|(_, w)| *w == 0));
        // FNV-1a offset basis: the digest of an empty switch log.
        assert_eq!(t.switch_digest(), "cbf29ce484222325");
    }

    #[test]
    fn every_job_is_assigned_and_released_exactly_once() {
        let mut e = engine(3);
        let mut assigned = 0usize;
        for id in 1..=9 {
            e.submit(job(id, 12.0, 3, 1));
        }
        let mut released = Vec::new();
        let mut guard = 0;
        while !e.is_idle() && guard < 10_000 {
            let out = e.step();
            assigned += usize::from(out.assigned.is_some()) + out.co_assigned.len();
            released.extend(out.released);
            guard += 1;
        }
        assert_eq!(assigned, 9);
        assert_eq!(released.len(), 9);
        let mut ids: Vec<JobId> = released.iter().map(|(id, _)| *id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (1..=9).collect::<Vec<_>>());
    }

    #[test]
    fn loaded_window_switches_away_from_sos_deterministically() {
        // The SOS candidate holds every job for its alpha-point before
        // release; greedy dispatches immediately, so on a loaded window
        // it completes the same jobs with strictly less weighted
        // latency and must win the first evaluated window.
        let run = || {
            let mut e = engine(3);
            for id in 1..=12 {
                e.submit(job(id, 40.0, 3, 1));
            }
            let (released, ticks) = drain(&mut e, 20_000);
            (released, ticks, e.telemetry())
        };
        let (rel_a, ticks_a, tel_a) = run();
        let (rel_b, ticks_b, tel_b) = run();
        assert!(tel_a.windows >= 1, "loaded window must be evaluated");
        assert!(tel_a.switches >= 1, "portfolio must leave SOS under load");
        assert_ne!(tel_a.live, "SOS");
        assert_eq!(
            tel_a.wins.iter().map(|(_, w)| *w).sum::<u64>(),
            tel_a.windows,
            "every evaluated window has exactly one winner"
        );
        assert!(tel_a.replay_ticks > 0 && tel_a.replay_submissions > 0);
        // Bit-identical rerun: released log, tick count, telemetry.
        assert_eq!(rel_a, rel_b);
        assert_eq!(ticks_a, ticks_b);
        assert_eq!(tel_a, tel_b);
        assert_eq!(tel_a.switch_digest(), tel_b.switch_digest());
    }

    #[test]
    fn switch_resubmits_undispatched_work_losslessly() {
        // Feed arrivals across several windows; whatever switching
        // happens, job conservation must hold.
        let mut e = engine(2);
        let mut submitted = 0u64;
        let mut released = Vec::new();
        for round in 0..5u64 {
            for k in 0..8u64 {
                submitted += 1;
                e.submit(job(round * 8 + k + 1, 30.0, 2, round * 40 + 1));
            }
            for _ in 0..40 {
                released.extend(e.step().released);
            }
        }
        let (tail, _) = drain(&mut e, 20_000);
        released.extend(tail);
        assert_eq!(released.len() as u64, submitted);
        let tel = e.telemetry();
        assert!(tel.windows >= 2);
        assert_eq!(tel.switch_log.len() as u64, tel.switches);
    }

    #[test]
    fn empty_windows_are_skipped_not_scored() {
        let mut e = engine(2);
        // Tick through two whole windows with no arrivals.
        for _ in 0..(2 * WINDOW_TICKS) {
            let out = e.step();
            assert!(out.released.is_empty());
        }
        let t = e.telemetry();
        assert_eq!(t.windows, 0);
        assert_eq!(t.switches, 0);
        assert_eq!(t.replay_ticks, 0);
    }
}
