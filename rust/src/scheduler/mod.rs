//! The Stochastic Online Scheduling algorithm (Jäger 2023, as discretized
//! by the paper, Section 3) — golden software model.
//!
//! Every other implementation in this repo — the Hercules and Stannic
//! cycle-accurate simulators, the XLA-offloaded cost engine, the SOSC and
//! SIMD software baselines — is required to produce *bit-identical
//! schedules* to [`SosEngine`]; integration tests enforce this parity.

mod continuous;
mod cost;
mod drive;
mod engine;
mod vschedule;
mod wavefront;

pub use continuous::ContinuousSos;
pub use cost::{cost_of, CostBreakdown, FULL_COST};
pub use drive::{drive_trace, DriveStats, Horizon};
pub use engine::{Assignment, SosEngine, TickOutcome};
pub use vschedule::{Slot, VirtualSchedule};
pub use wavefront::{Phase2Kernel, Phase2Work, Wavefront};
