//! Continuous-time SOS reference — Equations (1) and (2) of Section 3.1.
//!
//! The discretization of Section 3.2 replaces the virtual-work integral
//! `Omega = ∫ F_K(s) ds` with the cycle count `n_K`. This module keeps
//! real-valued time and evaluates the integral exactly (virtual work
//! accrues at unit rate while a job holds the head), so the discrete
//! engine can be validated against it: when every event falls on integer
//! times, the two produce identical costs and schedules.

use crate::core::JobId;

/// A tracked job in continuous time.
#[derive(Debug, Clone, Copy)]
struct CJob {
    id: JobId,
    weight: f64,
    ept: f64,
    wspt: f64,
    /// Exact accumulated virtual work `Omega` (time spent at head).
    omega: f64,
}

/// Continuous-time virtual schedule for one machine.
#[derive(Debug, Clone, Default)]
pub struct ContinuousSos {
    jobs: Vec<CJob>, // sorted by wspt desc
    alpha: f64,
    now: f64,
}

/// A release event returned by [`ContinuousSos::advance`].
#[derive(Debug, Clone, PartialEq)]
pub struct Release {
    pub id: JobId,
    pub at: f64,
}

impl ContinuousSos {
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0);
        ContinuousSos {
            jobs: Vec::new(),
            alpha,
            now: 0.0,
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Remaining fraction of virtual work `iota_K(t)` per Eq. (1).
    fn iota(j: &CJob) -> f64 {
        1.0 - j.omega / j.ept
    }

    /// Continuous-time cost of assigning (w, eps) at the current time,
    /// per Eq. (2). Returns (cost, insertion position).
    pub fn cost(&self, w: f64, eps: f64) -> (f64, usize) {
        let t_j = w / eps;
        let mut sum_hi = 0.0; // sum of iota_K * eps_K over sigma^H
        let mut sum_lo = 0.0; // sum of W_K * iota_K over sigma^L
        let mut pos = 0;
        for j in &self.jobs {
            if j.wspt >= t_j {
                sum_hi += Self::iota(j) * j.ept;
                pos += 1;
            } else {
                sum_lo += j.weight * Self::iota(j);
            }
        }
        (w * (eps + sum_hi) + eps * sum_lo, pos)
    }

    /// Assign a job at the current time.
    pub fn assign(&mut self, id: JobId, w: f64, eps: f64) -> usize {
        let t_j = w / eps;
        let pos = self.jobs.iter().take_while(|j| j.wspt >= t_j).count();
        self.jobs.insert(
            pos,
            CJob {
                id,
                weight: w,
                ept: eps,
                wspt: t_j,
                omega: 0.0,
            },
        );
        pos
    }

    /// Advance time by `dt`, accruing virtual work on the head and
    /// emitting releases whenever the head's omega crosses its
    /// `alpha * eps` release point (the continuous Phase III rule).
    pub fn advance(&mut self, dt: f64) -> Vec<Release> {
        assert!(dt >= 0.0);
        let mut releases = Vec::new();
        let mut remaining = dt;
        while remaining > 1e-12 {
            let Some(head) = self.jobs.first_mut() else {
                self.now += remaining;
                break;
            };
            let release_at = self.alpha * head.ept;
            let need = release_at - head.omega;
            if need > remaining {
                head.omega += remaining;
                self.now += remaining;
                remaining = 0.0;
            } else {
                head.omega = release_at;
                self.now += need.max(0.0);
                remaining -= need.max(0.0);
                let done = self.jobs.remove(0);
                releases.push(Release {
                    id: done.id,
                    at: self.now,
                });
            }
        }
        releases
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_matches_equation_2_by_hand() {
        let mut c = ContinuousSos::new(0.5);
        c.assign(1, 40.0, 20.0); // T=2
        c.assign(2, 10.0, 20.0); // T=0.5
        // half the head's virtual work done: omega = 5 => iota = 0.75
        c.advance(5.0);
        // probe J: w=15, eps=15, T=1 -> sigma^H={1}: iota*eps = 15
        //                              sigma^L={2}: W*iota = 10*1 = 10
        let (cost, pos) = c.cost(15.0, 15.0);
        assert!((cost - (15.0 * (15.0 + 15.0) + 15.0 * 10.0)).abs() < 1e-9);
        assert_eq!(pos, 1);
    }

    #[test]
    fn head_releases_exactly_at_alpha_eps() {
        let mut c = ContinuousSos::new(0.5);
        c.assign(1, 10.0, 20.0); // release after 10 time units at head
        let r = c.advance(9.99);
        assert!(r.is_empty());
        let r = c.advance(0.02);
        assert_eq!(r.len(), 1);
        assert!((r[0].at - 10.0).abs() < 1e-9);
    }

    #[test]
    fn consecutive_releases_within_one_advance() {
        let mut c = ContinuousSos::new(1.0);
        c.assign(1, 40.0, 4.0); // T=10, releases after 4
        c.assign(2, 30.0, 4.0); // T=7.5, releases 4 after job 1
        let r = c.advance(100.0);
        assert_eq!(r.len(), 2);
        assert!((r[0].at - 4.0).abs() < 1e-9);
        assert!((r[1].at - 8.0).abs() < 1e-9);
        assert!(c.is_empty());
        assert!((c.now() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn discrete_engine_agrees_on_integer_grid() {
        // Drive the continuous model on unit steps and compare costs with
        // the discrete formula cost^H/cost^L at every step.
        use crate::quant::Precision;
        use crate::scheduler::{cost_of, SosEngine};
        use crate::core::{Job, JobNature};

        let mut cont = ContinuousSos::new(0.5);
        let mut disc = SosEngine::new(1, 8, 0.5, Precision::Fp32);

        let arrivals: Vec<(u64, f32, f32)> =
            vec![(1, 8.0, 16.0), (3, 24.0, 12.0), (5, 4.0, 20.0)];
        let mut next = 0usize;
        for t in 1..=30u64 {
            let arr = (next < arrivals.len() && arrivals[next].0 == t).then(|| {
                let (_, w, e) = arrivals[next];
                next += 1;
                Job::new(t, w, vec![e], JobNature::Mixed)
            });
            // continuous: probe cost before assignment, then assign+advance
            if let Some(j) = &arr {
                let (cc, cp) = cont.cost(j.weight as f64, j.ept[0] as f64);
                // the tickless engine materializes virtual work lazily;
                // sync it so the probe sees the per-tick state
                disc.materialize();
                let dc = cost_of(disc.schedule(0), j.weight, j.ept[0], j.wspt(0));
                if let Some(d) = dc {
                    assert!(
                        (cc - d.total() as f64).abs() < 1e-3,
                        "tick {t}: continuous {cc} vs discrete {}",
                        d.total()
                    );
                    assert_eq!(cp, d.position, "tick {t} position");
                }
                cont.assign(j.id, j.weight as f64, j.ept[0] as f64);
            }
            disc.tick(arr.as_ref());
            cont.advance(1.0);
        }
    }
}
