//! The golden discrete-time SOS engine — tickless.
//!
//! One [`SosEngine::tick`] = one pass around the cyclical algorithmic
//! flow of Fig. 2b / Fig. 9, executing (in order):
//!
//! 1. **POP** (`B`) — release every machine head that reached its alpha
//!    point during a previous tick.
//! 2. **Cost + Insert** (`C`/`D`/`E`) — if a job is waiting at the
//!    arrival FIFO, compute `cost(J -> M_i)` for all machines over the
//!    post-pop state, pick the argmin (ties to the lowest machine index,
//!    matching both hardware Cost Comparators), insert at WSPT position.
//! 3. **Virtual work** (`F`) — the head of every non-empty schedule
//!    accrues one cycle of virtual work.
//!
//! Phases 1 and 3 used to cost O(machines) on *every* tick — including
//! the millions of pure-drain ticks at the end of a sweep cell, where
//! nothing can change. The engine is now event-driven:
//!
//! * **Phase 3 is implicit.** Virtual work lives lazily in each
//!   [`VirtualSchedule`] (`n = now - head_since`; see the vschedule
//!   module docs): the engine never loops over machines to accrue, it
//!   materializes a schedule only when it actually observes it (a pop or
//!   a cost query), via `sync_to(tick - 1)`.
//! * **Phase 1 reads an event horizon.** A min-heap of per-machine head
//!   release ticks (`head_since + alpha_pt - n₀`, pushed whenever a head
//!   is crowned, invalidated lazily) tells the engine exactly which
//!   machines can pop at the current tick, so pops cost
//!   O(pops · log machines) instead of an O(machines) scan per tick.
//! * **Drivers can jump.** [`SosEngine::next_event_tick`] exposes the
//!   horizon (earliest tick that can produce a non-empty
//!   [`TickOutcome`], absent new arrivals) and
//!   [`SosEngine::advance_to`] fast-forwards virtual time over a
//!   provably event-free window in O(1). Per-tick driving remains fully
//!   supported and bit-identical — the golden test pins it.
//!
//! Burst arrivals are serialized through the engine's internal FIFO: the
//! SOS algorithm assumes sequential job arrival (Phase I), so at most one
//! job is assigned per tick; the rest wait, exactly as the hardware's
//! host interface feeds one job per scheduling iteration.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::core::{Job, JobId, MachineId};
use crate::quant::Precision;

use super::cost::{cost_of, FULL_COST};
use super::vschedule::{Slot, VirtualSchedule};

/// Result of assigning one job (Phase II). The full per-machine cost
/// vector is not stored here (it cost a heap allocation per assignment);
/// callers that render it read [`SosEngine::last_cost_vector`] right
/// after the tick instead.
#[derive(Debug, Clone, PartialEq)]
pub struct Assignment {
    pub job: JobId,
    pub machine: MachineId,
    /// Insertion index within the winning machine's virtual schedule.
    pub position: usize,
    /// Winning (minimum) cost.
    pub cost: f32,
}

/// Everything that happened in one scheduler tick.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TickOutcome {
    /// Jobs released to machine work queues this tick (Phase III pops).
    pub released: Vec<(JobId, MachineId)>,
    /// The job assigned this tick, if an arrival was processed.
    pub assigned: Option<Assignment>,
    /// True when an arrival was waiting but *every* machine was full.
    pub stalled: bool,
}

/// Golden software model of the discretized SOS algorithm.
#[derive(Debug, Clone)]
pub struct SosEngine {
    schedules: Vec<VirtualSchedule>,
    alpha: f32,
    precision: Precision,
    /// Arrival FIFO (burst serialization).
    pending: VecDeque<Job>,
    tick_no: u64,
    /// Scratch cost vector, reused across ticks to keep the hot loop
    /// allocation-free; exposed via [`Self::last_cost_vector`].
    cost_scratch: Vec<f32>,
    /// Event horizon: min-heap of (head release tick, machine). Entries
    /// are pushed whenever a head is crowned and invalidated lazily —
    /// an entry that no longer matches its machine's current head
    /// release is stale and skipped.
    horizon: BinaryHeap<Reverse<(u64, usize)>>,
    /// Scratch list of machines due at the current tick (kept as a
    /// field so pop processing allocates nothing in steady state).
    due_scratch: Vec<usize>,
}

impl SosEngine {
    pub fn new(machines: usize, depth: usize, alpha: f32, precision: Precision) -> Self {
        assert!(machines >= 1);
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha in (0, 1] (Phase III)");
        // Memoized threshold sums are only bit-exact for the fixed-point
        // WSPT datapaths; floating datapaths keep the rescan so their
        // schedules are unchanged (see vschedule module docs).
        let memoized = matches!(precision, Precision::Int8 | Precision::Int4 | Precision::Mixed);
        SosEngine {
            schedules: (0..machines)
                .map(|_| VirtualSchedule::with_memoization(depth, memoized))
                .collect(),
            alpha,
            precision,
            pending: VecDeque::new(),
            tick_no: 0,
            cost_scratch: vec![0.0; machines],
            horizon: BinaryHeap::with_capacity(machines),
            due_scratch: Vec::with_capacity(machines),
        }
    }

    pub fn machines(&self) -> usize {
        self.schedules.len()
    }

    pub fn depth(&self) -> usize {
        self.schedules[0].depth()
    }

    pub fn alpha(&self) -> f32 {
        self.alpha
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    pub fn tick_no(&self) -> u64 {
        self.tick_no
    }

    /// One machine's virtual schedule. NOTE: the head's stored `n` is
    /// materialized lazily; call [`Self::materialize`] first when
    /// inspecting virtual-work counters mid-run.
    pub fn schedule(&self, m: MachineId) -> &VirtualSchedule {
        &self.schedules[m]
    }

    /// All virtual schedules (same lazy-`n` caveat as [`Self::schedule`]).
    pub fn schedules(&self) -> &[VirtualSchedule] {
        &self.schedules
    }

    /// Materialize every schedule's virtual work through the current
    /// tick, so external inspection of slot `n` values sees the same
    /// state a per-tick engine would have after this tick's Phase III.
    /// Purely observational — never changes scheduling decisions.
    pub fn materialize(&mut self) {
        let now = self.tick_no;
        for vs in &mut self.schedules {
            vs.sync_to(now);
        }
    }

    /// Jobs waiting in the arrival FIFO (not yet assigned).
    pub fn backlog(&self) -> usize {
        self.pending.len()
    }

    /// Total jobs currently tracked across all virtual schedules.
    pub fn in_flight(&self) -> usize {
        self.schedules.iter().map(|v| v.len()).sum()
    }

    /// Enqueue an arrival without running a tick (used by burst sources).
    pub fn submit(&mut self, job: Job) {
        self.pending.push_back(job);
    }

    /// The earliest future tick that can produce a non-empty
    /// [`TickOutcome`], given no further submissions: the next tick
    /// while the FIFO holds work (an assignment or stall happens every
    /// tick), else the earliest head release on the event horizon, else
    /// `None` (the engine is fully idle — nothing will ever happen
    /// again without a new arrival). Prunes stale horizon entries.
    pub fn next_event_tick(&mut self) -> Option<u64> {
        if !self.pending.is_empty() {
            return Some(self.tick_no + 1);
        }
        while let Some(&Reverse((release, m))) = self.horizon.peek() {
            if self.schedules[m].head_release_tick() == Some(release) {
                return Some(release.max(self.tick_no + 1));
            }
            self.horizon.pop(); // stale: that head was popped or displaced
        }
        None
    }

    /// Fast-forward virtual time to `tick` in O(1). The caller must
    /// ensure the skipped window is event-free, i.e.
    /// `tick < next_event_tick()` (and that no arrival is due inside
    /// the window) — every skipped tick would have produced an empty
    /// outcome, so the jump is semantically invisible: virtual work is
    /// captured by the schedules' lazy representation.
    pub fn advance_to(&mut self, tick: u64) {
        assert!(tick >= self.tick_no, "virtual time cannot rewind");
        debug_assert!(
            self.next_event_tick().map_or(true, |e| e > tick),
            "advance_to({tick}) would jump over a scheduler event"
        );
        self.tick_no = tick;
    }

    /// (Re)arm the event horizon for machine `m`'s current head, if any.
    /// Called whenever a head is crowned (pop revealing a successor, or
    /// an insert landing at position 0). Old entries for the machine are
    /// not removed — they become stale and are skipped lazily.
    fn arm_horizon(&mut self, m: usize) {
        if let Some(release) = self.schedules[m].head_release_tick() {
            self.horizon.push(Reverse((release, m)));
        }
    }

    /// Run one scheduler tick; `arrival` is this tick's new job, if any.
    pub fn tick(&mut self, arrival: Option<&Job>) -> TickOutcome {
        self.tick_no += 1;
        let now = self.tick_no;
        if let Some(j) = arrival {
            self.pending.push_back(j.clone());
        }

        let mut out = TickOutcome::default();

        // (1) POP iteration part: only machines whose horizon entry is
        // due can possibly release. Releases must be reported in
        // machine-index order (matching the historical O(M) scan), so
        // collect, sort, dedupe, then process.
        let mut due = std::mem::take(&mut self.due_scratch);
        while let Some(&Reverse((release, m))) = self.horizon.peek() {
            if release > now {
                break;
            }
            self.horizon.pop();
            due.push(m);
        }
        if !due.is_empty() {
            due.sort_unstable();
            due.dedup();
            for &m in &due {
                let vs = &mut self.schedules[m];
                vs.sync_to(now - 1);
                if vs.head().is_some_and(|h| h.ready()) {
                    let slot = vs.pop_head().expect("head checked above");
                    out.released.push((slot.id, m));
                    self.arm_horizon(m); // successor head, if any
                }
                // else: a stale entry fired early; the machine's real
                // head keeps its own (future) horizon entry.
            }
            due.clear();
        }
        self.due_scratch = due;

        // (2) Insert iteration part: assign the oldest pending arrival.
        if !self.pending.is_empty() {
            let any_free = self.schedules.iter().any(|v| !v.is_full());
            if any_free {
                let job = self.pending.pop_front().expect("front checked");
                out.assigned = Some(self.assign(&job));
            } else {
                out.stalled = true;
            }
        }

        // (3) Standard iteration part: virtual work accrues implicitly —
        // each schedule materializes `now - synced_at` cycles on its
        // head the next time it is observed.
        out
    }

    /// Phase II machine assignment: cost all machines, argmin, insert.
    fn assign(&mut self, job: &Job) -> Assignment {
        debug_assert_eq!(job.fanout(), self.schedules.len());
        let now = self.tick_no;
        let mut best: Option<(usize, f32, usize)> = None; // (machine, cost, pos)
        for (m, vs) in self.schedules.iter_mut().enumerate() {
            // cost is computed over the post-pop state with virtual work
            // through the previous tick's Phase III
            vs.sync_to(now - 1);
            let (j_w, j_eps, j_t) = self.precision.q_job(job.weight, job.ept[m]);
            match cost_of(vs, j_w, j_eps, j_t) {
                Some(c) => {
                    let total = c.total();
                    self.cost_scratch[m] = total;
                    // strict < keeps the first (lowest-index) minimum
                    if best.map_or(true, |(_, bc, _)| total < bc) {
                        best = Some((m, total, c.position));
                    }
                }
                None => {
                    self.cost_scratch[m] = FULL_COST;
                }
            }
        }
        let (machine, cost, position) =
            best.expect("assign() requires at least one non-full machine");
        let (j_w, j_eps, j_t) = self.precision.q_job(job.weight, job.ept[machine]);
        let slot = Slot {
            id: job.id,
            weight: j_w,
            ept: j_eps,
            wspt: j_t,
            alpha_pt: (self.alpha * j_eps).ceil() as u32,
            n: 0,
        };
        let inserted_at = self.schedules[machine].insert(slot);
        debug_assert_eq!(inserted_at, position, "cost position == insert position");
        debug_assert!(self.schedules[machine].is_properly_ordered());
        if inserted_at == 0 {
            // the newcomer is the head (fresh schedule or displacement):
            // its release defines the machine's next horizon event
            self.arm_horizon(machine);
        }
        Assignment {
            job: job.id,
            machine,
            position,
            cost,
        }
    }

    /// Full per-machine cost vector of the most recent assignment
    /// (`FULL_COST` where the V_i was full) — borrowed from the engine's
    /// scratch, valid until the next assignment. This replaces the old
    /// per-assignment `Assignment.cost_vector` clone so the steady-state
    /// assign path allocates nothing.
    pub fn last_cost_vector(&self) -> &[f32] {
        &self.cost_scratch
    }

    /// Drain-mode tick: no arrivals, just pops + virtual work. Used to
    /// flush schedules at end of trace.
    pub fn drain_tick(&mut self) -> TickOutcome {
        self.tick(None)
    }

    /// True when no work remains anywhere in the scheduler.
    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.schedules.iter().all(|v| v.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::JobNature;

    fn job(id: u64, w: f32, ept: Vec<f32>) -> Job {
        Job::new(id, w, ept, JobNature::Mixed)
    }

    #[test]
    fn single_job_lands_on_cheapest_machine() {
        let mut e = SosEngine::new(3, 4, 0.5, Precision::Fp32);
        let j = job(1, 2.0, vec![50.0, 10.0, 30.0]);
        let out = e.tick(Some(&j));
        let a = out.assigned.unwrap();
        assert_eq!(a.machine, 1); // cost = W*eps = 100/20/60
        assert_eq!(a.cost, 20.0);
        assert_eq!(a.position, 0);
        assert_eq!(e.last_cost_vector(), &[100.0, 20.0, 60.0][..]);
    }

    #[test]
    fn tie_goes_to_lowest_machine_index() {
        let mut e = SosEngine::new(3, 4, 0.5, Precision::Fp32);
        let j = job(1, 2.0, vec![10.0, 10.0, 10.0]);
        assert_eq!(e.tick(Some(&j)).assigned.unwrap().machine, 0);
    }

    #[test]
    fn head_releases_at_alpha_point() {
        let mut e = SosEngine::new(1, 4, 0.5, Precision::Fp32);
        let j = job(1, 2.0, vec![10.0]); // alpha_pt = 5
        e.tick(Some(&j));
        let mut released_at = None;
        for t in 2..=10 {
            let out = e.tick(None);
            if !out.released.is_empty() {
                released_at = Some(t);
                assert_eq!(out.released[0], (1, 0));
                break;
            }
        }
        // assigned at tick 1 (accrues at 1..=5), pops at tick 6
        assert_eq!(released_at, Some(6));
        assert!(e.is_idle());
    }

    #[test]
    fn burst_is_serialized_one_assignment_per_tick() {
        let mut e = SosEngine::new(2, 8, 0.5, Precision::Fp32);
        for i in 0..4 {
            e.submit(job(i, 2.0, vec![20.0, 20.0]));
        }
        let mut assigned = 0;
        for _ in 0..4 {
            let out = e.tick(None);
            assert!(out.assigned.is_some());
            assigned += 1;
        }
        assert_eq!(assigned, 4);
        assert_eq!(e.backlog(), 0);
    }

    #[test]
    fn stall_when_all_machines_full() {
        let mut e = SosEngine::new(1, 1, 1.0, Precision::Fp32);
        e.tick(Some(&job(1, 2.0, vec![100.0])));
        let out = e.tick(Some(&job(2, 2.0, vec![100.0])));
        assert!(out.stalled);
        assert!(out.assigned.is_none());
        assert_eq!(e.backlog(), 1);
    }

    #[test]
    fn higher_priority_newcomer_takes_head() {
        let mut e = SosEngine::new(1, 4, 1.0, Precision::Fp32);
        e.tick(Some(&job(1, 1.0, vec![100.0]))); // T = 0.01
        let out = e.tick(Some(&job(2, 50.0, vec![10.0]))); // T = 5
        let a = out.assigned.unwrap();
        assert_eq!(a.position, 0, "newcomer outranks incumbent head");
        assert_eq!(e.schedule(0).head().unwrap().id, 2);
        // The displaced job retains its accrued virtual work (n=1 from
        // the first tick) but stops accruing while off-head.
        assert_eq!(e.schedule(0).slots()[1].id, 1);
        assert_eq!(e.schedule(0).slots()[1].n, 1);
    }

    #[test]
    fn cost_accounts_for_queued_work() {
        // Machine 0 cheap but loaded; machine 1 pricier but empty.
        let mut e = SosEngine::new(2, 8, 1.0, Precision::Fp32);
        for i in 0..3 {
            e.tick(Some(&job(i, 10.0, vec![20.0, 100.0])));
        }
        // Job with ept 20 vs 26: naive picks m0; SOS sees m0's queue.
        let out = e.tick(Some(&job(9, 10.0, vec![20.0, 26.0])));
        let a = out.assigned.unwrap();
        assert_eq!(a.machine, 1, "queue-aware cost avoids the pile-up");
    }

    #[test]
    fn memoization_tracks_datapath_exactness() {
        for (p, want) in [
            (Precision::Int8, true),
            (Precision::Int4, true),
            (Precision::Mixed, true),
            (Precision::Fp32, false),
            (Precision::Fp16, false),
        ] {
            let e = SosEngine::new(2, 4, 0.5, p);
            assert_eq!(
                e.schedule(0).is_memoized(),
                want,
                "{} memoization",
                p.name()
            );
        }
    }

    #[test]
    fn quantized_engine_uses_quantized_attributes() {
        let mut e = SosEngine::new(1, 4, 0.5, Precision::Int8);
        e.tick(Some(&job(1, 3.7, vec![42.3])));
        let s = e.schedule(0).head().unwrap();
        assert_eq!(s.weight, 4.0);
        assert_eq!(s.ept, 42.0);
        assert_eq!(s.alpha_pt, 21);
    }

    #[test]
    fn next_event_tick_predicts_the_release() {
        let mut e = SosEngine::new(2, 4, 0.5, Precision::Fp32);
        assert_eq!(e.next_event_tick(), None, "fresh engine has no events");
        e.submit(job(1, 2.0, vec![10.0, 50.0])); // lands on m0, alpha_pt 5
        assert_eq!(e.next_event_tick(), Some(1), "pending arrival = next tick");
        e.tick(None); // assign at tick 1
        // accrues ticks 1..=5, pops at tick 6
        assert_eq!(e.next_event_tick(), Some(6));
        // per-tick driving confirms the prediction
        for t in 2..=5u64 {
            let out = e.tick(None);
            assert_eq!(out, TickOutcome::default(), "tick {t} must be empty");
        }
        let out = e.tick(None);
        assert_eq!(out.released, vec![(1, 0)]);
        assert_eq!(e.next_event_tick(), None, "drained: no further events");
    }

    #[test]
    fn advance_to_skips_exactly_the_empty_window() {
        // Two engines over the same scenario: one ticked, one jumped.
        let drive = |jump: bool| -> (u64, TickOutcome) {
            let mut e = SosEngine::new(2, 4, 0.5, Precision::Int8);
            e.submit(job(1, 8.0, vec![40.0, 90.0])); // alpha_pt = 20 on m0
            e.tick(None); // tick 1: assign
            let release = e.next_event_tick().expect("release scheduled");
            if jump {
                e.advance_to(release - 1);
            } else {
                for _ in e.tick_no()..release - 1 {
                    assert_eq!(e.tick(None), TickOutcome::default());
                }
            }
            assert_eq!(e.tick_no(), release - 1);
            (release, e.tick(None))
        };
        let (rt, ticked) = drive(false);
        let (rj, jumped) = drive(true);
        assert_eq!(rt, rj);
        assert_eq!(ticked, jumped);
        assert_eq!(ticked.released, vec![(1, 0)]);
    }

    #[test]
    fn horizon_survives_head_displacement() {
        // A higher-priority newcomer displaces the head; the stale
        // horizon entry must not cause an early pop, and the new head's
        // release must be predicted correctly.
        let mut e = SosEngine::new(1, 4, 1.0, Precision::Fp32);
        e.tick(Some(&job(1, 1.0, vec![100.0]))); // T=0.01, alpha_pt=100
        assert_eq!(e.next_event_tick(), Some(101));
        e.tick(Some(&job(2, 50.0, vec![10.0]))); // T=5 takes the head, alpha_pt=10
        // new head crowned at tick 2, accrues 2..=11, pops at 12
        assert_eq!(e.next_event_tick(), Some(12));
        e.advance_to(11);
        let out = e.tick(None);
        assert_eq!(out.released, vec![(2, 0)]);
        // job 1 resumes at the head with its retained n=1: crowned at
        // tick 12 (synced through 11), needs 99 more cycles -> pops at
        // 12 + 99 = 111
        assert_eq!(e.next_event_tick(), Some(111));
        e.advance_to(110);
        assert_eq!(e.tick(None).released, vec![(1, 0)]);
        assert!(e.is_idle());
    }

    #[test]
    fn materialize_exposes_per_tick_virtual_work() {
        let mut e = SosEngine::new(1, 4, 0.5, Precision::Int8);
        e.tick(Some(&job(1, 8.0, vec![40.0]))); // alpha_pt = 20
        for _ in 0..5 {
            e.tick(None);
        }
        // lazily the stored n may lag; materialized it must equal the
        // eager engine's count (assigned at tick 1, accrued ticks 1..=6)
        e.materialize();
        assert_eq!(e.schedule(0).head().unwrap().n, 6);
    }
}
