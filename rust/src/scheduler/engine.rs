//! The golden discrete-time SOS engine — tickless.
//!
//! One [`SosEngine::tick`] = one pass around the cyclical algorithmic
//! flow of Fig. 2b / Fig. 9, executing (in order):
//!
//! 1. **POP** (`B`) — release every machine head that reached its alpha
//!    point during a previous tick.
//! 2. **Cost + Insert** (`C`/`D`/`E`) — if a job is waiting at the
//!    arrival FIFO, compute `cost(J -> M_i)` for all machines over the
//!    post-pop state, pick the argmin (ties to the lowest machine index,
//!    matching both hardware Cost Comparators), insert at WSPT position.
//! 3. **Virtual work** (`F`) — the head of every non-empty schedule
//!    accrues one cycle of virtual work.
//!
//! Phases 1 and 3 used to cost O(machines) on *every* tick — including
//! the millions of pure-drain ticks at the end of a sweep cell, where
//! nothing can change. The engine is now event-driven:
//!
//! * **Phase 3 is implicit.** Virtual work lives lazily in each
//!   [`VirtualSchedule`] (`n = now - head_since`; see the vschedule
//!   module docs): the engine never loops over machines to accrue, it
//!   materializes a schedule only when it actually observes it (a pop or
//!   a cost query), via `sync_to(tick - 1)`.
//! * **Phase 1 reads an event horizon.** A min-heap of per-machine head
//!   release ticks (`head_since + alpha_pt - n₀`, pushed whenever a head
//!   is crowned, invalidated lazily) tells the engine exactly which
//!   machines can pop at the current tick, so pops cost
//!   O(pops · log machines) instead of an O(machines) scan per tick.
//! * **Drivers can jump.** [`SosEngine::next_event_tick`] exposes the
//!   horizon (earliest tick that can produce a non-empty
//!   [`TickOutcome`], absent new arrivals) and
//!   [`SosEngine::advance_to`] fast-forwards virtual time over a
//!   provably event-free window in O(1). Per-tick driving remains fully
//!   supported and bit-identical — the golden test pins it.
//!
//! Burst arrivals are serialized through the engine's internal FIFO: the
//! SOS algorithm assumes sequential job arrival (Phase I), so at most one
//! job is assigned per tick; the rest wait, exactly as the hardware's
//! host interface feeds one job per scheduling iteration.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::core::{Job, JobId, MachineId};
use crate::faults::{inflate_ept, DownPolicy, FaultKind, FaultPlan, FaultState, FaultStats};
use crate::quant::Precision;

use super::cost::{cost_of, FULL_COST};
use super::vschedule::{Slot, VirtualSchedule};
use super::wavefront::{Phase2Kernel, Phase2Work, Wavefront};

/// Result of assigning one job (Phase II). The full per-machine cost
/// vector is not stored here (it cost a heap allocation per assignment);
/// callers that render it read [`SosEngine::last_cost_vector`] right
/// after the tick instead.
#[derive(Debug, Clone, PartialEq)]
pub struct Assignment {
    pub job: JobId,
    pub machine: MachineId,
    /// Insertion index within the winning machine's virtual schedule.
    pub position: usize,
    /// Winning (minimum) cost.
    pub cost: f32,
}

/// Everything that happened in one scheduler tick.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TickOutcome {
    /// Jobs released to machine work queues this tick (Phase III pops).
    pub released: Vec<(JobId, MachineId)>,
    /// The job assigned this tick, if an arrival was processed.
    pub assigned: Option<Assignment>,
    /// True when an arrival was waiting but *every* machine was full.
    pub stalled: bool,
    /// Jobs evicted from a down machine back into the arrival FIFO this
    /// tick (fault layer; always empty in fault-free runs).
    pub evicted: Vec<(JobId, MachineId)>,
    /// Storm jobs injected into the arrival FIFO this tick (fault
    /// layer; the serve pipeline registers their payloads from here).
    pub injected: Vec<Job>,
    /// Additional same-tick assignments from a multi-domain engine (one
    /// per extra scheduling domain, e.g. the sharded coordinator's
    /// shards 1..K). Always empty for single-domain engines, so the
    /// single `assigned` slot keeps its historical meaning.
    pub co_assigned: Vec<Assignment>,
}

/// Golden software model of the discretized SOS algorithm.
#[derive(Debug, Clone)]
pub struct SosEngine {
    schedules: Vec<VirtualSchedule>,
    alpha: f32,
    precision: Precision,
    /// Arrival FIFO (burst serialization).
    pending: VecDeque<Job>,
    tick_no: u64,
    /// Scratch cost vector, reused across ticks to keep the hot loop
    /// allocation-free; exposed via [`Self::last_cost_vector`].
    cost_scratch: Vec<f32>,
    /// Event horizon: min-heap of (head release tick, machine). Entries
    /// are pushed whenever a head is crowned and invalidated lazily —
    /// an entry that no longer matches its machine's current head
    /// release is stale and skipped.
    horizon: BinaryHeap<Reverse<(u64, usize)>>,
    /// Scratch list of machines due at the current tick (kept as a
    /// field so pop processing allocates nothing in steady state).
    due_scratch: Vec<usize>,
    /// Installed fault layer, if any ([`Self::install_faults`]). Boxed:
    /// fault-free engines pay one pointer of state and a null check per
    /// tick phase.
    faults: Option<Box<FaultState>>,
    /// SoA mirror of per-machine cost-query state, swept by the
    /// batch-wavefront Phase II (see [`Wavefront`]'s module docs for
    /// the columns and the refresh invariant). Maintained only under
    /// [`Phase2Kernel::Wavefront`].
    wavefront: Wavefront,
    /// Which Phase-II cost kernel this engine runs (fixed at build).
    kernel: Phase2Kernel,
    /// Engine-work counters for the assignment path (the hotpath bench
    /// gates the wavefront batching win on these, not wall clock).
    work: Phase2Work,
}

impl SosEngine {
    pub fn new(machines: usize, depth: usize, alpha: f32, precision: Precision) -> Self {
        assert!(machines >= 1);
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha in (0, 1] (Phase III)");
        // Memoized threshold sums are only bit-exact for the fixed-point
        // WSPT datapaths; floating datapaths keep the rescan so their
        // schedules are unchanged (see vschedule module docs).
        let memoized = matches!(precision, Precision::Int8 | Precision::Int4 | Precision::Mixed);
        SosEngine {
            schedules: (0..machines)
                .map(|_| VirtualSchedule::with_memoization(depth, memoized))
                .collect(),
            alpha,
            precision,
            pending: VecDeque::new(),
            tick_no: 0,
            cost_scratch: vec![0.0; machines],
            horizon: BinaryHeap::with_capacity(machines),
            due_scratch: Vec::with_capacity(machines),
            faults: None,
            wavefront: Wavefront::new(machines, depth, memoized),
            kernel: Phase2Kernel::Wavefront,
            work: Phase2Work::default(),
        }
    }

    /// Downgrade Phase II to the historical per-machine scalar loop —
    /// the reference implementation the wavefront kernel is gated
    /// against (`tests/wavefront.rs`, the hotpath bench). Must be
    /// chosen before driving: the SoA mirror is not maintained in
    /// scalar mode, so the kernels cannot be switched mid-run.
    pub fn with_scalar_phase2(mut self) -> Self {
        assert_eq!(self.tick_no, 0, "choose the Phase-II kernel before driving");
        self.kernel = Phase2Kernel::Scalar;
        self
    }

    /// The Phase-II cost kernel this engine runs.
    pub fn phase2_kernel(&self) -> Phase2Kernel {
        self.kernel
    }

    /// Engine-work counters accumulated by the assignment path.
    pub fn phase2_work(&self) -> Phase2Work {
        self.work
    }

    /// Arm a deterministic fault plan (see [`crate::faults`]). The plan
    /// must have been built for this engine's park size, and must be
    /// installed before the first tick so every event lands on the
    /// virtual clock it was scheduled against.
    pub fn install_faults(&mut self, plan: FaultPlan) {
        assert_eq!(
            plan.machines(),
            self.schedules.len(),
            "fault plan built for a different park size"
        );
        assert_eq!(self.tick_no, 0, "install faults before driving the engine");
        let machines = self.schedules.len();
        self.faults = Some(Box::new(FaultState::new(plan, machines)));
    }

    /// Recovery metrics of the installed fault plan (None when the
    /// engine runs fault-free).
    pub fn fault_stats(&self) -> Option<&FaultStats> {
        self.faults.as_deref().map(|f| &f.stats)
    }

    pub fn machines(&self) -> usize {
        self.schedules.len()
    }

    pub fn depth(&self) -> usize {
        self.schedules[0].depth()
    }

    pub fn alpha(&self) -> f32 {
        self.alpha
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    pub fn tick_no(&self) -> u64 {
        self.tick_no
    }

    /// One machine's virtual schedule. NOTE: the head's stored `n` is
    /// materialized lazily; call [`Self::materialize`] first when
    /// inspecting virtual-work counters mid-run.
    pub fn schedule(&self, m: MachineId) -> &VirtualSchedule {
        &self.schedules[m]
    }

    /// All virtual schedules (same lazy-`n` caveat as [`Self::schedule`]).
    pub fn schedules(&self) -> &[VirtualSchedule] {
        &self.schedules
    }

    /// Materialize every schedule's virtual work through the current
    /// tick, so external inspection of slot `n` values sees the same
    /// state a per-tick engine would have after this tick's Phase III.
    /// Purely observational — never changes scheduling decisions.
    pub fn materialize(&mut self) {
        let now = self.tick_no;
        for vs in &mut self.schedules {
            vs.sync_to(now);
        }
    }

    /// Jobs waiting in the arrival FIFO (not yet assigned).
    pub fn backlog(&self) -> usize {
        self.pending.len()
    }

    /// Total jobs currently tracked across all virtual schedules.
    pub fn in_flight(&self) -> usize {
        self.schedules.iter().map(|v| v.len()).sum()
    }

    /// Enqueue an arrival without running a tick (used by burst sources).
    pub fn submit(&mut self, job: Job) {
        self.pending.push_back(job);
    }

    /// Enqueue one merged admission batch (a Phase-I burst) — the
    /// batched entry the serve/shard admission loop feeds. Scheduling
    /// semantics are identical to submitting each job in order: the
    /// FIFO still serializes Phase II to one assignment per tick, so
    /// batching changes how the burst is *costed*, never what is
    /// scheduled. Under the wavefront kernel each of the burst's
    /// Phase-II iterations sweeps the resident SoA columns (one winner
    /// sync + one row refresh per job) instead of running an
    /// independent scatter-gather scan over every machine's
    /// [`VirtualSchedule`].
    pub fn assign_batch(&mut self, jobs: impl IntoIterator<Item = Job>) {
        let before = self.pending.len();
        self.pending.extend(jobs);
        if self.pending.len() > before {
            self.work.batches += 1;
        }
    }

    /// Drain every queued-but-unstarted job out of the arrival FIFO, in
    /// FIFO order. Assigned work (virtual-schedule slots) is untouched —
    /// this is the rebalance surface of the sharded coordinator, which
    /// may only move jobs that no machine has started costing against.
    pub fn drain_backlog(&mut self) -> Vec<Job> {
        self.pending.drain(..).collect()
    }

    /// The earliest future tick that can produce a non-empty
    /// [`TickOutcome`], given no further submissions: the next tick
    /// while the FIFO holds work (an assignment or stall happens every
    /// tick), else the earliest of the next head release on the event
    /// horizon and the next pending fault event, else `None` (the
    /// engine is fully idle — nothing will ever happen again without a
    /// new arrival). Prunes stale horizon entries.
    ///
    /// Fault events are release-class events here *by construction*:
    /// every drive loop jumps to `min(next_event_tick, next_arrival)`,
    /// so a fault that was not folded into this minimum would be
    /// silently jumped over by [`Self::advance_to`]. A down machine's
    /// horizon entries are treated as stale (its head cannot pop); the
    /// matching up event re-arms them.
    pub fn next_event_tick(&mut self) -> Option<u64> {
        let floor = self.tick_no + 1;
        let fault_next = self
            .faults
            .as_deref()
            .and_then(|f| f.plan.next_tick())
            .map(|t| t.max(floor));
        if !self.pending.is_empty() {
            return Some(floor);
        }
        let mut release_next = None;
        while let Some(&Reverse((release, m))) = self.horizon.peek() {
            let is_down = self.faults.as_deref().is_some_and(|f| f.down[m]);
            if !is_down && self.schedules[m].head_release_tick() == Some(release) {
                release_next = Some(release.max(floor));
                break;
            }
            // stale: that head was popped or displaced — or its machine
            // is down (the up event re-arms the surviving head)
            self.horizon.pop();
        }
        match (release_next, fault_next) {
            (Some(r), Some(f)) => Some(r.min(f)),
            (r, f) => r.or(f),
        }
    }

    /// Fast-forward virtual time to `tick` in O(1). The caller must
    /// ensure the skipped window is event-free, i.e.
    /// `tick < next_event_tick()` (and that no arrival is due inside
    /// the window) — every skipped tick would have produced an empty
    /// outcome, so the jump is semantically invisible: virtual work is
    /// captured by the schedules' lazy representation.
    pub fn advance_to(&mut self, tick: u64) {
        assert!(tick >= self.tick_no, "virtual time cannot rewind");
        debug_assert!(
            self.next_event_tick().map_or(true, |e| e > tick),
            "advance_to({tick}) would jump over a scheduler event"
        );
        // Down machines stay down across the jump: account the dip
        // area/duration for the skipped window in bulk, bit-equal to
        // per-tick accounting.
        if let Some(f) = self.faults.as_deref_mut() {
            if f.n_down > 0 {
                let span = tick - self.tick_no;
                f.stats.degraded_ticks += span;
                f.stats.down_machine_ticks += span * f.n_down as u64;
            }
        }
        self.tick_no = tick;
    }

    /// (Re)arm the event horizon for machine `m`'s current head, if any.
    /// Called whenever a head is crowned (pop revealing a successor, or
    /// an insert landing at position 0). Old entries for the machine are
    /// not removed — they become stale and are skipped lazily.
    fn arm_horizon(&mut self, m: usize) {
        if let Some(release) = self.schedules[m].head_release_tick() {
            self.horizon.push(Reverse((release, m)));
        }
    }

    /// Re-mirror machine `m`'s row into the wavefront SoA columns.
    /// Called after every *structural* schedule mutation (insert, pop,
    /// eviction, up-skip); pure lazy syncs need no refresh — the sweep
    /// re-derives accrual read-only from the row's own `synced_at`.
    /// A no-op under the scalar kernel, which never reads the mirror.
    #[inline]
    fn mirror_refresh(&mut self, m: usize) {
        if self.kernel == Phase2Kernel::Wavefront {
            self.wavefront.refresh_row(m, &self.schedules[m]);
            self.work.row_refreshes += 1;
        }
    }

    /// Run one scheduler tick; `arrival` is this tick's new job, if any.
    pub fn tick(&mut self, arrival: Option<&Job>) -> TickOutcome {
        self.tick_no += 1;
        let now = self.tick_no;
        if let Some(j) = arrival {
            self.pending.push_back(j.clone());
        }

        let mut out = TickOutcome::default();

        // (0) Fault iteration part: apply every fault event due at this
        // tick before the pops, so the perturbed park is what the
        // tick's phases observe; then count the dip for this executed
        // tick (skipped windows are accounted in `advance_to`).
        self.apply_due_faults(now, &mut out);
        if let Some(f) = self.faults.as_deref_mut() {
            if f.n_down > 0 {
                f.stats.degraded_ticks += 1;
                f.stats.down_machine_ticks += f.n_down as u64;
            }
        }

        // (1) POP iteration part: only machines whose horizon entry is
        // due can possibly release. Releases must be reported in
        // machine-index order (matching the historical O(M) scan), so
        // collect, sort, dedupe, then process.
        let mut due = std::mem::take(&mut self.due_scratch);
        while let Some(&Reverse((release, m))) = self.horizon.peek() {
            if release > now {
                break;
            }
            self.horizon.pop();
            due.push(m);
        }
        if !due.is_empty() {
            due.sort_unstable();
            due.dedup();
            for &m in &due {
                if self.faults.as_deref().is_some_and(|f| f.down[m]) {
                    // down machine: the entry is dropped here and the
                    // surviving head re-armed by the up event
                    continue;
                }
                let vs = &mut self.schedules[m];
                vs.sync_to(now - 1);
                if vs.head().is_some_and(|h| h.ready()) {
                    let slot = vs.pop_head().expect("head checked above");
                    if let Some(f) = self.faults.as_deref_mut() {
                        f.retained.remove(&slot.id);
                    }
                    out.released.push((slot.id, m));
                    self.arm_horizon(m); // successor head, if any
                    self.mirror_refresh(m);
                }
                // else: a stale entry fired early; the machine's real
                // head keeps its own (future) horizon entry.
            }
            due.clear();
        }
        self.due_scratch = due;

        // (2) Insert iteration part: assign the oldest pending arrival.
        if !self.pending.is_empty() {
            let any_free = self
                .schedules
                .iter()
                .enumerate()
                .any(|(m, v)| {
                    !v.is_full() && !self.faults.as_deref().is_some_and(|f| f.down[m])
                });
            if any_free {
                let job = self.pending.pop_front().expect("front checked");
                out.assigned = Some(self.assign(&job));
            } else {
                out.stalled = true;
            }
        }

        // (3) Standard iteration part: virtual work accrues implicitly —
        // each schedule materializes `now - synced_at` cycles on its
        // head the next time it is observed.
        out
    }

    /// Apply every installed fault event due at `now` (start-of-tick).
    /// Field accesses stay split-borrow-friendly: the fault state is a
    /// disjoint field from the schedules/FIFO/horizon, so horizon pushes
    /// are inlined instead of going through [`Self::arm_horizon`].
    fn apply_due_faults(&mut self, now: u64, out: &mut TickOutcome) {
        let Some(f) = self.faults.as_deref_mut() else {
            return;
        };
        while let Some(ev) = f.plan.pop_due(now) {
            match ev.kind {
                FaultKind::Down(m) => {
                    f.stats.downs += 1;
                    if f.down[m] {
                        continue; // overlapping down window: already down
                    }
                    f.down[m] = true;
                    f.n_down += 1;
                    f.stats.max_concurrent_down = f.stats.max_concurrent_down.max(f.n_down);
                    let vs = &mut self.schedules[m];
                    vs.sync_to(now - 1);
                    let evicted = match f.plan.policy {
                        DownPolicy::Lose => vs.evict_all(),
                        DownPolicy::ResumeOnUp => vs.evict_tail(),
                    };
                    for slot in evicted {
                        f.stats.evicted_jobs += 1;
                        f.stats.work_lost_cycles += u64::from(slot.n);
                        let job = f
                            .retained
                            .remove(&slot.id)
                            .expect("every in-flight slot has a retained job");
                        f.evicted_at.insert(slot.id, now);
                        out.evicted.push((slot.id, m));
                        // re-queue in schedule (priority) order: the
                        // FIFO serializes the re-assignments one per
                        // tick, deterministically
                        self.pending.push_back(job);
                    }
                    // mirror hooks inlined (the live `f` borrow rules
                    // out the method call; these fields are disjoint)
                    if self.kernel == Phase2Kernel::Wavefront {
                        self.wavefront.set_down(m, true);
                        self.wavefront.refresh_row(m, &self.schedules[m]);
                        self.work.row_refreshes += 1;
                    }
                }
                FaultKind::Up(m) => {
                    f.stats.ups += 1;
                    if !f.down[m] {
                        continue;
                    }
                    f.down[m] = false;
                    f.n_down -= 1;
                    let vs = &mut self.schedules[m];
                    // downtime cycles never happened: advance the
                    // schedule's clock without accrual so the surviving
                    // head resumes exactly where it stopped
                    vs.skip_to(now - 1);
                    if let Some(release) = vs.head_release_tick() {
                        self.horizon.push(Reverse((release, m)));
                    }
                    if self.kernel == Phase2Kernel::Wavefront {
                        self.wavefront.set_down(m, false);
                        self.wavefront.refresh_row(m, &self.schedules[m]);
                        self.work.row_refreshes += 1;
                    }
                }
                FaultKind::SlowStart(m, factor) => {
                    f.stats.slow_events += 1;
                    f.slow[m] = factor.max(1);
                    if self.kernel == Phase2Kernel::Wavefront {
                        self.wavefront.set_slow(m, factor);
                    }
                }
                FaultKind::SlowEnd(m) => {
                    f.slow[m] = 1;
                    if self.kernel == Phase2Kernel::Wavefront {
                        self.wavefront.set_slow(m, 1);
                    }
                }
                FaultKind::Storm(jobs) => {
                    f.stats.storms += 1;
                    for job in jobs {
                        f.stats.injected_jobs += 1;
                        out.injected.push(job.clone());
                        self.pending.push_back(job);
                    }
                }
            }
        }
    }

    /// EPT the park quotes for `job` on machine `m`: the raw per-machine
    /// EPT, inflated when the fault layer marks `m` as a straggler —
    /// newly assigned jobs only; in-flight slots keep their contracted
    /// rate. Single source for the scalar cost probe and the winner's
    /// slot build; the wavefront sweep applies the same
    /// [`inflate_ept`] through its mirrored slow column.
    #[inline]
    fn effective_ept(&self, m: usize, job: &Job) -> f32 {
        inflate_ept(job.ept[m], self.faults.as_deref().map_or(1, |f| f.slow[m]))
    }

    /// The historical per-machine Phase-II scan — lazy-sync each
    /// schedule, then `cost_of` over it — retained as the scalar
    /// reference the wavefront kernel is gated against. Fills the cost
    /// vector and returns the argmin.
    fn scalar_scan(&mut self, job: &Job, now: u64) -> Option<(usize, f32, usize)> {
        let mut best: Option<(usize, f32, usize)> = None; // (machine, cost, pos)
        for m in 0..self.schedules.len() {
            if self.faults.as_deref().is_some_and(|f| f.down[m]) {
                // a down machine is excluded from Phase II outright (its
                // V_i is unreachable); do NOT sync it — downtime must
                // not accrue virtual work
                self.cost_scratch[m] = FULL_COST;
                continue;
            }
            let (j_w, j_eps, j_t) = self.precision.q_job(job.weight, self.effective_ept(m, job));
            // cost is computed over the post-pop state with virtual work
            // through the previous tick's Phase III
            let vs = &mut self.schedules[m];
            vs.sync_to(now - 1);
            self.work.schedule_syncs += 1;
            match cost_of(vs, j_w, j_eps, j_t) {
                Some(c) => {
                    let total = c.total();
                    self.cost_scratch[m] = total;
                    // strict < keeps the first (lowest-index) minimum
                    if best.map_or(true, |(_, bc, _)| total < bc) {
                        best = Some((m, total, c.position));
                    }
                }
                None => {
                    self.cost_scratch[m] = FULL_COST;
                }
            }
        }
        best
    }

    /// `strict-oracle` cross-check: re-derive the whole Phase-II
    /// decision through the scalar oracle (`cost_of` over a synced
    /// clone of each live schedule) and require bit-equality with the
    /// kernel's cost vector and argmin. Runs on every assignment when
    /// the feature is enabled (CI's tier-1 test job).
    #[cfg(feature = "strict-oracle")]
    fn assert_kernel_matches_scalar_oracle(
        &self,
        job: &Job,
        now: u64,
        best: Option<(usize, f32, usize)>,
    ) {
        let mut oracle: Option<(usize, f32, usize)> = None;
        for (m, vs) in self.schedules.iter().enumerate() {
            if self.faults.as_deref().is_some_and(|f| f.down[m]) {
                assert_eq!(self.cost_scratch[m], FULL_COST, "machine {m} is down");
                continue;
            }
            let (j_w, j_eps, j_t) = self.precision.q_job(job.weight, self.effective_ept(m, job));
            let mut synced = vs.clone();
            synced.sync_to(now - 1);
            match cost_of(&synced, j_w, j_eps, j_t) {
                Some(c) => {
                    assert_eq!(
                        self.cost_scratch[m],
                        c.total(),
                        "machine {m}: kernel cost drifted from the scalar oracle"
                    );
                    if oracle.map_or(true, |(_, bc, _)| c.total() < bc) {
                        oracle = Some((m, c.total(), c.position));
                    }
                }
                None => {
                    assert_eq!(self.cost_scratch[m], FULL_COST, "machine {m} is full");
                }
            }
        }
        assert_eq!(best, oracle, "Phase-II argmin drifted from the scalar oracle");
    }

    /// Phase II machine assignment: cost all machines, argmin, insert.
    /// The cost pass runs on the configured kernel — one wavefront
    /// sweep over the SoA mirror columns (default), or the scalar
    /// per-machine scan — with bit-identical results: same per-machine
    /// costs, same strict-`<` lowest-index argmin, same insert position.
    fn assign(&mut self, job: &Job) -> Assignment {
        debug_assert_eq!(job.fanout(), self.schedules.len());
        let now = self.tick_no;
        self.work.probes +=
            (self.schedules.len() - self.faults.as_deref().map_or(0, |f| f.n_down)) as u64;
        let best = match self.kernel {
            Phase2Kernel::Wavefront => self.wavefront.sweep(
                job.weight,
                &job.ept,
                self.precision,
                now,
                &mut self.cost_scratch,
            ),
            Phase2Kernel::Scalar => self.scalar_scan(job, now),
        };
        #[cfg(feature = "strict-oracle")]
        self.assert_kernel_matches_scalar_oracle(job, now, best);
        let (machine, cost, position) =
            best.expect("assign() requires at least one non-full machine");
        // the winner materializes through the previous tick before the
        // insert (the wavefront sweep is read-only and never synced it;
        // for the scalar scan this re-sync is a no-op)
        self.schedules[machine].sync_to(now - 1);
        self.work.schedule_syncs += 1;
        let (j_w, j_eps, j_t) = self
            .precision
            .q_job(job.weight, self.effective_ept(machine, job));
        let slot = Slot {
            id: job.id,
            weight: j_w,
            ept: j_eps,
            wspt: j_t,
            alpha_pt: (self.alpha * j_eps).ceil() as u32,
            n: 0,
        };
        let inserted_at = self.schedules[machine].insert(slot);
        debug_assert_eq!(inserted_at, position, "cost position == insert position");
        debug_assert!(self.schedules[machine].is_properly_ordered());
        self.mirror_refresh(machine);
        if inserted_at == 0 {
            // the newcomer is the head (fresh schedule or displacement):
            // its release defines the machine's next horizon event
            self.arm_horizon(machine);
        }
        if let Some(f) = self.faults.as_deref_mut() {
            // retain the payload so a future machine-down can re-queue
            // this slot; close the re-queue latency loop if this very
            // assignment is such a re-queue landing
            f.retained.insert(job.id, job.clone());
            if let Some(t0) = f.evicted_at.remove(&job.id) {
                f.stats.requeue_latency.record(now - t0);
            }
        }
        Assignment {
            job: job.id,
            machine,
            position,
            cost,
        }
    }

    /// Full per-machine cost vector of the most recent assignment
    /// (`FULL_COST` where the V_i was full) — borrowed from the engine's
    /// scratch, valid until the next assignment. This replaces the old
    /// per-assignment `Assignment.cost_vector` clone so the steady-state
    /// assign path allocates nothing.
    pub fn last_cost_vector(&self) -> &[f32] {
        &self.cost_scratch
    }

    /// Drain-mode tick: no arrivals, just pops + virtual work. Used to
    /// flush schedules at end of trace.
    pub fn drain_tick(&mut self) -> TickOutcome {
        self.tick(None)
    }

    /// True when no work remains anywhere in the scheduler. A faulted
    /// engine is never idle while fault events are still scheduled — an
    /// empty park must keep running into a pending storm (and a down
    /// machine's recovery metrics need its up event to fire).
    pub fn is_idle(&self) -> bool {
        self.pending.is_empty()
            && self.schedules.iter().all(|v| v.is_empty())
            && self.faults.as_deref().map_or(true, |f| f.plan.is_done())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::JobNature;

    fn job(id: u64, w: f32, ept: Vec<f32>) -> Job {
        Job::new(id, w, ept, JobNature::Mixed)
    }

    #[test]
    fn single_job_lands_on_cheapest_machine() {
        let mut e = SosEngine::new(3, 4, 0.5, Precision::Fp32);
        let j = job(1, 2.0, vec![50.0, 10.0, 30.0]);
        let out = e.tick(Some(&j));
        let a = out.assigned.unwrap();
        assert_eq!(a.machine, 1); // cost = W*eps = 100/20/60
        assert_eq!(a.cost, 20.0);
        assert_eq!(a.position, 0);
        assert_eq!(e.last_cost_vector(), &[100.0, 20.0, 60.0][..]);
    }

    #[test]
    fn tie_goes_to_lowest_machine_index() {
        let mut e = SosEngine::new(3, 4, 0.5, Precision::Fp32);
        let j = job(1, 2.0, vec![10.0, 10.0, 10.0]);
        assert_eq!(e.tick(Some(&j)).assigned.unwrap().machine, 0);
    }

    #[test]
    fn head_releases_at_alpha_point() {
        let mut e = SosEngine::new(1, 4, 0.5, Precision::Fp32);
        let j = job(1, 2.0, vec![10.0]); // alpha_pt = 5
        e.tick(Some(&j));
        let mut released_at = None;
        for t in 2..=10 {
            let out = e.tick(None);
            if !out.released.is_empty() {
                released_at = Some(t);
                assert_eq!(out.released[0], (1, 0));
                break;
            }
        }
        // assigned at tick 1 (accrues at 1..=5), pops at tick 6
        assert_eq!(released_at, Some(6));
        assert!(e.is_idle());
    }

    #[test]
    fn burst_is_serialized_one_assignment_per_tick() {
        let mut e = SosEngine::new(2, 8, 0.5, Precision::Fp32);
        for i in 0..4 {
            e.submit(job(i, 2.0, vec![20.0, 20.0]));
        }
        let mut assigned = 0;
        for _ in 0..4 {
            let out = e.tick(None);
            assert!(out.assigned.is_some());
            assigned += 1;
        }
        assert_eq!(assigned, 4);
        assert_eq!(e.backlog(), 0);
    }

    #[test]
    fn stall_when_all_machines_full() {
        let mut e = SosEngine::new(1, 1, 1.0, Precision::Fp32);
        e.tick(Some(&job(1, 2.0, vec![100.0])));
        let out = e.tick(Some(&job(2, 2.0, vec![100.0])));
        assert!(out.stalled);
        assert!(out.assigned.is_none());
        assert_eq!(e.backlog(), 1);
    }

    #[test]
    fn higher_priority_newcomer_takes_head() {
        let mut e = SosEngine::new(1, 4, 1.0, Precision::Fp32);
        e.tick(Some(&job(1, 1.0, vec![100.0]))); // T = 0.01
        let out = e.tick(Some(&job(2, 50.0, vec![10.0]))); // T = 5
        let a = out.assigned.unwrap();
        assert_eq!(a.position, 0, "newcomer outranks incumbent head");
        assert_eq!(e.schedule(0).head().unwrap().id, 2);
        // The displaced job retains its accrued virtual work (n=1 from
        // the first tick) but stops accruing while off-head.
        assert_eq!(e.schedule(0).slots()[1].id, 1);
        assert_eq!(e.schedule(0).slots()[1].n, 1);
    }

    #[test]
    fn cost_accounts_for_queued_work() {
        // Machine 0 cheap but loaded; machine 1 pricier but empty.
        let mut e = SosEngine::new(2, 8, 1.0, Precision::Fp32);
        for i in 0..3 {
            e.tick(Some(&job(i, 10.0, vec![20.0, 100.0])));
        }
        // Job with ept 20 vs 26: naive picks m0; SOS sees m0's queue.
        let out = e.tick(Some(&job(9, 10.0, vec![20.0, 26.0])));
        let a = out.assigned.unwrap();
        assert_eq!(a.machine, 1, "queue-aware cost avoids the pile-up");
    }

    #[test]
    fn memoization_tracks_datapath_exactness() {
        for (p, want) in [
            (Precision::Int8, true),
            (Precision::Int4, true),
            (Precision::Mixed, true),
            (Precision::Fp32, false),
            (Precision::Fp16, false),
        ] {
            let e = SosEngine::new(2, 4, 0.5, p);
            assert_eq!(
                e.schedule(0).is_memoized(),
                want,
                "{} memoization",
                p.name()
            );
        }
    }

    #[test]
    fn quantized_engine_uses_quantized_attributes() {
        let mut e = SosEngine::new(1, 4, 0.5, Precision::Int8);
        e.tick(Some(&job(1, 3.7, vec![42.3])));
        let s = e.schedule(0).head().unwrap();
        assert_eq!(s.weight, 4.0);
        assert_eq!(s.ept, 42.0);
        assert_eq!(s.alpha_pt, 21);
    }

    #[test]
    fn next_event_tick_predicts_the_release() {
        let mut e = SosEngine::new(2, 4, 0.5, Precision::Fp32);
        assert_eq!(e.next_event_tick(), None, "fresh engine has no events");
        e.submit(job(1, 2.0, vec![10.0, 50.0])); // lands on m0, alpha_pt 5
        assert_eq!(e.next_event_tick(), Some(1), "pending arrival = next tick");
        e.tick(None); // assign at tick 1
        // accrues ticks 1..=5, pops at tick 6
        assert_eq!(e.next_event_tick(), Some(6));
        // per-tick driving confirms the prediction
        for t in 2..=5u64 {
            let out = e.tick(None);
            assert_eq!(out, TickOutcome::default(), "tick {t} must be empty");
        }
        let out = e.tick(None);
        assert_eq!(out.released, vec![(1, 0)]);
        assert_eq!(e.next_event_tick(), None, "drained: no further events");
    }

    #[test]
    fn advance_to_skips_exactly_the_empty_window() {
        // Two engines over the same scenario: one ticked, one jumped.
        let drive = |jump: bool| -> (u64, TickOutcome) {
            let mut e = SosEngine::new(2, 4, 0.5, Precision::Int8);
            e.submit(job(1, 8.0, vec![40.0, 90.0])); // alpha_pt = 20 on m0
            e.tick(None); // tick 1: assign
            let release = e.next_event_tick().expect("release scheduled");
            if jump {
                e.advance_to(release - 1);
            } else {
                for _ in e.tick_no()..release - 1 {
                    assert_eq!(e.tick(None), TickOutcome::default());
                }
            }
            assert_eq!(e.tick_no(), release - 1);
            (release, e.tick(None))
        };
        let (rt, ticked) = drive(false);
        let (rj, jumped) = drive(true);
        assert_eq!(rt, rj);
        assert_eq!(ticked, jumped);
        assert_eq!(ticked.released, vec![(1, 0)]);
    }

    #[test]
    fn horizon_survives_head_displacement() {
        // A higher-priority newcomer displaces the head; the stale
        // horizon entry must not cause an early pop, and the new head's
        // release must be predicted correctly.
        let mut e = SosEngine::new(1, 4, 1.0, Precision::Fp32);
        e.tick(Some(&job(1, 1.0, vec![100.0]))); // T=0.01, alpha_pt=100
        assert_eq!(e.next_event_tick(), Some(101));
        e.tick(Some(&job(2, 50.0, vec![10.0]))); // T=5 takes the head, alpha_pt=10
        // new head crowned at tick 2, accrues 2..=11, pops at 12
        assert_eq!(e.next_event_tick(), Some(12));
        e.advance_to(11);
        let out = e.tick(None);
        assert_eq!(out.released, vec![(2, 0)]);
        // job 1 resumes at the head with its retained n=1: crowned at
        // tick 12 (synced through 11), needs 99 more cycles -> pops at
        // 12 + 99 = 111
        assert_eq!(e.next_event_tick(), Some(111));
        e.advance_to(110);
        assert_eq!(e.tick(None).released, vec![(1, 0)]);
        assert!(e.is_idle());
    }

    #[test]
    fn fault_event_bounds_the_horizon_jump() {
        // An otherwise-empty engine with a pending storm: the storm tick
        // must surface through next_event_tick, so drive loops cannot
        // jump over it (the tickless fault invariant).
        let mut e = SosEngine::new(2, 4, 0.5, Precision::Int8);
        e.install_faults(
            crate::faults::FaultSpec::parse("storm=2@50,seed=3")
                .unwrap()
                .plan(2)
                .unwrap(),
        );
        assert!(!e.is_idle(), "pending storm keeps the engine live");
        assert_eq!(e.next_event_tick(), Some(50));
        e.advance_to(49); // legal: [1, 49] is provably event-free
        let out = e.tick(None);
        assert_eq!(out.injected.len(), 2);
        assert!(out.assigned.is_some(), "first storm job assigned same tick");
        assert_eq!(e.fault_stats().unwrap().injected_jobs, 2);
    }

    #[test]
    fn down_resume_pauses_the_head_and_evicts_the_tail() {
        let mut e = SosEngine::new(1, 4, 1.0, Precision::Fp32);
        e.install_faults(crate::faults::FaultSpec::parse("down=0@5+10").unwrap().plan(1).unwrap());
        e.tick(Some(&job(1, 2.0, vec![10.0]))); // tick 1: head, alpha_pt 10 -> pops at 11
        e.tick(Some(&job(2, 1.0, vec![10.0]))); // tick 2: tail (T 0.1 < 0.2)
        e.advance_to(4);
        let out = e.tick(None); // tick 5: machine 0 goes down
        assert_eq!(out.evicted, vec![(2, 0)]);
        // the evicted job re-queues immediately, but the whole park is
        // down, so the engine stalls deterministically until the up
        assert!(out.stalled);
        for t in 6..=14u64 {
            assert!(e.tick(None).stalled, "tick {t}: park fully down");
        }
        let out = e.tick(None); // tick 15: up fires, job 2 re-assigns
        assert_eq!(out.assigned.expect("re-queued job lands").job, 2);
        // the head accrued 4 cycles before the down (ticks 1..=4) and
        // none while down: 6 remain after the up at 15 -> pops at 21
        assert_eq!(e.next_event_tick(), Some(21));
        e.advance_to(20);
        assert_eq!(e.tick(None).released, vec![(1, 0)]);
        let stats = e.fault_stats().unwrap();
        assert_eq!(stats.evicted_jobs, 1);
        assert_eq!(stats.degraded_ticks, 10);
        assert_eq!(stats.down_machine_ticks, 10);
        assert_eq!(stats.max_concurrent_down, 1);
        assert_eq!(stats.requeue_latency.count(), 1);
        assert_eq!(stats.requeue_latency.max(), 10, "evicted at 5, re-landed at 15");
        assert_eq!(stats.work_lost_cycles, 0, "resume: no virtual work discarded");
    }

    #[test]
    fn down_lose_discards_the_heads_progress() {
        let mut e = SosEngine::new(2, 4, 1.0, Precision::Fp32);
        e.install_faults(
            crate::faults::FaultSpec::parse("down=0@6+4,policy=lose")
                .unwrap()
                .plan(2)
                .unwrap(),
        );
        e.tick(Some(&job(1, 2.0, vec![10.0, 100.0]))); // m0, alpha_pt 10
        e.advance_to(5);
        let out = e.tick(None); // tick 6: down evicts the running head
        assert_eq!(out.evicted, vec![(1, 0)]);
        // the evicted job re-enters the FIFO before Phase II, so it
        // restarts from scratch the same tick; m0 is down -> lands on m1
        let a = out.assigned.unwrap();
        assert_eq!((a.job, a.machine), (1, 1));
        assert_eq!(e.fault_stats().unwrap().work_lost_cycles, 5);
    }

    #[test]
    fn slow_machine_inflates_new_assignments_only() {
        let mut e = SosEngine::new(1, 4, 0.5, Precision::Fp32);
        e.install_faults(crate::faults::FaultSpec::parse("slow=0@2+10x4").unwrap().plan(1).unwrap());
        e.tick(Some(&job(1, 2.0, vec![10.0]))); // before the slow: ept 10
        assert_eq!(e.schedule(0).head().unwrap().ept, 10.0);
        let out = e.tick(Some(&job(2, 2.0, vec![10.0]))); // during: ept x4
        assert!(out.assigned.is_some());
        let slot = e.schedule(0).slots().iter().find(|s| s.id == 2).unwrap();
        assert_eq!(slot.ept, 40.0, "straggler inflation applied at assignment");
        assert_eq!(slot.alpha_pt, 20);
        assert_eq!(
            e.schedule(0).head().unwrap().ept,
            10.0,
            "in-flight head keeps its contracted rate"
        );
    }

    #[test]
    fn materialize_exposes_per_tick_virtual_work() {
        let mut e = SosEngine::new(1, 4, 0.5, Precision::Int8);
        e.tick(Some(&job(1, 8.0, vec![40.0]))); // alpha_pt = 20
        for _ in 0..5 {
            e.tick(None);
        }
        // lazily the stored n may lag; materialized it must equal the
        // eager engine's count (assigned at tick 1, accrued ticks 1..=6)
        e.materialize();
        assert_eq!(e.schedule(0).head().unwrap().n, 6);
    }
}
