//! The golden discrete-time SOS engine.
//!
//! One [`SosEngine::tick`] = one pass around the cyclical algorithmic
//! flow of Fig. 2b / Fig. 9, executing (in order):
//!
//! 1. **POP** (`B`) — release every machine head that reached its alpha
//!    point during a previous tick.
//! 2. **Cost + Insert** (`C`/`D`/`E`) — if a job is waiting at the
//!    arrival FIFO, compute `cost(J -> M_i)` for all machines over the
//!    post-pop state, pick the argmin (ties to the lowest machine index,
//!    matching both hardware Cost Comparators), insert at WSPT position.
//! 3. **Virtual work** (`F`) — the head of every non-empty schedule
//!    accrues one cycle of virtual work.
//!
//! Burst arrivals are serialized through the engine's internal FIFO: the
//! SOS algorithm assumes sequential job arrival (Phase I), so at most one
//! job is assigned per tick; the rest wait, exactly as the hardware's
//! host interface feeds one job per scheduling iteration.

use std::collections::VecDeque;

use crate::core::{Job, JobId, MachineId};
use crate::quant::Precision;

use super::cost::{cost_of, FULL_COST};
use super::vschedule::{Slot, VirtualSchedule};

/// Result of assigning one job (Phase II).
#[derive(Debug, Clone, PartialEq)]
pub struct Assignment {
    pub job: JobId,
    pub machine: MachineId,
    /// Insertion index within the winning machine's virtual schedule.
    pub position: usize,
    /// Winning (minimum) cost.
    pub cost: f32,
    /// Full per-machine cost vector (FULL_COST where the V_i was full).
    pub cost_vector: Vec<f32>,
}

/// Everything that happened in one scheduler tick.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TickOutcome {
    /// Jobs released to machine work queues this tick (Phase III pops).
    pub released: Vec<(JobId, MachineId)>,
    /// The job assigned this tick, if an arrival was processed.
    pub assigned: Option<Assignment>,
    /// True when an arrival was waiting but *every* machine was full.
    pub stalled: bool,
}

/// Golden software model of the discretized SOS algorithm.
#[derive(Debug, Clone)]
pub struct SosEngine {
    schedules: Vec<VirtualSchedule>,
    alpha: f32,
    precision: Precision,
    /// Arrival FIFO (burst serialization).
    pending: VecDeque<Job>,
    tick_no: u64,
    /// Scratch cost vector, reused across ticks to keep the hot loop
    /// allocation-free.
    cost_scratch: Vec<f32>,
}

impl SosEngine {
    pub fn new(machines: usize, depth: usize, alpha: f32, precision: Precision) -> Self {
        assert!(machines >= 1);
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha in (0, 1] (Phase III)");
        // Memoized threshold sums are only bit-exact for the fixed-point
        // WSPT datapaths; floating datapaths keep the rescan so their
        // schedules are unchanged (see vschedule module docs).
        let memoized = matches!(precision, Precision::Int8 | Precision::Int4 | Precision::Mixed);
        SosEngine {
            schedules: (0..machines)
                .map(|_| VirtualSchedule::with_memoization(depth, memoized))
                .collect(),
            alpha,
            precision,
            pending: VecDeque::new(),
            tick_no: 0,
            cost_scratch: vec![0.0; machines],
        }
    }

    pub fn machines(&self) -> usize {
        self.schedules.len()
    }

    pub fn depth(&self) -> usize {
        self.schedules[0].depth()
    }

    pub fn alpha(&self) -> f32 {
        self.alpha
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    pub fn tick_no(&self) -> u64 {
        self.tick_no
    }

    pub fn schedule(&self, m: MachineId) -> &VirtualSchedule {
        &self.schedules[m]
    }

    pub fn schedules(&self) -> &[VirtualSchedule] {
        &self.schedules
    }

    /// Jobs waiting in the arrival FIFO (not yet assigned).
    pub fn backlog(&self) -> usize {
        self.pending.len()
    }

    /// Total jobs currently tracked across all virtual schedules.
    pub fn in_flight(&self) -> usize {
        self.schedules.iter().map(|v| v.len()).sum()
    }

    /// Enqueue an arrival without running a tick (used by burst sources).
    pub fn submit(&mut self, job: Job) {
        self.pending.push_back(job);
    }

    /// Run one scheduler tick; `arrival` is this tick's new job, if any.
    pub fn tick(&mut self, arrival: Option<&Job>) -> TickOutcome {
        self.tick_no += 1;
        if let Some(j) = arrival {
            self.pending.push_back(j.clone());
        }

        let mut out = TickOutcome::default();

        // (1) POP iteration part: alpha-ready heads release to machines.
        for (m, vs) in self.schedules.iter_mut().enumerate() {
            if vs.head().is_some_and(|h| h.ready()) {
                let slot = vs.pop_head().expect("head checked above");
                out.released.push((slot.id, m));
            }
        }

        // (2) Insert iteration part: assign the oldest pending arrival.
        if !self.pending.is_empty() {
            let any_free = self.schedules.iter().any(|v| !v.is_full());
            if any_free {
                let job = self.pending.pop_front().expect("front checked");
                out.assigned = Some(self.assign(&job));
            } else {
                out.stalled = true;
            }
        }

        // (3) Standard iteration part: heads accrue virtual work.
        for vs in &mut self.schedules {
            vs.accrue();
        }

        out
    }

    /// Phase II machine assignment: cost all machines, argmin, insert.
    fn assign(&mut self, job: &Job) -> Assignment {
        debug_assert_eq!(job.fanout(), self.schedules.len());
        let mut best: Option<(usize, f32, usize)> = None; // (machine, cost, pos)
        for (m, vs) in self.schedules.iter().enumerate() {
            let (j_w, j_eps, j_t) = self.precision.q_job(job.weight, job.ept[m]);
            match cost_of(vs, j_w, j_eps, j_t) {
                Some(c) => {
                    let total = c.total();
                    self.cost_scratch[m] = total;
                    // strict < keeps the first (lowest-index) minimum
                    if best.map_or(true, |(_, bc, _)| total < bc) {
                        best = Some((m, total, c.position));
                    }
                }
                None => {
                    self.cost_scratch[m] = FULL_COST;
                }
            }
        }
        let (machine, cost, position) =
            best.expect("assign() requires at least one non-full machine");
        let (j_w, j_eps, j_t) = self.precision.q_job(job.weight, job.ept[machine]);
        let slot = Slot {
            id: job.id,
            weight: j_w,
            ept: j_eps,
            wspt: j_t,
            alpha_pt: (self.alpha * j_eps).ceil() as u32,
            n: 0,
        };
        let inserted_at = self.schedules[machine].insert(slot);
        debug_assert_eq!(inserted_at, position, "cost position == insert position");
        debug_assert!(self.schedules[machine].is_properly_ordered());
        Assignment {
            job: job.id,
            machine,
            position,
            cost,
            cost_vector: self.cost_scratch.clone(),
        }
    }

    /// Drain-mode tick: no arrivals, just pops + virtual work. Used to
    /// flush schedules at end of trace.
    pub fn drain_tick(&mut self) -> TickOutcome {
        self.tick(None)
    }

    /// True when no work remains anywhere in the scheduler.
    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.schedules.iter().all(|v| v.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::JobNature;

    fn job(id: u64, w: f32, ept: Vec<f32>) -> Job {
        Job::new(id, w, ept, JobNature::Mixed)
    }

    #[test]
    fn single_job_lands_on_cheapest_machine() {
        let mut e = SosEngine::new(3, 4, 0.5, Precision::Fp32);
        let j = job(1, 2.0, vec![50.0, 10.0, 30.0]);
        let out = e.tick(Some(&j));
        let a = out.assigned.unwrap();
        assert_eq!(a.machine, 1); // cost = W*eps = 100/20/60
        assert_eq!(a.cost, 20.0);
        assert_eq!(a.position, 0);
        assert_eq!(a.cost_vector, vec![100.0, 20.0, 60.0]);
    }

    #[test]
    fn tie_goes_to_lowest_machine_index() {
        let mut e = SosEngine::new(3, 4, 0.5, Precision::Fp32);
        let j = job(1, 2.0, vec![10.0, 10.0, 10.0]);
        assert_eq!(e.tick(Some(&j)).assigned.unwrap().machine, 0);
    }

    #[test]
    fn head_releases_at_alpha_point() {
        let mut e = SosEngine::new(1, 4, 0.5, Precision::Fp32);
        let j = job(1, 2.0, vec![10.0]); // alpha_pt = 5
        e.tick(Some(&j));
        let mut released_at = None;
        for t in 2..=10 {
            let out = e.tick(None);
            if !out.released.is_empty() {
                released_at = Some(t);
                assert_eq!(out.released[0], (1, 0));
                break;
            }
        }
        // assigned at tick 1 (accrues at 1..=5), pops at tick 6
        assert_eq!(released_at, Some(6));
        assert!(e.is_idle());
    }

    #[test]
    fn burst_is_serialized_one_assignment_per_tick() {
        let mut e = SosEngine::new(2, 8, 0.5, Precision::Fp32);
        for i in 0..4 {
            e.submit(job(i, 2.0, vec![20.0, 20.0]));
        }
        let mut assigned = 0;
        for _ in 0..4 {
            let out = e.tick(None);
            assert!(out.assigned.is_some());
            assigned += 1;
        }
        assert_eq!(assigned, 4);
        assert_eq!(e.backlog(), 0);
    }

    #[test]
    fn stall_when_all_machines_full() {
        let mut e = SosEngine::new(1, 1, 1.0, Precision::Fp32);
        e.tick(Some(&job(1, 2.0, vec![100.0])));
        let out = e.tick(Some(&job(2, 2.0, vec![100.0])));
        assert!(out.stalled);
        assert!(out.assigned.is_none());
        assert_eq!(e.backlog(), 1);
    }

    #[test]
    fn higher_priority_newcomer_takes_head() {
        let mut e = SosEngine::new(1, 4, 1.0, Precision::Fp32);
        e.tick(Some(&job(1, 1.0, vec![100.0]))); // T = 0.01
        let out = e.tick(Some(&job(2, 50.0, vec![10.0]))); // T = 5
        let a = out.assigned.unwrap();
        assert_eq!(a.position, 0, "newcomer outranks incumbent head");
        assert_eq!(e.schedule(0).head().unwrap().id, 2);
        // The displaced job retains its accrued virtual work (n=1 from
        // the first tick) but stops accruing while off-head.
        assert_eq!(e.schedule(0).slots()[1].id, 1);
        assert_eq!(e.schedule(0).slots()[1].n, 1);
    }

    #[test]
    fn cost_accounts_for_queued_work() {
        // Machine 0 cheap but loaded; machine 1 pricier but empty.
        let mut e = SosEngine::new(2, 8, 1.0, Precision::Fp32);
        for i in 0..3 {
            e.tick(Some(&job(i, 10.0, vec![20.0, 100.0])));
        }
        // Job with ept 20 vs 26: naive picks m0; SOS sees m0's queue.
        let out = e.tick(Some(&job(9, 10.0, vec![20.0, 26.0])));
        let a = out.assigned.unwrap();
        assert_eq!(a.machine, 1, "queue-aware cost avoids the pile-up");
    }

    #[test]
    fn memoization_tracks_datapath_exactness() {
        for (p, want) in [
            (Precision::Int8, true),
            (Precision::Int4, true),
            (Precision::Mixed, true),
            (Precision::Fp32, false),
            (Precision::Fp16, false),
        ] {
            let e = SosEngine::new(2, 4, 0.5, p);
            assert_eq!(
                e.schedule(0).is_memoized(),
                want,
                "{} memoization",
                p.name()
            );
        }
    }

    #[test]
    fn quantized_engine_uses_quantized_attributes() {
        let mut e = SosEngine::new(1, 4, 0.5, Precision::Int8);
        e.tick(Some(&job(1, 3.7, vec![42.3])));
        let s = e.schedule(0).head().unwrap();
        assert_eq!(s.weight, 4.0);
        assert_eq!(s.ept, 42.0);
        assert_eq!(s.alpha_pt, 21);
    }
}
