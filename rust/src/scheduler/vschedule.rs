//! Virtual Schedule (Definition 3): the per-machine interim ordering of
//! assigned-but-not-yet-released jobs, kept sorted by WSPT priority.
//!
//! Since the memoized-sum optimization (Section 3.3 opt. 3, the same
//! trick the Stannic PE array implements in hardware) the schedule also
//! carries incrementally-maintained threshold sums, so a cost query
//! ([`crate::scheduler::cost_of`]) is a position scan plus two O(1)
//! lookups instead of a full O(depth) re-accumulation of
//! `rem_hi`/`rem_lo` per machine per arrival:
//!
//! * `memo_hi[i] - hi_bias` = prefix `Σ_{j<=i} rem_hi(j)` — the value
//!   `sum^H` takes when slot `i` is the last member of `sigma^H`;
//! * `memo_lo[i]` = suffix `Σ_{j>=i} rem_lo(j)` — the value `sum^L`
//!   takes when slot `i` is the first member of `sigma^L`.
//!
//! # Lazy virtual work (the tickless representation)
//!
//! The discretized algorithm accrues one cycle of virtual work on every
//! head per tick (Phase III). Mutating every machine every tick is
//! exactly the O(machines)-per-tick scan the paper's hardware avoids, so
//! the schedule stores virtual work *implicitly*: [`Self::synced_at`] is
//! the virtual tick through which the head's stored `n` is materialized,
//! and [`Self::sync_to`] fast-forwards the gap in O(1) —
//! `n += k`, `hi_bias += k`, `memo_lo[head] -= k * wspt` for a gap of
//! `k` ticks (the per-tick [`Self::accrue`] is the `k = 1` case, kept
//! for the per-tick baselines and tests). Equivalently the head's
//! virtual work is `n = now - head_since`; the engine only pays to
//! materialize it when the schedule is actually observed (a pop check or
//! a cost query), which is what makes event-horizon jumps over idle
//! drain tails free.
//!
//! **Why fast-forward is exact, per datapath:** `n` is a `u32`, so
//! `n += k` is bit-identical to `k` unit increments for *every*
//! precision; non-memoized (floating-datapath) schedules recompute
//! `rem_hi`/`rem_lo` from `n` on read and are therefore unaffected by
//! how `n` advanced. For the memoized fixed-point datapaths
//! (INT8/INT4/Mixed), every quantity is a multiple of the WSPT fixed
//! step (2^-4 for UQ4.4, 2^-2 for UQ2.2) and bounded far below f32's
//! exact-integer range, so `hi_bias += k` and `memo_lo -= k * wspt` are
//! exact and bit-equal to `k` repeated unit updates. The golden test,
//! the cross-engine parity suites and `tests/tickless.rs` pin this.
//!
//! `hi_bias` turns the accrue (which decrements *every* prefix by the
//! head's progress, because every prefix contains the head) into a
//! single scalar add, keeping accrue O(1) like the pre-memoization code.
//!
//! Exactness of the *memoized reads* is a datapath property: it holds
//! for the fixed-point WSPT schemes (INT8/INT4/Mixed — integer W/eps,
//! UQ-format T, all sums well inside f32's exact range) but not for
//! FP32/FP16, where `T = W/eps` carries enough significand that
//! incremental updates can round differently than a fresh rescan. The
//! engine therefore enables memoization per precision
//! ([`VirtualSchedule::with_memoization`]): floating datapaths keep the
//! original rescan in `threshold_read`, so their schedules stay
//! bit-identical to the pre-memoization code (and to the SOSC/SIMD
//! baselines) by construction.
//!
//! # O(1) pops
//!
//! Slots live in a front-offset buffer: [`Self::pop_head`] advances
//! `start` instead of shifting `slots`/`memo_hi`/`memo_lo` left, so a
//! pop is O(1) (the bias representation absorbs the PE array's `Δα`
//! broadcast as one scalar add). [`Self::slots`] stays a contiguous
//! `&[Slot]` view; the dead prefix is reclaimed on the next insert
//! (which is O(depth) anyway for the positional shift), so the buffer
//! never grows past `depth` dead plus `depth` live entries.

use crate::core::JobId;

/// One tracked job inside a virtual schedule — the attribute set the
/// hardware retains per job (Section 4.1): weight, EPT on *this* machine,
/// the stored WSPT ratio (division done once, Section 3.3 opt. 1), the
/// alpha release point, and the virtual-work cycle count `n_K`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slot {
    pub id: JobId,
    pub weight: f32,
    pub ept: f32,
    pub wspt: f32,
    pub alpha_pt: u32,
    pub n: u32,
}

impl Slot {
    /// Remaining contribution to `sum^H` (Eq. 4): `eps - n`.
    #[inline]
    pub fn rem_hi(&self) -> f32 {
        self.ept - self.n as f32
    }

    /// Remaining contribution to `sum^L` (Eq. 5): `W - n * T`.
    #[inline]
    pub fn rem_lo(&self) -> f32 {
        self.weight - self.n as f32 * self.wspt
    }

    /// Has the job reached its alpha release point?
    #[inline]
    pub fn ready(&self) -> bool {
        self.n >= self.alpha_pt
    }
}

/// A WSPT-ordered virtual schedule of bounded depth (the paper's `V_i`
/// with capacity `N`). Ordering invariant: non-increasing `wspt` from
/// head to tail — Definition 4's "properly ordered" property, minus the
/// systolic bubbles (the live view has none by construction).
#[derive(Debug, Clone)]
pub struct VirtualSchedule {
    /// Backing buffer; the live schedule is `slots[start..]`.
    slots: Vec<Slot>,
    depth: usize,
    /// Memoized prefix sums over the live range:
    /// `memo_hi[i] - hi_bias == Σ_{start <= j <= i} rem_hi(j)`.
    memo_hi: Vec<f32>,
    /// Memoized suffix sums over the live range:
    /// `memo_lo[i] == Σ_{j >= i} rem_lo(j)`.
    memo_lo: Vec<f32>,
    /// Shared subtrahend for `memo_hi` (see module docs).
    hi_bias: f32,
    /// Whether memoized threshold reads are enabled (exact datapaths
    /// only); when false, `threshold_read` falls back to the rescans.
    memoized: bool,
    /// Ring offset of the head inside `slots`/`memo_hi`/`memo_lo`.
    start: usize,
    /// Virtual tick through which the head's virtual work is
    /// materialized (lazy-`n`; see module docs). Only meaningful for
    /// owners that drive the schedule through [`Self::sync_to`].
    synced_at: u64,
}

/// Rebase `hi_bias` back to 0 before it grows past the f32 exact-integer
/// range (2^24), where adding small increments would stop changing the
/// value. The bias grows with accrued head cycles and absorbed pop
/// deltas, so this only triggers on schedules continuously occupied for
/// ~8M ticks.
const HI_BIAS_REBASE: f32 = 8_388_608.0; // 2^23

impl VirtualSchedule {
    /// Plain constructor: memoization OFF. Exactness of the memoized
    /// reads is a datapath property the *caller* must vouch for, so the
    /// default is the always-exact rescan; [`SosEngine::new`] opts into
    /// memoization for the fixed-point precisions.
    ///
    /// [`SosEngine::new`]: crate::scheduler::SosEngine::new
    pub fn new(depth: usize) -> Self {
        Self::with_memoization(depth, false)
    }

    /// Construct with memoized threshold sums enabled or disabled.
    /// Enable only for datapaths whose attribute arithmetic is exact in
    /// f32 (integer W/eps, fixed-point T — INT8/INT4/Mixed); floating
    /// datapaths must stay on the rescan, where incremental updates are
    /// not bit-equal.
    pub fn with_memoization(depth: usize, memoized: bool) -> Self {
        assert!(depth >= 1);
        VirtualSchedule {
            slots: Vec::with_capacity(depth),
            depth,
            memo_hi: Vec::with_capacity(depth),
            memo_lo: Vec::with_capacity(depth),
            hi_bias: 0.0,
            memoized,
            start: 0,
            synced_at: 0,
        }
    }

    /// True when cost queries use the memoized sums (exact datapaths).
    pub fn is_memoized(&self) -> bool {
        self.memoized
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.slots.len() - self.start
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.start == self.slots.len()
    }

    #[inline]
    pub fn is_full(&self) -> bool {
        self.len() == self.depth
    }

    #[inline]
    pub fn depth(&self) -> usize {
        self.depth
    }

    #[inline]
    pub fn head(&self) -> Option<&Slot> {
        self.slots.get(self.start)
    }

    /// Contiguous view of the live schedule, head first.
    pub fn slots(&self) -> &[Slot] {
        &self.slots[self.start..]
    }

    /// Virtual tick through which the head's stored `n` is materialized.
    #[inline]
    pub fn synced_at(&self) -> u64 {
        self.synced_at
    }

    /// Insertion index for a job with WSPT `t`: after every job with
    /// `wspt >= t` (Eq. 2 places ties in the sigma^H set, so an equal-
    /// priority incumbent stays ahead of the newcomer). The ordering
    /// invariant (non-increasing `wspt`) makes `wspt >= t` a prefix
    /// property, so this is an O(log depth) binary search.
    pub fn position_for(&self, t: f32) -> usize {
        self.slots[self.start..].partition_point(|s| s.wspt >= t)
    }

    /// Reclaim the dead prefix left behind by O(1) pops so positional
    /// insertion can index from 0 again.
    fn compact(&mut self) {
        if self.start == 0 {
            return;
        }
        self.slots.drain(..self.start);
        if self.memoized {
            self.memo_hi.drain(..self.start);
            self.memo_lo.drain(..self.start);
        }
        self.start = 0;
    }

    /// Insert a job at its WSPT position. Panics if full (the scheduler
    /// must never select a full machine — Section 6.2.2 "full V_i s can
    /// not be assigned new jobs"). Returns the insertion index.
    ///
    /// Memo maintenance mirrors the PE array's Insert iteration (Table
    /// 2): slots behind the newcomer gain `rem_hi(new)` in their prefix,
    /// slots ahead gain `rem_lo(new)` in their suffix, and the newcomer's
    /// own sums extend its neighbours'.
    pub fn insert(&mut self, slot: Slot) -> usize {
        assert!(!self.is_full(), "insert into full virtual schedule");
        self.compact();
        let pos = self.position_for(slot.wspt);
        if self.memoized {
            let rem_hi = slot.rem_hi();
            let rem_lo = slot.rem_lo();
            for m in &mut self.memo_hi[pos..] {
                *m += rem_hi;
            }
            for m in &mut self.memo_lo[..pos] {
                *m += rem_lo;
            }
            let prev_hi = if pos > 0 { self.memo_hi[pos - 1] } else { self.hi_bias };
            let new_hi = prev_hi + rem_hi;
            let new_lo = rem_lo + self.memo_lo.get(pos).copied().unwrap_or(0.0);
            self.memo_hi.insert(pos, new_hi);
            self.memo_lo.insert(pos, new_lo);
        }
        self.slots.insert(pos, slot);
        pos
    }

    /// Remove and return the head job (a POP iteration's release) — O(1).
    ///
    /// The departing head leaves every remaining prefix, so every true
    /// prefix drops by `rem_hi(head)` — the PE array's `Δα` broadcast —
    /// which the bias representation absorbs as one scalar add. Suffixes
    /// never contained a slot to their left and are untouched; the head
    /// entry itself is retired by advancing the ring offset.
    pub fn pop_head(&mut self) -> Option<Slot> {
        if self.is_empty() {
            return None;
        }
        if self.memoized {
            let delta_alpha = self.memo_hi[self.start] - self.hi_bias;
            self.hi_bias += delta_alpha;
        }
        let slot = self.slots[self.start];
        self.start += 1;
        if self.is_empty() {
            // reset the ring and the bias whenever the schedule drains
            // so neither can creep
            self.slots.clear();
            self.memo_hi.clear();
            self.memo_lo.clear();
            self.start = 0;
            self.hi_bias = 0.0;
        }
        Some(slot)
    }

    /// Apply `k` cycles of virtual work to the head in O(1): the head's
    /// `rem_hi` drops by `k` (bias add covers every prefix) and its
    /// `rem_lo` by `k` times its stored WSPT (only the head suffix
    /// contains the head). Bit-equal to `k` single-cycle accrues on
    /// every datapath (see module docs).
    fn advance_head(&mut self, k: u64) {
        let Some(h) = self.slots.get_mut(self.start) else {
            return;
        };
        debug_assert!(k <= u32::MAX as u64, "virtual-work jump overflows n");
        h.n += k as u32;
        if self.memoized {
            let kf = k as f32;
            self.hi_bias += kf;
            self.memo_lo[self.start] -= kf * h.wspt;
            if self.hi_bias >= HI_BIAS_REBASE {
                for m in &mut self.memo_hi[self.start..] {
                    *m -= self.hi_bias;
                }
                self.hi_bias = 0.0;
            }
        }
    }

    /// One cycle of virtual work on the head (Phase III discrete form) —
    /// the per-tick spelling of [`Self::sync_to`], used by per-tick
    /// drivers and tests that do not track virtual time.
    pub fn accrue(&mut self) {
        self.advance_head(1);
    }

    /// Materialize the head's virtual work through virtual tick `now`
    /// (lazy-`n` fast-forward). Owners that use this must route *all*
    /// accrual through it (never mix with [`Self::accrue`]); `now` must
    /// be monotone.
    pub fn sync_to(&mut self, now: u64) {
        debug_assert!(now >= self.synced_at, "virtual time cannot rewind");
        let k = now - self.synced_at;
        self.synced_at = now;
        if k > 0 {
            self.advance_head(k);
        }
    }

    /// Advance `synced_at` to `now` *without* accruing virtual work —
    /// the gap's cycles never happened, as opposed to [`Self::sync_to`]
    /// where they are materialized onto the head. Used by the fault
    /// layer when a machine comes back up: the head resumes with exactly
    /// its pre-down progress, and `head_release_tick` (being
    /// `synced_at`-relative) shifts out by the downtime automatically.
    pub fn skip_to(&mut self, now: u64) {
        debug_assert!(now >= self.synced_at, "virtual time cannot rewind");
        self.synced_at = now;
    }

    /// Evict every queued-but-unstarted slot behind the head, returning
    /// them in schedule (priority) order. The head stays in place with
    /// its accrued virtual work; used on a machine-down event under
    /// `policy=resume`. Memoized sums: the head's prefix (`memo_hi`) is
    /// untouched by removing slots behind it, and its suffix collapses
    /// to its own `rem_lo`.
    pub fn evict_tail(&mut self) -> Vec<Slot> {
        if self.len() <= 1 {
            return Vec::new();
        }
        let evicted: Vec<Slot> = self.slots.drain(self.start + 1..).collect();
        if self.memoized {
            self.memo_hi.truncate(self.start + 1);
            self.memo_lo.truncate(self.start + 1);
            self.memo_lo[self.start] = self.slots[self.start].rem_lo();
        }
        evicted
    }

    /// Evict *every* slot, head included, returning them in schedule
    /// order; the ring, bias and memo state fully reset (as after a
    /// natural drain) while `synced_at` is preserved. Used on a
    /// machine-down event under `policy=lose`.
    pub fn evict_all(&mut self) -> Vec<Slot> {
        let evicted: Vec<Slot> = self.slots.drain(self.start..).collect();
        self.slots.clear();
        self.memo_hi.clear();
        self.memo_lo.clear();
        self.start = 0;
        self.hi_bias = 0.0;
        evicted
    }

    /// The virtual tick at whose start the current head is (or becomes)
    /// alpha-ready, i.e. the tick a per-tick driver would pop it on.
    /// Sync-invariant: `synced_at + 1 + (alpha_pt - n)` gives the same
    /// tick at any materialization level, so the engine's event horizon
    /// can read it without paying a sync.
    pub fn head_release_tick(&self) -> Option<u64> {
        let h = self.head()?;
        Some(self.synced_at + 1 + u64::from(h.alpha_pt.saturating_sub(h.n)))
    }

    /// Threshold read for a probe priority `t`: the insertion position
    /// `|sigma^H|` (O(log depth) binary search) plus `sum^H` / `sum^L`
    /// of Eq. (4)/(5) in two O(1) lookups — the software form of the PE
    /// array's volunteered threshold values (Section 6.2.1). On
    /// non-memoized (floating-datapath) schedules this is the original
    /// O(depth) rescan, bit-identical to the pre-memoization engine.
    pub fn threshold_read(&self, t: f32) -> (f32, f32, usize) {
        if !self.memoized {
            // the pre-memoization fused single pass, kept verbatim so
            // floating-datapath schedules stay bit-identical (and pay
            // one traversal, not three)
            let mut sum_hi = 0.0f32;
            let mut sum_lo = 0.0f32;
            let mut pos = 0usize;
            for s in &self.slots[self.start..] {
                if s.wspt >= t {
                    sum_hi += s.rem_hi();
                    pos += 1;
                } else {
                    sum_lo += s.rem_lo();
                }
            }
            return (sum_hi, sum_lo, pos);
        }
        let pos = self.position_for(t);
        let sum_hi = if pos > 0 {
            self.memo_hi[self.start + pos - 1] - self.hi_bias
        } else {
            0.0
        };
        let sum_lo = self.memo_lo.get(self.start + pos).copied().unwrap_or(0.0);
        (sum_hi, sum_lo, pos)
    }

    /// `sum^H` of Eq. (4): remaining-EPT mass of jobs with priority >= t.
    /// Reference rescan — the memoized [`Self::threshold_read`] must
    /// agree with it (exactly, under quantized datapaths).
    pub fn sum_hi(&self, t: f32) -> f32 {
        self.slots[self.start..]
            .iter()
            .filter(|s| s.wspt >= t)
            .map(|s| s.rem_hi())
            .sum()
    }

    /// `sum^L` of Eq. (5): remaining-weight mass of jobs with priority < t.
    /// Reference rescan counterpart of [`Self::threshold_read`].
    pub fn sum_lo(&self, t: f32) -> f32 {
        self.slots[self.start..]
            .iter()
            .filter(|s| s.wspt < t)
            .map(|s| s.rem_lo())
            .sum()
    }

    /// Live-range views of the memoized threshold sums and their shared
    /// bias — the refresh source for the wavefront SoA mirror
    /// ([`crate::scheduler::Wavefront`]), which copies these columns
    /// verbatim on every structural mutation so its reads stay
    /// bit-identical to [`Self::threshold_read`]. Empty slices (and a
    /// zero bias) when memoization is off.
    pub fn memo_view(&self) -> (&[f32], &[f32], f32) {
        if !self.memoized {
            return (&[], &[], 0.0);
        }
        (
            &self.memo_hi[self.start..],
            &self.memo_lo[self.start..],
            self.hi_bias,
        )
    }

    /// Check the ordering invariant (used by tests and debug assertions).
    pub fn is_properly_ordered(&self) -> bool {
        self.slots[self.start..]
            .windows(2)
            .all(|w| w[0].wspt >= w[1].wspt)
    }

    /// True when no non-head job carries virtual work. NOTE: this is not
    /// a global invariant — a job displaced from the head by a higher-
    /// priority newcomer retains its accrued `n_K` (the paper tracks
    /// `n_K(t)` per job); it merely stops accruing until it regains the
    /// head. The property holds only while no displacement has occurred.
    pub fn vw_only_at_head(&self) -> bool {
        self.slots[self.start..].iter().skip(1).all(|s| s.n == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slot(id: JobId, w: f32, e: f32) -> Slot {
        Slot {
            id,
            weight: w,
            ept: e,
            wspt: w / e,
            alpha_pt: (0.5 * e).ceil() as u32,
            n: 0,
        }
    }

    #[test]
    fn insert_keeps_wspt_descending() {
        let mut v = VirtualSchedule::new(8);
        v.insert(slot(1, 10.0, 20.0)); // T=0.5
        v.insert(slot(2, 30.0, 20.0)); // T=1.5
        v.insert(slot(3, 20.0, 20.0)); // T=1.0
        assert!(v.is_properly_ordered());
        let ids: Vec<_> = v.slots().iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![2, 3, 1]);
    }

    #[test]
    fn equal_wspt_inserts_after_incumbent() {
        let mut v = VirtualSchedule::new(4);
        v.insert(slot(1, 10.0, 20.0)); // T=0.5
        let pos = v.insert(slot(2, 5.0, 10.0)); // T=0.5 too
        assert_eq!(pos, 1, "tie goes behind the incumbent (sigma^H is >=)");
    }

    #[test]
    fn sums_split_on_threshold() {
        let mut v = VirtualSchedule::new(8);
        v.insert(slot(1, 40.0, 20.0)); // T=2.0, rem_hi=20, rem_lo=40
        v.insert(slot(2, 20.0, 20.0)); // T=1.0, rem_hi=20, rem_lo=20
        v.insert(slot(3, 10.0, 20.0)); // T=0.5, rem_hi=20, rem_lo=10
        // probe T_j = 1.0: sigma^H = {T>=1} = jobs 1,2; sigma^L = {T<1} = job 3
        assert_eq!(v.sum_hi(1.0), 40.0);
        assert_eq!(v.sum_lo(1.0), 10.0);
        // probe above everything
        assert_eq!(v.sum_hi(9.0), 0.0);
        assert_eq!(v.sum_lo(9.0), 70.0);
    }

    #[test]
    fn accrue_touches_only_head() {
        let mut v = VirtualSchedule::new(4);
        v.insert(slot(1, 20.0, 10.0));
        v.insert(slot(2, 10.0, 10.0));
        v.accrue();
        v.accrue();
        assert_eq!(v.slots()[0].n, 2);
        assert_eq!(v.slots()[1].n, 0);
        assert!(v.vw_only_at_head());
    }

    #[test]
    fn rem_terms_shrink_with_vw() {
        let mut s = slot(1, 20.0, 10.0); // T=2
        assert_eq!(s.rem_hi(), 10.0);
        assert_eq!(s.rem_lo(), 20.0);
        s.n = 3;
        assert_eq!(s.rem_hi(), 7.0);
        assert_eq!(s.rem_lo(), 14.0);
    }

    #[test]
    fn ready_at_alpha_point() {
        let mut s = slot(1, 10.0, 21.0); // alpha_pt = ceil(10.5) = 11
        assert_eq!(s.alpha_pt, 11);
        s.n = 10;
        assert!(!s.ready());
        s.n = 11;
        assert!(s.ready());
    }

    #[test]
    #[should_panic]
    fn insert_into_full_panics() {
        let mut v = VirtualSchedule::new(1);
        v.insert(slot(1, 10.0, 10.0));
        v.insert(slot(2, 10.0, 10.0));
    }

    #[test]
    fn plain_constructor_defaults_to_rescan() {
        assert!(!VirtualSchedule::new(4).is_memoized());
        assert!(VirtualSchedule::with_memoization(4, true).is_memoized());
    }

    #[test]
    fn threshold_read_matches_rescan_oracle() {
        let mut v = VirtualSchedule::with_memoization(8, true);
        v.insert(slot(1, 40.0, 20.0)); // T=2.0
        v.insert(slot(2, 20.0, 20.0)); // T=1.0
        v.insert(slot(3, 10.0, 20.0)); // T=0.5
        for _ in 0..3 {
            v.accrue();
        }
        for t in [0.1, 0.5, 1.0, 2.0, 9.0] {
            let (hi, lo, pos) = v.threshold_read(t);
            assert_eq!(hi, v.sum_hi(t), "probe {t}");
            assert_eq!(lo, v.sum_lo(t), "probe {t}");
            assert_eq!(pos, v.position_for(t), "probe {t}");
        }
        // pop the head, probe again — Δα propagation
        assert_eq!(v.pop_head().unwrap().id, 1);
        for t in [0.1, 0.5, 1.0, 9.0] {
            let (hi, lo, pos) = v.threshold_read(t);
            assert_eq!(hi, v.sum_hi(t), "post-pop probe {t}");
            assert_eq!(lo, v.sum_lo(t), "post-pop probe {t}");
            assert_eq!(pos, v.position_for(t), "post-pop probe {t}");
        }
    }

    #[test]
    fn memoized_sums_exact_under_random_quantized_drive() {
        // Random insert/accrue/pop with the INT8 datapath (integer W and
        // eps, UQ4.4 T): the memoized reads must be bit-identical to the
        // rescans — the property the golden engine's cost path relies on.
        use crate::workload::Rng;
        let mut rng = Rng::new(4242);
        let depth = 10;
        let mut v = VirtualSchedule::with_memoization(depth, true);
        let mut id = 1u64;
        for step in 0..4000 {
            if v.head().is_some_and(|h| h.ready()) {
                v.pop_head();
            }
            if !v.is_full() && rng.chance(0.4) {
                let w = rng.uniform(1.0, 255.0).round();
                let e = rng.uniform(10.0, 255.0).round();
                let t = crate::core::fixed_round(w / e, 4, 4);
                v.insert(Slot {
                    id,
                    weight: w,
                    ept: e,
                    wspt: t,
                    alpha_pt: (0.5 * e).ceil() as u32,
                    n: 0,
                });
                id += 1;
            }
            let probe = crate::core::fixed_round(
                rng.uniform(1.0, 255.0).round() / rng.uniform(10.0, 255.0).round(),
                4,
                4,
            );
            let (hi, lo, pos) = v.threshold_read(probe);
            assert_eq!(hi, v.sum_hi(probe), "step {step}: memoized sum_hi drifted");
            assert_eq!(lo, v.sum_lo(probe), "step {step}: memoized sum_lo drifted");
            assert_eq!(pos, v.position_for(probe));
            v.accrue();
        }
    }

    #[test]
    fn pop_head_fifo_of_priority() {
        let mut v = VirtualSchedule::new(4);
        v.insert(slot(1, 10.0, 20.0));
        v.insert(slot(2, 30.0, 20.0));
        assert_eq!(v.pop_head().unwrap().id, 2);
        assert_eq!(v.pop_head().unwrap().id, 1);
        assert!(v.pop_head().is_none());
    }

    #[test]
    fn ring_offset_keeps_views_contiguous_across_interleaved_ops() {
        // Pops advance the offset instead of shifting; inserts compact
        // and re-index. The observable views (slots(), sums, positions)
        // must behave as if the buffer were always front-aligned.
        for memoized in [false, true] {
            let mut v = VirtualSchedule::with_memoization(6, memoized);
            v.insert(slot(1, 60.0, 20.0)); // T=3.0
            v.insert(slot(2, 40.0, 20.0)); // T=2.0
            v.insert(slot(3, 20.0, 20.0)); // T=1.0
            assert_eq!(v.pop_head().unwrap().id, 1);
            assert_eq!(v.len(), 2);
            assert_eq!(v.slots().iter().map(|s| s.id).collect::<Vec<_>>(), [2, 3]);
            // insert after a pop: compaction must land the newcomer at
            // its WSPT position within the live range
            let pos = v.insert(slot(4, 30.0, 20.0)); // T=1.5 -> between 2 and 3
            assert_eq!(pos, 1);
            assert_eq!(
                v.slots().iter().map(|s| s.id).collect::<Vec<_>>(),
                [2, 4, 3]
            );
            assert!(v.is_properly_ordered());
            for probe in [0.5f32, 1.0, 1.5, 2.0, 9.0] {
                let (hi, lo, pos) = v.threshold_read(probe);
                assert_eq!(hi, v.sum_hi(probe), "memoized={memoized} probe {probe}");
                assert_eq!(lo, v.sum_lo(probe), "memoized={memoized} probe {probe}");
                assert_eq!(pos, v.position_for(probe));
            }
            // drain completely; the ring must reset
            assert_eq!(v.pop_head().unwrap().id, 2);
            assert_eq!(v.pop_head().unwrap().id, 4);
            assert_eq!(v.pop_head().unwrap().id, 3);
            assert!(v.is_empty());
            assert!(v.pop_head().is_none());
            // and be reusable afterwards
            v.insert(slot(5, 10.0, 20.0));
            assert_eq!(v.head().unwrap().id, 5);
        }
    }

    #[test]
    fn sync_to_fast_forward_matches_per_tick_accrue() {
        // The lazy representation must be bit-identical to ticking: for
        // every datapath-relevant shape, advancing k ticks in one jump
        // produces the same slots and the same threshold reads as k
        // single accrues.
        for memoized in [false, true] {
            let build = |mem: bool| {
                let mut v = VirtualSchedule::with_memoization(8, mem);
                v.insert(slot(1, 40.0, 20.0)); // T=2.0
                v.insert(slot(2, 20.0, 20.0)); // T=1.0
                v.insert(slot(3, 10.0, 20.0)); // T=0.5
                v
            };
            let mut ticked = build(memoized);
            let mut jumped = build(memoized);
            for now in 1..=7u64 {
                ticked.sync_to(now); // k = 1 each call
            }
            jumped.sync_to(7); // one k = 7 jump
            assert_eq!(ticked.slots(), jumped.slots(), "memoized={memoized}");
            assert_eq!(ticked.synced_at(), jumped.synced_at());
            for probe in [0.1f32, 0.5, 1.0, 2.0, 9.0] {
                assert_eq!(
                    ticked.threshold_read(probe),
                    jumped.threshold_read(probe),
                    "memoized={memoized} probe {probe}"
                );
            }
        }
    }

    #[test]
    fn evict_tail_keeps_the_head_and_its_sums() {
        for memoized in [false, true] {
            let mut v = VirtualSchedule::with_memoization(6, memoized);
            v.insert(slot(1, 60.0, 20.0)); // T=3.0 (head)
            v.insert(slot(2, 40.0, 20.0)); // T=2.0
            v.insert(slot(3, 20.0, 20.0)); // T=1.0
            v.sync_to(4); // head accrues 4 cycles
            let evicted = v.evict_tail();
            assert_eq!(
                evicted.iter().map(|s| s.id).collect::<Vec<_>>(),
                [2, 3],
                "tail evicted in schedule order"
            );
            assert_eq!(v.len(), 1);
            assert_eq!(v.head().unwrap().id, 1);
            assert_eq!(v.head().unwrap().n, 4, "head keeps accrued work");
            for probe in [0.5f32, 1.0, 2.0, 3.0, 9.0] {
                let (hi, lo, pos) = v.threshold_read(probe);
                assert_eq!(hi, v.sum_hi(probe), "memoized={memoized} probe {probe}");
                assert_eq!(lo, v.sum_lo(probe), "memoized={memoized} probe {probe}");
                assert_eq!(pos, v.position_for(probe));
            }
            // schedule stays usable: insert + pop behave normally
            assert_eq!(v.insert(slot(4, 40.0, 20.0)), 1);
            assert!(v.is_properly_ordered());
        }
    }

    #[test]
    fn evict_tail_of_singleton_or_empty_is_a_noop() {
        let mut v = VirtualSchedule::with_memoization(4, true);
        assert!(v.evict_tail().is_empty());
        v.insert(slot(1, 10.0, 20.0));
        assert!(v.evict_tail().is_empty());
        assert_eq!(v.head().unwrap().id, 1);
    }

    #[test]
    fn evict_all_resets_like_a_drain() {
        for memoized in [false, true] {
            let mut v = VirtualSchedule::with_memoization(4, memoized);
            v.insert(slot(1, 60.0, 20.0));
            v.insert(slot(2, 40.0, 20.0));
            v.sync_to(3);
            let evicted = v.evict_all();
            assert_eq!(evicted.iter().map(|s| s.id).collect::<Vec<_>>(), [1, 2]);
            assert_eq!(evicted[0].n, 3, "evicted head carries its lost work");
            assert!(v.is_empty());
            assert_eq!(v.synced_at(), 3, "virtual time is preserved");
            assert_eq!(v.head_release_tick(), None);
            // reusable afterwards, memo state consistent
            v.insert(slot(5, 20.0, 20.0));
            let (hi, lo, _) = v.threshold_read(1.0);
            assert_eq!(hi, v.sum_hi(1.0));
            assert_eq!(lo, v.sum_lo(1.0));
        }
    }

    #[test]
    fn skip_to_advances_time_without_accrual() {
        let mut v = VirtualSchedule::new(4);
        v.insert(slot(1, 10.0, 20.0)); // alpha_pt = 10, crowned at synced_at=0
        v.sync_to(4);
        assert_eq!(v.head().unwrap().n, 4);
        assert_eq!(v.head_release_tick(), Some(11));
        v.skip_to(30); // 26 ticks of downtime: no virtual work
        assert_eq!(v.head().unwrap().n, 4, "no accrual across the skip");
        // 6 cycles remain, so the head pops at 30 + 1 + 6
        assert_eq!(v.head_release_tick(), Some(37));
        v.sync_to(36);
        assert!(v.head().unwrap().ready());
    }

    #[test]
    fn head_release_tick_is_sync_invariant() {
        let mut v = VirtualSchedule::new(4);
        assert_eq!(v.head_release_tick(), None);
        v.insert(slot(1, 10.0, 20.0)); // alpha_pt = 10
        // crowned with synced_at = 0: ready after 10 accruals (ticks
        // 1..=10), so a per-tick driver pops it at tick 11
        assert_eq!(v.head_release_tick(), Some(11));
        v.sync_to(4);
        assert_eq!(v.head_release_tick(), Some(11), "invariant under sync");
        v.sync_to(10);
        assert!(v.head().unwrap().ready());
        assert_eq!(v.head_release_tick(), Some(11), "ready head pops next tick");
    }
}
