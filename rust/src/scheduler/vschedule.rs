//! Virtual Schedule (Definition 3): the per-machine interim ordering of
//! assigned-but-not-yet-released jobs, kept sorted by WSPT priority.

use crate::core::JobId;

/// One tracked job inside a virtual schedule — the attribute set the
/// hardware retains per job (Section 4.1): weight, EPT on *this* machine,
/// the stored WSPT ratio (division done once, Section 3.3 opt. 1), the
/// alpha release point, and the virtual-work cycle count `n_K`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slot {
    pub id: JobId,
    pub weight: f32,
    pub ept: f32,
    pub wspt: f32,
    pub alpha_pt: u32,
    pub n: u32,
}

impl Slot {
    /// Remaining contribution to `sum^H` (Eq. 4): `eps - n`.
    #[inline]
    pub fn rem_hi(&self) -> f32 {
        self.ept - self.n as f32
    }

    /// Remaining contribution to `sum^L` (Eq. 5): `W - n * T`.
    #[inline]
    pub fn rem_lo(&self) -> f32 {
        self.weight - self.n as f32 * self.wspt
    }

    /// Has the job reached its alpha release point?
    #[inline]
    pub fn ready(&self) -> bool {
        self.n >= self.alpha_pt
    }
}

/// A WSPT-ordered virtual schedule of bounded depth (the paper's `V_i`
/// with capacity `N`). Ordering invariant: non-increasing `wspt` from
/// head (index 0) to tail — Definition 4's "properly ordered" property,
/// minus the systolic bubbles (a `Vec` has none by construction).
#[derive(Debug, Clone, PartialEq)]
pub struct VirtualSchedule {
    slots: Vec<Slot>,
    depth: usize,
}

impl VirtualSchedule {
    pub fn new(depth: usize) -> Self {
        assert!(depth >= 1);
        VirtualSchedule {
            slots: Vec::with_capacity(depth),
            depth,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    #[inline]
    pub fn is_full(&self) -> bool {
        self.slots.len() == self.depth
    }

    #[inline]
    pub fn depth(&self) -> usize {
        self.depth
    }

    #[inline]
    pub fn head(&self) -> Option<&Slot> {
        self.slots.first()
    }

    #[inline]
    pub fn head_mut(&mut self) -> Option<&mut Slot> {
        self.slots.first_mut()
    }

    pub fn slots(&self) -> &[Slot] {
        &self.slots
    }

    /// Insertion index for a job with WSPT `t`: after every job with
    /// `wspt >= t` (Eq. 2 places ties in the sigma^H set, so an equal-
    /// priority incumbent stays ahead of the newcomer).
    pub fn position_for(&self, t: f32) -> usize {
        self.slots.iter().take_while(|s| s.wspt >= t).count()
    }

    /// Insert a job at its WSPT position. Panics if full (the scheduler
    /// must never select a full machine — Section 6.2.2 "full V_i s can
    /// not be assigned new jobs").
    pub fn insert(&mut self, slot: Slot) -> usize {
        assert!(!self.is_full(), "insert into full virtual schedule");
        let pos = self.position_for(slot.wspt);
        self.slots.insert(pos, slot);
        pos
    }

    /// Remove and return the head job (a POP iteration's release).
    pub fn pop_head(&mut self) -> Option<Slot> {
        if self.slots.is_empty() {
            None
        } else {
            Some(self.slots.remove(0))
        }
    }

    /// One cycle of virtual work on the head (Phase III discrete form).
    pub fn accrue(&mut self) {
        if let Some(h) = self.slots.first_mut() {
            h.n += 1;
        }
    }

    /// `sum^H` of Eq. (4): remaining-EPT mass of jobs with priority >= t.
    pub fn sum_hi(&self, t: f32) -> f32 {
        self.slots
            .iter()
            .filter(|s| s.wspt >= t)
            .map(|s| s.rem_hi())
            .sum()
    }

    /// `sum^L` of Eq. (5): remaining-weight mass of jobs with priority < t.
    pub fn sum_lo(&self, t: f32) -> f32 {
        self.slots
            .iter()
            .filter(|s| s.wspt < t)
            .map(|s| s.rem_lo())
            .sum()
    }

    /// Check the ordering invariant (used by tests and debug assertions).
    pub fn is_properly_ordered(&self) -> bool {
        self.slots.windows(2).all(|w| w[0].wspt >= w[1].wspt)
    }

    /// True when no non-head job carries virtual work. NOTE: this is not
    /// a global invariant — a job displaced from the head by a higher-
    /// priority newcomer retains its accrued `n_K` (the paper tracks
    /// `n_K(t)` per job); it merely stops accruing until it regains the
    /// head. The property holds only while no displacement has occurred.
    pub fn vw_only_at_head(&self) -> bool {
        self.slots.iter().skip(1).all(|s| s.n == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slot(id: JobId, w: f32, e: f32) -> Slot {
        Slot {
            id,
            weight: w,
            ept: e,
            wspt: w / e,
            alpha_pt: (0.5 * e).ceil() as u32,
            n: 0,
        }
    }

    #[test]
    fn insert_keeps_wspt_descending() {
        let mut v = VirtualSchedule::new(8);
        v.insert(slot(1, 10.0, 20.0)); // T=0.5
        v.insert(slot(2, 30.0, 20.0)); // T=1.5
        v.insert(slot(3, 20.0, 20.0)); // T=1.0
        assert!(v.is_properly_ordered());
        let ids: Vec<_> = v.slots().iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![2, 3, 1]);
    }

    #[test]
    fn equal_wspt_inserts_after_incumbent() {
        let mut v = VirtualSchedule::new(4);
        v.insert(slot(1, 10.0, 20.0)); // T=0.5
        let pos = v.insert(slot(2, 5.0, 10.0)); // T=0.5 too
        assert_eq!(pos, 1, "tie goes behind the incumbent (sigma^H is >=)");
    }

    #[test]
    fn sums_split_on_threshold() {
        let mut v = VirtualSchedule::new(8);
        v.insert(slot(1, 40.0, 20.0)); // T=2.0, rem_hi=20, rem_lo=40
        v.insert(slot(2, 20.0, 20.0)); // T=1.0, rem_hi=20, rem_lo=20
        v.insert(slot(3, 10.0, 20.0)); // T=0.5, rem_hi=20, rem_lo=10
        // probe T_j = 1.0: sigma^H = {T>=1} = jobs 1,2; sigma^L = {T<1} = job 3
        assert_eq!(v.sum_hi(1.0), 40.0);
        assert_eq!(v.sum_lo(1.0), 10.0);
        // probe above everything
        assert_eq!(v.sum_hi(9.0), 0.0);
        assert_eq!(v.sum_lo(9.0), 70.0);
    }

    #[test]
    fn accrue_touches_only_head() {
        let mut v = VirtualSchedule::new(4);
        v.insert(slot(1, 20.0, 10.0));
        v.insert(slot(2, 10.0, 10.0));
        v.accrue();
        v.accrue();
        assert_eq!(v.slots()[0].n, 2);
        assert_eq!(v.slots()[1].n, 0);
        assert!(v.vw_only_at_head());
    }

    #[test]
    fn rem_terms_shrink_with_vw() {
        let mut s = slot(1, 20.0, 10.0); // T=2
        assert_eq!(s.rem_hi(), 10.0);
        assert_eq!(s.rem_lo(), 20.0);
        s.n = 3;
        assert_eq!(s.rem_hi(), 7.0);
        assert_eq!(s.rem_lo(), 14.0);
    }

    #[test]
    fn ready_at_alpha_point() {
        let mut s = slot(1, 10.0, 21.0); // alpha_pt = ceil(10.5) = 11
        assert_eq!(s.alpha_pt, 11);
        s.n = 10;
        assert!(!s.ready());
        s.n = 11;
        assert!(s.ready());
    }

    #[test]
    #[should_panic]
    fn insert_into_full_panics() {
        let mut v = VirtualSchedule::new(1);
        v.insert(slot(1, 10.0, 10.0));
        v.insert(slot(2, 10.0, 10.0));
    }

    #[test]
    fn pop_head_fifo_of_priority() {
        let mut v = VirtualSchedule::new(4);
        v.insert(slot(1, 10.0, 20.0));
        v.insert(slot(2, 30.0, 20.0));
        assert_eq!(v.pop_head().unwrap().id, 2);
        assert_eq!(v.pop_head().unwrap().id, 1);
        assert!(v.pop_head().is_none());
    }
}
