//! Event-jumping trace driver for the golden engine — the drive-loop
//! half of the tickless core.
//!
//! Every report generator, example and bench used to spin the same
//! per-tick loop (`tick += 1; submit due arrivals; engine.tick(None)`),
//! paying one engine call per *virtual* tick even across the long idle
//! gaps and drain tails where nothing can happen. [`drive_trace`] is the
//! shared replacement: it jumps virtual time straight to
//! `min(next_release, next_arrival)` via [`SosEngine::next_event_tick`]
//! and [`SosEngine::advance_to`], executing only the ticks that can
//! produce a non-empty [`TickOutcome`]. The skipped ticks are exactly
//! the ones a per-tick loop would observe as empty, so callbacks, final
//! tick counts and the schedule itself are bit-identical to the
//! historical loop — only [`DriveStats::iterations`] shrinks.

use crate::bail;
use crate::error::Result;
use crate::workload::Trace;

use super::engine::{SosEngine, TickOutcome};

/// An engine's event horizon, as seen by a drive loop deciding whether
/// it may jump virtual time. Produced by
/// [`SosEngine::next_event_tick`] (via [`Horizon::of`]) and by
/// `coordinator::EngineAdapter::horizon` for type-erased engines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Horizon {
    /// The engine cannot predict its next event — drive it one tick at
    /// a time (the default for per-tick engines: the baselines, both
    /// cycle-accurate simulators, and the XLA path).
    Unknown,
    /// Given no further submissions, every tick strictly before this
    /// one produces an empty [`TickOutcome`], and this is the earliest
    /// tick that can produce a non-empty one. Pending fault events
    /// ([`crate::faults`]) are release-class here: the golden engine
    /// folds them into [`SosEngine::next_event_tick`], so a jump can
    /// never skip over a machine-down/up, straggler or storm event.
    At(u64),
    /// Nothing will ever happen again without a new submission.
    Idle,
}

impl Horizon {
    /// Wrap [`SosEngine::next_event_tick`]'s answer.
    pub fn of(next_event: Option<u64>) -> Horizon {
        match next_event {
            Some(t) => Horizon::At(t),
            None => Horizon::Idle,
        }
    }

    /// Fold two horizons into the horizon of the combined system: if
    /// either side cannot predict, neither can the pair; otherwise the
    /// earliest predicted event wins and `Idle` is the identity. This
    /// is the min-over-shards combinator the sharded coordinator folds
    /// its per-shard horizons with — the merged horizon is safe to jump
    /// on exactly when every member's is.
    pub fn merge(self, other: Horizon) -> Horizon {
        match (self, other) {
            (Horizon::Unknown, _) | (_, Horizon::Unknown) => Horizon::Unknown,
            (Horizon::At(a), Horizon::At(b)) => Horizon::At(a.min(b)),
            (Horizon::At(t), Horizon::Idle) | (Horizon::Idle, Horizon::At(t)) => Horizon::At(t),
            (Horizon::Idle, Horizon::Idle) => Horizon::Idle,
        }
    }

    /// The next tick a drive loop must actually execute, at virtual
    /// time `tick` with the next known arrival (if any): the earlier of
    /// the engine's horizon and the arrival, never before `tick + 1`.
    /// [`Horizon::Unknown`] engines — and idle engines with nothing
    /// arriving — get `tick + 1`, which is exactly the per-tick loop.
    /// This is the one definition of the event-jump invariant; every
    /// tickless drive loop (trace driver, sweep cells, serve pipeline,
    /// lockstep verify) routes through it.
    pub fn jump_target(self, next_arrival: Option<u64>, tick: u64) -> u64 {
        match (self, next_arrival) {
            (Horizon::At(t), Some(a)) => t.min(a),
            (Horizon::At(t), None) => t,
            (Horizon::Idle, Some(a)) => a,
            (Horizon::Idle, None) | (Horizon::Unknown, _) => tick + 1,
        }
        .max(tick + 1)
    }
}

/// What a [`drive_trace`] run consumed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DriveStats {
    /// Virtual ticks elapsed (identical to the per-tick loop's count).
    pub ticks: u64,
    /// Engine-loop iterations actually executed (ticks not skipped by
    /// event-horizon jumps). The tickless win is `ticks / iterations`.
    pub iterations: u64,
}

/// Drive `engine` over `trace` until both are drained, jumping virtual
/// time between events. `on_tick(tick, outcome)` fires for every
/// *executed* tick — precisely the ticks where a per-tick loop could
/// see a non-default outcome (arrival submission, assignment, stall or
/// release). Errors if the trace does not drain within `max_ticks`
/// virtual ticks (same bound a per-tick loop would enforce).
pub fn drive_trace<F: FnMut(u64, &TickOutcome)>(
    engine: &mut SosEngine,
    trace: &Trace,
    max_ticks: u64,
    mut on_tick: F,
) -> Result<DriveStats> {
    let mut events = trace.events().iter().peekable();
    let mut tick = engine.tick_no();
    let mut iterations = 0u64;
    loop {
        // The next tick that can matter: the engine's event horizon or
        // the next trace arrival, whichever comes first. An idle engine
        // with a drained trace gets one more tick so the loop observes
        // the drained state, exactly like the historical loop did.
        let next_arrival = events.peek().map(|e| e.tick);
        let target = Horizon::of(engine.next_event_tick()).jump_target(next_arrival, tick);
        if target > max_ticks {
            bail!("trace did not drain within {max_ticks} virtual ticks");
        }
        engine.advance_to(target - 1);
        tick = target;
        while events.peek().is_some_and(|e| e.tick <= tick) {
            if let Some(job) = &events.next().expect("peeked").job {
                engine.submit(job.clone());
            }
        }
        let out = engine.tick(None);
        iterations += 1;
        on_tick(tick, &out);
        if engine.is_idle() && events.peek().is_none() {
            return Ok(DriveStats { ticks: tick, iterations });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::MachinePark;
    use crate::quant::Precision;
    use crate::workload::{generate_trace, WorkloadSpec};

    fn paper_engine() -> SosEngine {
        SosEngine::new(5, 10, 0.5, Precision::Int8)
    }

    #[test]
    fn jumped_drive_matches_per_tick_loop() {
        let park = MachinePark::paper_m1_m5();
        let trace = generate_trace(&WorkloadSpec::default(), &park, 120, 17);

        // reference: the historical per-tick loop
        let mut ref_engine = paper_engine();
        let mut ref_log: Vec<(u64, TickOutcome)> = Vec::new();
        let mut events = trace.events().iter().peekable();
        let mut t = 0u64;
        let ref_ticks = loop {
            t += 1;
            while events.peek().is_some_and(|e| e.tick <= t) {
                ref_engine.submit(events.next().unwrap().job.clone().unwrap());
            }
            let out = ref_engine.tick(None);
            if out != TickOutcome::default() {
                ref_log.push((t, out));
            }
            if ref_engine.is_idle() && events.peek().is_none() {
                break t;
            }
            assert!(t < 1_000_000);
        };

        let mut engine = paper_engine();
        let mut log: Vec<(u64, TickOutcome)> = Vec::new();
        let stats = drive_trace(&mut engine, &trace, 1_000_000, |tick, out| {
            if *out != TickOutcome::default() {
                log.push((tick, out.clone()));
            }
        })
        .unwrap();
        assert_eq!(stats.ticks, ref_ticks, "virtual time is preserved");
        assert_eq!(log, ref_log, "event streams bit-identical");
        assert!(
            stats.iterations <= stats.ticks,
            "iterations {} vs ticks {}",
            stats.iterations,
            stats.ticks
        );
    }

    #[test]
    fn sparse_arrivals_skip_most_ticks() {
        // Long inter-arrival gaps: the jump loop must execute far fewer
        // iterations than virtual ticks elapse.
        let park = MachinePark::paper_m1_m5();
        let spec = WorkloadSpec::default().with_idle(500, 3);
        let trace = generate_trace(&spec, &park, 60, 5);
        let mut engine = paper_engine();
        let stats = drive_trace(&mut engine, &trace, 10_000_000, |_, _| {}).unwrap();
        assert!(
            stats.iterations * 5 <= stats.ticks,
            "expected >=5x fewer iterations: {} iterations over {} ticks",
            stats.iterations,
            stats.ticks
        );
    }

    #[test]
    fn undrainable_trace_errors_at_the_bound() {
        let park = MachinePark::paper_m1_m5();
        let trace = generate_trace(&WorkloadSpec::default(), &park, 50, 3);
        let mut engine = paper_engine();
        let err = drive_trace(&mut engine, &trace, 10, |_, _| {}).unwrap_err();
        assert!(err.to_string().contains("did not drain"));
    }

    #[test]
    fn jump_target_encodes_the_event_jump_invariant() {
        use super::Horizon::*;
        // earliest of horizon and arrival wins
        assert_eq!(At(50).jump_target(Some(30), 10), 30);
        assert_eq!(At(20).jump_target(Some(30), 10), 20);
        assert_eq!(At(50).jump_target(None, 10), 50);
        assert_eq!(Idle.jump_target(Some(30), 10), 30);
        // nothing known / nothing left: the very next tick (per-tick)
        assert_eq!(Idle.jump_target(None, 10), 11);
        assert_eq!(Unknown.jump_target(Some(30), 10), 11);
        assert_eq!(Unknown.jump_target(None, 10), 11);
        // never before tick + 1, even against stale-looking inputs
        assert_eq!(At(5).jump_target(Some(3), 10), 11);
        assert_eq!(super::Horizon::of(Some(7)), At(7));
        assert_eq!(super::Horizon::of(None), Idle);
    }

    #[test]
    fn merge_is_min_with_unknown_dominant_and_idle_identity() {
        use super::Horizon::*;
        assert_eq!(At(5).merge(At(9)), At(5));
        assert_eq!(At(9).merge(At(5)), At(5));
        assert_eq!(At(5).merge(Idle), At(5));
        assert_eq!(Idle.merge(At(5)), At(5));
        assert_eq!(Idle.merge(Idle), Idle);
        // one unpredictable member poisons the whole fold
        assert_eq!(Unknown.merge(At(5)), Unknown);
        assert_eq!(Idle.merge(Unknown), Unknown);
        // fold shape used by the sharded coordinator
        let folded = [At(40), Idle, At(12)]
            .into_iter()
            .fold(Idle, Horizon::merge);
        assert_eq!(folded, At(12));
    }

    #[test]
    fn merge_is_commutative_associative_with_idle_identity() {
        use super::Horizon::*;
        // exhaustive over the variant shapes, including equal ticks:
        // the shard fold's correctness must not depend on fold order
        let vals = [Unknown, Idle, At(3), At(7), At(3)];
        for a in vals {
            for b in vals {
                assert_eq!(a.merge(b), b.merge(a), "{a:?} merge {b:?} commutes");
                assert_eq!(Idle.merge(a), a, "Idle is the identity");
                for c in vals {
                    assert_eq!(
                        a.merge(b).merge(c),
                        a.merge(b.merge(c)),
                        "associativity over ({a:?}, {b:?}, {c:?})"
                    );
                }
            }
        }
        // the empty fold (a zero-member coordinator) is the seed itself
        let none: [Horizon; 0] = [];
        assert_eq!(none.into_iter().fold(Idle, Horizon::merge), Idle);
    }

    #[test]
    fn empty_trace_drains_in_one_tick() {
        let trace = Trace::new(Vec::new(), 5);
        let mut engine = paper_engine();
        let stats = drive_trace(&mut engine, &trace, 100, |_, _| {}).unwrap();
        assert_eq!(stats, DriveStats { ticks: 1, iterations: 1 });
    }
}
