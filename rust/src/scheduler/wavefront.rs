//! Batch-wavefront SoA cost kernel for Phase-II assignment.
//!
//! The tickless core left Phase II as the golden engine's hot path:
//! [`SosEngine::assign`] walked the park one machine at a time per
//! arrival, re-touching scattered per-machine [`VirtualSchedule`] state
//! (a lazy-sync mutation plus a pointer-chased threshold read) for every
//! candidate. The Stannic microarchitecture gets its per-iteration
//! latency win by evaluating the whole machine array as one systolic
//! wavefront (`sim/stannic/pe.rs` models the PE array doing exactly
//! this), and HTS makes the same argument for parallel-prefix cost
//! evaluation in hardware task schedulers. [`Wavefront`] is the software
//! analogue: a struct-of-arrays mirror of every machine's cost-query
//! state, laid out as contiguous columns so one sweep costs an arrival
//! against the whole park — in the `baselines/simd.rs` idiom, with
//! branchless inner loops the compiler can auto-vectorize — without
//! touching a single `VirtualSchedule` object.
//!
//! # Columns
//!
//! Rows are machines; each machine owns a `depth`-strided segment of the
//! slot-attribute columns (index `m * depth + i` for slot `i`):
//!
//! * `wspt` — the WSPT boundary keys the position scan runs over;
//! * `ept` / `weight` / `n` — the attributes the floating datapaths'
//!   fused rescan accumulates (`rem_hi = ept - n`, `rem_lo = weight -
//!   n·wspt`, in slot order — bit-identical to
//!   [`VirtualSchedule::threshold_read`]'s non-memoized pass);
//! * `memo_hi` / `memo_lo` + per-machine `hi_bias` — the memoized
//!   threshold sums (fixed-point datapaths), copied verbatim from
//!   [`VirtualSchedule::memo_view`];
//! * per-machine scalars: `len`, `full` flags, `synced_at` (head accrual
//!   offsets), and the `down` / `slow` fault masks.
//!
//! # The mirror invariant
//!
//! The mirror is updated **on mutation, never per arrival**: the engine
//! refreshes machine `m`'s row exactly when its schedule structurally
//! changes — insert (the assignment winner), pop, tail/full eviction on
//! a down event, and the up event's `skip_to` — and flips the fault
//! masks on down/up/slow events. Pure lazy syncs (`sync_to`) do *not*
//! refresh: the row snapshot plus its own `synced_at` stays
//! value-consistent, because the head's pending accrual is applied at
//! probe time from the offset column, read-only:
//!
//! * floating datapaths: the head's effective `n` is `n + k` (`u32` add,
//!   exact for every datapath);
//! * memoized datapaths: `sum_hi` reads `memo_hi[pos-1] - (hi_bias + k)`
//!   and a `pos == 0` probe reads `memo_lo[0] - k·wspt[head]` — every
//!   quantity is an exact integer or fixed-point multiple far inside
//!   f32's exact range (the same argument that makes
//!   [`VirtualSchedule::sync_to`] bit-equal to `k` unit accrues), so the
//!   read-time adjustment equals the value a materializing sync would
//!   have produced, bit for bit.
//!
//! # Bit-exactness contract
//!
//! [`Wavefront::sweep`] must reproduce the scalar Phase-II loop exactly
//! on every precision datapath: same per-machine costs (same operation
//! order), same argmin tie-break (strict `<`, lowest index), same insert
//! positions. `cost_of` remains the scalar oracle — the engine's
//! `strict-oracle` feature cross-checks every sweep against it, and
//! `tests/wavefront.rs` pins wavefront == scalar across precisions,
//! parks, admission batches and active fault plans.
//!
//! [`SosEngine::assign`]: crate::scheduler::SosEngine
//! [`VirtualSchedule`]: crate::scheduler::VirtualSchedule
//! [`VirtualSchedule::memo_view`]: crate::scheduler::VirtualSchedule::memo_view
//! [`VirtualSchedule::sync_to`]: crate::scheduler::VirtualSchedule::sync_to
//! [`VirtualSchedule::threshold_read`]: crate::scheduler::VirtualSchedule::threshold_read

use crate::faults::inflate_ept;
use crate::quant::Precision;

use super::cost::FULL_COST;
use super::vschedule::VirtualSchedule;

/// Which Phase-II cost kernel an engine runs. Fixed at construction
/// (the mirror is only maintained under `Wavefront`, so switching
/// mid-run is not supported).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase2Kernel {
    /// The batched SoA sweep over the [`Wavefront`] columns (default).
    Wavefront,
    /// The historical per-machine scatter-gather loop, retained as the
    /// reference implementation the wavefront is gated against.
    Scalar,
}

/// Engine-work counters for Phase II — the measured quantity the
/// hotpath bench gates the batching win on (wall clock is too noisy to
/// assert in CI; these are deterministic).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Phase2Work {
    /// Cost probes evaluated (one per non-down machine per arrival —
    /// the B×M information floor, identical for both kernels).
    pub probes: u64,
    /// `VirtualSchedule` objects touched (lazy-sync mutations) by the
    /// assignment path. The scalar loop pays one per machine per
    /// arrival plus the winner's pre-insert sync; the wavefront sweep
    /// reads only mirror columns and pays the winner's sync alone.
    pub schedule_syncs: u64,
    /// Wavefront mirror rows rebuilt (one per structural mutation:
    /// insert, pop, down-eviction, up-resume).
    pub row_refreshes: u64,
    /// Merged admission batches received via `assign_batch`.
    pub batches: u64,
}

/// Struct-of-arrays mirror of per-machine cost-query state (see the
/// module docs for the layout and the consistency invariant).
#[derive(Debug, Clone)]
pub struct Wavefront {
    machines: usize,
    /// Row stride == schedule depth (slot capacity per machine).
    stride: usize,
    /// Live slots per machine (row prefix length).
    len: Vec<usize>,
    /// `len == stride` flags, mirrored so the sweep's skip test never
    /// derives state mid-loop.
    full: Vec<bool>,
    // slot-attribute columns, row-major per machine
    wspt: Vec<f32>,
    ept: Vec<f32>,
    weight: Vec<f32>,
    n: Vec<u32>,
    // memoized threshold-sum columns (fixed-point datapaths only)
    memo_hi: Vec<f32>,
    memo_lo: Vec<f32>,
    hi_bias: Vec<f32>,
    /// Head accrual offsets: the owning schedule's `synced_at` at
    /// snapshot time. A probe at tick `now` applies the outstanding
    /// `k = (now - 1) - synced_at` cycles read-only.
    synced_at: Vec<u64>,
    /// Fault masks (mirrored from the engine's fault layer).
    down: Vec<bool>,
    slow: Vec<u32>,
    /// Memoized threshold reads enabled (fixed-point datapaths); when
    /// false every probe runs the ordered fused rescan, bit-identical
    /// to the non-memoized `threshold_read`.
    memoized: bool,
}

impl Wavefront {
    pub fn new(machines: usize, depth: usize, memoized: bool) -> Self {
        let cells = machines * depth;
        Wavefront {
            machines,
            stride: depth,
            len: vec![0; machines],
            full: vec![false; machines],
            wspt: vec![0.0; cells],
            ept: vec![0.0; cells],
            weight: vec![0.0; cells],
            n: vec![0; cells],
            memo_hi: if memoized { vec![0.0; cells] } else { Vec::new() },
            memo_lo: if memoized { vec![0.0; cells] } else { Vec::new() },
            hi_bias: vec![0.0; machines],
            synced_at: vec![0; machines],
            down: vec![false; machines],
            slow: vec![1; machines],
            memoized,
        }
    }

    /// Rebuild machine `m`'s row from its schedule. Called by the
    /// engine on every structural mutation (insert / pop / evict /
    /// skip_to) — O(len), the same order as the mutation itself.
    pub fn refresh_row(&mut self, m: usize, vs: &VirtualSchedule) {
        let base = m * self.stride;
        let slots = vs.slots();
        self.len[m] = slots.len();
        self.full[m] = slots.len() == self.stride;
        self.synced_at[m] = vs.synced_at();
        for (i, s) in slots.iter().enumerate() {
            self.wspt[base + i] = s.wspt;
            self.ept[base + i] = s.ept;
            self.weight[base + i] = s.weight;
            self.n[base + i] = s.n;
        }
        if self.memoized {
            let (mhi, mlo, bias) = vs.memo_view();
            self.memo_hi[base..base + mhi.len()].copy_from_slice(mhi);
            self.memo_lo[base..base + mlo.len()].copy_from_slice(mlo);
            self.hi_bias[m] = bias;
        }
    }

    /// Flip the down mask for machine `m` (fault layer down/up events).
    pub fn set_down(&mut self, m: usize, down: bool) {
        self.down[m] = down;
    }

    /// Set the straggler inflation factor for machine `m` (1 = nominal).
    pub fn set_slow(&mut self, m: usize, factor: u32) {
        self.slow[m] = factor.max(1);
    }

    /// Threshold read for machine `m` at probe priority `t`, evaluated
    /// at tick `now` purely from the mirror columns (no schedule
    /// access): `(sum_hi, sum_lo, position)`, bit-identical to syncing
    /// the schedule to `now - 1` and calling `threshold_read(t)`.
    fn threshold_probe(&self, m: usize, t: f32, now: u64) -> (f32, f32, usize) {
        let base = m * self.stride;
        let len = self.len[m];
        debug_assert!(
            self.synced_at[m] <= now - 1,
            "mirror row ahead of the probe tick"
        );
        let k = (now - 1) - self.synced_at[m];
        debug_assert!(k <= u32::MAX as u64, "virtual-work jump overflows n");
        if self.memoized {
            // Branchless prefix count over the sorted boundary keys —
            // equals `partition_point(|s| s.wspt >= t)` because the
            // ordering invariant makes `wspt >= t` a prefix property.
            // This is the auto-vectorizable inner loop: one contiguous
            // f32 row, no branches, no data dependence across lanes.
            let mut pos = 0usize;
            for &w in &self.wspt[base..base + len] {
                pos += (w >= t) as usize;
            }
            let kf = k as f32;
            let sum_hi = if pos > 0 {
                self.memo_hi[base + pos - 1] - (self.hi_bias[m] + kf)
            } else {
                0.0
            };
            let sum_lo = if pos < len {
                let v = self.memo_lo[base + pos];
                // only the pos == 0 suffix contains the head, so only
                // it carries the outstanding accrual
                if pos == 0 { v - kf * self.wspt[base] } else { v }
            } else {
                0.0
            };
            (sum_hi, sum_lo, pos)
        } else {
            // Floating datapaths: the ordered fused single pass, term
            // for term the same accumulation as the scalar rescan (the
            // f32 summation order is semantically load-bearing), with
            // the head's effective n adjusted by the exact u32 offset.
            let mut sum_hi = 0.0f32;
            let mut sum_lo = 0.0f32;
            let mut pos = 0usize;
            for i in 0..len {
                let idx = base + i;
                let n_eff = self.n[idx] + if i == 0 { k as u32 } else { 0 };
                if self.wspt[idx] >= t {
                    sum_hi += self.ept[idx] - n_eff as f32;
                    pos += 1;
                } else {
                    sum_lo += self.weight[idx] - n_eff as f32 * self.wspt[idx];
                }
            }
            (sum_hi, sum_lo, pos)
        }
    }

    /// One Phase-II wavefront pass: cost a job (raw `weight`, raw
    /// per-machine `ept`) against the whole park at tick `now`, filling
    /// `costs` (the engine's cost vector; `FULL_COST` for down or full
    /// machines) and returning the argmin `(machine, cost, position)` —
    /// strict `<`, lowest index on ties, `None` when every machine is
    /// unavailable. Straggler inflation and per-machine quantization
    /// happen per lane, exactly as in the scalar loop; the mirror is
    /// never mutated (the engine syncs and refreshes only the winner).
    pub fn sweep(
        &self,
        weight: f32,
        ept: &[f32],
        precision: Precision,
        now: u64,
        costs: &mut [f32],
    ) -> Option<(usize, f32, usize)> {
        debug_assert_eq!(ept.len(), self.machines);
        debug_assert_eq!(costs.len(), self.machines);
        let mut best: Option<(usize, f32, usize)> = None;
        for m in 0..self.machines {
            if self.down[m] || self.full[m] {
                costs[m] = FULL_COST;
                continue;
            }
            let (j_w, j_eps, j_t) = precision.q_job(weight, inflate_ept(ept[m], self.slow[m]));
            let (sum_hi, sum_lo, pos) = self.threshold_probe(m, j_t, now);
            // same expression, same order as CostBreakdown::total()
            let total = j_w * (j_eps + sum_hi) + j_eps * sum_lo;
            costs[m] = total;
            if best.map_or(true, |(_, bc, _)| total < bc) {
                best = Some((m, total, pos));
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::fixed_round;
    use crate::scheduler::vschedule::Slot;
    use crate::workload::Rng;

    fn slot(id: u64, w: f32, e: f32, fixed: bool) -> Slot {
        let t = if fixed { fixed_round(w / e, 4, 4) } else { w / e };
        Slot {
            id,
            weight: w,
            ept: e,
            wspt: t,
            alpha_pt: (0.5 * e).ceil() as u32,
            n: 0,
        }
    }

    /// Random interleaved insert/pop/sync drive: the mirror probe must
    /// stay bit-identical to syncing the schedule and reading it, for
    /// both datapaths, including rows refreshed long before the probe
    /// tick (exercising the read-only accrual offsets).
    #[test]
    fn probe_matches_synced_threshold_read() {
        for memoized in [false, true] {
            let mut rng = Rng::new(99);
            let depth = 6;
            let mut vs = VirtualSchedule::with_memoization(depth, memoized);
            let mut wf = Wavefront::new(1, depth, memoized);
            let mut id = 1u64;
            let mut now = 0u64;
            for step in 0..2000 {
                now += 1 + rng.below(4); // leave unsynced gaps
                // pop phase
                vs.sync_to(now - 1);
                if vs.head().is_some_and(|h| h.ready()) {
                    vs.pop_head();
                    wf.refresh_row(0, &vs);
                }
                // occasional insert (the structural refresh)
                if !vs.is_full() && rng.chance(0.5) {
                    let w = rng.uniform(1.0, 255.0).round();
                    let e = rng.uniform(10.0, 255.0).round();
                    vs.insert(slot(id, w, e, memoized));
                    wf.refresh_row(0, &vs);
                    id += 1;
                }
                // probe from the mirror WITHOUT syncing a fresh oracle:
                // clone, sync, read — the scalar path's exact sequence
                let probe = if memoized {
                    fixed_round(
                        rng.uniform(1.0, 255.0).round() / rng.uniform(10.0, 255.0).round(),
                        4,
                        4,
                    )
                } else {
                    rng.uniform(1.0, 255.0) / rng.uniform(10.0, 255.0)
                };
                let next = now + 1; // a Phase II at tick `next`
                let got = wf.threshold_probe(0, probe, next);
                let mut oracle = vs.clone();
                oracle.sync_to(next - 1);
                let want = oracle.threshold_read(probe);
                assert_eq!(got, want, "step {step} memoized={memoized}");
            }
        }
    }

    #[test]
    fn sweep_skips_down_and_full_lanes_and_breaks_ties_low() {
        let depth = 2;
        let mut wf = Wavefront::new(4, depth, true);
        let mut schedules: Vec<VirtualSchedule> =
            (0..4).map(|_| VirtualSchedule::with_memoization(depth, true)).collect();
        // machine 2 full, machine 3 down; 0 and 1 identical -> tie to 0
        schedules[2].insert(slot(1, 10.0, 20.0, true));
        schedules[2].insert(slot(2, 10.0, 20.0, true));
        for (m, vs) in schedules.iter().enumerate() {
            wf.refresh_row(m, vs);
        }
        wf.set_down(3, true);
        let mut costs = vec![0.0; 4];
        let best = wf
            .sweep(8.0, &[40.0, 40.0, 40.0, 40.0], Precision::Int8, 1, &mut costs)
            .expect("machines 0/1 are free");
        assert_eq!(best.0, 0, "tie goes to the lowest machine index");
        assert_eq!(costs[2], FULL_COST);
        assert_eq!(costs[3], FULL_COST);
        assert_eq!(costs[0], costs[1]);
    }

    #[test]
    fn sweep_applies_straggler_inflation_per_lane() {
        let mut wf = Wavefront::new(2, 4, true);
        let schedules: Vec<VirtualSchedule> =
            (0..2).map(|_| VirtualSchedule::with_memoization(4, true)).collect();
        for (m, vs) in schedules.iter().enumerate() {
            wf.refresh_row(m, vs);
        }
        wf.set_slow(0, 4);
        let mut costs = vec![0.0; 2];
        // empty park: cost = W * eps; slow lane 0 sees eps * 4
        let best = wf
            .sweep(2.0, &[10.0, 30.0], Precision::Fp32, 1, &mut costs)
            .unwrap();
        assert_eq!(costs[0], 80.0, "lane 0 quoted the inflated EPT");
        assert_eq!(costs[1], 60.0);
        assert_eq!(best.0, 1);
        wf.set_slow(0, 1);
        wf.sweep(2.0, &[10.0, 30.0], Precision::Fp32, 1, &mut costs);
        assert_eq!(costs[0], 20.0, "slow-end restores the nominal rate");
    }
}
