//! Discrete-time assignment cost — Equations (4) and (5) of Section 3.2.

use super::vschedule::VirtualSchedule;

/// Sentinel cost for full virtual schedules; must match
/// `python/compile/kernels/ref.py::FULL_COST`.
pub const FULL_COST: f32 = 3.0e38;

/// The two cost components of Eq. (2)/(4)/(5).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostBreakdown {
    /// `cost^H = J.W * (J.eps_i + sum^H)` — delay imposed *on* J by
    /// higher-or-equal-priority incumbents.
    pub hi: f32,
    /// `cost^L = J.eps_i * sum^L` — delay imposed *by* J on lower-priority
    /// incumbents.
    pub lo: f32,
    /// Insertion index of J in the schedule (|sigma^H|).
    pub position: usize,
}

impl CostBreakdown {
    #[inline]
    pub fn total(&self) -> f32 {
        self.hi + self.lo
    }
}

/// Cost of scheduling a job with (quantized) weight `j_w`, EPT `j_eps`
/// and WSPT `j_t` onto the machine owning `vs`. Returns `None` when the
/// schedule is full (the machine cannot be selected).
pub fn cost_of(vs: &VirtualSchedule, j_w: f32, j_eps: f32, j_t: f32) -> Option<CostBreakdown> {
    if vs.is_full() {
        return None;
    }
    // Memoized-sum fast path (Section 3.3 opt. 3, mirroring the Stannic
    // PE array): the schedule maintains incremental prefix/suffix sums,
    // so a query is the position scan plus two O(1) lookups instead of a
    // full re-accumulation of rem_hi/rem_lo over the depth — the cost of
    // this function is paid once per machine per arrival, which made the
    // rescan the golden engine's hottest loop.
    let (sum_hi, sum_lo, position) = vs.threshold_read(j_t);
    // The rescan oracle re-accumulates the whole depth per probe, which
    // turns every debug cost query quadratic — so it is opt-in via the
    // `strict-oracle` feature (enabled by CI's tier-1 test job) instead
    // of riding along in every dev build.
    #[cfg(feature = "strict-oracle")]
    debug_assert!(
        {
            let want_hi = vs.sum_hi(j_t);
            let want_lo = vs.sum_lo(j_t);
            (sum_hi - want_hi).abs() <= 1e-2 * (1.0 + want_hi.abs())
                && (sum_lo - want_lo).abs() <= 1e-2 * (1.0 + want_lo.abs())
        },
        "memoized threshold sums drifted from the rescan oracle"
    );
    Some(CostBreakdown {
        hi: j_w * (j_eps + sum_hi),
        lo: j_eps * sum_lo,
        position,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::vschedule::Slot;

    fn slot(id: u64, w: f32, e: f32) -> Slot {
        Slot {
            id,
            weight: w,
            ept: e,
            wspt: w / e,
            alpha_pt: (0.5 * e).ceil() as u32,
            n: 0,
        }
    }

    #[test]
    fn empty_schedule_cost_is_w_times_eps() {
        let vs = VirtualSchedule::new(4);
        let c = cost_of(&vs, 3.0, 50.0, 3.0 / 50.0).unwrap();
        assert_eq!(c.hi, 150.0);
        assert_eq!(c.lo, 0.0);
        assert_eq!(c.position, 0);
    }

    #[test]
    fn matches_hand_computed_example() {
        // V_i: K1 (W=40,e=20,T=2), K2 (W=20,e=20,T=1), K3 (W=10,e=20,T=0.5)
        let mut vs = VirtualSchedule::new(8);
        vs.insert(slot(1, 40.0, 20.0));
        vs.insert(slot(2, 20.0, 20.0));
        vs.insert(slot(3, 10.0, 20.0));
        // J: W=15, eps=15, T=1.0 -> sigma^H={K1,K2} (ties count), sigma^L={K3}
        let c = cost_of(&vs, 15.0, 15.0, 1.0).unwrap();
        // cost^H = 15*(15 + (20+20)) = 825 ; cost^L = 15*10 = 150
        assert_eq!(c.hi, 825.0);
        assert_eq!(c.lo, 150.0);
        assert_eq!(c.total(), 975.0);
        assert_eq!(c.position, 2);
    }

    #[test]
    fn virtual_work_discounts_cost() {
        let mut vs = VirtualSchedule::new(4);
        vs.insert(slot(1, 40.0, 20.0)); // head, T=2
        for _ in 0..5 {
            vs.accrue(); // n_head = 5
        }
        // J with T=1: sum^H = (20-5) = 15
        let c = cost_of(&vs, 10.0, 10.0, 1.0).unwrap();
        assert_eq!(c.hi, 10.0 * (10.0 + 15.0));
        // J with T=3 (outranks head): sum^L = 40 - 5*2 = 30
        let c2 = cost_of(&vs, 30.0, 10.0, 3.0).unwrap();
        assert_eq!(c2.lo, 10.0 * 30.0);
        assert_eq!(c2.position, 0);
    }

    #[test]
    fn full_schedule_returns_none() {
        let mut vs = VirtualSchedule::new(1);
        vs.insert(slot(1, 10.0, 10.0));
        assert!(cost_of(&vs, 1.0, 10.0, 0.1).is_none());
    }

    #[test]
    fn remark_no_negative_contribution_under_alpha_policy() {
        // Section 3.2 Remark: with alpha in (0,1], a job releases at or
        // before n == eps, so rem_hi and rem_lo never go negative.
        let mut vs = VirtualSchedule::new(2);
        vs.insert(slot(1, 16.0, 8.0)); // alpha_pt = 4 (alpha 0.5)
        for _ in 0..4 {
            vs.accrue();
        }
        let head = *vs.head().unwrap();
        assert!(head.ready());
        assert!(head.rem_hi() >= 0.0);
        assert!(head.rem_lo() >= 0.0);
    }
}
