//! Persisted sweep results + cross-commit perf diffing — the repo's
//! benchmarking backbone.
//!
//! The paper's headline claims are throughput claims, yet bench tables
//! printed to a terminal evaporate. This module makes every sweep a
//! durable, machine-readable perf observation: [`SweepRecord`]
//! serializes per-cell results (scenario key, schedule digest, the
//! deterministic quality metrics, and the measured wall time) through
//! [`crate::jsonio`] into a `BENCH_<label>.json` artifact, and
//! [`diff_records`] compares two artifacts cell-by-cell so CI can fail a
//! PR that slows a cell down or — worse — silently changes a schedule
//! (a digest mismatch is a parity break, never a perf delta).
//!
//! Wall-clock comparisons across commits are noisy, so classification
//! normalizes each cell's throughput ratio by the *median* ratio across
//! the grid ("the machine got uniformly slower" is separated from "this
//! cell regressed"); a median shift beyond the threshold is reported
//! prominently as a whole-grid slowdown but only fails the gate under
//! [`DiffOpts::fail_on_shift`], because across hosts it is
//! indistinguishable from a slower machine. Set
//! [`DiffOpts::normalize`] to `false` for raw ratios.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::bench::Table;
use crate::jsonio::{arr, num, obj, s, Json};

use super::{CellResult, SweepResults};

/// Schema tag embedded in every artifact, bumped on breaking layout
/// changes so `sweep diff` can reject mismatched files with a clear
/// message instead of a field error.
pub const RECORD_SCHEMA: &str = "stannic.sweep.record.v1";

/// One persisted sweep cell: the full scenario key, the deterministic
/// outcome (digest + quality metrics), and the measured wall time.
#[derive(Debug, Clone, PartialEq)]
pub struct CellRecord {
    pub engine: String,
    pub workload: String,
    pub machines: usize,
    pub depth: usize,
    pub alpha: f32,
    pub precision: String,
    pub jobs: usize,
    pub seed: u64,
    /// FNV-1a digest of the deterministic outcome; equal scenarios with
    /// different digests mean scheduling semantics changed.
    pub digest: String,
    pub jobs_per_machine: Vec<usize>,
    pub avg_latency: f64,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub ticks: u64,
    pub stalls: u64,
    pub accel_cycles: u64,
    pub utilization: f64,
    pub fairness: f64,
    pub load_cv: f64,
    pub throughput: f64,
    /// Host wall-clock for the cell, ns (the only non-deterministic field).
    pub wall_ns: u64,
}

impl CellRecord {
    pub fn from_result(r: &CellResult) -> CellRecord {
        let mut rec = CellRecord {
            engine: r.cell.engine.name().to_string(),
            workload: r.cell.workload.clone(),
            machines: r.cell.machines,
            depth: r.cell.depth,
            alpha: r.cell.alpha,
            precision: r.cell.precision.name().to_string(),
            jobs: r.cell.jobs,
            seed: r.cell.seed,
            digest: String::new(),
            jobs_per_machine: r.metrics.jobs_per_machine.clone(),
            avg_latency: r.metrics.avg_latency,
            p50: r.p50,
            p95: r.p95,
            p99: r.p99,
            ticks: r.ticks,
            stalls: r.stalls,
            accel_cycles: r.accel_cycles,
            utilization: r.utilization,
            fairness: r.metrics.fairness,
            load_cv: r.metrics.load_balance_cv,
            throughput: r.metrics.throughput,
            wall_ns: r.wall_ns,
        };
        rec.digest = rec.compute_digest();
        rec
    }

    /// Scenario key: everything that must match for two cells (from two
    /// artifacts) to be the same measurement.
    pub fn key(&self) -> String {
        format!(
            "{}|{}|m{}|d{}|a{:.4}|{}|j{}|s{}",
            self.engine,
            self.workload,
            self.machines,
            self.depth,
            self.alpha,
            self.precision,
            self.jobs,
            self.seed
        )
    }

    /// Digest of the deterministic outcome. Every input is persisted, so
    /// a parsed record recomputes the identical value (f64 `Display`
    /// round-trips exactly).
    pub fn compute_digest(&self) -> String {
        let mut canon = String::new();
        let _ = write!(
            canon,
            "{:?}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}",
            self.jobs_per_machine,
            self.ticks,
            self.stalls,
            self.p50,
            self.p95,
            self.p99,
            self.accel_cycles,
            self.avg_latency,
            self.utilization,
            self.fairness,
            self.throughput
        );
        format!("{:016x}", fnv1a64(canon.as_bytes()))
    }

    /// Scheduling throughput: jobs scheduled per wall-clock second.
    pub fn jobs_per_sec(&self) -> f64 {
        if self.wall_ns == 0 {
            0.0
        } else {
            self.jobs as f64 / (self.wall_ns as f64 / 1e9)
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("engine", s(self.engine.clone())),
            ("workload", s(self.workload.clone())),
            ("machines", num(self.machines as f64)),
            ("depth", num(self.depth as f64)),
            ("alpha", num(f64::from(self.alpha))),
            ("precision", s(self.precision.clone())),
            ("jobs", num(self.jobs as f64)),
            // u64-exact fields go through strings: jsonio numbers are f64
            ("seed", s(self.seed.to_string())),
            ("digest", s(self.digest.clone())),
            (
                "jobs_per_machine",
                arr(self.jobs_per_machine.iter().map(|&c| num(c as f64)).collect()),
            ),
            ("avg_latency", num(self.avg_latency)),
            ("p50", num(self.p50 as f64)),
            ("p95", num(self.p95 as f64)),
            ("p99", num(self.p99 as f64)),
            ("ticks", num(self.ticks as f64)),
            ("stalls", num(self.stalls as f64)),
            ("accel_cycles", num(self.accel_cycles as f64)),
            ("utilization", num(self.utilization)),
            ("fairness", num(self.fairness)),
            ("load_cv", num(self.load_cv)),
            ("throughput", num(self.throughput)),
            ("wall_ns", s(self.wall_ns.to_string())),
            ("jobs_per_sec", num(self.jobs_per_sec())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<CellRecord, String> {
        Ok(CellRecord {
            engine: get_str(j, "engine")?,
            workload: get_str(j, "workload")?,
            machines: get_uint(j, "machines")? as usize,
            depth: get_uint(j, "depth")? as usize,
            alpha: get_f64(j, "alpha")? as f32,
            precision: get_str(j, "precision")?,
            jobs: get_uint(j, "jobs")? as usize,
            seed: get_u64_str(j, "seed")?,
            digest: get_str(j, "digest")?,
            jobs_per_machine: get_arr(j, "jobs_per_machine")?
                .iter()
                .map(|v| {
                    v.as_f64()
                        .ok_or_else(|| "non-numeric jobs_per_machine entry".to_string())
                        .and_then(|n| uint_value(n, "jobs_per_machine entry"))
                        .map(|n| n as usize)
                })
                .collect::<Result<Vec<usize>, String>>()?,
            avg_latency: get_f64(j, "avg_latency")?,
            p50: get_uint(j, "p50")?,
            p95: get_uint(j, "p95")?,
            p99: get_uint(j, "p99")?,
            ticks: get_uint(j, "ticks")?,
            stalls: get_uint(j, "stalls")?,
            accel_cycles: get_uint(j, "accel_cycles")?,
            utilization: get_f64(j, "utilization")?,
            fairness: get_f64(j, "fairness")?,
            load_cv: get_f64(j, "load_cv")?,
            throughput: get_f64(j, "throughput")?,
            wall_ns: get_u64_str(j, "wall_ns")?,
        })
    }
}

/// A persisted sweep: label + per-cell records, serializable to/from the
/// `BENCH_<label>.json` artifact format.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepRecord {
    pub label: String,
    /// Unix seconds at record time (0 when the clock is unavailable).
    pub created_unix: u64,
    /// Worker threads the sweep ran on (informational).
    pub threads: usize,
    pub cells: Vec<CellRecord>,
}

impl SweepRecord {
    pub fn from_results(label: &str, results: &SweepResults) -> SweepRecord {
        SweepRecord {
            label: label.to_string(),
            created_unix: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
            threads: results.threads,
            cells: results.cells.iter().map(CellRecord::from_result).collect(),
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("schema", s(RECORD_SCHEMA)),
            ("label", s(self.label.clone())),
            ("created_unix", s(self.created_unix.to_string())),
            ("threads", num(self.threads as f64)),
            ("cells", arr(self.cells.iter().map(CellRecord::to_json).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SweepRecord, String> {
        let schema = get_str(j, "schema")?;
        if schema != RECORD_SCHEMA {
            return Err(format!(
                "unsupported sweep record schema '{schema}' (expected {RECORD_SCHEMA})"
            ));
        }
        let cells = get_arr(j, "cells")?
            .iter()
            .map(CellRecord::from_json)
            .collect::<Result<Vec<CellRecord>, String>>()?;
        Ok(SweepRecord {
            label: get_str(j, "label")?,
            created_unix: get_u64_str(j, "created_unix")?,
            threads: get_uint(j, "threads")? as usize,
            cells,
        })
    }

    /// Parse an artifact from its serialized text.
    pub fn parse(text: &str) -> Result<SweepRecord, String> {
        SweepRecord::from_json(&Json::parse(text)?)
    }

    /// Serialize to the artifact text (compact JSON + trailing newline).
    pub fn render(&self) -> String {
        let mut text = self.to_json().render();
        text.push('\n');
        text
    }
}

/// Diff configuration.
#[derive(Debug, Clone, Copy)]
pub struct DiffOpts {
    /// Relative per-cell throughput drop that counts as a regression
    /// (0.25 = fail on >25% slower).
    pub threshold: f64,
    /// Normalize each cell's ratio by the grid's median ratio, so a
    /// uniformly slower/faster host doesn't flag every cell.
    pub normalize: bool,
    /// Also *fail* the gate when the median shift itself regressed past
    /// the threshold. Off by default: the shift conflates real uniform
    /// slowdowns with baseline-host-vs-CI-host speed differences, so it
    /// is reported prominently but only gates when the caller knows
    /// both records come from comparable hosts (same-machine A/B runs).
    pub fail_on_shift: bool,
}

impl Default for DiffOpts {
    fn default() -> Self {
        DiffOpts {
            threshold: 0.25,
            normalize: true,
            fail_on_shift: false,
        }
    }
}

/// Per-cell diff verdict.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellVerdict {
    Unchanged,
    Regression,
    Improvement,
    /// The deterministic outcome digest changed: scheduling semantics
    /// differ between the two records. Never a perf delta; requires an
    /// intentional re-bless of the baseline.
    ParityBreak,
    /// One side has no usable throughput measurement (zero wall time in
    /// a hand-edited or corrupt artifact — `run_cell` floors wall_ns at
    /// 1). Fails the gate: an unmeasured cell must not pass as "ok".
    Unmeasured,
}

impl CellVerdict {
    pub fn name(&self) -> &'static str {
        match self {
            CellVerdict::Unchanged => "ok",
            CellVerdict::Regression => "REGRESSION",
            CellVerdict::Improvement => "improvement",
            CellVerdict::ParityBreak => "PARITY-BREAK",
            CellVerdict::Unmeasured => "UNMEASURED",
        }
    }
}

/// One matched cell in a diff.
#[derive(Debug, Clone)]
pub struct CellDiff {
    pub key: String,
    pub old_jps: f64,
    pub new_jps: f64,
    /// Raw new/old throughput ratio (>1 = faster).
    pub ratio: f64,
    /// Ratio divided by the grid's median shift (== `ratio` when
    /// normalization is off).
    pub norm_ratio: f64,
    pub verdict: CellVerdict,
}

/// Result of diffing two sweep records.
#[derive(Debug, Clone)]
pub struct DiffReport {
    pub old_label: String,
    pub new_label: String,
    pub cells: Vec<CellDiff>,
    pub only_in_old: Vec<String>,
    pub only_in_new: Vec<String>,
    /// Median new/old throughput ratio across matched cells — the
    /// whole-grid (host) speed shift.
    pub shift: f64,
    pub threshold: f64,
    /// True when the median shift itself regressed past the threshold —
    /// a uniform slowdown *or* a slower host. Only fails the gate under
    /// [`DiffOpts::fail_on_shift`].
    pub global_regression: bool,
    /// Whether `global_regression` participates in [`Self::ok`].
    pub fail_on_shift: bool,
}

impl DiffReport {
    pub fn regressions(&self) -> usize {
        self.count(CellVerdict::Regression)
    }

    pub fn improvements(&self) -> usize {
        self.count(CellVerdict::Improvement)
    }

    pub fn parity_breaks(&self) -> usize {
        self.count(CellVerdict::ParityBreak)
    }

    pub fn unmeasured(&self) -> usize {
        self.count(CellVerdict::Unmeasured)
    }

    fn count(&self, v: CellVerdict) -> usize {
        self.cells.iter().filter(|c| c.verdict == v).count()
    }

    /// Gate verdict: no per-cell regressions, no parity breaks, no
    /// unmeasured cells, full coverage of the baseline grid, and (only
    /// when `fail_on_shift` is set) no global slowdown.
    pub fn ok(&self) -> bool {
        self.regressions() == 0
            && self.parity_breaks() == 0
            && self.unmeasured() == 0
            && !(self.fail_on_shift && self.global_regression)
            && self.only_in_old.is_empty()
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "sweep diff: {} -> {} ({} matched cells, threshold {:.0}%)\n",
            self.old_label,
            self.new_label,
            self.cells.len(),
            self.threshold * 100.0
        );
        let mut t = Table::new(&["cell", "old jobs/s", "new jobs/s", "ratio", "norm", "verdict"]);
        for c in &self.cells {
            t.row(vec![
                c.key.clone(),
                format!("{:.0}", c.old_jps),
                format!("{:.0}", c.new_jps),
                format!("{:.3}", c.ratio),
                format!("{:.3}", c.norm_ratio),
                c.verdict.name().to_string(),
            ]);
        }
        out.push_str(&t.render());
        let _ = writeln!(
            out,
            "\ngrid shift (median ratio): {:.3}x{}",
            self.shift,
            if self.global_regression && self.fail_on_shift {
                "  <- GLOBAL REGRESSION (gating: --fail-on-shift)"
            } else if self.global_regression {
                "  <- whole-grid slowdown (uniform regression OR slower \
                 host; advisory — gate with --fail-on-shift)"
            } else {
                ""
            }
        );
        for k in &self.only_in_old {
            let _ = writeln!(out, "MISSING in new record: {k}");
        }
        for k in &self.only_in_new {
            let _ = writeln!(out, "new cell (not in baseline): {k}");
        }
        let _ = writeln!(
            out,
            "{} regressions, {} improvements, {} parity breaks, {} unmeasured, {} missing => {}",
            self.regressions(),
            self.improvements(),
            self.parity_breaks(),
            self.unmeasured(),
            self.only_in_old.len(),
            if self.ok() { "OK" } else { "FAIL" }
        );
        out
    }
}

/// Diff two sweep records cell-by-cell (matched on the scenario key).
pub fn diff_records(old: &SweepRecord, new: &SweepRecord, opts: &DiffOpts) -> DiffReport {
    let old_by_key: BTreeMap<String, &CellRecord> =
        old.cells.iter().map(|c| (c.key(), c)).collect();
    let new_by_key: BTreeMap<String, &CellRecord> =
        new.cells.iter().map(|c| (c.key(), c)).collect();

    let mut matched: Vec<(String, &CellRecord, &CellRecord)> = Vec::new();
    let mut only_in_old = Vec::new();
    for (key, o) in &old_by_key {
        match new_by_key.get(key) {
            Some(n) => matched.push((key.clone(), o, n)),
            None => only_in_old.push(key.clone()),
        }
    }
    let only_in_new: Vec<String> = new_by_key
        .keys()
        .filter(|k| !old_by_key.contains_key(*k))
        .cloned()
        .collect();

    // Median throughput ratio over cells with sane measurements.
    let mut ratios: Vec<f64> = matched
        .iter()
        .filter(|(_, o, n)| o.jobs_per_sec() > 0.0 && n.jobs_per_sec() > 0.0)
        .map(|(_, o, n)| n.jobs_per_sec() / o.jobs_per_sec())
        .collect();
    ratios.sort_by(|a, b| a.partial_cmp(b).expect("finite ratios"));
    let shift = match ratios.len() {
        0 => 1.0,
        n if n % 2 == 1 => ratios[n / 2],
        n => (ratios[n / 2 - 1] * ratios[n / 2]).sqrt(),
    };
    // On tiny grids the median IS the (possibly regressed) cell, so
    // normalizing by it would cancel the very signal we gate on — a
    // 10x-slower single-cell grid must not read as "unchanged". Below
    // this many matched cells, ratios are compared raw.
    const MIN_CELLS_TO_NORMALIZE: usize = 4;
    let denom = if opts.normalize && shift > 0.0 && ratios.len() >= MIN_CELLS_TO_NORMALIZE {
        shift
    } else {
        1.0
    };

    let cells: Vec<CellDiff> = matched
        .into_iter()
        .map(|(key, o, n)| {
            let (old_jps, new_jps) = (o.jobs_per_sec(), n.jobs_per_sec());
            let ratio = if old_jps > 0.0 && new_jps > 0.0 {
                new_jps / old_jps
            } else {
                1.0
            };
            let norm_ratio = ratio / denom;
            let verdict = if o.digest != n.digest {
                CellVerdict::ParityBreak
            } else if old_jps <= 0.0 || new_jps <= 0.0 {
                CellVerdict::Unmeasured
            } else if norm_ratio < 1.0 - opts.threshold {
                CellVerdict::Regression
            } else if norm_ratio > 1.0 + opts.threshold {
                CellVerdict::Improvement
            } else {
                CellVerdict::Unchanged
            };
            CellDiff {
                key,
                old_jps,
                new_jps,
                ratio,
                norm_ratio,
                verdict,
            }
        })
        .collect();

    DiffReport {
        old_label: old.label.clone(),
        new_label: new.label.clone(),
        cells,
        only_in_old,
        only_in_new,
        shift,
        threshold: opts.threshold,
        global_regression: shift < 1.0 - opts.threshold,
        fail_on_shift: opts.fail_on_shift,
    }
}

/// FNV-1a 64-bit — deterministic, dependency-free digest for schedule
/// outcomes (not cryptographic; collisions only hide a parity break that
/// the golden test would catch anyway).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

pub(crate) fn get_str(j: &Json, k: &str) -> Result<String, String> {
    j.get(k)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("missing string field '{k}'"))
}

pub(crate) fn get_f64(j: &Json, k: &str) -> Result<f64, String> {
    j.get(k)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("missing numeric field '{k}'"))
}

/// Reject negative/fractional/huge values for integer-typed fields
/// instead of silently saturating through `as` casts — a hand-edited
/// artifact should fail at parse time with the field name, not surface
/// later as a confusing digest mismatch.
pub(crate) fn uint_value(v: f64, what: &str) -> Result<u64, String> {
    if v.is_nan() || v < 0.0 || v.fract() != 0.0 || v > 9_007_199_254_740_992.0 {
        return Err(format!("{what}: expected a non-negative integer, got {v}"));
    }
    Ok(v as u64)
}

pub(crate) fn get_uint(j: &Json, k: &str) -> Result<u64, String> {
    uint_value(get_f64(j, k)?, k)
}

/// Require an actual JSON array (`Json::items` silently yields an empty
/// slice for non-arrays, which would let a corrupt artifact parse).
pub(crate) fn get_arr<'a>(j: &'a Json, k: &str) -> Result<&'a [Json], String> {
    match j.get(k) {
        Some(Json::Arr(v)) => Ok(v),
        Some(_) => Err(format!("field '{k}': expected an array")),
        None => Err(format!("missing array field '{k}'")),
    }
}

pub(crate) fn get_u64_str(j: &Json, k: &str) -> Result<u64, String> {
    get_str(j, k)?
        .parse::<u64>()
        .map_err(|e| format!("field '{k}': {e}"))
}

#[cfg(test)]
mod tests {
    use super::super::{run_sweep, SweepConfig};
    use super::*;
    use crate::engine::EngineId;
    use crate::quant::Precision;
    use crate::workload::WorkloadSpec;

    fn small_record() -> SweepRecord {
        let cfg = SweepConfig {
            engines: vec![EngineId::Sos, EngineId::Sosc, EngineId::Simd],
            workloads: vec![("even".to_string(), WorkloadSpec::even())],
            machine_counts: vec![3],
            alphas: vec![0.5, 0.75],
            precisions: vec![Precision::Int8],
            depth: 6,
            jobs: 30,
            seed: 11,
            threads: 2,
        };
        SweepRecord::from_results("test", &run_sweep(&cfg))
    }

    #[test]
    fn record_round_trips_through_jsonio() {
        let rec = small_record();
        assert_eq!(rec.cells.len(), 6);
        let text = rec.render();
        let back = SweepRecord::parse(&text).expect("parse own artifact");
        assert_eq!(rec, back, "parse(render(r)) == r");
        // serialize -> parse -> serialize is a fixed point
        assert_eq!(text, back.render());
    }

    #[test]
    fn digest_recomputes_from_persisted_fields() {
        let rec = small_record();
        let back = SweepRecord::parse(&rec.render()).unwrap();
        for c in &back.cells {
            assert_eq!(c.digest, c.compute_digest(), "digest stable across round trip");
        }
    }

    #[test]
    fn rejects_wrong_schema_and_garbage() {
        assert!(SweepRecord::parse("{}").is_err());
        assert!(SweepRecord::parse("not json").is_err());
        let mut rec = small_record();
        rec.label = "x".into();
        let text = rec.render().replace(RECORD_SCHEMA, "stannic.sweep.record.v0");
        assert!(SweepRecord::parse(&text).is_err());
    }

    #[test]
    fn rejects_negative_and_fractional_integer_fields() {
        let rec = small_record();
        let machines = format!("\"machines\":{}", rec.cells[0].machines);
        let text = rec.render().replacen(&machines, "\"machines\":-3", 1);
        assert!(
            SweepRecord::parse(&text).is_err(),
            "negative machines must be rejected at parse time"
        );
        let ticks = format!("\"ticks\":{}", rec.cells[0].ticks);
        let text = rec
            .render()
            .replacen(&ticks, &format!("\"ticks\":{}.5", rec.cells[0].ticks), 1);
        assert!(
            SweepRecord::parse(&text).is_err(),
            "fractional ticks must be rejected at parse time"
        );
    }

    #[test]
    fn diff_identical_records_is_ok() {
        let rec = small_record();
        let report = diff_records(&rec, &rec, &DiffOpts::default());
        assert_eq!(report.cells.len(), 6);
        assert!(report.ok(), "identical records must pass:\n{}", report.render());
        assert_eq!(report.regressions(), 0);
        assert_eq!(report.parity_breaks(), 0);
        assert!((report.shift - 1.0).abs() < 1e-9);
    }

    #[test]
    fn diff_flags_injected_regression() {
        let old = small_record();
        let mut new = old.clone();
        new.cells[0].wall_ns *= 10; // one cell 10x slower
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert_eq!(report.regressions(), 1, "{}", report.render());
        assert!(!report.ok());
        // the regressed cell is the tampered one
        let bad = report
            .cells
            .iter()
            .find(|c| c.verdict == CellVerdict::Regression)
            .unwrap();
        assert_eq!(bad.key, old.cells[0].key());
        assert!(bad.ratio < 0.2);
    }

    #[test]
    fn diff_flags_improvement_without_failing() {
        let old = small_record();
        let mut new = old.clone();
        new.cells[2].wall_ns = (new.cells[2].wall_ns / 10).max(1); // ~10x faster
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert_eq!(report.improvements(), 1, "{}", report.render());
        assert_eq!(report.regressions(), 0, "{}", report.render());
        assert!(report.ok(), "an improvement must not fail the gate");
    }

    #[test]
    fn diff_reports_uniform_slowdown_as_global_shift() {
        let old = small_record();
        let mut new = old.clone();
        for c in &mut new.cells {
            c.wall_ns *= 3; // whole grid 3x slower
        }
        // advisory by default: across hosts a uniform shift is
        // indistinguishable from a slower machine
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert!(report.global_regression, "{}", report.render());
        assert!(report.ok(), "shift alone must not gate by default");
        // normalization keeps per-cell verdicts clean: it's the host/
        // whole-grid shift that moved, not one cell
        assert_eq!(report.regressions(), 0);
        // same-host A/B runs opt into gating on the shift
        let strict = DiffOpts {
            fail_on_shift: true,
            ..DiffOpts::default()
        };
        let report = diff_records(&old, &new, &strict);
        assert!(!report.ok(), "{}", report.render());
    }

    #[test]
    fn tiny_grids_compare_raw_ratios() {
        // With one matched cell the median ratio IS that cell, so
        // normalization would cancel any regression — the guard must
        // fall back to raw ratios and still flag it.
        let mut old = small_record();
        old.cells.truncate(1);
        let mut new = old.clone();
        new.cells[0].wall_ns *= 10;
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert_eq!(report.regressions(), 1, "{}", report.render());
        assert!(!report.ok());
    }

    #[test]
    fn diff_flags_unmeasured_cells() {
        // run_cell floors wall_ns at 1, so a zero can only come from a
        // hand-edited or corrupt artifact — it must fail the gate, not
        // silently pass as "unchanged".
        let old = small_record();
        let mut new = old.clone();
        new.cells[0].wall_ns = 0;
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert_eq!(report.unmeasured(), 1, "{}", report.render());
        assert_eq!(report.regressions(), 0);
        assert!(!report.ok());
    }

    #[test]
    fn diff_flags_parity_break_on_digest_change() {
        let old = small_record();
        let mut new = old.clone();
        new.cells[1].ticks += 1;
        new.cells[1].digest = new.cells[1].compute_digest();
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert_eq!(report.parity_breaks(), 1, "{}", report.render());
        assert!(!report.ok());
    }

    #[test]
    fn diff_fails_on_missing_baseline_cells() {
        let old = small_record();
        let mut new = old.clone();
        new.cells.pop();
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert_eq!(report.only_in_old.len(), 1);
        assert!(!report.ok());
        // the reverse direction (grid grew) is fine
        let report = diff_records(&new, &old, &DiffOpts::default());
        assert_eq!(report.only_in_new.len(), 1);
        assert!(report.ok(), "{}", report.render());
    }

    #[test]
    fn threshold_is_respected() {
        let old = small_record();
        let mut new = old.clone();
        // ~11% slower on one cell: inside the default 25% budget
        new.cells[0].wall_ns += new.cells[0].wall_ns / 9;
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert!(report.ok(), "{}", report.render());
        // but outside a 5% budget
        let strict = DiffOpts {
            threshold: 0.05,
            ..DiffOpts::default()
        };
        let report = diff_records(&old, &new, &strict);
        assert_eq!(report.regressions(), 1, "{}", report.render());
    }
}
