//! Persisted sweep results — the grid arm of the repo's benchmarking
//! backbone, built on the [`crate::artifact`] layer.
//!
//! The paper's headline claims are throughput claims, yet bench tables
//! printed to a terminal evaporate. This module makes every sweep a
//! durable, machine-readable perf observation: [`SweepRecord`]
//! serializes per-cell results (scenario key, schedule digest, the
//! deterministic quality metrics, and the measured wall time) through
//! [`crate::jsonio`] into a `BENCH_<label>.json` artifact
//! ([`crate::artifact::SWEEP_RECORD`] schema).
//!
//! Diffing is not implemented here: [`SweepRecord`] exposes its cells
//! as [`PerfCell`]s (scenario key, schedule digest as the parity
//! identity, jobs/sec as the perf scalar) and the generic
//! [`crate::artifact::diff`] core does the classification — the same
//! core `serve diff` runs on, so a digest mismatch is a parity break
//! and a wall-time shift is median-normalized identically on both
//! surfaces.

use std::fmt::Write as _;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::artifact::{
    self, fnv1a64_hex, get_arr, get_f64, get_str, get_u64_str, get_uint, get_usize_arr, Artifact,
    Diffable, PerfCell, Schema,
};
use crate::err;
use crate::error::Result;
use crate::jsonio::{arr, num, obj, s, Json};

use super::{CellResult, SweepResults};

/// Schema tag embedded in every artifact (the rendered form of
/// [`artifact::SWEEP_RECORD`]), bumped on breaking layout changes so
/// `sweep diff` can reject mismatched files with a clear message
/// instead of a field error.
pub const RECORD_SCHEMA: &str = "stannic.sweep.record.v1";

/// One persisted sweep cell: the full scenario key, the deterministic
/// outcome (digest + quality metrics), and the measured wall time.
#[derive(Debug, Clone, PartialEq)]
pub struct CellRecord {
    pub engine: String,
    pub workload: String,
    pub machines: usize,
    pub depth: usize,
    pub alpha: f32,
    pub precision: String,
    pub jobs: usize,
    pub seed: u64,
    /// Canonical fault key ([`crate::faults::FaultSpec::render`]); empty
    /// for clean cells. Part of the scenario key and digest only when
    /// non-empty, so clean artifacts stay byte-identical to pre-fault
    /// recordings (and `v1` files without the field keep parsing).
    pub fault: String,
    /// Interconnect width in bytes/tick; 0 for unconstrained cells.
    /// Same compat discipline as the fault key: part of the scenario
    /// key, digest and rendered JSON only when non-zero, so
    /// unconstrained artifacts stay byte-identical to pre-link
    /// recordings.
    pub link_width: u64,
    /// FNV-1a digest of the deterministic outcome; equal scenarios with
    /// different digests mean scheduling semantics changed.
    pub digest: String,
    pub jobs_per_machine: Vec<usize>,
    pub avg_latency: f64,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub ticks: u64,
    pub stalls: u64,
    pub accel_cycles: u64,
    pub utilization: f64,
    pub fairness: f64,
    pub load_cv: f64,
    pub throughput: f64,
    /// Host wall-clock for the cell, ns (the only non-deterministic field).
    pub wall_ns: u64,
}

impl CellRecord {
    pub fn from_result(r: &CellResult) -> CellRecord {
        let mut rec = CellRecord {
            engine: r.cell.engine.name().to_string(),
            workload: r.cell.workload.clone(),
            machines: r.cell.machines,
            depth: r.cell.depth,
            alpha: r.cell.alpha,
            precision: r.cell.precision.name().to_string(),
            jobs: r.cell.jobs,
            seed: r.cell.seed,
            fault: r.cell.fault.clone(),
            link_width: r.cell.link_width,
            digest: String::new(),
            jobs_per_machine: r.metrics.jobs_per_machine.clone(),
            avg_latency: r.metrics.avg_latency,
            p50: r.p50,
            p95: r.p95,
            p99: r.p99,
            ticks: r.ticks,
            stalls: r.stalls,
            accel_cycles: r.accel_cycles,
            utilization: r.utilization,
            fairness: r.metrics.fairness,
            load_cv: r.metrics.load_balance_cv,
            throughput: r.metrics.throughput,
            wall_ns: r.wall_ns,
        };
        rec.digest = rec.compute_digest();
        rec
    }

    /// Scenario key: everything that must match for two cells (from two
    /// artifacts) to be the same measurement.
    pub fn key(&self) -> String {
        let mut key = format!(
            "{}|{}|m{}|d{}|a{:.4}|{}|j{}|s{}",
            self.engine,
            self.workload,
            self.machines,
            self.depth,
            self.alpha,
            self.precision,
            self.jobs,
            self.seed
        );
        // the fault key is scenario identity: a faulted cell must never
        // be diffed against the clean cell it was derived from
        if !self.fault.is_empty() {
            let _ = write!(key, "|f:{}", self.fault);
        }
        // the link width is scenario identity too: a constrained cell
        // must never be diffed against its unconstrained twin
        if self.link_width > 0 {
            let _ = write!(key, "|lw:{}", self.link_width);
        }
        key
    }

    /// Digest of the deterministic outcome. Every input is persisted, so
    /// a parsed record recomputes the identical value (f64 `Display`
    /// round-trips exactly).
    pub fn compute_digest(&self) -> String {
        let mut canon = String::new();
        let _ = write!(
            canon,
            "{:?}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}",
            self.jobs_per_machine,
            self.ticks,
            self.stalls,
            self.p50,
            self.p95,
            self.p99,
            self.accel_cycles,
            self.avg_latency,
            self.utilization,
            self.fairness,
            self.throughput
        );
        if !self.fault.is_empty() {
            let _ = write!(canon, "|{}", self.fault);
        }
        if self.link_width > 0 {
            let _ = write!(canon, "|lw:{}", self.link_width);
        }
        fnv1a64_hex(canon.as_bytes())
    }

    /// Scheduling throughput: jobs scheduled per wall-clock second.
    pub fn jobs_per_sec(&self) -> f64 {
        artifact::jobs_per_sec(self.jobs, self.wall_ns)
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("engine", s(self.engine.clone())),
            ("workload", s(self.workload.clone())),
            ("machines", num(self.machines as f64)),
            ("depth", num(self.depth as f64)),
            ("alpha", num(f64::from(self.alpha))),
            ("precision", s(self.precision.clone())),
            ("jobs", num(self.jobs as f64)),
            // u64-exact fields go through strings: jsonio numbers are f64
            ("seed", s(self.seed.to_string())),
            ("digest", s(self.digest.clone())),
            (
                "jobs_per_machine",
                arr(self.jobs_per_machine.iter().map(|&c| num(c as f64)).collect()),
            ),
            ("avg_latency", num(self.avg_latency)),
            ("p50", num(self.p50 as f64)),
            ("p95", num(self.p95 as f64)),
            ("p99", num(self.p99 as f64)),
            ("ticks", num(self.ticks as f64)),
            ("stalls", num(self.stalls as f64)),
            ("accel_cycles", num(self.accel_cycles as f64)),
            ("utilization", num(self.utilization)),
            ("fairness", num(self.fairness)),
            ("load_cv", num(self.load_cv)),
            ("throughput", num(self.throughput)),
            ("wall_ns", s(self.wall_ns.to_string())),
            ("jobs_per_sec", num(self.jobs_per_sec())),
        ];
        // only faulted cells carry the field: clean artifacts render
        // byte-identically to pre-fault versions of this schema
        if !self.fault.is_empty() {
            fields.push(("fault", s(self.fault.clone())));
        }
        // only link-constrained cells carry the width, same discipline
        if self.link_width > 0 {
            fields.push(("link_width", num(self.link_width as f64)));
        }
        obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<CellRecord> {
        let rec = CellRecord {
            engine: get_str(j, "engine")?,
            workload: get_str(j, "workload")?,
            machines: get_uint(j, "machines")? as usize,
            depth: get_uint(j, "depth")? as usize,
            alpha: get_f64(j, "alpha")? as f32,
            precision: get_str(j, "precision")?,
            jobs: get_uint(j, "jobs")? as usize,
            seed: get_u64_str(j, "seed")?,
            fault: get_str(j, "fault").unwrap_or_default(),
            link_width: get_uint(j, "link_width").unwrap_or(0),
            digest: get_str(j, "digest")?,
            jobs_per_machine: get_usize_arr(j, "jobs_per_machine")?,
            avg_latency: get_f64(j, "avg_latency")?,
            p50: get_uint(j, "p50")?,
            p95: get_uint(j, "p95")?,
            p99: get_uint(j, "p99")?,
            ticks: get_uint(j, "ticks")?,
            stalls: get_uint(j, "stalls")?,
            accel_cycles: get_uint(j, "accel_cycles")?,
            utilization: get_f64(j, "utilization")?,
            fairness: get_f64(j, "fairness")?,
            load_cv: get_f64(j, "load_cv")?,
            throughput: get_f64(j, "throughput")?,
            wall_ns: get_u64_str(j, "wall_ns")?,
        };
        // Every digest input is persisted and round-trips exactly (f64
        // `Display` is shortest-round-trip), so a stored digest that
        // disagrees with the recomputation can only mean the artifact
        // was hand-edited — reject it before the parity gate trusts it.
        let expected = rec.compute_digest();
        if rec.digest != expected {
            return Err(err!(
                "cell {}: digest '{}' does not match the cell's persisted \
                 outcome (expected '{expected}') — artifact was hand-edited",
                rec.key(),
                rec.digest
            ));
        }
        Ok(rec)
    }
}

/// A persisted sweep: label + per-cell records, serializable to/from the
/// `BENCH_<label>.json` artifact format.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepRecord {
    pub label: String,
    /// Unix seconds at record time (0 when the clock is unavailable).
    pub created_unix: u64,
    /// Worker threads the sweep ran on (informational).
    pub threads: usize,
    pub cells: Vec<CellRecord>,
}

impl SweepRecord {
    pub fn from_results(label: &str, results: &SweepResults) -> SweepRecord {
        SweepRecord {
            label: label.to_string(),
            created_unix: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
            threads: results.threads,
            cells: results.cells.iter().map(CellRecord::from_result).collect(),
        }
    }
}

impl Artifact for SweepRecord {
    const SCHEMA: Schema = artifact::SWEEP_RECORD;

    fn to_json(&self) -> Json {
        obj(vec![
            ("schema", s(Self::SCHEMA.tag())),
            ("label", s(self.label.clone())),
            ("created_unix", s(self.created_unix.to_string())),
            ("threads", num(self.threads as f64)),
            ("cells", arr(self.cells.iter().map(CellRecord::to_json).collect())),
        ])
    }

    fn from_json(j: &Json) -> Result<SweepRecord> {
        Self::SCHEMA.check(j)?;
        let cells = get_arr(j, "cells")?
            .iter()
            .map(CellRecord::from_json)
            .collect::<Result<Vec<CellRecord>>>()?;
        Ok(SweepRecord {
            label: get_str(j, "label")?,
            created_unix: get_u64_str(j, "created_unix")?,
            threads: get_uint(j, "threads")? as usize,
            cells,
        })
    }
}

impl Diffable for SweepRecord {
    const KIND: &'static str = "sweep";
    const UNIT: &'static str = "jobs/s";

    fn label(&self) -> &str {
        &self.label
    }

    /// One cell per grid cell: matched on the scenario key,
    /// parity-gated on the schedule digest, perf-gated on jobs/sec
    /// (wall-clock derived, so marked noisy: the grid's median ratio
    /// absorbs host-speed differences).
    fn cells(&self) -> Vec<PerfCell> {
        self.cells
            .iter()
            .map(|c| {
                PerfCell::higher(c.key(), c.jobs_per_sec())
                    .with_ident(c.digest.clone())
                    .noisy()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{run_sweep, SweepConfig};
    use super::*;
    use crate::artifact::{diff_records, CellVerdict, DiffOpts};
    use crate::engine::EngineId;
    use crate::quant::Precision;
    use crate::workload::WorkloadSpec;

    fn small_record() -> SweepRecord {
        let cfg = SweepConfig {
            engines: vec![EngineId::Sos, EngineId::Sosc, EngineId::Simd],
            workloads: vec![("even".to_string(), WorkloadSpec::even())],
            machine_counts: vec![3],
            alphas: vec![0.5, 0.75],
            precisions: vec![Precision::Int8],
            depth: 6,
            jobs: 30,
            seed: 11,
            threads: 2,
            faults: Vec::new(),
            link_widths: Vec::new(),
        };
        SweepRecord::from_results("test", &run_sweep(&cfg))
    }

    #[test]
    fn faulted_cells_round_trip_and_never_pair_with_clean() {
        // clean artifacts carry no fault field at all
        let clean = small_record();
        assert!(!clean.render().contains("\"fault\""));

        let cfg = SweepConfig {
            engines: vec![EngineId::Sos],
            workloads: vec![("even".to_string(), WorkloadSpec::even())],
            machine_counts: vec![3],
            alphas: vec![0.5],
            precisions: vec![Precision::Int8],
            depth: 6,
            jobs: 30,
            seed: 11,
            threads: 1,
            faults: vec!["storm=2@8,seed=3".to_string()],
            link_widths: Vec::new(),
        };
        let rec = SweepRecord::from_results("test", &run_sweep(&cfg));
        assert_eq!(rec.cells.len(), 2, "one clean + one faulted cell");
        let (c, f) = (&rec.cells[0], &rec.cells[1]);
        assert!(c.fault.is_empty() && f.fault == "storm=2@8,seed=3");
        // same scenario otherwise, yet the keys (and digests) diverge:
        // diff can never pair the faulted cell with the clean one
        assert_ne!(c.key(), f.key());
        assert!(f.key().ends_with("|f:storm=2@8,seed=3"));
        assert_ne!(c.digest, f.digest);
        // the fault key survives the artifact round trip digest-checked
        let back = SweepRecord::parse(&rec.render()).unwrap();
        assert_eq!(rec, back);
        assert_eq!(back.cells[1].fault, "storm=2@8,seed=3");
    }

    #[test]
    fn link_cells_round_trip_and_never_pair_with_unconstrained() {
        // unconstrained artifacts carry no link field at all
        let clean = small_record();
        assert!(!clean.render().contains("link_width"));

        let cfg = SweepConfig {
            engines: vec![EngineId::Sos],
            workloads: vec![("even".to_string(), WorkloadSpec::even())],
            machine_counts: vec![3],
            alphas: vec![0.5],
            precisions: vec![Precision::Int8],
            depth: 6,
            jobs: 30,
            seed: 11,
            threads: 1,
            faults: Vec::new(),
            link_widths: vec![4],
        };
        let rec = SweepRecord::from_results("test", &run_sweep(&cfg));
        assert_eq!(rec.cells.len(), 2, "one clean + one constrained cell");
        let (c, l) = (&rec.cells[0], &rec.cells[1]);
        assert_eq!((c.link_width, l.link_width), (0, 4));
        // same scenario otherwise, yet the keys (and digests) diverge:
        // diff can never pair the constrained cell with the clean one
        assert_ne!(c.key(), l.key());
        assert!(l.key().ends_with("|lw:4"));
        assert_ne!(c.digest, l.digest);
        // the width survives the artifact round trip digest-checked
        let back = SweepRecord::parse(&rec.render()).unwrap();
        assert_eq!(rec, back);
        assert_eq!(back.cells[1].link_width, 4);
    }

    #[test]
    fn record_schema_is_the_registry_instance() {
        assert_eq!(RECORD_SCHEMA, artifact::SWEEP_RECORD.tag());
        assert_eq!(RECORD_SCHEMA, SweepRecord::SCHEMA.tag());
    }

    #[test]
    fn record_round_trips_through_jsonio() {
        let rec = small_record();
        assert_eq!(rec.cells.len(), 6);
        let text = rec.render();
        let back = SweepRecord::parse(&text).expect("parse own artifact");
        assert_eq!(rec, back, "parse(render(r)) == r");
        // serialize -> parse -> serialize is a fixed point
        assert_eq!(text, back.render());
    }

    #[test]
    fn digest_recomputes_from_persisted_fields() {
        let rec = small_record();
        let back = SweepRecord::parse(&rec.render()).unwrap();
        for c in &back.cells {
            assert_eq!(c.digest, c.compute_digest(), "digest stable across round trip");
        }
    }

    #[test]
    fn stale_digest_is_rejected_at_parse_time() {
        // A hand-edited artifact whose deterministic outcome changed but
        // whose digest was left stale must fail to parse — the parity
        // gate trusts stored digests.
        let rec = small_record();
        let ticks = format!("\"ticks\":{}", rec.cells[0].ticks);
        let tampered = rec
            .render()
            .replacen(&ticks, &format!("\"ticks\":{}", rec.cells[0].ticks + 1), 1);
        let err = SweepRecord::parse(&tampered).unwrap_err();
        assert!(
            format!("{err:#}").contains("does not match"),
            "stale digest must be named: {err:#}"
        );
    }

    #[test]
    fn rejects_wrong_schema_and_garbage() {
        assert!(SweepRecord::parse("{}").is_err());
        assert!(SweepRecord::parse("not json").is_err());
        let mut rec = small_record();
        rec.label = "x".into();
        let text = rec.render().replace(RECORD_SCHEMA, "stannic.sweep.record.v0");
        assert!(SweepRecord::parse(&text).is_err());
    }

    #[test]
    fn rejects_negative_and_fractional_integer_fields() {
        let rec = small_record();
        let machines = format!("\"machines\":{}", rec.cells[0].machines);
        let text = rec.render().replacen(&machines, "\"machines\":-3", 1);
        assert!(
            SweepRecord::parse(&text).is_err(),
            "negative machines must be rejected at parse time"
        );
        let ticks = format!("\"ticks\":{}", rec.cells[0].ticks);
        let text = rec
            .render()
            .replacen(&ticks, &format!("\"ticks\":{}.5", rec.cells[0].ticks), 1);
        assert!(
            SweepRecord::parse(&text).is_err(),
            "fractional ticks must be rejected at parse time"
        );
    }

    #[test]
    fn diff_identical_records_is_ok() {
        let rec = small_record();
        let report = diff_records(&rec, &rec, &DiffOpts::default());
        assert_eq!(report.cells.len(), 6);
        assert!(report.ok(), "identical records must pass:\n{}", report.render());
        assert_eq!(report.regressions(), 0);
        assert_eq!(report.parity_breaks(), 0);
        assert!((report.shift - 1.0).abs() < 1e-9);
        assert!(report.render().starts_with("sweep diff: test -> test"));
    }

    #[test]
    fn diff_flags_injected_regression() {
        let old = small_record();
        let mut new = old.clone();
        new.cells[0].wall_ns *= 10; // one cell 10x slower
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert_eq!(report.regressions(), 1, "{}", report.render());
        assert!(!report.ok());
        // the regressed cell is the tampered one
        let bad = report
            .cells
            .iter()
            .find(|c| c.verdict == CellVerdict::Regression)
            .unwrap();
        assert_eq!(bad.key, old.cells[0].key());
        assert!(bad.ratio < 0.2);
    }

    #[test]
    fn diff_flags_improvement_without_failing() {
        let old = small_record();
        let mut new = old.clone();
        new.cells[2].wall_ns = (new.cells[2].wall_ns / 10).max(1); // ~10x faster
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert_eq!(report.improvements(), 1, "{}", report.render());
        assert_eq!(report.regressions(), 0, "{}", report.render());
        assert!(report.ok(), "an improvement must not fail the gate");
    }

    #[test]
    fn diff_reports_uniform_slowdown_as_global_shift() {
        let old = small_record();
        let mut new = old.clone();
        for c in &mut new.cells {
            c.wall_ns *= 3; // whole grid 3x slower
        }
        // advisory by default: across hosts a uniform shift is
        // indistinguishable from a slower machine
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert!(report.global_regression, "{}", report.render());
        assert!(report.ok(), "shift alone must not gate by default");
        // normalization keeps per-cell verdicts clean: it's the host/
        // whole-grid shift that moved, not one cell
        assert_eq!(report.regressions(), 0);
        // same-host A/B runs opt into gating on the shift
        let strict = DiffOpts {
            fail_on_shift: true,
            ..DiffOpts::default()
        };
        let report = diff_records(&old, &new, &strict);
        assert!(!report.ok(), "{}", report.render());
    }

    #[test]
    fn tiny_grids_compare_raw_ratios() {
        // With one matched cell the median ratio IS that cell, so
        // normalization would cancel any regression — the guard must
        // fall back to raw ratios and still flag it.
        let mut old = small_record();
        old.cells.truncate(1);
        let mut new = old.clone();
        new.cells[0].wall_ns *= 10;
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert_eq!(report.regressions(), 1, "{}", report.render());
        assert!(!report.ok());
    }

    #[test]
    fn diff_flags_unmeasured_cells() {
        // run_cell floors wall_ns at 1, so a zero can only come from a
        // hand-edited or corrupt artifact — it must fail the gate, not
        // silently pass as "unchanged".
        let old = small_record();
        let mut new = old.clone();
        new.cells[0].wall_ns = 0;
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert_eq!(report.unmeasured(), 1, "{}", report.render());
        assert_eq!(report.regressions(), 0);
        assert!(!report.ok());
    }

    #[test]
    fn diff_flags_parity_break_on_digest_change() {
        let old = small_record();
        let mut new = old.clone();
        new.cells[1].ticks += 1;
        new.cells[1].digest = new.cells[1].compute_digest();
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert_eq!(report.parity_breaks(), 1, "{}", report.render());
        assert!(!report.ok());
    }

    #[test]
    fn diff_fails_on_missing_baseline_cells() {
        let old = small_record();
        let mut new = old.clone();
        new.cells.pop();
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert_eq!(report.only_in_old.len(), 1);
        assert!(!report.ok());
        // the reverse direction (grid grew) is fine
        let report = diff_records(&new, &old, &DiffOpts::default());
        assert_eq!(report.only_in_new.len(), 1);
        assert!(report.ok(), "{}", report.render());
    }

    #[test]
    fn threshold_is_respected() {
        let old = small_record();
        let mut new = old.clone();
        // ~11% slower on one cell: inside the default 25% budget
        new.cells[0].wall_ns += new.cells[0].wall_ns / 9;
        let report = diff_records(&old, &new, &DiffOpts::default());
        assert!(report.ok(), "{}", report.render());
        // but outside a 5% budget
        let strict = DiffOpts {
            threshold: 0.05,
            ..DiffOpts::default()
        };
        let report = diff_records(&old, &new, &strict);
        assert_eq!(report.regressions(), 1, "{}", report.render());
    }
}
