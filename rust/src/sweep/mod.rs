//! Parallel scenario-sweep subsystem — the repo's first *scale* layer.
//!
//! STOMP-style scheduler evaluation (arXiv:2007.14371) establishes a
//! scheduler's value by sweeping it across many synthetic workloads;
//! Agon (arXiv:2109.00665) adds that schedulers must hold up on large
//! heterogeneous systems. This module turns both into infrastructure:
//! a grid of `WorkloadSpec × MachinePark size × alpha × Precision`
//! cells is fanned across every software/simulator engine in the repo
//! (the [`crate::engine::EngineId::SOFTWARE`] set: golden SOS, naive
//! SOSC, lane-vectorised SIMD, and the Stannic and Hercules
//! cycle-accurate simulators) by a self-scheduling pool of worker
//! threads that pull cells from a shared `Mutex<VecDeque>` work queue
//! (fast workers automatically absorb more cells).
//!
//! Determinism is a hard requirement (and property-tested): every cell
//! is seeded, runs its engine single-threaded, and writes its result
//! into a slot indexed by cell id — so the aggregate output is
//! byte-identical whether the sweep ran on 1 or 8 workers. The XLA
//! engine is excluded: it needs compiled artifacts and a PJRT runtime,
//! neither of which exists offline.

pub mod record;

pub use record::{CellRecord, SweepRecord, RECORD_SCHEMA};
// The diff machinery lives in the shared artifact layer now; these
// re-exports keep `stannic::sweep::diff_records(...)` call sites valid.
pub use crate::artifact::{diff_records, CellVerdict, DiffOpts, DiffReport};

use std::collections::{HashMap, VecDeque};
use std::sync::Mutex;
use std::time::Instant;

use crate::bench::Table;
use crate::coordinator::{LinkModel, PcieModel, TimedLink};
use crate::core::{Job, JobId, MachinePark};
use crate::engine::EngineId;
use crate::faults::FaultSpec;
use crate::metrics::{Histogram, MetricSet, ScheduleMetrics};
use crate::quant::Precision;
use crate::workload::{generate_trace, WorkloadSpec};

/// One cell of the sweep grid: a fully specified scenario + engine.
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// Dense grid index; also the result slot, which is what makes the
    /// aggregate output independent of worker scheduling.
    pub id: usize,
    pub workload: String,
    pub spec: WorkloadSpec,
    pub machines: usize,
    pub depth: usize,
    pub alpha: f32,
    pub precision: Precision,
    pub engine: EngineId,
    pub jobs: usize,
    pub seed: u64,
    /// Canonical fault key ([`FaultSpec::render`]); empty = clean cell.
    /// Faulted cells run the golden engine only (the fault layer lives
    /// there) and never pair with clean cells in parity or diff.
    pub fault: String,
    /// Interconnect width in bytes/tick; 0 = unbounded (the historical
    /// cell, bit-for-bit). Constrained cells run the golden engine only
    /// behind a [`TimedLink`] admission gate and never pair with
    /// unconstrained cells in parity or diff.
    pub link_width: u64,
}

/// Measured outcome of one cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub cell: SweepCell,
    pub metrics: ScheduleMetrics,
    /// Queue-latency (arrival -> release) percentiles in ticks.
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    /// Scheduler ticks consumed until drain.
    pub ticks: u64,
    /// Stalled iterations (arrival waited while every V_i was full).
    pub stalls: u64,
    /// Simulated accelerator cycles (0 for pure-software engines).
    pub accel_cycles: u64,
    /// Mean fraction of machines holding in-flight work per tick.
    pub utilization: f64,
    /// Host wall-clock spent running this cell, in nanoseconds. The only
    /// non-deterministic field: excluded from `render()` (which must be
    /// byte-identical for any worker count) but persisted by
    /// [`record::SweepRecord`] as the perf trajectory across commits.
    pub wall_ns: u64,
}

/// Sweep grid configuration.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Engines to fan the grid across — artifact-free backends only
    /// (the CLI rejects `xla`, which needs a PJRT runtime).
    pub engines: Vec<EngineId>,
    pub workloads: Vec<(String, WorkloadSpec)>,
    pub machine_counts: Vec<usize>,
    pub alphas: Vec<f32>,
    pub precisions: Vec<Precision>,
    pub depth: usize,
    pub jobs: usize,
    pub seed: u64,
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// Fault-scenario axis: canonical [`FaultSpec`] strings. For each
    /// scenario the grid gains one golden-engine cell per clean
    /// scenario, *appended after* every clean cell so clean ids (and
    /// therefore clean artifacts) are unchanged by the axis.
    pub faults: Vec<String>,
    /// Interconnect-width axis (bytes/tick): for each width the grid
    /// gains one golden-engine cell per clean scenario, appended after
    /// the fault axis — clean and faulted ids are unchanged, and an
    /// empty axis (the default) leaves the grid bit-identical to
    /// pre-link sweeps.
    pub link_widths: Vec<u64>,
}

impl Default for SweepConfig {
    /// The default grid: 3 workload mixes × 2 park sizes × 2 alphas ×
    /// INT8 across all 5 engines = 60 cells.
    fn default() -> Self {
        SweepConfig {
            engines: EngineId::SOFTWARE.to_vec(),
            workloads: vec![
                ("even".to_string(), WorkloadSpec::even()),
                ("memory".to_string(), WorkloadSpec::memory_skewed()),
                ("compute".to_string(), WorkloadSpec::compute_skewed()),
            ],
            machine_counts: vec![5, 10],
            alphas: vec![0.25, 0.75],
            precisions: vec![Precision::Int8],
            depth: 10,
            jobs: 200,
            seed: 42,
            threads: 0,
            faults: Vec::new(),
            link_widths: Vec::new(),
        }
    }
}

impl SweepConfig {
    /// A reduced grid for smoke runs: one park size, fewer jobs
    /// (3 workloads × 2 alphas × 5 engines = 30 clean cells), plus one
    /// chaos scenario (down + straggler + storm) fanned across the
    /// clean scenarios on the golden engine — 6 faulted cells — and a
    /// narrow-interconnect axis (4 bytes/tick) — 6 link cells.
    pub fn quick() -> Self {
        SweepConfig {
            machine_counts: vec![5],
            jobs: 60,
            faults: vec!["down=1@40+30,slow=0@20+40x4,storm=6@60,seed=7".to_string()],
            link_widths: vec![4],
            ..Self::default()
        }
    }

    /// The Agon-scale grid (arXiv:2109.00665): competitive schedulers
    /// only pull away from greedy ones on parks of ~140 machines, far
    /// beyond the default grid. Three park sizes up to 140, the even mix
    /// plus the two stress mixes (bursty arrivals, heavy-tailed service
    /// times), single alpha, all engines: 3 × 3 × 1 × 1 × 5 = 45 clean
    /// cells, plus a rack-scale correlated-failure axis (a 5-machine
    /// rack drops mid-run) appended as one golden-engine cell per clean
    /// scenario — clean ids and artifacts are unchanged by the axis.
    /// The rack sits at machines 30..34 so the same canonical key is
    /// valid for every park size in the grid. Selected by
    /// `sweep --scale`; deliberately not the CI default.
    pub fn at_scale() -> Self {
        SweepConfig {
            workloads: vec![
                ("even".to_string(), WorkloadSpec::even()),
                ("bursty".to_string(), WorkloadSpec::bursty()),
                ("heavy".to_string(), WorkloadSpec::heavy_tailed()),
            ],
            machine_counts: vec![35, 70, 140],
            alphas: vec![0.5],
            jobs: 400,
            faults: vec!["down=30..34@60+40,seed=11".to_string()],
            ..Self::default()
        }
    }

    /// Expand the grid into cells, id-ordered: every clean cell first
    /// (ids identical to a fault-free grid), then the fault axis —
    /// golden-engine cells only, one per (scenario × fault) — then the
    /// interconnect-width axis, golden-engine cells only again, one per
    /// (scenario × width).
    pub fn cells(&self) -> Vec<SweepCell> {
        let mut out = Vec::new();
        let push = |out: &mut Vec<SweepCell>, engines: &[EngineId], fault: &str, width: u64| {
            for (name, spec) in &self.workloads {
                for &machines in &self.machine_counts {
                    for &alpha in &self.alphas {
                        for &precision in &self.precisions {
                            for &engine in engines {
                                out.push(SweepCell {
                                    id: out.len(),
                                    workload: name.clone(),
                                    spec: spec.clone(),
                                    machines,
                                    depth: self.depth,
                                    alpha,
                                    precision,
                                    engine,
                                    jobs: self.jobs,
                                    seed: self.seed,
                                    fault: fault.to_string(),
                                    link_width: width,
                                });
                            }
                        }
                    }
                }
            }
        };
        push(&mut out, &self.engines, "", 0);
        for fault in &self.faults {
            push(&mut out, &[EngineId::Sos], fault, 0);
        }
        for &width in &self.link_widths {
            push(&mut out, &[EngineId::Sos], "", width);
        }
        out
    }
}

/// Run one cell to completion (single-threaded; deterministic except for
/// the measured `wall_ns`).
///
/// Tickless: engines that expose an event horizon
/// ([`crate::scheduler::Horizon::At`], today the golden `sos` engine)
/// have their event-free windows jumped instead of ticked — the
/// virtual-tick counter, metrics and digests are bit-identical to
/// per-tick driving (skipped ticks are exactly the ones that produce
/// empty outcomes, and the per-tick utilization samples are
/// bulk-accounted since occupancy cannot change inside a jumped
/// window). [`crate::scheduler::Horizon::Unknown`] engines run
/// per-tick, which is the historical loop unchanged.
///
/// Link-constrained cells (`link_width > 0`) put a [`TimedLink`] in
/// front of the engine: arrivals park in an admission queue until the
/// wire is free, one ticket is issued per engine round trip, pending
/// completion ticks merge into the jump horizon, and the cell only
/// drains once the wire does. Width 0 constructs no link and is the
/// historical loop, bit for bit.
pub fn run_cell(cell: &SweepCell) -> CellResult {
    let wall_started = Instant::now();
    // cycled(5) is exactly the paper M1-M5 park, so one constructor
    // covers every grid size.
    let park = MachinePark::cycled(cell.machines);
    let trace = generate_trace(&cell.spec, &park, cell.jobs, cell.seed);
    let mut engine = cell
        .engine
        .build(cell.machines, cell.depth, cell.alpha, cell.precision)
        .expect("sweep engines are artifact-free (xla is rejected before the sweep runs)");
    if !cell.fault.is_empty() {
        let plan = FaultSpec::parse(&cell.fault)
            .and_then(|s| s.plan(cell.machines))
            .expect("faulted cells carry a canonical, park-validated fault key");
        engine
            .install_faults(plan)
            .expect("faulted cells run the golden engine");
    }
    let pcie = PcieModel::default();
    let mut link = (cell.link_width > 0)
        .then(|| TimedLink::new(LinkModel::with_width(cell.link_width)));
    let mut pending: VecDeque<Job> = VecDeque::new();

    let mut metrics = MetricSet::new(cell.machines, 64);
    let mut hist = Histogram::new();
    let mut arrivals: HashMap<JobId, u64> = HashMap::with_capacity(cell.jobs);
    let mut in_flight = vec![0usize; cell.machines];
    let mut busy_machine_ticks = 0u64;
    let mut stalls = 0u64;
    let mut events = trace.events().iter().peekable();
    let mut tick = 0u64;

    loop {
        let next_arrival = events.peek().map(|e| e.tick);
        let mut horizon = engine.horizon();
        if let Some(l) = link.as_ref() {
            horizon = horizon.merge(crate::scheduler::Horizon::of(l.next_completion()));
        }
        // parked arrivals retry admission every tick: a jump may never
        // skip a tick on which the wire could have freed up
        let target = if pending.is_empty() {
            horizon.jump_target(next_arrival, tick)
        } else {
            tick + 1
        };
        if target > tick + 1 {
            // event-free window: machine occupancy cannot change, so the
            // per-tick utilization samples are all equal — bulk them
            let busy = in_flight.iter().filter(|&&n| n > 0).count() as u64;
            busy_machine_ticks += (target - 1 - tick) * busy;
            if let Some(l) = link.as_mut() {
                l.bulk_occupancy(target - 1 - tick);
            }
            engine.advance_to(target - 1);
        }
        tick = target;
        if let Some(l) = link.as_mut() {
            l.begin_tick(tick);
        }
        while events.peek().is_some_and(|e| e.tick <= tick) {
            let e = events.next().expect("peeked");
            if let Some(job) = &e.job {
                arrivals.insert(job.id, job.arrival);
                match link.as_ref() {
                    // the timed link gates admission: arrivals park in
                    // order and enter the engine on a free wire only
                    Some(_) => pending.push_back(job.clone()),
                    None => engine.submit(job.clone()),
                }
            }
        }
        if let Some(l) = link.as_mut() {
            if !pending.is_empty() {
                match l.try_acquire(tick) {
                    Ok(()) => {
                        while let Some(job) = pending.pop_front() {
                            engine.submit(job);
                        }
                    }
                    Err(why) => l.note_admission_stall(why),
                }
            }
        }
        let out = engine
            .tick()
            .expect("software/simulator engines cannot fail");
        if out.stalled {
            stalls += 1;
        }
        // co_assigned carries the portfolio meta-engine's same-tick
        // secondary dispatches (work-stealing moves land several jobs in
        // one tick); plain engines leave it empty, so chaining is a no-op
        // for every historical cell
        for a in out.assigned.iter().chain(&out.co_assigned) {
            metrics.record_assignment(a.machine, tick);
            in_flight[a.machine] += 1;
        }
        // fault traffic: storm jobs need an arrival for the latency
        // accounting; evicted slots leave their machine until reassigned
        for job in &out.injected {
            arrivals.insert(job.id, job.arrival);
        }
        for (_, machine) in &out.evicted {
            in_flight[*machine] -= 1;
        }
        for (id, machine) in &out.released {
            let arrived = arrivals.remove(id).expect("released job has an arrival");
            metrics.record_latency(*machine, arrived, tick);
            hist.record(tick - arrived);
            in_flight[*machine] -= 1;
        }
        if let Some(l) = link.as_mut() {
            // one round trip per active engine tick, billed with the
            // PCIe byte model (mirrors the serve loop's dispatch path)
            if out.assigned.is_some() || !out.released.is_empty() {
                let bytes =
                    pcie.request_bytes(cell.machines) + pcie.response_bytes(out.released.len());
                l.issue(tick, bytes);
            }
            l.end_tick();
        }
        busy_machine_ticks += in_flight.iter().filter(|&&n| n > 0).count() as u64;
        // a constrained cell drains only once the wire does: parked
        // arrivals admitted and every issued ticket retired
        let link_drained = link.as_ref().map_or(true, |l| l.is_drained());
        if engine.is_idle() && events.peek().is_none() && pending.is_empty() && link_drained {
            break;
        }
        assert!(tick < 50_000_000, "sweep cell {} did not drain", cell.id);
    }

    CellResult {
        cell: cell.clone(),
        metrics: metrics.finish(),
        p50: hist.p50(),
        p95: hist.p95(),
        p99: hist.p99(),
        ticks: tick,
        stalls,
        accel_cycles: engine.cycles(),
        utilization: busy_machine_ticks as f64 / (cell.machines as u64 * tick) as f64,
        // floor of 1 so a coarse clock can never record an unmeasurable
        // (zero-throughput) cell into a perf artifact
        wall_ns: wall_started.elapsed().as_nanos().max(1) as u64,
    }
}

/// All cell results of one sweep, id-ordered.
#[derive(Debug, Clone)]
pub struct SweepResults {
    pub cells: Vec<CellResult>,
    /// Worker threads actually used (not part of the rendered output).
    pub threads: usize,
}

/// Run the whole grid across a worker pool. Workers steal cells from a
/// shared deque; each result lands in its cell's slot, so the output is
/// identical for any thread count.
pub fn run_sweep(cfg: &SweepConfig) -> SweepResults {
    // Fail on the caller's thread with a clear message rather than
    // poisoning a pool worker: the XLA engine cannot construct offline.
    assert!(
        cfg.engines.iter().all(|e| e.is_software()),
        "sweep engines must be artifact-free (xla needs a PJRT runtime; drive it via serve)"
    );
    let cells = cfg.cells();
    let n = cells.len();
    let threads = if cfg.threads == 0 {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
    } else {
        cfg.threads
    }
    .clamp(1, n.max(1));

    let queue: Mutex<VecDeque<SweepCell>> = Mutex::new(cells.into_iter().collect());
    let slots: Mutex<Vec<Option<CellResult>>> = Mutex::new((0..n).map(|_| None).collect());

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let next = queue.lock().expect("queue lock").pop_front();
                let Some(cell) = next else {
                    break;
                };
                let id = cell.id;
                let result = run_cell(&cell);
                slots.lock().expect("slot lock")[id] = Some(result);
            });
        }
    });

    let cells: Vec<CellResult> = slots
        .into_inner()
        .expect("no worker panicked")
        .into_iter()
        .map(|r| r.expect("every cell ran exactly once"))
        .collect();
    SweepResults { cells, threads }
}

impl SweepResults {
    /// Every engine implements the *same* algorithm, so cells that share
    /// a scenario must produce identical schedules. Returns the number
    /// of multi-engine scenario groups checked, or the first divergence.
    pub fn check_parity(&self) -> Result<usize, String> {
        // the fault key and the link width are part of the scenario: a
        // faulted or link-constrained cell can never be compared
        // against (or pair with) a clean one
        type ScenarioKey = (String, usize, u32, &'static str, String, u64);
        let mut groups: HashMap<ScenarioKey, &CellResult> = HashMap::new();
        let mut checked = 0usize;
        for r in &self.cells {
            // the portfolio meta-engine races policies and switches
            // mid-run — its schedule *intentionally* diverges from the
            // single-policy group, so it is excluded from parity
            if r.cell.engine == EngineId::Portfolio {
                continue;
            }
            let key = (
                r.cell.workload.clone(),
                r.cell.machines,
                r.cell.alpha.to_bits(),
                r.cell.precision.name(),
                r.cell.fault.clone(),
                r.cell.link_width,
            );
            match groups.get(&key) {
                None => {
                    groups.insert(key, r);
                }
                Some(first) => {
                    checked += 1;
                    if first.metrics.jobs_per_machine != r.metrics.jobs_per_machine {
                        return Err(format!(
                            "schedule divergence in scenario {}/{}m/a{}: {} got {:?}, {} got {:?}",
                            r.cell.workload,
                            r.cell.machines,
                            r.cell.alpha,
                            first.cell.engine.name(),
                            first.metrics.jobs_per_machine,
                            r.cell.engine.name(),
                            r.metrics.jobs_per_machine
                        ));
                    }
                }
            }
        }
        Ok(checked)
    }

    /// Render the per-cell table plus per-engine aggregates. Contains no
    /// wall-clock or thread-count data, so the text is reproducible.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "scenario sweep — {} cells ({} jobs per cell)\n",
            self.cells.len(),
            self.cells.first().map_or(0, |c| c.cell.jobs),
        ));
        let mut t = Table::new(&[
            "cell", "engine", "workload", "M", "alpha", "prec", "avg lat", "p95", "fair",
            "loadCV", "util", "thru", "stall", "cycles",
        ]);
        for r in &self.cells {
            t.row(vec![
                r.cell.id.to_string(),
                r.cell.engine.name().into(),
                r.cell.workload.clone(),
                r.cell.machines.to_string(),
                format!("{:.2}", r.cell.alpha),
                r.cell.precision.name().into(),
                format!("{:.1}", r.metrics.avg_latency),
                r.p95.to_string(),
                format!("{:.3}", r.metrics.fairness),
                format!("{:.3}", r.metrics.load_balance_cv),
                format!("{:.3}", r.utilization),
                format!("{:.3}", r.metrics.throughput),
                r.stalls.to_string(),
                r.accel_cycles.to_string(),
            ]);
        }
        out.push_str(&t.render());

        out.push_str("\naggregates per engine\n");
        let mut t = Table::new(&[
            "engine", "cells", "mean avg lat", "mean util", "mean fair", "total cycles",
        ]);
        // portfolio rides after the parity group: it only appears when
        // the sweep explicitly named it, so clean grids render unchanged
        for engine in EngineId::SOFTWARE.into_iter().chain([EngineId::Portfolio]) {
            let rs: Vec<&CellResult> = self
                .cells
                .iter()
                .filter(|r| {
                    r.cell.engine == engine && r.cell.fault.is_empty() && r.cell.link_width == 0
                })
                .collect();
            if rs.is_empty() {
                continue;
            }
            let n = rs.len() as f64;
            t.row(vec![
                engine.name().into(),
                rs.len().to_string(),
                format!("{:.2}", rs.iter().map(|r| r.metrics.avg_latency).sum::<f64>() / n),
                format!("{:.4}", rs.iter().map(|r| r.utilization).sum::<f64>() / n),
                format!("{:.4}", rs.iter().map(|r| r.metrics.fairness).sum::<f64>() / n),
                rs.iter().map(|r| r.accel_cycles).sum::<u64>().to_string(),
            ]);
        }
        out.push_str(&t.render());

        // fault keys per cell id, only when the sweep had a fault axis —
        // a clean sweep's render stays byte-identical to earlier versions
        let faulted: Vec<&CellResult> = self
            .cells
            .iter()
            .filter(|r| !r.cell.fault.is_empty())
            .collect();
        if !faulted.is_empty() {
            out.push_str("\nfaulted cells (golden engine)\n");
            let mut t = Table::new(&["cell", "workload", "M", "fault"]);
            for r in &faulted {
                t.row(vec![
                    r.cell.id.to_string(),
                    r.cell.workload.clone(),
                    r.cell.machines.to_string(),
                    r.cell.fault.clone(),
                ]);
            }
            out.push_str(&t.render());
        }

        // link widths per cell id, only when the sweep had a link axis —
        // a default sweep's render stays byte-identical to earlier
        // versions
        let constrained: Vec<&CellResult> = self
            .cells
            .iter()
            .filter(|r| r.cell.link_width > 0)
            .collect();
        if !constrained.is_empty() {
            out.push_str("\nlink-constrained cells (golden engine)\n");
            let mut t = Table::new(&["cell", "workload", "M", "link B/tick"]);
            for r in &constrained {
                t.row(vec![
                    r.cell.id.to_string(),
                    r.cell.workload.clone(),
                    r.cell.machines.to_string(),
                    r.cell.link_width.to_string(),
                ]);
            }
            out.push_str(&t.render());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SweepConfig {
        SweepConfig {
            engines: vec![EngineId::Sos, EngineId::StannicSim],
            workloads: vec![("even".to_string(), WorkloadSpec::even())],
            machine_counts: vec![3],
            alphas: vec![0.5],
            precisions: vec![Precision::Int8],
            depth: 6,
            jobs: 40,
            seed: 9,
            threads: 2,
            faults: Vec::new(),
            link_widths: Vec::new(),
        }
    }

    #[test]
    fn default_grid_meets_scale_floor() {
        let cells = SweepConfig::default().cells();
        assert!(cells.len() >= 24, "grid has {} cells", cells.len());
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.id, i, "dense ids");
        }
        assert!(SweepConfig::quick().cells().len() >= 24);
    }

    #[test]
    fn cell_conserves_jobs_and_measures_latency() {
        let cfg = tiny();
        let r = run_cell(&cfg.cells()[0]);
        assert_eq!(r.metrics.total_scheduled, 40);
        assert_eq!(r.metrics.jobs_per_machine.iter().sum::<usize>(), 40);
        assert!(r.p50 <= r.p95 && r.p95 <= r.p99);
        assert!(r.utilization > 0.0 && r.utilization <= 1.0);
        assert!(r.ticks > 0);
        assert!(r.wall_ns > 0, "wall time must be measured for the perf record");
    }

    #[test]
    fn scale_grid_reaches_agon_parks() {
        let cfg = SweepConfig::at_scale();
        assert!(
            cfg.machine_counts.iter().any(|&m| m >= 140),
            "Agon-scale grid must include a 140-machine park"
        );
        assert!(cfg.workloads.iter().any(|(n, _)| n == "bursty"));
        assert!(cfg.workloads.iter().any(|(n, _)| n == "heavy"));
        let cells = cfg.cells();
        assert!(cells.len() >= 24, "scale grid has {} cells", cells.len());
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.id, i, "dense ids");
        }
        // the rack-failure axis: one golden cell per clean scenario,
        // appended after the clean grid so clean ids are unchanged
        let faulted: Vec<&SweepCell> = cells.iter().filter(|c| !c.fault.is_empty()).collect();
        assert_eq!(faulted.len(), 9, "3 workloads x 3 park sizes");
        assert!(faulted.iter().all(|c| c.engine == EngineId::Sos));
        let mut clean_cfg = cfg.clone();
        clean_cfg.faults.clear();
        for (a, b) in clean_cfg.cells().iter().zip(&cells) {
            assert_eq!(a.id, b.id, "clean ids unchanged by the fault axis");
            assert_eq!(a.engine, b.engine);
        }
        // the rack key is canonical and fits every park size in the grid
        for c in &faulted {
            let spec = crate::faults::FaultSpec::parse(&c.fault).unwrap();
            assert_eq!(spec.render(), c.fault);
            assert!(spec.plan(c.machines).is_ok(), "rack fits the {}-park", c.machines);
        }
    }

    #[test]
    fn simulator_cells_report_cycles() {
        let cfg = tiny();
        let results = run_sweep(&cfg);
        let sos = &results.cells[0];
        let sim = &results.cells[1];
        assert_eq!(sos.cell.engine, EngineId::Sos);
        assert_eq!(sim.cell.engine, EngineId::StannicSim);
        assert_eq!(sos.accel_cycles, 0, "software engine has no cycle model");
        assert!(sim.accel_cycles > 0);
    }

    #[test]
    fn tickless_sos_cell_matches_per_tick_engines() {
        // The sos cell is driven with event-horizon jumps; sosc runs the
        // historical per-tick loop. Every deterministic field — virtual
        // tick count, stalls, latency percentiles, utilization — must be
        // bit-identical, proving the jumps are semantically invisible.
        let mut cfg = tiny();
        cfg.engines = vec![EngineId::Sos, EngineId::Sosc];
        let results = run_sweep(&cfg);
        let a = &results.cells[0];
        let b = &results.cells[1];
        assert_eq!(a.cell.engine, EngineId::Sos);
        assert_eq!(b.cell.engine, EngineId::Sosc);
        assert_eq!(a.ticks, b.ticks, "virtual time preserved across the jumps");
        assert_eq!(a.stalls, b.stalls);
        assert_eq!((a.p50, a.p95, a.p99), (b.p50, b.p95, b.p99));
        assert_eq!(a.metrics.jobs_per_machine, b.metrics.jobs_per_machine);
        assert_eq!(a.metrics.avg_latency, b.metrics.avg_latency);
        assert_eq!(a.utilization, b.utilization, "bulk-accounted samples exact");
    }

    #[test]
    fn parity_holds_across_engines() {
        let mut cfg = tiny();
        cfg.engines = EngineId::SOFTWARE.to_vec();
        let results = run_sweep(&cfg);
        assert_eq!(results.check_parity().unwrap(), 4, "4 non-reference engines");
    }

    #[test]
    fn results_are_slot_ordered_regardless_of_threads() {
        let mut cfg = tiny();
        cfg.engines = EngineId::SOFTWARE.to_vec();
        cfg.threads = 1;
        let a = run_sweep(&cfg);
        cfg.threads = 8;
        let b = run_sweep(&cfg);
        assert_eq!(a.render(), b.render());
        for (x, y) in a.cells.iter().zip(&b.cells) {
            assert_eq!(x.cell.id, y.cell.id);
            assert_eq!(x.metrics.jobs_per_machine, y.metrics.jobs_per_machine);
            assert_eq!(x.metrics.avg_latency, y.metrics.avg_latency);
            assert_eq!(x.ticks, y.ticks);
        }
    }

    #[test]
    fn fault_axis_appends_sos_only_cells_after_the_clean_grid() {
        let q = SweepConfig::quick();
        let cells = q.cells();
        let clean: Vec<&SweepCell> = cells
            .iter()
            .filter(|c| c.fault.is_empty() && c.link_width == 0)
            .collect();
        let faulted: Vec<&SweepCell> = cells.iter().filter(|c| !c.fault.is_empty()).collect();
        let linked: Vec<&SweepCell> = cells.iter().filter(|c| c.link_width > 0).collect();
        assert_eq!(clean.len(), 30, "clean quick grid unchanged by the axes");
        assert_eq!(faulted.len(), 6, "one chaos scenario x 6 clean scenarios");
        assert!(faulted.iter().all(|c| c.engine == EngineId::Sos));
        // the link axis rides after the fault axis, golden engine only,
        // never combined with a fault key
        assert_eq!(linked.len(), 6, "one width x 6 clean scenarios");
        assert!(linked.iter().all(|c| c.engine == EngineId::Sos));
        assert!(linked.iter().all(|c| c.fault.is_empty()));
        assert!(
            faulted.iter().map(|c| c.id).max() < linked.iter().map(|c| c.id).min(),
            "link cells are appended after the fault axis"
        );
        // clean cells come first with the same dense ids a fault-free
        // grid would assign, so clean artifacts are unaffected
        let mut no_faults = q.clone();
        no_faults.faults.clear();
        for (a, b) in no_faults.cells().iter().zip(&clean) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.engine, b.engine);
            assert_eq!(a.workload, b.workload);
        }
        // every fault key round-trips as a canonical spec valid for its park
        for c in &faulted {
            let spec = crate::faults::FaultSpec::parse(&c.fault).unwrap();
            assert_eq!(spec.render(), c.fault);
            assert!(spec.plan(c.machines).is_ok());
        }
    }

    #[test]
    fn faulted_cells_are_deterministic_and_parity_isolated() {
        let mut cfg = tiny();
        cfg.engines = EngineId::SOFTWARE.to_vec();
        cfg.faults = vec!["down=0@10+15,storm=3@12,seed=5".to_string()];
        let results = run_sweep(&cfg);
        // the faulted cell is a singleton scenario group: parity still
        // checks exactly the clean multi-engine groups
        assert_eq!(results.check_parity().unwrap(), 4, "4 non-reference engines");
        let faulted: Vec<&CellResult> = results
            .cells
            .iter()
            .filter(|r| !r.cell.fault.is_empty())
            .collect();
        assert_eq!(faulted.len(), 1);
        let f = faulted[0];
        assert!(
            f.metrics.total_scheduled >= 43,
            "40 trace jobs + 3 storm jobs (re-assignments after eviction may add more): {}",
            f.metrics.total_scheduled
        );
        // bit-reproducible: re-running the cell gives the identical result
        let again = run_cell(&f.cell);
        assert_eq!(again.metrics.jobs_per_machine, f.metrics.jobs_per_machine);
        assert_eq!(again.metrics.avg_latency, f.metrics.avg_latency);
        assert_eq!(again.ticks, f.ticks);
        assert_eq!((again.p50, again.p95, again.p99), (f.p50, f.p95, f.p99));
        // and the render names the faulted cell with its canonical key
        assert!(results.render().contains("down=0@10+15,storm=3@12,seed=5"));
    }

    #[test]
    fn portfolio_column_sweeps_and_stays_out_of_parity() {
        let mut cfg = tiny();
        cfg.engines = vec![EngineId::Sos, EngineId::Sosc, EngineId::Portfolio];
        let results = run_sweep(&cfg);
        // parity still checks exactly the single-policy pair; the
        // portfolio column's intentional divergence is not a violation
        assert_eq!(results.check_parity().unwrap(), 1, "sos vs sosc only");
        let p = results
            .cells
            .iter()
            .find(|r| r.cell.engine == EngineId::Portfolio)
            .expect("portfolio cell ran");
        assert_eq!(p.metrics.total_scheduled, 40, "portfolio cell conserves jobs");
        assert_eq!(p.metrics.jobs_per_machine.iter().sum::<usize>(), 40);
        // bit-reproducible: re-running the cell gives the identical result
        let again = run_cell(&p.cell);
        assert_eq!(again.metrics.jobs_per_machine, p.metrics.jobs_per_machine);
        assert_eq!(again.metrics.avg_latency, p.metrics.avg_latency);
        assert_eq!(again.ticks, p.ticks);
        // the aggregates table carries the portfolio column by name
        assert!(results.render().contains("portfolio"));
    }

    #[test]
    fn link_axis_appends_sos_only_cells_and_throttles_deterministically() {
        let mut cfg = tiny();
        cfg.engines = vec![EngineId::Sos];
        cfg.link_widths = vec![4];
        let results = run_sweep(&cfg);
        // clean and constrained cells are singleton scenario groups:
        // parity never compares across the link axis
        assert_eq!(results.check_parity().unwrap(), 0);
        let clean = &results.cells[0];
        let linked = &results.cells[1];
        assert_eq!(clean.cell.link_width, 0);
        assert_eq!(linked.cell.link_width, 4);
        assert_eq!(linked.cell.engine, EngineId::Sos);
        assert_eq!(
            linked.metrics.jobs_per_machine.iter().sum::<usize>(),
            40,
            "the narrow link throttles admission but never drops jobs"
        );
        assert!(
            linked.ticks > clean.ticks,
            "a 4 B/tick wire costs virtual time: {} vs {}",
            linked.ticks,
            clean.ticks
        );
        // bit-reproducible: re-running the cell gives the identical result
        let again = run_cell(&linked.cell);
        assert_eq!(again.metrics.jobs_per_machine, linked.metrics.jobs_per_machine);
        assert_eq!(again.metrics.avg_latency, linked.metrics.avg_latency);
        assert_eq!(again.ticks, linked.ticks);
        assert_eq!((again.p50, again.p95, again.p99), (linked.p50, linked.p95, linked.p99));
        // and the render carries the constrained-cell table
        assert!(results.render().contains("link-constrained cells"));
    }

    #[test]
    fn engine_list_parsing_feeds_the_grid() {
        // the sweep consumes the one registry's list parser directly
        assert_eq!(EngineId::parse_list("all").unwrap(), EngineId::SOFTWARE.to_vec());
        let mut cfg = tiny();
        cfg.engines = EngineId::parse_list("sos, simd").unwrap();
        assert_eq!(cfg.cells().len(), 2);
        assert!(EngineId::parse_list("warp-drive").is_err());
    }
}
