//! Reduced-precision numeric substrates for the quantization study
//! (Section 4.2): IEEE-754 half-precision rounding and generic unsigned
//! fixed-point rounding, both implemented from scratch (no half/fixed
//! crates in this environment).

/// Round an `f32` through IEEE-754 binary16 (round-to-nearest-even) and
/// back. Overflow saturates to ±65504 (f16 max finite) rather than inf,
/// matching hardware saturating converters.
pub fn f16_round(x: f32) -> f32 {
    if x.is_nan() {
        return f32::NAN;
    }
    let bits = x.to_bits();
    let sign = (bits >> 16) & 0x8000;
    let mut exp = ((bits >> 23) & 0xff) as i32;
    let mut frac = bits & 0x007f_ffff;

    const F16_MAX: f32 = 65504.0;
    if exp == 0xff {
        // inf stays inf in magnitude; saturate to max finite instead
        return if sign != 0 { -F16_MAX } else { F16_MAX };
    }
    exp -= 127; // unbias
    if exp > 15 {
        return if sign != 0 { -F16_MAX } else { F16_MAX };
    }
    if exp < -25 {
        // below half of the smallest subnormal: underflow to signed zero
        return if sign != 0 { -0.0 } else { 0.0 };
    }
    let half: u16;
    if exp < -14 {
        // subnormal half: shift frac (with implicit leading 1) right
        let shift = (-14 - exp) as u32; // 1..=11
        frac |= 0x0080_0000; // implicit bit
        let rshift = 13 + shift;
        let kept = frac >> rshift;
        let round_bit = (frac >> (rshift - 1)) & 1;
        let sticky = frac & ((1 << (rshift - 1)) - 1) != 0;
        let mut h = kept;
        if round_bit == 1 && (sticky || (kept & 1) == 1) {
            h += 1;
        }
        half = (sign | h as u32) as u16;
    } else {
        // normal half
        let kept = frac >> 13;
        let round_bit = (frac >> 12) & 1;
        let sticky = frac & 0x0fff != 0;
        let mut h = (((exp + 15) as u32) << 10) | kept;
        if round_bit == 1 && (sticky || (h & 1) == 1) {
            h += 1; // may carry into exponent — that is correct rounding
        }
        if h >= 0x7c00 {
            return if sign != 0 { -F16_MAX } else { F16_MAX };
        }
        half = (sign | h) as u16;
    }
    // decode back to f32
    let s = ((half as u32) & 0x8000) << 16;
    let e = ((half as u32) >> 10) & 0x1f;
    let f = (half as u32) & 0x3ff;
    let out = if e == 0 {
        if f == 0 {
            f32::from_bits(s)
        } else {
            // subnormal: f * 2^-24
            let v = f as f32 * (-24f32).exp2();
            if s != 0 {
                -v
            } else {
                v
            }
        }
    } else {
        let v = f32::from_bits(s | ((e + 127 - 15) << 23) | (f << 13));
        v
    };
    out
}

/// Unsigned fixed-point format `UQ(int_bits).(frac_bits)`, saturating.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fixed {
    pub int_bits: u32,
    pub frac_bits: u32,
}

impl Fixed {
    pub const fn new(int_bits: u32, frac_bits: u32) -> Self {
        Fixed { int_bits, frac_bits }
    }

    /// Total storage width in bits.
    pub fn width(&self) -> u32 {
        self.int_bits + self.frac_bits
    }

    /// Largest representable value.
    pub fn max_value(&self) -> f32 {
        let steps = (1u64 << self.width()) - 1;
        steps as f32 / (1u64 << self.frac_bits) as f32
    }

    /// Resolution (value of one LSB).
    pub fn resolution(&self) -> f32 {
        1.0 / (1u64 << self.frac_bits) as f32
    }

    /// Round `x` to the nearest representable value, saturating at
    /// `[0, max_value]`.
    pub fn round(&self, x: f32) -> f32 {
        fixed_round(x, self.int_bits, self.frac_bits)
    }
}

/// Free-function form of [`Fixed::round`].
pub fn fixed_round(x: f32, int_bits: u32, frac_bits: u32) -> f32 {
    let scale = (1u64 << frac_bits) as f32;
    let max_steps = ((1u64 << (int_bits + frac_bits)) - 1) as f32;
    let steps = (x * scale).round().clamp(0.0, max_steps);
    steps / scale
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_exact_small_integers() {
        for v in [0.0f32, 1.0, 2.0, 3.0, 100.0, 1024.0, -5.0] {
            assert_eq!(f16_round(v), v, "exact half-representable {v}");
        }
    }

    #[test]
    fn f16_rounds_to_nearest_even() {
        // 1 + 2^-11 is exactly between 1.0 and 1+2^-10 -> ties to even (1.0)
        let x = 1.0 + (-11f32).exp2();
        assert_eq!(f16_round(x), 1.0);
        // 1 + 3*2^-11 ties between 1+2^-10 and 1+2^-9 -> to even (1+2^-9)
        let y = 1.0 + 3.0 * (-11f32).exp2();
        assert_eq!(f16_round(y), 1.0 + (-9f32).exp2());
    }

    #[test]
    fn f16_saturates() {
        assert_eq!(f16_round(1e9), 65504.0);
        assert_eq!(f16_round(-1e9), -65504.0);
        assert_eq!(f16_round(f32::INFINITY), 65504.0);
    }

    #[test]
    fn f16_subnormals() {
        let tiny = (-24f32).exp2(); // smallest positive half subnormal
        assert_eq!(f16_round(tiny), tiny);
        assert_eq!(f16_round(tiny * 0.4), 0.0);
        assert_eq!(f16_round(tiny * 0.6), tiny);
    }

    #[test]
    fn f16_error_bounded() {
        // relative error of normal-range rounding <= 2^-11
        let mut x = 0.001f32;
        while x < 60000.0 {
            let r = f16_round(x);
            let rel = ((r - x) / x).abs();
            assert!(rel <= (-10f32).exp2(), "x={x} r={r} rel={rel}");
            x *= 1.37;
        }
    }

    #[test]
    fn fixed_q44() {
        let q = Fixed::new(4, 4);
        assert_eq!(q.width(), 8);
        assert_eq!(q.max_value(), 15.9375);
        assert_eq!(q.resolution(), 0.0625);
        assert_eq!(q.round(1.03), 1.0); // 1.03*16 = 16.48 rounds to 16
        assert_eq!(q.round(1.04), 1.0625); // 16.64 rounds to 17
        assert_eq!(q.round(100.0), 15.9375); // saturates
        assert_eq!(q.round(-3.0), 0.0);
    }

    #[test]
    fn fixed_q80_is_integer_rounding() {
        let q = Fixed::new(8, 0);
        assert_eq!(q.round(3.4), 3.0);
        assert_eq!(q.round(3.5), 4.0);
        assert_eq!(q.round(300.0), 255.0);
    }
}
