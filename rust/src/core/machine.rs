//! Machine abstraction (Definition 1): `M = <T, Q>` with
//! `T in {CPU, GPU, Mixed}` and `Q in {Best, Worst}`.

use std::fmt;

/// Index of a machine within a [`MachinePark`].
pub type MachineId = usize;

/// Machine type `T` of Definition 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MachineKind {
    Cpu,
    Gpu,
    /// A machine equally suited to compute- and memory-bound programs
    /// (e.g. an APU or a balanced node).
    Mixed,
}

impl fmt::Display for MachineKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MachineKind::Cpu => write!(f, "CPU"),
            MachineKind::Gpu => write!(f, "GPU"),
            MachineKind::Mixed => write!(f, "Mixed"),
        }
    }
}

/// Machine quality `Q` of Definition 1: `Time(P)_Best << Time(P)_Worst`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Quality {
    Best,
    Worst,
}

impl fmt::Display for Quality {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Quality::Best => write!(f, "Best"),
            Quality::Worst => write!(f, "Worst"),
        }
    }
}

/// A compute unit of the target heterogeneous system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Machine {
    pub id: MachineId,
    pub kind: MachineKind,
    pub quality: Quality,
}

impl Machine {
    pub fn new(id: MachineId, kind: MachineKind, quality: Quality) -> Self {
        Machine { id, kind, quality }
    }

    /// Quality multiplier applied to a program's base processing time.
    /// `Best` machines run programs much faster than `Worst` ones
    /// (Definition 1's `Time(P)_Best << Time(P)_Worst`).
    pub fn quality_factor(&self) -> f32 {
        match self.quality {
            Quality::Best => 1.0,
            Quality::Worst => 3.0,
        }
    }

    pub fn label(&self) -> String {
        format!("<{},{}>", self.kind, self.quality)
    }
}

/// An ordered set of machines — the target system configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MachinePark {
    machines: Vec<Machine>,
}

impl MachinePark {
    pub fn new(machines: Vec<Machine>) -> Self {
        for (i, m) in machines.iter().enumerate() {
            assert_eq!(m.id, i, "machine ids must be dense and ordered");
        }
        MachinePark { machines }
    }

    /// The paper's five-machine evaluation configuration (Section 7.1):
    /// M1:<CPU,Best>  M2:<CPU,Worst>  M3:<Mixed,Best>
    /// M4:<GPU,Best>  M5:<GPU,Worst>
    pub fn paper_m1_m5() -> Self {
        MachinePark::new(vec![
            Machine::new(0, MachineKind::Cpu, Quality::Best),
            Machine::new(1, MachineKind::Cpu, Quality::Worst),
            Machine::new(2, MachineKind::Mixed, Quality::Best),
            Machine::new(3, MachineKind::Gpu, Quality::Best),
            Machine::new(4, MachineKind::Gpu, Quality::Worst),
        ])
    }

    /// A homogeneous CPU park with alternating quality — the paper's
    /// experiment (5) "Performance on homogeneous machines".
    pub fn homogeneous_cpu(n: usize) -> Self {
        MachinePark::new(
            (0..n)
                .map(|i| {
                    Machine::new(
                        i,
                        MachineKind::Cpu,
                        if i % 2 == 0 { Quality::Best } else { Quality::Worst },
                    )
                })
                .collect(),
        )
    }

    /// A park of `n` machines cycling through the M1–M5 pattern — used by
    /// the scaling studies (Fig. 17/18) that need 5..=140 machines.
    pub fn cycled(n: usize) -> Self {
        let proto = MachinePark::paper_m1_m5();
        MachinePark::new(
            (0..n)
                .map(|i| {
                    let p = proto.machines[i % 5];
                    Machine::new(i, p.kind, p.quality)
                })
                .collect(),
        )
    }

    /// Build from an explicit (cpu, gpu, mixed) Machine Composition, the
    /// workload generator's MC parameter. Quality alternates Best/Worst
    /// within each kind group.
    pub fn from_composition(cpu: usize, gpu: usize, mixed: usize) -> Self {
        let mut machines = Vec::with_capacity(cpu + gpu + mixed);
        let mut id = 0;
        for (kind, count) in [
            (MachineKind::Cpu, cpu),
            (MachineKind::Gpu, gpu),
            (MachineKind::Mixed, mixed),
        ] {
            for j in 0..count {
                machines.push(Machine::new(
                    id,
                    kind,
                    if j % 2 == 0 { Quality::Best } else { Quality::Worst },
                ));
                id += 1;
            }
        }
        MachinePark::new(machines)
    }

    pub fn len(&self) -> usize {
        self.machines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.machines.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Machine> {
        self.machines.iter()
    }

    pub fn get(&self, id: MachineId) -> &Machine {
        &self.machines[id]
    }

    pub fn labels(&self) -> Vec<String> {
        self.machines.iter().map(|m| m.label()).collect()
    }
}

impl std::ops::Index<MachineId> for MachinePark {
    type Output = Machine;
    fn index(&self, id: MachineId) -> &Machine {
        &self.machines[id]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_park_matches_section_7_1() {
        let p = MachinePark::paper_m1_m5();
        assert_eq!(p.len(), 5);
        assert_eq!(p[0].label(), "<CPU,Best>");
        assert_eq!(p[1].label(), "<CPU,Worst>");
        assert_eq!(p[2].label(), "<Mixed,Best>");
        assert_eq!(p[3].label(), "<GPU,Best>");
        assert_eq!(p[4].label(), "<GPU,Worst>");
    }

    #[test]
    fn cycled_repeats_pattern() {
        let p = MachinePark::cycled(12);
        assert_eq!(p.len(), 12);
        assert_eq!(p[5].kind, p[0].kind);
        assert_eq!(p[11].kind, p[1].kind);
        assert_eq!(p[7].id, 7);
    }

    #[test]
    fn composition_counts() {
        let p = MachinePark::from_composition(2, 3, 1);
        assert_eq!(p.len(), 6);
        assert_eq!(p.iter().filter(|m| m.kind == MachineKind::Cpu).count(), 2);
        assert_eq!(p.iter().filter(|m| m.kind == MachineKind::Gpu).count(), 3);
        assert_eq!(p.iter().filter(|m| m.kind == MachineKind::Mixed).count(), 1);
        // quality alternates within a kind group
        assert_eq!(p[0].quality, Quality::Best);
        assert_eq!(p[1].quality, Quality::Worst);
    }

    #[test]
    fn quality_factor_orders_best_below_worst() {
        let best = Machine::new(0, MachineKind::Cpu, Quality::Best);
        let worst = Machine::new(1, MachineKind::Cpu, Quality::Worst);
        assert!(best.quality_factor() < worst.quality_factor());
    }

    #[test]
    #[should_panic]
    fn non_dense_ids_rejected() {
        MachinePark::new(vec![Machine::new(3, MachineKind::Cpu, Quality::Best)]);
    }
}
