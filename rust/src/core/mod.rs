//! Core domain types: machines, jobs, and the paper's conventions
//! (Definitions 1–3 of Section 2).

mod fixed;
mod job;
mod machine;

pub use fixed::{f16_round, fixed_round, Fixed};
pub use job::{Job, JobId, JobNature};
pub use machine::{Machine, MachineId, MachineKind, MachinePark, Quality};

/// Weighted Shortest Processing Time ratio `T_i^J = J.W / eps_i`
/// (Definition 2). The single priority key of the SOS algorithm.
#[inline]
pub fn wspt(weight: f32, ept: f32) -> f32 {
    debug_assert!(ept > 0.0, "EPT must be positive");
    weight / ept
}

/// Discrete alpha release threshold: the head job is released once it has
/// accrued `ceil(alpha * eps)` cycles of virtual work (Phase III,
/// discretized per Section 3.2).
#[inline]
pub fn alpha_point(alpha: f32, ept: f32) -> u32 {
    (alpha * ept).ceil() as u32
}
