//! Job abstraction (Definition 2): `J = <W, eps, P, ID>` — weight, per-
//! machine expected processing times (EPT), program nature, unique id.

use std::fmt;

/// Unique job identifier (`ID in Z+` of Definition 2).
pub type JobId = u64;

/// Nature/bounding `P` of the underlying program (Definition 2 and the
/// "Conventions" paragraph): compute-bound, memory-bound, or mixed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobNature {
    Compute,
    Memory,
    Mixed,
}

impl fmt::Display for JobNature {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JobNature::Compute => write!(f, "compute"),
            JobNature::Memory => write!(f, "memory"),
            JobNature::Mixed => write!(f, "mixed"),
        }
    }
}

/// A program with uncertain execution time, ready for scheduling.
///
/// `ept[i]` is the *expected* processing time of the job on machine `i`
/// — a best guess synthesized from prior execution history (Phase I of
/// the algorithm), not a guarantee. `weight` is the global prioritization
/// metric (e.g. downstream-dependency count or source priority).
#[derive(Debug, Clone, PartialEq)]
pub struct Job {
    pub id: JobId,
    pub weight: f32,
    pub ept: Vec<f32>,
    pub nature: JobNature,
    /// Clock tick at which the job was created (used by latency metrics).
    pub arrival: u64,
    /// The job's *actual* processing time factor: actual runtime on
    /// machine `i` is `ept[i] * actual_factor` (stochastic deviation from
    /// the estimate — the "variance from data loading, shared memory
    /// usage, etc." of Section 2).
    pub actual_factor: f32,
}

impl Job {
    pub fn new(id: JobId, weight: f32, ept: Vec<f32>, nature: JobNature) -> Self {
        assert!(weight >= 1.0, "minimum job weight is 1 (Section 4.2)");
        assert!(
            ept.iter().all(|&e| e >= 1.0),
            "EPTs must be positive"
        );
        Job {
            id,
            weight,
            ept,
            nature,
            arrival: 0,
            actual_factor: 1.0,
        }
    }

    pub fn with_arrival(mut self, tick: u64) -> Self {
        self.arrival = tick;
        self
    }

    pub fn with_actual_factor(mut self, f: f32) -> Self {
        self.actual_factor = f;
        self
    }

    /// WSPT priority of this job on machine `i` (Definition 2).
    #[inline]
    pub fn wspt(&self, machine: usize) -> f32 {
        super::wspt(self.weight, self.ept[machine])
    }

    /// Actual runtime of the job on machine `i`, in ticks (>= 1).
    pub fn actual_time(&self, machine: usize) -> u64 {
        ((self.ept[machine] * self.actual_factor).round() as u64).max(1)
    }

    /// Number of machines this job carries EPT estimates for.
    pub fn fanout(&self) -> usize {
        self.ept.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job() -> Job {
        Job::new(7, 4.0, vec![10.0, 20.0, 40.0], JobNature::Compute)
    }

    #[test]
    fn wspt_is_weight_over_ept() {
        let j = job();
        assert_eq!(j.wspt(0), 0.4);
        assert_eq!(j.wspt(1), 0.2);
        assert_eq!(j.wspt(2), 0.1);
    }

    #[test]
    fn actual_time_scales_with_factor() {
        let j = job().with_actual_factor(1.5);
        assert_eq!(j.actual_time(0), 15);
        assert_eq!(j.actual_time(1), 30);
    }

    #[test]
    fn actual_time_never_zero() {
        let j = Job::new(1, 1.0, vec![1.0], JobNature::Memory).with_actual_factor(0.01);
        assert_eq!(j.actual_time(0), 1);
    }

    #[test]
    #[should_panic]
    fn zero_weight_rejected() {
        Job::new(1, 0.0, vec![10.0], JobNature::Mixed);
    }
}
