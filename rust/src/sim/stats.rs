//! Per-iteration cycle accounting shared by both simulators.

/// The four looping paths of the Virtual-Schedule algorithmic flow
/// (Fig. 9b): Standard `A->C->F`, Pop `A->B->C->F`, Insert
/// `A->C->D->E->F`, Pop+Insert `A->B->C->D->E->F`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IterationKind {
    Standard,
    Pop,
    Insert,
    PopInsert,
}

impl IterationKind {
    pub fn classify(popped: bool, inserted: bool) -> Self {
        match (popped, inserted) {
            (false, false) => IterationKind::Standard,
            (true, false) => IterationKind::Pop,
            (false, true) => IterationKind::Insert,
            (true, true) => IterationKind::PopInsert,
        }
    }

    pub const ALL: [IterationKind; 4] = [
        IterationKind::Standard,
        IterationKind::Pop,
        IterationKind::Insert,
        IterationKind::PopInsert,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            IterationKind::Standard => "standard",
            IterationKind::Pop => "pop",
            IterationKind::Insert => "insert",
            IterationKind::PopInsert => "pop+insert",
        }
    }

    fn index(&self) -> usize {
        match self {
            IterationKind::Standard => 0,
            IterationKind::Pop => 1,
            IterationKind::Insert => 2,
            IterationKind::PopInsert => 3,
        }
    }
}

/// Cycle accounting across a run.
#[derive(Debug, Clone, Default)]
pub struct IterationStats {
    counts: [u64; 4],
    cycles: [u64; 4],
    /// Latency of the full decision path (the Fig. 18a metric) as
    /// reported by the timing model; recorded once since it is
    /// configuration-static per architecture.
    pub decision_latency: u64,
    total_cycles: u64,
}

impl IterationStats {
    pub fn record(&mut self, kind: IterationKind, cycles: u64) {
        let i = kind.index();
        self.counts[i] += 1;
        self.cycles[i] += cycles;
        self.total_cycles += cycles;
    }

    pub fn count(&self, kind: IterationKind) -> u64 {
        self.counts[kind.index()]
    }

    pub fn iterations(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn total_cycles(&self) -> u64 {
        self.total_cycles
    }

    pub fn avg_cycles(&self, kind: IterationKind) -> f64 {
        let i = kind.index();
        if self.counts[i] == 0 {
            0.0
        } else {
            self.cycles[i] as f64 / self.counts[i] as f64
        }
    }

    /// Mean cycles per iteration over the whole run.
    pub fn avg_cycles_overall(&self) -> f64 {
        let n = self.iterations();
        if n == 0 {
            0.0
        } else {
            self.total_cycles as f64 / n as f64
        }
    }

    /// Wall-clock seconds at a given FPGA clock frequency.
    pub fn seconds_at(&self, freq_hz: f64) -> f64 {
        self.total_cycles as f64 / freq_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_matches_fig9_paths() {
        assert_eq!(
            IterationKind::classify(false, false),
            IterationKind::Standard
        );
        assert_eq!(IterationKind::classify(true, false), IterationKind::Pop);
        assert_eq!(IterationKind::classify(false, true), IterationKind::Insert);
        assert_eq!(
            IterationKind::classify(true, true),
            IterationKind::PopInsert
        );
    }

    #[test]
    fn accounting() {
        let mut s = IterationStats::default();
        s.record(IterationKind::Standard, 10);
        s.record(IterationKind::Standard, 10);
        s.record(IterationKind::Insert, 50);
        assert_eq!(s.iterations(), 3);
        assert_eq!(s.total_cycles(), 70);
        assert_eq!(s.avg_cycles(IterationKind::Standard), 10.0);
        assert_eq!(s.avg_cycles(IterationKind::Insert), 50.0);
        assert!((s.avg_cycles_overall() - 70.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.seconds_at(70.0), 1.0);
    }
}
