//! Cycle-accurate microarchitecture simulators for the two SOSA designs.
//!
//! Both simulators execute the *actual dataflow* of their architecture —
//! register files, CAMs, shift registers, systolic PEs with memoized
//! partial sums — and are required to produce schedules identical to the
//! golden [`crate::scheduler::SosEngine`]. On top of the functional
//! model, each accounts cycles per scheduling iteration using the timing
//! model of its `timing` module (constants derived from the component
//! structure of Sections 4/6 and calibrated against Fig. 18a).

pub mod hercules;
pub mod stannic;
mod stats;

pub use stats::{IterationKind, IterationStats};

use crate::bail;
use crate::core::Job;
use crate::error::Result;
use crate::scheduler::TickOutcome;

/// Common interface of the two architecture simulators.
pub trait ArchSim {
    fn name(&self) -> &'static str;
    /// (machines, virtual-schedule depth).
    fn config(&self) -> (usize, usize);
    /// Run one scheduling iteration (one tick of the golden semantics).
    fn tick(&mut self, arrival: Option<&Job>) -> TickOutcome;
    /// Enqueue an arrival without advancing the clock.
    fn submit(&mut self, job: Job);
    /// Cycle/iteration accounting so far.
    fn stats(&self) -> &IterationStats;
    fn is_idle(&self) -> bool;
}

/// Convenience: drive a simulator and the golden engine in lockstep over
/// a trace, asserting identical outcomes. Returns the number of virtual
/// ticks. Used by integration tests and the `verify` CLI command.
///
/// The golden engine jumps virtual time to the next event
/// (`min(next_release, next_arrival)` via
/// [`crate::scheduler::SosEngine::next_event_tick`]); the cycle-accurate
/// simulator models hardware time and therefore still executes every
/// tick of the skipped window — but since the golden engine proved the
/// window event-free, any non-empty simulator outcome inside it is
/// itself a divergence, so nothing is compared tick-by-tick there. This
/// keeps full divergence detection while removing the golden engine's
/// O(machines)-per-tick cost from the verify path.
pub fn lockstep_verify<S: ArchSim>(
    sim: &mut S,
    golden: &mut crate::scheduler::SosEngine,
    trace: &crate::workload::Trace,
    max_ticks: u64,
) -> Result<u64> {
    let mut events = trace.events().iter().peekable();
    let mut t = golden.tick_no();
    loop {
        let next_arrival = events.peek().map(|e| e.tick);
        let target = crate::scheduler::Horizon::of(golden.next_event_tick())
            .jump_target(next_arrival, t);
        if target > max_ticks {
            bail!("did not drain within {max_ticks} ticks");
        }
        // the golden engine promised (t, target) is event-free: the sim
        // must agree with one empty outcome per skipped tick
        for tt in t + 1..target {
            let s = sim.tick(None);
            if !s.released.is_empty() || s.assigned.is_some() {
                bail!(
                    "tick {tt}: sim produced an event inside a window the golden \
                     engine proved empty: released={:?} assigned={:?}",
                    s.released,
                    s.assigned.as_ref().map(|a| (a.job, a.machine, a.position)),
                );
            }
        }
        golden.advance_to(target - 1);
        t = target;
        while events.peek().is_some_and(|e| e.tick <= t) {
            let j = events.next().expect("peeked").job.clone().expect("job");
            golden.submit(j.clone());
            sim.submit(j);
        }
        let g = golden.tick(None);
        let s = sim.tick(None);
        if g.released != s.released {
            bail!(
                "tick {t}: release divergence golden={:?} sim={:?}",
                g.released,
                s.released
            );
        }
        let ga = g.assigned.as_ref().map(|a| (a.job, a.machine, a.position));
        let sa = s.assigned.as_ref().map(|a| (a.job, a.machine, a.position));
        if ga != sa {
            bail!("tick {t}: assignment divergence golden={ga:?} sim={sa:?}");
        }
        if golden.is_idle() && sim.is_idle() && events.peek().is_none() {
            return Ok(t);
        }
    }
}
