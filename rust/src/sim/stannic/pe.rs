//! The systolic PE array of one SMMU (Section 6.1.2/6.2) — a
//! one-dimensional array where each PE tracks one job of the machine's
//! virtual schedule together with *memoized* threshold sums:
//!
//! * `sum_hi` — the value `sum^HI` would take if this PE's job were the
//!   last element of the higher-priority set: the prefix sum
//!   `Σ_{j<=k} (eps_j - n_j)` over valid PEs from the head;
//! * `sum_lo` — the value `sum^LO` would take if this PE's job were the
//!   first element of the lower-priority set: the suffix sum
//!   `Σ_{j>=k} (W_j - n_j·T_j)` to the tail.
//!
//! PEs do **not** store weight or EPT — exactly like the hardware, every
//! update is expressed in terms of locally-held values and broadcast
//! quantities (Tables 2 and 3), which is what makes the O(1)-lookup cost
//! calculation possible. An invariant checker recomputes the prefix/
//! suffix sums from a shadow copy of (w, eps) kept *outside* the PE state
//! (test-only) to prove the local update rules maintain them.

use crate::core::JobId;

/// One processing element. `valid == false` models the "invalid job /
/// bubble" state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Pe {
    pub valid: bool,
    pub id: JobId,
    /// Stored WSPT ratio T_i^K.
    pub t: f32,
    /// Virtual-work cycle counter n_K.
    pub n: u32,
    /// Alpha release point (cycles of VW before release).
    pub alpha_pt: u32,
    /// Memoized prefix sum (see module docs).
    pub sum_hi: f32,
    /// Memoized suffix sum (see module docs).
    pub sum_lo: f32,
}

impl Pe {
    pub const INVALID: Pe = Pe {
        valid: false,
        id: 0,
        t: 0.0,
        n: 0,
        alpha_pt: 0,
        sum_hi: 0.0,
        sum_lo: 0.0,
    };
}

/// The systolic array of one machine's SMMU.
#[derive(Debug, Clone)]
pub struct PeArray {
    pes: Vec<Pe>,
}

/// Result of a cost query against the array (the volunteered values of
/// the two threshold PEs plus the popcount insertion index).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ThresholdRead {
    pub sum_hi: f32,
    pub sum_lo: f32,
    pub pos: usize,
    pub full: bool,
}

impl PeArray {
    pub fn new(depth: usize) -> Self {
        assert!(depth >= 1);
        PeArray {
            pes: vec![Pe::INVALID; depth],
        }
    }

    pub fn depth(&self) -> usize {
        self.pes.len()
    }

    pub fn pes(&self) -> &[Pe] {
        &self.pes
    }

    pub fn len(&self) -> usize {
        self.pes.iter().take_while(|p| p.valid).count()
    }

    pub fn is_empty(&self) -> bool {
        !self.pes[0].valid
    }

    pub fn is_full(&self) -> bool {
        self.pes.last().is_some_and(|p| p.valid)
    }

    pub fn head(&self) -> Option<&Pe> {
        self.pes[0].valid.then(|| &self.pes[0])
    }

    /// Broadcast the incoming job's WSPT on the broadcast bus; every PE
    /// does its local comparison C (Eq. 6) and the two threshold PEs
    /// volunteer their memoized sums — the single-cycle lookup replacing
    /// the depth-wide summation (Section 6.2.1).
    pub fn threshold_read(&self, j_t: f32) -> ThresholdRead {
        // C = 0 (HI) iff T_k >= T_j for a valid PE; invalid PEs read C=1.
        // Proper ordering makes the C string 0...01...1, so:
        let pos = self
            .pes
            .iter()
            .take_while(|p| p.valid && p.t >= j_t)
            .count();
        let sum_hi = if pos > 0 { self.pes[pos - 1].sum_hi } else { 0.0 };
        let sum_lo = if pos < self.pes.len() && self.pes[pos].valid {
            self.pes[pos].sum_lo
        } else {
            0.0
        };
        ThresholdRead {
            sum_hi,
            sum_lo,
            pos,
            full: self.is_full(),
        }
    }

    /// Standard-iteration cost update (Fig. 11): the head accrues one
    /// cycle of virtual work. Head PE decrements both memoized values
    /// (`sum_hi -= 1`, `sum_lo -= T`); every other valid PE decrements
    /// only `sum_hi` (its prefix includes the head).
    pub fn standard_update(&mut self) {
        if !self.pes[0].valid {
            return;
        }
        self.pes[0].n += 1;
        self.pes[0].sum_hi -= 1.0;
        self.pes[0].sum_lo -= self.pes[0].t;
        for pe in self.pes.iter_mut().skip(1) {
            if !pe.valid {
                break; // proper ordering: valid PEs form a prefix
            }
            pe.sum_hi -= 1.0;
        }
    }

    /// POP iteration (Fig. 12): release the head, broadcast
    /// `Δα = sum_hi(head)` (its remaining contribution), subtract it from
    /// every remaining PE's prefix sum, synchronous left shift with an
    /// invalid job entering at the tail. Returns the released job id.
    pub fn pop(&mut self) -> JobId {
        debug_assert!(self.pes[0].valid, "pop on empty array");
        let released = self.pes[0].id;
        let delta_alpha = self.pes[0].sum_hi;
        let d = self.pes.len();
        for i in 0..d - 1 {
            let mut next = self.pes[i + 1];
            if next.valid {
                next.sum_hi -= delta_alpha;
            }
            self.pes[i] = next;
        }
        self.pes[d - 1] = Pe::INVALID;
        released
    }

    /// Insert iteration (Fig. 13 / Table 2): the HI set (C=0) stays
    /// stationary and adds `J.W` to its suffix sums; the LO set (C=1)
    /// right-shifts and adds `J.eps` to its prefix sums; the threshold PE
    /// stores the new job with initial sums computed by the Cost
    /// Calculator from the volunteered threshold values.
    ///
    /// `read` must be the `threshold_read(j_t)` of this same iteration
    /// (the hardware reuses the comparison values C from the cost
    /// calculation earlier in the cycle).
    pub fn insert(&mut self, read: ThresholdRead, id: JobId, j_w: f32, j_eps: f32, j_t: f32, alpha_pt: u32) {
        debug_assert!(!self.is_full(), "insert into full array");
        let p = read.pos;
        let d = self.pes.len();
        // LO set right-shift (from tail toward threshold)
        for i in (p..d - 1).rev() {
            if self.pes[i].valid {
                let mut moved = self.pes[i];
                moved.sum_hi += j_eps; // new job enters their prefix
                self.pes[i + 1] = moved;
            }
        }
        // HI set cost updates (stationary)
        for pe in self.pes[..p].iter_mut() {
            debug_assert!(pe.valid);
            pe.sum_lo += j_w; // new job enters their suffix
        }
        // Threshold PE loads the new job from the broadcast bus; initial
        // sums from the cost calculator (Section 6.2.2 (3a)).
        self.pes[p] = Pe {
            valid: true,
            id,
            t: j_t,
            n: 0,
            alpha_pt,
            sum_hi: read.sum_hi + j_eps,
            sum_lo: read.sum_lo + j_w,
        };
    }

    /// Fused POP + Insert iteration (Fig. 14 / Table 3): the two
    /// reorderings compose into "HI set shifts left, LO set stationary,
    /// new job lands at the C=0 side of the threshold", with cost updates
    /// accounting for both the departing head (`Δα`) and the incoming job.
    /// Returns the released job id.
    ///
    /// `read` must be a `threshold_read(j_t)` taken *after* the pop's
    /// effect is known — the hardware evaluates the cost query on the
    /// post-pop state within the same iteration (the Head PE sets C=0 on
    /// pop so the insertion point self-identifies, Section 6.2.2 (4c)).
    /// For simulation simplicity we express the fused form directly in
    /// terms of the pre-pop state and the paper's Table 3 update rules.
    pub fn pop_insert(&mut self, id: JobId, j_w: f32, j_eps: f32, j_t: f32, alpha_pt: u32) -> JobId {
        debug_assert!(self.pes[0].valid, "pop_insert on empty array");
        let released = self.pes[0].id;
        let delta_alpha = self.pes[0].sum_hi;
        let d = self.pes.len();

        // Post-pop threshold position: count valid PEs *after* the head
        // with T >= j_t (the head is leaving).
        let p = self.pes[1..]
            .iter()
            .take_while(|pe| pe.valid && pe.t >= j_t)
            .count();

        // Volunteered values on the post-pop state:
        // sum_hi threshold = prefix through PE p (pre-pop index) minus Δα
        let v_sum_hi = if p > 0 {
            self.pes[p].sum_hi - delta_alpha
        } else {
            0.0
        };
        let v_sum_lo = if p + 1 < d && self.pes[p + 1].valid {
            self.pes[p + 1].sum_lo
        } else {
            0.0
        };

        // HI set (pre-pop indices 1..=p): net left shift, updates
        // sum_hi -= Δα (head leaves prefix), sum_lo += J.W (J enters suffix).
        for i in 1..=p {
            let mut moved = self.pes[i];
            moved.sum_hi -= delta_alpha;
            moved.sum_lo += j_w;
            self.pes[i - 1] = moved;
        }
        // New job lands at post-pop index p.
        self.pes[p] = Pe {
            valid: true,
            id,
            t: j_t,
            n: 0,
            alpha_pt,
            sum_hi: v_sum_hi + j_eps,
            sum_lo: v_sum_lo + j_w,
        };
        // LO set (pre-pop indices p+1..): stationary in place (pop's left
        // shift cancels insert's right shift), updates
        // sum_hi += (J.eps - Δα).
        for i in p + 1..d {
            if self.pes[i].valid {
                self.pes[i].sum_hi += j_eps - delta_alpha;
            }
        }
        released
    }

    /// Definition 4 "Properly Ordered Systolic Virtual Schedule".
    pub fn properly_ordered(&self) -> bool {
        // valid jobs form a prefix (no bubbles)
        let len = self.len();
        if self.pes[len..].iter().any(|p| p.valid) {
            return false;
        }
        // non-increasing T
        self.pes[..len].windows(2).all(|w| w[0].t >= w[1].t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shadow model: recompute what the memoized sums *should* be from
    /// full (w, eps) knowledge, to verify the local update rules.
    struct Shadow {
        jobs: Vec<(JobId, f32, f32, f32, u32)>, // id, w, eps, t, n
    }

    impl Shadow {
        fn expected_sums(&self) -> Vec<(f32, f32)> {
            let k = self.jobs.len();
            let mut out = vec![(0.0f32, 0.0f32); k];
            let mut prefix = 0.0f32;
            for i in 0..k {
                let (_, _, eps, _, n) = self.jobs[i];
                prefix += eps - n as f32;
                out[i].0 = prefix;
            }
            let mut suffix = 0.0f32;
            for i in (0..k).rev() {
                let (_, w, _, t, n) = self.jobs[i];
                suffix += w - n as f32 * t;
                out[i].1 = suffix;
            }
            out
        }
    }

    fn check_invariants(arr: &PeArray, shadow: &Shadow) {
        assert!(arr.properly_ordered());
        let want = shadow.expected_sums();
        assert_eq!(arr.len(), want.len());
        for (i, pe) in arr.pes()[..want.len()].iter().enumerate() {
            assert_eq!(pe.id, shadow.jobs[i].0, "slot {i} id");
            assert!(
                (pe.sum_hi - want[i].0).abs() < 1e-3,
                "slot {i}: sum_hi {} want {}",
                pe.sum_hi,
                want[i].0
            );
            assert!(
                (pe.sum_lo - want[i].1).abs() < 1e-3,
                "slot {i}: sum_lo {} want {}",
                pe.sum_lo,
                want[i].1
            );
        }
    }

    /// Drive random operations and verify the memoized sums stay exact.
    #[test]
    fn memoized_sums_match_shadow_model() {
        use crate::workload::Rng;
        let mut rng = Rng::new(99);
        let depth = 8;
        let mut arr = PeArray::new(depth);
        let mut shadow = Shadow { jobs: vec![] };
        let mut next_id = 1u64;

        for _step in 0..2000 {
            // maybe pop (alpha-ready head)
            if let Some(h) = arr.head() {
                if h.n >= h.alpha_pt {
                    let id = arr.pop();
                    assert_eq!(id, shadow.jobs.remove(0).0);
                }
            }
            // maybe insert (WSPT quantized to the UQ4.4 hardware format,
            // making every update arithmetic exact in f32 — the same
            // property the INT8 datapath relies on)
            if !arr.is_full() && rng.chance(0.35) {
                let w = rng.uniform(1.0, 255.0).round();
                let eps = rng.uniform(10.0, 255.0).round();
                let t = crate::core::fixed_round(w / eps, 4, 4);
                let alpha_pt = (0.5 * eps).ceil() as u32;
                let read = arr.threshold_read(t);
                arr.insert(read, next_id, w, eps, t, alpha_pt);
                shadow.jobs.insert(read.pos, (next_id, w, eps, t, 0));
                next_id += 1;
            }
            // standard update (every iteration)
            arr.standard_update();
            if let Some(first) = shadow.jobs.first_mut() {
                first.4 += 1;
            }
            check_invariants(&arr, &shadow);
        }
    }

    #[test]
    fn threshold_read_splits_sets() {
        let mut arr = PeArray::new(4);
        // insert three jobs: T = 2.0 (w40 e20), 1.0 (w20 e20), 0.5 (w10 e20)
        for (id, w, eps) in [(1u64, 40.0, 20.0), (2, 20.0, 20.0), (3, 10.0, 20.0)] {
            let t = w / eps;
            let read = arr.threshold_read(t);
            arr.insert(read, id, w, eps, t, 10);
        }
        let r = arr.threshold_read(1.0); // ties are HI
        assert_eq!(r.pos, 2);
        assert_eq!(r.sum_hi, 40.0); // (20-0)+(20-0)
        assert_eq!(r.sum_lo, 10.0); // job 3's W
        assert!(!r.full);

        let r_top = arr.threshold_read(100.0);
        assert_eq!(r_top.pos, 0);
        assert_eq!(r_top.sum_hi, 0.0);
        assert_eq!(r_top.sum_lo, 70.0);

        let r_bot = arr.threshold_read(0.001);
        assert_eq!(r_bot.pos, 3);
        assert_eq!(r_bot.sum_hi, 60.0);
        assert_eq!(r_bot.sum_lo, 0.0);
    }

    #[test]
    fn fused_pop_insert_equals_sequential() {
        use crate::workload::Rng;
        let mut rng = Rng::new(7);
        for trial in 0..200 {
            // build a random ready-to-pop array
            let depth = rng.range(2, 8);
            let mut a = PeArray::new(depth);
            let k = rng.range(1, depth - 1);
            let mut ts: Vec<(f32, f32)> = (0..k)
                .map(|_| {
                    let w = rng.uniform(1.0, 255.0).round();
                    let e = rng.uniform(10.0, 255.0).round();
                    (w, e)
                })
                .collect();
            ts.sort_by(|x, y| (y.0 / y.1).partial_cmp(&(x.0 / x.1)).unwrap());
            for (i, (w, e)) in ts.iter().enumerate() {
                let t = w / e;
                let read = a.threshold_read(t);
                a.insert(read, (i + 1) as u64, *w, *e, t, 1);
            }
            // accrue until head ready
            while a.head().is_some_and(|h| h.n < h.alpha_pt) {
                a.standard_update();
            }
            let mut b = a.clone();

            let w = rng.uniform(1.0, 255.0).round();
            let e = rng.uniform(10.0, 255.0).round();
            let t = w / e;
            let id = 999u64;

            // sequential: pop then insert
            let ra = a.pop();
            let read = a.threshold_read(t);
            a.insert(read, id, w, e, t, 5);

            // fused Table-3 path
            let rb = b.pop_insert(id, w, e, t, 5);

            assert_eq!(ra, rb, "trial {trial}");
            for (i, (pa, pb)) in a.pes().iter().zip(b.pes()).enumerate() {
                assert_eq!(pa.valid, pb.valid, "trial {trial} slot {i}");
                if pa.valid {
                    assert_eq!(pa.id, pb.id, "trial {trial} slot {i}");
                    assert!((pa.sum_hi - pb.sum_hi).abs() < 1e-3, "trial {trial} slot {i} hi");
                    assert!((pa.sum_lo - pb.sum_lo).abs() < 1e-3, "trial {trial} slot {i} lo");
                }
            }
        }
    }

    #[test]
    fn pop_inserts_bubble_at_tail() {
        let mut arr = PeArray::new(3);
        for (id, w, e) in [(1u64, 30.0, 10.0), (2, 10.0, 10.0)] {
            let t = w / e;
            let read = arr.threshold_read(t);
            arr.insert(read, id, w, e, t, 1);
        }
        assert_eq!(arr.pop(), 1);
        assert_eq!(arr.len(), 1);
        assert!(!arr.pes()[1].valid && !arr.pes()[2].valid);
        assert!(arr.properly_ordered());
    }

    #[test]
    fn insert_at_head_edge_case() {
        // Section 6.2.2 (3c): incoming job outranks everything.
        let mut arr = PeArray::new(3);
        let read = arr.threshold_read(0.5);
        arr.insert(read, 1, 5.0, 10.0, 0.5, 5);
        let read = arr.threshold_read(3.0);
        assert_eq!(read.pos, 0);
        arr.insert(read, 2, 30.0, 10.0, 3.0, 5);
        assert_eq!(arr.head().unwrap().id, 2);
        assert_eq!(arr.pes()[1].id, 1);
        assert!(arr.properly_ordered());
    }

    #[test]
    fn pop_insert_with_highest_wspt_edge_case() {
        // Section 6.2.2 (4c): J has the highest WSPT while the head pops.
        let mut arr = PeArray::new(3);
        for (id, w, e) in [(1u64, 20.0, 10.0), (2, 5.0, 10.0)] {
            let t = w / e;
            let read = arr.threshold_read(t);
            arr.insert(read, id, w, e, t, 1);
        }
        arr.standard_update(); // head ready (alpha_pt 1)
        let released = arr.pop_insert(9, 100.0, 10.0, 10.0, 5);
        assert_eq!(released, 1);
        assert_eq!(arr.head().unwrap().id, 9, "newcomer takes the head");
        assert_eq!(arr.pes()[1].id, 2);
        assert!(arr.properly_ordered());
    }
}
