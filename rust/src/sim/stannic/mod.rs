//! STANNIC — the schedule-centric systolic microarchitecture (Section 6).
//!
//! One [`Smmu`] (Systolic Memory Management Unit) per machine, each
//! owning a [`pe::PeArray`]; a single shared iterative Cost Comparator
//! performs the inter-machine Phase II argmin, exactly like the hardware.

pub mod pe;
pub mod timing;

use std::collections::VecDeque;

use crate::core::{Job, MachineId};
use crate::quant::Precision;
use crate::scheduler::{Assignment, TickOutcome, FULL_COST};
use crate::sim::{ArchSim, IterationKind, IterationStats};

use pe::{PeArray, ThresholdRead};

/// One machine's SMMU: systolic PE array + local cost calculator state.
#[derive(Debug, Clone)]
pub struct Smmu {
    pub array: PeArray,
}

impl Smmu {
    fn new(depth: usize) -> Self {
        Smmu {
            array: PeArray::new(depth),
        }
    }

    /// The SMMU-local Cost Calculator: threshold lookup + two MACs.
    fn cost(&self, j_w: f32, j_eps: f32, j_t: f32) -> (f32, ThresholdRead) {
        let read = self.array.threshold_read(j_t);
        let cost = if read.full {
            FULL_COST
        } else {
            j_w * (j_eps + read.sum_hi) + j_eps * read.sum_lo
        };
        (cost, read)
    }
}

/// Cycle-accurate STANNIC simulator.
pub struct StannicSim {
    smmus: Vec<Smmu>,
    depth: usize,
    alpha: f32,
    precision: Precision,
    pending: VecDeque<Job>,
    stats: IterationStats,
    tick_no: u64,
    /// Debug-mode invariant checking of Definition 4 after every tick.
    check_invariants: bool,
}

impl StannicSim {
    pub fn new(machines: usize, depth: usize, alpha: f32, precision: Precision) -> Self {
        let mut stats = IterationStats::default();
        stats.decision_latency = timing::decision_latency(machines, depth);
        StannicSim {
            smmus: (0..machines).map(|_| Smmu::new(depth)).collect(),
            depth,
            alpha,
            precision,
            pending: VecDeque::new(),
            stats,
            tick_no: 0,
            check_invariants: cfg!(debug_assertions),
        }
    }

    pub fn with_invariant_checks(mut self, on: bool) -> Self {
        self.check_invariants = on;
        self
    }

    pub fn smmu(&self, m: MachineId) -> &Smmu {
        &self.smmus[m]
    }

    fn assign(&mut self, job: &Job) -> Assignment {
        // Phase II: every SMMU computes its cost concurrently; the shared
        // iterative comparator scans machines in index order (ties keep
        // the earlier machine, matching the golden engine).
        let m_count = self.smmus.len();
        let mut best: Option<(usize, f32, ThresholdRead)> = None;
        for m in 0..m_count {
            let (j_w, j_eps, j_t) = self.precision.q_job(job.weight, job.ept[m]);
            let (c, read) = self.smmus[m].cost(j_w, j_eps, j_t);
            if c < FULL_COST && best.as_ref().map_or(true, |&(_, bc, _)| c < bc) {
                best = Some((m, c, read));
            }
        }
        let (machine, cost, read) = best.expect("caller ensured a free machine");
        let (j_w, j_eps, j_t) = self.precision.q_job(job.weight, job.ept[machine]);
        let alpha_pt = (self.alpha * j_eps).ceil() as u32;
        self.smmus[machine]
            .array
            .insert(read, job.id, j_w, j_eps, j_t, alpha_pt);
        Assignment {
            job: job.id,
            machine,
            position: read.pos,
            cost,
        }
    }
}

impl ArchSim for StannicSim {
    fn name(&self) -> &'static str {
        "stannic"
    }

    fn config(&self) -> (usize, usize) {
        (self.smmus.len(), self.depth)
    }

    fn submit(&mut self, job: Job) {
        self.pending.push_back(job);
    }

    fn tick(&mut self, arrival: Option<&Job>) -> TickOutcome {
        self.tick_no += 1;
        if let Some(j) = arrival {
            self.pending.push_back(j.clone());
        }
        let mut out = TickOutcome::default();

        // alpha check (Head PEs only): pop ready heads.
        for (m, s) in self.smmus.iter_mut().enumerate() {
            if s.array.head().is_some_and(|h| h.n >= h.alpha_pt) {
                let id = s.array.pop();
                out.released.push((id, m));
            }
        }

        // cost + insert for the oldest pending arrival.
        if !self.pending.is_empty() {
            if self.smmus.iter().any(|s| !s.array.is_full()) {
                let job = self.pending.pop_front().expect("non-empty");
                out.assigned = Some(self.assign(&job));
            } else {
                out.stalled = true;
            }
        }

        // standard alpha updates everywhere (heads accrue VW).
        for s in &mut self.smmus {
            s.array.standard_update();
        }

        if self.check_invariants {
            for (m, s) in self.smmus.iter().enumerate() {
                debug_assert!(
                    s.array.properly_ordered(),
                    "machine {m} lost proper ordering at tick {}",
                    self.tick_no
                );
            }
        }

        // cycle accounting
        let (m, d) = self.config();
        let kind = IterationKind::classify(!out.released.is_empty(), out.assigned.is_some());
        let cycles = match kind {
            IterationKind::Standard => timing::standard_latency(m, d),
            IterationKind::Pop => timing::pop_latency(m, d),
            IterationKind::Insert => timing::insert_latency(m, d),
            IterationKind::PopInsert => timing::pop_insert_latency(m, d),
        };
        self.stats.record(kind, cycles);
        out
    }

    fn stats(&self) -> &IterationStats {
        &self.stats
    }

    fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.smmus.iter().all(|s| s.array.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::MachinePark;
    use crate::scheduler::SosEngine;
    use crate::sim::lockstep_verify;
    use crate::workload::{generate_trace, WorkloadSpec};

    #[test]
    fn lockstep_parity_with_golden() {
        let park = MachinePark::paper_m1_m5();
        let trace = generate_trace(&WorkloadSpec::default(), &park, 500, 31);
        let mut golden = SosEngine::new(5, 10, 0.5, Precision::Int8);
        let mut sim = StannicSim::new(5, 10, 0.5, Precision::Int8);
        lockstep_verify(&mut sim, &mut golden, &trace, 500_000).unwrap();
        assert!(sim.stats().iterations() > 0);
    }

    #[test]
    fn lockstep_parity_large_config() {
        let park = MachinePark::cycled(20);
        let trace = generate_trace(&WorkloadSpec::default(), &park, 300, 77);
        let mut golden = SosEngine::new(20, 10, 0.5, Precision::Int8);
        let mut sim = StannicSim::new(20, 10, 0.5, Precision::Int8);
        lockstep_verify(&mut sim, &mut golden, &trace, 500_000).unwrap();
    }

    #[test]
    fn decision_latency_reported() {
        let sim = StannicSim::new(10, 20, 0.5, Precision::Int8);
        assert_eq!(sim.stats().decision_latency, 75);
    }

    #[test]
    fn iteration_kinds_counted() {
        let park = MachinePark::paper_m1_m5();
        let trace = generate_trace(&WorkloadSpec::default(), &park, 100, 3);
        let mut golden = SosEngine::new(5, 10, 0.5, Precision::Int8);
        let mut sim = StannicSim::new(5, 10, 0.5, Precision::Int8);
        lockstep_verify(&mut sim, &mut golden, &trace, 500_000).unwrap();
        let s = sim.stats();
        assert_eq!(
            s.count(IterationKind::Insert) + s.count(IterationKind::PopInsert),
            100,
            "one assignment iteration per job"
        );
        // pops can coalesce (several machines release in one iteration),
        // so the pop-iteration count is bounded by, not equal to, 100.
        let pop_iters = s.count(IterationKind::Pop) + s.count(IterationKind::PopInsert);
        assert!(pop_iters > 0 && pop_iters <= 100);
        assert!(s.count(IterationKind::Standard) > 0);
    }
}
