//! STANNIC timing model — cycles per scheduling iteration.
//!
//! Derived from the Section 6 dataflow and calibrated against Fig. 18a:
//! the measured averages across C1–C4 (5×10, 5×20, 10×10, 10×20) are
//! 62 cycles with ≈5 extra cycles per additional machine and *negligible*
//! sensitivity to virtual-schedule depth (the systolic threshold lookup
//! replaces the depth-wide summation).
//!
//! Decision-path breakdown (Insert iteration, the full `A->C->D->E->F`
//! path that Fig. 18a reports):
//!
//! | stage                                   | cycles       |
//! |-----------------------------------------|--------------|
//! | host interface / job intake             | 6            |
//! | broadcast bus drive (T_j, W, eps)       | 2            |
//! | local PE compare C (all PEs, parallel)  | 1            |
//! | threshold self-identification (C_L/C_R) | 2            |
//! | memoized sum volunteer (bus arbitration)| 2            |
//! | SMMU cost calc (2 mul + 2 add, all M in parallel) | 4  |
//! | iterative cost comparator               | 5 per machine|
//! | insert broadcast + writeback (single)   | 4            |
//! | control / FSM overhead                  | 4            |
//!
//! Total: `25 + 5·M` — e.g. 50 cycles at M=5, 75 at M=10 (avg 62.5 over
//! C1–C4, matching the paper's reported 62 within 1%).

/// Cycles for the full decision (Insert) path — the Fig. 18a metric.
pub fn decision_latency(machines: usize, _depth: usize) -> u64 {
    FIXED + PER_MACHINE * machines as u64
}

/// Fixed pipeline cost of the decision path (see table above).
pub const FIXED: u64 = 25;
/// Iterative cost comparator cost per machine.
pub const PER_MACHINE: u64 = 5;

/// Cycles for a Standard iteration: Section 3.2 — "We track and update
/// n_K(t_J) in every clock cycle". The alpha updates are single-cycle
/// parallel register decrements in every PE; a no-decision tick costs
/// exactly one clock in hardware.
pub fn standard_latency(_machines: usize, _depth: usize) -> u64 {
    1
}

/// Cycles for a Pop iteration: alpha check fires, Δα broadcast, parallel
/// subtract, synchronous left shift, queue handoff.
pub fn pop_latency(_machines: usize, _depth: usize) -> u64 {
    4
}

/// Cycles for an Insert iteration (the full decision path).
pub fn insert_latency(machines: usize, depth: usize) -> u64 {
    decision_latency(machines, depth)
}

/// Cycles for the fused Pop+Insert iteration: the pop overlaps with the
/// cost query (the head sets C=0), costing only the extra Δα broadcast.
pub fn pop_insert_latency(machines: usize, depth: usize) -> u64 {
    decision_latency(machines, depth) + 3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_against_fig18a() {
        // C1–C4 average must land on the paper's 62 cycles (±2%).
        let configs = [(5, 10), (5, 20), (10, 10), (10, 20)];
        let avg: f64 = configs
            .iter()
            .map(|&(m, d)| decision_latency(m, d) as f64)
            .sum::<f64>()
            / 4.0;
        assert!((avg - 62.0).abs() / 62.0 < 0.02, "avg {avg}");
    }

    #[test]
    fn per_machine_scaling_is_about_5() {
        let a = decision_latency(10, 10);
        let b = decision_latency(11, 10);
        assert_eq!(b - a, 5);
    }

    #[test]
    fn depth_insensitive() {
        assert_eq!(decision_latency(10, 10), decision_latency(10, 100));
    }

    #[test]
    fn path_ordering() {
        // standard < pop < insert < pop+insert
        let (m, d) = (10, 20);
        assert!(standard_latency(m, d) < pop_latency(m, d));
        assert!(pop_latency(m, d) < insert_latency(m, d));
        assert!(insert_latency(m, d) < pop_insert_latency(m, d));
    }
}
