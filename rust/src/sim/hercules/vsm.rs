//! Virtual Schedule Manager (Section 4.1.7): a configurable shift-
//! register structure storing Job IDs in WSPT order. Supports the three
//! register movements of Fig. 6d — full/partial left shift on insert,
//! right shift on release — via each register's four-input Data Selector
//! (left neighbour, right neighbour, new job, hold).

use crate::core::JobId;

/// The per-register Data Selector control (Fig. 6d).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum DsCtl {
    Hold,
    FromLeft,  // take value of index k-1 (used on insert right-of-p shifts)
    FromRight, // take value of index k+1 (used on release)
    LoadNew,
}

/// Shift-register VSM for one machine.
#[derive(Debug, Clone)]
pub struct Vsm {
    regs: Vec<Option<JobId>>,
}

impl Vsm {
    pub fn new(depth: usize) -> Self {
        Vsm {
            regs: vec![None; depth],
        }
    }

    pub fn head(&self) -> Option<JobId> {
        self.regs[0]
    }

    pub fn len(&self) -> usize {
        self.regs.iter().take_while(|r| r.is_some()).count()
    }

    pub fn is_full(&self) -> bool {
        self.regs.last().is_some_and(|r| r.is_some())
    }

    pub fn is_empty(&self) -> bool {
        self.regs[0].is_none()
    }

    pub fn ids(&self) -> impl Iterator<Item = JobId> + '_ {
        self.regs.iter().filter_map(|r| *r)
    }

    /// Apply one cycle of data-selector controls to every register —
    /// the hardware's synchronous update. (Controls are computed first,
    /// then applied at the clock edge, so "FromLeft"/"FromRight" read the
    /// *pre-update* neighbour values.)
    fn apply(&mut self, ctl: &[DsCtl], new_id: JobId) {
        let old = self.regs.clone();
        let d = old.len();
        for k in 0..d {
            self.regs[k] = match ctl[k] {
                DsCtl::Hold => old[k],
                DsCtl::FromLeft => {
                    if k == 0 {
                        None
                    } else {
                        old[k - 1]
                    }
                }
                DsCtl::FromRight => {
                    if k + 1 == d {
                        None
                    } else {
                        old[k + 1]
                    }
                }
                DsCtl::LoadNew => Some(new_id),
            };
        }
    }

    /// Release the head (pop from AC): every register takes its right
    /// neighbour (`J_{k-1} <- J_k` in the paper's indexing).
    pub fn release(&mut self) -> Option<JobId> {
        let head = self.regs[0]?;
        let ctl = vec![DsCtl::FromRight; self.regs.len()];
        self.apply(&ctl, 0);
        Some(head)
    }

    /// Insert a new job at index `p` (from the CC's Job Index
    /// Calculator): registers `< p` hold, register `p` loads the new job,
    /// registers `> p` take their left neighbour (partial left shift).
    pub fn insert(&mut self, p: usize, id: JobId) {
        debug_assert!(!self.is_full(), "insert into full VSM");
        debug_assert!(p <= self.len());
        let d = self.regs.len();
        let mut ctl = vec![DsCtl::Hold; d];
        for k in ctl.iter_mut().take(d).skip(p + 1) {
            *k = DsCtl::FromLeft;
        }
        ctl[p] = DsCtl::LoadNew;
        self.apply(&ctl, id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_and_release_preserve_order() {
        let mut v = Vsm::new(4);
        v.insert(0, 10);
        v.insert(0, 20); // 20 outranks -> head
        v.insert(1, 15);
        assert_eq!(v.ids().collect::<Vec<_>>(), vec![20, 15, 10]);
        assert_eq!(v.release(), Some(20));
        assert_eq!(v.ids().collect::<Vec<_>>(), vec![15, 10]);
        assert_eq!(v.len(), 2);
    }

    #[test]
    fn insert_at_tail() {
        let mut v = Vsm::new(3);
        v.insert(0, 1);
        v.insert(1, 2);
        v.insert(2, 3);
        assert!(v.is_full());
        assert_eq!(v.ids().collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn release_empty_is_none() {
        let mut v = Vsm::new(2);
        assert_eq!(v.release(), None);
    }

    #[test]
    #[should_panic]
    fn insert_full_panics_in_debug() {
        let mut v = Vsm::new(1);
        v.insert(0, 1);
        v.insert(0, 2);
    }
}
