//! HERCULES timing model — cycles per scheduling iteration.
//!
//! Derived from the Section 4 pipeline and the Section 5 bottleneck
//! analysis, calibrated against Fig. 18a: average 466 cycles across
//! C1–C4, ≈7 extra cycles per machine, and a *strong* dependence on
//! virtual-schedule depth (the paper: "latency of Hercules significantly
//! increases with the increased depth of the Virtual Schedules").
//!
//! Decision-path breakdown:
//!
//! | stage                                           | cycles          |
//! |-------------------------------------------------|-----------------|
//! | batched host memory interface (X-entry table scan) | 10 per depth |
//! | MMU/VSM/JMM coherency handshakes                | 10 per depth    |
//! | JMM bank read through MMU                       | 12              |
//! | CC: IJCC evaluate + mask                        | 10              |
//! | CC: tree adders                                 | 8 per stage     |
//! | iterative cost comparator                       | 7 per machine   |
//! | MMU alloc + JMM write + VSM/AC update           | 24              |
//! | control / FSM                                   | 32              |
//!
//! Total: `78 + 7·M + 20·d + 8·ceil(log2 d)` — C1: 345, C2: 557, C3: 380,
//! C4: 592; average 468.5 ≈ the paper's 466 (0.5%).

use super::cost_calc::tree_stages;

/// Fixed pipeline cost (JMM read 12 + IJCC 10 + alloc/write 24 + FSM 32).
pub const FIXED: u64 = 78;
/// Iterative cost comparator cost per machine.
pub const PER_MACHINE: u64 = 7;
/// Batch interface + coherency cost per virtual-schedule slot.
pub const PER_DEPTH: u64 = 20;
/// Tree-adder cost per reduction stage.
pub const PER_TREE_STAGE: u64 = 8;

/// Cycles for the full decision (Insert) path — the Fig. 18a metric.
pub fn decision_latency(machines: usize, depth: usize) -> u64 {
    FIXED
        + PER_MACHINE * machines as u64
        + PER_DEPTH * depth as u64
        + PER_TREE_STAGE * tree_stages(depth) as u64
}

/// Standard iteration: Section 3.2 — `n_K` is updated every clock cycle;
/// the JMM registers and AC countdowns decrement in parallel. A
/// no-decision tick costs one clock, same as Stannic (the architectures
/// differ on the *decision* path, not the idle tick).
pub fn standard_latency(_machines: usize, _depth: usize) -> u64 {
    1
}

/// Pop iteration: AC fire + VSM right shift + MMU invalidate + JMM
/// free-list update — the three-component coherency handshake the
/// Section 5 analysis calls out (vs Stannic's single-writeback pop).
pub fn pop_latency(_machines: usize, _depth: usize) -> u64 {
    12
}

/// Insert iteration — the full decision path.
pub fn insert_latency(machines: usize, depth: usize) -> u64 {
    decision_latency(machines, depth)
}

/// Pop+Insert: Hercules cannot overlap the two (separate components must
/// re-achieve coherency), so the pop path serializes before the insert.
pub fn pop_insert_latency(machines: usize, depth: usize) -> u64 {
    pop_latency(machines, depth) + decision_latency(machines, depth)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_against_fig18a() {
        let configs = [(5, 10), (5, 20), (10, 10), (10, 20)];
        let avg: f64 = configs
            .iter()
            .map(|&(m, d)| decision_latency(m, d) as f64)
            .sum::<f64>()
            / 4.0;
        assert!((avg - 466.0).abs() / 466.0 < 0.02, "avg {avg}");
    }

    #[test]
    fn per_machine_scaling_is_about_7() {
        assert_eq!(decision_latency(11, 10) - decision_latency(10, 10), 7);
    }

    #[test]
    fn depth_sensitivity() {
        // doubling depth should add hundreds of cycles (unlike Stannic)
        let delta = decision_latency(5, 20) - decision_latency(5, 10);
        assert!(delta >= 200, "depth delta {delta}");
    }

    #[test]
    fn average_ratio_is_about_7_5x() {
        // Section 8.3.1: Stannic averages a 7.5x reduction in iteration
        // latency over the C1-C4 comparison configurations.
        use crate::sim::stannic::timing as st;
        let configs = [(5usize, 10usize), (5, 20), (10, 10), (10, 20)];
        let h: f64 = configs.iter().map(|&(m, d)| decision_latency(m, d) as f64).sum();
        let s: f64 = configs.iter().map(|&(m, d)| st::decision_latency(m, d) as f64).sum();
        let ratio = h / s;
        assert!((7.0..8.0).contains(&ratio), "avg ratio {ratio}");
        // and Hercules is slower at every individual config
        for (m, d) in configs {
            assert!(decision_latency(m, d) > st::decision_latency(m, d));
        }
    }
}
