//! HERCULES — the task-centric pipelined microarchitecture (Section 4).
//!
//! Per machine: a register-based [`jmm::Jmm`] bank, a [`mmu::Mmu`]
//! bridging it to the [`vsm::Vsm`] shift register and the
//! [`alpha_check::AlphaCheck`] CAM, plus a [`cost_calc`] datapath of
//! IJCCs and tree adders. A single iterative Cost Comparator performs
//! the Phase II argmin. The decentralized coherency between JMM/VSM/MMU
//! is exactly what the Section 5 bottleneck analysis blames for the
//! architecture's latency and routing limits — and what the timing model
//! charges for.

pub mod alpha_check;
pub mod cost_calc;
pub mod jmm;
pub mod mmu;
pub mod timing;
pub mod vsm;

use std::collections::VecDeque;

use crate::core::Job;
use crate::quant::Precision;
use crate::scheduler::{Assignment, TickOutcome};
use crate::sim::{ArchSim, IterationKind, IterationStats};

use alpha_check::AlphaCheck;
use cost_calc::cost_calculator;
use jmm::{Jmm, JmmEntry};
use mmu::Mmu;
use vsm::Vsm;

/// Per-machine scheduler slice (Fig. 4's per-machine components).
#[derive(Debug, Clone)]
struct MachineSlice {
    jmm: Jmm,
    mmu: Mmu,
    vsm: Vsm,
    ac: AlphaCheck,
}

impl MachineSlice {
    fn new(depth: usize) -> Self {
        MachineSlice {
            jmm: Jmm::new(depth),
            mmu: Mmu::new(depth),
            vsm: Vsm::new(depth),
            ac: AlphaCheck::new(depth),
        }
    }
}

/// Cycle-accurate HERCULES simulator.
pub struct HerculesSim {
    slices: Vec<MachineSlice>,
    depth: usize,
    alpha: f32,
    precision: Precision,
    pending: VecDeque<Job>,
    stats: IterationStats,
    tick_no: u64,
}

impl HerculesSim {
    pub fn new(machines: usize, depth: usize, alpha: f32, precision: Precision) -> Self {
        let mut stats = IterationStats::default();
        stats.decision_latency = timing::decision_latency(machines, depth);
        HerculesSim {
            slices: (0..machines).map(|_| MachineSlice::new(depth)).collect(),
            depth,
            alpha,
            precision,
            pending: VecDeque::new(),
            stats,
            tick_no: 0,
        }
    }

    fn assign(&mut self, job: &Job) -> Assignment {
        // Phase II: each machine's CC computes concurrently; the CR scans
        // costs iteratively (lowest index wins ties).
        let m_count = self.slices.len();
        let mut best: Option<(usize, f32, usize)> = None;
        for m in 0..m_count {
            if self.slices[m].vsm.is_full() {
                continue; // full V_i cannot be selected
            }
            let (j_w, j_eps, j_t) = self.precision.q_job(job.weight, job.ept[m]);
            let out = cost_calculator(self.slices[m].jmm.bank(), j_w, j_eps, j_t);
            if best.map_or(true, |(_, bc, _)| out.cost < bc) {
                best = Some((m, out.cost, out.index));
            }
        }
        let (machine, cost, index) = best.expect("caller ensured a free machine");
        let (j_w, j_eps, j_t) = self.precision.q_job(job.weight, job.ept[machine]);
        let slice = &mut self.slices[machine];
        // CR informs CC -> CC requests a free address from the MMU ->
        // JMM stores the metadata; VSM partial-left-shift insert; AC
        // starts tracking the alpha countdown.
        let addr = slice.mmu.alloc(job.id).expect("VSM not full => JMM free");
        slice.jmm.write(
            addr,
            JmmEntry {
                valid: true,
                id: job.id,
                rem_hi: j_eps,
                rem_lo: j_w,
                t: j_t,
            },
        );
        slice.vsm.insert(index, job.id);
        slice.ac.track(job.id, (self.alpha * j_eps).ceil() as u32);
        Assignment {
            job: job.id,
            machine,
            position: index,
            cost,
        }
    }
}

impl ArchSim for HerculesSim {
    fn name(&self) -> &'static str {
        "hercules"
    }

    fn config(&self) -> (usize, usize) {
        (self.slices.len(), self.depth)
    }

    fn submit(&mut self, job: Job) {
        self.pending.push_back(job);
    }

    fn tick(&mut self, arrival: Option<&Job>) -> TickOutcome {
        self.tick_no += 1;
        if let Some(j) = arrival {
            self.pending.push_back(j.clone());
        }
        let mut out = TickOutcome::default();

        // (1) AC pop: head countdown exhausted -> release; MMU
        // invalidates the metadata, VSM right-shifts, CAM evicts.
        for (m, slice) in self.slices.iter_mut().enumerate() {
            if let Some(head) = slice.vsm.head() {
                if slice.ac.ready(head) {
                    let released = slice.vsm.release().expect("head exists");
                    debug_assert_eq!(released, head);
                    let addr = slice.mmu.invalidate(head).expect("tracked");
                    slice.jmm.invalidate(addr);
                    slice.ac.evict(head);
                    out.released.push((head, m));
                }
            }
        }

        // (2) Phase II for the oldest pending arrival.
        if !self.pending.is_empty() {
            if self.slices.iter().any(|s| !s.vsm.is_full()) {
                let job = self.pending.pop_front().expect("non-empty");
                out.assigned = Some(self.assign(&job));
            } else {
                out.stalled = true;
            }
        }

        // (3) VW update: head's JMM entry decrements (rem_hi by 1,
        // rem_lo by T) and its AC countdown steps.
        for slice in &mut self.slices {
            if let Some(head) = slice.vsm.head() {
                let addr = slice.mmu.lookup(head).expect("head tracked");
                let e = slice.jmm.read_mut(addr);
                e.rem_hi -= 1.0;
                e.rem_lo -= e.t;
                slice.ac.decrement(head);
            }
        }

        // cycle accounting
        let (m, d) = self.config();
        let kind = IterationKind::classify(!out.released.is_empty(), out.assigned.is_some());
        let cycles = match kind {
            IterationKind::Standard => timing::standard_latency(m, d),
            IterationKind::Pop => timing::pop_latency(m, d),
            IterationKind::Insert => timing::insert_latency(m, d),
            IterationKind::PopInsert => timing::pop_insert_latency(m, d),
        };
        self.stats.record(kind, cycles);
        out
    }

    fn stats(&self) -> &IterationStats {
        &self.stats
    }

    fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.slices.iter().all(|s| s.vsm.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::MachinePark;
    use crate::scheduler::SosEngine;
    use crate::sim::lockstep_verify;
    use crate::workload::{generate_trace, WorkloadSpec};

    #[test]
    fn lockstep_parity_with_golden() {
        let park = MachinePark::paper_m1_m5();
        let trace = generate_trace(&WorkloadSpec::default(), &park, 500, 31);
        let mut golden = SosEngine::new(5, 10, 0.5, Precision::Int8);
        let mut sim = HerculesSim::new(5, 10, 0.5, Precision::Int8);
        lockstep_verify(&mut sim, &mut golden, &trace, 500_000).unwrap();
    }

    #[test]
    fn lockstep_parity_deep_schedules() {
        let park = MachinePark::paper_m1_m5();
        let trace = generate_trace(
            &WorkloadSpec::default().with_burst(5, crate::workload::BurstType::Uniform),
            &park,
            400,
            13,
        );
        let mut golden = SosEngine::new(5, 20, 0.5, Precision::Int8);
        let mut sim = HerculesSim::new(5, 20, 0.5, Precision::Int8);
        lockstep_verify(&mut sim, &mut golden, &trace, 500_000).unwrap();
    }

    #[test]
    fn hercules_and_stannic_produce_identical_schedules() {
        // Section 8: "Due to the two architectures implementing the same
        // scheduling algorithm, the resulting schedules from both
        // Hercules and Stannic are identical."
        use crate::sim::stannic::StannicSim;
        let park = MachinePark::paper_m1_m5();
        let trace = generate_trace(&WorkloadSpec::memory_skewed(), &park, 300, 47);
        let mut h = HerculesSim::new(5, 10, 0.5, Precision::Int8);
        let mut s = StannicSim::new(5, 10, 0.5, Precision::Int8);
        let mut events = trace.events().iter().peekable();
        for t in 1..=500_000u64 {
            while events.peek().is_some_and(|e| e.tick <= t) {
                let j = events.next().unwrap().job.clone().unwrap();
                h.submit(j.clone());
                s.submit(j);
            }
            let ho = h.tick(None);
            let so = s.tick(None);
            assert_eq!(ho.released, so.released, "tick {t}");
            assert_eq!(
                ho.assigned.as_ref().map(|a| (a.job, a.machine, a.position)),
                so.assigned.as_ref().map(|a| (a.job, a.machine, a.position)),
                "tick {t}"
            );
            if h.is_idle() && s.is_idle() && events.peek().is_none() {
                break;
            }
        }
        assert!(h.is_idle() && s.is_idle());
        // ... while Stannic does it in ~7.5x fewer cycles on the
        // decision path (Fig. 18a).
        let ratio = h.stats().decision_latency as f64 / s.stats().decision_latency as f64;
        assert!(ratio > 5.0, "decision-latency ratio {ratio}");
    }

    #[test]
    fn decision_latency_reported() {
        let sim = HerculesSim::new(10, 20, 0.5, Precision::Int8);
        assert_eq!(sim.stats().decision_latency, timing::decision_latency(10, 20));
    }
}
