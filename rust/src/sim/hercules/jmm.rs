//! Job Metadata Memory (Section 4.1.1): a fully register-based M×N array
//! holding each tracked job's attributes. Entries live at *arbitrary*
//! addresses handed out by the MMU's free list — WSPT ordering exists
//! only in the VSM, which is precisely the decentralization the paper
//! identifies as Hercules's bottleneck.

use crate::core::JobId;

/// One JMM register (Fig. 5): Job ID tag plus the per-job running cost
/// state of Section 3.3 — `sum^H`-contribution (`eps - n`),
/// `sum^L`-contribution (`W - n·T`), and the stored WSPT ratio.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JmmEntry {
    pub valid: bool,
    pub id: JobId,
    /// Remaining HI contribution, initialized to `eps`, decremented by 1
    /// per cycle of virtual work.
    pub rem_hi: f32,
    /// Remaining LO contribution, initialized to `W`, decremented by `T`
    /// per cycle of virtual work.
    pub rem_lo: f32,
    /// Stored WSPT ratio `T_i^K` (division done once at job creation).
    pub t: f32,
}

impl JmmEntry {
    pub const INVALID: JmmEntry = JmmEntry {
        valid: false,
        id: 0,
        rem_hi: 0.0,
        rem_lo: 0.0,
        t: 0.0,
    };
}

/// One machine's bank of N registers.
#[derive(Debug, Clone)]
pub struct Jmm {
    regs: Vec<JmmEntry>,
}

impl Jmm {
    pub fn new(depth: usize) -> Self {
        Jmm {
            regs: vec![JmmEntry::INVALID; depth],
        }
    }

    pub fn read(&self, addr: usize) -> &JmmEntry {
        &self.regs[addr]
    }

    pub fn read_mut(&mut self, addr: usize) -> &mut JmmEntry {
        &mut self.regs[addr]
    }

    pub fn write(&mut self, addr: usize, e: JmmEntry) {
        self.regs[addr] = e;
    }

    pub fn invalidate(&mut self, addr: usize) {
        self.regs[addr] = JmmEntry::INVALID;
    }

    /// All registers (the CC reads the full bank every query).
    pub fn bank(&self) -> &[JmmEntry] {
        &self.regs
    }

    pub fn occupancy(&self) -> usize {
        self.regs.iter().filter(|e| e.valid).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_invalidate() {
        let mut j = Jmm::new(4);
        assert_eq!(j.occupancy(), 0);
        j.write(
            2,
            JmmEntry {
                valid: true,
                id: 7,
                rem_hi: 20.0,
                rem_lo: 40.0,
                t: 2.0,
            },
        );
        assert_eq!(j.read(2).id, 7);
        assert_eq!(j.occupancy(), 1);
        j.invalidate(2);
        assert!(!j.read(2).valid);
        assert_eq!(j.occupancy(), 0);
    }
}
