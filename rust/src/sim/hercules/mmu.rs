//! Memory Management Unit (Section 4.1.4): the gatekeeper between the
//! decentralized JMM / VSM / AC components. Maintains (1) a lookup table
//! mapping Job ID -> JMM address and (2) a FIFO of free addresses.

use std::collections::{HashMap, VecDeque};

use crate::core::JobId;

#[derive(Debug, Clone)]
pub struct Mmu {
    lut: HashMap<JobId, usize>,
    free: VecDeque<usize>,
}

impl Mmu {
    pub fn new(depth: usize) -> Self {
        Mmu {
            lut: HashMap::with_capacity(depth),
            free: (0..depth).collect(),
        }
    }

    /// Allocate an address for a new job (the CC's metadata-write request).
    pub fn alloc(&mut self, id: JobId) -> Option<usize> {
        let addr = self.free.pop_front()?;
        let prev = self.lut.insert(id, addr);
        debug_assert!(prev.is_none(), "duplicate job id {id}");
        Some(addr)
    }

    /// Resolve a job's metadata address.
    pub fn lookup(&self, id: JobId) -> Option<usize> {
        self.lut.get(&id).copied()
    }

    /// Invalidate on the alpha-check's release signal; the address is
    /// queued for reuse.
    pub fn invalidate(&mut self, id: JobId) -> Option<usize> {
        let addr = self.lut.remove(&id)?;
        self.free.push_back(addr);
        Some(addr)
    }

    pub fn free_count(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_lookup_invalidate_cycle() {
        let mut m = Mmu::new(2);
        let a = m.alloc(10).unwrap();
        let b = m.alloc(11).unwrap();
        assert_ne!(a, b);
        assert!(m.alloc(12).is_none(), "bank full");
        assert_eq!(m.lookup(10), Some(a));
        assert_eq!(m.invalidate(10), Some(a));
        assert_eq!(m.lookup(10), None);
        // freed address is reused (FIFO)
        assert_eq!(m.alloc(13), Some(a));
        assert_eq!(m.free_count(), 0);
        let _ = b;
    }
}
