//! Alpha-check module (Section 4.1.6): a Content Addressable Memory of
//! size N keyed by Job ID, holding each job's remaining head-time
//! countdown `t = ceil(alpha * eps)`. The countdown of the job currently
//! at `Head.V_i` decrements every clock cycle; at zero the job pops.

use crate::core::JobId;

/// CAM entry: (tag, content).
#[derive(Debug, Clone, Copy)]
struct CamEntry {
    tag: JobId,
    countdown: u32,
}

/// Per-machine alpha-check CAM.
#[derive(Debug, Clone)]
pub struct AlphaCheck {
    cam: Vec<Option<CamEntry>>,
}

impl AlphaCheck {
    pub fn new(depth: usize) -> Self {
        AlphaCheck {
            cam: vec![None; depth],
        }
    }

    /// Associative write into any free way.
    pub fn track(&mut self, id: JobId, countdown: u32) {
        let way = self
            .cam
            .iter()
            .position(|e| e.is_none())
            .expect("CAM has a way per VSM slot");
        self.cam[way] = Some(CamEntry {
            tag: id,
            countdown,
        });
    }

    /// Content match on the head job's tag; decrement its countdown.
    pub fn decrement(&mut self, head: JobId) {
        for e in self.cam.iter_mut().flatten() {
            if e.tag == head {
                e.countdown = e.countdown.saturating_sub(1);
                return;
            }
        }
        debug_assert!(false, "head {head} not tracked in CAM");
    }

    /// Is the head job's countdown exhausted (ready to pop)?
    pub fn ready(&self, head: JobId) -> bool {
        self.cam
            .iter()
            .flatten()
            .any(|e| e.tag == head && e.countdown == 0)
    }

    /// Invalidate the entry on release.
    pub fn evict(&mut self, id: JobId) {
        for e in self.cam.iter_mut() {
            if e.is_some_and(|x| x.tag == id) {
                *e = None;
                return;
            }
        }
        debug_assert!(false, "evict of untracked id {id}");
    }

    pub fn occupancy(&self) -> usize {
        self.cam.iter().flatten().count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn countdown_to_release() {
        let mut ac = AlphaCheck::new(2);
        ac.track(5, 3);
        assert!(!ac.ready(5));
        ac.decrement(5);
        ac.decrement(5);
        assert!(!ac.ready(5));
        ac.decrement(5);
        assert!(ac.ready(5));
        ac.evict(5);
        assert_eq!(ac.occupancy(), 0);
    }

    #[test]
    fn non_head_entries_freeze() {
        let mut ac = AlphaCheck::new(2);
        ac.track(1, 2);
        ac.track(2, 2);
        ac.decrement(1);
        ac.decrement(1);
        assert!(ac.ready(1));
        assert!(!ac.ready(2), "only the head decrements");
    }

    #[test]
    fn zero_countdown_is_immediately_ready() {
        let mut ac = AlphaCheck::new(1);
        ac.track(3, 0);
        assert!(ac.ready(3));
    }
}
