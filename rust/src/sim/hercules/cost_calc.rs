//! Cost Calculator (Section 4.1.2/4.1.3): N Individual Job Cost
//! Calculators feeding two tree adders (TAH / TAL) plus the popcount
//! Job Index Calculator. Every IJCC computes *both* candidate cost
//! contributions and masks the irrelevant one — the redundant circuitry
//! the paper calls out as a Hercules bottleneck.

use super::jmm::JmmEntry;

/// Output of one IJCC (Fig. 6b).
#[derive(Debug, Clone, Copy, Default)]
pub struct IjccOut {
    /// Masked `sum^H` contribution (0 unless valid and `T_K >= T_J`).
    pub hi: f32,
    /// Masked `sum^L` contribution (0 unless valid and `T_K < T_J`).
    pub lo: f32,
    /// WSPT comparator output (1 when `T_K >= T_J`), fed to the Job
    /// Index Calculator.
    pub cmp: bool,
}

/// One IJCC evaluation.
pub fn ijcc(entry: &JmmEntry, j_t: f32, j_valid: bool) -> IjccOut {
    if !entry.valid || !j_valid {
        return IjccOut::default();
    }
    let cmp = entry.t >= j_t;
    IjccOut {
        hi: if cmp { entry.rem_hi } else { 0.0 },
        lo: if cmp { 0.0 } else { entry.rem_lo },
        cmp,
    }
}

/// Single-cycle tree adder: N-1 adders in ceil(log2 N) stages. We model
/// the staged reduction explicitly (and test it equals a linear sum) —
/// the stage count feeds the timing model.
pub fn tree_add(values: &[f32]) -> f32 {
    if values.is_empty() {
        return 0.0;
    }
    let mut layer: Vec<f32> = values.to_vec();
    while layer.len() > 1 {
        let mut next = Vec::with_capacity(layer.len().div_ceil(2));
        for pair in layer.chunks(2) {
            next.push(if pair.len() == 2 {
                pair[0] + pair[1]
            } else {
                pair[0]
            });
        }
        layer = next;
    }
    layer[0]
}

/// Number of adder stages for a depth-N tree (timing model input).
pub fn tree_stages(n: usize) -> u32 {
    (usize::BITS - n.saturating_sub(1).leading_zeros()).max(1)
}

/// Full CC evaluation for one machine: cost of the probe job plus its
/// VSM insertion index.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CcOut {
    pub cost: f32,
    pub index: usize,
}

pub fn cost_calculator(bank: &[JmmEntry], j_w: f32, j_eps: f32, j_t: f32) -> CcOut {
    let outs: Vec<IjccOut> = bank.iter().map(|e| ijcc(e, j_t, true)).collect();
    let sum_hi = tree_add(&outs.iter().map(|o| o.hi).collect::<Vec<_>>());
    let sum_lo = tree_add(&outs.iter().map(|o| o.lo).collect::<Vec<_>>());
    // popcount of comparator bits = index in the WSPT-ordered VSM
    let index = outs.iter().filter(|o| o.cmp).count();
    CcOut {
        cost: j_w * (j_eps + sum_hi) + j_eps * sum_lo,
        index,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(id: u64, rem_hi: f32, rem_lo: f32, t: f32) -> JmmEntry {
        JmmEntry {
            valid: true,
            id,
            rem_hi,
            rem_lo,
            t,
        }
    }

    #[test]
    fn ijcc_masks_by_comparison() {
        let e = entry(1, 20.0, 40.0, 2.0);
        let hi_side = ijcc(&e, 1.0, true);
        assert_eq!((hi_side.hi, hi_side.lo, hi_side.cmp), (20.0, 0.0, true));
        let lo_side = ijcc(&e, 3.0, true);
        assert_eq!((lo_side.hi, lo_side.lo, lo_side.cmp), (0.0, 40.0, false));
        let invalid = ijcc(&JmmEntry::INVALID, 1.0, true);
        assert_eq!((invalid.hi, invalid.lo, invalid.cmp), (0.0, 0.0, false));
        let no_job = ijcc(&e, 1.0, false);
        assert_eq!((no_job.hi, no_job.lo), (0.0, 0.0));
    }

    #[test]
    fn tree_add_equals_linear_sum() {
        for n in 1..20 {
            let v: Vec<f32> = (0..n).map(|i| (i * 3 + 1) as f32).collect();
            let linear: f32 = v.iter().sum();
            assert_eq!(tree_add(&v), linear, "n={n}");
        }
        assert_eq!(tree_add(&[]), 0.0);
    }

    #[test]
    fn tree_stage_count() {
        assert_eq!(tree_stages(1), 1);
        assert_eq!(tree_stages(2), 1);
        assert_eq!(tree_stages(8), 3);
        assert_eq!(tree_stages(10), 4);
        assert_eq!(tree_stages(20), 5);
    }

    #[test]
    fn cc_matches_hand_example() {
        // Same example as scheduler::cost tests: K1(T2, hi20, lo40),
        // K2(T1, hi20, lo20), K3(T0.5, hi20, lo10); J(W15, eps15, T1).
        let bank = vec![
            entry(3, 20.0, 10.0, 0.5), // arbitrary address order
            JmmEntry::INVALID,
            entry(1, 20.0, 40.0, 2.0),
            entry(2, 20.0, 20.0, 1.0),
        ];
        let out = cost_calculator(&bank, 15.0, 15.0, 1.0);
        assert_eq!(out.cost, 975.0);
        assert_eq!(out.index, 2);
    }
}
