//! Baseline schedulers and software SOS implementations (Section 7.1
//! "Baseline schedulers" and Section 8.2's software comparators).
//!
//! * [`RoundRobin`] — classic cyclic dispatch (Silberschatz et al.).
//! * [`GreedyScheduler`] — assign to the machine with the least estimated
//!   completion time (Dong et al.).
//! * [`WsRoundRobin`] / [`WsGreedy`] — the work-stealing variants
//!   (Taskflow-style): idle machines steal pending jobs from the most
//!   loaded queue.
//! * [`SoscEngine`] — the paper's single-threaded C software baseline:
//!   a deliberately naive SOS implementation (per-query divisions, full
//!   recomputation) that must produce schedules identical to the golden
//!   engine while being much slower (it is the ST column of Fig. 16b).
//! * [`simd`] — the AVX-style lane-vectorised SOS of Fig. 17.
//!
//! These schedulers are no longer report fodder only: the competitive
//! portfolio meta-engine ([`crate::engine::portfolio`]) races
//! [`GreedyScheduler`], [`RoundRobin`], [`WsGreedy`] and
//! [`WsRoundRobin`] against the golden engine as live candidates,
//! shadow-replaying each decision window's arrivals through every
//! policy and switching the serving policy to the window winner. Any
//! behavioural change here therefore shifts portfolio switch decisions
//! — the determinism gates in `tests/portfolio.rs` and the ci.sh
//! portfolio A/B smoke will surface it as a switch-log digest change.

mod greedy;
mod rr;
pub mod simd;
mod sosc;
mod ws;

pub use greedy::GreedyScheduler;
pub use rr::RoundRobin;
pub use simd::SimdSos;
pub use sosc::SoscEngine;
pub use ws::{WsGreedy, WsRoundRobin};

use crate::cluster::WorkQueue;
use crate::core::MachineId;

/// Work stealing used by WSRR/WSG: every idle machine (not busy, empty
/// queue) steals the *tail* job of the longest pending queue, provided
/// that queue holds more than one job. Returns the moves performed.
pub(crate) fn steal(queues: &mut [WorkQueue]) -> Vec<(MachineId, MachineId)> {
    let mut moves = Vec::new();
    loop {
        let Some(thief) = queues
            .iter()
            .position(|q| !q.busy && q.pending.is_empty())
        else {
            break;
        };
        let Some(victim) = (0..queues.len())
            .filter(|&m| queues[m].pending.len() > 1)
            .max_by_key(|&m| queues[m].pending.len())
        else {
            break;
        };
        if victim == thief {
            break;
        }
        let job = queues[victim].pending.pop_back().expect("len > 1");
        queues[thief].pending.push_back(job);
        moves.push((victim, thief));
        // Loop again: several machines can be idle in the same tick, but
        // each steal fills one thief's queue, so the loop terminates.
    }
    moves
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Job, JobNature};

    fn job(id: u64, m: usize) -> Job {
        Job::new(id, 1.0, vec![10.0; m], JobNature::Mixed)
    }

    #[test]
    fn idle_machine_steals_from_longest_queue() {
        let mut queues: Vec<WorkQueue> = (0..3).map(|_| WorkQueue::default()).collect();
        for i in 0..4 {
            queues[0].pending.push_back(job(i, 3));
        }
        queues[1].pending.push_back(job(9, 3));
        let moves = steal(&mut queues);
        assert!(moves.contains(&(0, 2)));
        assert_eq!(queues[2].pending.len(), 1);
        assert_eq!(queues[2].pending[0].id, 3, "steals the tail");
    }

    #[test]
    fn no_steal_from_single_job_queue() {
        let mut queues: Vec<WorkQueue> = (0..2).map(|_| WorkQueue::default()).collect();
        queues[0].pending.push_back(job(1, 2));
        assert!(steal(&mut queues).is_empty());
        assert_eq!(queues[0].pending.len(), 1);
    }

    #[test]
    fn busy_machines_do_not_steal() {
        let mut queues: Vec<WorkQueue> = (0..2).map(|_| WorkQueue::default()).collect();
        for i in 0..3 {
            queues[0].pending.push_back(job(i, 2));
        }
        queues[1].busy = true;
        assert!(steal(&mut queues).is_empty());
    }
}
