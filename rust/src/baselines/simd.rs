//! AVX-style lane-vectorised software SOS — the Fig. 17 comparator.
//!
//! The paper's strongest software baseline vectorises the cost
//! computation with AVX SIMD. We reproduce its structure with explicit
//! 8-wide f32 lane blocks (`[f32; LANES]`) over struct-of-arrays virtual
//! schedule state, written so LLVM auto-vectorises the lane loops to
//! SSE/AVX on x86. The paper's observed failure mode is preserved by
//! construction: per-machine state lives in *separate* padded arrays, so
//! as the machine count grows the working set inflates and the head/tail
//! partial blocks ("misaligned with AVX vector bounds") become a larger
//! fraction of the work.
//!
//! Schedule parity with the golden engine is integration-tested; only
//! wall-clock differs.

use std::collections::VecDeque;

use crate::core::{Job, JobId};
use crate::quant::Precision;
use crate::scheduler::{Assignment, TickOutcome};

pub const LANES: usize = 8;

/// Struct-of-arrays virtual schedule for one machine, padded to LANES.
#[derive(Debug, Clone)]
struct LaneSchedule {
    ids: Vec<JobId>,
    t: Vec<f32>,      // WSPT per slot (0 padding)
    rem_hi: Vec<f32>, // eps - n
    rem_lo: Vec<f32>, // w - n*t
    eps: Vec<f32>,
    alpha_pt: Vec<u32>,
    n: Vec<u32>,
    len: usize,
}

impl LaneSchedule {
    fn new(depth: usize) -> Self {
        let cap = depth.div_ceil(LANES) * LANES;
        LaneSchedule {
            ids: Vec::with_capacity(cap),
            t: vec![0.0; cap],
            rem_hi: vec![0.0; cap],
            rem_lo: vec![0.0; cap],
            eps: vec![0.0; cap],
            alpha_pt: vec![0; cap],
            n: vec![0; cap],
            len: 0,
        }
    }

    /// Vectorised masked accumulation of sum^H and sum^L against `j_t`.
    /// Full blocks run as straight-line 8-lane arithmetic; the tail block
    /// falls back to a scalar loop (the "misalignment" cost).
    #[inline]
    fn sums(&self, j_t: f32) -> (f32, f32, usize) {
        let mut hi = [0.0f32; LANES];
        let mut lo = [0.0f32; LANES];
        let mut pos = 0usize;
        let full_blocks = self.len / LANES;
        for b in 0..full_blocks {
            let base = b * LANES;
            for l in 0..LANES {
                let i = base + l;
                let is_hi = self.t[i] >= j_t;
                // branchless select keeps the loop vectorisable
                hi[l] += if is_hi { self.rem_hi[i] } else { 0.0 };
                lo[l] += if is_hi { 0.0 } else { self.rem_lo[i] };
                pos += is_hi as usize;
            }
        }
        let mut s_hi: f32 = hi.iter().sum();
        let mut s_lo: f32 = lo.iter().sum();
        // scalar tail (partial block)
        for i in full_blocks * LANES..self.len {
            if self.t[i] >= j_t {
                s_hi += self.rem_hi[i];
                pos += 1;
            } else {
                s_lo += self.rem_lo[i];
            }
        }
        (s_hi, s_lo, pos)
    }

    fn insert(&mut self, pos: usize, id: JobId, w: f32, eps: f32, t: f32, alpha_pt: u32) {
        // shift everything right of pos by one (memmove-style)
        for i in (pos..self.len).rev() {
            self.t[i + 1] = self.t[i];
            self.rem_hi[i + 1] = self.rem_hi[i];
            self.rem_lo[i + 1] = self.rem_lo[i];
            self.eps[i + 1] = self.eps[i];
            self.alpha_pt[i + 1] = self.alpha_pt[i];
            self.n[i + 1] = self.n[i];
        }
        self.ids.insert(pos, id);
        self.t[pos] = t;
        self.rem_hi[pos] = eps;
        self.rem_lo[pos] = w;
        self.eps[pos] = eps;
        self.alpha_pt[pos] = alpha_pt;
        self.n[pos] = 0;
        self.len += 1;
    }

    fn pop_head(&mut self) -> JobId {
        let id = self.ids.remove(0);
        for i in 1..self.len {
            self.t[i - 1] = self.t[i];
            self.rem_hi[i - 1] = self.rem_hi[i];
            self.rem_lo[i - 1] = self.rem_lo[i];
            self.eps[i - 1] = self.eps[i];
            self.alpha_pt[i - 1] = self.alpha_pt[i];
            self.n[i - 1] = self.n[i];
        }
        self.len -= 1;
        self.t[self.len] = 0.0;
        self.rem_hi[self.len] = 0.0;
        self.rem_lo[self.len] = 0.0;
        id
    }

    fn accrue(&mut self) {
        if self.len > 0 {
            self.n[0] += 1;
            self.rem_hi[0] -= 1.0;
            self.rem_lo[0] -= self.t[0];
        }
    }
}

/// Lane-vectorised SOS engine (schedule-parity with the golden engine).
#[derive(Debug)]
pub struct SimdSos {
    schedules: Vec<LaneSchedule>,
    depth: usize,
    alpha: f32,
    precision: Precision,
    pending: VecDeque<Job>,
    tick_no: u64,
}

impl SimdSos {
    pub fn new(machines: usize, depth: usize, alpha: f32, precision: Precision) -> Self {
        SimdSos {
            schedules: (0..machines).map(|_| LaneSchedule::new(depth)).collect(),
            depth,
            alpha,
            precision,
            pending: VecDeque::new(),
            tick_no: 0,
        }
    }

    pub fn submit(&mut self, job: Job) {
        self.pending.push_back(job);
    }

    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.schedules.iter().all(|s| s.len == 0)
    }

    pub fn tick(&mut self, arrival: Option<&Job>) -> TickOutcome {
        self.tick_no += 1;
        if let Some(j) = arrival {
            self.pending.push_back(j.clone());
        }
        let mut out = TickOutcome::default();

        for (m, s) in self.schedules.iter_mut().enumerate() {
            if s.len > 0 && s.n[0] >= s.alpha_pt[0] {
                out.released.push((s.pop_head(), m));
            }
        }

        if !self.pending.is_empty() {
            if self.schedules.iter().any(|s| s.len < self.depth) {
                let job = self.pending.pop_front().expect("front checked");
                out.assigned = Some(self.assign(&job));
            } else {
                out.stalled = true;
            }
        }

        for s in &mut self.schedules {
            s.accrue();
        }
        out
    }

    fn assign(&mut self, job: &Job) -> Assignment {
        let m_count = self.schedules.len();
        let mut best: Option<(usize, f32, usize)> = None;
        for m in 0..m_count {
            if self.schedules[m].len >= self.depth {
                continue;
            }
            let (j_w, j_eps, j_t) = self.precision.q_job(job.weight, job.ept[m]);
            let (s_hi, s_lo, pos) = self.schedules[m].sums(j_t);
            let c = j_w * (j_eps + s_hi) + j_eps * s_lo;
            if best.map_or(true, |(_, bc, _)| c < bc) {
                best = Some((m, c, pos));
            }
        }
        let (machine, cost, position) = best.expect("caller ensured free machine");
        let (j_w, j_eps, j_t) = self.precision.q_job(job.weight, job.ept[machine]);
        self.schedules[machine].insert(
            position,
            job.id,
            j_w,
            j_eps,
            j_t,
            (self.alpha * j_eps).ceil() as u32,
        );
        Assignment {
            job: job.id,
            machine,
            position,
            cost,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::MachinePark;
    use crate::scheduler::SosEngine;
    use crate::workload::{generate_trace, WorkloadSpec};

    #[test]
    fn lane_schedule_sums_match_scalar() {
        let mut s = LaneSchedule::new(20);
        // descending T: 2.0, 1.5, 1.0, ..., insert in order
        for (i, t) in [2.0f32, 1.5, 1.0, 0.8, 0.5, 0.4, 0.3, 0.2, 0.1, 0.05]
            .iter()
            .enumerate()
        {
            s.insert(i, i as u64, *t * 10.0, 10.0, *t, 5);
        }
        let (hi, lo, pos) = s.sums(0.75);
        // HI = slots with T >= 0.75 -> 4 slots, rem_hi = eps = 10 each
        assert_eq!(hi, 40.0);
        assert_eq!(pos, 4);
        // LO = remaining 6 slots, rem_lo = w = t*10
        let want: f32 = [0.5f32, 0.4, 0.3, 0.2, 0.1, 0.05]
            .iter()
            .map(|t| t * 10.0)
            .sum();
        assert!((lo - want).abs() < 1e-5);
    }

    #[test]
    fn schedule_parity_with_golden_engine() {
        let park = MachinePark::cycled(12);
        let trace = generate_trace(&WorkloadSpec::default(), &park, 400, 23);
        let mut golden = SosEngine::new(12, 10, 0.5, Precision::Int8);
        let mut simd = SimdSos::new(12, 10, 0.5, Precision::Int8);

        let mut events = trace.events().iter().peekable();
        for t in 1..=500_000u64 {
            while events.peek().is_some_and(|e| e.tick <= t) {
                let j = events.next().unwrap().job.clone().unwrap();
                golden.submit(j.clone());
                simd.submit(j);
            }
            let g = golden.tick(None);
            let s = simd.tick(None);
            assert_eq!(g.released, s.released, "tick {t}");
            assert_eq!(
                g.assigned.as_ref().map(|a| (a.job, a.machine, a.position)),
                s.assigned.as_ref().map(|a| (a.job, a.machine, a.position)),
                "tick {t}"
            );
            if golden.is_idle() && simd.is_idle() && events.peek().is_none() {
                break;
            }
        }
        assert!(golden.is_idle() && simd.is_idle());
    }

    #[test]
    fn pop_shifts_left_and_clears_tail() {
        let mut s = LaneSchedule::new(8);
        s.insert(0, 1, 20.0, 10.0, 2.0, 5);
        s.insert(1, 2, 10.0, 10.0, 1.0, 5);
        assert_eq!(s.pop_head(), 1);
        assert_eq!(s.len, 1);
        assert_eq!(s.t[0], 1.0);
        assert_eq!(s.t[1], 0.0, "tail cleared");
    }
}
