//! SOSC — the paper's software baseline: a deliberately *naive*
//! single-threaded implementation of the discretized SOS algorithm,
//! mirroring a straightforward C translation of Equations (1)–(5)
//! without any of the Section 3.3 design optimizations:
//!
//! * WSPT ratios are **recomputed with a division on every use** (the
//!   hardware stores `T_i^K` once);
//! * virtual work `n_K(t)` is **reconstructed by scanning the job's
//!   head-occupancy history** (the hardware keeps an incrementally
//!   updated counter);
//! * `sum^H` / `sum^L` are **fully re-accumulated per cost query** (the
//!   hardware decrements memoized partial sums).
//!
//! It must produce schedules *identical* to [`crate::scheduler::SosEngine`]
//! (integration-tested) — only its per-iteration wall time differs, which
//! is exactly what the ST column of Fig. 16b measures.

use std::collections::VecDeque;

use crate::core::{Job, JobId, MachineId};
use crate::quant::Precision;
use crate::scheduler::TickOutcome;

/// A tracked job with the naive representation: no derived values cached.
#[derive(Debug, Clone)]
struct NaiveEntry {
    id: JobId,
    weight: f32,
    ept: f32,
    /// Tick-stamped head-occupancy log: entry per cycle this job spent at
    /// the head (the naive reconstruction of `n_K(t)` from history —
    /// deliberately memory- and scan-heavy).
    head_cycles: Vec<u64>,
}

impl NaiveEntry {
    /// Division on every use — the cost the paper's opt. 1 removes. The
    /// quotient is still rounded through the datapath's WSPT format so
    /// the *numerical semantics* match the golden engine exactly (a C
    /// baseline of the same quantized algorithm would do the same); only
    /// the repeated-division work differs.
    fn wspt(&self, precision: Precision) -> f32 {
        precision.q_wspt(self.weight / self.ept)
    }

    fn n(&self) -> u32 {
        // Scan the history instead of keeping a counter. The scan is
        // intentionally O(n); `black_box` prevents the optimizer from
        // collapsing it to `len()`.
        let mut count = 0u32;
        for &c in &self.head_cycles {
            count += std::hint::black_box((c != u64::MAX) as u32);
        }
        count
    }
}

/// Naive software SOS scheduler.
#[derive(Debug)]
pub struct SoscEngine {
    schedules: Vec<Vec<NaiveEntry>>, // each sorted by wspt desc
    depth: usize,
    alpha: f32,
    precision: Precision,
    pending: VecDeque<Job>,
    tick_no: u64,
}

impl SoscEngine {
    pub fn new(machines: usize, depth: usize, alpha: f32, precision: Precision) -> Self {
        SoscEngine {
            schedules: vec![Vec::new(); machines],
            depth,
            alpha,
            precision,
            pending: VecDeque::new(),
            tick_no: 0,
        }
    }

    pub fn submit(&mut self, job: Job) {
        self.pending.push_back(job);
    }

    pub fn backlog(&self) -> usize {
        self.pending.len()
    }

    pub fn in_flight(&self) -> usize {
        self.schedules.iter().map(|v| v.len()).sum()
    }

    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.in_flight() == 0
    }

    /// Naive per-machine cost: full rescan of the virtual schedule with
    /// fresh divisions, per Eq. (4)/(5).
    fn cost(&self, m: MachineId, j_w: f32, j_eps: f32, j_t: f32) -> (f32, usize) {
        let mut sum_hi = 0.0f32;
        let mut sum_lo = 0.0f32;
        let mut pos = 0usize;
        for e in &self.schedules[m] {
            let t_k = e.wspt(self.precision); // division per entry per query
            let n = e.n() as f32; // history scan per entry per query
            if t_k >= j_t {
                sum_hi += e.ept - n;
                pos += 1;
            } else {
                sum_lo += e.weight - n * t_k;
            }
        }
        (j_w * (j_eps + sum_hi) + j_eps * sum_lo, pos)
    }

    /// One scheduler tick — same semantics as the golden engine:
    /// pop alpha-ready heads, assign one pending arrival, accrue VW.
    pub fn tick(&mut self, arrival: Option<&Job>) -> TickOutcome {
        self.tick_no += 1;
        if let Some(j) = arrival {
            self.pending.push_back(j.clone());
        }
        let mut out = TickOutcome::default();

        // POP: heads that reached ceil(alpha * eps)
        for (m, vs) in self.schedules.iter_mut().enumerate() {
            if let Some(head) = vs.first() {
                let release_at = (self.alpha * head.ept).ceil() as u32;
                if head.n() >= release_at {
                    let e = vs.remove(0);
                    out.released.push((e.id, m));
                }
            }
        }

        // ASSIGN one pending job
        if !self.pending.is_empty() {
            if self.schedules.iter().any(|v| v.len() < self.depth) {
                let job = self.pending.pop_front().expect("front checked");
                out.assigned = Some(self.assign(&job));
            } else {
                out.stalled = true;
            }
        }

        // VW: heads accrue one cycle (append to history log)
        let now = self.tick_no;
        for vs in &mut self.schedules {
            if let Some(h) = vs.first_mut() {
                h.head_cycles.push(now);
            }
        }

        // Per-cycle re-evaluation: the hardware's incremental updates
        // "prevent the need for explicit evaluation across each job K"
        // every cycle (Section 3.3 opt. 2) — the naive software has no
        // such memoization, so it refreshes every job's derived state
        // (WSPT division + virtual-work reconstruction + ordering check)
        // each tick, exactly the work the paper's C baseline pays for.
        self.revalidate();
        out
    }

    /// Explicit per-cycle evaluation across every tracked job.
    fn revalidate(&mut self) {
        let precision = self.precision;
        for vs in &self.schedules {
            let mut prev_t = f32::MAX;
            for e in vs {
                let t_k = e.wspt(precision); // division
                let n = e.n(); // history scan
                // remaining contributions, recomputed from scratch
                let rem_hi = e.ept - n as f32;
                let rem_lo = e.weight - n as f32 * t_k;
                std::hint::black_box((rem_hi, rem_lo));
                // "complex reconstruction of V_i": verify ordering by
                // re-deriving priorities
                debug_assert!(prev_t >= t_k || (prev_t - t_k).abs() < 1e-6 || prev_t >= t_k);
                prev_t = std::hint::black_box(t_k);
            }
        }
    }

    fn assign(&mut self, job: &Job) -> crate::scheduler::Assignment {
        let m_count = self.schedules.len();
        let mut best: Option<(usize, f32, usize)> = None;
        for m in 0..m_count {
            if self.schedules[m].len() >= self.depth {
                continue;
            }
            let (j_w, j_eps, j_t) = self.precision.q_job(job.weight, job.ept[m]);
            let (c, p) = self.cost(m, j_w, j_eps, j_t);
            if best.map_or(true, |(_, bc, _)| c < bc) {
                best = Some((m, c, p));
            }
        }
        let (machine, cost, position) = best.expect("caller ensured a free machine");
        let (j_w, j_eps, j_t) = self.precision.q_job(job.weight, job.ept[machine]);
        let entry = NaiveEntry {
            id: job.id,
            weight: j_w,
            ept: j_eps,
            head_cycles: Vec::new(),
        };
        // insert at WSPT position (ties after incumbents)
        let pos = self.schedules[machine]
            .iter()
            .take_while(|e| e.wspt(self.precision) >= j_t)
            .count();
        debug_assert_eq!(pos, position);
        self.schedules[machine].insert(pos, entry);
        crate::scheduler::Assignment {
            job: job.id,
            machine,
            position,
            cost,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::JobNature;
    use crate::scheduler::SosEngine;
    use crate::workload::{generate_trace, WorkloadSpec};
    use crate::core::MachinePark;

    #[test]
    fn schedule_parity_with_golden_engine() {
        let park = MachinePark::paper_m1_m5();
        let trace = generate_trace(&WorkloadSpec::default(), &park, 300, 17);
        let mut golden = SosEngine::new(5, 10, 0.5, Precision::Int8);
        let mut naive = SoscEngine::new(5, 10, 0.5, Precision::Int8);

        let mut events = trace.events().iter().peekable();
        for t in 1..=200_000u64 {
            let mut arrivals = Vec::new();
            while events.peek().is_some_and(|e| e.tick <= t) {
                arrivals.push(events.next().unwrap().job.clone().unwrap());
            }
            for a in &arrivals {
                golden.submit(a.clone());
                naive.submit(a.clone());
            }
            let g = golden.tick(None);
            let n = naive.tick(None);
            assert_eq!(g.released, n.released, "tick {t} releases");
            match (&g.assigned, &n.assigned) {
                (Some(a), Some(b)) => {
                    assert_eq!(a.job, b.job, "tick {t}");
                    assert_eq!(a.machine, b.machine, "tick {t}");
                    assert_eq!(a.position, b.position, "tick {t}");
                }
                (None, None) => {}
                other => panic!("tick {t}: assignment divergence {other:?}"),
            }
            if golden.is_idle() && naive.is_idle() && events.peek().is_none() {
                break;
            }
        }
        assert!(golden.is_idle() && naive.is_idle());
    }

    #[test]
    fn naive_engine_basic_flow() {
        let mut e = SoscEngine::new(2, 4, 0.5, Precision::Fp32);
        let j = Job::new(1, 2.0, vec![50.0, 10.0], JobNature::Mixed);
        let out = e.tick(Some(&j));
        assert_eq!(out.assigned.unwrap().machine, 1);
        assert_eq!(e.in_flight(), 1);
        // drain: alpha_pt = 5 -> released on tick 6
        let mut released = false;
        for _ in 0..8 {
            if !e.tick(None).released.is_empty() {
                released = true;
                break;
            }
        }
        assert!(released);
    }
}
