//! Round-Robin baseline: jobs dispatch immediately to machines in cyclic
//! order, ignoring heterogeneity entirely.

use crate::cluster::{OnlineScheduler, WorkQueue};
use crate::core::Job;

#[derive(Debug, Default)]
pub struct RoundRobin {
    buf: Vec<Job>,
    next: usize,
}

impl RoundRobin {
    pub fn new() -> Self {
        Self::default()
    }
}

impl OnlineScheduler for RoundRobin {
    fn name(&self) -> &'static str {
        "RR"
    }

    fn submit(&mut self, job: Job) {
        self.buf.push(job);
    }

    fn tick(&mut self, _now: u64, queues: &mut [WorkQueue]) {
        for job in self.buf.drain(..) {
            queues[self.next].pending.push_back(job);
            self.next = (self.next + 1) % queues.len();
        }
    }

    fn idle(&self) -> bool {
        self.buf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::JobNature;

    #[test]
    fn cycles_through_machines() {
        let mut rr = RoundRobin::new();
        let mut queues: Vec<WorkQueue> = (0..3).map(|_| WorkQueue::default()).collect();
        for id in 0..7 {
            rr.submit(Job::new(id + 1, 1.0, vec![10.0; 3], JobNature::Mixed));
        }
        rr.tick(1, &mut queues);
        assert_eq!(queues[0].pending.len(), 3);
        assert_eq!(queues[1].pending.len(), 2);
        assert_eq!(queues[2].pending.len(), 2);
        assert!(rr.idle());
    }
}
