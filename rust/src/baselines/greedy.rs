//! Greedy baseline (Dong et al.): each job goes to the machine with the
//! minimum estimated completion time — current backlog plus the job's
//! own EPT on that machine. Heterogeneity-aware through the EPT vector,
//! but has no notion of job priority or stochastic release control.

use crate::cluster::{OnlineScheduler, WorkQueue};
use crate::core::Job;

#[derive(Debug, Default)]
pub struct GreedyScheduler {
    buf: Vec<Job>,
}

impl GreedyScheduler {
    pub fn new() -> Self {
        Self::default()
    }
}

impl OnlineScheduler for GreedyScheduler {
    fn name(&self) -> &'static str {
        "Greedy"
    }

    fn submit(&mut self, job: Job) {
        self.buf.push(job);
    }

    fn tick(&mut self, now: u64, queues: &mut [WorkQueue]) {
        for job in self.buf.drain(..) {
            let best = (0..queues.len())
                .min_by(|&a, &b| {
                    let ca = queues[a].backlog_estimate(a, now) + job.ept[a] as f64;
                    let cb = queues[b].backlog_estimate(b, now) + job.ept[b] as f64;
                    ca.partial_cmp(&cb).expect("finite costs")
                })
                .expect("at least one machine");
            queues[best].pending.push_back(job);
        }
    }

    fn idle(&self) -> bool {
        self.buf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::JobNature;

    #[test]
    fn picks_min_completion_machine() {
        let mut g = GreedyScheduler::new();
        let mut queues: Vec<WorkQueue> = (0..2).map(|_| WorkQueue::default()).collect();
        // machine 0 is cheaper for the job but has a big backlog
        queues[0]
            .pending
            .push_back(Job::new(99, 1.0, vec![100.0, 100.0], JobNature::Mixed));
        g.submit(Job::new(1, 1.0, vec![10.0, 30.0], JobNature::Mixed));
        g.tick(1, &mut queues);
        assert_eq!(queues[1].pending.len(), 1, "avoids the backlog");
    }

    #[test]
    fn empty_queues_pick_fastest_ept() {
        let mut g = GreedyScheduler::new();
        let mut queues: Vec<WorkQueue> = (0..3).map(|_| WorkQueue::default()).collect();
        g.submit(Job::new(1, 1.0, vec![30.0, 10.0, 20.0], JobNature::Mixed));
        g.tick(1, &mut queues);
        assert_eq!(queues[1].pending.len(), 1);
    }
}
