//! Work-stealing variants of RR and Greedy (the paper's WSRR / WSG,
//! after Taskflow): the base policy dispatches, then idle machines steal
//! pending work from the most loaded queue each tick.

use crate::cluster::{OnlineScheduler, WorkQueue};
use crate::core::Job;

use super::{steal, GreedyScheduler, RoundRobin};

/// Work-Stealing Round Robin.
#[derive(Debug, Default)]
pub struct WsRoundRobin {
    inner: RoundRobin,
}

impl WsRoundRobin {
    pub fn new() -> Self {
        Self::default()
    }
}

impl OnlineScheduler for WsRoundRobin {
    fn name(&self) -> &'static str {
        "WSRR"
    }

    fn submit(&mut self, job: Job) {
        self.inner.submit(job);
    }

    fn tick(&mut self, now: u64, queues: &mut [WorkQueue]) {
        self.inner.tick(now, queues);
        steal(queues);
    }

    fn idle(&self) -> bool {
        self.inner.idle()
    }
}

/// Work-Stealing Greedy.
#[derive(Debug, Default)]
pub struct WsGreedy {
    inner: GreedyScheduler,
}

impl WsGreedy {
    pub fn new() -> Self {
        Self::default()
    }
}

impl OnlineScheduler for WsGreedy {
    fn name(&self) -> &'static str {
        "WSG"
    }

    fn submit(&mut self, job: Job) {
        self.inner.submit(job);
    }

    fn tick(&mut self, now: u64, queues: &mut [WorkQueue]) {
        self.inner.tick(now, queues);
        steal(queues);
    }

    fn idle(&self) -> bool {
        self.inner.idle()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::JobNature;

    #[test]
    fn wsrr_rebalances_after_rr_dispatch() {
        let mut ws = WsRoundRobin::new();
        let mut queues: Vec<WorkQueue> = (0..2).map(|_| WorkQueue::default()).collect();
        // All jobs land round-robin, but machine 1 is busy -> its queue
        // grows while machine 0 idles after draining; force imbalance:
        for id in 0..4 {
            ws.submit(Job::new(id + 1, 1.0, vec![10.0, 10.0], JobNature::Mixed));
        }
        queues[1].busy = true;
        ws.tick(1, &mut queues);
        // RR gave 2+2; machine 0 idle with nonempty queue -> no steal needed
        assert_eq!(queues[0].pending.len() + queues[1].pending.len(), 4);
    }

    #[test]
    fn wsg_steals_for_idle_machine() {
        let mut ws = WsGreedy::new();
        let mut queues: Vec<WorkQueue> = (0..2).map(|_| WorkQueue::default()).collect();
        // Greedy sends everything to machine 0 (much cheaper EPT there)
        for id in 0..3 {
            ws.submit(Job::new(id + 1, 1.0, vec![10.0, 200.0], JobNature::Mixed));
        }
        ws.tick(1, &mut queues);
        assert!(
            !queues[1].pending.is_empty(),
            "idle machine 1 stole work: {:?} {:?}",
            queues[0].pending.len(),
            queues[1].pending.len()
        );
    }
}
