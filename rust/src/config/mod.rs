//! Run configuration: a single [`RunConfig`] consumed by the CLI, the
//! coordinator, and the examples, with JSON round-trip (via
//! [`crate::jsonio`]) so experiment setups can be archived.

use crate::bail;
use crate::core::MachinePark;
use crate::engine::EngineId;
use crate::error::Result;
use crate::jsonio::{arr, num, obj, s, Json};
use crate::quant::Precision;
use crate::workload::{BurstType, WorkloadSpec};

/// Full experiment configuration. Engine selection goes through the
/// single [`crate::engine::EngineId`] registry; archived configs using
/// the historical names (`native`, `stannic`, `hercules`) still parse
/// via the registry's aliases.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub machines: usize,
    pub depth: usize,
    pub alpha: f32,
    pub precision: Precision,
    pub engine: EngineId,
    pub jobs: usize,
    pub seed: u64,
    pub workload: WorkloadSpec,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            machines: 5,
            depth: 10,
            alpha: 0.5,
            precision: Precision::Int8,
            engine: EngineId::Sos,
            jobs: 1000,
            seed: 42,
            workload: WorkloadSpec::default(),
        }
    }
}

impl RunConfig {
    pub fn park(&self) -> MachinePark {
        if self.machines == 5 {
            MachinePark::paper_m1_m5()
        } else {
            MachinePark::cycled(self.machines)
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("machines", num(self.machines as f64)),
            ("depth", num(self.depth as f64)),
            ("alpha", num(self.alpha as f64)),
            ("precision", s(self.precision.name())),
            ("engine", s(self.engine.name())),
            ("jobs", num(self.jobs as f64)),
            ("seed", num(self.seed as f64)),
            (
                "workload",
                obj(vec![
                    ("frac_compute", num(self.workload.frac_compute)),
                    ("frac_memory", num(self.workload.frac_memory)),
                    ("frac_mixed", num(self.workload.frac_mixed)),
                    ("burst_factor", num(self.workload.burst_factor as f64)),
                    (
                        "burst_type",
                        s(match self.workload.burst_type {
                            BurstType::Random => "random",
                            BurstType::Uniform => "uniform",
                        }),
                    ),
                    ("idle_time", num(self.workload.idle_time as f64)),
                    ("idle_interval", num(self.workload.idle_interval as f64)),
                    (
                        "weight_range",
                        arr(vec![
                            num(self.workload.weight_range.0 as f64),
                            num(self.workload.weight_range.1 as f64),
                        ]),
                    ),
                    (
                        "ept_range",
                        arr(vec![
                            num(self.workload.ept_range.0 as f64),
                            num(self.workload.ept_range.1 as f64),
                        ]),
                    ),
                    ("runtime_noise", num(self.workload.runtime_noise as f64)),
                ]),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let mut c = RunConfig::default();
        let get_num = |j: &Json, k: &str| -> Option<f64> { j.get(k).and_then(Json::as_f64) };
        if let Some(v) = get_num(j, "machines") {
            c.machines = v as usize;
        }
        if let Some(v) = get_num(j, "depth") {
            c.depth = v as usize;
        }
        if let Some(v) = get_num(j, "alpha") {
            c.alpha = v as f32;
        }
        if let Some(v) = j.get("precision").and_then(Json::as_str) {
            c.precision = match v {
                "FP32" => Precision::Fp32,
                "FP16" => Precision::Fp16,
                "INT8" => Precision::Int8,
                "INT4" => Precision::Int4,
                "Mixed" => Precision::Mixed,
                other => bail!("bad precision {other}"),
            };
        }
        if let Some(v) = j.get("engine").and_then(Json::as_str) {
            c.engine = EngineId::parse(v)?;
        }
        if let Some(v) = get_num(j, "jobs") {
            c.jobs = v as usize;
        }
        if let Some(v) = get_num(j, "seed") {
            c.seed = v as u64;
        }
        if let Some(w) = j.get("workload") {
            if let Some(v) = get_num(w, "frac_compute") {
                c.workload.frac_compute = v;
            }
            if let Some(v) = get_num(w, "frac_memory") {
                c.workload.frac_memory = v;
            }
            if let Some(v) = get_num(w, "frac_mixed") {
                c.workload.frac_mixed = v;
            }
            if let Some(v) = get_num(w, "burst_factor") {
                c.workload.burst_factor = v as usize;
            }
            if let Some(v) = w.get("burst_type").and_then(Json::as_str) {
                c.workload.burst_type = match v {
                    "random" => BurstType::Random,
                    "uniform" => BurstType::Uniform,
                    other => bail!("bad burst_type {other}"),
                };
            }
            if let Some(v) = get_num(w, "idle_time") {
                c.workload.idle_time = v as u64;
            }
            if let Some(v) = get_num(w, "idle_interval") {
                c.workload.idle_interval = v as usize;
            }
            if let Some(v) = get_num(w, "runtime_noise") {
                c.workload.runtime_noise = v as f32;
            }
        }
        c.workload.validate()?;
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trip() {
        let mut c = RunConfig::default();
        c.machines = 20;
        c.precision = Precision::Fp16;
        c.engine = EngineId::StannicSim;
        c.workload = WorkloadSpec::memory_skewed();
        let j = c.to_json();
        let back = RunConfig::from_json(&Json::parse(&j.render()).unwrap()).unwrap();
        assert_eq!(back.machines, 20);
        assert_eq!(back.precision, Precision::Fp16);
        assert_eq!(back.engine, EngineId::StannicSim);
        assert!((back.workload.frac_memory - 0.70).abs() < 1e-9);
    }

    #[test]
    fn archived_configs_with_alias_names_still_parse() {
        // Pre-registry configs serialized "native"/"stannic"/"hercules".
        let j = Json::parse(r#"{"engine": "native"}"#).unwrap();
        assert_eq!(RunConfig::from_json(&j).unwrap().engine, EngineId::Sos);
        let j = Json::parse(r#"{"engine": "hercules"}"#).unwrap();
        assert_eq!(
            RunConfig::from_json(&j).unwrap().engine,
            EngineId::HerculesSim
        );
        let j = Json::parse(r#"{"engine": "gpu"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn park_uses_paper_machines_at_5() {
        let c = RunConfig::default();
        assert_eq!(c.park().len(), 5);
        let mut c2 = RunConfig::default();
        c2.machines = 17;
        assert_eq!(c2.park().len(), 17);
    }
}
