//! Heterogeneous-cluster execution simulator.
//!
//! This is the substrate that turns scheduling decisions into measurable
//! outcomes (Fig. 15/16a/19): machines execute dispatched jobs for their
//! *actual* (stochastic) runtimes, and the simulator records job
//! distribution, queue latency, load balance and throughput.

mod sos_adapter;

pub use sos_adapter::SosCluster;

use std::collections::VecDeque;

use crate::core::{Job, MachineId, MachinePark};
use crate::metrics::{MetricSet, ScheduleMetrics};
use crate::workload::Trace;

/// A machine's work queue as exposed to schedulers. Schedulers push
/// dispatched jobs onto `pending`; work-stealing schedulers may also move
/// *pending* (not yet started) jobs between queues.
#[derive(Debug, Default)]
pub struct WorkQueue {
    pub pending: VecDeque<Job>,
    /// Set by the cluster: is the machine currently executing a job?
    pub busy: bool,
    /// Set by the cluster: tick at which the running job finishes
    /// (meaningful only when `busy`).
    pub busy_until: u64,
}

impl WorkQueue {
    /// Estimated remaining work on this queue for greedy cost decisions:
    /// pending EPTs on this machine + remaining runtime of the current job.
    pub fn backlog_estimate(&self, machine: MachineId, now: u64) -> f64 {
        let pending: f64 = self.pending.iter().map(|j| j.ept[machine] as f64).sum();
        let running = if self.busy {
            self.busy_until.saturating_sub(now) as f64
        } else {
            0.0
        };
        pending + running
    }
}

/// The interface every scheduler under evaluation implements — the SOS
/// engines (golden, simulators, XLA-offloaded) via adapters, and the four
/// baseline algorithms directly.
pub trait OnlineScheduler {
    fn name(&self) -> &'static str;
    /// A job has been created at the current tick.
    fn submit(&mut self, job: Job);
    /// Advance one scheduler tick; dispatch by pushing onto `queues`.
    fn tick(&mut self, now: u64, queues: &mut [WorkQueue]);
    /// True when the scheduler holds no undispatched work.
    fn idle(&self) -> bool;
}

/// Cluster configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Interval length (ticks) for the load-balance CV metric.
    pub metric_interval: u64,
    /// Hard cap on simulated ticks (guards against non-draining runs).
    pub max_ticks: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            metric_interval: 64,
            max_ticks: 2_000_000,
        }
    }
}

#[derive(Debug)]
struct Running {
    #[allow(dead_code)] // retained for debugging/inspection
    job: Job,
    finish: u64,
}

/// The execution simulator.
pub struct Cluster {
    park: MachinePark,
    queues: Vec<WorkQueue>,
    running: Vec<Option<Running>>,
    metrics: MetricSet,
    completed: usize,
    now: u64,
    cfg: ClusterConfig,
}

/// Result of a full cluster run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub scheduler: &'static str,
    pub metrics: ScheduleMetrics,
    /// Tick at which the last job completed.
    pub makespan: u64,
    pub completed: usize,
}

impl Cluster {
    pub fn new(park: MachinePark, cfg: ClusterConfig) -> Self {
        let n = park.len();
        Cluster {
            park,
            queues: (0..n).map(|_| WorkQueue::default()).collect(),
            running: (0..n).map(|_| None).collect(),
            metrics: MetricSet::new(n, cfg.metric_interval),
            completed: 0,
            now: 0,
            cfg,
        }
    }

    pub fn now(&self) -> u64 {
        self.now
    }

    pub fn queues(&self) -> &[WorkQueue] {
        &self.queues
    }

    /// Drive `scheduler` over `trace` until every job has completed (or
    /// `max_ticks` elapses). Returns the measured summary.
    pub fn run<S: OnlineScheduler>(mut self, scheduler: &mut S, trace: &Trace) -> RunSummary {
        let total = trace.n_jobs();
        let mut events = trace.events().iter().peekable();

        while self.completed < total && self.now < self.cfg.max_ticks {
            self.now += 1;

            // 1. arrivals scheduled for this tick
            while events
                .peek()
                .is_some_and(|e| e.tick <= self.now)
            {
                let e = events.next().expect("peeked");
                if let Some(job) = &e.job {
                    scheduler.submit(job.clone());
                }
            }

            // 2. expose machine status, let the scheduler act
            for (m, q) in self.queues.iter_mut().enumerate() {
                match &self.running[m] {
                    Some(r) => {
                        q.busy = true;
                        q.busy_until = r.finish;
                    }
                    None => {
                        q.busy = false;
                        q.busy_until = 0;
                    }
                }
            }
            scheduler.tick(self.now, &mut self.queues);

            // 3. machine execution: finish, then start
            for m in 0..self.park.len() {
                if let Some(r) = &self.running[m] {
                    if r.finish <= self.now {
                        self.running[m] = None;
                        self.completed += 1;
                    }
                }
                if self.running[m].is_none() {
                    if let Some(job) = self.queues[m].pending.pop_front() {
                        let dur = job.actual_time(m);
                        self.metrics.record_assignment(m, self.now);
                        self.metrics.record_latency(m, job.arrival, self.now);
                        self.running[m] = Some(Running {
                            finish: self.now + dur,
                            job,
                        });
                    }
                }
            }
        }

        RunSummary {
            scheduler: scheduler.name(),
            metrics: self.metrics.finish(),
            makespan: self.now,
            completed: self.completed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::JobNature;
    use crate::workload::{generate_trace, WorkloadSpec};

    /// Trivial scheduler: everything to machine 0 immediately.
    struct ToZero {
        buf: Vec<Job>,
    }
    impl OnlineScheduler for ToZero {
        fn name(&self) -> &'static str {
            "to-zero"
        }
        fn submit(&mut self, job: Job) {
            self.buf.push(job);
        }
        fn tick(&mut self, _now: u64, queues: &mut [WorkQueue]) {
            for j in self.buf.drain(..) {
                queues[0].pending.push_back(j);
            }
        }
        fn idle(&self) -> bool {
            self.buf.is_empty()
        }
    }

    #[test]
    fn single_machine_executes_serially() {
        let park = MachinePark::homogeneous_cpu(1);
        let cluster = Cluster::new(park, ClusterConfig::default());
        let mut s = ToZero { buf: vec![] };
        // two jobs, both 10 ticks on machine 0, arriving together at tick 1
        let mut events = Vec::new();
        for id in 1..=2 {
            events.push(crate::workload::TraceEvent {
                tick: 1,
                job: Some(
                    Job::new(id, 1.0, vec![10.0], JobNature::Mixed).with_arrival(1),
                ),
            });
        }
        let trace = Trace::new(events, 1);
        let sum = cluster.run(&mut s, &trace);
        assert_eq!(sum.completed, 2);
        // job1 starts at 1 (latency 0) finishes 11; job2 starts 11 (latency 10)
        assert_eq!(sum.metrics.jobs_per_machine, vec![2]);
        assert_eq!(sum.metrics.avg_latency, 5.0);
        assert_eq!(sum.makespan, 21);
    }

    #[test]
    fn full_trace_drains() {
        let park = MachinePark::paper_m1_m5();
        let trace = generate_trace(&WorkloadSpec::default(), &park, 100, 5);
        let mut s = ToZero { buf: vec![] };
        let sum = Cluster::new(park, ClusterConfig::default()).run(&mut s, &trace);
        assert_eq!(sum.completed, 100);
        assert_eq!(sum.metrics.jobs_per_machine[0], 100);
        assert!(sum.metrics.starvation);
    }

    #[test]
    fn backlog_estimate_counts_pending_and_running() {
        let mut q = WorkQueue::default();
        q.pending
            .push_back(Job::new(1, 1.0, vec![7.0], JobNature::Mixed));
        q.busy = true;
        q.busy_until = 15;
        assert_eq!(q.backlog_estimate(0, 10), 7.0 + 5.0);
    }
}
