//! Adapter running the SOS engine inside the [`Cluster`] executor, so
//! SOSA competes with the baseline schedulers under identical execution
//! semantics (Fig. 19). The engine tracks metadata only (like the
//! FPGA); the adapter keeps job payloads and forwards releases to the
//! machine queues.

use std::collections::HashMap;

use crate::cluster::{OnlineScheduler, WorkQueue};
use crate::core::{Job, JobId};
use crate::quant::Precision;
use crate::scheduler::SosEngine;

pub struct SosCluster {
    engine: SosEngine,
    payloads: HashMap<JobId, Job>,
}

impl SosCluster {
    pub fn new(machines: usize, depth: usize, alpha: f32, precision: Precision) -> Self {
        SosCluster {
            engine: SosEngine::new(machines, depth, alpha, precision),
            payloads: HashMap::new(),
        }
    }

    pub fn engine(&self) -> &SosEngine {
        &self.engine
    }
}

impl OnlineScheduler for SosCluster {
    fn name(&self) -> &'static str {
        "SOS"
    }

    fn submit(&mut self, job: Job) {
        self.payloads.insert(job.id, job.clone());
        self.engine.submit(job);
    }

    fn tick(&mut self, _now: u64, queues: &mut [WorkQueue]) {
        let out = self.engine.tick(None);
        for (id, m) in out.released {
            let job = self.payloads.remove(&id).expect("payload tracked");
            queues[m].pending.push_back(job);
        }
    }

    fn idle(&self) -> bool {
        self.engine.is_idle()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterConfig};
    use crate::core::MachinePark;
    use crate::workload::{generate_trace, WorkloadSpec};

    #[test]
    fn sos_runs_inside_cluster_executor() {
        let park = MachinePark::paper_m1_m5();
        let trace = generate_trace(&WorkloadSpec::default(), &park, 150, 8);
        let mut sched = SosCluster::new(5, 10, 0.5, Precision::Int8);
        let sum = Cluster::new(park, ClusterConfig::default()).run(&mut sched, &trace);
        assert_eq!(sum.completed, 150);
        assert_eq!(
            sum.metrics.jobs_per_machine.iter().sum::<usize>(),
            150
        );
        assert!(sched.idle());
    }

    #[test]
    fn sos_distribution_differs_from_round_robin() {
        // SOS is heterogeneity-aware: on the M1-M5 park it must not
        // produce RR's flat distribution under a compute-heavy workload.
        use crate::baselines::RoundRobin;
        let park = MachinePark::paper_m1_m5();
        let trace = generate_trace(&WorkloadSpec::compute_skewed(), &park, 400, 5);
        let mut sos = SosCluster::new(5, 10, 0.5, Precision::Int8);
        let a = Cluster::new(park.clone(), ClusterConfig::default()).run(&mut sos, &trace);
        let mut rr = RoundRobin::new();
        let b = Cluster::new(park, ClusterConfig::default()).run(&mut rr, &trace);
        assert_ne!(a.metrics.jobs_per_machine, b.metrics.jobs_per_machine);
    }
}
