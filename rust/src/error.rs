//! In-house error substrate (anyhow is unavailable offline): a
//! context-chaining [`Error`] type, a crate-wide [`Result`] alias, the
//! [`err!`](crate::err)/[`bail!`](crate::bail)/[`ensure!`](crate::ensure)
//! macros, and the [`Ctx`] extension trait that adds `.ctx()` /
//! `.with_ctx()` context chaining to `Result` and `Option`.
//!
//! Display semantics mirror what the rest of the crate relied on:
//! `{e}` prints the outermost (most recently attached) message, `{e:#}`
//! prints the whole chain outermost-first separated by `": "`, and
//! `{e:?}` prints an indented `Caused by:` listing.

use std::fmt;

/// A message chain: `chain[0]` is the root cause; each later entry is a
/// context attached while the error propagated upward.
#[derive(Clone, PartialEq, Eq)]
pub struct Error {
    chain: Vec<String>,
}

/// Crate-wide result alias (the `E = Error` default keeps signatures
/// using custom error types, e.g. `Result<T, String>`, valid).
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// A fresh error with a single root-cause message.
    pub fn msg(message: impl Into<String>) -> Self {
        Error {
            chain: vec![message.into()],
        }
    }

    /// Attach a higher-level context message.
    pub fn context(mut self, message: impl Into<String>) -> Self {
        self.chain.push(message.into());
        self
    }

    /// The innermost (first-created) message.
    pub fn root_cause(&self) -> &str {
        &self.chain[0]
    }

    /// The outermost (most recently attached) message.
    pub fn outer(&self) -> &str {
        self.chain.last().expect("chain is never empty")
    }

    /// Messages outermost-first, anyhow-`chain()` style.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().rev().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, m) in self.chain().enumerate() {
                if i > 0 {
                    f.write_str(": ")?;
                }
                f.write_str(m)?;
            }
            Ok(())
        } else {
            f.write_str(self.outer())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.outer())?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, m) in self.chain().skip(1).enumerate() {
                write!(f, "\n    {i}: {m}")?;
            }
        }
        Ok(())
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error::msg(s)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e.to_string())
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Self {
        Error::msg(e.to_string())
    }
}

/// Build an [`Error`] from a format string — the `anyhow!` analog.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*).into())
    };
}

/// Return early with a formatted [`Error`] unless `$cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

/// Context chaining for `Result` and `Option` — the `Context` analog.
/// `.ctx("msg")` attaches an eager message; `.with_ctx(|| ...)` defers
/// the formatting to the error path.
pub trait Ctx<T> {
    fn ctx<S: Into<String>>(self, message: S) -> Result<T>;
    fn with_ctx<S: Into<String>, F: FnOnce() -> S>(self, message: F) -> Result<T>;
}

impl<T, E: Into<Error>> Ctx<T> for std::result::Result<T, E> {
    fn ctx<S: Into<String>>(self, message: S) -> Result<T> {
        self.map_err(|e| e.into().context(message))
    }

    fn with_ctx<S: Into<String>, F: FnOnce() -> S>(self, message: F) -> Result<T> {
        self.map_err(|e| e.into().context(message()))
    }
}

impl<T> Ctx<T> for Option<T> {
    fn ctx<S: Into<String>>(self, message: S) -> Result<T> {
        self.ok_or_else(|| Error::msg(message))
    }

    fn with_ctx<S: Into<String>, F: FnOnce() -> S>(self, message: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(message()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_missing() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such artifact")
    }

    #[test]
    fn context_chaining_orders_outermost_first() {
        let e = Error::msg("root").context("middle").context("outer");
        assert_eq!(e.root_cause(), "root");
        assert_eq!(e.outer(), "outer");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["outer", "middle", "root"]);
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: root");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("0: middle"), "{dbg}");
        assert!(dbg.contains("1: root"), "{dbg}");
    }

    #[test]
    fn bail_formats_arguments() {
        fn run(v: usize) -> Result<()> {
            ensure!(v < 10, "value {v} out of range (max {})", 9);
            if v == 7 {
                bail!("seven is right out");
            }
            Ok(())
        }
        assert!(run(3).is_ok());
        let e = run(12).unwrap_err();
        assert_eq!(format!("{e}"), "value 12 out of range (max 9)");
        let e = run(7).unwrap_err();
        assert_eq!(format!("{e}"), "seven is right out");
    }

    #[test]
    fn err_macro_builds_without_returning() {
        let e = err!("cell {} failed on machine {}", 4, 2);
        assert_eq!(e.root_cause(), "cell 4 failed on machine 2");
    }

    #[test]
    fn from_io_error_preserves_message() {
        fn read() -> Result<String> {
            Err::<String, std::io::Error>(io_missing())?;
            unreachable!()
        }
        let e = read().unwrap_err();
        assert!(format!("{e}").contains("no such artifact"));
    }

    #[test]
    fn ctx_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_missing());
        let e = r.ctx("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: no such artifact");

        let o: Option<u32> = None;
        let e = o.with_ctx(|| format!("slot {} empty", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "slot 3 empty");

        let some: Option<u32> = Some(5);
        assert_eq!(some.ctx("unused").unwrap(), 5);
    }

    #[test]
    fn string_errors_convert() {
        fn parse() -> Result<()> {
            Err::<(), String>("bad flag".to_string())?;
            Ok(())
        }
        assert_eq!(format!("{}", parse().unwrap_err()), "bad flag");
    }
}
