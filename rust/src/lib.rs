//! # stannic — full-system reproduction of STANNIC / HERCULES
//!
//! *STANNIC: Systolic STochAstic ONliNe Scheduling AcCelerator*
//! (Ross, Palaniappan, Pal — ICCAD 2025).
//!
//! This crate implements, from scratch, every system the paper describes
//! or depends on:
//!
//! * [`scheduler`] — the golden discrete-time Stochastic Online Scheduling
//!   (SOS) engine (Jäger's algorithm with the paper's hardware-oriented
//!   discretization, Eq. 3–5), plus the continuous-time reference (Eq. 1–2).
//! * [`sim`] — cycle-accurate component-level simulators of both
//!   microarchitectures: **Hercules** (task-centric pipeline, Section 4)
//!   and **Stannic** (schedule-centric systolic array, Section 6).
//! * [`hw`] — the FPGA substrate models: LUT/FF resource estimation,
//!   routing-congestion feasibility, and the Alveo U55C power envelope.
//! * [`quant`] — the numerical-precision study of Section 4.2
//!   (FP32/FP16/INT8/INT4/Mixed).
//! * [`workload`] — the in-house workload generator of Section 7.1
//!   (JC/MC/BF/BT/IT/II parameters) with Monte-Carlo sampling.
//! * [`baselines`] — RR, Greedy, WSRR, WSG, the single-threaded software
//!   SOS (the paper's C baseline) and the AVX-style lane-vectorised SOS.
//! * [`cluster`] — the heterogeneous-cluster execution simulator that
//!   turns schedules into measured fairness/latency/throughput.
//! * [`runtime`] — the PJRT/XLA accelerator path: loads the AOT-compiled
//!   HLO artifacts produced by `python/compile/aot.py` and executes the
//!   cost datapath from Rust (Python is never on the request path).
//! * [`engine`] — the single engine registry ([`engine::EngineId`]):
//!   one parse/name/build table over every backend, shared by the CLI,
//!   the coordinator, the sweep, and the config JSON round-trip.
//! * [`coordinator`] — the online serving pipeline (threads + channels):
//!   concurrent arrival sources merged deterministically into a batched
//!   scheduler loop, the PCIe transport model, per-machine workers, and
//!   pluggable scheduling engines behind [`coordinator::EngineAdapter`].
//! * [`report`] — renders every table and figure of the paper's
//!   evaluation section from freshly-run experiments.
//!
//! * [`sweep`] — the parallel scenario-sweep subsystem: a shared-queue
//!   multi-threaded runner that fans a grid of workload × machine-count
//!   × alpha × precision cells across every software/simulator engine
//!   and aggregates per-cell latency/utilization metrics
//!   deterministically (results are independent of thread count).
//! * [`artifact`] — the versioned-artifact layer: the schema registry
//!   (`stannic.sweep.record.v1`, `stannic.serve.record.v1`), the shared
//!   jsonio codec + parse-back-verified file I/O, the FNV-1a
//!   schedule-identity digest, and the generic diff core behind both
//!   `sweep diff` and `serve diff`.
//! * [`faults`] — seeded deterministic fault injection (machine
//!   down/up, stragglers, arrival storms, source dropout) as
//!   first-class virtual-time events on the tickless event horizon,
//!   with per-run recovery metrics.
//!
//! Offline-environment substrates (clap/criterion/serde/proptest/anyhow
//! are not available here): [`cli`], [`bench`], [`error`], [`jsonio`],
//! [`testing`].
//!
//! ## Quickstart
//!
//! ```no_run
//! use stannic::prelude::*;
//!
//! // Five machines (the paper's M1–M5), alpha = 0.5, depth-10 schedules.
//! let machines = MachinePark::paper_m1_m5();
//! let mut engine = SosEngine::new(machines.len(), 10, 0.5, Precision::Fp32);
//! let spec = WorkloadSpec::default();
//! let trace = generate_trace(&spec, &machines, 1000, 42);
//! for event in trace.events() {
//!     let _ = engine.tick(event.job.as_ref());
//! }
//! ```

pub mod artifact;
pub mod baselines;
pub mod bench;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod core;
pub mod engine;
pub mod error;
pub mod faults;
pub mod hw;
pub mod jsonio;
pub mod metrics;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod sweep;
pub mod testing;
pub mod workload;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::baselines::{GreedyScheduler, RoundRobin, SoscEngine, WsGreedy, WsRoundRobin};
    pub use crate::cluster::{Cluster, ClusterConfig, RunSummary};
    pub use crate::core::{
        Job, JobId, JobNature, Machine, MachineId, MachineKind, MachinePark, Quality,
    };
    pub use crate::engine::EngineId;
    pub use crate::metrics::{MetricSet, ScheduleMetrics};
    pub use crate::quant::Precision;
    pub use crate::scheduler::{drive_trace, DriveStats, SosEngine, TickOutcome};
    pub use crate::sim::{hercules::HerculesSim, stannic::StannicSim, ArchSim, IterationKind};
    pub use crate::workload::{generate_trace, Trace, WorkloadSpec};
}
