//! End-to-end coverage of the benchmarking backbone through the real
//! binary (Cargo exposes it as `CARGO_BIN_EXE_stannic`):
//!
//! * `sweep --record <path>` emits a parseable `SweepRecord` artifact;
//! * `sweep diff a.json b.json` exits 0 on identical inputs;
//! * an injected beyond-threshold regression (and a parity break) make
//!   it exit non-zero.

use std::path::{Path, PathBuf};
use std::process::Command;

use stannic::artifact::Artifact;
use stannic::sweep::{diff_records, DiffOpts, SweepRecord};

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_stannic"))
}

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("stannic_perfdiff_{}_{name}", std::process::id()));
    p
}

/// Record a tiny sweep (narrow grid so the test stays fast) to `path`.
fn record_to(path: &Path) {
    let out = bin()
        .args([
            "sweep",
            "--quick",
            "--engines",
            "sos,sosc",
            "--workload",
            "even",
            "--machines",
            "3",
            "--jobs",
            "30",
            "--threads",
            "2",
            "--record",
        ])
        .arg(path)
        .args(["--label", "itest"])
        .output()
        .expect("spawn stannic sweep");
    assert!(
        out.status.success(),
        "sweep --record failed:\n{}\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn record_artifact_parses_and_diff_gates_regressions() {
    let base = tmp("base.json");
    record_to(&base);

    // artifact is parseable and non-trivial
    let text = std::fs::read_to_string(&base).expect("artifact written");
    let record = SweepRecord::parse(&text).expect("artifact parses as SweepRecord");
    assert_eq!(record.label, "itest");
    assert!(!record.cells.is_empty());
    assert!(record.cells.iter().all(|c| c.wall_ns > 0));

    // identical inputs -> exit 0
    let ok = bin()
        .args(["sweep", "diff"])
        .arg(&base)
        .arg(&base)
        .output()
        .expect("spawn stannic sweep diff");
    assert!(
        ok.status.success(),
        "diff of identical records must exit 0:\n{}\n{}",
        String::from_utf8_lossy(&ok.stdout),
        String::from_utf8_lossy(&ok.stderr)
    );

    // inject a >threshold regression into one cell -> exit non-zero
    let mut slow = record.clone();
    slow.cells[0].wall_ns *= 10;
    let slow_path = tmp("slow.json");
    std::fs::write(&slow_path, slow.render()).unwrap();
    let fail = bin()
        .args(["sweep", "diff"])
        .arg(&base)
        .arg(&slow_path)
        .output()
        .expect("spawn stannic sweep diff");
    assert!(
        !fail.status.success(),
        "injected 10x regression must fail the diff:\n{}",
        String::from_utf8_lossy(&fail.stdout)
    );
    assert!(
        String::from_utf8_lossy(&fail.stdout).contains("REGRESSION"),
        "report names the regression:\n{}",
        String::from_utf8_lossy(&fail.stdout)
    );

    // a loose env threshold lets the same regression pass
    let pass = bin()
        .args(["sweep", "diff"])
        .arg(&base)
        .arg(&slow_path)
        .env("STANNIC_PERF_THRESHOLD", "0.95")
        .output()
        .expect("spawn stannic sweep diff");
    assert!(
        pass.status.success(),
        "STANNIC_PERF_THRESHOLD=0.95 must absorb a 10x single-cell slowdown:\n{}",
        String::from_utf8_lossy(&pass.stdout)
    );

    // a parity break (tampered deterministic outcome) fails regardless
    let mut broken = record.clone();
    broken.cells[0].ticks += 1;
    broken.cells[0].digest = broken.cells[0].compute_digest();
    let broken_path = tmp("broken.json");
    std::fs::write(&broken_path, broken.render()).unwrap();
    let fail = bin()
        .args(["sweep", "diff"])
        .arg(&base)
        .arg(&broken_path)
        .env("STANNIC_PERF_THRESHOLD", "0.95")
        .output()
        .expect("spawn stannic sweep diff");
    assert!(
        !fail.status.success(),
        "parity break must fail even with a loose threshold:\n{}",
        String::from_utf8_lossy(&fail.stdout)
    );

    // in-process sanity: the library classifies the same way the CLI did
    let report = diff_records(&record, &slow, &DiffOpts::default());
    assert_eq!(report.regressions(), 1);

    for p in [&base, &slow_path, &broken_path] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn two_recordings_of_same_grid_share_digests() {
    // Wall times differ run-to-run; the deterministic outcome must not.
    let a_path = tmp("a.json");
    let b_path = tmp("b.json");
    record_to(&a_path);
    record_to(&b_path);
    let a = SweepRecord::parse(&std::fs::read_to_string(&a_path).unwrap()).unwrap();
    let b = SweepRecord::parse(&std::fs::read_to_string(&b_path).unwrap()).unwrap();
    assert_eq!(a.cells.len(), b.cells.len());
    for (ca, cb) in a.cells.iter().zip(&b.cells) {
        assert_eq!(ca.key(), cb.key());
        assert_eq!(ca.digest, cb.digest, "digest must be wall-time independent");
    }
    // and the diff never reports parity breaks or coverage gaps between
    // honest recordings (wall-time noise on tiny cells makes the perf
    // verdicts themselves unsuitable for a unit-test assertion)
    let report = diff_records(&a, &b, &DiffOpts::default());
    assert_eq!(report.parity_breaks(), 0, "{}", report.render());
    assert!(report.only_in_old.is_empty() && report.only_in_new.is_empty());
    for p in [&a_path, &b_path] {
        let _ = std::fs::remove_file(p);
    }
}
