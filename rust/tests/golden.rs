//! Golden-schedule regression: the exact schedule (assignment sequence
//! + release times) the golden `SosEngine` produces for the paper's
//! M1–M5 park at seed 42 is pinned in `tests/golden/`, so future
//! refactors cannot silently change scheduling behavior.
//!
//! Re-bless after an *intentional* semantic change with
//! `STANNIC_BLESS=1 cargo test golden`; `tools/gen_golden.py` is an
//! independent cross-implementation that regenerates the same file.

use std::fmt::Write as _;

use stannic::core::MachinePark;
use stannic::quant::Precision;
use stannic::scheduler::SosEngine;
use stannic::workload::{generate_trace, WorkloadSpec};

const JOBS: usize = 40;
const SEED: u64 = 42;

/// Drive the golden engine over the pinned scenario and log one line
/// per event: `R <tick> <job> <machine>` for releases (pops happen
/// before the assignment within a tick, so they log first) and
/// `A <tick> <job> <machine> <position>` for assignments.
fn schedule_log() -> String {
    let park = MachinePark::paper_m1_m5();
    let trace = generate_trace(&WorkloadSpec::default(), &park, JOBS, SEED);
    let mut engine = SosEngine::new(5, 10, 0.5, Precision::Int8);
    let mut out = String::new();
    let mut events = trace.events().iter().peekable();
    for t in 1..=200_000u64 {
        while events.peek().is_some_and(|e| e.tick <= t) {
            engine.submit(events.next().expect("peeked").job.clone().expect("job"));
        }
        let o = engine.tick(None);
        for (id, m) in &o.released {
            writeln!(out, "R {t} {id} {m}").expect("write to string");
        }
        if let Some(a) = &o.assigned {
            writeln!(out, "A {t} {} {} {}", a.job, a.machine, a.position)
                .expect("write to string");
        }
        if engine.is_idle() && events.peek().is_none() {
            return out;
        }
    }
    panic!("golden scenario did not drain");
}

fn golden_path() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/sos_m1m5_seed42.txt")
}

#[test]
fn golden_sos_schedule_m1m5_seed42() {
    let got = schedule_log();
    let path = golden_path();
    let bless = std::env::var("STANNIC_BLESS")
        .map(|v| !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false"))
        .unwrap_or(false);
    if bless {
        std::fs::write(path, &got).expect("bless golden file");
        eprintln!("golden blessed: {path}");
        return;
    }
    let want = std::fs::read_to_string(path)
        .expect("golden file missing — bless with STANNIC_BLESS=1 cargo test golden");
    assert_eq!(
        got, want,
        "SosEngine schedule diverged from the pinned golden; if the change \
         is intentional, re-bless with STANNIC_BLESS=1 cargo test golden"
    );
}

#[test]
fn golden_log_is_structurally_sound() {
    // Independent of the pinned file: every job appears exactly once as
    // an assignment and once as a release, and ticks are monotone.
    let log = schedule_log();
    let mut assigned = Vec::new();
    let mut released = Vec::new();
    let mut last_tick = 0u64;
    for line in log.lines() {
        let parts: Vec<&str> = line.split_whitespace().collect();
        let tick: u64 = parts[1].parse().expect("tick");
        let job: u64 = parts[2].parse().expect("job id");
        assert!(tick >= last_tick, "ticks non-decreasing: {line}");
        last_tick = tick;
        match parts[0] {
            "A" => {
                assert_eq!(parts.len(), 5, "{line}");
                let machine: usize = parts[3].parse().expect("machine");
                let position: usize = parts[4].parse().expect("position");
                assert!(machine < 5 && position < 10, "{line}");
                assigned.push(job);
            }
            "R" => {
                assert_eq!(parts.len(), 4, "{line}");
                released.push(job);
            }
            other => panic!("unknown event {other}"),
        }
    }
    assigned.sort_unstable();
    released.sort_unstable();
    let want: Vec<u64> = (1..=JOBS as u64).collect();
    assert_eq!(assigned, want);
    assert_eq!(released, want);
}
