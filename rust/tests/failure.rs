//! Failure injection & edge-case integration tests: saturation/stall
//! recovery, malformed inputs, missing artifacts, pathological workloads.

use stannic::baselines::{WsGreedy, WsRoundRobin};
use stannic::cluster::{Cluster, ClusterConfig, SosCluster};
use stannic::config::RunConfig;
use stannic::coordinator::{serve, serve_sources, ArrivalSource, ServeOpts};
use stannic::core::{Job, JobNature, MachinePark};
use stannic::engine::EngineId;
use stannic::faults::FaultSpec;
use stannic::jsonio::Json;
use stannic::quant::Precision;
use stannic::runtime::ArtifactRegistry;
use stannic::scheduler::SosEngine;
use stannic::sweep::{run_sweep, SweepConfig};
use stannic::workload::{generate_trace, BurstType, Trace, TraceEvent, WorkloadSpec};

#[test]
fn stall_and_recover_under_saturation() {
    // Capacity 1x1: the second job must stall, then assign after the
    // first releases; nothing is lost.
    let mut e = SosEngine::new(1, 1, 1.0, Precision::Int8);
    e.submit(Job::new(1, 10.0, vec![10.0], JobNature::Mixed));
    e.submit(Job::new(2, 10.0, vec![10.0], JobNature::Mixed));
    let mut stalls = 0;
    let mut assigned = vec![];
    let mut released = vec![];
    for _ in 0..100 {
        let out = e.tick(None);
        stalls += out.stalled as usize;
        if let Some(a) = out.assigned {
            assigned.push(a.job);
        }
        released.extend(out.released.iter().map(|r| r.0));
        if e.is_idle() {
            break;
        }
    }
    assert!(stalls > 0, "saturation must stall");
    assert_eq!(assigned, vec![1, 2]);
    assert_eq!(released, vec![1, 2]);
    assert!(e.is_idle());
}

#[test]
fn coordinator_survives_saturating_burst() {
    // 100 jobs all at tick 1 with capacity 5x3=15 — heavy stalling.
    let mut events = Vec::new();
    for id in 1..=100u64 {
        events.push(TraceEvent {
            tick: 1,
            job: Some(
                Job::new(id, 5.0, vec![20.0, 30.0, 25.0, 15.0, 40.0], JobNature::Mixed)
                    .with_arrival(1),
            ),
        });
    }
    let trace = Trace::new(events, 5);
    let engine = EngineId::Sos.build(5, 3, 0.5, Precision::Int8).unwrap();
    let r = serve(engine, &trace, &ServeOpts::new()).unwrap();
    assert_eq!(r.completions.len(), 100);
    assert!(r.stalls > 0);
}

#[test]
fn machine_down_mid_saturation_drains_without_losing_jobs() {
    // The saturating burst again, but machine 2 dies at tick 10 for 40
    // ticks while the burst is still draining. Its queued-but-unstarted
    // slots are evicted back to the pending FIFO; under both head
    // policies every job must still complete exactly once.
    let mut events = Vec::new();
    for id in 1..=100u64 {
        events.push(TraceEvent {
            tick: 1,
            job: Some(
                Job::new(id, 5.0, vec![20.0, 30.0, 25.0, 15.0, 40.0], JobNature::Mixed)
                    .with_arrival(1),
            ),
        });
    }
    let trace = Trace::new(events, 5);
    for policy in ["", ",policy=lose"] {
        let spec = FaultSpec::parse(&format!("down=2@10+40{policy}")).unwrap();
        let opts = ServeOpts::new().with_faults(spec);
        let engine = EngineId::Sos.build(5, 3, 0.5, Precision::Int8).unwrap();
        let r = serve(engine, &trace, &opts).unwrap();
        assert_eq!(r.completions.len(), 100, "policy '{policy}' lost jobs");
        let mut ids: Vec<u64> = r.completions.iter().map(|c| c.job.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 100, "policy '{policy}' duplicated a job");
        let f = r.faults.expect("faulted run must report fault stats");
        assert_eq!((f.downs, f.ups), (1, 1));
        assert!(f.evicted_jobs > 0, "a saturated machine holds evictable slots");
        assert_eq!(f.requeue_latency.count(), f.evicted_jobs);
    }
}

#[test]
fn fault_in_a_proved_empty_window_still_fires() {
    // One short job drains within a few ticks; a down/up cycle sits
    // deep inside the window the golden engine can prove pop-free. The
    // tickless drive must stop exactly at both fault ticks — fault
    // events are release-class on the event horizon — instead of
    // fast-forwarding over them.
    let mut e = SosEngine::new(2, 4, 0.5, Precision::Int8);
    e.install_faults(FaultSpec::parse("down=1@50+25").unwrap().plan(2).unwrap());
    e.submit(Job::new(1, 4.0, vec![4.0, 4.0], JobNature::Mixed));
    let mut visited = Vec::new();
    for _ in 0..100 {
        let Some(next) = e.next_event_tick() else { break };
        visited.push(next);
        e.advance_to(next - 1);
        e.tick(None);
    }
    assert!(visited.contains(&50), "down tick jumped over: {visited:?}");
    assert!(visited.contains(&75), "up tick jumped over: {visited:?}");
    let f = e.fault_stats().expect("fault stats armed");
    assert_eq!((f.downs, f.ups), (1, 1));
    assert_eq!(f.degraded_ticks, 25, "dip accounting must span the jump");
    assert_eq!(f.down_machine_ticks, 25);
    assert!(e.is_idle(), "plan exhausted and work drained");
}

#[test]
fn faulted_sweep_is_thread_count_invariant() {
    // A fixed fault seed must yield a bit-identical rendered report for
    // any worker-pool size (the cell grid is deterministic and cells
    // are independent).
    let mut cfg = SweepConfig::quick();
    cfg.workloads.truncate(1);
    cfg.machine_counts.truncate(1);
    cfg.alphas.truncate(1);
    cfg.jobs = 60;
    cfg.faults = vec!["down=1@25+20,storm=5@30,seed=6".to_string()];
    let render = |threads: usize| {
        let mut c = cfg.clone();
        c.threads = threads;
        let results = run_sweep(&c);
        results.check_parity().expect("faulted cells are parity-isolated");
        results.render()
    };
    assert_eq!(render(1), render(8), "faulted sweep must not depend on --threads");
}

#[test]
fn bounded_arrival_queues_stall_sources_without_losing_jobs() {
    // Backpressure path: queue_depth 1 bounds the per-source arrival
    // channels AND the merge queue, and batch 1 drains one arrival per
    // tick — far slower than two uniform-burst producers emit. Every
    // source must hit a full queue (enqueue stalls > 0), and the run
    // must still complete every job.
    let dense = WorkloadSpec::default()
        .with_burst(6, BurstType::Uniform)
        .with_idle(0, 0);
    let sources = vec![
        ArrivalSource::synthetic("s0", dense.clone(), 5, 150, 3),
        ArrivalSource::synthetic("s1", dense, 5, 150, 4),
    ];
    let opts = ServeOpts::new().with_queue_depth(1).with_batch(1);
    let engine = EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap();
    let r = serve_sources(engine, sources, &opts).unwrap();
    assert_eq!(r.completions.len(), 300, "backpressure must not lose jobs");
    assert_eq!(r.sources.len(), 2);
    for src in &r.sources {
        assert!(
            src.enqueue_stalls > 0,
            "source {} should have stalled on its bounded queue",
            src.name
        );
    }
    // the merge queue respects its bound, and admission respects batch
    assert!(r.merge_depth.max() <= 1, "merge depth {}", r.merge_depth.max());
    assert!(r.batch_sizes.max() <= 1, "batch {}", r.batch_sizes.max());
}

#[test]
fn trace_parser_rejects_corruption() {
    let park = MachinePark::paper_m1_m5();
    let good = generate_trace(&WorkloadSpec::default(), &park, 10, 1).to_text();
    // truncation mid-record is a hard, line-numbered parse error — the
    // parser must never silently accept the surviving prefix
    let bad = &good[..good.len() - 5];
    assert!(Trace::from_text(bad).is_err());
    // header corruption
    assert!(Trace::from_text(&good.replace("machines=5", "machines=abc")).is_err());
    // negative/garbage fields
    assert!(Trace::from_text("# stannic-trace v1 machines=1\nx 1 5 C 1.0 10\n").is_err());
}

#[test]
fn artifact_registry_missing_and_corrupt() {
    assert!(ArtifactRegistry::open("/definitely/not/here").is_err());
    let dir = std::env::temp_dir().join("stannic_corrupt_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    assert!(ArtifactRegistry::open(&dir).is_err());
    std::fs::write(dir.join("manifest.json"), r#"{"configs": []}"#).unwrap();
    assert!(ArtifactRegistry::open(&dir).is_err(), "empty config list");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn xla_engine_rejects_unknown_config() {
    let Ok(reg) = ArtifactRegistry::open_default() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    use stannic::runtime::{CostImpl, XlaCostEngine};
    // 7x13 is not an emitted configuration
    assert!(XlaCostEngine::compile(&reg, CostImpl::Stannic, 7, 13).is_err());
}

#[test]
fn config_round_trip_rejects_bad_values() {
    let j = Json::parse(r#"{"precision": "INT7"}"#).unwrap();
    assert!(RunConfig::from_json(&j).is_err());
    let j = Json::parse(r#"{"engine": "quantum"}"#).unwrap();
    assert!(RunConfig::from_json(&j).is_err());
    let j = Json::parse(r#"{"workload": {"frac_compute": 0.9}}"#).unwrap();
    assert!(RunConfig::from_json(&j).is_err(), "composition must sum to 1");
}

#[test]
fn work_stealing_handles_empty_and_single_queues() {
    // Degenerate park: one machine — stealing must be a no-op, jobs flow.
    let park = MachinePark::homogeneous_cpu(1);
    let trace = generate_trace(
        &WorkloadSpec {
            frac_compute: 1.0,
            frac_memory: 0.0,
            frac_mixed: 0.0,
            ..WorkloadSpec::default()
        },
        &park,
        30,
        3,
    );
    for summary in [
        Cluster::new(park.clone(), ClusterConfig::default())
            .run(&mut WsRoundRobin::new(), &trace),
        Cluster::new(park.clone(), ClusterConfig::default()).run(&mut WsGreedy::new(), &trace),
        Cluster::new(park.clone(), ClusterConfig::default())
            .run(&mut SosCluster::new(1, 10, 0.5, Precision::Int8), &trace),
    ] {
        assert_eq!(summary.completed, 30, "{}", summary.scheduler);
    }
}

#[test]
fn extreme_workloads_drain() {
    let park = MachinePark::paper_m1_m5();
    // max-burst uniform, no idle
    let spec = WorkloadSpec::default()
        .with_burst(6, BurstType::Uniform)
        .with_idle(0, 0);
    let trace = generate_trace(&spec, &park, 500, 77);
    let engine = EngineId::Sos.build(5, 10, 0.5, Precision::Int8).unwrap();
    let r = serve(engine, &trace, &ServeOpts::new()).unwrap();
    assert_eq!(r.completions.len(), 500);

    // pathological weights/EPTs at the representable extremes
    let mut e = SosEngine::new(2, 4, 0.5, Precision::Int8);
    e.submit(Job::new(1, 255.0, vec![10.0, 255.0], JobNature::Compute));
    e.submit(Job::new(2, 1.0, vec![255.0, 10.0], JobNature::Memory));
    for _ in 0..2000 {
        e.tick(None);
        if e.is_idle() {
            break;
        }
    }
    assert!(e.is_idle());
}

#[test]
fn alpha_one_and_tiny_alpha_both_terminate() {
    let park = MachinePark::paper_m1_m5();
    let trace = generate_trace(&WorkloadSpec::default(), &park, 100, 13);
    for alpha in [1.0f32, 0.01] {
        let engine = EngineId::Sos.build(5, 10, alpha, Precision::Int8).unwrap();
        let r = serve(engine, &trace, &ServeOpts::new()).unwrap();
        assert_eq!(r.completions.len(), 100, "alpha={alpha}");
    }
}
