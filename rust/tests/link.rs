//! Timed-interconnect integration tests: the `TimedLink` backpressure
//! layer end to end through `serve_sources` and the artifact record.
//!
//! * ticket conservation — a saturated wire issues exactly as many
//!   tickets as it completes, and every job still finishes exactly once;
//! * graceful degradation — a narrow link throttles admission (typed
//!   stalls, stretched virtual time) but never drops or reorders work;
//! * determinism — for each source count, the schedule digest, tick
//!   count and typed stall counters are bit-identical across reruns and
//!   across queue depths (the merge queue parks, it never races);
//! * compat — an unconstrained run carries no link surface at all, and
//!   its record refuses to pair with a constrained one in `serve diff`.

use stannic::artifact::{diff_records, Artifact, DiffOpts};
use stannic::coordinator::{serve_sources, ArrivalSource, LinkModel, ServeOpts, ServeRecord};
use stannic::engine::EngineId;
use stannic::quant::Precision;
use stannic::workload::WorkloadSpec;

const MACHINES: usize = 5;
const SLOTS: usize = 8;
const JOBS: usize = 160;
const SEED: u64 = 31;

/// One constrained run of the fixed scenario.
fn run_linked(n_sources: usize, depth: usize, width: u64) -> stannic::coordinator::ServeReport {
    serve_sources(
        EngineId::Sos.build(MACHINES, SLOTS, 0.5, Precision::Int8).unwrap(),
        ArrivalSource::standard_mix(&WorkloadSpec::bursty(), MACHINES, JOBS, SEED, n_sources),
        &ServeOpts::new()
            .with_queue_depth(depth)
            .with_link(LinkModel::with_width(width)),
    )
    .unwrap()
}

#[test]
fn saturated_link_conserves_tickets_and_jobs() {
    let r = run_linked(2, 8, 4);
    // every arrival completes exactly once — backpressure parks jobs in
    // the merge queue, it never sheds them
    assert_eq!(r.completions.len(), JOBS);
    let mut ids: Vec<u64> = r.completions.iter().map(|c| c.job.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), JOBS, "a job completed twice or vanished");
    let l = r.link.as_ref().expect("constrained run must report link telemetry");
    // ticket conservation: the serve loop drains the wire before exiting
    assert_eq!(l.issued, l.completed, "tickets in flight at exit");
    assert!(l.issued > 0);
    assert_eq!(l.wait.count(), l.completed, "one wait sample per retired ticket");
    // a 4 B/tick wire under the bursty mix is genuinely saturated: the
    // stall reasons are typed, and at least one fired
    assert!(l.total_stalls() > 0, "narrow link must push back");
    assert_eq!(
        l.total_stalls(),
        l.stall_busy + l.stall_window + l.stall_response,
        "total is exactly the sum of the typed reasons"
    );
}

#[test]
fn narrow_link_degrades_gracefully_against_unbounded_baseline() {
    let linked = run_linked(2, 8, 4);
    let clean = serve_sources(
        EngineId::Sos.build(MACHINES, SLOTS, 0.5, Precision::Int8).unwrap(),
        ArrivalSource::standard_mix(&WorkloadSpec::bursty(), MACHINES, JOBS, SEED, 2),
        &ServeOpts::new().with_queue_depth(8),
    )
    .unwrap();
    // same work either way: the constrained run completes the identical
    // job set (no drops), just later
    let id_set = |r: &stannic::coordinator::ServeReport| {
        let mut ids: Vec<u64> = r.completions.iter().map(|c| c.job.id).collect();
        ids.sort_unstable();
        ids
    };
    assert_eq!(id_set(&linked), id_set(&clean));
    assert!(
        linked.ticks > clean.ticks,
        "a saturated wire must stretch virtual drain time ({} vs {})",
        linked.ticks,
        clean.ticks
    );
    // the unbounded run carries no link surface anywhere: report,
    // summary JSON, record render
    assert!(clean.link.is_none());
    let summary = clean.json_summary().render();
    assert!(!summary.contains("link_"), "clean summary leaked link keys: {summary}");
    let rec = ServeRecord::from_report("clean", &clean);
    let rendered = rec.render();
    assert!(!rendered.contains("link_"), "clean record leaked link keys");
    assert!(!rendered.contains("pcie_fs"), "clean record leaked the link perf cell");
}

#[test]
fn constrained_schedule_is_invariant_across_sources_and_depths() {
    // Within each source count the run is a pure function of the
    // scenario: rerunning, or widening the bounded queues, must not move
    // a single bit of the identity — digest, ticks, or stall counters.
    for n_sources in [1usize, 2, 8] {
        let base = run_linked(n_sources, 2, 6);
        let base_rec = ServeRecord::from_report("l", &base);
        let base_digest = base_rec.compute_digest();
        let bl = base.link.as_ref().unwrap();
        for depth in [8usize, 256] {
            let other = run_linked(n_sources, depth, 6);
            let ol = other.link.as_ref().unwrap();
            assert_eq!(
                ServeRecord::from_report("l", &other).compute_digest(),
                base_digest,
                "digest moved at {n_sources} sources, depth {depth}"
            );
            assert_eq!(other.ticks, base.ticks);
            assert_eq!(other.completions, base.completions);
            assert_eq!(
                (ol.issued, ol.completed, ol.stall_busy, ol.stall_window, ol.stall_response),
                (bl.issued, bl.completed, bl.stall_busy, bl.stall_window, bl.stall_response),
                "typed stall counters raced at {n_sources} sources, depth {depth}"
            );
            assert_eq!(ol.occupancy.p50(), bl.occupancy.p50());
            assert_eq!(ol.wait.p95(), bl.wait.p95());
        }
    }
}

#[test]
fn constrained_and_unbounded_records_refuse_to_pair() {
    let linked = ServeRecord::from_report("linked", &run_linked(2, 8, 4));
    let clean = ServeRecord::from_report(
        "clean",
        &serve_sources(
            EngineId::Sos.build(MACHINES, SLOTS, 0.5, Precision::Int8).unwrap(),
            ArrivalSource::standard_mix(&WorkloadSpec::bursty(), MACHINES, JOBS, SEED, 2),
            &ServeOpts::new().with_queue_depth(8),
        )
        .unwrap(),
    );
    assert_ne!(linked.compute_digest(), clean.compute_digest());
    // the service law is part of the identity: a constrained recording
    // never silently baselines against an unconstrained one
    assert!(!diff_records(&clean, &linked, &DiffOpts::default()).ok());
    assert!(!diff_records(&linked, &clean, &DiffOpts::default()).ok());
    // but a constrained A/B self-diff is parity-clean
    assert!(diff_records(&linked, &linked, &DiffOpts::default()).ok());
}
