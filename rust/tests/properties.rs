//! Property-based invariants (via the in-house `testing` substrate; see
//! `STANNIC_PROP_SEED` for failure replay):
//!
//! * Definition 4 proper ordering survives arbitrary operation sequences
//! * conservation: every submitted job is assigned exactly once and
//!   released exactly once
//! * cost positivity/monotonicity properties of Eq. (4)/(5)
//! * stannic memoized sums == recomputed sums under random drive
//! * workload generator determinism & composition bounds
//! * sweep results are byte-identical for any worker-thread count
//! * the multi-source serve pipeline yields one schedule for any thread
//!   interleaving and any bounded-queue depth

use stannic::coordinator::{serve_sources, ArrivalSource, ServeOpts};
use stannic::core::{Job, JobNature, MachinePark};
use stannic::engine::EngineId;
use stannic::quant::Precision;
use stannic::scheduler::{cost_of, SosEngine};
use stannic::sim::{stannic::StannicSim, ArchSim};
use stannic::sweep::{run_sweep, SweepConfig};
use stannic::testing::{check, property};
use stannic::workload::{generate_trace, Rng, WorkloadSpec};

fn random_job(rng: &mut Rng, id: u64, machines: usize) -> Job {
    let w = rng.uniform(1.0, 255.0).round();
    let ept = (0..machines)
        .map(|_| rng.uniform(10.0, 255.0).round())
        .collect();
    Job::new(id, w, ept, JobNature::Mixed)
}

#[test]
fn prop_ordering_invariant_under_random_drive() {
    property("proper ordering", 120, |rng| {
        let m = rng.range(1, 6);
        let d = rng.range(2, 12);
        let alpha = rng.uniform(0.1, 1.0);
        let mut engine = SosEngine::new(m, d, alpha, Precision::Int8);
        let mut next_id = 1u64;
        for _ in 0..rng.range(20, 120) {
            let arrival = rng.chance(0.4).then(|| {
                let j = random_job(rng, next_id, m);
                next_id += 1;
                j
            });
            engine.tick(arrival.as_ref());
            for vs in engine.schedules() {
                check(vs.is_properly_ordered(), "WSPT non-increasing")?;
                check(vs.len() <= d, "depth bound")?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_conservation_every_job_assigned_and_released_once() {
    property("conservation", 60, |rng| {
        let m = rng.range(1, 5);
        let d = rng.range(2, 8);
        let mut engine = SosEngine::new(m, d, 0.5, Precision::Int8);
        let n_jobs = rng.range(5, 60);
        for id in 1..=n_jobs as u64 {
            engine.submit(random_job(rng, id, m));
        }
        let mut assigned = Vec::new();
        let mut released = Vec::new();
        for _ in 0..2_000_000u64 {
            let out = engine.tick(None);
            if let Some(a) = out.assigned {
                assigned.push(a.job);
            }
            released.extend(out.released.iter().map(|(id, _)| *id));
            if engine.is_idle() {
                break;
            }
        }
        check(engine.is_idle(), "engine drained")?;
        assigned.sort_unstable();
        released.sort_unstable();
        let want: Vec<u64> = (1..=n_jobs as u64).collect();
        check(assigned == want, "each job assigned exactly once")?;
        check(released == want, "each job released exactly once")
    });
}

#[test]
fn prop_cost_is_positive_and_scales_with_load() {
    property("cost positivity/monotonicity", 100, |rng| {
        let d = rng.range(3, 12);
        let mut engine = SosEngine::new(1, d, 1.0, Precision::Fp32);
        // fill the schedule progressively; the cost of a fixed probe job
        // must be strictly non-decreasing as incumbents accumulate
        let probe_w = rng.uniform(1.0, 255.0).round();
        let probe_e = rng.uniform(10.0, 255.0).round();
        let probe_t = probe_w / probe_e;
        let mut last_cost = 0.0f32;
        for id in 1..d as u64 {
            let c = cost_of(engine.schedule(0), probe_w, probe_e, probe_t)
                .expect("not full");
            check(c.total() > 0.0, "positive cost")?;
            check(
                c.total() >= last_cost,
                "cost non-decreasing with queued work",
            )?;
            last_cost = c.total();
            engine.submit(random_job(rng, id, 1));
            engine.tick(None);
        }
        Ok(())
    });
}

#[test]
fn prop_stannic_memoized_sums_exact() {
    // Random drive of the systolic simulator, cross-checking its
    // memoized threshold sums against the golden engine's rescans.
    property("memoized sums", 60, |rng| {
        let m = rng.range(1, 4);
        let d = rng.range(2, 10);
        let mut golden = SosEngine::new(m, d, 0.5, Precision::Int8);
        let mut sim = StannicSim::new(m, d, 0.5, Precision::Int8);
        let mut next_id = 1u64;
        for _ in 0..rng.range(30, 150) {
            let arrival = rng.chance(0.4).then(|| {
                let j = random_job(rng, next_id, m);
                next_id += 1;
                j
            });
            if let Some(j) = &arrival {
                golden.submit(j.clone());
                ArchSim::submit(&mut sim, j.clone());
            }
            golden.tick(None);
            ArchSim::tick(&mut sim, None);
            // the tickless engine materializes virtual work lazily; sync
            // it so slot n values match the per-tick simulator's view
            golden.materialize();
            for mac in 0..m {
                let vs = golden.schedule(mac);
                let arr = &sim.smmu(mac).array;
                check(arr.len() == vs.len(), "occupancy parity")?;
                check(arr.properly_ordered(), "Definition 4")?;
                // verify memoized prefix/suffix at every fill level
                let slots = vs.slots();
                let mut prefix = 0.0f32;
                for (k, slot) in slots.iter().enumerate() {
                    prefix += slot.rem_hi();
                    let pe = &arr.pes()[k];
                    check(
                        (pe.sum_hi - prefix).abs() < 1e-2,
                        "sum_hi memoization exact",
                    )?;
                }
                let mut suffix = 0.0f32;
                for (k, slot) in slots.iter().enumerate().rev() {
                    suffix += slot.rem_lo();
                    let pe = &arr.pes()[k];
                    check(
                        (pe.sum_lo - suffix).abs() < 1e-2,
                        "sum_lo memoization exact",
                    )?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_vschedule_memoized_sums_exact() {
    // The golden engine's virtual schedules now carry the same memoized
    // threshold sums as the PE array; under the quantized datapath the
    // memoized reads must equal the rescans *bit-exactly* at any point
    // of a random engine drive (this is what keeps the memoized cost
    // path from ever changing a schedule).
    property("vschedule memoized sums", 60, |rng| {
        let m = rng.range(1, 5);
        let d = rng.range(2, 12);
        let mut engine = SosEngine::new(m, d, 0.5, Precision::Int8);
        let mut next_id = 1u64;
        for _ in 0..rng.range(30, 150) {
            let arrival = rng.chance(0.4).then(|| {
                let j = random_job(rng, next_id, m);
                next_id += 1;
                j
            });
            engine.tick(arrival.as_ref());
            for vs in engine.schedules() {
                let probe_w = rng.uniform(1.0, 255.0).round();
                let probe_e = rng.uniform(10.0, 255.0).round();
                let probe = Precision::Int8.q_wspt(probe_w / probe_e);
                let (hi, lo, pos) = vs.threshold_read(probe);
                check(hi == vs.sum_hi(probe), "memoized sum_hi bit-exact")?;
                check(lo == vs.sum_lo(probe), "memoized sum_lo bit-exact")?;
                check(pos == vs.position_for(probe), "threshold position")?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_workload_generator_bounds() {
    property("workload bounds", 40, |rng| {
        let park = MachinePark::cycled(rng.range(1, 20));
        let spec = WorkloadSpec::default();
        let n = rng.range(1, 120);
        let seed = rng.next_u64();
        let a = generate_trace(&spec, &park, n, seed);
        let b = generate_trace(&spec, &park, n, seed);
        check(a == b, "deterministic per seed")?;
        check(a.n_jobs() == n, "exact job count")?;
        for j in a.jobs() {
            check(j.fanout() == park.len(), "EPT fanout")?;
            check(j.weight >= 1.0, "weight floor")?;
            check(j.ept.iter().all(|&e| (10.0..=255.0).contains(&e)), "EPT range")?;
        }
        Ok(())
    });
}

#[test]
fn prop_sweep_identical_across_worker_counts() {
    // Parallelism must not leak into results: the same grid swept on 1,
    // 2, and 8 workers renders byte-identical output and identical
    // per-cell metrics (work stealing only changes who computes a cell,
    // never what the cell computes).
    property("sweep thread determinism", 4, |rng| {
        let mut cfg = SweepConfig {
            engines: EngineId::SOFTWARE.to_vec(),
            workloads: vec![
                ("even".to_string(), WorkloadSpec::even()),
                ("memory".to_string(), WorkloadSpec::memory_skewed()),
            ],
            machine_counts: vec![rng.range(2, 4)],
            alphas: vec![rng.uniform(0.2, 0.9)],
            precisions: vec![Precision::Int8],
            depth: rng.range(4, 8),
            jobs: rng.range(20, 50),
            seed: rng.next_u64(),
            threads: 1,
            faults: Vec::new(),
            link_widths: Vec::new(),
        };
        let one = run_sweep(&cfg);
        cfg.threads = 2;
        let two = run_sweep(&cfg);
        cfg.threads = 8;
        let eight = run_sweep(&cfg);
        check(one.render() == two.render(), "1-thread output == 2-thread output")?;
        check(one.render() == eight.render(), "1-thread output == 8-thread output")?;
        for (a, b) in one.cells.iter().zip(&eight.cells) {
            check(a.cell.id == b.cell.id, "slot order preserved")?;
            check(
                a.metrics.jobs_per_machine == b.metrics.jobs_per_machine,
                "schedule identity",
            )?;
            check(a.metrics.avg_latency == b.metrics.avg_latency, "latency identity")?;
            check(a.utilization == b.utilization, "utilization identity")?;
            check(
                a.p99 == b.p99 && a.ticks == b.ticks && a.stalls == b.stalls,
                "counter identity",
            )?;
        }
        check(one.check_parity().is_ok(), "cross-engine schedule parity")?;
        Ok(())
    });
}

#[test]
fn prop_multisource_serve_deterministic_for_any_interleaving() {
    // The merged arrival order is a pure function of (virtual tick,
    // source id, per-source FIFO order): re-running the same source set
    // must reproduce the schedule bit-for-bit regardless of how the OS
    // interleaves the source threads, and shrinking every bounded queue
    // to depth 2 (maximum backpressure, different interleavings again)
    // must not change it either — queue bounds may only move the
    // *telemetry*, never the schedule.
    property("multi-source serve determinism", 3, |rng| {
        let total_jobs = rng.range(40, 90);
        let seed = rng.next_u64();
        let batch = rng.range(1, 4);
        for n_sources in [1usize, 2, 8] {
            let run = |queue_depth: usize| {
                let sources = ArrivalSource::standard_mix(
                    &WorkloadSpec::default(),
                    5,
                    total_jobs,
                    seed,
                    n_sources,
                );
                let opts = ServeOpts::new()
                    .with_queue_depth(queue_depth)
                    .with_batch(batch);
                let engine = EngineId::Sos.build(5, 8, 0.5, Precision::Int8).unwrap();
                serve_sources(engine, sources, &opts).unwrap()
            };
            let a = run(2);
            let b = run(2);
            let wide = run(256);
            check(a.completions.len() == total_jobs, "all jobs complete")?;
            check(
                a.completions == b.completions,
                "schedule identical across reruns (interleaving-free)",
            )?;
            check(
                a.completions == wide.completions,
                "schedule independent of queue depth",
            )?;
            check(a.ticks == b.ticks && a.ticks == wide.ticks, "tick counts identical")?;
            check(
                a.metrics.jobs_per_machine == wide.metrics.jobs_per_machine,
                "distribution identical",
            )?;
            // the deterministic telemetry reproduces too (for a fixed
            // queue depth; depth changes legitimately move these)
            check(
                a.merge_depth.p50() == b.merge_depth.p50()
                    && a.merge_depth.max() == b.merge_depth.max(),
                "merge-depth histogram deterministic",
            )?;
            check(
                a.batch_sizes.count() == b.batch_sizes.count()
                    && a.batch_sizes.max() == b.batch_sizes.max(),
                "batch-size histogram deterministic",
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_quantization_idempotent() {
    property("quantization idempotence", 80, |rng| {
        use stannic::quant::Precision;
        let w = rng.uniform(1.0, 300.0);
        let e = rng.uniform(10.0, 300.0);
        for p in Precision::ALL {
            let (wq, eq, tq) = p.q_job(w, e);
            // quantizing a quantized value is a fixed point
            check(p.q_weight(wq) == wq, "weight idempotent")?;
            check(p.q_ept(eq) == eq, "ept idempotent")?;
            check(p.q_wspt(tq) == tq, "wspt idempotent")?;
        }
        Ok(())
    });
}
