//! Tickless-core equivalence suite: the event-horizon fast-forward path
//! must be *semantically invisible*. For random workloads across all
//! five precision schemes, the jump-driven golden engine must produce
//! bit-identical schedules, `TickOutcome` sequences and FNV-1a schedule
//! digests versus (a) the same engine driven by the historical
//! tick-by-tick loop — kept here verbatim as the test oracle — and
//! (b) the independently-implemented eager SOSC baseline.

use stannic::artifact::fnv1a64_hex;
use stannic::baselines::SoscEngine;
use stannic::core::{Job, JobNature, MachinePark};
use stannic::quant::Precision;
use stannic::scheduler::{drive_trace, SosEngine, TickOutcome};
use stannic::testing::{check, property};
use stannic::workload::{generate_trace, Rng, Trace, WorkloadSpec};

/// One schedule event, tick-stamped: the comparable projection of a
/// non-empty [`TickOutcome`].
type Event = (u64, Vec<(u64, usize)>, Option<(u64, usize, usize)>, bool);

fn project(tick: u64, out: &TickOutcome) -> Event {
    (
        tick,
        out.released.clone(),
        out.assigned.as_ref().map(|a| (a.job, a.machine, a.position)),
        out.stalled,
    )
}

/// FNV-1a digest over an event log — the same digest family the
/// artifact layer uses for schedule identity.
fn digest(events: &[Event]) -> String {
    let mut canon = String::new();
    for (tick, released, assigned, stalled) in events {
        canon.push_str(&format!("{tick}|{released:?}|{assigned:?}|{stalled}\n"));
    }
    fnv1a64_hex(canon.as_bytes())
}

/// The engines the per-tick oracle can drive: the golden engine (so the
/// jumped drive compares against its own tick-by-tick semantics) and
/// the naive SOSC baseline (an independent eager implementation —
/// per-tick by construction, it reconstructs virtual work from history
/// logs, nothing lazy anywhere).
trait EagerDrive {
    fn submit_job(&mut self, job: Job);
    fn tick_once(&mut self) -> TickOutcome;
    fn drained(&self) -> bool;
}

impl EagerDrive for SosEngine {
    fn submit_job(&mut self, job: Job) {
        self.submit(job);
    }
    fn tick_once(&mut self) -> TickOutcome {
        self.tick(None)
    }
    fn drained(&self) -> bool {
        self.is_idle()
    }
}

impl EagerDrive for SoscEngine {
    fn submit_job(&mut self, job: Job) {
        self.submit(job);
    }
    fn tick_once(&mut self) -> TickOutcome {
        self.tick(None)
    }
    fn drained(&self) -> bool {
        self.is_idle()
    }
}

/// The OLD drive loop, kept verbatim as the oracle: tick every virtual
/// tick, record every non-empty outcome. Returns (events, final tick).
fn drive_per_tick<E: EagerDrive>(engine: &mut E, trace: &Trace, max_ticks: u64) -> (Vec<Event>, u64) {
    let mut events = trace.events().iter().peekable();
    let mut log = Vec::new();
    let mut t = 0u64;
    loop {
        t += 1;
        assert!(t <= max_ticks, "oracle did not drain");
        while events.peek().is_some_and(|e| e.tick <= t) {
            engine.submit_job(events.next().unwrap().job.clone().unwrap());
        }
        let out = engine.tick_once();
        if out != TickOutcome::default() {
            log.push(project(t, &out));
        }
        if engine.drained() && events.peek().is_none() {
            return (log, t);
        }
    }
}

fn random_spec(rng: &mut Rng) -> WorkloadSpec {
    // span saturated bursts, steady streams and long sparse gaps — the
    // regimes where the horizon logic differs most
    let spec = WorkloadSpec {
        burst_factor: rng.range(1, 6),
        ..WorkloadSpec::default()
    };
    if rng.chance(0.5) {
        spec.with_idle(rng.range(1, 400) as u64, rng.range(2, 12))
    } else {
        spec
    }
}

#[test]
fn prop_fast_forward_bit_identical_across_all_precisions() {
    property("tickless == per-tick oracle", 12, |rng| {
        let machines = rng.range(2, 8);
        let depth = rng.range(2, 10);
        let jobs = rng.range(20, 90);
        let alpha = [0.1f32, 0.25, 0.5, 0.75, 1.0][rng.range(0, 4)];
        let seed = rng.next_u64();
        let park = MachinePark::cycled(machines);
        let spec = random_spec(rng);
        let trace = generate_trace(&spec, &park, jobs, seed);
        let max = 50_000_000u64;

        for precision in Precision::ALL {
            // oracle: the historical per-tick loop over a fresh engine
            let mut oracle = SosEngine::new(machines, depth, alpha, precision);
            let (oracle_log, oracle_ticks) = drive_per_tick(&mut oracle, &trace, max);

            // tickless: the event-jumping driver
            let mut engine = SosEngine::new(machines, depth, alpha, precision);
            let mut log = Vec::new();
            let stats = drive_trace(&mut engine, &trace, max, |tick, out| {
                if *out != TickOutcome::default() {
                    log.push(project(tick, out));
                }
            })
            .map_err(|e| format!("{} tickless drive failed: {e}", precision.name()))?;

            check(
                stats.ticks == oracle_ticks,
                "virtual tick count preserved",
            )?;
            check(
                stats.iterations <= stats.ticks,
                "never more iterations than ticks",
            )?;
            check(log == oracle_log, "TickOutcome event streams bit-identical")?;
            check(
                digest(&log) == digest(&oracle_log),
                "FNV schedule digests identical",
            )?;

            // cross-implementation oracle: the eager SOSC baseline (its
            // TickOutcome carries no cost field differences — project()
            // compares job/machine/position/stall/release only)
            let mut sosc = SoscEngine::new(machines, depth, alpha, precision);
            let (sosc_log, sosc_ticks) = drive_per_tick(&mut sosc, &trace, max);
            check(sosc_ticks == stats.ticks, "sosc agrees on virtual time")?;
            check(
                log == sosc_log,
                "independent eager implementation agrees",
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_fast_forward_saves_iterations_on_sparse_workloads() {
    property("tickless skips idle windows", 8, |rng| {
        let park = MachinePark::cycled(rng.range(2, 6));
        let spec = WorkloadSpec::default().with_idle(rng.range(300, 900) as u64, 3);
        let trace = generate_trace(&spec, &park, rng.range(20, 60), rng.next_u64());
        let mut engine = SosEngine::new(park.len(), 8, 0.5, Precision::Int8);
        let stats = drive_trace(&mut engine, &trace, 50_000_000, |_, _| {})
            .map_err(|e| format!("drive failed: {e}"))?;
        check(
            stats.iterations * 5 <= stats.ticks,
            "sparse workload must skip >=5x of its virtual ticks",
        )
    });
}

#[test]
fn burst_saturation_stall_ticks_are_never_skipped() {
    // Saturate a 2x2 park with a 30-job burst: every backlogged tick
    // must execute (assign or stall), so the tickless event stream —
    // including per-tick stall outcomes — matches the oracle exactly.
    let mut events = Vec::new();
    for id in 1..=30u64 {
        events.push(stannic::workload::TraceEvent {
            tick: 1,
            job: Some(Job::new(id, 10.0, vec![30.0, 45.0], JobNature::Mixed).with_arrival(1)),
        });
    }
    let trace = Trace::new(events, 2);
    let mut oracle = SosEngine::new(2, 2, 1.0, Precision::Int8);
    let (oracle_log, oracle_ticks) = drive_per_tick(&mut oracle, &trace, 1_000_000);
    assert!(
        oracle_log.iter().any(|(_, _, _, stalled)| *stalled),
        "scenario must actually stall"
    );

    let mut engine = SosEngine::new(2, 2, 1.0, Precision::Int8);
    let mut log = Vec::new();
    let stats = drive_trace(&mut engine, &trace, 1_000_000, |tick, out| {
        if *out != TickOutcome::default() {
            log.push(project(tick, out));
        }
    })
    .unwrap();
    assert_eq!(stats.ticks, oracle_ticks);
    assert_eq!(log, oracle_log, "stall-for-stall identical under saturation");
}
